#!/usr/bin/env python3
"""Reconstruct incident timelines from an artifacts directory.

  PYTHONPATH=src python tools/incidents.py out/            # print + write
  PYTHONPATH=src python tools/incidents.py out/ --no-write # print only

Reads ``events.jsonl`` (the trace ``--artifacts`` runs export), folds it
into causal incident timelines with :func:`repro.obs.reconstruct_incidents`
— fault windows, the alerts they triggered, detection latency against the
ground-truth schedule, time-to-mitigation and time-to-clear — then prints
the markdown section and writes the machine-readable ``incidents.json``
next to the trace. ``tools/report.py`` inlines the same section into
``report.md`` when that file is present.
"""

from __future__ import annotations

import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.obs.export import EVENTS_NAME, read_events, read_manifest  # noqa: E402
from repro.obs.incidents import (  # noqa: E402
    INCIDENTS_NAME,
    incidents_json,
    reconstruct_incidents,
    render_incidents_markdown,
)


def build_incidents(d: str, *, write: bool = True) -> dict:
    """Reconstruct from ``d``/events.jsonl; optionally write incidents.json.
    Returns ``(machine-readable dict, IncidentReport, tick_s)``."""
    events_path = os.path.join(d, EVENTS_NAME)
    if not os.path.exists(events_path):
        raise FileNotFoundError(
            f"{events_path} not found — run a benchmark with --artifacts")
    events = read_events(events_path)
    tick_s = 2.0
    try:
        m = read_manifest(d)
        sc = m.get("scenario") or {}
        if isinstance(sc, dict):
            tick_s = float((sc.get("telemetry") or {})
                           .get("telemetry_s", tick_s) or tick_s)
    except (OSError, ValueError):
        pass
    report = reconstruct_incidents(events)
    doc = incidents_json(report, tick_s=tick_s)
    if write:
        with open(os.path.join(d, INCIDENTS_NAME), "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True)
            f.write("\n")
    return doc, report, tick_s


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    write = "--no-write" not in argv
    argv = [a for a in argv if a != "--no-write"]
    if len(argv) != 1:
        print(__doc__)
        return 2
    try:
        _, report, tick_s = build_incidents(argv[0], write=write)
    except FileNotFoundError as exc:
        print(str(exc), file=sys.stderr)
        return 1
    print(render_incidents_markdown(report, tick_s=tick_s), end="")
    return 0


if __name__ == "__main__":
    sys.exit(main())
