#!/usr/bin/env bash
# Pre-merge smoke gate: tier-1 tests + the table2 quick benchmark, so policy
# regressions surface before merge (DESIGN.md §7).
#
#   bash tools/smoke.sh
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 pytest =="
python -m pytest -x -q

echo "== table2 quick benchmark =="
python -m benchmarks.run --quick --only table2

echo "== capacity-planning quick benchmark =="
python -m benchmarks.run --quick --only capacity

echo "== fleet-routing quick benchmark =="
python -m benchmarks.run --quick --only fleet_routing

echo "== fleet-rebalance quick benchmark =="
python -m benchmarks.run --quick --only fleet_rebalance

echo "== site-hierarchy quick benchmark =="
python -m benchmarks.run --quick --only site_hierarchy

echo "== chaos-resilience quick benchmark =="
python -m benchmarks.run --quick --only chaos_resilience

echo "== scenario + registry docs sync check =="
python tools/gen_scenario_docs.py --check

echo "smoke OK"
