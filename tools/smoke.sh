#!/usr/bin/env bash
# Pre-merge smoke gate: tier-1 tests + the table2 quick benchmark, so policy
# regressions surface before merge (DESIGN.md §7).
#
#   bash tools/smoke.sh
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 pytest =="
python -m pytest -x -q

echo "== table2 quick benchmark =="
python -m benchmarks.run --quick --only table2

echo "== capacity-planning quick benchmark =="
python -m benchmarks.run --quick --only capacity

echo "== fleet-routing quick benchmark =="
python -m benchmarks.run --quick --only fleet_routing

echo "== fleet-rebalance quick benchmark =="
python -m benchmarks.run --quick --only fleet_rebalance

echo "== site-hierarchy quick benchmark =="
python -m benchmarks.run --quick --only site_hierarchy

echo "== chaos-resilience quick benchmark =="
python -m benchmarks.run --quick --only chaos_resilience

echo "== observability quick benchmark =="
python -m benchmarks.run --quick --only observability

echo "== alerting quick benchmark =="
python -m benchmarks.run --quick --only alerting

echo "== batched-engine quick benchmark (grid engine + kernel parity + tails) =="
# forced host devices exercise the sharded member axis; the grid rows record
# members/sec trajectory into BENCH_batched_engine.json via --artifacts below
ARTIFACTS_DIR="${ARTIFACTS_DIR:-out/smoke-artifacts}"
rm -rf "$ARTIFACTS_DIR"
XLA_FLAGS="--xla_force_host_platform_device_count=8${XLA_FLAGS:+ $XLA_FLAGS}" \
    python -m benchmarks.run --quick --only batched_engine \
    --artifacts "$ARTIFACTS_DIR"

echo "== artifact pipeline (instrumented run -> manifest/metrics/events/incidents/report) =="
python -m benchmarks.run --quick --only table2,alerting --artifacts "$ARTIFACTS_DIR"
python tools/incidents.py "$ARTIFACTS_DIR" > /dev/null
python - "$ARTIFACTS_DIR" <<'EOF'
import json, os, sys
d = sys.argv[1]
from repro.obs.export import read_events, read_manifest, read_prometheus
man = read_manifest(d)
assert man["numpy"] and "git_sha" in man, man
read_prometheus(os.path.join(d, "metrics.prom"))
read_events(os.path.join(d, "events.jsonl"))
bench = [p for p in os.listdir(d) if p.startswith("BENCH_") and p.endswith(".json")]
assert bench, f"no BENCH_*.json under {d}"
for p in bench:
    with open(os.path.join(d, p)) as f:
        assert json.load(f)["rows"] is not None, f"{p}: module raised"
with open(os.path.join(d, "incidents.json")) as f:
    inc = json.load(f)
assert inc["n_incidents"] >= 1, inc  # the alerting module injects real faults
assert inc["n_false_alarms"] == 0, inc
print(f"artifacts OK: {sorted(os.listdir(d))}")
EOF
python tools/report.py "$ARTIFACTS_DIR" > "$ARTIFACTS_DIR/report.md"
echo "report: $ARTIFACTS_DIR/report.md"

echo "== scenario + registry docs sync check =="
python tools/gen_scenario_docs.py --check

echo "smoke OK"
