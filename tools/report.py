#!/usr/bin/env python3
"""Render (or diff) a benchmark artifacts directory as a markdown report.

  PYTHONPATH=src python tools/report.py out/          # one-run report
  PYTHONPATH=src python tools/report.py old/ new/     # perf-trajectory diff

A report covers the run manifest, the PASS/FAIL table folded from every
``BENCH_<module>.json``, a span "flame" summary (the wall-clock stage
profile from ``metrics.prom``), and the top event counts from
``events.jsonl``. The diff mode compares two artifact dirs row by row:
validation regressions (PASS -> FAIL) and per-row timing deltas — the
artifact pipeline's answer to "what did this PR do to the benchmarks".
"""

from __future__ import annotations

import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.obs.export import (  # noqa: E402
    EVENTS_NAME,
    MANIFEST_NAME,
    METRICS_NAME,
    read_events,
    read_manifest,
    read_prometheus,
)


def _load_bench(d: str) -> dict:
    """{module: {row_name: {us_per_call, derived, ok}} | None} from every
    BENCH_*.json under ``d``."""
    out = {}
    for path in sorted(glob.glob(os.path.join(d, "BENCH_*.json"))):
        with open(path) as f:
            rec = json.load(f)
        out[rec.get("module",
                    os.path.basename(path)[len("BENCH_"):-len(".json")])] = \
            rec.get("rows")
    return out


def _flag(ok) -> str:
    return "PASS" if ok is True else ("FAIL" if ok is False else "-")


def render_report(d: str) -> str:
    lines = [f"# Benchmark run report — `{d}`", ""]

    # -- manifest ------------------------------------------------------------
    manifest_path = os.path.join(d, MANIFEST_NAME)
    if os.path.exists(manifest_path):
        m = read_manifest(d)
        lines += ["## Manifest", ""]
        for key in ("kind", "quick", "seed", "git_sha", "python", "numpy",
                    "jax", "platform", "wall_clock_s", "validation_failures"):
            if key in m and m[key] is not None:
                lines.append(f"- **{key}**: `{m[key]}`")
        if m.get("argv"):
            lines.append(f"- **argv**: `{' '.join(map(str, m['argv']))}`")
        lines.append("")

    # -- PASS/FAIL table -----------------------------------------------------
    bench = _load_bench(d)
    if bench:
        lines += ["## Benchmarks", "",
                  "| module | rows | pass | fail |",
                  "|---|---:|---:|---:|"]
        failures = []
        for module, rows in bench.items():
            if rows is None:
                lines.append(f"| {module} | - | - | ERROR |")
                failures.append((module, "<module raised>", ""))
                continue
            n_pass = sum(1 for r in rows.values() if r["ok"] is True)
            n_fail = sum(1 for r in rows.values() if r["ok"] is False)
            lines.append(f"| {module} | {len(rows)} | {n_pass} | {n_fail} |")
            failures += [(module, name, r["derived"])
                         for name, r in rows.items() if r["ok"] is False]
        lines.append("")
        if failures:
            lines += ["### Failing rows", ""]
            lines += [f"- `{mod}` / `{name}`: {derived}"
                      for mod, name, derived in failures]
            lines.append("")

    # -- span flame summary --------------------------------------------------
    metrics_path = os.path.join(d, METRICS_NAME)
    if os.path.exists(metrics_path):
        prom = read_prometheus(metrics_path)
        sums = prom.get("summary", {})
        spans = []
        for name, series in sums.items():
            if not name.endswith("_seconds_sum"):
                continue
            base = name[:-len("_seconds_sum")]
            counts = {tuple(sorted(lb.items())): v for lb, v in
                      sums.get(base + "_seconds_count", [])}
            for labels, total in series:
                key = tuple(sorted(labels.items()))
                n = counts.get(key, 0.0)
                spans.append((total, n, base, labels))
        if spans:
            lines += ["## Stage spans (wall-clock)", "",
                      "| stage | labels | calls | total s | mean s |",
                      "|---|---|---:|---:|---:|"]
            for total, n, base, labels in sorted(spans, reverse=True)[:20]:
                lb = ",".join(f"{k}={v}" for k, v in sorted(labels.items()))
                mean = total / n if n else 0.0
                lines.append(f"| {base} | {lb or '-'} | {n:.0f} "
                             f"| {total:.3f} | {mean:.3f} |")
            lines.append("")

    # -- top events ----------------------------------------------------------
    events_path = os.path.join(d, EVENTS_NAME)
    if os.path.exists(events_path):
        counts: dict = {}
        for e in read_events(events_path):
            key = (e.subsystem, e.kind)
            counts[key] = counts.get(key, 0) + 1
        if counts:
            lines += ["## Events", "",
                      "| subsystem | kind | count |", "|---|---|---:|"]
            for (sub, kind), n in sorted(counts.items(),
                                         key=lambda kv: -kv[1])[:15]:
                lines.append(f"| {sub} | {kind} | {n} |")
            lines.append(f"\n{sum(counts.values())} events total.")
            lines.append("")
    return "\n".join(lines)


def render_diff(old: str, new: str) -> str:
    """Row-by-row comparison of two artifact dirs."""
    a, b = _load_bench(old), _load_bench(new)
    lines = [f"# Benchmark diff — `{old}` -> `{new}`", ""]
    regressions, fixes, timing = [], [], []
    for module in sorted(set(a) | set(b)):
        ra, rb = a.get(module), b.get(module)
        if ra is None or rb is None:
            lines.append(f"- `{module}`: only in "
                         f"`{old if module in a else new}` (or raised)")
            continue
        for name in sorted(set(ra) | set(rb)):
            va, vb = ra.get(name), rb.get(name)
            if va is None or vb is None:
                lines.append(f"- `{module}` / `{name}`: "
                             f"{'removed' if vb is None else 'added'}")
                continue
            if va["ok"] != vb["ok"]:
                (regressions if vb["ok"] is False else fixes).append(
                    (module, name, _flag(va["ok"]), _flag(vb["ok"]),
                     vb["derived"]))
            ua, ub = va["us_per_call"], vb["us_per_call"]
            if ua > 0 and ub > 0:
                timing.append((ub / ua - 1.0, module, name, ua, ub))
    if regressions:
        lines += ["## Regressions", ""]
        lines += [f"- `{m}` / `{n}`: {fa} -> {fb} — {d}"
                  for m, n, fa, fb, d in regressions]
        lines.append("")
    if fixes:
        lines += ["## Newly passing / changed validation", ""]
        lines += [f"- `{m}` / `{n}`: {fa} -> {fb}"
                  for m, n, fa, fb, _ in fixes]
        lines.append("")
    if timing:
        lines += ["## Largest timing deltas", "",
                  "| row | old us | new us | delta |", "|---|---:|---:|---:|"]
        for delta, module, name, ua, ub in sorted(
                timing, key=lambda x: -abs(x[0]))[:15]:
            lines.append(f"| {module}/{name} | {ua:.1f} | {ub:.1f} "
                         f"| {delta:+.1%} |")
        lines.append("")
    if not (regressions or fixes or timing):
        lines.append("No comparable rows.")
    return "\n".join(lines)


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    if len(argv) == 1:
        print(render_report(argv[0]))
        return 0
    if len(argv) == 2:
        print(render_diff(argv[0], argv[1]))
        return 0
    print(__doc__)
    return 2


if __name__ == "__main__":
    sys.exit(main())
