#!/usr/bin/env python3
"""Render (or diff) a benchmark artifacts directory as a markdown report.

  PYTHONPATH=src python tools/report.py out/          # one-run report
  PYTHONPATH=src python tools/report.py old/ new/     # perf-trajectory diff
  PYTHONPATH=src python tools/report.py --json ...    # machine-readable

A report covers the run manifest, the PASS/FAIL table folded from every
``BENCH_<module>.json``, a span "flame" summary (the wall-clock stage
profile from ``metrics.prom``), the top event counts from ``events.jsonl``,
and — when ``tools/incidents.py`` has left an ``incidents.json`` behind —
the reconstructed incident timelines. The diff mode compares two artifact
dirs row by row: validation regressions (PASS -> FAIL) and per-row timing
deltas — the artifact pipeline's answer to "what did this PR do to the
benchmarks". With ``--json`` the same facts come out as one JSON document
on stdout (CI-parseable); in diff mode the exit code is 1 when any row
regressed PASS -> FAIL, so pipelines fail loudly instead of paging through
markdown.
"""

from __future__ import annotations

import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.obs.export import (  # noqa: E402
    EVENTS_NAME,
    MANIFEST_NAME,
    METRICS_NAME,
    read_events,
    read_manifest,
    read_prometheus,
)
from repro.obs.incidents import INCIDENTS_NAME  # noqa: E402


def _load_bench(d: str) -> dict:
    """{module: {row_name: {us_per_call, derived, ok}} | None} from every
    BENCH_*.json under ``d``."""
    out = {}
    for path in sorted(glob.glob(os.path.join(d, "BENCH_*.json"))):
        with open(path) as f:
            rec = json.load(f)
        out[rec.get("module",
                    os.path.basename(path)[len("BENCH_"):-len(".json")])] = \
            rec.get("rows")
    return out


def _flag(ok) -> str:
    return "PASS" if ok is True else ("FAIL" if ok is False else "-")


def render_report(d: str) -> str:
    lines = [f"# Benchmark run report — `{d}`", ""]

    # -- manifest ------------------------------------------------------------
    manifest_path = os.path.join(d, MANIFEST_NAME)
    if os.path.exists(manifest_path):
        m = read_manifest(d)
        lines += ["## Manifest", ""]
        for key in ("kind", "quick", "seed", "git_sha", "python", "numpy",
                    "jax", "platform", "wall_clock_s", "validation_failures"):
            if key in m and m[key] is not None:
                lines.append(f"- **{key}**: `{m[key]}`")
        if m.get("argv"):
            lines.append(f"- **argv**: `{' '.join(map(str, m['argv']))}`")
        lines.append("")

    # -- PASS/FAIL table -----------------------------------------------------
    bench = _load_bench(d)
    if bench:
        lines += ["## Benchmarks", "",
                  "| module | rows | pass | fail |",
                  "|---|---:|---:|---:|"]
        failures = []
        for module, rows in bench.items():
            if rows is None:
                lines.append(f"| {module} | - | - | ERROR |")
                failures.append((module, "<module raised>", ""))
                continue
            n_pass = sum(1 for r in rows.values() if r["ok"] is True)
            n_fail = sum(1 for r in rows.values() if r["ok"] is False)
            lines.append(f"| {module} | {len(rows)} | {n_pass} | {n_fail} |")
            failures += [(module, name, r["derived"])
                         for name, r in rows.items() if r["ok"] is False]
        lines.append("")
        if failures:
            lines += ["### Failing rows", ""]
            lines += [f"- `{mod}` / `{name}`: {derived}"
                      for mod, name, derived in failures]
            lines.append("")

    # -- span flame summary --------------------------------------------------
    metrics_path = os.path.join(d, METRICS_NAME)
    if os.path.exists(metrics_path):
        prom = read_prometheus(metrics_path)
        sums = prom.get("summary", {})
        spans = []
        for name, series in sums.items():
            if not name.endswith("_seconds_sum"):
                continue
            base = name[:-len("_seconds_sum")]
            counts = {tuple(sorted(lb.items())): v for lb, v in
                      sums.get(base + "_seconds_count", [])}
            for labels, total in series:
                key = tuple(sorted(labels.items()))
                n = counts.get(key, 0.0)
                spans.append((total, n, base, labels))
        if spans:
            lines += ["## Stage spans (wall-clock)", "",
                      "| stage | labels | calls | total s | mean s |",
                      "|---|---|---:|---:|---:|"]
            for total, n, base, labels in sorted(spans, reverse=True)[:20]:
                lb = ",".join(f"{k}={v}" for k, v in sorted(labels.items()))
                mean = total / n if n else 0.0
                lines.append(f"| {base} | {lb or '-'} | {n:.0f} "
                             f"| {total:.3f} | {mean:.3f} |")
            lines.append("")

    # -- top events ----------------------------------------------------------
    events_path = os.path.join(d, EVENTS_NAME)
    if os.path.exists(events_path):
        counts: dict = {}
        for e in read_events(events_path):
            key = (e.subsystem, e.kind)
            counts[key] = counts.get(key, 0) + 1
        if counts:
            lines += ["## Events", "",
                      "| subsystem | kind | count |", "|---|---|---:|"]
            for (sub, kind), n in sorted(counts.items(),
                                         key=lambda kv: -kv[1])[:15]:
                lines.append(f"| {sub} | {kind} | {n} |")
            lines.append(f"\n{sum(counts.values())} events total.")
            lines.append("")

    # -- incident timelines (when tools/incidents.py has run) ----------------
    incidents_path = os.path.join(d, INCIDENTS_NAME)
    if os.path.exists(incidents_path) and os.path.exists(events_path):
        from repro.obs.incidents import (
            reconstruct_incidents, render_incidents_markdown)
        with open(incidents_path) as f:
            tick_s = float(json.load(f).get("tick_s", 2.0))
        report = reconstruct_incidents(read_events(events_path))
        lines += [render_incidents_markdown(report, tick_s=tick_s)]
    return "\n".join(lines)


def diff_data(old: str, new: str) -> dict:
    """Row-by-row comparison of two artifact dirs as plain data:
    ``regressions`` (ok went PASS/- -> FAIL), ``fixes`` (the reverse),
    ``timing`` deltas, and ``lopsided`` rows present on one side only."""
    a, b = _load_bench(old), _load_bench(new)
    regressions, fixes, timing, lopsided = [], [], [], []
    for module in sorted(set(a) | set(b)):
        ra, rb = a.get(module), b.get(module)
        if ra is None or rb is None:
            lopsided.append({"module": module, "row": None,
                             "side": "old" if module in a else "new"})
            continue
        for name in sorted(set(ra) | set(rb)):
            va, vb = ra.get(name), rb.get(name)
            if va is None or vb is None:
                lopsided.append({"module": module, "row": name,
                                 "side": "old" if vb is None else "new"})
                continue
            if va["ok"] != vb["ok"]:
                rec = {"module": module, "row": name,
                       "old": _flag(va["ok"]), "new": _flag(vb["ok"]),
                       "derived": vb["derived"]}
                (regressions if vb["ok"] is False else fixes).append(rec)
            ua, ub = va["us_per_call"], vb["us_per_call"]
            if ua > 0 and ub > 0:
                timing.append({"module": module, "row": name,
                               "old_us": ua, "new_us": ub,
                               "delta": ub / ua - 1.0})
    return {"old": old, "new": new, "regressions": regressions,
            "fixes": fixes, "timing": timing, "lopsided": lopsided}


def render_diff(old: str, new: str, data: dict = None) -> str:
    """Row-by-row comparison of two artifact dirs."""
    d = data if data is not None else diff_data(old, new)
    lines = [f"# Benchmark diff — `{old}` -> `{new}`", ""]
    for rec in d["lopsided"]:
        if rec["row"] is None:
            lines.append(f"- `{rec['module']}`: only in "
                         f"`{old if rec['side'] == 'old' else new}` "
                         f"(or raised)")
        else:
            lines.append(f"- `{rec['module']}` / `{rec['row']}`: "
                         f"{'removed' if rec['side'] == 'old' else 'added'}")
    regressions = [(r["module"], r["row"], r["old"], r["new"], r["derived"])
                   for r in d["regressions"]]
    fixes = [(r["module"], r["row"], r["old"], r["new"], r["derived"])
             for r in d["fixes"]]
    timing = [(r["delta"], r["module"], r["row"], r["old_us"], r["new_us"])
              for r in d["timing"]]
    if regressions:
        lines += ["## Regressions", ""]
        lines += [f"- `{m}` / `{n}`: {fa} -> {fb} — {d}"
                  for m, n, fa, fb, d in regressions]
        lines.append("")
    if fixes:
        lines += ["## Newly passing / changed validation", ""]
        lines += [f"- `{m}` / `{n}`: {fa} -> {fb}"
                  for m, n, fa, fb, _ in fixes]
        lines.append("")
    if timing:
        lines += ["## Largest timing deltas", "",
                  "| row | old us | new us | delta |", "|---|---:|---:|---:|"]
        for delta, module, name, ua, ub in sorted(
                timing, key=lambda x: -abs(x[0]))[:15]:
            lines.append(f"| {module}/{name} | {ua:.1f} | {ub:.1f} "
                         f"| {delta:+.1%} |")
        lines.append("")
    if not (regressions or fixes or timing):
        lines.append("No comparable rows.")
    return "\n".join(lines)


def report_json(d: str) -> dict:
    """The one-run report as plain data (``--json`` single-dir mode)."""
    out = {"dir": d}
    manifest_path = os.path.join(d, MANIFEST_NAME)
    if os.path.exists(manifest_path):
        out["manifest"] = read_manifest(d)
    modules = {}
    for module, rows in _load_bench(d).items():
        if rows is None:
            modules[module] = {"error": True}
            continue
        modules[module] = {
            "rows": len(rows),
            "pass": sum(1 for r in rows.values() if r["ok"] is True),
            "fail": sum(1 for r in rows.values() if r["ok"] is False),
            "failing": sorted(n for n, r in rows.items()
                              if r["ok"] is False),
        }
    out["benchmarks"] = modules
    incidents_path = os.path.join(d, INCIDENTS_NAME)
    if os.path.exists(incidents_path):
        with open(incidents_path) as f:
            out["incidents"] = json.load(f)
    return out


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    as_json = "--json" in argv
    argv = [a for a in argv if a != "--json"]
    if len(argv) == 1:
        if as_json:
            print(json.dumps(report_json(argv[0]), indent=2, sort_keys=True,
                             default=str))
        else:
            print(render_report(argv[0]))
        return 0
    if len(argv) == 2:
        data = diff_data(argv[0], argv[1])
        if as_json:
            print(json.dumps(data, indent=2, sort_keys=True))
        else:
            print(render_diff(argv[0], argv[1], data))
        # a PASS -> FAIL regression is a pipeline failure, not just prose
        return 1 if data["regressions"] else 0
    print(__doc__)
    return 2


if __name__ == "__main__":
    sys.exit(main())
