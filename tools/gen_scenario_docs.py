#!/usr/bin/env python
"""Generate docs/scenarios.md and docs/registries.md from the live
registries.

Every named scenario (``table2-*``, ``fig*``, ``cluster-*``, ``mc-*``,
``fleet-*``, ``fleet-rebalance-*``, ``site-*``, ``chaos-*``) is rendered
into one scenario reference table, and every pluggable-component registry —
policies, routers, admission controllers, rebalance policies, occupancy
generators, chaos fault events, alert rules — into a registry reference, so the docs cannot drift from the code: a tier-1
test regenerates both files in memory and asserts they match what is checked
in, and ``--check`` does the same from the command line (wired into
``tools/smoke.sh`` / CI).

  PYTHONPATH=src python tools/gen_scenario_docs.py          # rewrite both
  PYTHONPATH=src python tools/gen_scenario_docs.py --check  # verify only
"""

from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

DOC_PATH = os.path.join(os.path.dirname(__file__), "..", "docs", "scenarios.md")
REG_PATH = os.path.join(os.path.dirname(__file__), "..", "docs", "registries.md")

HEADER = """\
# Scenario reference

<!-- GENERATED FILE — do not edit by hand.
     Regenerate with: PYTHONPATH=src python tools/gen_scenario_docs.py
     A tier-1 test (tests/test_docs.py) asserts this file matches the
     registry; tools/smoke.sh runs the same check before merge. -->

Every experiment in this repo is a named, JSON-serializable
[`Scenario`](architecture.md) in a process-wide registry
(`repro.experiments.get_scenario`). Benchmarks, tests, and the examples
share these exact configurations; variants derive from them with
`with_()` / `with_fleet()` / `with_policy()` / `with_routing()` /
`with_controller()` / `with_hierarchy()`.

Run any scenario end to end with:

```python
from repro.experiments import get_scenario, run_experiment
import repro.provisioning  # registers the mc-* generator families

outcome = run_experiment(get_scenario("fleet-rebalance-predictive"))
```

| scenario | duration | fleet | traffic | policy | routing | controller | budget | faults | alerts |
|---|---|---|---|---|---|---|---|---|---|
"""

FOOTER = """
**Column notes.** *fleet* is `n_rows x n_servers` actually hosted
(`n_provisioned x (1 + added_frac)` per row); a trailing `derated` marks
heterogeneous per-row budgets (`FleetSpec.row_budget_fracs`), and a
`tree AxBxC` marks an explicit power-budget hierarchy
(`HierarchySpec.shape`, root-down fan-outs; `!path` lists derated interior
nodes). *traffic* names the occupancy generator and its peak busy-server
fraction. *routing* is `router/admission` for fleet scenarios (empty for
pre-baked per-row traces). *controller* is the power-rebalancing policy
(`ControllerSpec.kind`, with its rebalance interval and — when not the
per-rack default — its scope) for dynamically rebalanced fleets. *budget*
is the row power envelope rule: `calibrated` (Table-2 79%-peak operating
point), `nominal` (n_provisioned x server rating), or explicit watts.
*faults* is the scenario's injected chaos timeline (`Scenario.faults`),
one `kind@t` entry per `FaultEvent` (`none` marks an explicitly attached
empty `FaultSpec` — the bit-parity anchor); empty means no fault engine at
all. *alerts* is the scenario's attached alert pack (`Scenario.alerts`):
`default (n)` for the stock `default_alert_pack()`, otherwise one entry
per `AlertSpec` kind; empty means no alert engine (the evaluator is
write-only either way — alerts never perturb the simulation).
"""

REG_HEADER = """\
# Registry reference

<!-- GENERATED FILE — do not edit by hand.
     Regenerate with: PYTHONPATH=src python tools/gen_scenario_docs.py
     A tier-1 test (tests/test_docs.py) asserts this file matches the
     live registries; tools/smoke.sh runs the same check before merge. -->

Every pluggable component is registered by name so scenarios stay
JSON-serializable: a [`Scenario`](scenarios.md) names a policy, router,
admission controller, rebalance policy, occupancy generator — and, for
chaos scenarios, fault-event kinds and alert-rule kinds — and the
builders below construct fresh instances per run. The one-line summaries
are the first line of each implementation's docstring.
"""

REG_FOOTER = """
**Where they plug in.** *policies* consume per-row `Telemetry` samples and
emit frequency-cap commands (`PolicySpec.kind`). *routers* place each
admitted request on a row (`RoutingSpec.router`); *admission controllers*
decide first whether it runs at all (`RoutingSpec.admission`). *rebalance
policies* re-divide power envelopes across the budget hierarchy
(`ControllerSpec.kind`, with `scope` = `rack` | `cluster` | `tree` — the
latter recursing over every interior node of the scenario's
`HierarchySpec`). *occupancy generators* produce the seeded busy-server
curves traffic is sampled from (`TrafficSpec.generator`). *fault events*
are the `FaultEvent.kind` values a `FaultSpec` timeline may carry
(`Scenario.faults`); the `ChaosInjector` applies them between telemetry
ticks and logs every application to `FleetResult.fault_events`. *alert
rules* are the `AlertSpec.kind` values a scenario's alert pack may carry
(`Scenario.alerts`); the `AlertEngine` evaluates them per telemetry tick
and emits `alert_engage`/`alert_release` events without perturbing the
run (`repro.obs.alerts`).
"""


def _fmt_duration(s: float) -> str:
    day = 86_400.0
    if s % (7 * day) == 0:
        return f"{int(s // (7 * day))} w"
    if s % day == 0:
        return f"{int(s // day)} d"
    if s % 3600.0 == 0:
        return f"{int(s // 3600.0)} h"
    hours = f"{s / 3600.0:.2f}".rstrip("0").rstrip(".")
    return f"{hours} h"


def _fmt_fleet(sc) -> str:
    f = sc.fleet
    txt = f"{f.n_rows} x {f.n_servers}"
    if f.added_frac:
        txt += f" (+{f.added_frac:.0%})"
    if f.row_budget_fracs is not None:
        txt += " derated"
    h = getattr(sc, "hierarchy", None)
    if h is not None:
        txt += " tree" + "x".join(str(s) for s in h.shape)
        for path in sorted(h.budget_fracs):
            txt += f" !{path}"
    return txt


def _fmt_traffic(sc) -> str:
    t = sc.traffic
    txt = f"{t.generator} @{t.occ_peak:.2f}"
    if t.priority_mix_override is not None:
        txt += f" hp={t.priority_mix_override:.2f}"
    return txt


def _fmt_routing(sc) -> str:
    r = sc.routing
    if r is None:
        return ""
    return r.router if r.admission == "admit-all" else f"{r.router}/{r.admission}"


def _fmt_controller(sc) -> str:
    c = getattr(sc, "controller", None)
    if c is None:
        return ""
    txt = f"{c.kind} @{c.interval_s:.0f}s"
    if c.scope != "rack":
        txt += f" {c.scope}"
    return txt


def _fmt_budget(sc) -> str:
    if isinstance(sc.budget, str):
        return sc.budget
    return f"{sc.budget:.0f} W"


def _fmt_faults(sc) -> str:
    fs = getattr(sc, "faults", None)
    if fs is None:
        return ""
    if fs.is_noop:
        return "none"
    return " ".join(f"`{e.kind}@{e.t:.0f}s`" for e in fs.events)


def _fmt_alerts(sc) -> str:
    from repro.obs.alerts import default_alert_pack
    alerts = getattr(sc, "alerts", None)
    if alerts is None:
        return ""
    if tuple(alerts) == default_alert_pack():
        return f"default ({len(alerts)})"
    return " ".join(f"`{s.kind}`" for s in alerts)


def generate() -> str:
    """The full docs/scenarios.md contents for the current registry."""
    import repro.provisioning  # noqa: F401  (registers mc-* scenarios)
    from repro.experiments import get_scenario, list_scenarios

    rows = []
    for name in list_scenarios():
        sc = get_scenario(name)
        rows.append(
            f"| `{name}` | {_fmt_duration(sc.duration_s)} | {_fmt_fleet(sc)} "
            f"| {_fmt_traffic(sc)} | {sc.policy.kind} | {_fmt_routing(sc)} "
            f"| {_fmt_controller(sc)} | {_fmt_budget(sc)} "
            f"| {_fmt_faults(sc)} | {_fmt_alerts(sc)} |")
    return HEADER + "\n".join(rows) + "\n" + FOOTER


def _summary(obj) -> str:
    """First docstring line of a registered implementation (builders that
    are classes document themselves; partials/functions likewise)."""
    doc = getattr(obj, "__doc__", None) or ""
    first = doc.strip().splitlines()[0].strip() if doc.strip() else ""
    return first


def _registry_table(title: str, intro: str, entries) -> str:
    lines = [f"## {title}", "", intro, "",
             "| name | implementation | summary |", "|---|---|---|"]
    for name, obj in entries:
        impl = getattr(obj, "__name__", type(obj).__name__)
        lines.append(f"| `{name}` | `{impl}` | {_summary(obj)} |")
    return "\n".join(lines) + "\n"


def generate_registries() -> str:
    """The full docs/registries.md contents for the current registries."""
    import repro.provisioning  # noqa: F401  (registers the mc-* generators)
    from repro.chaos import FAULT_EVENT_BUILDERS
    from repro.core.traces import get_occupancy_generator, list_occupancy_generators
    from repro.obs.alerts import ALERT_BUILDERS
    from repro.experiments.scenario import POLICY_BUILDERS
    from repro.fleet.controller import REBALANCE_BUILDERS
    from repro.fleet.router import ADMISSION_BUILDERS, ROUTER_BUILDERS

    sections = [
        _registry_table(
            "Capping policies (`PolicySpec.kind`)",
            "Per-row power-management policies consuming 2 s `Telemetry` "
            "samples (`repro.core.policy`).",
            sorted(POLICY_BUILDERS.items())),
        _registry_table(
            "Routers (`RoutingSpec.router`)",
            "Fleet dispatch policies scoring `RowView` snapshots per arrival "
            "(`repro.fleet.router`).",
            sorted(ROUTER_BUILDERS.items())),
        _registry_table(
            "Admission controllers (`RoutingSpec.admission`)",
            "Fleet-door shedding policies consulted before routing "
            "(`repro.fleet.router`).",
            sorted(ADMISSION_BUILDERS.items())),
        _registry_table(
            "Rebalance policies (`ControllerSpec.kind`)",
            "Budget-division policies the `FleetController` runs per rack, "
            "per cluster, or recursively per hierarchy node "
            "(`repro.fleet.controller`).",
            sorted(REBALANCE_BUILDERS.items())),
        _registry_table(
            "Occupancy generators (`TrafficSpec.generator`)",
            "Seeded busy-server-curve families behind the trace generators "
            "(`repro.core.traces`, `repro.provisioning.ensembles`).",
            [(n, get_occupancy_generator(n))
             for n in list_occupancy_generators()]),
        _registry_table(
            "Fault events (`FaultEvent.kind`)",
            "Chaos-timeline event kinds the `ChaosInjector` applies to a "
            "running fleet between telemetry ticks (`repro.chaos`).",
            sorted(FAULT_EVENT_BUILDERS.items())),
        _registry_table(
            "Alert rules (`AlertSpec.kind`)",
            "Streaming alert rules the `AlertEngine` evaluates per "
            "telemetry tick, with hysteresis and engage-streak debouncing "
            "(`repro.obs.alerts`).",
            sorted(ALERT_BUILDERS.items())),
    ]
    return REG_HEADER + "\n" + "\n".join(sections) + REG_FOOTER


def _targets():
    return [(os.path.normpath(DOC_PATH), generate),
            (os.path.normpath(REG_PATH), generate_registries)]


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="exit 1 if docs/scenarios.md or docs/registries.md "
                         "is out of sync")
    args = ap.parse_args()
    rc = 0
    for path, gen in _targets():
        text = gen()
        if args.check:
            try:
                with open(path) as fh:
                    on_disk = fh.read()
            except FileNotFoundError:
                print(f"missing {path}; run tools/gen_scenario_docs.py")
                rc = 1
                continue
            if on_disk != text:
                print(f"{path} is out of sync with the live registries; "
                      "run: PYTHONPATH=src python tools/gen_scenario_docs.py")
                rc = 1
            else:
                print(f"{path} in sync ({len(text.splitlines())} lines)")
            continue
        os.makedirs(os.path.dirname(path), exist_ok=True)
        with open(path, "w") as fh:
            fh.write(text)
        print(f"wrote {path}")
    return rc


if __name__ == "__main__":
    sys.exit(main())
