#!/usr/bin/env python
"""Generate docs/scenarios.md from the live scenario registry.

Every named scenario (``table2-*``, ``fig*``, ``cluster-*``, ``mc-*``,
``fleet-*``, ``fleet-rebalance-*``) is rendered into one reference table, so
the docs cannot drift from the code: a tier-1 test regenerates this file in
memory and asserts it matches what is checked in, and ``--check`` does the
same from the command line (wired into ``tools/smoke.sh`` / CI).

  PYTHONPATH=src python tools/gen_scenario_docs.py          # rewrite
  PYTHONPATH=src python tools/gen_scenario_docs.py --check  # verify only
"""

from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

DOC_PATH = os.path.join(os.path.dirname(__file__), "..", "docs", "scenarios.md")

HEADER = """\
# Scenario reference

<!-- GENERATED FILE — do not edit by hand.
     Regenerate with: PYTHONPATH=src python tools/gen_scenario_docs.py
     A tier-1 test (tests/test_docs.py) asserts this file matches the
     registry; tools/smoke.sh runs the same check before merge. -->

Every experiment in this repo is a named, JSON-serializable
[`Scenario`](architecture.md) in a process-wide registry
(`repro.experiments.get_scenario`). Benchmarks, tests, and the examples
share these exact configurations; variants derive from them with
`with_()` / `with_fleet()` / `with_policy()` / `with_routing()` /
`with_controller()`.

Run any scenario end to end with:

```python
from repro.experiments import get_scenario, run_experiment
import repro.provisioning  # registers the mc-* generator families

outcome = run_experiment(get_scenario("fleet-rebalance-predictive"))
```

| scenario | duration | fleet | traffic | policy | routing | controller | budget |
|---|---|---|---|---|---|---|---|
"""

FOOTER = """
**Column notes.** *fleet* is `n_rows x n_servers` actually hosted
(`n_provisioned x (1 + added_frac)` per row); a trailing `derated` marks
heterogeneous per-row budgets (`FleetSpec.row_budget_fracs`). *traffic*
names the occupancy generator and its peak busy-server fraction. *routing*
is `router/admission` for fleet scenarios (empty for pre-baked per-row
traces). *controller* is the power-rebalancing policy
(`ControllerSpec.kind`, with its rebalance interval) for dynamically
rebalanced fleets. *budget* is the row power envelope rule: `calibrated`
(Table-2 79%-peak operating point), `nominal` (n_provisioned x server
rating), or explicit watts.
"""


def _fmt_duration(s: float) -> str:
    day = 86_400.0
    if s % (7 * day) == 0:
        return f"{int(s // (7 * day))} w"
    if s % day == 0:
        return f"{int(s // day)} d"
    if s % 3600.0 == 0:
        return f"{int(s // 3600.0)} h"
    hours = f"{s / 3600.0:.2f}".rstrip("0").rstrip(".")
    return f"{hours} h"


def _fmt_fleet(sc) -> str:
    f = sc.fleet
    txt = f"{f.n_rows} x {f.n_servers}"
    if f.added_frac:
        txt += f" (+{f.added_frac:.0%})"
    if f.row_budget_fracs is not None:
        txt += " derated"
    return txt


def _fmt_traffic(sc) -> str:
    t = sc.traffic
    txt = f"{t.generator} @{t.occ_peak:.2f}"
    if t.priority_mix_override is not None:
        txt += f" hp={t.priority_mix_override:.2f}"
    return txt


def _fmt_routing(sc) -> str:
    r = sc.routing
    if r is None:
        return ""
    return r.router if r.admission == "admit-all" else f"{r.router}/{r.admission}"


def _fmt_controller(sc) -> str:
    c = getattr(sc, "controller", None)
    if c is None:
        return ""
    return f"{c.kind} @{c.interval_s:.0f}s"


def _fmt_budget(sc) -> str:
    if isinstance(sc.budget, str):
        return sc.budget
    return f"{sc.budget:.0f} W"


def generate() -> str:
    """The full docs/scenarios.md contents for the current registry."""
    import repro.provisioning  # noqa: F401  (registers mc-* scenarios)
    from repro.experiments import get_scenario, list_scenarios

    rows = []
    for name in list_scenarios():
        sc = get_scenario(name)
        rows.append(
            f"| `{name}` | {_fmt_duration(sc.duration_s)} | {_fmt_fleet(sc)} "
            f"| {_fmt_traffic(sc)} | {sc.policy.kind} | {_fmt_routing(sc)} "
            f"| {_fmt_controller(sc)} | {_fmt_budget(sc)} |")
    return HEADER + "\n".join(rows) + "\n" + FOOTER


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="exit 1 if docs/scenarios.md is out of sync")
    args = ap.parse_args()
    text = generate()
    path = os.path.normpath(DOC_PATH)
    if args.check:
        try:
            with open(path) as fh:
                on_disk = fh.read()
        except FileNotFoundError:
            print(f"missing {path}; run tools/gen_scenario_docs.py")
            return 1
        if on_disk != text:
            print(f"{path} is out of sync with the scenario registry; "
                  "run: PYTHONPATH=src python tools/gen_scenario_docs.py")
            return 1
        print(f"{path} in sync ({len(text.splitlines())} lines)")
        return 0
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as fh:
        fh.write(text)
    print(f"wrote {path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
