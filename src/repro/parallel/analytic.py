"""Exact analytic FLOP counts + HBM-traffic lower bounds per (arch x shape).

Why this exists (EXPERIMENTS.md §Roofline methodology): XLA's HloCostAnalysis
counts while-loop bodies ONCE. We unroll the *layer* scans for the dry-run
(which fixes the dominant term and makes the collective parse exact), but the
attention query-chunk scan and the SSD chunk scan remain loops, so compiled
FLOPs/bytes still undercount for long-context cells. Since we control every
einsum in the model, the analytic count below is exact for the linear algebra
and is used as the primary compute/memory roofline source; the compiled
numbers are reported alongside as a cross-check (they agree within the remat
factor for fully-unrollable cells — verified for llama3.2-1b x train_4k).

All counts are GLOBAL; divide by n_devices for per-chip terms.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

from repro.models.config import ATTN, LOCAL, MAMBA, ModelConfig, ShapeConfig


def _dtype_bytes(dt: str) -> int:
    return {"bfloat16": 2, "float32": 4, "float16": 2}[dt]


def _causal_ctx_total(S: int, window: int) -> float:
    """Sum over query positions of attended context length."""
    if not window or window >= S:
        return S * (S + 1) / 2.0
    # positions < window attend i+1; the rest attend `window`
    w = window
    return w * (w + 1) / 2.0 + (S - w) * w


@dataclass
class StepCost:
    flops: float  # global FLOPs for one step
    hbm_bytes: float  # global HBM traffic lower bound (Pallas/fused-attn path)
    # extra traffic when attention scores materialize in HBM (the XLA einsum
    # path); the dry-run adds this unless cfg.use_pallas — reporting both makes
    # the flash-kernel win visible in §Roofline
    attn_score_bytes: float

    def per_device(self, n: int) -> "StepCost":
        return StepCost(self.flops / n, self.hbm_bytes / n, self.attn_score_bytes / n)


def _attn_flops(cfg: ModelConfig, T_tok: float, ctx_total: float, B: float) -> float:
    """One attention block: projections + scores + AV."""
    D, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    proj = 2 * T_tok * D * (2 * H * hd + 2 * KV * hd)  # q,o + k,v
    core = 4 * B * ctx_total * H * hd  # QK^T + PV (2 matmuls x 2 flops)
    return proj + core


def _ssd_flops(cfg: ModelConfig, T_tok: float) -> float:
    D = cfg.d_model
    d_in = cfg.ssm_expand * D
    Hs = d_in // cfg.ssm_headdim
    G, N, P = cfg.ssm_n_groups, cfg.ssm_d_state, cfg.ssm_headdim
    Q = cfg.ssm_chunk
    proj = 2 * T_tok * D * (2 * d_in + 2 * G * N + Hs) + 2 * T_tok * d_in * D
    conv = 2 * T_tok * cfg.ssm_conv_width * (d_in + 2 * G * N)
    core = 2 * T_tok * (Q * G * N + Q * Hs * P + 2 * Hs * N * P)
    return proj + conv + core


def _ssd_decode_flops(cfg: ModelConfig, B: float) -> float:
    D = cfg.d_model
    d_in = cfg.ssm_expand * D
    Hs = d_in // cfg.ssm_headdim
    G, N, P = cfg.ssm_n_groups, cfg.ssm_d_state, cfg.ssm_headdim
    proj = 2 * B * D * (2 * d_in + 2 * G * N + Hs) + 2 * B * d_in * D
    core = 2 * B * 2 * Hs * N * P
    return proj + core


def _mlp_flops(cfg: ModelConfig, T_tok: float, d_ff: int) -> float:
    n_mats = 3 if cfg.mlp_type in ("swiglu", "geglu") else 2
    return 2 * T_tok * cfg.d_model * d_ff * n_mats


def _moe_flops(cfg: ModelConfig, T_tok: float) -> float:
    n_mats = 3 if cfg.mlp_type in ("swiglu", "geglu") else 2
    routed = 2 * T_tok * cfg.moe_top_k * cfg.moe_capacity_factor * \
        cfg.d_model * cfg.moe_d_ff * n_mats
    router = 2 * T_tok * cfg.d_model * cfg.moe_num_experts
    shared = _mlp_flops(cfg, T_tok, cfg.moe_shared_expert_ff) if cfg.moe_shared_expert_ff else 0
    return routed + router + shared


def _block_is_moe(cfg: ModelConfig, i: int, kind: str) -> bool:
    has_ffn = kind != MAMBA or cfg.ffn_every_block
    if not cfg.moe_num_experts or not has_ffn:
        return False
    return cfg.moe_layer_period == 1 or i % cfg.moe_layer_period == cfg.moe_layer_period - 1


def forward_flops(cfg: ModelConfig, B: int, S: int, enc_S: int, *,
                  decode: bool = False, cache_len: int = 0) -> float:
    """One forward pass (prefill/train fwd if not decode; one token if decode)."""
    T = float(B) * (1 if decode else S)
    total = 0.0
    # decoder blocks
    for i, kind in enumerate(cfg.pattern):
        if kind == MAMBA:
            total += _ssd_decode_flops(cfg, B) if decode else _ssd_flops(cfg, T)
        else:
            window = cfg.window_size if kind == LOCAL else 0
            if decode:
                ctx = min(cache_len, window) if window else cache_len
                ctx_total = float(ctx)  # per query token
            else:
                ctx_total = _causal_ctx_total(S, window)
            total += _attn_flops(cfg, T, ctx_total, B)
            if cfg.is_encoder_decoder:
                # cross attention: q/o projections + scores over enc_S
                D, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
                total += 2 * T * D * 2 * H * hd + 4 * B * (1 if decode else S) * enc_S * H * hd
                if not decode:  # cross kv projected at prefill/train
                    total += 2 * (B * enc_S) * D * 2 * KV * hd
        if kind != MAMBA or cfg.ffn_every_block:
            if _block_is_moe(cfg, i, kind):
                total += _moe_flops(cfg, T)
            else:
                total += _mlp_flops(cfg, T, cfg.d_ff)
    total *= cfg.num_groups
    # encoder (not re-run at decode)
    if cfg.is_encoder_decoder and not decode:
        T_e = float(B) * enc_S
        enc = _attn_flops(cfg, T_e, enc_S * enc_S, B) + _mlp_flops(cfg, T_e, cfg.d_ff)
        total += enc * cfg.num_encoder_layers
    # unembed
    total += 2 * T * cfg.d_model * cfg.vocab_size
    return total


REMAT_FACTOR = {"none": 3.0, "dots": 10.0 / 3.0, "full": 4.0}


def step_cost(cfg: ModelConfig, shape: ShapeConfig, enc_S: int, dec_S: int) -> StepCost:
    """Global analytic cost for the cell's step."""
    B = shape.global_batch
    act = _dtype_bytes(cfg.dtype)
    wb = _dtype_bytes(cfg.param_dtype)
    n_params = cfg.total_params()
    n_active = cfg.active_params()

    # --- attention-score HBM traffic for the XLA (non-Pallas) path ---------
    def score_bytes(S, fwd_only):
        b = 0.0
        for i, kind in enumerate(cfg.pattern):
            if kind == MAMBA:
                continue
            window = cfg.window_size if kind == LOCAL else 0
            ctx = _causal_ctx_total(S, window)
            # fp32 scores written+read once (fused softmax), fwd (+1 recompute in bwd)
            b += B * ctx * cfg.num_heads * 4 * 2 * (1 if fwd_only else 2)
        return b * cfg.num_groups

    if shape.kind == "train":
        fl = forward_flops(cfg, B, dec_S, enc_S) * REMAT_FACTOR[cfg.remat_policy]
        # params 2x read + 1 write (fwd+bwd read, update write), grads r/w,
        # optimizer state r/w, saved layer-boundary activations w+r
        opt_bytes = n_params * (8 if cfg.optimizer == "adamw" else 2)
        act_saved = B * dec_S * cfg.d_model * act * cfg.num_layers
        hbm = (3 * n_params * wb + 2 * n_params * 4 + 2 * opt_bytes
               + 2 * act_saved)
        return StepCost(fl, hbm, score_bytes(dec_S, fwd_only=False))

    if shape.kind == "prefill":
        fl = forward_flops(cfg, B, dec_S, enc_S)
        kv_write = 2 * B * dec_S * cfg.num_kv_heads * cfg.head_dim * act * \
            sum(1 for k in cfg.pattern if k != MAMBA) * cfg.num_groups
        hbm = n_active * wb + B * dec_S * cfg.d_model * act * cfg.num_layers * 2 \
            + kv_write
        return StepCost(fl, hbm, score_bytes(dec_S, fwd_only=True))

    # decode: one token against a cache of dec_S
    fl = forward_flops(cfg, B, dec_S, enc_S, decode=True, cache_len=dec_S)
    # weights: dense-active read once; MoE: experts actually touched
    if cfg.moe_num_experts:
        moe_blocks = sum(1 for i, k in enumerate(cfg.pattern) if _block_is_moe(cfg, i, k))
        moe_blocks *= cfg.num_groups
        n_mats = 3 if cfg.mlp_type in ("swiglu", "geglu") else 2
        per_expert = n_mats * cfg.d_model * cfg.moe_d_ff
        touched = min(cfg.moe_num_experts, B * cfg.moe_top_k)
        w_bytes = (n_active - moe_blocks * cfg.moe_top_k * per_expert) * wb \
            + moe_blocks * touched * per_expert * wb
    else:
        w_bytes = n_active * wb
    # KV cache read (+ tiny new-token write)
    kv = 0.0
    for i, kind in enumerate(cfg.pattern):
        if kind == MAMBA:
            d_in = cfg.ssm_expand * cfg.d_model
            Hs = d_in // cfg.ssm_headdim
            kv += B * Hs * cfg.ssm_d_state * cfg.ssm_headdim * 4 * 2  # state r+w
        else:
            window = cfg.window_size if kind == LOCAL else 0
            ctx = min(dec_S, window) if window else dec_S
            kv += B * ctx * 2 * cfg.num_kv_heads * cfg.head_dim * act
            if cfg.is_encoder_decoder:
                kv += B * enc_S * 2 * cfg.num_kv_heads * cfg.head_dim * act
    kv *= cfg.num_groups
    hbm = w_bytes + kv
    return StepCost(fl, hbm, 0.0)
