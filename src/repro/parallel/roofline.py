"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh) cell, in seconds (TPU v5e constants):

  compute    = HLO_FLOPs_per_device / peak_FLOPs
  memory     = HLO_bytes_per_device / HBM_bw
  collective = collective_bytes_per_device / ICI_link_bw

``compiled.cost_analysis()`` on this JAX version reports *per-device* FLOPs
and bytes (verified against a hand-computed matmul), so we divide by per-chip
peaks — algebraically identical to the assignment's global/(chips*peak) form.

Collective bytes are not in cost_analysis: we parse the post-SPMD-partitioning
HLO and apply per-op ring-cost formulas (bytes sent per device):
  all-gather:         R * (n-1)/n        (R = full gathered result bytes)
  reduce-scatter:     R * (n-1)          (R = scattered result bytes; operand = R*n)
  all-reduce:         2 * R * (n-1)/n    (RS + AG phases)
  all-to-all:         R * (n-1)/n
  collective-permute: R
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

# ---- TPU v5e hardware constants (per assignment) ---------------------------
PEAK_FLOPS = 197e12  # bf16 FLOP/s per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link
HBM_BYTES = 16 * 1024**3  # v5e HBM capacity

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# result types on the LHS of `= ... op-name(`
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_EXPL_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_EXPL_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 2  # conservative default


@dataclass
class CollectiveStats:
    ops: Dict[str, int] = field(default_factory=dict)  # op kind -> count
    bytes_by_kind: Dict[str, float] = field(default_factory=dict)
    total_bytes: float = 0.0  # per-device bytes on the wire
    lines: List[str] = field(default_factory=list)


def parse_collectives(hlo_text: str, keep_lines: bool = False) -> CollectiveStats:
    st = CollectiveStats()
    for line in hlo_text.splitlines():
        kind = None
        for k in _COLLECTIVES:
            if "=" not in line:
                continue
            if f" {k}(" in line or f" {k}-start(" in line:
                kind = k
                break
        if kind is None or f" {kind}-done(" in line:
            continue  # async pairs: count the -start only (it has the shapes)
        lhs = line.split("=", 1)[0] + "=" + line.split("=", 1)[1].split(kind)[0]
        r = _shape_bytes(lhs)
        # XLA:CPU converts bf16 operands to f32 before reducing (the collective
        # arithmetic runs in f32 on host); TPU reduces bf16 natively. When the
        # f32 all-reduce consumes a convert fusion, count the TPU (bf16) bytes.
        if kind in ("all-reduce", "reduce-scatter") and "f32[" in lhs                 and "(%convert" in line:
            r //= 2
        n = _group_size(line)
        if kind == "all-gather":
            b = r * (n - 1) / n
        elif kind == "reduce-scatter":
            b = r * (n - 1)
        elif kind == "all-reduce":
            b = 2 * r * (n - 1) / n
        elif kind == "all-to-all":
            b = r * (n - 1) / n
        else:  # collective-permute
            b = r
        st.ops[kind] = st.ops.get(kind, 0) + 1
        st.bytes_by_kind[kind] = st.bytes_by_kind.get(kind, 0.0) + b
        st.total_bytes += b
        if keep_lines:
            st.lines.append(line.strip()[:200])
    return st


@dataclass
class Roofline:
    flops_per_device: float  # analytic (exact; see parallel/analytic.py)
    hbm_bytes_per_device: float  # analytic traffic lower bound
    collective_bytes_per_device: float  # parsed from post-SPMD HLO
    model_flops_global: float  # 6*N*D (train) / 2*N*D (inference), active params
    n_devices: int
    collectives: Optional[CollectiveStats] = None
    hlo_flops_per_device: float = 0.0  # compiled cross-check (undercounts loops)
    hlo_bytes_per_device: float = 0.0
    kind: str = "train"  # train | prefill | decode

    @property
    def t_compute(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes_per_device / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes_per_device / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        """Lower-bound step time: perfectly-overlapped roofline."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs (global) — catches remat/redundancy waste."""
        total = self.flops_per_device * self.n_devices
        return self.model_flops_global / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """How close the cell sits to its NATURAL roofline: compute-bound for
        train/prefill (t_compute / t_bound), memory-bound for decode
        (t_memory / t_bound; decode must stream weights+KV, so the memory
        term IS the ideal). 1.0 = at the roofline; this is the §Perf score."""
        t = self.t_bound
        if t <= 0:
            return 0.0
        ideal = self.t_memory if self.kind == "decode" else self.t_compute
        return ideal / t

    @property
    def mfu_bound(self) -> float:
        """Model-FLOPs utilization at the roofline bound (the score)."""
        t = self.t_bound
        if t <= 0:
            return 0.0
        return (self.model_flops_global / self.n_devices / t) / PEAK_FLOPS

    def to_dict(self) -> dict:
        d = {
            "flops_per_device": self.flops_per_device,
            "hbm_bytes_per_device": self.hbm_bytes_per_device,
            "collective_bytes_per_device": self.collective_bytes_per_device,
            "hlo_flops_per_device": self.hlo_flops_per_device,
            "hlo_bytes_per_device": self.hlo_bytes_per_device,
            "model_flops_global": self.model_flops_global,
            "n_devices": self.n_devices,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
            "mfu_bound": self.mfu_bound,
            "kind": self.kind,
            "roofline_fraction": self.roofline_fraction,
        }
        if self.collectives:
            d["collective_ops"] = self.collectives.ops
            d["collective_bytes_by_kind"] = self.collectives.bytes_by_kind
        return d


def model_flops(cfg, shape) -> float:
    """Analytic MODEL_FLOPS = 6*N*D (train) or 2*N*D (inference) with
    N = active params (MoE-aware)."""
    n = cfg.active_params()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def extrapolate_collectives(st1: CollectiveStats, st2: CollectiveStats,
                            groups: int) -> CollectiveStats:
    """Linear extrapolation from 1-group/2-group compiles to ``groups``."""
    out = CollectiveStats()
    kinds = set(st1.ops) | set(st2.ops)
    for k in kinds:
        c1, c2 = st1.ops.get(k, 0), st2.ops.get(k, 0)
        b1, b2 = st1.bytes_by_kind.get(k, 0.0), st2.bytes_by_kind.get(k, 0.0)
        # clamp at the 1-group floor: XLA occasionally fuses collectives in the
        # 2-group graph, which would extrapolate negative
        out.ops[k] = max(c1, c1 + (groups - 1) * (c2 - c1), 0)
        out.bytes_by_kind[k] = max(0.0, b1 + (groups - 1) * (b2 - b1))
        out.total_bytes += out.bytes_by_kind[k]
    return out


def build_roofline_extrapolated(comp1, comp2, cfg, shape, n_devices: int,
                                enc_S: int, dec_S: int) -> Roofline:
    """Roofline from 1-group and 2-group fully-unrolled compiles."""
    from repro.parallel.analytic import step_cost

    g = cfg.num_groups
    st1 = parse_collectives(comp1.as_text())
    st2 = parse_collectives(comp2.as_text())
    st = extrapolate_collectives(st1, st2, g)
    c1, c2 = comp1.cost_analysis(), comp2.cost_analysis()

    def extrap(key):
        a, b = float(c1.get(key, 0.0)), float(c2.get(key, 0.0))
        return a + (g - 1) * (b - a)

    ac = step_cost(cfg, shape, enc_S, dec_S).per_device(n_devices)
    hbm = ac.hbm_bytes + (0.0 if cfg.use_pallas else ac.attn_score_bytes)
    return Roofline(
        flops_per_device=ac.flops,
        hbm_bytes_per_device=hbm,
        collective_bytes_per_device=st.total_bytes,
        model_flops_global=model_flops(cfg, shape),
        n_devices=n_devices,
        collectives=st,
        hlo_flops_per_device=extrap("flops"),
        hlo_bytes_per_device=extrap("bytes accessed"),
        kind=shape.kind,
    )


def build_roofline(compiled, cfg, shape, n_devices: int, enc_S: int, dec_S: int,
                   keep_lines: bool = False) -> Roofline:
    from repro.parallel.analytic import step_cost

    cost = compiled.cost_analysis()
    st = parse_collectives(compiled.as_text(), keep_lines=keep_lines)
    ac = step_cost(cfg, shape, enc_S, dec_S).per_device(n_devices)
    hbm = ac.hbm_bytes + (0.0 if cfg.use_pallas else ac.attn_score_bytes)
    return Roofline(
        flops_per_device=ac.flops,
        hbm_bytes_per_device=hbm,
        collective_bytes_per_device=st.total_bytes,
        model_flops_global=model_flops(cfg, shape),
        n_devices=n_devices,
        collectives=st,
        hlo_flops_per_device=float(cost.get("flops", 0.0)),
        hlo_bytes_per_device=float(cost.get("bytes accessed", 0.0)),
        kind=shape.kind,
    )
