"""Deterministic, sharded synthetic data pipeline.

Real deployments stream tokenized corpora; this pipeline generates seeded
synthetic token batches with the same interface so every layer above it
(train loop, checkpoint-resume, elastic re-sharding) exercises production
behaviour: per-step determinism, exact resume from a step index, and
host-local sharding (each host materializes only its slice).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.launch.inputs import split_seq


@dataclass(frozen=True)
class DataConfig:
    global_batch: int
    seq_len: int
    seed: int = 1234


class SyntheticTokenPipeline:
    """Seeded LM batches; ``batch_at(step)`` is pure so resume == replay."""

    def __init__(self, cfg: ModelConfig, data: DataConfig):
        self.cfg = cfg
        self.data = data
        self.enc_S, self.dec_S = split_seq(cfg, data.seq_len)

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg, d = self.cfg, self.data
        rng = np.random.default_rng(np.uint64(d.seed) + np.uint64(step))
        B = d.global_batch
        out: Dict[str, np.ndarray] = {}
        if cfg.is_encoder_decoder:
            out["enc_embeds"] = rng.standard_normal(
                (B, self.enc_S, cfg.d_model), dtype=np.float32).astype(jnp.bfloat16)
            out["tokens"] = rng.integers(0, cfg.vocab_size, (B, self.dec_S), dtype=np.int32)
        elif cfg.frontend == "vision_stub":
            n_img = cfg.num_image_embeds
            out["image_embeds"] = rng.standard_normal(
                (B, n_img, cfg.d_model), dtype=np.float32).astype(jnp.bfloat16)
            out["tokens"] = rng.integers(0, cfg.vocab_size, (B, d.seq_len - n_img), dtype=np.int32)
        else:
            out["tokens"] = rng.integers(0, cfg.vocab_size, (B, d.seq_len), dtype=np.int32)
        if cfg.is_encoder_only:
            out["targets"] = rng.integers(0, cfg.vocab_size, out["tokens"].shape, dtype=np.int32)
        return out

    def iter_from(self, step: int) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.batch_at(step)
            step += 1


def device_put_batch(batch: Dict[str, np.ndarray], mesh, rules) -> Dict[str, jax.Array]:
    """Place a host batch onto the mesh with the training shardings."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    bspec = rules.get("batch")
    out = {}
    for k, v in batch.items():
        spec = P(bspec, *([None] * (v.ndim - 1)))
        out[k] = jax.device_put(jnp.asarray(v), NamedSharding(mesh, spec))
    return out
