"""Per-(model, request-config, frequency) performance/power characterization.

Bridges the model zoo to the power plane: ``analytic.step_cost`` supplies the
exact FLOPs/bytes of prefill and per-token decode for any ``ModelConfig``;
this module turns them into phase timings (roofline with an achievable-
efficiency derate), per-phase power operating points, and request latencies —
the quantities the paper measures in Figures 4-7 and feeds its simulator.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import lru_cache
from typing import Tuple

from repro.models.config import ModelConfig, ShapeConfig
from repro.core.power_model import DevicePower, ServerPower
from repro.parallel import analytic


# achievable fraction of peak (kernel efficiency; typical well-tuned serving)
COMPUTE_EFF = 0.55
MEMBW_EFF = 0.75
# fixed per-step launch/sync overhead (s): bounds decode rate at tiny batches
STEP_OVERHEAD = 0.004
# fraction of even a memory-bound step that scales with clock (launch overhead,
# softmax/pointwise work, small gemms). Calibrated so BLOOM shows ~5% perf loss
# at ~13% peak-power reduction (paper Fig. 7).
CLOCK_SENSITIVE_FLOOR = 0.30


@dataclass(frozen=True)
class PhasePoint:
    """One phase's roofline operating point on a server."""
    t_seconds: float  # duration at f=1
    u_compute: float
    u_memory: float
    compute_frac: float  # fraction of time compute-bound (for perf_scale)

    def time_at(self, dev: DevicePower, f: float) -> float:
        return self.t_seconds * dev.perf_scale(self.compute_frac, f)

    def power_at(self, server: ServerPower, f: float) -> float:
        # utilization of the *limiting* resource stays ~1 under capping;
        # the non-limiting one rises as compute slows
        return server.power(self.u_compute, self.u_memory, f)


def _phase_point(flops: float, bytes_: float, server: ServerPower) -> PhasePoint:
    dev = server.device
    n = server.n_devices
    t_c = flops / n / (dev.peak_flops * COMPUTE_EFF)
    t_m = bytes_ / n / (dev.hbm_bw * MEMBW_EFF)
    t = max(t_c, t_m) + STEP_OVERHEAD
    return PhasePoint(
        t_seconds=t,
        # even fully compute-bound phases sit slightly below the power-virus
        # point; 0.95 reproduces the paper's 'at-or-just-above TDP' spikes
        u_compute=min(1.0, t_c / t) * 0.95,
        u_memory=min(1.0, t_m / t),
        compute_frac=max(CLOCK_SENSITIVE_FLOOR, min(1.0, t_c / t)),
    )


@lru_cache(maxsize=4096)
def characterize(cfg: ModelConfig, prompt: int, batch: int,
                 server: ServerPower) -> Tuple[PhasePoint, PhasePoint]:
    """(prefill phase, per-token decode phase) for one request batch."""
    # pad the KV/context length decode works against to prompt size (output
    # grows it further; we use prompt + half a typical output as the operating
    # context — the sensitivity is small because decode is weight-bound)
    prefill_shape = ShapeConfig("wl_prefill", max(prompt, 16), batch, "prefill")
    decode_shape = ShapeConfig("wl_decode", max(prompt, 16), batch, "decode")
    enc_S, dec_S = (0, prefill_shape.seq_len)
    if cfg.is_encoder_decoder:
        enc_S = int(prefill_shape.seq_len * cfg.encoder_seq_frac)
        if cfg.max_encoder_len:
            enc_S = min(enc_S, cfg.max_encoder_len)
        dec_S = prefill_shape.seq_len - enc_S
    pre = analytic.step_cost(cfg, prefill_shape, enc_S, dec_S)
    dec = analytic.step_cost(cfg, decode_shape, enc_S, dec_S)
    return (_phase_point(pre.flops, pre.hbm_bytes + pre.attn_score_bytes, server),
            _phase_point(dec.flops, dec.hbm_bytes, server))


@dataclass(frozen=True)
class RequestTiming:
    t_prefill: float  # seconds at f=1
    t_token: float  # per output token at f=1
    prefill_point: PhasePoint
    token_point: PhasePoint

    def latency(self, out_tokens: int, dev: DevicePower, f_prefill: float = 1.0,
                f_token: float = 1.0) -> float:
        return (self.prefill_point.time_at(dev, f_prefill)
                + out_tokens * self.token_point.time_at(dev, f_token))


def request_timing(cfg: ModelConfig, prompt: int, batch: int,
                   server: ServerPower) -> RequestTiming:
    pre, tok = characterize(cfg, prompt, batch, server)
    return RequestTiming(pre.t_seconds, tok.t_seconds, pre, tok)


# ---------------------------------------------------------------------------
# Training phases (paper §2.4): compute burst / communication trough
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class TrainProfile:
    """One training iteration as (compute phase, sync trough) — the paper's
    power-swing structure. ``trough_util``: GPU compute utilization during the
    gradient-sync bubble (RoBERTa ~high, Flan-T5 ~idle; Fig. 8)."""
    t_iter: float
    compute_point: PhasePoint
    trough_frac: float  # fraction of the iteration spent in the trough
    trough_util: float

    def phases(self):
        return [(self.t_iter * (1 - self.trough_frac), self.compute_point),
                (self.t_iter * self.trough_frac, None)]


def train_profile(cfg: ModelConfig, batch: int, seq: int, server: ServerPower,
                  trough_frac: float = 0.15, trough_util: float = 0.2) -> TrainProfile:
    shape = ShapeConfig("wl_train", seq, batch, "train")
    enc_S, dec_S = 0, seq
    if cfg.is_encoder_decoder:
        enc_S = min(int(seq * cfg.encoder_seq_frac), cfg.max_encoder_len or seq)
        dec_S = seq - enc_S
    c = analytic.step_cost(cfg, shape, enc_S, dec_S)
    pt = _phase_point(c.flops, c.hbm_bytes + c.attn_score_bytes, server)
    return TrainProfile(t_iter=pt.t_seconds / (1 - trough_frac),
                        compute_point=pt, trough_frac=trough_frac,
                        trough_util=trough_util)
