"""Service-level objectives (paper Table 5) and their evaluation."""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List

import numpy as np


@dataclass(frozen=True)
class SLO:
    hp_p50: float = 0.01  # < 1% latency impact
    hp_p99: float = 0.05  # < 5%
    lp_p50: float = 0.05  # < 5%
    lp_p99: float = 0.50  # < 50%
    max_powerbrakes: int = 0


DEFAULT_SLO = SLO()


@dataclass
class LatencyStats:
    """Relative latency impact vs the uncapped ideal, per priority class."""
    hp_impacts: List[float] = field(default_factory=list)
    lp_impacts: List[float] = field(default_factory=list)

    def add(self, priority: str, actual: float, ideal: float):
        impact = max(0.0, actual / ideal - 1.0)
        (self.hp_impacts if priority == "high" else self.lp_impacts).append(impact)

    def percentile(self, priority: str, q: float) -> float:
        xs = self.hp_impacts if priority == "high" else self.lp_impacts
        if not xs:
            return 0.0
        return float(np.percentile(np.asarray(xs), q))

    def summary(self) -> Dict[str, float]:
        return {
            "hp_p50": self.percentile("high", 50),
            "hp_p99": self.percentile("high", 99),
            "lp_p50": self.percentile("low", 50),
            "lp_p99": self.percentile("low", 99),
            "n_hp": len(self.hp_impacts),
            "n_lp": len(self.lp_impacts),
        }


def impact_vs_reference(latencies: Dict[int, float],
                        ref_latencies: Dict[int, float],
                        priorities: Dict[int, str]) -> "LatencyStats":
    """Per-request latency impact of a policy run vs the uncapped reference
    run on the same trace (the paper's comparison in §6). Requests missing
    from either run (dropped) are skipped."""
    st = LatencyStats()
    for rid, lat in latencies.items():
        ref = ref_latencies.get(rid)
        if ref is None or ref <= 0:
            continue
        st.add(priorities[rid], lat, ref)
    return st


def meets_slo(stats: LatencyStats, n_powerbrakes: int, slo: SLO = DEFAULT_SLO) -> bool:
    s = stats.summary()
    return (s["hp_p50"] < slo.hp_p50 and s["hp_p99"] < slo.hp_p99
            and s["lp_p50"] < slo.lp_p50 and s["lp_p99"] < slo.lp_p99
            and n_powerbrakes <= slo.max_powerbrakes)
