"""Beyond-paper extension (paper §7 'Phase-aware power management').

The serving engine knows which phase each server is in (the paper's
controller does not — it caps per priority class only). A phase-aware policy
down-clocks the *token phase only*: decode is memory-bound, so a frequency
cap reclaims ~f^gamma dynamic power for only ~CLOCK_SENSITIVE_FLOOR * df
latency. Prompt phases run uncapped, so TTFT is untouched.

``phase_aware_headroom`` quantifies the reclaimed average+peak power and the
resulting extra servers at iso-SLO — the §Perf 'beyond paper' row for the
power plane.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List

from repro.core.power_model import DevicePower, ServerPower
from repro.core.workload import RequestTiming


@dataclass
class PhaseAwareOutcome:
    f_token: float
    avg_power_saving: float  # fraction of busy-server power saved
    peak_power_saving: float
    token_latency_impact: float
    ttft_impact: float  # always 0 by construction


def phase_aware_headroom(timing: RequestTiming, server: ServerPower,
                         mean_out_tokens: float, f_token: float) -> PhaseAwareOutcome:
    dev = server.device
    t_pre = timing.t_prefill
    t_tok_base = mean_out_tokens * timing.t_token
    t_tok_capped = t_tok_base * dev.perf_scale(timing.token_point.compute_frac, f_token)

    p_pre = timing.prefill_point.power_at(server, 1.0)
    p_tok = timing.token_point.power_at(server, 1.0)
    p_tok_capped = timing.token_point.power_at(server, f_token)

    e_base = p_pre * t_pre + p_tok * t_tok_base
    e_capped = p_pre * t_pre + p_tok_capped * t_tok_capped
    avg_base = e_base / (t_pre + t_tok_base)
    avg_capped = e_capped / (t_pre + t_tok_capped)

    return PhaseAwareOutcome(
        f_token=f_token,
        avg_power_saving=1.0 - avg_capped / avg_base,
        # row peak is set by overlapping token phases (prompt spikes are
        # uncorrelated); token-phase power drop moves the peak directly
        peak_power_saving=1.0 - p_tok_capped / p_tok,
        token_latency_impact=t_tok_capped / t_tok_base - 1.0,
        ttft_impact=0.0,
    )


def sweep(timing: RequestTiming, server: ServerPower, mean_out_tokens: float,
          freqs: List[float]) -> List[PhaseAwareOutcome]:
    return [phase_aware_headroom(timing, server, mean_out_tokens, f) for f in freqs]
