"""Oversubscription capacity planning: threshold search + SLO gate (Fig 13).

``evaluate`` runs a policy on a trace at N servers against the uncapped
reference on the same trace; ``max_servers`` sweeps N upward until SLOs (or
the no-powerbrake constraint) break.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.policy import NoCap, PolcaPolicy
from repro.core.power_model import ServerPower
from repro.core.simulator import Request, RowSimulator, SimConfig, SimResult
from repro.core.slo import DEFAULT_SLO, SLO, LatencyStats, impact_vs_reference, meets_slo
from repro.core.traces import generate_requests


@dataclass
class EvalOutcome:
    n_servers: int
    added_frac: float
    stats: LatencyStats
    result: SimResult
    ref_result: SimResult
    meets: bool
    throughput_ratio_hp: float
    throughput_ratio_lp: float


BASELINE_PEAK_UTIL = 0.79  # Table 2: inference rows peak at 79% of provisioned


def calibrated_budget(workloads, shares, server, n_provisioned: int,
                      duration: float, *, seed: int = 7, occ_peak: float = 0.62,
                      power_scale: float = 1.0) -> float:
    """Row power budget such that the n_provisioned baseline peaks at 79% of
    it (the paper's Table-2 operating point — budgets are PDU limits, not the
    sum of server ratings)."""
    reqs = generate_requests(duration, n_provisioned, workloads, shares, seed=seed,
                             occ_kwargs={"peak": occ_peak})
    base = RowSimulator(workloads, server, n_provisioned, 100 * n_provisioned,
                        NoCap(), reqs, shares,
                        SimConfig(power_scale=power_scale, record_power=False),
                        duration=duration).run()
    peak_w = base.peak_power_frac * 100 * n_provisioned * server.provisioned_w
    return peak_w / BASELINE_PEAK_UTIL


def evaluate(policy_factory: Callable, workloads, shares, server: ServerPower,
             n_provisioned: int, n_servers: int, duration: float,
             *, seed: int = 7, power_scale: float = 1.0, occ_peak: float = 0.62,
             slo: SLO = DEFAULT_SLO, sim_cfg: SimConfig = None,
             provisioned_w: float = None) -> EvalOutcome:
    reqs = generate_requests(duration, n_servers, workloads, shares, seed=seed,
                             occ_kwargs={"peak": occ_peak})
    prios = {r.rid: r.priority for r in reqs}
    base_cfg = sim_cfg or SimConfig()
    if provisioned_w is None:
        provisioned_w = calibrated_budget(workloads, shares, server, n_provisioned,
                                          min(duration, 2 * 86400.0), seed=seed,
                                          occ_peak=occ_peak, power_scale=1.0)

    # uncapped reference (infinite power budget: never brakes, never caps)
    ref = RowSimulator(workloads, server, n_servers, 10 * n_servers, NoCap(), reqs,
                       shares, SimConfig(power_scale=power_scale,
                                         record_power=False), duration=duration).run()
    cfgd = SimConfig(power_scale=power_scale,
                     telemetry_s=base_cfg.telemetry_s,
                     oob_latency_s=base_cfg.oob_latency_s,
                     brake_latency_s=base_cfg.brake_latency_s)
    res = RowSimulator(workloads, server, n_servers, n_provisioned,
                       policy_factory(), reqs, shares, cfgd, duration=duration,
                       provisioned_w=provisioned_w).run()
    stats = impact_vs_reference(res.latencies, ref.latencies, prios)

    def tput(res_, prio):
        tot = sum(r.out_tokens for r in reqs if prios[r.rid] == prio)
        got = sum(r.out_tokens for r in reqs
                  if prios[r.rid] == prio and r.rid in res_.latencies)
        return got / max(1, tot)

    ok = meets_slo(stats, res.n_brakes, slo)
    return EvalOutcome(
        n_servers=n_servers,
        added_frac=n_servers / n_provisioned - 1.0,
        stats=stats, result=res, ref_result=ref, meets=ok,
        throughput_ratio_hp=tput(res, "high") / max(1e-9, tput(ref, "high")),
        throughput_ratio_lp=tput(res, "low") / max(1e-9, tput(ref, "low")),
    )


def threshold_search(combos: List[Tuple[float, float]], workloads, shares, server,
                     n_provisioned: int, duration: float,
                     added_grid: List[float], **kw) -> Dict[Tuple[float, float], dict]:
    """Fig 13: per (T1,T2), the max added-server fraction that (a) avoids
    powerbrakes and (b) meets SLOs."""
    out = {}
    budget = calibrated_budget(workloads, shares, server, n_provisioned,
                               min(duration, 2 * 86400.0),
                               seed=kw.get("seed", 7),
                               occ_peak=kw.get("occ_peak", 0.62))
    kw = dict(kw, provisioned_w=budget)
    for (t1, t2) in combos:
        rows = []
        max_no_brake = 0.0
        max_slo = 0.0
        for add in added_grid:
            n = int(round(n_provisioned * (1 + add)))
            o = evaluate(lambda: PolcaPolicy(t1=t1, t2=t2), workloads, shares,
                         server, n_provisioned, n, duration, **kw)
            rows.append((add, o))
            if o.result.n_brakes == 0:
                max_no_brake = max(max_no_brake, add)
            if o.meets:
                max_slo = max(max_slo, add)
        out[(t1, t2)] = {"rows": rows, "max_added_no_brake": max_no_brake,
                         "max_added_slo": max_slo}
    return out
