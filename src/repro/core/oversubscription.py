"""Back-compat shims over the experiments API (Fig 13 capacity planning).

The experiment workflow that used to live here — budget calibration,
reference-vs-policy evaluation, threshold search — moved to
``repro.experiments.runner`` behind the declarative ``Scenario`` API
(DESIGN.md §8). These wrappers keep the old positional signatures working:
``evaluate(...)`` builds the equivalent ``Scenario`` and delegates to
``run_experiment``; results are identical bit-for-bit on the same seed.

New code should construct a ``Scenario`` and call
``repro.experiments.run_experiment`` directly.
"""

from __future__ import annotations

from typing import Callable, Dict, List, Optional, Tuple

from repro.core.power_model import ServerPower
from repro.core.simulator import SimConfig
from repro.core.slo import DEFAULT_SLO, SLO
from repro.experiments.runner import BASELINE_PEAK_UTIL  # noqa: F401 (re-export)
from repro.experiments.runner import ExperimentResult
from repro.experiments.runner import calibrated_budget  # noqa: F401 (re-export)
from repro.experiments.runner import run_experiment
from repro.experiments.runner import threshold_search as _threshold_search
from repro.experiments.scenario import (
    FleetSpec,
    Scenario,
    TelemetryConfig,
    TrafficSpec,
)

# the old result type is the new one under its old name
EvalOutcome = ExperimentResult


def _scenario_from_args(name: str, n_provisioned: int, n_servers: int,
                        duration: float, *, seed: int, power_scale: float,
                        occ_peak: float, slo: SLO, sim_cfg: Optional[SimConfig],
                        provisioned_w: Optional[float]) -> Scenario:
    cfg = sim_cfg or SimConfig()
    return Scenario(
        name=name,
        duration_s=duration,
        fleet=FleetSpec(n_provisioned=n_provisioned,
                        added_frac=n_servers / n_provisioned - 1.0),
        traffic=TrafficSpec(occ_peak=occ_peak),
        telemetry=TelemetryConfig(telemetry_s=cfg.telemetry_s,
                                  oob_latency_s=cfg.oob_latency_s,
                                  brake_latency_s=cfg.brake_latency_s),
        slo=slo,
        power_scale=power_scale,
        seed=seed,
        budget="calibrated" if provisioned_w is None else float(provisioned_w),
    )


def evaluate(policy_factory: Callable, workloads, shares, server: ServerPower,
             n_provisioned: int, n_servers: int, duration: float,
             *, seed: int = 7, power_scale: float = 1.0, occ_peak: float = 0.62,
             slo: SLO = DEFAULT_SLO, sim_cfg: SimConfig = None,
             provisioned_w: float = None) -> EvalOutcome:
    """Legacy signature: runs a policy on a trace at N servers against the
    uncapped reference on the same trace. Delegates to ``run_experiment``."""
    sc = _scenario_from_args("legacy-evaluate", n_provisioned, n_servers, duration,
                             seed=seed, power_scale=power_scale, occ_peak=occ_peak,
                             slo=slo, sim_cfg=sim_cfg, provisioned_w=provisioned_w)
    return run_experiment(sc, workloads=(workloads, shares),
                          policy_factory=policy_factory, server=server)


def threshold_search(combos: List[Tuple[float, float]], workloads, shares, server,
                     n_provisioned: int, duration: float,
                     added_grid: List[float], **kw) -> Dict[Tuple[float, float], dict]:
    """Legacy signature for the Fig-13 (T1,T2) sweep."""
    sc = _scenario_from_args("legacy-threshold-search", n_provisioned,
                             n_provisioned, duration,
                             seed=kw.get("seed", 7),
                             power_scale=kw.get("power_scale", 1.0),
                             occ_peak=kw.get("occ_peak", 0.62),
                             slo=kw.get("slo", DEFAULT_SLO),
                             sim_cfg=kw.get("sim_cfg"),
                             provisioned_w=kw.get("provisioned_w"))
    return _threshold_search(sc, combos, added_grid,
                             workloads=(workloads, shares), server=server)
