"""Discrete-event simulator of an LLM inference row under POLCA (paper §6).

Model (matches §6.1):
  * a row of N servers, each dedicated to one workload class (Table 4 mix)
    with a one-request buffer (load-balanced arrivals, queueing delays);
  * each request: prefill phase (compute-bound power spike) then
    ``out_tokens`` of decode (memory-bound, low flat power) — timings and
    per-phase power from ``core.workload`` (roofline-derived);
  * a rack power manager samples row power every ``telemetry_s`` (2 s, Table 1)
    and runs a policy (Algorithm 1 or a baseline); frequency-cap commands take
    effect after ``oob_latency_s`` (40 s), powerbrake after ``brake_latency_s``
    (5 s);
  * oversubscription: provisioned row power is set for ``n_provisioned``
    servers; the row actually hosts N >= n_provisioned.

Everything is deterministic given the trace (seeded), so policy comparisons
diff per-request latencies against an uncapped reference run.
"""

from __future__ import annotations

import heapq
import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.power_model import FREQ_UNCAPPED, ServerPower
from repro.core.slo import LatencyStats
from repro.core.telemetry import Telemetry, dispatch
from repro.core.workload import RequestTiming
from repro.obs.metrics import get_recorder


@dataclass(frozen=True)
class Request:
    t_arrival: float
    wl: int  # workload-class index
    prompt: int
    out_tokens: int
    priority: str  # "high" | "low"
    rid: int


@dataclass(frozen=True)
class WorkloadClass:
    name: str
    timing: RequestTiming  # from core.workload.request_timing
    priority_mix: float  # fraction of requests that are high priority


@dataclass
class SimConfig:
    telemetry_s: float = 2.0
    oob_latency_s: float = 40.0
    brake_latency_s: float = 5.0
    power_scale: float = 1.0  # robustness runs: x1.05 = +5% workload power
    record_power: bool = True
    power_sample_s: float = 2.0


@dataclass
class SimResult:
    latency: LatencyStats
    n_brakes: int
    n_dropped: int
    n_completed: int
    served_tokens: float
    peak_power_frac: float
    mean_power_frac: float
    power_t: np.ndarray = field(default=None, repr=False)
    power_w: np.ndarray = field(default=None, repr=False)
    # per-sample powerbrake state on the power_t grid (True while the row's
    # policy holds the brake) — the signal runtime.fault_tolerance's
    # BrakeSentinel turns into sustained-brake power events
    braked_series: np.ndarray = field(default=None, repr=False)
    latencies: Dict[int, float] = field(default_factory=dict, repr=False)
    cap_events: int = 0
    # time each completed request waited before prefill started (fleet
    # routing attributes queueing delay per dispatch decision from this)
    queue_delays: Dict[int, float] = field(default_factory=dict, repr=False)

    def spike(self, window_s: float) -> float:
        """Max increase of power (fraction of provisioned) over any window."""
        if self.power_w is None or len(self.power_w) < 3:
            return 0.0
        dt = self.power_t[1] - self.power_t[0]
        k = max(1, int(round(window_s / dt)))
        w = self.power_w
        diffs = w[k:] - w[:-k]
        return float(diffs.max()) if len(diffs) else 0.0


class _Server:
    __slots__ = ("idx", "wl", "priority", "state", "queue", "cur", "work_left",
                 "epoch", "freq", "t_service_start", "power_w", "t_last",
                 "power_state")

    def __init__(self, idx, wl, priority):
        self.idx = idx
        self.wl = wl
        self.priority = priority
        self.state = "idle"  # idle | prefill | decode
        self.queue: List[Request] = []
        self.cur: Optional[Request] = None
        self.work_left = 0.0  # seconds of f=1 work in current phase
        self.epoch = 0
        self.freq = FREQ_UNCAPPED
        self.t_service_start = 0.0
        self.power_w = 0.0
        self.t_last = 0.0
        self.power_state = "idle"  # state the power buckets last attributed


class RowSimulator:
    def __init__(self, workloads: List[WorkloadClass], server_power: ServerPower,
                 n_servers: int, n_provisioned: int, policy, requests: List[Request],
                 wl_server_share: List[float], sim_cfg: SimConfig = None,
                 duration: float = None, rng_seed: int = 0,
                 provisioned_w: float = None, row_index: int = 0):
        self.workloads = workloads
        self.sp = server_power
        self.policy = policy
        self.cfg = sim_cfg or SimConfig()
        self.provisioned_w = provisioned_w or (n_provisioned * server_power.provisioned_w)
        self.requests = requests
        self.duration = duration or (requests[-1].t_arrival + 600 if requests else 600)
        self.rng = np.random.default_rng(rng_seed)
        self.row_index = row_index
        # ancestor budget fractions, published by the hierarchy driver
        # (ClusterSimulator / FleetSimulator) before each lockstep tick (one
        # tick stale — rack managers aggregate with delay): a level-indexed
        # vector ordered nearest enclosure first (rack, [pdu-set, ...], root).
        # (None, None) on standalone rows. Read/write through the
        # ``group_fracs`` property (legacy 2-tuple view) or
        # ``group_frac_vec`` (the full vector).
        self._group_frac_vec: Tuple[Optional[float], ...] = (None, None)

        # dedicate servers to workload classes per the Table-4 share
        self.servers: List[_Server] = []
        counts = [max(1, int(round(s * n_servers))) for s in wl_server_share]
        while sum(counts) > n_servers:
            counts[counts.index(max(counts))] -= 1
        while sum(counts) < n_servers:
            counts[counts.index(min(counts))] += 1
        idx = 0
        self.by_wl: Dict[int, List[_Server]] = {i: [] for i in range(len(workloads))}
        for w, c in enumerate(counts):
            n_hp = int(round(c * workloads[w].priority_mix))
            for j in range(c):
                prio = "high" if j < n_hp else "low"
                s = _Server(idx, w, prio)
                self.servers.append(s)
                self.by_wl[w].append(s)
                idx += 1

        self.row_power = sum(self._server_power(s) for s in self.servers)
        self.prio_power = {"high": 0.0, "low": 0.0}
        self.phase_power = {"idle": 0.0, "prefill": 0.0, "decode": 0.0}
        for s in self.servers:
            s.power_w = self._server_power(s)
            s.power_state = s.state
            self.prio_power[s.priority] += s.power_w
            self.phase_power[s.state] += s.power_w

        self.lp_freq = FREQ_UNCAPPED
        self.hp_freq = FREQ_UNCAPPED
        self.events: List[Tuple[float, int, str, tuple]] = []
        self._eid = 0
        self.result = SimResult(LatencyStats(), 0, 0, 0, 0.0, 0.0, 0.0)
        self._power_samples_t: List[float] = []
        self._power_samples_w: List[float] = []
        self._braked_samples: List[bool] = []
        # last brake state seen on the telemetry grid, for edge events
        # (matches braked_series semantics: initial state is unbraked)
        self._last_braked = False
        self._power_integral = 0.0
        self._last_power_t = 0.0
        self._peak = 0.0
        self._t = 0.0
        self._started = False
        self._past_end = False
        # budget-era accounting, only engaged once set_budget() is called
        # (the fleet rebalancing controller): peak/mean power *fractions*
        # must be measured against the budget in force when the power was
        # drawn, not the final budget
        self._budget_moved = False
        self._era_peak = 0.0
        self._era_integral0 = 0.0
        self._frac_peak = 0.0
        self._frac_integral = 0.0

    # ------------------------------------------------------------------
    @property
    def group_frac_vec(self) -> Tuple[Optional[float], ...]:
        """Ancestor budget fractions, nearest level first, root last."""
        return self._group_frac_vec

    @property
    def group_fracs(self) -> Tuple[Optional[float], Optional[float]]:
        """Back-compat 2-tuple view of :attr:`group_frac_vec`:
        ``(rack_frac, cluster_frac)`` = (nearest enclosure, root). On the
        classic two-level tree this is exactly the full vector; on deeper
        trees the intermediate levels are visible via ``group_frac_vec``."""
        vec = self._group_frac_vec
        if not vec:
            return (None, None)
        return (vec[0], vec[-1])

    @group_fracs.setter
    def group_fracs(self, vec) -> None:
        """Accepts a tuple of any depth >= 1 (hierarchy publishers write the
        full ancestor vector here; legacy writers pass the 2-tuple)."""
        self._group_frac_vec = tuple(vec)

    def _push(self, t, kind, args=()):
        self._eid += 1
        heapq.heappush(self.events, (t, self._eid, kind, args))

    def _server_power(self, s: _Server) -> float:
        dev = self.sp.device
        n = self.sp.n_devices
        if s.state == "idle":
            p = n * dev.idle_w + self.sp.other_w
        else:
            wl = self.workloads[s.wl]
            point = wl.timing.prefill_point if s.state == "prefill" else wl.timing.token_point
            p = point.power_at(self.sp, s.freq)
        return p * self.cfg.power_scale

    def _update_power(self, s: _Server, t: float):
        new_p = self._server_power(s)
        if new_p != s.power_w or s.state != s.power_state:
            self._account_power(t)
            self.row_power += new_p - s.power_w
            self.prio_power[s.priority] += new_p - s.power_w
            self.phase_power[s.power_state] -= s.power_w
            self.phase_power[s.state] += new_p
            s.power_state = s.state
            s.power_w = new_p
            self._peak = max(self._peak, self.row_power)
            if self._budget_moved:
                self._era_peak = max(self._era_peak, self.row_power)

    def _account_power(self, t: float):
        self._power_integral += self.row_power * (t - self._last_power_t)
        self._last_power_t = t

    def set_budget(self, budget_w: float, t: float):
        """Change the row power budget at time ``t`` (the fleet rebalancing
        controller's actuation point). Closes the current budget *era* so
        ``peak_power_frac``/``mean_power_frac`` stay measured against the
        budget in force when the power was drawn: the watts-integral and
        running peak accumulated so far are folded into fraction space at
        the old budget before the new one takes effect. Rows that never see
        a ``set_budget`` call keep the original (bit-identical) single-era
        accounting."""
        self._account_power(t)  # fold the open watts segment at the old budget
        if not self._budget_moved:
            self._budget_moved = True
            self._era_peak = self._peak
        self._frac_peak = max(self._frac_peak,
                              self._era_peak / self.provisioned_w)
        self._frac_integral += ((self._power_integral - self._era_integral0)
                                / self.provisioned_w)
        self._era_integral0 = self._power_integral
        self._era_peak = self.row_power  # the standing draw opens the new era
        self.provisioned_w = float(budget_w)

    # ------------------------------------------------------------------
    def _start_next(self, s: _Server, t: float):
        if not s.queue:
            s.state = "idle"
            s.cur = None
            self._update_power(s, t)
            return
        req = s.queue.pop(0)
        s.cur = req
        s.state = "prefill"
        s.t_service_start = t
        wl = self.workloads[s.wl]
        s.work_left = wl.timing.t_prefill
        s.epoch += 1
        self._schedule_phase_end(s, t)
        self._update_power(s, t)

    def _rate(self, s: _Server) -> float:
        """Work-seconds per wall-second at the current frequency."""
        wl = self.workloads[s.wl]
        point = wl.timing.prefill_point if s.state == "prefill" else wl.timing.token_point
        return 1.0 / self.sp.device.perf_scale(point.compute_frac, s.freq)

    def _schedule_phase_end(self, s: _Server, t: float):
        s.t_last = t
        dt = s.work_left / self._rate(s)
        self._push(t + dt, "phase_end", (s.idx, s.epoch))

    # ------------------------------------------------------------------
    def run(self) -> SimResult:
        """Standalone run: start, drain every event, finalize."""
        self.start()
        self.advance_to(self.duration)
        return self.finalize()

    def start(self):
        """Seed the event queue. Idempotent so run() after start() is safe."""
        if self._started:
            return
        self._started = True
        for r in self.requests:
            self._push(r.t_arrival, "arrival", (r,))
        self._push(self.cfg.telemetry_s, "telemetry", ())

    def inject(self, req: Request):
        """Accept an externally dispatched request (the fleet routing layer).

        The arrival rides the same event queue as trace arrivals, so a row
        fed one request at a time by a dispatcher reproduces the standalone
        trace run bit-for-bit (arrival times are continuous, so relative
        event order is decided by time alone; tier-1 asserts the parity).
        Must be called after ``start()``; the arrival must lie within the
        row's duration. A row that already drained past its duration (its
        next queued event overshot — possible in the final partial telemetry
        window when duration is not a multiple of telemetry_s) is revived:
        the overshoot event was discarded, but any event beyond the duration
        is side-effect-free by definition, so processing the late arrival is
        exactly what the standalone trace path would have done."""
        if not self._started:
            raise RuntimeError("inject() before start()")
        if req.t_arrival > self.duration:
            raise ValueError(
                f"inject() at t={req.t_arrival:.1f} beyond the row duration "
                f"({self.duration:.1f})")
        self._past_end = False
        self._push(req.t_arrival, "arrival", (req,))

    def advance_to(self, t_target: float) -> bool:
        """Process every event with t <= min(t_target, duration). Returns
        False once the row is past its duration (no more work will happen).

        ``run()`` is exactly ``advance_to(duration)``; ClusterSimulator calls
        this tick-by-tick to lockstep N rows, which therefore reproduces the
        standalone event sequence bit-for-bit."""
        if self._past_end:
            return False
        while self.events:
            item = heapq.heappop(self.events)
            t = item[0]
            if t > self.duration:
                self._t = t  # matches the standalone loop's break-with-overshoot
                self._past_end = True
                return False
            if t > t_target:
                heapq.heappush(self.events, item)  # same eid: order preserved
                return True
            self._t = t
            self._handle(t, item[2], item[3])
        return False

    def finalize(self) -> SimResult:
        res = self.result
        t = self._t
        self._account_power(t if t <= self.duration else self.duration)
        res.n_brakes = self.policy.n_brakes
        dur = max(1e-9, self._last_power_t)
        if self._budget_moved:
            # per-era fractions: each watt-second against its era's budget
            res.peak_power_frac = max(self._frac_peak,
                                      self._era_peak / self.provisioned_w)
            res.mean_power_frac = (self._frac_integral
                                   + (self._power_integral - self._era_integral0)
                                   / self.provisioned_w) / dur
        else:
            res.peak_power_frac = self._peak / self.provisioned_w
            res.mean_power_frac = self._power_integral / dur / self.provisioned_w
        if self.cfg.record_power:
            res.power_t = np.asarray(self._power_samples_t)
            res.power_w = np.asarray(self._power_samples_w)
            res.braked_series = np.asarray(self._braked_samples, dtype=bool)
        return res

    def candidates(self, wl: int, priority: str) -> List[_Server]:
        """The server pool a request of (wl, priority) is served from: the
        workload class AND the request's priority pool — HP requests must not
        land on LP-capped servers — falling back to the whole class when the
        priority sub-pool is empty. The fleet router scores rows against this
        same pool (single source of the eligibility rule)."""
        cands = [s for s in self.by_wl[wl] if s.priority == priority]
        return cands if cands else self.by_wl[wl]

    def sample_telemetry(self, t: float) -> Telemetry:
        """The structured controller sample at time t (see core.telemetry)."""
        rack_frac, cluster_frac = self.group_fracs
        vec = self._group_frac_vec
        group_vec = vec if (vec and vec[0] is not None) else None
        return Telemetry(
            t=t,
            power_frac=self.row_power / self.provisioned_w,
            hp_power_frac=self.prio_power["high"] / self.provisioned_w,
            lp_power_frac=self.prio_power["low"] / self.provisioned_w,
            prefill_power_frac=self.phase_power["prefill"] / self.provisioned_w,
            lp_freq=self.lp_freq,
            hp_freq=self.hp_freq,
            braked=bool(getattr(self.policy, "braked", False)),
            row_index=self.row_index,
            rack_power_frac=rack_frac,
            cluster_power_frac=cluster_frac,
            group_power_fracs=group_vec,
        )

    def _handle(self, t: float, kind: str, args: tuple):
        res = self.result
        if kind == "arrival":
            (req,) = args
            cands = self.candidates(req.wl, req.priority)
            idle = [s for s in cands if s.state == "idle"]
            buf = [s for s in cands if s.state != "idle" and len(s.queue) < 1]
            if idle:
                s = idle[int(self.rng.integers(len(idle)))]
                s.queue.append(req)
                self._start_next(s, t)
            elif buf:
                s = min(buf, key=lambda x: len(x.queue))
                s.queue.append(req)
            else:
                res.n_dropped += 1
        elif kind == "phase_end":
            sid, epoch = args
            s = self.servers[sid]
            if epoch != s.epoch or s.state == "idle":
                return  # stale event
            if s.state == "prefill":
                s.state = "decode"
                wl = self.workloads[s.wl]
                s.work_left = s.cur.out_tokens * wl.timing.t_token
                s.epoch += 1
                self._schedule_phase_end(s, t)
                self._update_power(s, t)
            else:
                req = s.cur
                wl = self.workloads[s.wl]
                # unqueued, uncapped ideal latency
                ideal = wl.timing.t_prefill + req.out_tokens * wl.timing.t_token
                actual = t - req.t_arrival
                res.latency.add(req.priority, actual, ideal)
                res.latencies[req.rid] = actual
                qd = s.t_service_start - req.t_arrival
                res.queue_delays[req.rid] = qd
                res.n_completed += 1
                res.served_tokens += req.out_tokens
                # write-only observability: a no-op on the NullRecorder
                # default, never read back into simulation state
                get_recorder().observe_k("row_queue_delay_seconds", qd,
                                         (("priority", req.priority),))
                self._start_next(s, t)
        elif kind == "telemetry":
            tel = self.sample_telemetry(t)
            for cmd in dispatch(self.policy, tel):
                lat = self.cfg.brake_latency_s if cmd.brake else self.cfg.oob_latency_s
                self._push(t + lat, "apply", (cmd.lp_freq, cmd.hp_freq))
                res.cap_events += 1
            if self.cfg.record_power:
                self._power_samples_t.append(t)
                self._power_samples_w.append(tel.power_frac)
                braked = bool(tel.braked)
                self._braked_samples.append(braked)
                if braked != self._last_braked:
                    # brake engage/release *edge* events, emitted at the
                    # same sample point braked_series records — so edge
                    # counts in the event trace reconcile exactly with
                    # braked_series transitions (benchmark-asserted)
                    self._last_braked = braked
                    rec = get_recorder()
                    rec.event("row",
                              "brake_engage" if braked else "brake_release",
                              t=t, row=self.row_index)
                    rec.counter("row_brake_edges_total",
                                edge="engage" if braked else "release",
                                row=self.row_index)
            self._push(t + self.cfg.telemetry_s, "telemetry", ())
        elif kind == "apply":
            lp, hp = args
            if lp is not None:
                self.lp_freq = lp
            if hp is not None:
                self.hp_freq = hp
            for s in self.servers:
                f = self.lp_freq if s.priority == "low" else self.hp_freq
                if f != s.freq:
                    if s.state != "idle":
                        # bank progress at the old rate, then re-plan
                        s.work_left = max(
                            0.0, s.work_left - (t - s.t_last) * self._rate(s))
                        s.freq = f
                        s.epoch += 1
                        self._schedule_phase_end(s, t)
                    else:
                        s.freq = f
                    self._update_power(s, t)
