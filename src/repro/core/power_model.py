"""Device/server power model driven by roofline utilization (paper §2).

The paper measures GPU power with DCGM; this container has no power meter, so
POLCA's power plane is closed mechanistically instead (DESIGN.md §2): each
inference phase gets a (compute-util, membw-util) operating point from the
same analytic/compiled roofline terms the dry-run produces, and utilization
maps to watts via a DVFS model:

    P(u_c, u_m, f) = P_idle + (P_peak - P_idle) * (w_c*u_c + w_m*u_m) * (f/f_max)^gamma

with gamma ~ 2.4 (dynamic power ~ C f V^2, V tracking f near the top of the
DVFS range). This reproduces the paper's two central observations by
construction rather than by curve-fitting:

  * prompt (prefill) phases are compute-bound: u_c ~ 1 -> spiky power at or
    above TDP (P_peak = spike_frac * TDP > TDP, Fig. 4/5);
  * token (decode) phases are memory-bound: u_c << 1, u_m ~ 1 -> flat power
    around ~half of TDP (Fig. 4);
  * frequency capping is superlinear (Fig. 7): power drops ~ f^gamma while
    only the compute-bound fraction of the workload slows down ~ f.

Two device profiles ship: A100-80GB (to replicate the paper's published
characterization and production patterns) and TPU v5e (the deployment target;
same constants as §Roofline).
"""

from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class DevicePower:
    name: str
    peak_flops: float  # per chip, bf16
    hbm_bw: float  # bytes/s
    tdp_w: float
    idle_w: float
    spike_frac: float = 1.25  # instantaneous peak above TDP (paper Fig 11: up to +500W/8)
    gamma: float = 2.4  # DVFS exponent
    f_max: float = 1.0  # normalized frequency range
    f_base: float = 1275.0 / 1410.0  # A100: base/boost clock
    f_brake: float = 288.0 / 1410.0  # powerbrake clock
    # dynamic-power shares (calibrated so BLOOM prompt ~= 1.0-1.1 TDP and
    # token ~= 0.55 TDP as in paper Fig. 4; they may sum > 1 — the power-virus
    # point u_c = u_m = 1 hits p_peak = spike_frac * TDP)
    w_compute: float = 0.77
    w_memory: float = 0.32

    @property
    def p_peak(self) -> float:
        return self.tdp_w * self.spike_frac

    def power(self, u_compute: float, u_memory: float, f: float = 1.0) -> float:
        """Watts at (utilization, normalized frequency)."""
        u = min(1.0, self.w_compute * min(u_compute, 1.0)
                + self.w_memory * min(u_memory, 1.0))
        return self.idle_w + (self.p_peak - self.idle_w) * u * (f / self.f_max) ** self.gamma

    def perf_scale(self, compute_frac: float, f: float) -> float:
        """Relative execution-time multiplier at capped frequency.

        ``compute_frac``: fraction of (uncapped) step time that is
        compute-bound. Memory-bound time is frequency-insensitive until the
        slowed compute exceeds it; this max() is what makes the paper's
        power/perf trade superlinear.
        """
        f = max(f, 1e-3)
        return compute_frac / f + (1.0 - compute_frac)


# The paper's measurement platform: DGX A100-80GB.
A100 = DevicePower(
    name="a100-80g",
    peak_flops=312e12,
    hbm_bw=2039e9,
    tdp_w=400.0,
    idle_w=90.0,
)

# Deployment target (same constants as parallel/roofline.py).
TPU_V5E = DevicePower(
    name="tpu-v5e",
    peak_flops=197e12,
    hbm_bw=819e9,
    tdp_w=220.0,
    idle_w=55.0,
    f_base=0.9,
    f_brake=0.2,
)

# A100 frequency levels used by POLCA's modes (Table 3), normalized to 1410 MHz.
FREQ_UNCAPPED = 1.0
FREQ_LP_T1 = 1275.0 / 1410.0  # 1275 MHz: A100 base clock
FREQ_LP_T2 = 1110.0 / 1410.0
FREQ_HP_T2 = 1305.0 / 1410.0
FREQ_BRAKE = 288.0 / 1410.0


@dataclass(frozen=True)
class ServerPower:
    """A GPU server: n_devices accelerators ~ 60% of server power (Fig 11)."""

    device: DevicePower
    n_devices: int = 8
    gpu_power_share: float = 0.6  # GPUs / total server power (consumed)

    @property
    def other_w(self) -> float:
        # non-GPU components, sized so GPUs at TDP are `gpu_power_share`
        return self.n_devices * self.device.tdp_w * (1 - self.gpu_power_share) / self.gpu_power_share

    @property
    def provisioned_w(self) -> float:
        """Per-server power rating: GPUs at TDP + the rest of the box.

        Instantaneous GPU spikes may exceed TDP (Fig. 11: up to +500 W per
        server), so row power can transiently exceed 100% of provisioned —
        that is exactly the excursion the powerbrake backstop exists for.
        """
        return self.n_devices * self.device.tdp_w + self.other_w

    def power(self, u_compute: float, u_memory: float, f: float = 1.0) -> float:
        return self.n_devices * self.device.power(u_compute, u_memory, f) + self.other_w

    @property
    def idle_power(self) -> float:
        return self.n_devices * self.device.idle_w + self.other_w
