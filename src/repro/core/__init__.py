"""POLCA: power oversubscription for LLM clusters (the paper's contribution)."""
