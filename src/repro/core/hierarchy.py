"""First-class power-budget trees: arbitrary-depth site -> rack -> row
accounting shared by the simulator, fleet, controller, and planner.

POLCA's oversubscription argument is hierarchical: headroom exists at the
row, rack, PDU-set, and site levels, and production clusters enforce a power
budget at *each* ("From Servers to Sites" and the 100 MW-cluster papers model
exactly this composition). Before this module the repo hard-coded a two-level
rack/cluster split in four independent places; :class:`PowerHierarchy` is the
single structure they all now share:

* **Topology** — a rooted tree whose leaves are rows (leaf index ==
  ``RowSimulator`` list index) and whose interior nodes (racks, PDU sets,
  the site root, any depth) each hold a power budget. Budgets default to the
  sum of their children's budgets, level by level — no extra oversubscription
  appears at an aggregation level unless explicitly configured.

* **Vectorized accounting** — :meth:`fold_w` turns a ``[T, R]`` per-row
  power matrix into a ``[T, N]`` per-node matrix in one pass; every interior
  node's series is the masked sum of its *descendant-leaf* columns in leaf
  order, which makes the two-level fold bit-identical to the legacy
  ``RackHierarchy`` expressions (``power[:, rack_of == k].sum(axis=1)`` and
  ``power.sum(axis=1)``) — asserted in tier-1.

* **Telemetry publishing** — :meth:`publish` pushes each leaf's *ancestor*
  budget fractions into its row as a level-indexed vector (immediate parent
  first, root last). On a two-level tree that vector is exactly the legacy
  ``(rack_frac, cluster_frac)`` 2-tuple.

The fleet rebalancing controller mutates ``node_budget_w`` for interior
nodes when it re-divides a site budget across racks
(:class:`~repro.fleet.controller.FleetController` ``scope="tree"``); the
tree's *root* budget is the envelope and never moves under rebalancing.
Only the chaos engine (:mod:`repro.chaos`) may change the root: a fault
event physically removes (and later returns) deliverable watts, recorded
as ``node_cap_w`` capacity ceilings the controller's divisions respect.
"""

from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


class PowerHierarchy:
    """An arbitrary-depth power-budget tree over ``n_leaves`` rows.

    Nodes are indexed ``0 .. n_nodes-1`` with the leaves first
    (``0 .. n_leaves-1``, matching the row order) and interior nodes after,
    children always before their parent (the root is the last node). This
    bottom-up ordering makes "sum children into parents" a single forward
    pass over the interior nodes.

    ``parent[i]`` is the parent node index (``-1`` for the root);
    ``node_budget_w[i]`` the node's power budget in watts (mutable — the
    fleet controller re-divides interior budgets under ``scope="tree"``);
    ``names[i]`` a human-readable label carried into telemetry and docs.
    """

    def __init__(self, parent: Sequence[int], node_budget_w: Sequence[float],
                 n_leaves: int, names: Optional[Sequence[str]] = None):
        self.parent = np.asarray(parent, dtype=int)
        self.node_budget_w = np.asarray(node_budget_w, dtype=float).copy()
        self.n_leaves = int(n_leaves)
        self.n_nodes = len(self.parent)
        if len(self.node_budget_w) != self.n_nodes:
            raise ValueError(
                f"{len(self.node_budget_w)} budgets for {self.n_nodes} nodes")
        if not 0 < self.n_leaves <= self.n_nodes:
            raise ValueError(
                f"n_leaves={self.n_leaves} out of range for {self.n_nodes} nodes")
        roots = np.flatnonzero(self.parent < 0)
        if len(roots) != 1:
            raise ValueError(f"need exactly one root, got {len(roots)}")
        self.root = int(roots[0])
        # children before parents: a forward pass over interior nodes folds
        # leaves upward without an explicit toposort
        for i, p in enumerate(self.parent):
            if p >= 0 and p <= i:
                raise ValueError(
                    f"node {i} has parent {p} <= itself; order children first")
            if 0 <= p < self.n_leaves:
                raise ValueError(f"leaf {p} cannot be a parent (of node {i})")
        self.names: Tuple[str, ...] = tuple(
            names if names is not None
            else [f"row{i}" for i in range(self.n_leaves)]
            + [f"node{i}" for i in range(self.n_leaves, self.n_nodes)])
        if len(self.names) != self.n_nodes:
            raise ValueError(f"{len(self.names)} names for {self.n_nodes} nodes")
        # physical capacity ceilings, +inf by default. Distinct from budgets:
        # a budget is the *planner's* division of the envelope and moves
        # freely under rebalancing; a cap is what the hardware can currently
        # deliver. The chaos engine lowers a node's cap on a derate (PDU feed
        # loss, thermal throttle) and the rebalancing controller clamps its
        # divisions to it — otherwise a tree-scope pass would "heal" the
        # fault by growing the derated subtree back on its next interval.
        self.node_cap_w = np.full(self.n_nodes, np.inf)

        self.children: List[np.ndarray] = [
            np.flatnonzero(self.parent == i) for i in range(self.n_nodes)]
        for i in range(self.n_leaves):
            if len(self.children[i]):
                raise ValueError(f"leaf {i} has children")
        for i in range(self.n_leaves, self.n_nodes):
            if not len(self.children[i]):
                raise ValueError(f"interior node {i} ({self.names[i]}) is "
                                 "childless — every interior node needs rows "
                                 "under it")
        # descendant leaves per node, in leaf-index order (the summation
        # order every fold uses — this is what makes two-level folds
        # bit-identical to the legacy flat expressions)
        self.leaf_desc: List[np.ndarray] = [np.asarray([i], dtype=int)
                                            for i in range(self.n_leaves)]
        for i in range(self.n_leaves, self.n_nodes):
            self.leaf_desc.append(np.sort(np.concatenate(
                [self.leaf_desc[int(c)] for c in self.children[i]])))
        if len(self.leaf_desc[self.root]) != self.n_leaves:
            raise ValueError("root does not cover every leaf")
        # ancestors per leaf, leaf-upward (immediate parent first, root last)
        self.ancestors: List[np.ndarray] = []
        for i in range(self.n_leaves):
            chain = []
            p = int(self.parent[i])
            while p >= 0:
                chain.append(p)
                p = int(self.parent[p])
            self.ancestors.append(np.asarray(chain, dtype=int))
        self.depth = max(len(a) for a in self.ancestors)
        # interior nodes grouped by level, counted from the leaves: level 0 =
        # leaf parents ("racks" on a two-level tree), the last level = root
        self.levels: List[np.ndarray] = []
        for lv in range(self.depth):
            seen: List[int] = []
            for a in self.ancestors:
                if len(a) > lv and int(a[lv]) not in seen:
                    seen.append(int(a[lv]))
            self.levels.append(np.asarray(seen, dtype=int))

    # -- constructors -------------------------------------------------------
    @classmethod
    def two_level(cls, row_budget_w: Sequence[float], *, rows_per_rack: int = 2,
                  rack_budget_w: Optional[Sequence[float]] = None,
                  cluster_budget_w: Optional[float] = None) -> "PowerHierarchy":
        """The legacy row -> rack -> cluster split (``RackHierarchy``'s
        topology and budget defaulting, bit for bit): racks take consecutive
        runs of ``rows_per_rack`` rows (the last rack may be ragged), rack
        budgets default to the sum of their rows, the cluster budget to the
        sum of the racks."""
        row_budget_w = np.asarray(row_budget_w, dtype=float)
        n_rows = len(row_budget_w)
        rows_per_rack = max(1, int(rows_per_rack))
        n_racks = math.ceil(n_rows / rows_per_rack)
        rack_of = np.asarray([i // rows_per_rack for i in range(n_rows)])
        if rack_budget_w is None:
            rack_budget_w = [float(row_budget_w[rack_of == k].sum())
                             for k in range(n_racks)]
        rack_budget_w = np.asarray(rack_budget_w, dtype=float)
        if len(rack_budget_w) != n_racks:
            raise ValueError(
                f"{len(rack_budget_w)} rack budgets for {n_racks} racks")
        cluster = float(cluster_budget_w if cluster_budget_w is not None
                        else rack_budget_w.sum())
        parent = ([n_rows + k for k in rack_of]
                  + [n_rows + n_racks] * n_racks + [-1])
        budgets = np.concatenate([row_budget_w, rack_budget_w, [cluster]])
        names = ([f"row{i}" for i in range(n_rows)]
                 + [f"rack{k}" for k in range(n_racks)] + ["cluster"])
        return cls(parent, budgets, n_rows, names)

    @classmethod
    def from_shape(cls, shape: Sequence[int], row_budget_w: Sequence[float], *,
                   level_names: Optional[Sequence[str]] = None,
                   budget_fracs: Optional[Dict[str, float]] = None
                   ) -> "PowerHierarchy":
        """A uniform tree from root-down fan-outs: ``shape=(2, 2, 3)`` is a
        root with 2 children (PDU sets), each with 2 children (racks), each
        hosting 3 rows — ``prod(shape)`` leaves total.

        ``level_names`` labels the *interior* levels root-down (default
        ``site`` / ``pduN`` / ``rackN`` style); ``budget_fracs`` derates
        nodes by root-down path (``"0/1"`` = second child of the root's
        first child). A derate multiplies every descendant leaf's budget —
        planner-shaped budgets stay *conservative*: each node's budget is
        exactly the sum of its children's, so a derated rack shrinks its
        rows' budgets rather than promising watts the PDU can't deliver.
        """
        shape = tuple(int(s) for s in shape)
        if not shape or any(s < 1 for s in shape):
            raise ValueError(f"shape must be positive fan-outs, got {shape}")
        n_rows = int(np.prod(shape))
        row_budget_w = np.asarray(row_budget_w, dtype=float)
        if len(row_budget_w) != n_rows:
            raise ValueError(
                f"shape {shape} implies {n_rows} rows, got "
                f"{len(row_budget_w)} row budgets")
        budget_fracs = dict(budget_fracs or {})
        if level_names is None:
            defaults = ["site", "pdu", "rack", "subrack", "shelf"]
            level_names = (defaults[:len(shape)] if len(shape) <= len(defaults)
                           else [f"l{d}" for d in range(len(shape))])
        level_names = tuple(level_names)
        if len(level_names) != len(shape):
            raise ValueError(f"{len(level_names)} level names for "
                             f"{len(shape)} interior levels")

        # enumerate interior nodes per level, root-down; leaves come first in
        # the node index space, then the deepest interior level, ..., root
        # (children always precede parents)
        counts = [1]
        for s in shape[:-1]:
            counts.append(counts[-1] * s)  # nodes at interior level d
        n_interior = sum(counts)
        n_nodes = n_rows + n_interior
        # interior node index for (level d root-down, ordinal j at that
        # level): deepest level sits right after the leaves
        offsets = {}
        base = n_rows
        for d in range(len(shape) - 1, -1, -1):
            offsets[d] = base
            base += counts[d]

        parent = np.empty(n_nodes, dtype=int)
        names: List[str] = [f"row{i}" for i in range(n_rows)] + [""] * n_interior
        paths: Dict[int, str] = {}
        leaf_derate = np.ones(n_rows)
        for d in range(len(shape)):
            for j in range(counts[d]):
                node = offsets[d] + j
                parent[node] = -1 if d == 0 else offsets[d - 1] + j // shape[d - 1]
                path = "/".join(str(x) for x in _path_digits(j, shape[:d]))
                paths[node] = path
                label = level_names[d] if d == 0 and counts[d] == 1 else \
                    f"{level_names[d]}{path.replace('/', '.')}"
                names[node] = label
        # leaves hang off the deepest interior level
        deepest = len(shape) - 1
        for i in range(n_rows):
            parent[i] = offsets[deepest] + i // shape[deepest]
        # derates: multiply every descendant leaf's budget
        known_paths = set(paths.values())
        for path, frac in budget_fracs.items():
            if path not in known_paths:
                raise ValueError(
                    f"budget_fracs path {path!r} names no interior node of "
                    f"shape {shape} (known: {sorted(known_paths)})")
            if not (np.isfinite(frac) and frac > 0.0):
                # a 0 W row budget divides telemetry by zero (and the
                # RowSimulator nominal fallback would silently *undo* it)
                raise ValueError(
                    f"budget_fracs[{path!r}] must be a positive finite "
                    f"multiplier, got {frac!r}")
            digits = [int(x) for x in path.split("/")] if path else []
            lo, hi = _leaf_span(digits, shape)
            leaf_derate[lo:hi] *= float(frac)
        budgets = np.empty(n_nodes)
        budgets[:n_rows] = row_budget_w * leaf_derate
        # interior budgets: sum of children, filled deepest level first
        for d in range(len(shape) - 1, -1, -1):
            for j in range(counts[d]):
                node = offsets[d] + j
                kids = (np.arange(j * shape[d], (j + 1) * shape[d])
                        if d == len(shape) - 1
                        else offsets[d + 1] + np.arange(j * shape[d],
                                                        (j + 1) * shape[d]))
                budgets[node] = float(budgets[kids].sum())
        return cls(parent, budgets, n_rows, names)

    # -- views --------------------------------------------------------------
    @property
    def leaf_budget_w(self) -> np.ndarray:
        """Budgets of the leaves (rows), in row order — a view."""
        return self.node_budget_w[:self.n_leaves]

    @property
    def interior(self) -> np.ndarray:
        """Interior node indices, children-first (root last)."""
        return np.arange(self.n_leaves, self.n_nodes)

    @property
    def leaf_parents(self) -> np.ndarray:
        """The leaf-parent ("rack") nodes, first-leaf order — level 0."""
        return self.levels[0]

    @property
    def root_budget_w(self) -> float:
        return float(self.node_budget_w[self.root])

    def subtree_leaves(self, node: int) -> np.ndarray:
        """Descendant-leaf indices of ``node``, in leaf order."""
        return self.leaf_desc[int(node)]

    # -- accounting ---------------------------------------------------------
    def node_w(self, row_w: np.ndarray) -> np.ndarray:
        """Per-node watts ``[N]`` from per-row watts ``[R]`` — the *publish*
        accumulation. Matches the legacy publish path bit for bit at any
        rack width: leaves accumulate into their parents via ``np.add.at``
        (strictly sequential in leaf order, exactly the legacy rack
        expression), interior totals then propagate upward children-first,
        and the root uses the direct ``row_w.sum()`` the legacy cluster
        expression used. (A pairwise ``row_w[desc].sum()``
        diverges from ``np.add.at`` in the last bits once a node spans > 8
        rows — the distinction is load-bearing for parity.)"""
        row_w = np.asarray(row_w, dtype=float)
        out = np.zeros(self.n_nodes)
        out[:self.n_leaves] = row_w
        np.add.at(out, self.parent[:self.n_leaves], row_w)
        for i in range(self.n_leaves, self.n_nodes - 1):
            p = int(self.parent[i])
            if p >= 0:
                out[p] += out[i]
        # the root alone uses the direct sum (the legacy *cluster*
        # expression); a full-cover rack keeps the accumulated value — the
        # legacy rack and cluster series were computed by different
        # expressions even when they covered the same rows
        out[self.root] = row_w.sum()
        return out

    def fold_w(self, power: np.ndarray) -> np.ndarray:
        """``[T, R]`` per-row watts -> ``[T, N]`` per-node watts, one
        vectorized masked sum per interior node."""
        power = np.asarray(power, dtype=float)
        out = np.empty((power.shape[0], self.n_nodes))
        out[:, :self.n_leaves] = power
        for i in range(self.n_leaves, self.n_nodes):
            # masked-column reductions for interior nodes (the legacy rack
            # expression — fancy and boolean masks reduce identically); the
            # root alone uses the direct sum (the legacy cluster
            # expression), which diverges from a masked copy in the last
            # bits once it spans > 8 rows
            out[:, i] = (power.sum(axis=1) if i == self.root
                         else power[:, self.leaf_desc[i]].sum(axis=1))
        return out

    def fold(self, power: np.ndarray,
             node_budget_w: Optional[np.ndarray] = None) -> np.ndarray:
        """``[T, R]`` per-row watts -> ``[T, N]`` per-node *fractions* of
        each node's budget. ``node_budget_w`` may be ``[N]`` (static budgets,
        default: the hierarchy's current budgets) or ``[T, N]`` (per-tick
        budgets recorded under a rebalancing controller)."""
        folded = self.fold_w(power)
        if not len(folded):
            return folded
        budgets = (self.node_budget_w if node_budget_w is None
                   else np.asarray(node_budget_w, dtype=float))
        if budgets.ndim == 1:
            return folded / budgets[None, :]
        return folded / budgets

    def publish(self, rows, row_w: np.ndarray) -> np.ndarray:
        """Compute per-node budget fractions from current per-row watts and
        push each leaf's ancestor fractions (parent first, root last) into
        its row's ``group_fracs`` vector. Returns the ``[N]`` fraction
        vector (callers read the root entry as the stale cluster frac)."""
        frac = self.node_w(row_w) / self.node_budget_w
        for i, r in enumerate(rows):
            r.group_fracs = tuple(float(frac[a]) for a in self.ancestors[i])
        return frac

    def conservation_errors(self, atol: float = 1e-6) -> List[str]:
        """Budget-tree consistency: every interior node's budget must equal
        the sum of its children's (the structural invariant rebalancing
        preserves). Returns human-readable violations (empty = consistent)."""
        errs = []
        for i in range(self.n_leaves, self.n_nodes):
            kids = float(self.node_budget_w[self.children[i]].sum())
            own = float(self.node_budget_w[i])
            if abs(kids - own) > atol:
                errs.append(f"{self.names[i]}: budget {own:.3f} W != "
                            f"children sum {kids:.3f} W")
        return errs


def _path_digits(ordinal: int, fanouts: Sequence[int]) -> List[int]:
    """Root-down path digits of the ``ordinal``-th node at a level whose
    ancestor fan-outs are ``fanouts`` (mixed-radix decomposition)."""
    digits: List[int] = []
    for f in reversed(fanouts):
        digits.append(ordinal % f)
        ordinal //= f
    return list(reversed(digits))


def _leaf_span(digits: Sequence[int], shape: Sequence[int]) -> Tuple[int, int]:
    """The contiguous leaf-index range under the interior node at root-down
    path ``digits`` in a uniform tree of ``shape`` (mixed-radix ordinal at
    the node's level, times leaves per node at that level)."""
    ordinal = 0
    for d, digit in enumerate(digits):
        ordinal = ordinal * shape[d] + digit
    leaves_per = int(np.prod(shape[len(digits):]))
    return ordinal * leaves_per, (ordinal + 1) * leaves_per
