"""POLCA power-management policy (paper Algorithm 1 + Table 3) and baselines.

The controller consumes *delayed* row-power telemetry and emits frequency-cap
commands that take effect after the out-of-band latency (Table 1). It is a
pure state machine: the simulator (or a real rack manager) owns time.

Policies implement the structured protocol ``observe(Telemetry)`` (see
``core.telemetry``); the legacy ``step(p: float)`` survives as a shim that
wraps the bare row-power fraction, so old traces replay bit-identically.

Power modes (Table 3, A100 MHz normalized to 1410):
  | mode        | low priority        | high priority       |
  | uncapped    | uncapped            | uncapped            |
  | T1          | 1275 MHz            | uncapped            |
  | T2          | 1110 MHz            | 1305 MHz            |
  | powerbrake  | 288 MHz             | 288 MHz             |
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Tuple

from repro.core.power_model import (
    FREQ_BRAKE,
    FREQ_HP_T2,
    FREQ_LP_T1,
    FREQ_LP_T2,
    FREQ_UNCAPPED,
)
from repro.core.telemetry import Telemetry, TelemetryPolicy


@dataclass(frozen=True)
class CapCommand:
    """Set (lp_freq, hp_freq) across the row; None = leave unchanged."""
    lp_freq: Optional[float] = None
    hp_freq: Optional[float] = None
    brake: bool = False
    reason: str = ""


@dataclass
class PolcaPolicy(TelemetryPolicy):
    """Dual-threshold, priority-aware frequency capping with hysteresis."""

    t1: float = 0.80  # thresholds as fractions of provisioned row power
    t2: float = 0.89
    t1_buffer: float = 0.05  # uncap hysteresis (§5.1: 5% below threshold)
    t2_buffer: float = 0.05
    lp_freq_t1: float = FREQ_LP_T1
    lp_freq_t2: float = FREQ_LP_T2
    hp_freq_t2: float = FREQ_HP_T2
    brake_freq: float = FREQ_BRAKE
    # HP escalation waits for the LP T2 cap to actuate through the slow OOB
    # path (40 s) and verifiably fail before touching HP (Algorithm 1's
    # "subsequently if needed"); 25 ticks x 2 s > 40 s + settling.
    escalation_ticks: int = 25

    # state
    t1_capped: bool = False
    t2_capped: bool = False
    hp_capped: bool = False
    braked: bool = False
    n_brakes: int = 0
    _t2_since: int = 0

    name: str = "polca"

    def observe(self, tel: Telemetry) -> List[CapCommand]:
        """One telemetry sample. Algorithm 1 over ``tel.power_frac``."""
        p = tel.power_frac
        cmds: List[CapCommand] = []
        if p > 1.0:
            if not self.braked:
                self.braked = True
                self.n_brakes += 1
                cmds.append(CapCommand(self.brake_freq, self.brake_freq, brake=True,
                                       reason="powerbrake"))
            self.t1_capped = True
            self.t2_capped = True
            self.hp_capped = True
            return cmds
        if self.braked:
            # leaving brake: fall back to the T2 mode caps
            self.braked = False
            cmds.append(CapCommand(self.lp_freq_t2, self.hp_freq_t2,
                                   reason="brake-release->T2"))
        if p > self.t2:
            if not self.t2_capped:
                self.t2_capped = True
                self.t1_capped = True
                self._t2_since = 0
                cmds.append(CapCommand(lp_freq=self.lp_freq_t2, reason="T2: cap LP"))
            elif not self.hp_capped:
                self._t2_since += 1
                if self._t2_since >= self.escalation_ticks:
                    # LP capping verifiably insufficient: cap HP (Algorithm 1)
                    self.hp_capped = True
                    cmds.append(CapCommand(hp_freq=self.hp_freq_t2, reason="T2: cap HP"))
        elif p > self.t1:
            if not self.t1_capped:
                self.t1_capped = True
                cmds.append(CapCommand(lp_freq=self.lp_freq_t1, reason="T1: cap LP"))
        # uncap with hysteresis
        if self.t2_capped and p < self.t2 - self.t2_buffer:
            self.t2_capped = False
            self.hp_capped = False
            cmds.append(CapCommand(lp_freq=self.lp_freq_t1, hp_freq=FREQ_UNCAPPED,
                                   reason="T2 release -> T1 caps"))
        if self.t1_capped and not self.t2_capped and p < self.t1 - self.t1_buffer:
            self.t1_capped = False
            cmds.append(CapCommand(lp_freq=FREQ_UNCAPPED, reason="T1 release"))
        return cmds


@dataclass
class PredictivePolcaPolicy(PolcaPolicy):
    """Telemetry-enabled POLCA variant (beyond paper, enabled by the richer
    protocol):

    * **predictive capping** — least-squares slope over the last ``window``
      samples extrapolates row power ``horizon_s`` ahead (default = the 40 s
      out-of-band actuation latency, Table 1) and caps on the *predicted*
      crossing, so caps land when the threshold is actually reached instead
      of 40 s late;
    * **informed escalation** — the per-priority power split tells the
      controller when LP capping *cannot* shed enough power (LP share smaller
      than the excess over T2), so it escalates to the HP cap immediately
      instead of waiting ``escalation_ticks`` for the LP cap to verifiably
      fail.

    The powerbrake path is never predicted: brakes fire on measured overload
    only, so ``n_brakes`` keeps its physical meaning.
    """

    horizon_s: float = 40.0
    window: int = 8
    name: str = "polca-predictive"
    _hist_t: List[float] = field(default_factory=list)
    _hist_p: List[float] = field(default_factory=list)

    def _predict(self, t: float, p: float) -> float:
        self._hist_t.append(t)
        self._hist_p.append(p)
        if len(self._hist_t) > self.window:
            del self._hist_t[0]
            del self._hist_p[0]
        if len(self._hist_t) < 3:
            return p
        tm = sum(self._hist_t) / len(self._hist_t)
        pm = sum(self._hist_p) / len(self._hist_p)
        num = sum((ti - tm) * (pi - pm) for ti, pi in zip(self._hist_t, self._hist_p))
        den = sum((ti - tm) ** 2 for ti in self._hist_t)
        if den <= 0.0:
            return p
        slope = num / den
        return max(p, p + slope * self.horizon_s)

    def observe(self, tel: Telemetry) -> List[CapCommand]:
        p = tel.power_frac
        p_eff = self._predict(tel.t, p)
        if p <= 1.0:
            # prediction may cap early but must never fake a powerbrake
            p_eff = min(p_eff, 1.0 - 1e-9)
        if (tel.lp_power_frac is not None and self.t2_capped and not self.hp_capped
                and p > self.t2 and tel.lp_power_frac < p - self.t2):
            # even shutting LP off entirely cannot bring the row below T2:
            # skip the wait-and-verify loop and cap HP on the next decision
            self._t2_since = self.escalation_ticks
        return super().observe(replace(tel, power_frac=p_eff))


@dataclass
class OneThreshold(TelemetryPolicy):
    """Baselines: single threshold at ``t`` (Fig. 17): cap LP only or all."""

    t: float = 0.89
    buffer: float = 0.05
    cap_hp: bool = False  # False: 1-Thresh-Low-Pri; True: 1-Thresh-All
    freq: float = FREQ_LP_T2
    brake_freq: float = FREQ_BRAKE

    capped: bool = False
    braked: bool = False
    n_brakes: int = 0

    @property
    def name(self) -> str:
        return "1-thresh-all" if self.cap_hp else "1-thresh-low-pri"

    def observe(self, tel: Telemetry) -> List[CapCommand]:
        p = tel.power_frac
        cmds: List[CapCommand] = []
        if p > 1.0:
            if not self.braked:
                self.braked = True
                self.n_brakes += 1
                cmds.append(CapCommand(self.brake_freq, self.brake_freq, brake=True,
                                       reason="powerbrake"))
            self.capped = True
            return cmds
        if self.braked:
            self.braked = False
            cmds.append(CapCommand(self.freq, self.freq if self.cap_hp else FREQ_UNCAPPED,
                                   reason="brake-release"))
        if p > self.t and not self.capped:
            self.capped = True
            cmds.append(CapCommand(self.freq, self.freq if self.cap_hp else None,
                                   reason="threshold cap"))
        elif self.capped and p < self.t - self.buffer:
            self.capped = False
            cmds.append(CapCommand(FREQ_UNCAPPED, FREQ_UNCAPPED, reason="release"))
        return cmds


@dataclass
class NoCap(TelemetryPolicy):
    """No-cap baseline (with the hardware powerbrake as the only backstop)."""

    brake_freq: float = FREQ_BRAKE
    braked: bool = False
    n_brakes: int = 0
    name: str = "no-cap"

    def observe(self, tel: Telemetry) -> List[CapCommand]:
        p = tel.power_frac
        if p > 1.0:
            if not self.braked:
                self.braked = True
                self.n_brakes += 1
                return [CapCommand(self.brake_freq, self.brake_freq, brake=True,
                                   reason="powerbrake")]
            return []
        if self.braked:
            self.braked = False
            return [CapCommand(FREQ_UNCAPPED, FREQ_UNCAPPED, reason="brake-release")]
        return []
