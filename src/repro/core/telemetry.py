"""Structured controller telemetry (the experiments-API policy protocol).

The paper's rack manager samples one number — row power — every 2 s and feeds
it to Algorithm 1. The redesigned protocol hands policies a full ``Telemetry``
sample instead: the row-power fraction Algorithm 1 consumed, plus the
per-priority power split, the phase split (prompt vs token power), the
currently-commanded cap state, the sample timestamp, and — in cluster runs —
the enclosing rack/cluster power fractions. Policies that only need the bare
fraction read ``tel.power_frac`` and behave exactly as before; richer policies
(predictive, phase-aware, cluster-aware) read the rest.

Legacy call sites keep working: ``step(p)`` on every policy wraps the sample
via ``Telemetry.from_power_frac``.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

from repro.core.power_model import FREQ_UNCAPPED


@dataclass(frozen=True)
class Telemetry:
    """One controller sample. All power fields are fractions of the *row*
    budget except ``rack_power_frac``/``cluster_power_frac`` (fractions of the
    rack/cluster budgets, one tick stale in cluster runs — aggregation delay).
    ``None`` means "not observable on this deployment" (e.g. the legacy
    single-float path)."""

    t: float = 0.0
    power_frac: float = 0.0  # row power / row budget: Algorithm 1's `p`
    hp_power_frac: Optional[float] = None  # high-priority servers' share
    lp_power_frac: Optional[float] = None  # low-priority servers' share
    prefill_power_frac: Optional[float] = None  # servers in prompt phase
    lp_freq: float = FREQ_UNCAPPED  # currently-commanded cap state
    hp_freq: float = FREQ_UNCAPPED
    braked: bool = False
    row_index: int = 0
    rack_power_frac: Optional[float] = None
    cluster_power_frac: Optional[float] = None
    # budget fractions of every enclosing hierarchy level, nearest first
    # (rack), root last (cluster/site) — the full vector behind the two
    # convenience fields above; None outside hierarchy-driven runs. On the
    # classic two-level tree this is exactly (rack_power_frac,
    # cluster_power_frac); deeper site trees (row -> rack -> pdu-set ->
    # site) expose the intermediate levels here.
    group_power_fracs: Optional[Tuple[float, ...]] = None

    @classmethod
    def from_power_frac(cls, p: float, t: float = 0.0) -> "Telemetry":
        """Wrap the legacy bare row-power fraction."""
        return cls(t=t, power_frac=p)


class TelemetryPolicy:
    """Policy protocol: consume a ``Telemetry`` sample, emit cap commands.

    Subclasses implement ``observe``. ``step`` is the legacy protocol (bare
    row-power fraction) kept as a shim so pre-redesign call sites and traces
    replay identically.
    """

    def observe(self, tel: Telemetry) -> List:
        raise NotImplementedError

    def step(self, p: float) -> List:
        return self.observe(Telemetry.from_power_frac(p))


def dispatch(policy, tel: Telemetry) -> List:
    """Feed a sample to either protocol: ``observe(Telemetry)`` when the
    policy implements it, else the legacy ``step(p)``."""
    observe = getattr(policy, "observe", None)
    if observe is not None:
        return observe(tel)
    return policy.step(tel.power_frac)
