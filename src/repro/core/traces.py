"""Synthetic production-trace replication (paper §6.1, Fig. 16).

The paper replays a six-week power trace from a production inference cluster
and generates request arrivals whose simulated power matches it (MAPE < 3%).
We have no production trace, so we construct the target the way the paper
describes production behaving (Table 2): a diurnal interactive pattern with
weekly structure, peaking at ~79-80% of provisioned power, short-term (2 s)
variation <= 9%. Request arrivals are then derived from the same occupancy
curve, and the MAPE between the simulated row power and the analytic target
validates that the workload/power models close the loop.

Workload mix = Table 4 (BLOOM-176B): Summarize (LP, 25%), Search (HP, 25%),
Chat (50:50, 50%).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Tuple

import numpy as np

from repro.configs import get_config
from repro.core.power_model import A100, ServerPower
from repro.core.simulator import Request, WorkloadClass
from repro.core.workload import request_timing

DAY = 86_400.0
WEEK = 7 * DAY


@dataclass(frozen=True)
class WorkloadSpec:
    name: str
    prompt_range: Tuple[int, int]
    out_range: Tuple[int, int]
    share: float  # fraction of cluster traffic / servers
    priority_mix: float  # fraction high-priority


# Table 4
TABLE4 = (
    WorkloadSpec("summarize", (2048, 8192), (256, 512), 0.25, 0.0),
    WorkloadSpec("search", (512, 2048), (1024, 2048), 0.25, 1.0),
    WorkloadSpec("chat", (2048, 4096), (128, 2048), 0.50, 0.5),
)


def build_workload_classes(model_name: str = "bloom-176b",
                           server: ServerPower = None) -> Tuple[List[WorkloadClass], List[float]]:
    server = server or ServerPower(A100)
    cfg = get_config(model_name)
    classes, shares = [], []
    for spec in TABLE4:
        p_mid = int(np.sqrt(spec.prompt_range[0] * spec.prompt_range[1]))
        timing = request_timing(cfg, p_mid, 1, server)
        classes.append(WorkloadClass(spec.name, timing, spec.priority_mix))
        shares.append(spec.share)
    return classes, shares


def occupancy_curve(t: np.ndarray, *, peak: float = 0.62, trough: float = 0.30,
                    noise: float = 0.02, seed: int = 1) -> np.ndarray:
    """Diurnal + weekly interactive-load curve in [0,1] (busy-server fraction)."""
    rng = np.random.default_rng(seed)
    mid = 0.5 * (peak + trough)
    amp = 0.5 * (peak - trough)
    diurnal = mid + amp * np.sin(2 * np.pi * (t / DAY - 0.375))
    weekly = 1.0 - 0.06 * (np.sin(2 * np.pi * t / WEEK - 1.1) > 0.62)  # weekend dip
    slow_noise = np.interp(t, t[:: max(1, len(t) // 200)],
                           rng.normal(0, noise, size=len(t[:: max(1, len(t) // 200)])))
    return np.clip(diurnal * weekly + slow_noise, 0.05, 0.98)


def target_power_curve(occ: np.ndarray, workloads: List[WorkloadClass],
                       shares: List[float], server: ServerPower,
                       n_servers: int, n_provisioned: int) -> np.ndarray:
    """Analytic expected row power (fraction of provisioned) at occupancy."""
    provisioned = n_provisioned * server.provisioned_w
    p_busy = 0.0
    for w, sh in zip(workloads, shares):
        t_total = w.timing.t_prefill + 0.5 * 1000 * w.timing.t_token  # rough mean
        f_prefill = w.timing.t_prefill / t_total
        p_w = (f_prefill * w.timing.prefill_point.power_at(server, 1.0)
               + (1 - f_prefill) * w.timing.token_point.power_at(server, 1.0))
        p_busy += sh * p_w
    p_idle = server.idle_power
    row = n_servers * (occ * p_busy + (1 - occ) * p_idle)
    return row / provisioned


def generate_requests(duration_s: float, n_servers: int,
                      workloads: List[WorkloadClass], shares: List[float],
                      *, occupancy: np.ndarray = None, t_grid: np.ndarray = None,
                      seed: int = 7, occ_kwargs: dict = None) -> List[Request]:
    """Request priorities follow each WorkloadClass's priority_mix (so mix
    sweeps stay consistent with the server-pool split)."""
    """Poisson arrivals per workload class with rate matched to the occupancy
    curve: lambda_w(t) = occ(t) * n_servers_w / E[service_w]."""
    rng = np.random.default_rng(seed)
    if t_grid is None:
        t_grid = np.arange(0.0, duration_s, 60.0)
    if occupancy is None:
        occupancy = occupancy_curve(t_grid, **(occ_kwargs or {}))
    reqs: List[Request] = []
    rid = 0
    for wi, (wl, share) in enumerate(zip(workloads, shares)):
        spec = TABLE4[wi]
        n_w = max(1, int(round(share * n_servers)))
        # mean service time at the midpoint request
        mean_out = 0.5 * (spec.out_range[0] + spec.out_range[1])
        mean_service = wl.timing.t_prefill + mean_out * wl.timing.t_token
        t = 0.0
        while t < duration_s:
            occ = float(np.interp(t, t_grid, occupancy))
            lam = occ * n_w / mean_service  # arrivals/s for this class
            lam = max(lam, 1e-6)
            t += float(rng.exponential(1.0 / lam))
            if t >= duration_s:
                break
            prompt = int(rng.integers(spec.prompt_range[0], spec.prompt_range[1] + 1))
            out = int(rng.integers(spec.out_range[0], spec.out_range[1] + 1))
            prio = "high" if rng.random() < wl.priority_mix else "low"
            reqs.append(Request(t, wi, prompt, out, prio, rid))
            rid += 1
    reqs.sort(key=lambda r: r.t_arrival)
    return [Request(r.t_arrival, r.wl, r.prompt, r.out_tokens, r.priority, i)
            for i, r in enumerate(reqs)]


def mape(a: np.ndarray, b: np.ndarray) -> float:
    """Mean absolute percentage error between two power series."""
    m = np.abs(b) > 1e-9
    return float(np.mean(np.abs(a[m] - b[m]) / np.abs(b[m])))
