"""Synthetic production-trace replication (paper §6.1, Fig. 16).

The paper replays a six-week power trace from a production inference cluster
and generates request arrivals whose simulated power matches it (MAPE < 3%).
We have no production trace, so we construct the target the way the paper
describes production behaving (Table 2): a diurnal interactive pattern with
weekly structure, peaking at ~79-80% of provisioned power, short-term (2 s)
variation <= 9%. Request arrivals are then derived from the same occupancy
curve, and the MAPE between the simulated row power and the analytic target
validates that the workload/power models close the loop.

Workload mix = Table 4 (BLOOM-176B): Summarize (LP, 25%), Search (HP, 25%),
Chat (50:50, 50%).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, Dict, List, Tuple

import numpy as np

from repro.configs import get_config
from repro.core.power_model import A100, ServerPower
from repro.core.simulator import Request, WorkloadClass
from repro.core.workload import request_timing

DAY = 86_400.0
WEEK = 7 * DAY


@dataclass(frozen=True)
class WorkloadSpec:
    name: str
    prompt_range: Tuple[int, int]
    out_range: Tuple[int, int]
    share: float  # fraction of cluster traffic / servers
    priority_mix: float  # fraction high-priority


# Table 4
TABLE4 = (
    WorkloadSpec("summarize", (2048, 8192), (256, 512), 0.25, 0.0),
    WorkloadSpec("search", (512, 2048), (1024, 2048), 0.25, 1.0),
    WorkloadSpec("chat", (2048, 4096), (128, 2048), 0.50, 0.5),
)


def build_workload_classes(model_name: str = "bloom-176b",
                           server: ServerPower = None) -> Tuple[List[WorkloadClass], List[float]]:
    server = server or ServerPower(A100)
    cfg = get_config(model_name)
    classes, shares = [], []
    for spec in TABLE4:
        p_mid = int(np.sqrt(spec.prompt_range[0] * spec.prompt_range[1]))
        timing = request_timing(cfg, p_mid, 1, server)
        classes.append(WorkloadClass(spec.name, timing, spec.priority_mix))
        shares.append(spec.share)
    return classes, shares


def occupancy_curve(t: np.ndarray, *, peak: float = 0.62, trough: float = 0.30,
                    noise: float = 0.02, seed: int = 1) -> np.ndarray:
    """Diurnal + weekly interactive-load curve in [0,1] (busy-server fraction)."""
    rng = np.random.default_rng(seed)
    mid = 0.5 * (peak + trough)
    amp = 0.5 * (peak - trough)
    diurnal = mid + amp * np.sin(2 * np.pi * (t / DAY - 0.375))
    weekly = 1.0 - 0.06 * (np.sin(2 * np.pi * t / WEEK - 1.1) > 0.62)  # weekend dip
    slow_noise = np.interp(t, t[:: max(1, len(t) // 200)],
                           rng.normal(0, noise, size=len(t[:: max(1, len(t) // 200)])))
    return np.clip(diurnal * weekly + slow_noise, 0.05, 0.98)


def target_power_curve(occ: np.ndarray, workloads: List[WorkloadClass],
                       shares: List[float], server: ServerPower,
                       n_servers: int, n_provisioned: int) -> np.ndarray:
    """Analytic expected row power (fraction of provisioned) at occupancy."""
    provisioned = n_provisioned * server.provisioned_w
    p_busy = 0.0
    for w, sh in zip(workloads, shares):
        t_total = w.timing.t_prefill + 0.5 * 1000 * w.timing.t_token  # rough mean
        f_prefill = w.timing.t_prefill / t_total
        p_w = (f_prefill * w.timing.prefill_point.power_at(server, 1.0)
               + (1 - f_prefill) * w.timing.token_point.power_at(server, 1.0))
        p_busy += sh * p_w
    p_idle = server.idle_power
    row = n_servers * (occ * p_busy + (1 - occ) * p_idle)
    return row / provisioned


def generate_requests(duration_s: float, n_servers: int,
                      workloads: List[WorkloadClass], shares: List[float],
                      *, occupancy: np.ndarray = None, t_grid: np.ndarray = None,
                      seed: int = 7, occ_kwargs: dict = None) -> List[Request]:
    """Request priorities follow each WorkloadClass's priority_mix (so mix
    sweeps stay consistent with the server-pool split)."""
    """Poisson arrivals per workload class with rate matched to the occupancy
    curve: lambda_w(t) = occ(t) * n_servers_w / E[service_w]."""
    rng = np.random.default_rng(seed)
    if t_grid is None:
        t_grid = np.arange(0.0, duration_s, 60.0)
    if occupancy is None:
        occupancy = occupancy_curve(t_grid, **(occ_kwargs or {}))
    reqs: List[Request] = []
    rid = 0
    for wi, (wl, share) in enumerate(zip(workloads, shares)):
        spec = TABLE4[wi]
        n_w = max(1, int(round(share * n_servers)))
        # mean service time at the midpoint request
        mean_out = 0.5 * (spec.out_range[0] + spec.out_range[1])
        mean_service = wl.timing.t_prefill + mean_out * wl.timing.t_token
        t = 0.0
        while t < duration_s:
            occ = float(np.interp(t, t_grid, occupancy))
            lam = occ * n_w / mean_service  # arrivals/s for this class
            lam = max(lam, 1e-6)
            t += float(rng.exponential(1.0 / lam))
            if t >= duration_s:
                break
            prompt = int(rng.integers(spec.prompt_range[0], spec.prompt_range[1] + 1))
            out = int(rng.integers(spec.out_range[0], spec.out_range[1] + 1))
            prio = "high" if rng.random() < wl.priority_mix else "low"
            reqs.append(Request(t, wi, prompt, out, prio, rid))
            rid += 1
    reqs.sort(key=lambda r: r.t_arrival)
    return [Request(r.t_arrival, r.wl, r.prompt, r.out_tokens, r.priority, i)
            for i, r in enumerate(reqs)]


def mape(a: np.ndarray, b: np.ndarray) -> float:
    """Mean absolute percentage error between two power series."""
    m = np.abs(b) > 1e-9
    return float(np.mean(np.abs(a[m] - b[m]) / np.abs(b[m])))


# ---------------------------------------------------------------------------
# occupancy-generator registry
# ---------------------------------------------------------------------------
# A generator maps (t_grid, seed, peak, row-context, params) to a busy-server
# occupancy curve in [0, 1]. ``TrafficSpec.generator`` names one of these;
# the experiment runner dispatches through this registry so scenario families
# (bursty, colocated, failover, ...) plug in without the runner knowing them.
# The families themselves live in ``repro.provisioning.ensembles`` and
# register here on import; only "diurnal" is built in.

OccupancyGenerator = Callable[..., np.ndarray]

_OCC_GENERATORS: Dict[str, OccupancyGenerator] = {}


def register_occupancy_generator(name: str, gen: OccupancyGenerator, *,
                                 overwrite: bool = False) -> OccupancyGenerator:
    if name in _OCC_GENERATORS and not overwrite:
        raise ValueError(f"occupancy generator {name!r} already registered")
    _OCC_GENERATORS[name] = gen
    return gen


def get_occupancy_generator(name: str) -> OccupancyGenerator:
    try:
        return _OCC_GENERATORS[name]
    except KeyError:
        known = ", ".join(sorted(_OCC_GENERATORS))
        raise KeyError(
            f"unknown occupancy generator {name!r}; registered: {known}. "
            "The scenario families register on `import repro.provisioning`."
        ) from None


def list_occupancy_generators() -> List[str]:
    return sorted(_OCC_GENERATORS)


def _diurnal_generator(t_grid: np.ndarray, *, seed: int = 1, peak: float = 0.62,
                       n_rows: int = 1, row: int = 0, **kw) -> np.ndarray:
    # The member/scenario seed is deliberately NOT forwarded: the diurnal
    # baseline models one fixed production curve (occupancy-noise seed 1,
    # exactly the legacy generate_requests default), so passing gen_params
    # does not discontinuously re-seed the occupancy realization. Override
    # explicitly with gen_params={"seed": ...} to vary the curve itself.
    return occupancy_curve(t_grid, peak=peak, **kw)


register_occupancy_generator("diurnal", _diurnal_generator)


# ---------------------------------------------------------------------------
# trace-replication validation (paper Fig. 16)
# ---------------------------------------------------------------------------

def rolling_mean(x: np.ndarray, window: int) -> np.ndarray:
    """Centered-ish rolling mean ('valid' mode) used for Fig-16 smoothing."""
    window = max(1, int(window))
    return np.convolve(x, np.ones(window) / window, mode="valid")


@dataclass(frozen=True)
class ReplicationReport:
    """Simulated-vs-analytic row power comparison (Fig. 16 / §6.1)."""

    mape: float
    sim_smooth: np.ndarray
    target_smooth: np.ndarray
    smooth_window_s: float


def replication_report(power_t: np.ndarray, power_frac: np.ndarray,
                       workloads: List[WorkloadClass], shares: List[float],
                       server: ServerPower, n_servers: int, n_provisioned: int,
                       *, occ_peak: float = 0.62, occ_kwargs: dict = None,
                       occupancy: np.ndarray = None,
                       smooth_window_s: float = 300.0,
                       duration_s: float = None) -> ReplicationReport:
    """Compare a simulated row-power series against the analytic production
    target at the paper's Fig-16 granularity (5-minute averages by default).

    ``power_t``/``power_frac`` are a ``SimResult`` power series (fractions of
    provisioned row power on the telemetry grid). The target is
    :func:`target_power_curve` over the diurnal baseline occupancy curve
    (the production pattern Fig. 16 replicates) — pass ``occupancy`` (on a
    60 s grid over ``duration_s``) to validate a trace generated by any
    other occupancy family. The returned MAPE is the §6.1 replication-error
    metric (paper: < 3% over six weeks).
    """
    power_t = np.asarray(power_t, float)
    power_frac = np.asarray(power_frac, float)
    if len(power_t) < 3:
        raise ValueError("replication_report needs a recorded power series "
                         "(run with record_power=True)")
    duration = float(duration_s if duration_s is not None else power_t[-1])
    t_grid = np.arange(0.0, duration, 60.0)
    occ = (np.asarray(occupancy, float) if occupancy is not None
           else occupancy_curve(t_grid, peak=occ_peak, **(occ_kwargs or {})))
    if len(occ) != len(t_grid):
        raise ValueError(f"occupancy has {len(occ)} samples; expected "
                         f"{len(t_grid)} (60 s grid over duration_s)")
    target = target_power_curve(np.interp(power_t, t_grid, occ), workloads,
                                shares, server, n_servers, n_provisioned)
    dt = float(power_t[1] - power_t[0])
    k = max(1, int(round(smooth_window_s / dt)))
    sim_s, tgt_s = rolling_mean(power_frac, k), rolling_mean(target, k)
    return ReplicationReport(mape=mape(sim_s, tgt_s), sim_smooth=sim_s,
                             target_smooth=tgt_s, smooth_window_s=smooth_window_s)
