"""Fleet-scale runtime machinery: crash-restart, stragglers, elasticity.

Scope note (DESIGN.md §5): this container is one process, so the mechanisms
are implemented against an injectable fault source and exercised by tests —
the same control logic a multi-host launcher would run per pod:

  * ``TrainSupervisor``: step loop with checkpoint/restart semantics; any
    exception (injected device loss, preemption) triggers restore-from-latest
    and replay (the data pipeline is step-addressable, so replay is exact).
  * ``StragglerMonitor``: per-step wall-time watermarking; a step exceeding
    ``threshold x`` the trailing median flags mitigation (in a real fleet:
    re-shard away from the slow host / swap in a hot spare; here: recorded
    and surfaced so the launcher can act).
  * ``ElasticMesh``: re-builds the mesh and re-shards the state when the
    device set changes between restarts (scale 512 -> 256 -> 512): the state
    dict is host-resident numpy at restore time, so resharding is a
    device_put with the new mesh's shardings.

POLCA interaction: a powerbrake event is fleet-visible; the supervisor treats
sustained brakes like stragglers (checkpoint + drain) — wired via the
``on_power_event`` hook. :class:`BrakeSentinel` closes the loop from real
telemetry: it scans the ``braked_series`` a sim/fleet run records (or
observes live samples) and turns N consecutive braked ticks into one
``"sustained-brake"`` event; delivering that to
:meth:`TrainSupervisor.power_event` checkpoints and drains the run at the
next step boundary (training on a braked row wastes power-capped cycles —
better to checkpoint and let the launcher reschedule).
"""

from __future__ import annotations

import statistics
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

from repro.checkpoint import checkpointer


@dataclass
class StragglerMonitor:
    threshold: float = 2.0  # x trailing median
    window: int = 16
    times: List[float] = field(default_factory=list)
    flagged_steps: List[int] = field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        hist = self.times[-self.window:]
        self.times.append(dt)
        if len(hist) >= 4 and dt > self.threshold * statistics.median(hist):
            self.flagged_steps.append(step)
            return True
        return False


@dataclass
class TrainSupervisor:
    """Crash-restart step loop. ``step_fn(state, batch) -> (state, metrics)``
    may raise (injected faults); we restore and replay."""

    step_fn: Callable
    pipeline: Any  # step-addressable: batch_at(step)
    ckpt_dir: str
    ckpt_interval: int = 50
    max_restarts: int = 10
    straggler: StragglerMonitor = field(default_factory=StragglerMonitor)
    on_power_event: Optional[Callable[[str], None]] = None

    n_restarts: int = 0
    history: List[Dict] = field(default_factory=list)
    power_events: List[str] = field(default_factory=list)
    _drain_requested: bool = field(default=False, repr=False)

    def power_event(self, event: str) -> None:
        """Deliver a fleet power-plane signal (typically a
        :class:`BrakeSentinel` ``"sustained-brake"``). Every event is
        recorded and forwarded to the ``on_power_event`` callback; a
        sustained brake additionally requests checkpoint + drain — the run
        loop saves and returns at the next step boundary, the same
        mitigation stragglers get."""
        self.power_events.append(event)
        if self.on_power_event is not None:
            self.on_power_event(event)
        if event == "sustained-brake":
            self._drain_requested = True

    def run(self, state, n_steps: int, start_step: int = 0,
            place_batch: Callable = None):
        step = start_step
        while step < n_steps:
            if self._drain_requested:
                # sustained powerbrake: checkpoint and hand control back to
                # the launcher (drain), exactly like straggler mitigation
                self._drain_requested = False
                checkpointer.save(self.ckpt_dir, step, state)
                return state, step
            try:
                batch = self.pipeline.batch_at(step)
                if place_batch is not None:
                    batch = place_batch(batch)
                t0 = time.perf_counter()
                state, metrics = self.step_fn(state, batch)
                dt = time.perf_counter() - t0
                slow = self.straggler.observe(step, dt)
                self.history.append({"step": step, "dt": dt, "straggler": slow,
                                     **{k: float(v) for k, v in metrics.items()}})
                step += 1
                if step % self.ckpt_interval == 0:
                    checkpointer.save(self.ckpt_dir, step, state)
            except Exception:
                self.n_restarts += 1
                if self.n_restarts > self.max_restarts:
                    raise
                restored_step, state = checkpointer.restore_latest(self.ckpt_dir, state)
                step = restored_step if restored_step is not None else start_step
        checkpointer.save(self.ckpt_dir, step, state)
        return state, step


class FaultInjector:
    """Deterministic fault source for tests: raises at the given steps."""

    def __init__(self, fail_at: List[int]):
        self.fail_at = set(fail_at)
        self.seen: set = set()

    def maybe_fail(self, step: int):
        if step in self.fail_at and step not in self.seen:
            self.seen.add(step)
            raise RuntimeError(f"injected fault at step {step}")

    def reset(self) -> None:
        """Forget which steps already fired, so one injector can drive
        repeated supervisor runs (each run re-injects the same timeline)."""
        self.seen.clear()


@dataclass
class BrakeSentinel:
    """Turns row brake telemetry into supervisor power events: N
    consecutive braked telemetry samples constitute one sustained brake
    (one 2 s blip is the brake doing its job; ``sustain_ticks`` of them
    means the row is pinned at the brake floor and training there is
    wasted). Feed live samples through :meth:`observe`, or scan a finished
    run's recorded series (``SimResult.braked_series``, also produced by
    ``fleet.as_sim_result``) with :meth:`scan`."""

    sustain_ticks: int = 3
    events: List[float] = field(default_factory=list)
    _run_len: int = field(default=0, repr=False)

    def observe(self, t: float, braked: bool) -> Optional[str]:
        """One telemetry sample. Returns ``"sustained-brake"`` on the
        sample that completes a run of ``sustain_ticks`` braked ticks
        (once per run — a longer brake does not re-fire)."""
        self._run_len = self._run_len + 1 if braked else 0
        if self._run_len == self.sustain_ticks:
            self.events.append(float(t))
            return "sustained-brake"
        return None

    def scan(self, result, supervisor=None) -> List[float]:
        """Scan a finished run's ``braked_series`` on its ``power_t`` grid.
        Returns the sustained-brake times; with ``supervisor`` given, each
        event is also delivered to ``supervisor.power_event`` (the
        checkpoint+drain wiring)."""
        fired: List[float] = []
        if result.braked_series is None:
            return fired
        for t, b in zip(result.power_t, result.braked_series):
            ev = self.observe(float(t), bool(b))
            if ev is not None:
                fired.append(float(t))
                if supervisor is not None:
                    supervisor.power_event(ev)
        return fired


def elastic_reshard(state_template_fn: Callable[[Any], Any], host_state: Any,
                    new_mesh) -> Any:
    """Re-shard a host-resident (numpy) state onto a new mesh.

    ``state_template_fn(mesh) -> abstract state`` (shapes + shardings for that
    mesh); values come from ``host_state``. This is the restart path when the
    healthy-device set changed (elastic scale up/down).
    """
    import jax
    import numpy as np

    template = state_template_fn(new_mesh)
    flat_t, treedef = jax.tree_util.tree_flatten(template)
    flat_v = treedef.flatten_up_to(host_state)
    out = [jax.device_put(np.asarray(v, dtype=t.dtype), t.sharding)
           for t, v in zip(flat_t, flat_v)]
    return jax.tree_util.tree_unflatten(treedef, out)
