"""POLCA tick inner loop: shared vectorized step math + a Pallas kernel.

The batched ensemble engine (``provisioning.batched``, DESIGN.md §15-16)
advances N members x T ticks of the POLCA state machine. Its inner loop is
three fused pieces: the closed-form power fold over rows, the
:class:`~repro.core.policy.PolcaPolicy` latch/escalation update, and the
NaN-sentinel actuation-delay ring. This module is the single home of that
math, with three consumers:

* ``provisioning.batched._jax_runner`` — the ``lax.scan``/``vmap`` engine
  calls :func:`polca_latch_step` / :func:`row_power_w` per tick with traced
  scalars;
* :func:`polca_tick_loop` — the same step inside one ``pl.pallas_call``:
  grid over member blocks, ``fori_loop`` over ticks, frequency/ring/latch
  state carried in-kernel, per-tick loads/stores against the block refs.
  Interpret mode on CPU (float64, the oracle-contract dtype); a TPU
  deployment would run float32 blocks with lanes on the member axis and
  accept the looser tolerance documented in DESIGN.md §16;
* :func:`~repro.kernels.ref.polca_tick_reference` — a plain scan+vmap
  reference harness for the kernel shell (padding, ring indexing, stores).

Semantics are *not* re-derived here twice: the genuine oracle is the numpy
tick backend driving the real policy objects
(``tests/test_batched_parity.py``), and every consumer above is
differentially gated against it — brake-tick sets bit-identical, power
series <= 1e-6 relative.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

DEFAULT_BLOCK_MEMBERS = 8


class TickConsts(NamedTuple):
    """Per-scenario scalar constants of the tick program (policy thresholds
    + the closed-form power plane). Plain floats make it hashable (a static
    jit key for the kernel wrapper); the scan engine passes the same field
    names as traced leaves."""

    t1: float
    t2: float
    t1_buf: float
    t2_buf: float
    lp_t1: float
    lp_t2: float
    hp_t2: float
    brake_freq: float
    p0_srv_w: float
    k_lp_w: float
    k_hp_w: float
    lp_share: float
    gamma: float
    n_servers: float
    power_scale: float


class PolcaLatches(NamedTuple):
    """The boolean cap/brake state machine of one policy instance,
    vectorized over arbitrary leading shape (rows, or members x rows)."""

    t1c: jnp.ndarray  # T1 cap active
    t2c: jnp.ndarray  # T2 cap active
    hpc: jnp.ndarray  # HP cap active (escalated)
    brk: jnp.ndarray  # braking right now
    t2s: jnp.ndarray  # escalation tick counter (int32)


def row_power_w(c, occ, f_lp, f_hp):
    """Per-row watts at occupancy + frequency state — the identical
    expression ``provisioning.batched._row_power_w`` evaluates (kept in
    lockstep by the differential parity gates)."""
    busy = c.k_lp_w * f_lp ** c.gamma + c.k_hp_w * f_hp ** c.gamma
    return c.power_scale * c.n_servers * (c.p0_srv_w + occ * busy)


def lp_power_w(c, occ, f_lp):
    return (c.power_scale * c.n_servers
            * (c.lp_share * c.p0_srv_w + occ * c.k_lp_w * f_lp ** c.gamma))


def polca_latch_step(latches: PolcaLatches, p_obs, p_raw, lp_frac, c, *,
                     esc: int, predictive: bool):
    """One vectorized tick of ``PolcaPolicy.observe`` over any batch shape.

    Mirrors ``core.policy`` line for line: the overload path sets every cap
    flag and skips releases; cap/escalation branches run only out of
    overload; releases read the *post-cap* flags, and the T1 release
    additionally requires T2 to have just released or been clear.
    ``predictive`` adds the informed-escalation shortcut of
    ``PredictivePolcaPolicy`` (p_obs is then the extrapolated power).

    Returns ``(latches', fire, lp_cmd, hp_cmd)`` — ``fire`` marks brake
    firings; the command planes are NaN where no command is issued, in the
    policy's cmd-list order (later overwrites earlier, the DES
    same-due-time rule).
    """
    t1c, t2c, hpc, brk, t2s = latches
    over = p_obs > 1.0
    fire = over & ~brk
    rel_brake = ~over & brk
    if predictive:
        informed = (t2c & ~hpc & (p_raw > c.t2)
                    & (lp_frac < p_raw - c.t2))
        t2s = jnp.where(informed, esc, t2s)
    hi2 = p_obs > c.t2
    cap_t2 = ~over & hi2 & ~t2c
    esc_tick = ~over & hi2 & t2c & ~hpc
    t2s = jnp.where(cap_t2, 0, jnp.where(esc_tick, t2s + 1, t2s))
    cap_hp = esc_tick & (t2s >= esc)
    cap_t1 = ~over & ~hi2 & (p_obs > c.t1) & ~t1c
    t2c_mid = t2c | over | cap_t2
    t1c_mid = t1c | over | cap_t2 | cap_t1
    hpc_mid = hpc | over | cap_hp
    rel_t2 = ~over & t2c_mid & (p_obs < c.t2 - c.t2_buf)
    t2c = t2c_mid & ~rel_t2
    hpc = hpc_mid & ~rel_t2
    rel_t1 = (~over & t1c_mid & ~t2c
              & (p_obs < c.t1 - c.t1_buf))
    t1c = t1c_mid & ~rel_t1
    nanv = jnp.full(p_obs.shape, jnp.nan, dtype=p_obs.dtype)
    lp_cmd = nanv
    hp_cmd = nanv
    lp_cmd = jnp.where(rel_brake, c.lp_t2, lp_cmd)
    hp_cmd = jnp.where(rel_brake, c.hp_t2, hp_cmd)
    lp_cmd = jnp.where(cap_t2, c.lp_t2, lp_cmd)
    hp_cmd = jnp.where(cap_hp, c.hp_t2, hp_cmd)
    lp_cmd = jnp.where(cap_t1, c.lp_t1, lp_cmd)
    lp_cmd = jnp.where(rel_t2, c.lp_t1, lp_cmd)
    hp_cmd = jnp.where(rel_t2, 1.0, hp_cmd)
    lp_cmd = jnp.where(rel_t1, 1.0, lp_cmd)
    return (PolcaLatches(t1c=t1c, t2c=t2c, hpc=hpc, brk=over, t2s=t2s),
            fire, lp_cmd, hp_cmd)


def apply_ring_tick(ring, f_lp, f_hp, k, *, ring_depth: int):
    """Pop the actuation ring at tick k: apply any due command per frequency
    field, clear the slot. ``ring`` is ``[D, 2, ...]`` (NaN = no command).
    Returns ``(ring', f_lp', f_hp')``."""
    slot = k % ring_depth
    pend = lax.dynamic_index_in_dim(ring, slot, axis=0, keepdims=False)
    has = ~jnp.isnan(pend)
    f_lp = jnp.where(has[0], pend[0], f_lp)
    f_hp = jnp.where(has[1], pend[1], f_hp)
    ring = lax.dynamic_update_index_in_dim(
        ring, jnp.full(ring.shape[1:], jnp.nan, ring.dtype), slot, axis=0)
    return ring, f_lp, f_hp


def push_ring_commands(ring, fire, lp_cmd, hp_cmd, brake_freq, k, *,
                       oob_ticks: int, brake_ticks: int, ring_depth: int):
    """Queue this tick's commands: OOB cap/release commands land
    ``oob_ticks`` ahead, brake commands ``brake_ticks`` ahead and overwrite
    both frequency fields (issued last, the DES same-due-time rule)."""
    D = ring_depth
    s_oob = (k + oob_ticks) % D
    s_brk = (k + brake_ticks) % D
    oob_slot = lax.dynamic_index_in_dim(ring, s_oob, axis=0, keepdims=False)
    oob_slot = jnp.stack([
        jnp.where(jnp.isnan(lp_cmd), oob_slot[0], lp_cmd),
        jnp.where(jnp.isnan(hp_cmd), oob_slot[1], hp_cmd)], axis=0)
    ring = lax.dynamic_update_index_in_dim(ring, oob_slot, s_oob, axis=0)
    brk_slot = lax.dynamic_index_in_dim(ring, s_brk, axis=0, keepdims=False)
    brk_val = jnp.where(fire[None], jnp.full_like(brk_slot, brake_freq),
                        brk_slot)
    ring = lax.dynamic_update_index_in_dim(ring, brk_val, s_brk, axis=0)
    return ring


def _tick_init(C: int, R: int, D: int, dtype):
    f_lp = jnp.ones((C, R), dtype)
    f_hp = jnp.ones((C, R), dtype)
    ring = jnp.full((D, 2, C, R), jnp.nan, dtype)
    lat = PolcaLatches(
        t1c=jnp.zeros((C, R), bool), t2c=jnp.zeros((C, R), bool),
        hpc=jnp.zeros((C, R), bool), brk=jnp.zeros((C, R), bool),
        t2s=jnp.zeros((C, R), jnp.int32))
    nbr = jnp.zeros((C, R), jnp.int32)
    return f_lp, f_hp, ring, lat, nbr


def _tick_body(k, carry, occ_k, bscale_k, row_budget, c: TickConsts, *,
               oob_ticks, brake_ticks, ring_depth, esc):
    """One tick on a ``[C, R]`` member block — shared verbatim between the
    Pallas kernel body and the scan reference, so the kernel test isolates
    the pallas shell (blocking, loads/stores) rather than re-proving the
    state machine."""
    f_lp, f_hp, ring, lat, nbr = carry
    ring, f_lp, f_hp = apply_ring_tick(ring, f_lp, f_hp, k,
                                       ring_depth=ring_depth)
    rw = row_power_w(c, occ_k, f_lp, f_hp)
    tick_budget = row_budget * bscale_k  # [R] broadcast over members
    p_raw = rw / tick_budget
    lp_frac = lp_power_w(c, occ_k, f_lp) / tick_budget
    lat, fire, lp_cmd, hp_cmd = polca_latch_step(
        lat, p_raw, p_raw, lp_frac, c, esc=esc, predictive=False)
    ring = push_ring_commands(ring, fire, lp_cmd, hp_cmd, c.brake_freq, k,
                              oob_ticks=oob_ticks, brake_ticks=brake_ticks,
                              ring_depth=ring_depth)
    nbr = nbr + fire.astype(jnp.int32)
    return (f_lp, f_hp, ring, lat, nbr), rw, fire


def _tick_kernel(occ_ref, bscale_ref, rb_ref,
                 roww_ref, fire_ref, flp_ref, fhp_ref, nbr_ref, *,
                 T, R, C, oob_ticks, brake_ticks, ring_depth, esc,
                 c: TickConsts):
    """Pallas kernel body: one member block, full T-tick loop. State lives
    in the ``fori_loop`` carry (the compiler keeps it in VMEM/registers);
    per-tick planes stream out through the block refs."""
    dtype = occ_ref.dtype

    def body(k, carry):
        occ_k = pl.load(occ_ref, (slice(None), pl.dslice(k, 1),
                                  slice(None)))[:, 0, :]
        bscale_k = pl.load(bscale_ref, (pl.dslice(k, 1), slice(None)))[0]
        carry, rw, fire = _tick_body(
            k, carry, occ_k, bscale_k, rb_ref[...], c,
            oob_ticks=oob_ticks, brake_ticks=brake_ticks,
            ring_depth=ring_depth, esc=esc)
        f_lp, f_hp = carry[0], carry[1]
        idx = (slice(None), pl.dslice(k, 1), slice(None))
        pl.store(roww_ref, idx, rw[:, None, :])
        pl.store(fire_ref, idx, fire[:, None, :])
        pl.store(flp_ref, idx, f_lp[:, None, :])
        pl.store(fhp_ref, idx, f_hp[:, None, :])
        return carry

    final = lax.fori_loop(0, T, body, _tick_init(C, R, ring_depth, dtype))
    nbr_ref[...] = final[4]


def _auto_interpret(interpret):
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def polca_tick_loop(occ, bscale, row_budget, consts: TickConsts, *,
                    oob_ticks: int, brake_ticks: int, ring_depth: int,
                    esc: int, block_members: int = DEFAULT_BLOCK_MEMBERS,
                    interpret=None):
    """The non-predictive POLCA tick loop as one ``pallas_call``.

    ``occ`` is the *effective* per-tick occupancy ``[N, T, R]`` (60 s-grid
    interpolation x row-alive mask, precomputed by the lowering — the
    kernel owns the power fold + latch/ring update that dominates the scan
    body). ``bscale`` is the ``[T, R]`` fault budget scale, ``row_budget``
    the ``[R]`` static budgets. Members are padded to a multiple of
    ``block_members``; the grid walks member blocks and each program
    instance runs the full T-tick loop on its block.

    Returns ``dict(row_w=[N, T, R], fire=[N, T, R] bool,
    f_lp=[N, T, R], f_hp=[N, T, R], n_brakes=[N, R] int32)`` — the
    frequency planes let the SLO fluid proxy run as a cheap post-pass.
    """
    N, T, R = occ.shape
    C = max(1, min(int(block_members), N))
    n_pad = (-N) % C
    if n_pad:
        occ = jnp.concatenate([occ, occ[:n_pad]], axis=0)
    B = (N + n_pad) // C
    dtype = occ.dtype
    kernel = functools.partial(
        _tick_kernel, T=T, R=R, C=C, oob_ticks=int(oob_ticks),
        brake_ticks=int(brake_ticks), ring_depth=int(ring_depth),
        esc=int(esc), c=consts)
    plane = jax.ShapeDtypeStruct((B * C, T, R), dtype)
    out = pl.pallas_call(
        kernel,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((C, T, R), lambda b: (b, 0, 0)),
            pl.BlockSpec((T, R), lambda b: (0, 0)),
            pl.BlockSpec((R,), lambda b: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((C, T, R), lambda b: (b, 0, 0)),
            pl.BlockSpec((C, T, R), lambda b: (b, 0, 0)),
            pl.BlockSpec((C, T, R), lambda b: (b, 0, 0)),
            pl.BlockSpec((C, T, R), lambda b: (b, 0, 0)),
            pl.BlockSpec((C, R), lambda b: (b, 0)),
        ],
        out_shape=[
            plane,
            jax.ShapeDtypeStruct((B * C, T, R), jnp.bool_),
            plane,
            plane,
            jax.ShapeDtypeStruct((B * C, R), jnp.int32),
        ],
        interpret=_auto_interpret(interpret),
    )(occ, bscale, row_budget)
    row_w, fire, f_lp, f_hp, nbr = (a[:N] for a in out)
    return dict(row_w=row_w, fire=fire, f_lp=f_lp, f_hp=f_hp, n_brakes=nbr)
