"""Pallas TPU flash attention (prefill/train path).

TPU-native adaptation (DESIGN.md hardware notes): the grid walks
(batch x kv_head, q_block, kv_block); each step keeps a [G*Bq, hd] query tile
and a [Bk, hd] KV tile resident in VMEM, runs the MXU matmuls in fp32
accumulation, and maintains online-softmax running stats in VMEM scratch.
GQA is handled by folding the G=H/KV query heads that share a KV head into
the query tile rows, so the KV tile is loaded once per G query heads —
an HBM-traffic win dense GPU-style per-head kernels don't get.

Supports: causal masking, sliding windows, logit softcap (gemma2), and a
valid-length bound. Out-of-window KV blocks are skipped entirely (their
contribution is provably zero), which makes long-context SWA prefill linear.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale, causal, window, softcap, block_q, block_k,
                  kv_seq, q_offset, n_kv_blocks):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]  # [G, Bq, hd]
    G, Bq, hd = q.shape
    rows = G * Bq
    q2 = q.reshape(rows, hd)
    k = k_ref[0]  # [Bk, hd]
    v = v_ref[0]

    # absolute positions: query row r -> q_offset + qi*Bq + (r % Bq)
    r = jax.lax.broadcasted_iota(jnp.int32, (rows, 1), 0)
    q_pos = q_offset + qi * block_q + jax.lax.rem(r, Bq)
    t_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)

    def compute():
        s = jax.lax.dot_general(
            q2, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # [rows, Bk]
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        mask = jnp.ones(s.shape, jnp.bool_)
        mask &= t_pos < kv_seq
        if causal:
            mask &= t_pos <= q_pos
        if window:
            mask &= t_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=-1)[:, None]
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1)[:, None]
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new
        l_scr[...] = l_new

    # Skip provably-empty KV blocks (causal: block entirely in the future;
    # window: block entirely before the window of every query in this tile).
    needed = jnp.bool_(True)
    if causal:
        first_q = q_offset + qi * block_q
        needed &= ki * block_k <= first_q + block_q - 1
    if window:
        # the union of windows over queries in this tile starts at
        # first_q - window + 1 (the earliest query reaches furthest back)
        first_q = q_offset + qi * block_q
        needed &= (ki + 1) * block_k - 1 > first_q - window
    pl.when(needed)(compute)

    @pl.when(ki == n_kv_blocks - 1)
    def _finalize():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_scr[...] / l).reshape(G, Bq, hd).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal=True, window=0, softcap=0.0,
                    q_offset=0, block_q=DEFAULT_BLOCK_Q,
                    block_k=DEFAULT_BLOCK_K, interpret=False):
    """q: [B,Sq,H,hd]; k/v: [B,Skv,KV,hd] -> [B,Sq,H,hd].

    ``q_offset``: absolute position of q[:,0] (continuation chunks).
    """
    B, Sq, H, hd = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV
    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    assert Sq % block_q == 0 and Skv % block_k == 0, (Sq, Skv, block_q, block_k)
    n_q = Sq // block_q
    n_k = Skv // block_k

    # [B,Sq,H,hd] -> [B*KV, G, Sq, hd]: fold the shared-KV query heads together
    qr = q.reshape(B, Sq, KV, G, hd).transpose(0, 2, 3, 1, 4).reshape(B * KV, G, Sq, hd)
    kr = k.transpose(0, 2, 1, 3).reshape(B * KV, Skv, hd)
    vr = v.transpose(0, 2, 1, 3).reshape(B * KV, Skv, hd)

    kernel = functools.partial(
        _flash_kernel, scale=hd ** -0.5, causal=causal, window=window,
        softcap=softcap, block_q=block_q, block_k=block_k, kv_seq=Skv,
        q_offset=q_offset, n_kv_blocks=n_k)

    out = pl.pallas_call(
        kernel,
        grid=(B * KV, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, G, block_q, hd), lambda b, qi, ki: (b, 0, qi, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, qi, ki: (b, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, block_q, hd), lambda b, qi, ki: (b, 0, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * KV, G, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G * block_q, 1), jnp.float32),  # running max
            pltpu.VMEM((G * block_q, 1), jnp.float32),  # running denom
            pltpu.VMEM((G * block_q, hd), jnp.float32),  # fp32 accumulator
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(B, KV, G, Sq, hd).transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, hd)
