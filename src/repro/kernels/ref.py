"""Pure-jnp oracles for every Pallas kernel (and the SSD sequential oracle).

These are deliberately naive: full score matrices, exact softmax, sequential
recurrences. Kernel tests sweep shapes/dtypes and assert_allclose against
these references.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def mha_reference(q, k, v, *, causal=True, window=0, softcap=0.0, valid_len=None):
    """q: [B,Sq,H,D]; k/v: [B,Skv,KV,D]; GQA by head grouping.

    ``q_offset`` is implied: query i sits at absolute position
    Skv - Sq + i (decode-style alignment) when Sq != Skv, else i.
    Returns [B,Sq,H,D] in q.dtype.
    """
    B, Sq, H, D = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV
    qf = q.astype(jnp.float32).reshape(B, Sq, KV, G, D)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bqkgd,btkd->bkgqt", qf, kf) * (D ** -0.5)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    q_pos = jnp.arange(Sq) + (Skv - Sq)
    t_pos = jnp.arange(Skv)
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= t_pos[None, :] <= q_pos[:, None]
    if window:
        mask &= t_pos[None, :] > q_pos[:, None] - window
    if valid_len is not None:
        mask &= (t_pos < valid_len)[None, :]
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqt,btkd->bqkgd", p, vf)
    return o.reshape(B, Sq, H, D).astype(q.dtype)


def decode_attention_reference(q, k, v, valid_len, *, softcap=0.0):
    """Single-token decode. q: [B,H,D]; k/v: [B,T,KV,D]; valid_len scalar."""
    o = mha_reference(q[:, None], k, v, causal=False, softcap=softcap,
                      valid_len=valid_len)
    return o[:, 0]


def ssd_reference(x, dt, A, B, C, D_skip, init_state=None):
    """Sequential SSD recurrence (the oracle for the chunked form).

    x: [Bt,S,H,P]; dt: [Bt,S,H] (post-softplus); A: [H] (negative);
    B/C: [Bt,S,G,N]; D_skip: [H]. Returns (y [Bt,S,H,P], final_state).
    """
    Bt, S, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    rep = H // G
    Bh = jnp.repeat(B.astype(jnp.float32), rep, axis=2)  # [Bt,S,H,N]
    Ch = jnp.repeat(C.astype(jnp.float32), rep, axis=2)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)

    def step(state, inp):
        xt, dtt, Bt_, Ct_ = inp  # [Bt,H,P], [Bt,H], [Bt,H,N], [Bt,H,N]
        decay = jnp.exp(dtt * A[None, :])  # [Bt,H]
        state = state * decay[:, :, None, None] + jnp.einsum(
            "bhn,bh,bhp->bhnp", Bt_, dtt, xt)
        y = jnp.einsum("bhn,bhnp->bhp", Ct_, state)
        return state, y

    init = jnp.zeros((Bt, H, N, P), jnp.float32) if init_state is None else init_state
    final, ys = jax.lax.scan(
        step, init,
        (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtf, 1, 0),
         jnp.moveaxis(Bh, 1, 0), jnp.moveaxis(Ch, 1, 0)))
    y = jnp.moveaxis(ys, 0, 1) + D_skip.astype(jnp.float32)[None, None, :, None] * xf
    return y.astype(x.dtype), final


def rmsnorm_reference(x, w, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)).astype(x.dtype)


def polca_tick_reference(occ, bscale, row_budget, consts, *, oob_ticks,
                         brake_ticks, ring_depth, esc):
    """Plain ``lax.scan`` form of the POLCA tick loop — the shell oracle for
    :func:`repro.kernels.tick.polca_tick_loop`.

    Shares ``tick._tick_body`` with the kernel on purpose: this reference
    isolates the Pallas plumbing (member blocking, ring/scratch indexing,
    per-tick loads/stores, padding) rather than re-deriving the state
    machine. Semantic ground truth for the step itself is the numpy tick
    oracle driving the *real* policy objects (``tests/test_batched_parity``
    runs ``engine="pallas"`` through that differential harness).

    occ: [N,T,R] effective occupancy; bscale: [T,R]; row_budget: [R].
    """
    from repro.kernels import tick as _tick

    N, T, R = occ.shape
    init = _tick._tick_init(N, R, ring_depth, occ.dtype)

    def step(carry, x):
        k, occ_k, bs_k = x
        carry, rw, fire = _tick._tick_body(
            k, carry, occ_k, bs_k, row_budget, consts,
            oob_ticks=oob_ticks, brake_ticks=brake_ticks,
            ring_depth=ring_depth, esc=esc)
        return carry, (rw, fire, carry[0], carry[1])

    xs = (jnp.arange(T, dtype=jnp.int32), jnp.moveaxis(occ, 1, 0), bscale)
    final, (rw, fire, f_lp, f_hp) = jax.lax.scan(step, init, xs)
    return dict(row_w=jnp.moveaxis(rw, 0, 1), fire=jnp.moveaxis(fire, 0, 1),
                f_lp=jnp.moveaxis(f_lp, 0, 1),
                f_hp=jnp.moveaxis(f_hp, 0, 1), n_brakes=final[4])
