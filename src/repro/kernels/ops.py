"""Jit'd public wrappers for the Pallas kernels.

``interpret=None`` auto-selects: compiled on TPU, Pallas interpreter on CPU
(correctness validation path used by the test suite).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import decode_attention as _dec
from repro.kernels import flash_attention as _fa
from repro.kernels import tick as _tick


def _auto_interpret(interpret):
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


@partial(jax.jit, static_argnames=("causal", "window", "softcap", "q_offset",
                                   "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0, softcap=0.0, q_offset=0,
                    block_q=_fa.DEFAULT_BLOCK_Q, block_k=_fa.DEFAULT_BLOCK_K,
                    interpret=None):
    return _fa.flash_attention(
        q, k, v, causal=causal, window=window, softcap=softcap,
        q_offset=q_offset, block_q=block_q, block_k=block_k,
        interpret=_auto_interpret(interpret))


@partial(jax.jit, static_argnames=("softcap", "block_k", "interpret"))
def decode_attention(q, k, v, valid_len, *, softcap=0.0,
                     block_k=_dec.DEFAULT_BLOCK_K, interpret=None):
    return _dec.decode_attention(
        q, k, v, valid_len, softcap=softcap, block_k=block_k,
        interpret=_auto_interpret(interpret))


@partial(jax.jit, static_argnames=("consts", "oob_ticks", "brake_ticks",
                                   "ring_depth", "esc", "block_members",
                                   "interpret"))
def polca_tick(occ, bscale, row_budget, *, consts, oob_ticks, brake_ticks,
               ring_depth, esc, block_members=_tick.DEFAULT_BLOCK_MEMBERS,
               interpret=None):
    """Non-predictive POLCA tick loop (power fold + latch/ring update) as a
    Pallas kernel. ``consts`` is a hashable :class:`~repro.kernels.tick.
    TickConsts` — per-scenario scalars are compile-time here (the scan
    engine in ``provisioning.batched`` is the probe-sweep path; this kernel
    recompiles per scenario by design)."""
    return _tick.polca_tick_loop(
        occ, bscale, row_budget, consts, oob_ticks=oob_ticks,
        brake_ticks=brake_ticks, ring_depth=ring_depth, esc=esc,
        block_members=block_members, interpret=_auto_interpret(interpret))
