"""Jit'd public wrappers for the Pallas kernels.

``interpret=None`` auto-selects: compiled on TPU, Pallas interpreter on CPU
(correctness validation path used by the test suite).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import decode_attention as _dec
from repro.kernels import flash_attention as _fa


def _auto_interpret(interpret):
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


@partial(jax.jit, static_argnames=("causal", "window", "softcap", "q_offset",
                                   "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0, softcap=0.0, q_offset=0,
                    block_q=_fa.DEFAULT_BLOCK_Q, block_k=_fa.DEFAULT_BLOCK_K,
                    interpret=None):
    return _fa.flash_attention(
        q, k, v, causal=causal, window=window, softcap=softcap,
        q_offset=q_offset, block_q=block_q, block_k=block_k,
        interpret=_auto_interpret(interpret))


@partial(jax.jit, static_argnames=("softcap", "block_k", "interpret"))
def decode_attention(q, k, v, valid_len, *, softcap=0.0,
                     block_k=_dec.DEFAULT_BLOCK_K, interpret=None):
    return _dec.decode_attention(
        q, k, v, valid_len, softcap=softcap, block_k=block_k,
        interpret=_auto_interpret(interpret))
