"""Pallas TPU flash-decoding kernel: one query token vs a long KV cache.

Decode is memory-bound (the POLCA paper's token phase): the kernel's only job
is to stream the KV cache through VMEM exactly once at full HBM bandwidth
while keeping online-softmax stats in registers/VMEM. Grid: (B*KV, kv_blocks)
with the KV walk sequential; GQA query heads sharing a KV head ride in the
same tile (rows = G), so cache bytes are read once per KV head.

A ``valid_len`` scalar bounds attention to written cache slots; ``t_offset``
supports ring-buffer (sliding-window) caches where slot i holds absolute
position ``pos - ((pos - i) mod W)``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)

DEFAULT_BLOCK_K = 512


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                   scale, softcap, block_k, n_kv_blocks):
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]  # [G, hd]
    k = k_ref[0]  # [Bk, hd]
    v = v_ref[0]
    valid_len = len_ref[0]

    t_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)

    def compute():
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale  # [G, Bk]
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        s = jnp.where(t_pos < valid_len, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1)[:, None])
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=-1)[:, None]
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    pl.when(ki * block_k < valid_len)(compute)

    @pl.when(ki == n_kv_blocks - 1)
    def _finalize():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


def decode_attention(q, k, v, valid_len, *, softcap=0.0,
                     block_k=DEFAULT_BLOCK_K, interpret=False):
    """q: [B,H,hd]; k/v: [B,T,KV,hd]; valid_len: scalar int32 (slots < valid_len
    attend). Returns [B,H,hd]."""
    B, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    block_k = min(block_k, T)
    assert T % block_k == 0, (T, block_k)
    n_k = T // block_k

    qr = q.reshape(B, KV, G, hd).reshape(B * KV, G, hd)
    kr = k.transpose(0, 2, 1, 3).reshape(B * KV, T, hd)
    vr = v.transpose(0, 2, 1, 3).reshape(B * KV, T, hd)
    lens = jnp.broadcast_to(jnp.asarray(valid_len, jnp.int32), (B * KV,))

    kernel = functools.partial(_decode_kernel, scale=hd ** -0.5, softcap=softcap,
                               block_k=block_k, n_kv_blocks=n_k)
    out = pl.pallas_call(
        kernel,
        grid=(B * KV, n_k),
        in_specs=[
            pl.BlockSpec((1,), lambda b, ki: (b,)),
            pl.BlockSpec((1, G, hd), lambda b, ki: (b, 0, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, ki: (b, ki, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, ki: (b, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, hd), lambda b, ki: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * KV, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
        interpret=interpret,
    )(lens, qr, kr, vr)
    return out.reshape(B, KV, G, hd).reshape(B, H, hd)
