"""Experiment execution: ``run_experiment(scenario)`` and sweeps.

This is the single entrypoint the benchmarks and examples drive. It owns the
workflow the old ``core.oversubscription.evaluate`` hard-coded behind eight
positional arguments: build the Table-4 workload classes for the scenario's
model/device, generate the seeded arrival trace, calibrate the row power
budget to the paper's Table-2 operating point (unless the scenario pins it),
run an uncapped reference plus the policy run (row or multi-row cluster), and
gate the outcome against the SLOs.

``core.oversubscription`` keeps thin shims over these functions so pre-
redesign call signatures continue to work unchanged.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.policy import NoCap
from repro.core.simulator import Request, RowSimulator, SimConfig, SimResult, WorkloadClass
from repro.core.slo import LatencyStats, impact_vs_reference, meets_slo
from repro.core.traces import (
    build_workload_classes,
    generate_requests,
    get_occupancy_generator,
)
from repro.experiments.cluster import ClusterResult, ClusterSimulator
from repro.experiments.scenario import PolicySpec, Scenario

BASELINE_PEAK_UTIL = 0.79  # Table 2: inference rows peak at 79% of provisioned


@dataclass
class ExperimentResult:
    """Outcome of one scenario run (field-compatible with the old
    ``EvalOutcome`` for the row path; cluster runs add ``cluster``, routed
    fleet runs add ``fleet`` — for those, ``result`` is the cluster-shaped
    merge from :func:`repro.fleet.as_sim_result`)."""

    n_servers: int
    added_frac: float
    stats: LatencyStats
    result: SimResult  # policy run (row 0's result for cluster runs)
    ref_result: Optional[SimResult]
    meets: bool
    throughput_ratio_hp: Optional[float]
    throughput_ratio_lp: Optional[float]
    scenario: Optional[Scenario] = None
    budget_w: Optional[float] = None
    cluster: Optional[ClusterResult] = None
    fleet: Optional[object] = None  # repro.fleet.FleetResult


def build_workloads(scenario: Scenario) -> Tuple[List[WorkloadClass], List[float]]:
    """Table-4 workload classes for the scenario's model/device, with the
    scenario's priority-mix override applied (Fig. 15b sweeps)."""
    server = scenario.fleet.server()
    wls, shares = build_workload_classes(scenario.fleet.model, server)
    mix = scenario.traffic.priority_mix_override
    if mix is not None:
        wls = [WorkloadClass(w.name, w.timing, mix) for w in wls]
    return wls, shares


def _sim_config(scenario: Scenario, **overrides) -> SimConfig:
    tc = scenario.telemetry
    kw = dict(power_scale=scenario.power_scale, telemetry_s=tc.telemetry_s,
              oob_latency_s=tc.oob_latency_s, brake_latency_s=tc.brake_latency_s,
              record_power=tc.record_power)
    kw.update(overrides)
    return SimConfig(**kw)


def _generated_occupancy(scenario: Scenario, duration_s: float,
                         row: int = 0) -> Optional[Tuple[np.ndarray, np.ndarray]]:
    """(t_grid, occupancy) from the scenario's registered generator, or None
    for the built-in diurnal default (which ``generate_requests`` produces
    itself — kept on the original code path so legacy traces replay
    bit-identically)."""
    tr = scenario.traffic
    if tr.generator == "diurnal" and not tr.gen_params:
        return None
    gen = get_occupancy_generator(tr.generator)
    t_grid = np.arange(0.0, duration_s, 60.0)
    occ = gen(t_grid, seed=scenario.seed, peak=tr.occ_peak,
              n_rows=scenario.fleet.n_rows, row=row, **tr.gen_params)
    return t_grid, occ


def row_trace(scenario: Scenario, workloads, shares, n_servers: int, *,
              seed: int, row: int = 0) -> List[Request]:
    """The seeded arrival trace for one row of the scenario. The occupancy
    curve comes from the scenario's trace generator (seeded by
    ``scenario.seed`` so correlated multi-row structure is preserved); the
    arrival sampling uses ``seed`` (per-row decorrelation in clusters)."""
    grid = _generated_occupancy(scenario, scenario.duration_s, row=row)
    if grid is None:
        return generate_requests(scenario.duration_s, n_servers, workloads,
                                 shares, seed=seed,
                                 occ_kwargs={"peak": scenario.traffic.occ_peak})
    t_grid, occ = grid
    return generate_requests(scenario.duration_s, n_servers, workloads, shares,
                             occupancy=occ, t_grid=t_grid, seed=seed)


def row_budgets(scenario: Scenario, budget_w: Optional[float],
                server) -> List[float]:
    """Per-row budgets in watts (``budget_w=None`` resolves to the nominal
    ``n_provisioned x server rating`` — the single copy of that rule).
    ``FleetSpec.row_budget_fracs`` scales each row's share of the envelope
    (heterogeneous PDU headroom)."""
    fleet = scenario.fleet
    base = (budget_w if budget_w is not None
            else fleet.n_provisioned * server.provisioned_w)
    fracs = fleet.row_budget_fracs
    if fracs is None:
        return [float(base)] * fleet.n_rows
    if len(fracs) != fleet.n_rows:
        raise ValueError(
            f"row_budget_fracs has {len(fracs)} entries for "
            f"{fleet.n_rows} rows")
    return [float(base) * float(f) for f in fracs]


def row_sim(scenario: Scenario, workloads, shares, server,
            budget_w: Optional[float], policy, reqs: List[Request], *,
            row_index: int = 0) -> RowSimulator:
    """The policy-run RowSimulator for one row of the scenario — the single
    construction point shared by ``run_experiment`` and the Monte-Carlo
    engine (``repro.provisioning.montecarlo``), so batched runs stay
    bit-identical with sequential ones by construction."""
    fleet = scenario.fleet
    return RowSimulator(workloads, server, fleet.n_servers, fleet.n_provisioned,
                        policy, reqs, shares, _sim_config(scenario),
                        duration=scenario.duration_s, provisioned_w=budget_w,
                        row_index=row_index)


def calibrated_budget(workloads, shares, server, n_provisioned: int,
                      duration: float, *, seed: int = 7, occ_peak: float = 0.62,
                      power_scale: float = 1.0, occupancy: np.ndarray = None,
                      t_grid: np.ndarray = None) -> float:
    """Row power budget such that the n_provisioned baseline peaks at 79% of
    it (the paper's Table-2 operating point — budgets are PDU limits, not the
    sum of server ratings). Pass ``occupancy``/``t_grid`` to calibrate
    against a generated (non-diurnal) occupancy curve."""
    reqs = generate_requests(duration, n_provisioned, workloads, shares, seed=seed,
                             occupancy=occupancy, t_grid=t_grid,
                             occ_kwargs={"peak": occ_peak})
    base = RowSimulator(workloads, server, n_provisioned, 100 * n_provisioned,
                        NoCap(), reqs, shares,
                        SimConfig(power_scale=power_scale, record_power=False),
                        duration=duration).run()
    peak_w = base.peak_power_frac * 100 * n_provisioned * server.provisioned_w
    return peak_w / BASELINE_PEAK_UTIL


def resolve_budget(scenario: Scenario, workloads, shares, server) -> Optional[float]:
    """The row budget in watts, or None for the nominal RowSimulator default
    (n_provisioned x server rating)."""
    if isinstance(scenario.budget, (int, float)):
        return float(scenario.budget)
    if scenario.budget == "nominal":
        return None
    if scenario.budget == "calibrated":
        cal_dur = min(scenario.duration_s, 2 * 86400.0)
        grid = _generated_occupancy(scenario, cal_dur)
        t_grid, occ = grid if grid is not None else (None, None)
        return calibrated_budget(
            workloads, shares, server, scenario.fleet.n_provisioned, cal_dur,
            seed=scenario.seed, occ_peak=scenario.traffic.occ_peak,
            power_scale=1.0, occupancy=occ, t_grid=t_grid)
    raise ValueError(f"unknown budget spec {scenario.budget!r}")


def run_experiment(scenario: Scenario, *,
                   workloads: Optional[Tuple[List[WorkloadClass], List[float]]] = None,
                   policy_factory=None, server=None) -> ExperimentResult:
    """Run one scenario end to end.

    ``workloads``, ``policy_factory``, and ``server`` are escape hatches for
    legacy call sites that already built (non-declarative) workload classes,
    pass a bare policy callable, or carry a custom ``ServerPower``;
    everything else resolves from the scenario itself.
    """
    if scenario.duration_s <= 0:
        raise ValueError(f"scenario {scenario.name!r}: duration_s must be > 0, "
                         f"got {scenario.duration_s}")
    faults = getattr(scenario, "faults", None)
    if faults is not None and not faults.is_noop and scenario.routing is None:
        # the ChaosInjector rides the FleetSimulator tick lockstep; the
        # per-row/cluster paths have no dispatcher to fence rows from
        raise ValueError(
            f"scenario {scenario.name!r} carries a fault timeline but no "
            f"RoutingSpec; the chaos engine needs a routed fleet")
    server = server if server is not None else scenario.fleet.server()
    wls, shares = workloads if workloads is not None else build_workloads(scenario)
    budget_w = resolve_budget(scenario, wls, shares, server)
    mk = policy_factory if policy_factory is not None else scenario.policy.build
    if scenario.routing is not None:
        return _run_fleet(scenario, wls, shares, server, budget_w, mk)
    if scenario.fleet.n_rows > 1:
        return _run_cluster(scenario, wls, shares, server, budget_w, mk)
    return _run_row(scenario, wls, shares, server, budget_w, mk)


def _throughput(reqs, prios, res: SimResult, prio: str) -> float:
    tot = sum(r.out_tokens for r in reqs if prios[r.rid] == prio)
    got = sum(r.out_tokens for r in reqs
              if prios[r.rid] == prio and r.rid in res.latencies)
    return got / max(1, tot)


def _reference_stats(reqs, res: SimResult, ref: Optional[SimResult]):
    """(stats, throughput_ratio_hp, throughput_ratio_lp) for a policy run,
    against its paired uncapped reference when one ran (the paper's
    capping-impact-only comparison), else raw ideal-relative stats."""
    if ref is None:
        return res.latency, None, None
    prios = {r.rid: r.priority for r in reqs}
    stats = impact_vs_reference(res.latencies, ref.latencies, prios)
    tr_hp = (_throughput(reqs, prios, res, "high")
             / max(1e-9, _throughput(reqs, prios, ref, "high")))
    tr_lp = (_throughput(reqs, prios, res, "low")
             / max(1e-9, _throughput(reqs, prios, ref, "low")))
    return stats, tr_hp, tr_lp


def _run_row(scenario: Scenario, wls, shares, server,
             budget_w: Optional[float], policy_factory) -> ExperimentResult:
    fleet = scenario.fleet
    n = fleet.n_servers
    reqs = row_trace(scenario, wls, shares, n, seed=scenario.seed)

    ref = None
    if scenario.compare_to_reference:
        # uncapped reference (infinite power budget: never brakes, never caps)
        ref = RowSimulator(wls, server, n, 10 * n, NoCap(), reqs, shares,
                           SimConfig(power_scale=scenario.power_scale,
                                     record_power=False),
                           duration=scenario.duration_s).run()
    res = row_sim(scenario, wls, shares, server, budget_w, policy_factory(),
                  reqs).run()

    stats, tr_hp, tr_lp = _reference_stats(reqs, res, ref)
    return ExperimentResult(
        n_servers=n,
        added_frac=n / fleet.n_provisioned - 1.0,
        stats=stats, result=res, ref_result=ref,
        meets=meets_slo(stats, res.n_brakes, scenario.slo),
        throughput_ratio_hp=tr_hp, throughput_ratio_lp=tr_lp,
        scenario=scenario, budget_w=budget_w,
    )


def _run_cluster(scenario: Scenario, wls, shares, server,
                 budget_w: Optional[float], policy_factory) -> ExperimentResult:
    fleet = scenario.fleet
    n = fleet.n_servers
    hspec = scenario.hierarchy
    hierarchy = None
    per_row_budget = [budget_w] * fleet.n_rows
    if hspec is not None:
        # planner-shaped budget tree: interior derates propagate down to the
        # per-row budgets (the tree stays conservative), exactly as on the
        # routed-fleet path — base budgets resolved by the same
        # row_budgets rule
        hierarchy = hspec.build(row_budgets(scenario, budget_w, server))
        per_row_budget = [float(b) for b in hierarchy.leaf_budget_w]
    rows = []
    traces = []
    for i in range(fleet.n_rows):
        # each row gets its own arrival trace (decorrelated arrivals; the
        # occupancy generator controls cross-row correlation structure)
        reqs = row_trace(scenario, wls, shares, n, seed=scenario.seed + i, row=i)
        traces.append(reqs)
        rows.append(row_sim(scenario, wls, shares, server, per_row_budget[i],
                            policy_factory(), reqs, row_index=i))
    cres = ClusterSimulator(rows, rows_per_rack=fleet.rows_per_rack,
                            telemetry_s=scenario.telemetry.telemetry_s,
                            hierarchy=hierarchy).run()
    if scenario.compare_to_reference:
        # per-row uncapped references on the same traces, merged cluster-wide
        stats = LatencyStats()
        for reqs, rr in zip(traces, cres.row_results):
            ref = RowSimulator(wls, server, n, 10 * n, NoCap(), reqs, shares,
                               SimConfig(power_scale=scenario.power_scale,
                                         record_power=False),
                               duration=scenario.duration_s).run()
            st = impact_vs_reference(rr.latencies, ref.latencies,
                                     {r.rid: r.priority for r in reqs})
            stats.hp_impacts.extend(st.hp_impacts)
            stats.lp_impacts.extend(st.lp_impacts)
    else:
        stats = LatencyStats(
            hp_impacts=[x for rr in cres.row_results for x in rr.latency.hp_impacts],
            lp_impacts=[x for rr in cres.row_results for x in rr.latency.lp_impacts])
    return ExperimentResult(
        n_servers=n * fleet.n_rows,
        added_frac=n / fleet.n_provisioned - 1.0,
        stats=stats, result=cres.row_results[0], ref_result=None,
        meets=meets_slo(stats, cres.n_brakes, scenario.slo),
        throughput_ratio_hp=None, throughput_ratio_lp=None,
        scenario=scenario, budget_w=budget_w, cluster=cres,
    )


def _run_fleet(scenario: Scenario, wls, shares, server,
               budget_w: Optional[float], policy_factory) -> ExperimentResult:
    """Routed fleet run: one cluster-wide arrival process dispatched over
    ``n_rows`` rows by the scenario's RoutingSpec (repro.fleet). The
    reference, when requested, is the uncapped twin fleet under the same
    router on the same trace, so stats isolate power-management impact from
    the routing policy's own queueing behavior."""
    # imported here: repro.fleet sits above repro.experiments in the stack
    from repro.fleet.fleet import as_sim_result, build_fleet, fleet_trace

    fleet = scenario.fleet
    reqs = fleet_trace(scenario, wls, shares)
    fres = build_fleet(scenario, wls, shares, server, budget_w,
                       policy_factory, reqs).run()
    res = as_sim_result(fres)

    ref = None
    if scenario.compare_to_reference:
        ref_fres = build_fleet(scenario, wls, shares, server, budget_w,
                               policy_factory, reqs, reference=True).run()
        ref = as_sim_result(ref_fres)
    stats, tr_hp, tr_lp = _reference_stats(reqs, res, ref)
    return ExperimentResult(
        n_servers=fleet.n_servers * fleet.n_rows,
        added_frac=fleet.n_servers / fleet.n_provisioned - 1.0,
        stats=stats, result=res, ref_result=ref,
        meets=meets_slo(stats, fres.n_brakes, scenario.slo),
        throughput_ratio_hp=tr_hp, throughput_ratio_lp=tr_lp,
        scenario=scenario, budget_w=budget_w, fleet=fres,
    )


def threshold_search(base: Scenario, combos: Sequence[Tuple[float, float]],
                     added_grid: Sequence[float], *,
                     workloads=None, server=None) -> Dict[Tuple[float, float], dict]:
    """Fig 13: per (T1,T2), the max added-server fraction that (a) avoids
    powerbrakes and (b) meets SLOs. The budget is calibrated once from the
    base scenario and pinned across the sweep."""
    server = server if server is not None else base.fleet.server()
    wls, shares = workloads if workloads is not None else build_workloads(base)
    budget = resolve_budget(base, wls, shares, server)
    if budget is None:  # "nominal": pin the explicit equivalent
        budget = base.fleet.n_provisioned * server.provisioned_w
    out = {}
    for (t1, t2) in combos:
        rows = []
        max_no_brake = 0.0
        max_slo = 0.0
        for add in added_grid:
            sc = (base.with_fleet(added_frac=add)
                      .with_policy("polca", t1=t1, t2=t2)
                      .with_(budget=budget))
            o = run_experiment(sc, workloads=(wls, shares), server=server)
            rows.append((add, o))
            if o.result.n_brakes == 0:
                max_no_brake = max(max_no_brake, add)
            if o.meets:
                max_slo = max(max_slo, add)
        out[(t1, t2)] = {"rows": rows, "max_added_no_brake": max_no_brake,
                         "max_added_slo": max_slo}
    return out
