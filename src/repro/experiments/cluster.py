"""Multi-row cluster simulation under hierarchical power budgets.

``ClusterSimulator`` composes N :class:`~repro.core.simulator.RowSimulator`
instances into a row -> rack -> cluster hierarchy. Rows keep their own event
queues, policies, and budgets; the cluster layer locksteps them on the
telemetry grid and, before each tick, publishes one-tick-stale rack/cluster
power fractions into every row's ``group_fracs`` (a real rack manager
aggregates with exactly this delay). Row policies therefore see the full
hierarchical :class:`~repro.core.telemetry.Telemetry` sample; policies that
ignore the group fields behave exactly as on a standalone row — a cluster run
whose per-row budget equals the single-row budget reproduces the standalone
``RowSimulator`` results bit-for-bit on the same trace.

Power accounting is vectorized: per-tick row powers land in a [T, R] numpy
array, and rack/cluster series are reductions over it.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np

from repro.core.simulator import RowSimulator, SimResult


@dataclass
class ClusterResult:
    row_results: List[SimResult]
    power_t: np.ndarray = field(repr=False)  # [T] tick times
    row_power_frac: np.ndarray = field(repr=False)  # [T, R] of each row budget
    rack_power_frac: np.ndarray = field(repr=False)  # [T, n_racks]
    cluster_power_frac: np.ndarray = field(repr=False)  # [T] of cluster budget
    n_brakes: int = 0
    peak_cluster_frac: float = 0.0
    mean_cluster_frac: float = 0.0

    @property
    def n_rows(self) -> int:
        return len(self.row_results)

    def spike(self, window_s: float) -> float:
        """Max cluster-power rise (fraction of cluster budget) in any window."""
        w = self.cluster_power_frac
        if len(w) < 3:
            return 0.0
        dt = float(self.power_t[1] - self.power_t[0])
        k = max(1, int(round(window_s / dt)))
        diffs = w[k:] - w[:-k]
        return float(diffs.max()) if len(diffs) else 0.0


class RackHierarchy:
    """Row -> rack -> cluster budget bookkeeping, shared by
    :class:`ClusterSimulator` and the fleet driver
    (:class:`repro.fleet.fleet.FleetSimulator`): rack assignment, budget
    defaulting (each level defaults to the sum of its children), stale
    group-fraction publishing, and the vectorized [T, R] power folding."""

    def __init__(self, rows: List[RowSimulator], *, rows_per_rack: int = 2,
                 rack_budget_w: Optional[List[float]] = None,
                 cluster_budget_w: Optional[float] = None):
        self.rows_per_rack = max(1, rows_per_rack)
        self.n_racks = math.ceil(len(rows) / self.rows_per_rack)
        self.rack_of = np.asarray([i // self.rows_per_rack for i in range(len(rows))])
        self.row_budget_w = np.asarray([r.provisioned_w for r in rows], float)
        if rack_budget_w is None:
            rack_budget_w = [float(self.row_budget_w[self.rack_of == k].sum())
                             for k in range(self.n_racks)]
        self.rack_budget_w = np.asarray(rack_budget_w, float)
        self.cluster_budget_w = float(cluster_budget_w
                                      if cluster_budget_w is not None
                                      else self.rack_budget_w.sum())

    def publish_group_fracs(self, rows: List[RowSimulator], row_w: np.ndarray):
        """Push rack/cluster power fractions into every row's telemetry."""
        rack_w = np.zeros(self.n_racks)
        np.add.at(rack_w, self.rack_of, row_w)
        rack_frac = rack_w / self.rack_budget_w
        cluster_frac = float(row_w.sum() / self.cluster_budget_w)
        for i, r in enumerate(rows):
            r.group_fracs = (float(rack_frac[self.rack_of[i]]), cluster_frac)
        return rack_frac, cluster_frac

    def fold(self, power: np.ndarray):
        """[T, R] watts -> (row_frac [T,R], rack_frac [T,K], cluster_frac
        [T]), each as fractions of the level's budget."""
        row_frac = power / self.row_budget_w[None, :] if len(power) else power
        rack_w = np.zeros((len(power), self.n_racks))
        for k in range(self.n_racks):
            rack_w[:, k] = power[:, self.rack_of == k].sum(axis=1)
        rack_frac = rack_w / self.rack_budget_w[None, :] if len(power) else rack_w
        cluster_frac = power.sum(axis=1) / self.cluster_budget_w
        return row_frac, rack_frac, cluster_frac


class ClusterSimulator:
    """Lockstep N rows under row/rack/cluster budgets.

    ``rack_budget_w``/``cluster_budget_w`` default to the sum of their
    children's budgets (no extra oversubscription at the aggregation levels);
    pass smaller values to model oversubscribed PDUs above the row.
    """

    def __init__(self, rows: List[RowSimulator], *, rows_per_rack: int = 2,
                 rack_budget_w: Optional[List[float]] = None,
                 cluster_budget_w: Optional[float] = None,
                 telemetry_s: Optional[float] = None):
        if not rows:
            raise ValueError("ClusterSimulator needs at least one row")
        self.rows = rows
        self.hierarchy = RackHierarchy(rows, rows_per_rack=rows_per_rack,
                                       rack_budget_w=rack_budget_w,
                                       cluster_budget_w=cluster_budget_w)
        self.telemetry_s = float(telemetry_s or rows[0].cfg.telemetry_s)

    def _publish_group_fracs(self, row_w: np.ndarray):
        return self.hierarchy.publish_group_fracs(self.rows, row_w)

    def run(self) -> ClusterResult:
        rows = self.rows
        for r in rows:
            r.start()
        duration = max(r.duration for r in rows)
        alive = [True] * len(rows)
        t = self.telemetry_s
        ticks: List[float] = []
        samples: List[np.ndarray] = []
        prev_row_w: Optional[np.ndarray] = None
        while t <= duration and any(alive):
            if prev_row_w is not None:
                # one tick stale: what the rack manager aggregated last sample
                self._publish_group_fracs(prev_row_w)
            for i, r in enumerate(rows):
                if alive[i]:
                    alive[i] = r.advance_to(min(t, r.duration))
            row_w = np.asarray([r.row_power for r in rows], float)
            ticks.append(t)
            samples.append(row_w)
            prev_row_w = row_w
            t += self.telemetry_s
        for r in rows:  # drain any events between the last tick and duration
            r.advance_to(r.duration)
        row_results = [r.finalize() for r in rows]

        power = (np.stack(samples) if samples
                 else np.zeros((0, len(rows))))  # [T, R] watts
        power_t = np.asarray(ticks)
        row_frac, rack_frac, cluster_frac = self.hierarchy.fold(power)
        return ClusterResult(
            row_results=row_results,
            power_t=power_t,
            row_power_frac=row_frac,
            rack_power_frac=rack_frac,
            cluster_power_frac=cluster_frac,
            n_brakes=sum(rr.n_brakes for rr in row_results),
            peak_cluster_frac=float(cluster_frac.max()) if len(cluster_frac) else 0.0,
            mean_cluster_frac=float(cluster_frac.mean()) if len(cluster_frac) else 0.0,
        )
