"""Multi-row cluster simulation under hierarchical power budgets.

``ClusterSimulator`` composes N :class:`~repro.core.simulator.RowSimulator`
instances under a :class:`~repro.core.hierarchy.PowerHierarchy` — by default
the classic row -> rack -> cluster split, but any arbitrary-depth budget tree
(row -> rack -> PDU set -> site) plugs in via the ``hierarchy`` parameter.
Rows keep their own event queues, policies, and budgets; the cluster layer
locksteps them on the telemetry grid and, before each tick, publishes
one-tick-stale ancestor power fractions into every row's ``group_fracs``
vector (a real rack manager aggregates with exactly this delay). Row policies
therefore see the full hierarchical
:class:`~repro.core.telemetry.Telemetry` sample; policies that ignore the
group fields behave exactly as on a standalone row — a cluster run whose
per-row budget equals the single-row budget reproduces the standalone
``RowSimulator`` results bit-for-bit on the same trace.

Power accounting is vectorized: per-tick row powers land in a [T, R] numpy
array, and every aggregation level is one fold over it
(:meth:`~repro.core.hierarchy.PowerHierarchy.fold`).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import numpy as np

from repro.core.hierarchy import PowerHierarchy
from repro.core.simulator import RowSimulator, SimResult


@dataclass
class ClusterResult:
    row_results: List[SimResult]
    power_t: np.ndarray = field(repr=False)  # [T] tick times
    row_power_frac: np.ndarray = field(repr=False)  # [T, R] of each row budget
    rack_power_frac: np.ndarray = field(repr=False)  # [T, n_racks] (leaf parents)
    cluster_power_frac: np.ndarray = field(repr=False)  # [T] of the root budget
    n_brakes: int = 0
    peak_cluster_frac: float = 0.0
    mean_cluster_frac: float = 0.0
    # full per-node telemetry (leaves first, root last) — the two fields
    # above are views into this for the rack level and the root
    node_power_frac: Optional[np.ndarray] = field(default=None, repr=False)  # [T, N]
    node_names: Tuple[str, ...] = ()

    @property
    def n_rows(self) -> int:
        return len(self.row_results)

    def spike(self, window_s: float) -> float:
        """Max cluster-power rise (fraction of cluster budget) in any window."""
        w = self.cluster_power_frac
        if len(w) < 3:
            return 0.0
        dt = float(self.power_t[1] - self.power_t[0])
        k = max(1, int(round(window_s / dt)))
        diffs = w[k:] - w[:-k]
        return float(diffs.max()) if len(diffs) else 0.0


class RackHierarchy(PowerHierarchy):
    """Thin two-level constructor over :class:`PowerHierarchy`: the classic
    row -> rack -> cluster split shared by :class:`ClusterSimulator` and the
    fleet driver (:class:`repro.fleet.fleet.FleetSimulator`). Rack assignment
    (consecutive runs of ``rows_per_rack``), budget defaulting (each level
    the sum of its children), stale group-fraction publishing, and the
    vectorized fold all live in the base class now — this subclass only
    keeps the legacy construction signature and attribute names."""

    def __init__(self, rows: List[RowSimulator], *, rows_per_rack: int = 2,
                 rack_budget_w: Optional[List[float]] = None,
                 cluster_budget_w: Optional[float] = None):
        proto = PowerHierarchy.two_level(
            [r.provisioned_w for r in rows], rows_per_rack=rows_per_rack,
            rack_budget_w=rack_budget_w, cluster_budget_w=cluster_budget_w)
        super().__init__(proto.parent, proto.node_budget_w, proto.n_leaves,
                         proto.names)
        self.rows_per_rack = max(1, rows_per_rack)
        self.n_racks = len(self.leaf_parents)
        self.rack_of = self.parent[:self.n_leaves] - self.n_leaves

    # legacy attribute names (tests and external callers)
    @property
    def row_budget_w(self) -> np.ndarray:
        return self.node_budget_w[:self.n_leaves]

    @property
    def rack_budget_w(self) -> np.ndarray:
        return self.node_budget_w[self.leaf_parents]

    @property
    def cluster_budget_w(self) -> float:
        return self.root_budget_w

    def publish_group_fracs(self, rows: List[RowSimulator], row_w: np.ndarray):
        """Legacy-shaped publish: push ancestor fracs into every row (the
        base-class :meth:`~repro.core.hierarchy.PowerHierarchy.publish`) and
        return ``(rack_frac [K], cluster_frac)`` like the pre-hierarchy
        code."""
        frac = self.publish(rows, row_w)
        return frac[self.leaf_parents], float(frac[self.root])


def resolve_row_hierarchy(rows: List[RowSimulator],
                          hierarchy: Optional[PowerHierarchy], *,
                          rows_per_rack: int = 2,
                          rack_budget_w: Optional[List[float]] = None,
                          cluster_budget_w: Optional[float] = None) -> PowerHierarchy:
    """The budget tree a row-driving simulator runs under — shared by
    :class:`ClusterSimulator` and the fleet driver. An explicit
    ``hierarchy`` must match the row count and excludes the two-level
    budget arguments (they would be silently ignored otherwise); without
    one, the classic :class:`RackHierarchy` split is built from the rows."""
    if hierarchy is not None:
        if hierarchy.n_leaves != len(rows):
            raise ValueError(f"hierarchy has {hierarchy.n_leaves} leaves "
                             f"for {len(rows)} rows")
        if rack_budget_w is not None or cluster_budget_w is not None:
            raise ValueError(
                "pass either an explicit hierarchy or rack_budget_w/"
                "cluster_budget_w, not both — the hierarchy carries every "
                "level's budget")
        return hierarchy
    return RackHierarchy(rows, rows_per_rack=rows_per_rack,
                         rack_budget_w=rack_budget_w,
                         cluster_budget_w=cluster_budget_w)


class ClusterSimulator:
    """Lockstep N rows under a hierarchical power budget tree.

    With the default two-level tree, ``rack_budget_w``/``cluster_budget_w``
    default to the sum of their children's budgets (no extra
    oversubscription at the aggregation levels); pass smaller values to
    model oversubscribed PDUs above the row, or pass an explicit
    ``hierarchy`` (:class:`~repro.core.hierarchy.PowerHierarchy`) for
    arbitrary-depth site topologies.
    """

    def __init__(self, rows: List[RowSimulator], *, rows_per_rack: int = 2,
                 rack_budget_w: Optional[List[float]] = None,
                 cluster_budget_w: Optional[float] = None,
                 telemetry_s: Optional[float] = None,
                 hierarchy: Optional[PowerHierarchy] = None):
        if not rows:
            raise ValueError("ClusterSimulator needs at least one row")
        self.rows = rows
        self.hierarchy = resolve_row_hierarchy(
            rows, hierarchy, rows_per_rack=rows_per_rack,
            rack_budget_w=rack_budget_w, cluster_budget_w=cluster_budget_w)
        self.telemetry_s = float(telemetry_s or rows[0].cfg.telemetry_s)

    def _publish_group_fracs(self, row_w: np.ndarray):
        return self.hierarchy.publish(self.rows, row_w)

    def run(self) -> ClusterResult:
        rows = self.rows
        for r in rows:
            r.start()
        duration = max(r.duration for r in rows)
        alive = [True] * len(rows)
        t = self.telemetry_s
        ticks: List[float] = []
        samples: List[np.ndarray] = []
        prev_row_w: Optional[np.ndarray] = None
        while t <= duration and any(alive):
            if prev_row_w is not None:
                # one tick stale: what the rack manager aggregated last sample
                self._publish_group_fracs(prev_row_w)
            for i, r in enumerate(rows):
                if alive[i]:
                    alive[i] = r.advance_to(min(t, r.duration))
            row_w = np.asarray([r.row_power for r in rows], float)
            ticks.append(t)
            samples.append(row_w)
            prev_row_w = row_w
            t += self.telemetry_s
        for r in rows:  # drain any events between the last tick and duration
            r.advance_to(r.duration)
        row_results = [r.finalize() for r in rows]

        power = (np.stack(samples) if samples
                 else np.zeros((0, len(rows))))  # [T, R] watts
        power_t = np.asarray(ticks)
        h = self.hierarchy
        node_frac = h.fold(power)  # [T, N] fractions of each node's budget
        cluster_frac = node_frac[:, h.root]
        return ClusterResult(
            row_results=row_results,
            power_t=power_t,
            row_power_frac=node_frac[:, :h.n_leaves],
            rack_power_frac=node_frac[:, h.leaf_parents],
            cluster_power_frac=cluster_frac,
            n_brakes=sum(rr.n_brakes for rr in row_results),
            peak_cluster_frac=float(cluster_frac.max()) if len(cluster_frac) else 0.0,
            mean_cluster_frac=float(cluster_frac.mean()) if len(cluster_frac) else 0.0,
            node_power_frac=node_frac,
            node_names=h.names,
        )
