"""Unified Scenario/Experiment API for the POLCA power plane.

Declare an experiment as a :class:`Scenario` (fleet x workload x policy x
telemetry x seed), run it with :func:`run_experiment`, and read a structured
:class:`ExperimentResult`. Multi-row fleets run under the hierarchical
:class:`ClusterSimulator`; policies consume structured
:class:`~repro.core.telemetry.Telemetry` samples. See DESIGN.md §8.
"""

from repro.chaos import FaultEvent, FaultSpec
from repro.core.hierarchy import PowerHierarchy
from repro.core.telemetry import Telemetry, TelemetryPolicy, dispatch
from repro.experiments.cluster import ClusterResult, ClusterSimulator, RackHierarchy
from repro.experiments.runner import (
    BASELINE_PEAK_UTIL,
    ExperimentResult,
    build_workloads,
    calibrated_budget,
    resolve_budget,
    row_budgets,
    row_sim,
    row_trace,
    run_experiment,
    threshold_search,
)
from repro.experiments.scenario import (
    CHAOS_SCENARIO_FAMILY,
    DAY,
    FLEET_SCENARIO_FAMILY,
    SITE_SCENARIO_FAMILY,
    WEEK,
    ControllerSpec,
    FleetSpec,
    HierarchySpec,
    PolicySpec,
    RoutingSpec,
    Scenario,
    TelemetryConfig,
    TrafficSpec,
    get_scenario,
    list_scenarios,
    register_scenario,
)

__all__ = [
    "BASELINE_PEAK_UTIL",
    "CHAOS_SCENARIO_FAMILY",
    "ClusterResult",
    "ClusterSimulator",
    "ControllerSpec",
    "DAY",
    "ExperimentResult",
    "FLEET_SCENARIO_FAMILY",
    "FaultEvent",
    "FaultSpec",
    "FleetSpec",
    "HierarchySpec",
    "PowerHierarchy",
    "RackHierarchy",
    "SITE_SCENARIO_FAMILY",
    "PolicySpec",
    "RoutingSpec",
    "Scenario",
    "Telemetry",
    "TelemetryConfig",
    "TelemetryPolicy",
    "TrafficSpec",
    "WEEK",
    "build_workloads",
    "calibrated_budget",
    "dispatch",
    "get_scenario",
    "list_scenarios",
    "register_scenario",
    "resolve_budget",
    "row_budgets",
    "row_sim",
    "row_trace",
    "run_experiment",
    "threshold_search",
]
