"""Declarative experiment specification (the `Scenario` API).

A ``Scenario`` is a serializable description of one POLCA experiment: fleet
composition (rows x servers, model, device), workload mix knobs, the policy
to run (by name + params, so it round-trips through JSON), telemetry/latency
constants, SLOs, seeds, and how the row power budget is set. It replaces the
sprawling positional signatures of the old ``core.oversubscription.evaluate``
— every benchmark, example, and sweep constructs a ``Scenario`` and hands it
to :func:`repro.experiments.runner.run_experiment`.

Named scenarios live in a registry (``get_scenario`` / ``register_scenario``)
so figures, tests, and the CLI can share exact configurations by name.
"""

from __future__ import annotations

import dataclasses
import json
import math
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

from repro.chaos.faults import FaultEvent, FaultSpec
from repro.obs.alerts import AlertSpec, coerce_alerts, default_alert_pack
from repro.core.policy import NoCap, OneThreshold, PolcaPolicy, PredictivePolcaPolicy
from repro.core.power_model import A100, TPU_V5E, DevicePower, ServerPower
from repro.core.slo import DEFAULT_SLO, SLO

DAY = 86_400.0
WEEK = 7 * DAY

DEVICE_PROFILES: Dict[str, DevicePower] = {
    A100.name: A100,
    TPU_V5E.name: TPU_V5E,
}

POLICY_BUILDERS: Dict[str, Callable[..., Any]] = {
    "polca": PolcaPolicy,
    "polca-predictive": PredictivePolcaPolicy,
    "one-threshold": OneThreshold,
    "no-cap": NoCap,
}


@dataclass(frozen=True)
class PolicySpec:
    """A policy by registry name + constructor params (JSON-serializable)."""

    kind: str = "polca"
    params: Dict[str, Any] = field(default_factory=dict)

    def build(self):
        """A fresh (stateless) policy instance for one simulation run."""
        return POLICY_BUILDERS[self.kind](**self.params)


@dataclass(frozen=True)
class FleetSpec:
    """What hardware hosts the experiment, and how oversubscribed it is."""

    n_provisioned: int = 40  # servers the row budget was provisioned for
    added_frac: float = 0.0  # oversubscription: the row hosts (1+added) * n
    n_rows: int = 1  # >1: ClusterSimulator composes rows
    rows_per_rack: int = 2
    model: str = "bloom-176b"
    device: str = A100.name
    n_devices_per_server: int = 8
    # per-row budget multipliers (heterogeneous PDU headroom) for routed
    # fleet runs; None = every row gets the full resolved budget
    row_budget_fracs: Optional[Tuple[float, ...]] = None

    @property
    def n_servers(self) -> int:
        return int(round(self.n_provisioned * (1.0 + self.added_frac)))

    def server(self) -> ServerPower:
        return ServerPower(DEVICE_PROFILES[self.device],
                           n_devices=self.n_devices_per_server)


@dataclass(frozen=True)
class TrafficSpec:
    """Workload-mix knobs over the Table-4 classes.

    ``generator`` names an occupancy-curve family in the
    ``core.traces`` generator registry ("diurnal" is built in; the scenario
    families — bursty, colocated, failover-surge, rack-incident, nighttime —
    register on ``import repro.provisioning``). ``gen_params`` are passed to
    the generator verbatim, so scenarios stay JSON-serializable.
    """

    occ_peak: float = 0.62  # diurnal occupancy peak (busy-server fraction)
    priority_mix_override: Optional[float] = None  # force every class's HP mix
    generator: str = "diurnal"
    gen_params: Dict[str, Any] = field(default_factory=dict)


@dataclass(frozen=True)
class RoutingSpec:
    """Fleet serving configuration: how a cluster-wide arrival process lands
    on rows. ``router``/``admission`` name entries in the ``repro.fleet``
    registries (round-robin, jsq, power-headroom, cap-aware / admit-all,
    shed-lp); params pass to the builders verbatim, so the spec round-trips
    through JSON. A Scenario carrying a RoutingSpec runs the
    :class:`~repro.fleet.fleet.FleetSimulator` path in ``run_experiment``
    instead of per-row pre-baked traces."""

    router: str = "round-robin"
    params: Dict[str, Any] = field(default_factory=dict)
    admission: str = "admit-all"
    admission_params: Dict[str, Any] = field(default_factory=dict)


@dataclass(frozen=True)
class HierarchySpec:
    """A serializable arbitrary-depth power-budget tree over a fleet's rows
    (built into a :class:`~repro.core.hierarchy.PowerHierarchy` at run
    time). ``shape`` lists the fan-out per interior level root-down —
    ``(2, 2, 3)`` is a site with 2 PDU sets x 2 racks x 3 rows = 12 rows
    (``prod(shape)`` must equal ``FleetSpec.n_rows``). ``level_names``
    labels the interior levels root-down (defaults to site/pdu/rack...).
    ``budget_fracs`` derates interior nodes by root-down path (``"0/1"`` =
    the second rack of the first PDU set); a derate multiplies every
    descendant row's budget, so planner-shaped budgets stay conservative —
    each node's budget is exactly the sum of its children's. A Scenario
    carrying a HierarchySpec runs its fleet (or cluster) under this tree
    instead of the default two-level ``rows_per_rack`` split; with a
    ``ControllerSpec(scope="tree")`` the rebalancing controller re-divides
    budgets recursively at every interior node."""

    shape: Tuple[int, ...] = (2, 2)
    level_names: Optional[Tuple[str, ...]] = None
    budget_fracs: Dict[str, float] = field(default_factory=dict)

    @property
    def n_rows(self) -> int:
        return int(math.prod(self.shape))

    def build(self, row_budget_w: Sequence[float]):
        """The live :class:`~repro.core.hierarchy.PowerHierarchy` for these
        per-row base budgets (derates applied, interior sums filled in)."""
        from repro.core.hierarchy import PowerHierarchy
        return PowerHierarchy.from_shape(
            self.shape, row_budget_w, level_names=self.level_names,
            budget_fracs=self.budget_fracs)


@dataclass(frozen=True)
class ControllerSpec:
    """Fleet-level power-rebalancing configuration. ``kind`` names a
    rebalance policy in the ``repro.fleet.controller`` registry (``static``
    — budgets never move, bit-identical to controller-less fleets;
    ``proportional`` — envelope split by measured row power; ``predictive``
    — split by the 40 s OOB-horizon power forecast); ``params`` pass to the
    policy builder verbatim. The controller re-divides the fixed ``scope``
    envelope every ``interval_s`` — ``"rack"``: each leaf-parent's rows
    share that rack's envelope; ``"cluster"``: all rows share the root
    envelope as one flat pool; ``"tree"``: the policy runs recursively at
    every interior node of the scenario's budget hierarchy (the site
    re-divides across PDU sets, PDU sets across racks, racks across rows;
    only the root envelope is frozen) — stepping
    ``alpha`` of the way to the target and never dropping a row below
    ``min_share`` of its group's equal split. A Scenario carrying a
    ControllerSpec (and a RoutingSpec — the controller rides the fleet
    driver's telemetry lockstep) gets a
    :class:`~repro.fleet.controller.FleetController`. Rebalances that would
    move fewer than ``deadband_w`` watts in total are skipped."""

    kind: str = "static"
    params: Dict[str, Any] = field(default_factory=dict)
    interval_s: float = 60.0
    scope: str = "rack"
    alpha: float = 0.5
    min_share: float = 0.5
    deadband_w: float = 1.0


@dataclass(frozen=True)
class TelemetryConfig:
    """Controller-plane constants (paper Table 1)."""

    telemetry_s: float = 2.0
    oob_latency_s: float = 40.0
    brake_latency_s: float = 5.0
    record_power: bool = True


@dataclass(frozen=True)
class Scenario:
    """One fully-specified experiment. Immutable; vary with ``with_()``."""

    name: str
    duration_s: float
    fleet: FleetSpec = field(default_factory=FleetSpec)
    policy: PolicySpec = field(default_factory=PolicySpec)
    traffic: TrafficSpec = field(default_factory=TrafficSpec)
    telemetry: TelemetryConfig = field(default_factory=TelemetryConfig)
    slo: SLO = DEFAULT_SLO
    power_scale: float = 1.0  # robustness runs: x1.05 = +5% workload power
    seed: int = 7
    # row power budget: "calibrated" (Table-2 79%-peak operating point),
    # "nominal" (n_provisioned x server rating), or explicit watts
    budget: Union[str, float] = "calibrated"
    compare_to_reference: bool = True  # diff latencies vs an uncapped run
    # fleet serving: a cluster-wide arrival process dispatched by a router
    # (repro.fleet) instead of pre-baked per-row traces
    routing: Optional[RoutingSpec] = None
    # fleet-level dynamic power rebalancing (requires routing; None = static
    # per-row budgets, exactly the pre-controller behavior)
    controller: Optional[ControllerSpec] = None
    # the power-budget tree over the rows (None = the classic two-level
    # rows_per_rack split, exactly the pre-hierarchy behavior)
    hierarchy: Optional[HierarchySpec] = None
    # chaos engine: an injectable fault timeline (row crashes, PDU loss,
    # thermal derates, demand-response) applied between telemetry ticks by
    # repro.chaos.ChaosInjector. Requires routing; None or an empty spec is
    # exactly the fault-free fleet (bit-identical, tier-1-asserted)
    faults: Optional[FaultSpec] = None
    # online alerting: AlertSpec rules evaluated per telemetry tick by an
    # obs.alerts.AlertEngine on the fleet lockstep. Requires routing;
    # write-only (alerts-on is bit-identical to alerts-off except for
    # FleetResult.alert_events, tier-1-asserted); None or () disables
    alerts: Optional[Tuple[AlertSpec, ...]] = None

    def with_(self, **kw) -> "Scenario":
        return dataclasses.replace(self, **kw)

    def with_fleet(self, **kw) -> "Scenario":
        return self.with_(fleet=dataclasses.replace(self.fleet, **kw))

    def with_policy(self, kind: str, **params) -> "Scenario":
        return self.with_(policy=PolicySpec(kind, params))

    def with_routing(self, router: str, **params) -> "Scenario":
        """Same scenario under a different routing policy (admission spec is
        preserved when one is already set)."""
        prev = self.routing or RoutingSpec()
        return self.with_(routing=dataclasses.replace(
            prev, router=router, params=params))

    def with_controller(self, kind: str, **kw) -> "Scenario":
        """Same scenario under a different rebalance policy. Keyword args
        matching ControllerSpec fields (``interval_s``, ``scope``,
        ``alpha``, ``min_share``) configure the controller; the rest pass to
        the policy builder as ``params``."""
        fields = {f.name for f in dataclasses.fields(ControllerSpec)} - {"kind", "params"}
        spec_kw = {k: v for k, v in kw.items() if k in fields}
        params = {k: v for k, v in kw.items() if k not in fields}
        prev = self.controller or ControllerSpec()
        return self.with_(controller=dataclasses.replace(
            prev, kind=kind, params=params, **spec_kw))

    def with_faults(self, faults) -> "Scenario":
        """Same scenario under a fault timeline: a
        :class:`~repro.chaos.faults.FaultSpec`, an iterable of
        :class:`~repro.chaos.faults.FaultEvent`, or ``None`` to clear."""
        if faults is not None and not isinstance(faults, FaultSpec):
            faults = FaultSpec(tuple(faults))
        return self.with_(faults=faults)

    def with_alerts(self, alerts) -> "Scenario":
        """Same scenario under an alert rule set: an iterable of
        :class:`~repro.obs.alerts.AlertSpec` (or their dicts), or ``None``
        to clear. Alerting is write-only, so every variant replays the
        unalerted scenario bit for bit."""
        return self.with_(alerts=coerce_alerts(alerts))

    def with_hierarchy(self, shape: Tuple[int, ...], **kw) -> "Scenario":
        """Same scenario under an explicit budget tree (and a fleet sized to
        match: ``n_rows`` is set to ``prod(shape)``). Keyword args pass to
        :class:`HierarchySpec` (``level_names``, ``budget_fracs``)."""
        spec = HierarchySpec(shape=tuple(shape), **kw)
        return (self.with_(hierarchy=spec)
                .with_fleet(n_rows=spec.n_rows))

    # -- serialization ------------------------------------------------------
    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "Scenario":
        d = dict(d)
        fleet = dict(d.get("fleet", {}))
        if fleet.get("row_budget_fracs") is not None:
            fleet["row_budget_fracs"] = tuple(fleet["row_budget_fracs"])
        d["fleet"] = FleetSpec(**fleet)
        d["policy"] = PolicySpec(**d.get("policy", {}))
        d["traffic"] = TrafficSpec(**d.get("traffic", {}))
        d["telemetry"] = TelemetryConfig(**d.get("telemetry", {}))
        d["slo"] = SLO(**d.get("slo", {}))
        if d.get("routing") is not None:
            d["routing"] = RoutingSpec(**d["routing"])
        if d.get("controller") is not None:
            d["controller"] = ControllerSpec(**d["controller"])
        if d.get("hierarchy") is not None:
            h = dict(d["hierarchy"])
            h["shape"] = tuple(h.get("shape", ()))
            if h.get("level_names") is not None:
                h["level_names"] = tuple(h["level_names"])
            d["hierarchy"] = HierarchySpec(**h)
        if d.get("faults") is not None:
            d["faults"] = FaultSpec.from_dict(d["faults"])
        if d.get("alerts") is not None:
            d["alerts"] = coerce_alerts(d["alerts"])
        return cls(**d)

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), sort_keys=True)

    @classmethod
    def from_json(cls, s: str) -> "Scenario":
        return cls.from_dict(json.loads(s))


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Scenario] = {}


def register_scenario(scenario: Scenario, *, overwrite: bool = False) -> Scenario:
    if scenario.name in _REGISTRY and not overwrite:
        raise ValueError(f"scenario {scenario.name!r} already registered")
    _REGISTRY[scenario.name] = scenario
    return scenario


def get_scenario(name: str) -> Scenario:
    try:
        return _REGISTRY[name]
    except KeyError:
        known = ", ".join(sorted(_REGISTRY))
        raise KeyError(f"unknown scenario {name!r}; registered: {known}") from None


def list_scenarios() -> List[str]:
    return sorted(_REGISTRY)


# Named configurations shared by benchmarks, examples, and tests. Benchmarks
# shorten durations in --quick mode via ``with_()``.
register_scenario(Scenario(
    name="table2-baseline",
    duration_s=WEEK,
    policy=PolicySpec("no-cap"),
    seed=11,
    budget="nominal",
    compare_to_reference=False,
))
register_scenario(Scenario(
    name="fig13-search-base",
    duration_s=WEEK / 2,
    fleet=FleetSpec(added_frac=0.30),
))
register_scenario(Scenario(
    name="fig14-plus30",
    duration_s=WEEK / 2,
    fleet=FleetSpec(added_frac=0.30),
))
register_scenario(Scenario(
    name="fig16-six-week",
    duration_s=6 * WEEK,
    policy=PolicySpec("no-cap"),
    traffic=TrafficSpec(occ_peak=0.97),
    seed=23,
    budget="nominal",
    compare_to_reference=False,
))
register_scenario(Scenario(
    name="fig17-comparison",
    duration_s=WEEK / 2,
    fleet=FleetSpec(added_frac=0.30),
))
register_scenario(Scenario(
    name="quickstart-plus30",
    duration_s=3 * 3600.0,
    fleet=FleetSpec(added_frac=0.30),
))
register_scenario(Scenario(
    name="cluster-2rack",
    duration_s=DAY / 4,
    fleet=FleetSpec(n_provisioned=20, added_frac=0.30, n_rows=4, rows_per_rack=2),
    budget="nominal",
    traffic=TrafficSpec(occ_peak=0.9),
    compare_to_reference=False,
))
register_scenario(Scenario(
    name="cluster-six-week",
    duration_s=6 * WEEK,
    fleet=FleetSpec(added_frac=0.30, n_rows=8, rows_per_rack=2),
    traffic=TrafficSpec(occ_peak=0.97),
    budget="nominal",
    compare_to_reference=False,
))

# Fleet serving scenarios (repro.fleet): one cluster-wide arrival process
# dispatched over an oversubscribed 6-row cluster whose last row sits on a
# 30%-derated PDU (row_budget_fracs) under sustained near-peak traffic — the
# configuration where routing policy decides whether the HP SLO survives:
# round-robin keeps feeding the derated row (brakes, blown HP p99) while
# cap-state-aware routing water-fills around it inside the same envelope.
# Variants swap the router only, so policy comparisons share the exact same
# trace and envelope.
_FLEET_BASE = Scenario(
    name="fleet-round-robin",
    duration_s=DAY / 4,
    fleet=FleetSpec(n_provisioned=20, added_frac=0.05, n_rows=6,
                    rows_per_rack=2,
                    row_budget_fracs=(1.0, 1.0, 1.0, 1.0, 1.0, 0.7)),
    policy=PolicySpec("polca"),
    traffic=TrafficSpec(occ_peak=0.62, gen_params={"trough": 0.55}),
    routing=RoutingSpec("round-robin"),
    budget="calibrated",
)
register_scenario(_FLEET_BASE)
register_scenario(_FLEET_BASE.with_routing("jsq").with_(name="fleet-jsq"))
register_scenario(_FLEET_BASE.with_routing("power-headroom")
                  .with_(name="fleet-power-headroom"))
register_scenario(_FLEET_BASE.with_routing("cap-aware")
                  .with_(name="fleet-cap-aware"))
# admission-control variant: round-robin keeps overloading the derated row
# (power emergencies), so LP shedding actually engages — the demo that shed
# accounting is exact and HP is never shed
register_scenario(_FLEET_BASE.with_(
    name="fleet-rr-shed",
    routing=RoutingSpec("round-robin", admission="shed-lp",
                        admission_params={"shed_above": 0.97})))

# Fleet rebalancing scenarios (repro.fleet.controller): the derated-row
# cluster pushed past the point where routing alone saves it — traffic high
# enough that even cap-aware dispatch powerbrakes the 0.7x row under static
# per-row budgets, while its rack partner holds slack it never spends. The
# variants differ ONLY in the ControllerSpec (same trace, envelope, router),
# so they measure exactly what dynamic rebalancing buys: `static` reproduces
# pre-controller behavior bit-for-bit, `proportional` follows measured
# demand, `predictive` follows the 40s OOB-horizon forecast, and the
# forecast-router variant pairs the predictive controller with the
# forecast-aware router (budget moves toward predicted demand while marginal
# load steers away from predicted congestion).
_REBALANCE_BASE = _FLEET_BASE.with_routing("cap-aware").with_(
    name="fleet-rebalance-static",
    traffic=TrafficSpec(occ_peak=0.70, gen_params={"trough": 0.62}),
    controller=ControllerSpec("static"),
)
register_scenario(_REBALANCE_BASE)
register_scenario(_REBALANCE_BASE.with_controller("proportional")
                  .with_(name="fleet-rebalance-proportional"))
register_scenario(_REBALANCE_BASE.with_controller("predictive")
                  .with_(name="fleet-rebalance-predictive"))
register_scenario(_REBALANCE_BASE.with_controller("predictive")
                  .with_routing("forecast-aware")
                  .with_(name="fleet-rebalance-forecast-router"))

# The routed-fleet scenario family (one trace + envelope, router swapped):
# the set the provisioning planner sweeps in benchmarks/capacity_planning.py
# ("how far does the envelope stretch under each dispatch policy").
FLEET_SCENARIO_FAMILY: List[str] = [
    "fleet-round-robin",
    "fleet-jsq",
    "fleet-power-headroom",
    "fleet-cap-aware",
    "fleet-rr-shed",
]

# Site-scale hierarchy scenarios (repro.core.hierarchy): a 12-row site — 2
# PDU sets x 2 racks x 3 rows — whose second rack (path "0/1") sits on a
# 30%-derated PDU, under the same stressed traffic as the fleet-rebalance
# family. The derate is *planner-shaped*: it propagates down to the rack's
# three row budgets (the tree stays conservative), so every row of that rack
# powerbrakes under load while the sibling rack and the entire second PDU
# set hold slack a flat per-row (or per-rack) rebalance can never reach —
# rack-scope rebalancing is structurally useless here (all three siblings
# are equally starved). Only the tree-scope controller, re-dividing the site
# envelope across PDU sets and racks recursively, moves that headroom to
# where the demand is. Variants differ ONLY in the ControllerSpec.
_SITE_BASE = Scenario(
    name="site-static",
    duration_s=DAY / 4,
    fleet=FleetSpec(n_provisioned=20, added_frac=0.05, n_rows=12),
    policy=PolicySpec("polca"),
    traffic=TrafficSpec(occ_peak=0.70, gen_params={"trough": 0.62}),
    routing=RoutingSpec("cap-aware"),
    controller=ControllerSpec("static"),
    hierarchy=HierarchySpec(shape=(2, 2, 3), budget_fracs={"0/1": 0.7}),
    budget="calibrated",
)
register_scenario(_SITE_BASE)
register_scenario(_SITE_BASE.with_controller("predictive", scope="rack")
                  .with_(name="site-rack-predictive"))
register_scenario(_SITE_BASE.with_controller("predictive", scope="tree")
                  .with_(name="site-tree-predictive"))

SITE_SCENARIO_FAMILY: List[str] = [
    "site-static",
    "site-rack-predictive",
    "site-tree-predictive",
]

# Chaos scenarios (repro.chaos): the 12-row site under injected fault
# timelines. Unlike the site-* family the site starts *healthy* (no
# budget_fracs derate) — the fault is the only stress, so every variant
# isolates how the unchanged control plane handles one emergency:
#
# * chaos-noop         — site-static plus an empty FaultSpec: the tier-1
#                        bit-parity anchor (must be identical to the PR 5
#                        fleet, byte for byte).
# * chaos-pdu-loss-*   — pdu0 (half the site) loses 30% of its feed for a
#                        40 min window mid-trace (the OOB budget step-down
#                        ramps over 2 min as the redundant feed saturates).
#                        `static` + admit-all holds budgets where
#                        provisioning put them and powerbrakes; `tree`
#                        re-divides the shrunk site envelope around the
#                        capacity cap every interval while shed-lp sheds LP
#                        load during the emergency. The family pins an
#                        explicit thin-headroom row budget (105 kW, ~98% of
#                        nominal) — the operating point where a 30% PDU
#                        derate is survivable by rebalancing but not by
#                        static budgets (benchmarks/chaos_resilience.py).
# * chaos-row-crash    — one row crashes and later revives: the
#                        conservation demo (admitted + shed == offered
#                        across the outage; in-flight work drains; revival
#                        re-enters via inject()).
# * chaos-demand-response — a grid event ramps the *site* envelope down 15%
#                        over 10 min and restores it later; tree-scope
#                        rebalancing follows the shrinking root.
#
# The whole family carries the default alert pack (obs.alerts): alerting is
# write-only, so the rules ride along without moving a bit of any series —
# chaos-noop doubles as the zero-false-alarm anchor, and the pdu-loss
# variants are the detection-latency yardstick (benchmarks/alerting.py).
_CHAOS_BASE = Scenario(
    name="chaos-pdu-loss-static",
    duration_s=DAY / 4,
    fleet=FleetSpec(n_provisioned=20, added_frac=0.05, n_rows=12),
    policy=PolicySpec("polca"),
    traffic=TrafficSpec(occ_peak=0.70, gen_params={"trough": 0.62}),
    routing=RoutingSpec("cap-aware"),
    controller=ControllerSpec("static"),
    hierarchy=HierarchySpec(shape=(2, 2, 3)),
    budget=105_000.0,
    faults=FaultSpec((FaultEvent("node-derate", t=2400.0, node="pdu0",
                                 factor=0.7, until=4800.0, ramp_s=120.0),)),
    alerts=default_alert_pack(),
)
register_scenario(_SITE_BASE.with_(name="chaos-noop", faults=FaultSpec(),
                                   alerts=default_alert_pack()))
register_scenario(_CHAOS_BASE)
register_scenario(_CHAOS_BASE.with_controller("predictive", scope="tree")
                  .with_(name="chaos-pdu-loss-tree",
                         routing=RoutingSpec(
                             "cap-aware", admission="shed-lp",
                             admission_params={"shed_above": 0.97})))
register_scenario(_CHAOS_BASE.with_(
    name="chaos-row-crash",
    faults=FaultSpec((FaultEvent("row-crash", t=1800.0, row=3),
                      FaultEvent("row-revive", t=4500.0, row=3)))))
register_scenario(_CHAOS_BASE.with_controller("predictive", scope="tree")
                  .with_(name="chaos-demand-response",
                         faults=FaultSpec((FaultEvent(
                             "site-demand-response", t=2400.0, factor=0.85,
                             ramp_s=600.0, until=5400.0),))))

CHAOS_SCENARIO_FAMILY: List[str] = [
    "chaos-noop",
    "chaos-pdu-loss-static",
    "chaos-pdu-loss-tree",
    "chaos-row-crash",
    "chaos-demand-response",
]
