"""Mamba2 (SSD, state-space duality) blocks — chunked matmul form + decode step.

The chunked SSD forward (quadratic-within-chunk + linear state passing across
chunks) is the TPU-friendly matmul formulation from arXiv:2405.21060. A naive
sequential recurrence lives in ``repro.kernels.ref`` as the oracle; the decode
step below *is* that recurrence for a single token.

Shapes: d_inner = expand*d_model, H = d_inner//headdim heads, G groups sharing
(B, C) projections of state size N.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import rmsnorm
from repro.models.param import ParamSpec


def ssm_dims(cfg: ModelConfig) -> Tuple[int, int, int, int]:
    d_in = cfg.ssm_expand * cfg.d_model
    H = d_in // cfg.ssm_headdim
    return d_in, H, cfg.ssm_n_groups, cfg.ssm_d_state


def ssm_specs(cfg: ModelConfig) -> dict:
    D = cfg.d_model
    d_in, H, G, N = ssm_dims(cfg)
    W = cfg.ssm_conv_width
    wd = cfg.weight_dtype
    return {
        "w_z": ParamSpec((D, d_in), ("embed", "ssm_inner"), dtype=wd),
        "w_x": ParamSpec((D, d_in), ("embed", "ssm_inner"), dtype=wd),
        "w_B": ParamSpec((D, G * N), ("embed", None), dtype=wd),
        "w_C": ParamSpec((D, G * N), ("embed", None), dtype=wd),
        "w_dt": ParamSpec((D, H), ("embed", "ssm_heads"), dtype=wd),
        "dt_bias": ParamSpec((H,), ("ssm_heads",), init="ssm_dt", dtype=wd),
        "A_log": ParamSpec((H,), ("ssm_heads",), init="ssm_a", dtype=wd),
        "D_skip": ParamSpec((H,), ("ssm_heads",), init="ones", dtype=wd),
        "conv_x": ParamSpec((W, d_in), ("conv", "ssm_inner"), scale=1.0, dtype=wd),
        "conv_B": ParamSpec((W, G * N), ("conv", None), dtype=wd),
        "conv_C": ParamSpec((W, G * N), ("conv", None), dtype=wd),
        "gate_norm": ParamSpec((d_in,), ("ssm_inner",), init="ones", dtype=wd),
        "w_out": ParamSpec((d_in, D), ("ssm_inner", "embed"), dtype=wd),
    }


def _causal_conv(x, w, tail=None):
    """Depthwise causal conv along S. x: [B,S,C]; w: [W,C]; tail: [B,W-1,C]
    carried state for decode/continuation. Returns (y, new_tail)."""
    W = w.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)
    y = sum(xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(W))
    new_tail = xp[:, xp.shape[1] - (W - 1) :, :]
    return y, new_tail


def _project(cfg, p, x):
    dt_ = cfg.activation_dtype
    z = x @ p["w_z"].astype(dt_)
    xin = x @ p["w_x"].astype(dt_)
    Bm = x @ p["w_B"].astype(dt_)
    Cm = x @ p["w_C"].astype(dt_)
    dt_raw = (x @ p["w_dt"].astype(dt_)).astype(jnp.float32) + p["dt_bias"].astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw)  # [B,S,H] fp32
    return z, xin, Bm, Cm, dt


def ssm_forward(cfg: ModelConfig, p: dict, x, *, init_state=None, conv_tails=None,
                return_state: bool = False):
    """Full-sequence SSD. x: [B,S,D]. Returns y [B,S,D] (+ (ssm_state, conv_tail))."""
    B_, S, D = x.shape
    d_in, H, G, N = ssm_dims(cfg)
    P = cfg.ssm_headdim
    act = cfg.activation_dtype

    z, xin, Bm, Cm, dt = _project(cfg, p, x)
    xin, tail_x = _causal_conv(xin, p["conv_x"].astype(act),
                               None if conv_tails is None else conv_tails["x"])
    Bm, tail_B = _causal_conv(Bm, p["conv_B"].astype(act),
                              None if conv_tails is None else conv_tails["B"])
    Cm, tail_C = _causal_conv(Cm, p["conv_C"].astype(act),
                              None if conv_tails is None else conv_tails["C"])
    xin, Bm, Cm = jax.nn.silu(xin), jax.nn.silu(Bm), jax.nn.silu(Cm)

    # Pad S up to a chunk multiple. Padded steps get dt=0: decay exp(0)=1 and
    # zero input contribution, so the final state is exact.
    Q = min(cfg.ssm_chunk, S)
    S_orig = S
    if S % Q:
        pad = Q - S % Q
        padf = lambda a: jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2))
        xin, Bm, Cm, dt = padf(xin), padf(Bm), padf(Cm), padf(dt)
        S = S + pad
    C_ = S // Q

    X = xin.reshape(B_, C_, Q, H, P)
    Bm = Bm.reshape(B_, C_, Q, G, N).astype(jnp.float32)
    Cm = Cm.reshape(B_, C_, Q, G, N).astype(jnp.float32)
    dt = dt.reshape(B_, C_, Q, H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))  # [H]
    dA = dt * A[None, None, None, :]  # [B,c,Q,H]
    cs = jnp.cumsum(dA, axis=2)  # inclusive

    rep = H // G
    Xf = X.astype(jnp.float32)

    # --- intra-chunk (quadratic within chunk) ------------------------------
    # L[q,k] = exp(cs[q]-cs[k]) for q>=k else 0
    Lexp = cs[:, :, :, None, :] - cs[:, :, None, :, :]  # [B,c,Q,Q,H] (q,k)
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.where(tri[None, None, :, :, None], jnp.exp(Lexp), 0.0)
    CB = jnp.einsum("bcqgn,bckgn->bcqkg", Cm, Bm)  # [B,c,Q,Q,G]
    CB = jnp.repeat(CB, rep, axis=-1)  # [B,c,Q,Q,H]
    M = CB * L * dt[:, :, None, :, :]  # weight for input k at query q
    Y = jnp.einsum("bcqkh,bckhp->bcqhp", M, Xf)

    # --- chunk states -------------------------------------------------------
    decay_states = jnp.exp(cs[:, :, -1:, :] - cs)  # [B,c,Q,H]
    Bh = jnp.repeat(Bm, rep, axis=3)  # [B,c,Q,H,N]
    states = jnp.einsum("bckhn,bckh,bckhp->bchnp", Bh, decay_states * dt, Xf)

    # --- inter-chunk recurrence ---------------------------------------------
    chunk_decay = jnp.exp(cs[:, :, -1, :])  # [B,c,H]
    if init_state is None:
        init_state = jnp.zeros((B_, H, N, P), jnp.float32)

    def scan_body(s_prev, inp):
        st_c, dec_c = inp  # [B,H,N,P], [B,H]
        s_new = s_prev * dec_c[:, :, None, None] + st_c
        return s_new, s_prev

    sts = jnp.moveaxis(states, 1, 0)  # [c,B,H,N,P]
    decs = jnp.moveaxis(chunk_decay, 1, 0)  # [c,B,H]
    final_state, prev_states = jax.lax.scan(scan_body, init_state.astype(jnp.float32), (sts, decs))
    prev_states = jnp.moveaxis(prev_states, 0, 1)  # [B,c,H,N,P] state at chunk starts

    Ch = jnp.repeat(Cm, rep, axis=3)  # [B,c,Q,H,N]
    Y += jnp.einsum("bcqhn,bchnp->bcqhp", Ch * jnp.exp(cs)[..., None], prev_states)

    # --- skip, gate, out ------------------------------------------------------
    Y += p["D_skip"].astype(jnp.float32)[None, None, None, :, None] * Xf
    y = Y.reshape(B_, S, d_in)[:, :S_orig].astype(act)
    y = rmsnorm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    out = y @ p["w_out"].astype(act)
    if return_state:
        return out, (final_state, {"x": tail_x, "B": tail_B, "C": tail_C})
    return out


def ssm_decode(cfg: ModelConfig, p: dict, x, state, conv_tails):
    """One-token recurrence. x: [B,1,D]; state: [B,H,N,P] fp32."""
    B_, _, D = x.shape
    d_in, H, G, N = ssm_dims(cfg)
    P = cfg.ssm_headdim
    act = cfg.activation_dtype

    z, xin, Bm, Cm, dt = _project(cfg, p, x)
    xin, tail_x = _causal_conv(xin, p["conv_x"].astype(act), conv_tails["x"])
    Bm, tail_B = _causal_conv(Bm, p["conv_B"].astype(act), conv_tails["B"])
    Cm, tail_C = _causal_conv(Cm, p["conv_C"].astype(act), conv_tails["C"])
    xin, Bm, Cm = jax.nn.silu(xin), jax.nn.silu(Bm), jax.nn.silu(Cm)

    X = xin.reshape(B_, H, P).astype(jnp.float32)
    Bm = Bm.reshape(B_, G, N).astype(jnp.float32)
    Cm = Cm.reshape(B_, G, N).astype(jnp.float32)
    dt = dt.reshape(B_, H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dA = jnp.exp(dt * A[None, :])  # [B,H]

    rep = H // G
    Bh = jnp.repeat(Bm, rep, axis=1)  # [B,H,N]
    Ch = jnp.repeat(Cm, rep, axis=1)
    state = state * dA[:, :, None, None] + jnp.einsum(
        "bhn,bh,bhp->bhnp", Bh, dt, X
    )
    y = jnp.einsum("bhn,bhnp->bhp", Ch, state)
    y += p["D_skip"].astype(jnp.float32)[None, :, None] * X
    y = y.reshape(B_, 1, d_in).astype(act)
    y = rmsnorm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    out = y @ p["w_out"].astype(act)
    return out, (state, {"x": tail_x, "B": tail_B, "C": tail_C})
