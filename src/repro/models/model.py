"""Composable model: parameter specs, train forward, prefill and decode.

One stack serves all 10 assigned architectures (plus the paper's own
workloads): the config's ``pattern`` decides the per-group block sequence
(attention / sliding-window attention / mamba), MoE placement, encoder-decoder
wiring and modality stubs. Depth is folded into ``lax.scan`` over
``num_groups`` stacked parameter groups so HLO size is O(pattern), not
O(num_layers).
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.config import ATTN, LOCAL, MAMBA, ModelConfig
from repro.models.layers import mlp, mlp_specs, rmsnorm, rmsnorm_spec, softcap
from repro.models.param import (
    ParamSpec,
    Rules,
    is_spec,
    logical_to_spec,
    resolve_spec,
    tree_map_specs,
)


# ---------------------------------------------------------------------------
# Mesh context: sharding constraints from logical axes
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MeshCtx:
    mesh: Any
    rules: Rules

    def spec(self, *logical) -> P:
        return logical_to_spec(tuple(logical), self.rules)

    def shard(self, x, *logical):
        spec = resolve_spec(x.shape, tuple(logical), self.rules, self.mesh)
        return jax.lax.with_sharding_constraint(x, NamedSharding(self.mesh, spec))

    @property
    def n_model(self) -> int:
        return self.mesh.shape["model"]

    @property
    def batch_axes(self):
        return self.rules.get("batch")

    @property
    def expert_gather_axes(self) -> Tuple[str, ...]:
        ax = self.rules.get("expert_embed")
        if ax is None:
            return ()
        return tuple(ax) if isinstance(ax, (tuple, list)) else (ax,)


# ---------------------------------------------------------------------------
# Block-level parameter specs
# ---------------------------------------------------------------------------

def _has_ffn(cfg: ModelConfig, kind: str) -> bool:
    return kind != MAMBA or cfg.ffn_every_block


def _is_moe_block(cfg: ModelConfig, idx: int, kind: str) -> bool:
    if not cfg.moe_num_experts or not _has_ffn(cfg, kind):
        return False
    if cfg.moe_layer_period == 1:
        return True
    return idx % cfg.moe_layer_period == cfg.moe_layer_period - 1


def block_specs(cfg: ModelConfig, idx: int, kind: str, moe_shards: int, *, cross: bool) -> dict:
    D = cfg.d_model
    p: Dict[str, Any] = {}
    if kind == MAMBA:
        p["ln"] = rmsnorm_spec(D)
        p["ssm"] = ssm_mod.ssm_specs(cfg)
    else:
        p["ln_attn"] = rmsnorm_spec(D)
        p["attn"] = attn_mod.attn_specs(cfg)
        if cfg.use_post_norm:
            p["post_ln_attn"] = rmsnorm_spec(D)
        if cross:
            p["ln_cross"] = rmsnorm_spec(D)
            p["cross"] = attn_mod.attn_specs(cfg, cross=True)
    if _has_ffn(cfg, kind):
        p["ln_mlp"] = rmsnorm_spec(D)
        if _is_moe_block(cfg, idx, kind):
            p["moe"] = moe_mod.moe_specs(cfg, moe_shards)
            if cfg.moe_shared_expert_ff:
                p["shared_mlp"] = mlp_specs(cfg, cfg.moe_shared_expert_ff)
        else:
            p["mlp"] = mlp_specs(cfg)
        if cfg.use_post_norm:
            p["post_ln_mlp"] = rmsnorm_spec(D)
    return p


def _stack_specs(tree, n: int):
    return tree_map_specs(
        lambda s: ParamSpec((n,) + s.shape, ("layers",) + s.logical, s.init, s.scale, s.dtype),
        tree,
    )


def model_specs(cfg: ModelConfig, n_model: int, moe_shards: int = 0) -> dict:
    """Full abstract parameter tree. ``moe_shards``: size of the expert-
    parallel domain (defaults to the model axis; the token-routed serve path
    uses data x model)."""
    moe_shards = moe_shards or n_model
    D, V = cfg.d_model, cfg.vocab_size
    wd = cfg.weight_dtype
    specs: Dict[str, Any] = {
        "embed": ParamSpec((V, D), ("vocab", "embed"), scale=1.0, dtype=wd),
        "final_norm": rmsnorm_spec(D),
    }
    if not cfg.tie_embeddings and not cfg.is_encoder_only:
        specs["unembed"] = ParamSpec((D, V), ("embed", "vocab"), dtype=wd)
    cross = cfg.is_encoder_decoder
    group = {
        f"b{i}": block_specs(cfg, i, kind, moe_shards, cross=cross)
        for i, kind in enumerate(cfg.pattern)
    }
    specs["decoder"] = _stack_specs(group, cfg.num_groups)
    if cfg.is_encoder_decoder:
        enc_layer = block_specs(cfg, 0, ATTN, moe_shards, cross=False)
        specs["encoder"] = _stack_specs(enc_layer, cfg.num_encoder_layers)
        specs["enc_norm"] = rmsnorm_spec(D)
    if cfg.is_encoder_only:
        specs["mlm_head"] = ParamSpec((D, V), ("embed", "vocab"), dtype=wd)
    return specs


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------

def _ffn_apply(cfg, bp, h, ctx: MeshCtx, aux_losses):
    y = rmsnorm(h, bp["ln_mlp"], cfg.norm_eps)
    if "moe" in bp:
        if ctx.rules.get("moe_mode") == "token":
            out = moe_mod.moe_apply_token_routed(
                cfg, bp["moe"], y, mesh=ctx.mesh, batch_spec=ctx.batch_axes)
        else:
            out = moe_mod.moe_apply(
                cfg, bp["moe"], y,
                mesh=ctx.mesh,
                batch_spec=ctx.batch_axes,
                gather_axes=ctx.expert_gather_axes,
            )
        if aux_losses is not None:
            aux_losses.append(moe_mod.moe_aux_loss(cfg, bp["moe"], y))
        if "shared_mlp" in bp:
            out = out + mlp(cfg, bp["shared_mlp"], y)
    else:
        out = mlp(cfg, bp["mlp"], y)
    if cfg.use_post_norm:
        out = rmsnorm(out, bp["post_ln_mlp"], cfg.norm_eps)
    return h + out


def _group_forward(cfg, gp, h, *, ctx, positions, causal, enc_out, aux_losses):
    """Run one pattern group at full sequence length."""
    for i, kind in enumerate(cfg.pattern):
        bp = gp[f"b{i}"]
        if kind == MAMBA:
            h = h + ssm_mod.ssm_forward(cfg, bp["ssm"], rmsnorm(h, bp["ln"], cfg.norm_eps))
        else:
            window = cfg.window_size if kind == LOCAL else 0
            a = attn_mod.self_attention(
                cfg, bp["attn"], rmsnorm(h, bp["ln_attn"], cfg.norm_eps),
                positions=positions, causal=causal, window=window,
            )
            if cfg.use_post_norm:
                a = rmsnorm(a, bp["post_ln_attn"], cfg.norm_eps)
            h = h + a
            if enc_out is not None:
                enc_kv = attn_mod.project_cross_kv(cfg, bp["cross"], enc_out)
                c = attn_mod.cross_attention(
                    cfg, bp["cross"], rmsnorm(h, bp["ln_cross"], cfg.norm_eps),
                    enc_kv,
                )
                h = h + c
        if _has_ffn(cfg, kind):
            h = _ffn_apply(cfg, bp, h, ctx, aux_losses)
        h = ctx.shard(h, "batch", "seq", "act_embed")
        if cfg.grad_barrier:
            # Pin the residual stream to bf16 across the TP boundary: without
            # this XLA hoists rmsnorm's fp32 upcast above the all-reduce and
            # every activation collective doubles (EXPERIMENTS §Perf H2).
            (h,) = jax.lax.optimization_barrier((h,))
    return h


def _unroll(cfg, length):
    return length if cfg.unroll_layers else 1


def _remat(cfg, fn):
    if cfg.remat_policy == "none":
        return fn
    if cfg.remat_policy == "dots":
        pol = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(fn, policy=pol)
    return jax.checkpoint(fn)


def _run_encoder(cfg, params, enc_embeds, ctx):
    h = enc_embeds.astype(cfg.activation_dtype)
    S = h.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)

    def body(carry, lp):
        out = _group_forward(cfg, {"b0": lp}, carry, ctx=ctx, positions=positions,
                             causal=False, enc_out=None, aux_losses=None)
        return out, None

    h, _ = jax.lax.scan(_remat(cfg, body), h, params["encoder"], unroll=_unroll(cfg, cfg.num_encoder_layers))
    return rmsnorm(h, params["enc_norm"], cfg.norm_eps)


def _embed_inputs(cfg, params, batch, ctx):
    """Token/modality embedding. Returns (h, enc_out)."""
    act = cfg.activation_dtype
    enc_out = None
    if cfg.is_encoder_decoder:
        enc_out = _run_encoder(cfg, params, batch["enc_embeds"], ctx)
    h = jnp.take(params["embed"], batch["tokens"], axis=0).astype(act)
    if cfg.frontend == "vision_stub":
        img = batch["image_embeds"].astype(act)  # [B, Ni, D]
        h = jnp.concatenate([img, h], axis=1)
    h = ctx.shard(h, "batch", "seq", "act_embed")
    if enc_out is not None:
        enc_kv = None  # cross-attn projects enc_out per block
        enc_out = ctx.shard(enc_out, "batch", "seq", "act_embed")
    return h, enc_out


def _decoder_stack(cfg, params, h, *, ctx, positions, causal, enc_out, aux_losses):
    def body(carry, gp):
        out = _group_forward(cfg, gp, carry, ctx=ctx, positions=positions,
                             causal=causal, enc_out=enc_out, aux_losses=None)
        return out, None

    if aux_losses is not None and cfg.moe_num_experts:
        # accumulate aux loss outside the scan (first group only, as a
        # representative sample — the router distribution is what matters)
        first = jax.tree.map(lambda x: x[0], params["decoder"])
        for i, kind in enumerate(cfg.pattern):
            if "moe" in first[f"b{i}"]:
                y = rmsnorm(h, first[f"b{i}"]["ln_mlp"], cfg.norm_eps)
                aux_losses.append(moe_mod.moe_aux_loss(cfg, first[f"b{i}"]["moe"], y))
                break
    h, _ = jax.lax.scan(_remat(cfg, body), h, params["decoder"], unroll=_unroll(cfg, cfg.num_groups))
    return rmsnorm(h, params["final_norm"], cfg.norm_eps)


def _logits(cfg, params, h, ctx):
    act = cfg.activation_dtype
    if cfg.is_encoder_only:
        w = params["mlm_head"].astype(act)
    elif cfg.tie_embeddings:
        w = params["embed"].astype(act).T
    else:
        w = params["unembed"].astype(act)
    logits = jnp.einsum("bsd,dv->bsv", h, w, preferred_element_type=jnp.float32)
    if cfg.final_logit_softcap:
        logits = softcap(logits, cfg.final_logit_softcap)
    return ctx.shard(logits, "batch", "seq", "vocab")


def loss_fn(cfg: ModelConfig, params, batch, ctx: MeshCtx):
    """Next-token (or MLM) cross-entropy loss, fp32."""
    h, enc_out = _embed_inputs(cfg, params, batch, ctx)
    S = h.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    causal = not cfg.is_encoder_only
    aux_losses: Optional[list] = [] if cfg.moe_num_experts else None
    h = _decoder_stack(cfg, params, h, ctx=ctx, positions=positions, causal=causal,
                       enc_out=enc_out, aux_losses=aux_losses)
    logits = _logits(cfg, params, h, ctx)

    tokens = batch["tokens"]
    n_txt = tokens.shape[1]
    if cfg.is_encoder_only:
        targets = batch["targets"]
        lg = logits
    else:
        # causal LM: predict token t+1 at text position t
        targets = tokens[:, 1:]
        lg = logits[:, -n_txt:, :][:, :-1, :]
    logz = jax.nn.logsumexp(lg, axis=-1)
    gold = jnp.take_along_axis(lg, targets[..., None].astype(jnp.int32), axis=-1)[..., 0]
    ce = jnp.mean(logz - gold)
    if aux_losses:
        ce = ce + cfg.moe_aux_loss_weight * sum(aux_losses)
    return ce


# ---------------------------------------------------------------------------
# Serving: prefill + decode
# ---------------------------------------------------------------------------

# KV caches are padded to a multiple of CACHE_PAD so the sequence dim always
# divides the mesh axes (a non-dividing dim silently loses its sharding and
# replicates cache reads — measured 16x flops/bytes on whisper decode_32k).
CACHE_PAD = 512


def cache_len(T: int) -> int:
    return -(-T // CACHE_PAD) * CACHE_PAD


def _cache_shape(cfg: ModelConfig, kind: str, idx: int, B: int, T: int, enc_S: int):
    """Abstract cache entry (shapes + logical axes) for one block kind."""
    KV, hd = cfg.num_kv_heads, cfg.head_dim
    d_in, H, G, N = ssm_mod.ssm_dims(cfg)
    W = cfg.ssm_conv_width
    act = cfg.activation_dtype
    if kind == MAMBA:
        return {
            "state": ParamSpec((B, H, N, cfg.ssm_headdim),
                               ("batch", "ssm_heads", None, None), "zeros", dtype=jnp.float32),
            "conv_x": ParamSpec((B, W - 1, d_in), ("batch", None, "ssm_inner"), "zeros", dtype=act),
            "conv_B": ParamSpec((B, W - 1, G * N), ("batch", None, None), "zeros", dtype=act),
            "conv_C": ParamSpec((B, W - 1, G * N), ("batch", None, None), "zeros", dtype=act),
        }
    Tc = min(T, cfg.window_size) if kind == LOCAL and cfg.window_size else cache_len(T)
    e: Dict[str, Any] = {
        "k": ParamSpec((B, Tc, KV, hd), ("batch", "kv_seq", None, None), "zeros", dtype=act),
        "v": ParamSpec((B, Tc, KV, hd), ("batch", "kv_seq", None, None), "zeros", dtype=act),
    }
    if cfg.is_encoder_decoder:
        e["cross_k"] = ParamSpec((B, enc_S, KV, hd), ("batch", None, "kv_heads", None), "zeros", dtype=act)
        e["cross_v"] = ParamSpec((B, enc_S, KV, hd), ("batch", None, "kv_heads", None), "zeros", dtype=act)
    return e


def cache_specs(cfg: ModelConfig, B: int, T: int, enc_S: int = 0) -> dict:
    group = {
        f"b{i}": _cache_shape(cfg, kind, i, B, T, enc_S)
        for i, kind in enumerate(cfg.pattern)
    }
    return _stack_specs(group, cfg.num_groups)


def prefill_fn(cfg: ModelConfig, params, batch, ctx: MeshCtx, max_len: int):
    """Process the prompt; return (last-position logits, cache)."""
    h, enc_out = _embed_inputs(cfg, params, batch, ctx)
    B, S = h.shape[0], h.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)

    def body(carry, gp):
        hh = carry
        caches = {}
        for i, kind in enumerate(cfg.pattern):
            bp = gp[f"b{i}"]
            if kind == MAMBA:
                y, (state, tails) = ssm_mod.ssm_forward(
                    cfg, bp["ssm"], rmsnorm(hh, bp["ln"], cfg.norm_eps), return_state=True)
                hh = hh + y
                caches[f"b{i}"] = {"state": state, "conv_x": tails["x"],
                                   "conv_B": tails["B"], "conv_C": tails["C"]}
            else:
                window = cfg.window_size if kind == LOCAL else 0
                a, (k, v) = attn_mod.self_attention(
                    cfg, bp["attn"], rmsnorm(hh, bp["ln_attn"], cfg.norm_eps),
                    positions=positions, causal=True, window=window, return_kv=True)
                if cfg.use_post_norm:
                    a = rmsnorm(a, bp["post_ln_attn"], cfg.norm_eps)
                hh = hh + a
                ce = {}
                if kind == LOCAL and cfg.window_size and cfg.window_size <= S:
                    W = cfg.window_size
                    idx = S - W + jnp.mod(jnp.arange(W) - (S - W), W)
                    ce["k"], ce["v"] = k[:, idx], v[:, idx]
                else:
                    Tc = min(max_len, cfg.window_size) if kind == LOCAL and cfg.window_size else max_len
                    pad = [(0, 0), (0, Tc - S), (0, 0), (0, 0)]
                    ce["k"], ce["v"] = jnp.pad(k, pad), jnp.pad(v, pad)
                if enc_out is not None:
                    enc_kv = attn_mod.project_cross_kv(cfg, bp["cross"], enc_out)
                    c = attn_mod.cross_attention(
                        cfg, bp["cross"], rmsnorm(hh, bp["ln_cross"], cfg.norm_eps),
                        enc_kv)
                    hh = hh + c
                    ce["cross_k"], ce["cross_v"] = enc_kv
                caches[f"b{i}"] = ce
            if _has_ffn(cfg, kind):
                hh = _ffn_apply(cfg, bp, hh, ctx, None)
            hh = ctx.shard(hh, "batch", "seq", "act_embed")
        return hh, caches

    h, cache = jax.lax.scan(body, h, params["decoder"], unroll=_unroll(cfg, cfg.num_groups))
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    logits = _logits(cfg, params, h[:, -1:, :], ctx)
    return logits, cache


def decode_fn(cfg: ModelConfig, params, token, pos, cache, ctx: MeshCtx):
    """One decode step. token: [B,1] int32; pos: scalar int32; cache pytree."""
    act = cfg.activation_dtype
    h = jnp.take(params["embed"], token, axis=0).astype(act)
    h = ctx.shard(h, "batch", None, "act_embed")

    def body(carry, xs):
        hh = carry
        gp, gc = xs
        new_c = {}
        for i, kind in enumerate(cfg.pattern):
            bp, bc = gp[f"b{i}"], gc[f"b{i}"]
            if kind == MAMBA:
                y, (state, tails) = ssm_mod.ssm_decode(
                    cfg, bp["ssm"], rmsnorm(hh, bp["ln"], cfg.norm_eps),
                    bc["state"], {"x": bc["conv_x"], "B": bc["conv_B"], "C": bc["conv_C"]})
                hh = hh + y
                new_c[f"b{i}"] = {"state": state, "conv_x": tails["x"],
                                  "conv_B": tails["B"], "conv_C": tails["C"]}
            else:
                is_ring = kind == LOCAL and cfg.window_size and bc["k"].shape[1] == cfg.window_size
                x_norm = rmsnorm(hh, bp["ln_attn"], cfg.norm_eps)
                if is_ring:
                    y, ck, cv = attn_mod.decode_ring_attention(
                        cfg, bp["attn"], x_norm, bc["k"], bc["v"], pos, cfg.window_size)
                else:
                    window = cfg.window_size if kind == LOCAL else 0
                    y, ck, cv = attn_mod.decode_self_attention(
                        cfg, bp["attn"], x_norm, bc["k"], bc["v"], pos, window=window)
                if cfg.use_post_norm:
                    y = rmsnorm(y, bp["post_ln_attn"], cfg.norm_eps)
                hh = hh + y
                ce = {"k": ck, "v": cv}
                if cfg.is_encoder_decoder:
                    c = attn_mod.cross_attention(
                        cfg, bp["cross"], rmsnorm(hh, bp["ln_cross"], cfg.norm_eps),
                        (bc["cross_k"].astype(act), bc["cross_v"].astype(act)))
                    hh = hh + c
                    ce["cross_k"], ce["cross_v"] = bc["cross_k"], bc["cross_v"]
                new_c[f"b{i}"] = ce
            if _has_ffn(cfg, kind):
                hh = _ffn_apply(cfg, bp, hh, ctx, None)
        return hh, new_c

    h, new_cache = jax.lax.scan(body, h, (params["decoder"], cache), unroll=_unroll(cfg, cfg.num_groups))
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    logits = _logits(cfg, params, h, ctx)
    return logits, new_cache
