"""Model/architecture configuration for the repro framework.

Every assigned architecture (plus the paper's own workloads) is an instance of
``ModelConfig``. One composable stack (``models/model.py``) consumes these; the
config fully determines parameter shapes, the per-layer block pattern and the
attention/MoE/SSM variants.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax.numpy as jnp

# Block kinds that may appear in a layer pattern.
ATTN = "attn"  # self attention (causal unless encoder), optionally sliding window
LOCAL = "local"  # sliding-window self attention
MAMBA = "mamba"  # Mamba2 SSD block


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm | audio | encoder
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # --- block pattern -----------------------------------------------------
    # The decoder is ``num_layers`` deep; it is built as
    # ``num_layers // len(pattern)`` scanned groups, each executing ``pattern``.
    pattern: Tuple[str, ...] = (ATTN,)
    window_size: int = 0  # sliding window for LOCAL blocks

    # --- attention variants -------------------------------------------------
    qk_norm: bool = False
    attn_logit_softcap: float = 0.0
    final_logit_softcap: float = 0.0
    use_rope: bool = True
    rope_theta: float = 10_000.0
    use_post_norm: bool = False  # gemma2-style post-sublayer norms

    # --- MLP ------------------------------------------------------------------
    mlp_type: str = "swiglu"  # swiglu | gelu
    tie_embeddings: bool = False
    # jamba-style: every block (incl. mamba) is followed by an FFN/MoE sublayer;
    # otherwise only attention blocks carry an FFN and mamba blocks stand alone.
    ffn_every_block: bool = False

    # --- MoE ------------------------------------------------------------------
    moe_num_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0  # expert hidden dim (defaults to d_ff)
    moe_shared_expert_ff: int = 0  # shared (always-on) expert hidden dim
    moe_layer_period: int = 1  # every n-th block in the pattern is MoE
    moe_capacity_factor: float = 1.25
    moe_aux_loss_weight: float = 0.01

    # --- SSM (Mamba2 / SSD) ---------------------------------------------------
    ssm_d_state: int = 128
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    ssm_conv_width: int = 4
    ssm_n_groups: int = 1

    # --- encoder-decoder --------------------------------------------------------
    num_encoder_layers: int = 0  # >0 -> encoder-decoder model
    # fraction of a shape's seq_len given to the encoder (rest to decoder)
    encoder_seq_frac: float = 0.5
    # cap on encoder context (whisper: 1500 audio frames = 30 s); 0 = no cap
    max_encoder_len: int = 0

    # --- modality frontends (STUBS: input_specs provide embeddings) -----------
    frontend: str = "none"  # none | audio_stub | vision_stub
    num_image_embeds: int = 0  # VLM: patch embeddings prepended to the text

    # --- numerics ---------------------------------------------------------------
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"  # activation/compute dtype
    param_dtype: str = "float32"  # parameter storage dtype
    logits_fp32: bool = True

    # training parallelism strategy: "tp_fsdp" (TP over model + FSDP over data)
    # or "fsdp" (pure FSDP/ZeRO-3 over ALL axes — wins for small dense models
    # where TP collectives dominate; see EXPERIMENTS.md §Perf)
    train_strategy: str = "tp_fsdp"

    # --- runtime / perf knobs ---------------------------------------------------
    # "full" by default: saving per-matmul outputs ("dots") costs ~3.7 GB/layer
    # per device at train_4k scale and blows HBM (measured in EXPERIMENTS.md §Perf)
    remat_policy: str = "full"  # none | dots | full
    optimizer: str = "adamw"  # adamw | adafactor
    use_pallas: bool = False  # Pallas kernels (TPU target); XLA path otherwise
    # Unroll the layer-group scans (dry-run only): XLA's cost analysis counts
    # while-loop bodies once, so rooflines must be measured unrolled.
    unroll_layers: bool = False
    # decode KV-cache sequence sharding over the model axis (flash-decoding style)
    decode_seq_shard: bool = True
    # optimization barrier on the residual stream at block boundaries (see
    # model._group_forward): keeps TP activation collectives in bf16
    grad_barrier: int = 0

    # pad attention q/o heads up to a multiple (0 = off): yi-34b's 56 heads
    # cannot shard over a 16-way axis; padding to 64 shards cleanly and the
    # padded wo rows are zero-initialized so outputs are exact. Padding is
    # per-KV-group (each group grows 7->8 query heads for yi) so the GQA
    # head->kv mapping of the real checkpoint is preserved. GQA only: do not
    # enable for MHA archs (KV==H) — the kv grouping would shift.
    pad_heads_multiple: int = 0

    @property
    def padded_heads(self) -> int:
        if not self.pad_heads_multiple:
            return self.num_heads
        m = self.pad_heads_multiple
        return -(-self.num_heads // m) * m

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.moe_num_experts and self.moe_d_ff == 0:
            object.__setattr__(self, "moe_d_ff", self.d_ff)
        assert self.num_layers % len(self.pattern) == 0, (
            f"{self.name}: num_layers={self.num_layers} not divisible by "
            f"pattern length {len(self.pattern)}"
        )

    # ------------------------------------------------------------------------
    @property
    def num_groups(self) -> int:
        return self.num_layers // len(self.pattern)

    @property
    def is_encoder_decoder(self) -> bool:
        return self.num_encoder_layers > 0

    @property
    def is_encoder_only(self) -> bool:
        return self.family == "encoder"

    @property
    def attention_free(self) -> bool:
        return all(k == MAMBA for k in self.pattern)

    @property
    def sub_quadratic(self) -> bool:
        """True when decode-time context cost is bounded (SSM/SWA-only/hybrid-light)."""
        kinds = set(self.pattern)
        if kinds == {MAMBA}:
            return True
        if ATTN not in kinds:  # only LOCAL (+ MAMBA)
            return True
        # hybrid: bounded number of global-attention layers per group is still
        # linear in context, but the *memory* is dominated by a handful of
        # layers; we follow the assignment and run hybrids.
        return MAMBA in kinds

    @property
    def activation_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def weight_dtype(self):
        return jnp.dtype(self.param_dtype)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # --- analytic parameter counts (for roofline MODEL_FLOPS) -----------------
    def param_counts(self) -> dict:
        D, H, KV, hd = self.d_model, self.num_heads, self.num_kv_heads, self.head_dim
        attn = D * H * hd + 2 * D * KV * hd + H * hd * D  # q,k,v,o
        if self.qk_norm:
            attn += 2 * hd
        mlp_dense = (3 if self.mlp_type in ("swiglu", "geglu") else 2) * D * self.d_ff
        n_mats = 3 if self.mlp_type in ("swiglu", "geglu") else 2
        counts = {"embed": self.vocab_size * D}
        if not self.tie_embeddings and not self.is_encoder_only:
            counts["unembed"] = self.vocab_size * D
        # Per-pattern accounting. Attention blocks always carry an FFN/MoE slot;
        # mamba blocks do so only when ffn_every_block (jamba-style).
        per_group = 0.0
        for i, kind in enumerate(self.pattern):
            if kind == MAMBA:
                d_in = self.ssm_expand * D
                nheads = d_in // self.ssm_headdim
                per_group += D * (2 * d_in + 2 * self.ssm_n_groups * self.ssm_d_state + nheads)
                per_group += d_in * D  # out proj
                per_group += (self.ssm_conv_width) * (d_in + 2 * self.ssm_n_groups * self.ssm_d_state)
                per_group += 2 * nheads + d_in  # A, D, dt_bias (+ gate norm)
            else:
                per_group += attn
            if kind != MAMBA or self.ffn_every_block:
                moe_here = self.moe_num_experts and (
                    self.moe_layer_period == 1
                    or i % self.moe_layer_period == self.moe_layer_period - 1
                )
                if moe_here:
                    per_group += self.moe_num_experts * n_mats * D * self.moe_d_ff
                    per_group += D * self.moe_num_experts  # router
                    if self.moe_shared_expert_ff:
                        per_group += n_mats * D * self.moe_shared_expert_ff
                else:
                    per_group += mlp_dense
        counts["blocks"] = per_group * self.num_groups
        if self.is_encoder_decoder:
            # encoder layers: attn + dense mlp; decoder cross-attn extra
            enc = (attn + mlp_dense) * self.num_encoder_layers
            cross = attn * self.num_layers
            counts["encoder"] = enc
            counts["cross_attn"] = cross
        return counts

    def total_params(self) -> float:
        return float(sum(self.param_counts().values()))

    def active_params(self) -> float:
        """Params touched per token (MoE: top-k + shared experts only)."""
        if not self.moe_num_experts:
            return self.total_params()
        n_mats = 3 if self.mlp_type in ("swiglu", "geglu") else 2
        total = self.total_params()
        # subtract non-active expert weight
        moe_blocks = 0
        for i, kind in enumerate(self.pattern):
            if kind == MAMBA and not self.ffn_every_block:
                continue
            if self.moe_layer_period == 1 or (i % self.moe_layer_period == self.moe_layer_period - 1):
                moe_blocks += 1
        moe_blocks *= self.num_groups
        all_experts = moe_blocks * self.moe_num_experts * n_mats * self.d_model * self.moe_d_ff
        active_experts = moe_blocks * self.moe_top_k * n_mats * self.d_model * self.moe_d_ff
        return total - all_experts + active_experts


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in ALL_SHAPES}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether an (arch, shape) cell runs, per the assignment rules."""
    if shape.is_decode and cfg.is_encoder_only:
        return False, "encoder-only arch has no decode step"
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, (
            "pure full-attention arch: 500k dense-attention decode is the "
            "quadratic regime excluded by the assignment (see DESIGN.md)"
        )
    return True, ""
