"""Shared neural-net building blocks (pure functions over param pytrees)."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.param import ParamSpec


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_spec(dim: int, logical=("act_embed",)) -> ParamSpec:
    return ParamSpec((dim,), logical, init="ones")


def rmsnorm(x, w, eps: float):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * w.astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope(x, positions, theta: float):
    """Apply RoPE. x: [..., S, H, D]; positions: [..., S] (broadcastable).

    Angles are computed in fp32 (position * freq needs the range) but the
    rotation itself runs in x.dtype: multiplying bf16 activations by fp32
    cos/sin promotes q/k — and, transposed, their backward — to fp32, which
    doubles every tensor-parallel activation all-reduce (EXPERIMENTS §Perf H2).
    """
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions.astype(jnp.float32)[..., None] * freqs  # [..., S, half]
    cos = jnp.cos(angles)[..., None, :].astype(x.dtype)  # [..., S, 1, half]
    sin = jnp.sin(angles)[..., None, :].astype(x.dtype)
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def mlp_specs(cfg: ModelConfig, d_ff: Optional[int] = None) -> dict:
    D = cfg.d_model
    F = d_ff or cfg.d_ff
    wd = cfg.weight_dtype
    if cfg.mlp_type in ("swiglu", "geglu"):
        return {
            "w_gate": ParamSpec((D, F), ("embed", "mlp"), dtype=wd),
            "w_up": ParamSpec((D, F), ("embed", "mlp"), dtype=wd),
            "w_down": ParamSpec((F, D), ("mlp", "embed"), dtype=wd),
        }
    return {
        "w_up": ParamSpec((D, F), ("embed", "mlp"), dtype=wd),
        "w_down": ParamSpec((F, D), ("mlp", "embed"), dtype=wd),
    }


def mlp(cfg: ModelConfig, p: dict, x):
    dt = cfg.activation_dtype
    if cfg.mlp_type in ("swiglu", "geglu"):
        g = x @ p["w_gate"].astype(dt)
        u = x @ p["w_up"].astype(dt)
        act = jax.nn.silu if cfg.mlp_type == "swiglu" else jax.nn.gelu
        h = act(g) * u
    else:
        h = jax.nn.gelu(x @ p["w_up"].astype(dt))
    return h @ p["w_down"].astype(dt)


def softcap(x, cap: float):
    return jnp.tanh(x / cap) * cap if cap else x
