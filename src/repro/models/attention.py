"""Attention: GQA, sliding-window, logit softcap, qk-norm, cross-attention.

XLA path (used for lowering/dry-run and CPU tests) with query-chunked scores so
long-context prefill never materializes the full [S, T] score matrix. The
Pallas flash kernels in ``repro.kernels`` implement the same contract for the
TPU target (``cfg.use_pallas``).
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import rmsnorm, rope, softcap
from repro.models.param import ParamSpec

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)

# query-chunk length for the chunked XLA attention path
Q_CHUNK = 1024


def attn_specs(cfg: ModelConfig, cross: bool = False) -> dict:
    D, KV, hd = cfg.d_model, cfg.num_kv_heads, cfg.head_dim
    H = cfg.padded_heads  # zero-padded wo rows: exact outputs, clean sharding
    wd = cfg.weight_dtype
    p = {
        "wq": ParamSpec((D, H, hd), ("embed", "heads", "head_dim"), dtype=wd),
        "wk": ParamSpec((D, KV, hd), ("embed", "kv_heads", "head_dim"), dtype=wd),
        "wv": ParamSpec((D, KV, hd), ("embed", "kv_heads", "head_dim"), dtype=wd),
        "wo": ParamSpec((H, hd, D), ("heads", "head_dim", "embed"),
                        init="zeros" if H != cfg.num_heads else "normal", dtype=wd),
    }
    if cfg.qk_norm and not cross:
        p["q_norm"] = ParamSpec((hd,), ("head_dim",), init="ones", dtype=wd)
        p["k_norm"] = ParamSpec((hd,), ("head_dim",), init="ones", dtype=wd)
    return p


def _project_q(cfg, p, x, positions):
    dt = cfg.activation_dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    if "q_norm" in p:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
    if cfg.use_rope and positions is not None:
        q = rope(q, positions, cfg.rope_theta)
    return q


def _project_kv(cfg, p, x, positions):
    dt = cfg.activation_dtype
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt))
    if "k_norm" in p:
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    if cfg.use_rope and positions is not None:
        k = rope(k, positions, cfg.rope_theta)
    return k, v


def _chunk_scores(cfg, q_chunk, k, v, mask):
    """One query chunk of attention. q_chunk [B,Qc,H,hd]; k/v [B,T,KV,hd];
    mask [Qc,T] bool (True = attend) or None (full)."""
    B, Qc, H, hd = q_chunk.shape
    KV = k.shape[2]
    G = H // KV
    q = q_chunk.reshape(B, Qc, KV, G, hd)
    # NOTE (EXPERIMENTS §Perf G6): the dot outputs the activation dtype and is
    # upcast afterwards. TPU MXUs accumulate bf16 dots in fp32 regardless, and
    # a fp32-preferred dot here makes every backward activation gradient (and
    # its tensor-parallel all-reduce) fp32 — measured 2x collective bytes.
    s = jnp.einsum("bqkgd,btkd->bkgqt", q, k).astype(jnp.float32)
    s = s * (hd ** -0.5)
    if cfg.attn_logit_softcap:
        s = softcap(s, cfg.attn_logit_softcap)
    if mask is not None:
        s = jnp.where(mask[None, None, None], s, NEG_INF)
    probs = jax.nn.softmax(s, axis=-1).astype(q_chunk.dtype)
    out = jnp.einsum("bkgqt,btkd->bqkgd", probs, v)
    return out.reshape(B, Qc, H, hd)


def _make_mask(q_pos, t_len, *, causal, window, t_offset=0, valid_len=None):
    """Boolean attend-mask [Qc, T]. q_pos: [Qc] absolute query positions."""
    t_pos = jnp.arange(t_len, dtype=jnp.int32) + t_offset
    m = jnp.ones((q_pos.shape[0], t_len), dtype=bool)
    if causal:
        m &= t_pos[None, :] <= q_pos[:, None]
    if window:
        m &= t_pos[None, :] > q_pos[:, None] - window
    if valid_len is not None:
        m &= t_pos[None, :] < valid_len
    return m


def self_attention(
    cfg: ModelConfig,
    p: dict,
    x,
    *,
    positions,
    causal: bool,
    window: int = 0,
    return_kv: bool = False,
):
    """Full-sequence self attention (train/prefill/encoder)."""
    B, S, D = x.shape
    q = _project_q(cfg, p, x, positions if cfg.use_rope else None)
    k, v = _project_kv(cfg, p, x, positions if cfg.use_rope else None)

    n_chunks = max(1, S // Q_CHUNK) if S % Q_CHUNK == 0 else 1
    if n_chunks > 1 and (causal or window):
        Qc = S // n_chunks
        qs = q.reshape(B, n_chunks, Qc, q.shape[2], q.shape[3]).transpose(1, 0, 2, 3, 4)
        pos_c = positions.reshape(n_chunks, Qc) if positions.ndim == 1 else None

        def body(carry, inp):
            qc, pc = inp
            mask = _make_mask(pc, S, causal=causal, window=window)
            return carry, _chunk_scores(cfg, qc, k, v, mask)

        _, outs = jax.lax.scan(body, None, (qs, pos_c))
        out = outs.transpose(1, 0, 2, 3, 4).reshape(B, S, q.shape[2], q.shape[3])
    else:
        mask = None
        if causal or window:
            qpos = positions if positions.ndim == 1 else jnp.arange(S, dtype=jnp.int32)
            mask = _make_mask(qpos, S, causal=causal, window=window)
        out = _chunk_scores(cfg, q, k, v, mask)

    dt = cfg.activation_dtype
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt))
    if return_kv:
        return y, (k, v)
    return y


def cross_attention(cfg: ModelConfig, p: dict, x, enc_kv):
    """Decoder cross-attention over encoder outputs (no mask, no rope)."""
    dt = cfg.activation_dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k, v = enc_kv
    out = _chunk_scores(cfg, q, k, v, None)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt))


def project_cross_kv(cfg: ModelConfig, p: dict, enc_out):
    dt = cfg.activation_dtype
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"].astype(dt))
    return k, v


def decode_self_attention(
    cfg: ModelConfig,
    p: dict,
    x,
    cache_k,
    cache_v,
    pos,
    *,
    window: int = 0,
):
    """Single-token decode against a KV cache.

    x: [B, 1, D]; cache_k/v: [B, T, KV, hd]; pos: scalar int32 (tokens 0..pos-1
    are valid; the new token is written at index pos).
    Returns (y [B,1,D], cache_k', cache_v').
    """
    B, _, D = x.shape
    T = cache_k.shape[1]
    positions = jnp.full((B, 1), pos, dtype=jnp.int32)
    q = _project_q(cfg, p, x, positions if cfg.use_rope else None)
    k_new, v_new = _project_kv(cfg, p, x, positions if cfg.use_rope else None)

    cache_k = jax.lax.dynamic_update_slice(cache_k, k_new.astype(cache_k.dtype), (0, pos, 0, 0))
    cache_v = jax.lax.dynamic_update_slice(cache_v, v_new.astype(cache_v.dtype), (0, pos, 0, 0))

    qpos = jnp.full((1,), pos, dtype=jnp.int32)
    mask = _make_mask(qpos, T, causal=True, window=window, valid_len=pos + 1)
    out = _chunk_scores(cfg, q, cache_k.astype(q.dtype), cache_v.astype(q.dtype), mask)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cfg.activation_dtype))
    return y, cache_k, cache_v


def decode_ring_attention(cfg: ModelConfig, p: dict, x, cache_k, cache_v, pos, window: int):
    """Decode against a ring-buffer KV cache of size ``window``.

    Slot i holds the KV of absolute position ``pos - ((pos - i) mod W)`` once
    the new token has been written at slot ``pos mod W``. RoPE is applied at
    absolute positions before caching, so ring rotation is transparent.
    """
    B = x.shape[0]
    W = window
    positions = jnp.full((B, 1), pos, dtype=jnp.int32)
    q = _project_q(cfg, p, x, positions if cfg.use_rope else None)
    k_new, v_new = _project_kv(cfg, p, x, positions if cfg.use_rope else None)

    slot = jnp.mod(pos, W)
    cache_k = jax.lax.dynamic_update_slice(cache_k, k_new.astype(cache_k.dtype), (0, slot, 0, 0))
    cache_v = jax.lax.dynamic_update_slice(cache_v, v_new.astype(cache_v.dtype), (0, slot, 0, 0))

    i = jnp.arange(W, dtype=jnp.int32)
    t_pos = pos - jnp.mod(pos - i, W)  # absolute position stored in slot i
    mask = ((t_pos >= 0) & (t_pos <= pos))[None, :]  # [1, W]
    out = _chunk_scores(cfg, q, cache_k.astype(q.dtype), cache_v.astype(q.dtype), mask)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cfg.activation_dtype))
    return y, cache_k, cache_v
