"""Mixture-of-Experts with expert parallelism via shard_map + lax.ragged_dot.

Design (DESIGN.md §5): experts are sharded over the ``model`` mesh axis. When
E >= n_model we shard whole experts (kimi-k2: 384/16 = 24 per shard); when
E < n_model each expert's FFN dim is additionally split into ``f_shards``
chunks so that every device owns exactly one (expert, ffn-chunk) "slot"
(mixtral: 8 experts x 2 chunks over 16 devices). Dispatch is sort-based and
capacity-bounded: no [T, E, C] one-hot dispatch tensors are ever materialized;
each shard gathers only the rows routed to its local experts and runs a
grouped matmul (``lax.ragged_dot``). The combine is a scatter-add followed by
a psum over ``model`` — which coincides with the tensor-parallel reduction the
surrounding dense layers already pay, so EP adds no extra collective steps.

Expert weights may additionally be ZeRO-sharded over the FSDP axes
(``gather_axes``); they are all-gathered just-in-time inside the shard_map
(re-gathered in backward under remat), which is what makes the 1T-param
kimi-k2 optimizer state fit.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig
from repro.models.param import ParamSpec

if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
else:
    # jax < 0.6: shard_map lives in jax.experimental and spells the
    # replication check `check_rep` instead of `check_vma`.
    from jax.experimental.shard_map import shard_map as _experimental_shard_map

    def _shard_map(f, *, mesh, in_specs, out_specs, check_vma=True):
        return _experimental_shard_map(f, mesh=mesh, in_specs=in_specs,
                                       out_specs=out_specs, check_rep=check_vma)


def moe_layout(cfg: ModelConfig, n_shards: int) -> Tuple[int, int, int, int]:
    """(e_shards, f_shards, n_local_experts, slots) for an EP domain of
    ``n_shards`` devices. Works for any (E, n): e_shards = gcd(E, n) expert
    groups of n_local_e experts; each group's FFN dim is split into f_shards
    chunks. Device i owns (group i // f_shards, chunk i % f_shards) — i.e.
    slot s maps to expert ((s // n_local_e) // f_shards) * n_local_e
    + (s % n_local_e), chunk (s // n_local_e) % f_shards. All slots on one
    device are DISTINCT experts (same chunk), so ragged_dot groups never
    overlap."""
    E = cfg.moe_num_experts
    e_shards = math.gcd(E, n_shards)
    f_shards = n_shards // e_shards
    n_local_e = E // e_shards
    slots = n_shards * n_local_e
    return e_shards, f_shards, n_local_e, slots


def moe_specs(cfg: ModelConfig, n_model: int) -> dict:
    D, E, F = cfg.d_model, cfg.moe_num_experts, cfg.moe_d_ff
    _, f_shards, _, slots = moe_layout(cfg, n_model)
    Fc = F // f_shards
    wd = cfg.weight_dtype
    assert F % f_shards == 0
    logical = ("expert_slot", "expert_embed", "expert_mlp")
    p = {
        "router": ParamSpec((D, E), (None, None), dtype=jnp.float32),
        "wg": ParamSpec((slots, D, Fc), logical, dtype=wd),
        "wu": ParamSpec((slots, D, Fc), logical, dtype=wd),
        "wd_": ParamSpec((slots, Fc, D), ("expert_slot", "expert_mlp", "expert_embed"), dtype=wd),
    }
    return p


def _capacity(n_rows_local: int, e_shards: int, cf: float) -> int:
    c = int(math.ceil(n_rows_local * cf / e_shards))
    return max(8, min(n_rows_local, (c + 7) // 8 * 8))


def _grouped_ffn(cfg, xs, wg, wu, wd_, group_sizes):
    """xs: [C, D]; wg/wu: [n_le, D, Fc]; wd_: [n_le, Fc, D]."""
    act = cfg.activation_dtype
    n_le = wg.shape[0]
    if n_le == 1:
        g = xs @ wg[0]
        u = xs @ wu[0]
        h = jax.nn.silu(g) * u
        return h @ wd_[0]
    g = jax.lax.ragged_dot(xs, wg, group_sizes)
    u = jax.lax.ragged_dot(xs, wu, group_sizes)
    h = jax.nn.silu(g) * u
    return jax.lax.ragged_dot(h, wd_, group_sizes)


def moe_apply(
    cfg: ModelConfig,
    p: dict,
    x,
    *,
    mesh,
    batch_spec,  # PartitionSpec entry for the batch dim (e.g. ("data",) or None)
    gather_axes: Tuple[str, ...] = (),  # FSDP axes to all-gather expert weights over
    model_axis: str = "model",
):
    """x: [B, S, D] -> [B, S, D]. Pure-functional; shard_map inside."""
    E, k = cfg.moe_num_experts, cfg.moe_top_k
    n_model = mesh.shape[model_axis]
    e_shards, f_shards, n_local_e, slots = moe_layout(cfg, n_model)

    x_spec = P(batch_spec, None, None)
    w_spec = P(model_axis, tuple(gather_axes) if gather_axes else None, None)
    wd_spec = P(model_axis, None, tuple(gather_axes) if gather_axes else None)
    r_spec = P(None, None)

    # rows per *device* after the data-parallel split of the batch
    def local_fn(x_local, router, wg, wu, wd_):
        B_l, S, D = x_local.shape
        act = cfg.activation_dtype
        T = B_l * S
        x_flat = x_local.reshape(T, D)

        # --- routing (replicated over model axis; fp32) ---------------------
        logits = (x_flat.astype(jnp.float32)) @ router  # [T, E]
        probs = jax.nn.softmax(logits, axis=-1)
        topw, topi = jax.lax.top_k(probs, k)  # [T, k]
        topw = topw / jnp.sum(topw, axis=-1, keepdims=True)

        # --- local selection -------------------------------------------------
        m = jax.lax.axis_index(model_axis)
        e_start = (m // f_shards) * n_local_e
        flat_e = topi.reshape(-1)  # [T*k]
        flat_w = topw.reshape(-1)
        is_local = (flat_e >= e_start) & (flat_e < e_start + n_local_e)
        sort_key = jnp.where(is_local, flat_e, E)
        order = jnp.argsort(sort_key, stable=True)
        C = _capacity(T * k, e_shards, cfg.moe_capacity_factor)
        sel = order[:C]
        sel_key = sort_key[sel]
        valid = sel_key < E
        sel_local_e = jnp.clip(sel_key - e_start, 0, n_local_e - 1)
        sel_local_e = jnp.where(valid, sel_local_e, n_local_e - 1)
        sel_tok = sel // k

        counts = jnp.bincount(sel_local_e, length=n_local_e)
        group_sizes = counts.astype(jnp.int32)

        xs = jnp.take(x_flat, sel_tok, axis=0)  # [C, D]

        # --- just-in-time ZeRO gather of expert weights ----------------------
        if gather_axes:
            wg = jax.lax.all_gather(wg, gather_axes, axis=1, tiled=True)
            wu = jax.lax.all_gather(wu, gather_axes, axis=1, tiled=True)
            wd_ = jax.lax.all_gather(wd_, gather_axes, axis=2, tiled=True)

        out_rows = _grouped_ffn(cfg, xs, wg.astype(act), wu.astype(act), wd_.astype(act),
                                group_sizes)
        w_row = (flat_w[sel] * valid).astype(out_rows.dtype)
        out_rows = out_rows * w_row[:, None]

        out = jnp.zeros((T, D), out_rows.dtype).at[sel_tok].add(out_rows)
        out = jax.lax.psum(out, model_axis)
        return out.reshape(B_l, S, D)

    fn = _shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(x_spec, r_spec, w_spec, w_spec, wd_spec),
        out_specs=x_spec,
        check_vma=False,
    )
    return fn(x, p["router"], p["wg"], p["wu"], p["wd_"])


def moe_apply_token_routed(
    cfg: ModelConfig,
    p: dict,
    x,
    *,
    mesh,
    batch_spec,  # mesh axes the batch dim is sharded over (or None)
):
    """Serve-time EP with experts RESIDENT across the whole mesh.

    A 1T-param MoE cannot replicate experts over the data axis (125 GB/device
    on a 16x16 pod) and ZeRO-gathering weights per decode step moves GBs to
    process KBs of tokens. Decode inverts the ratio: tokens are tiny, so we
    shard the (expert, ffn-chunk) slots over EVERY mesh axis (1T bf16 -> 8 GB
    resident/device), all-gather the token activations over the batch axes
    (~MBs), let each device compute the rows routed to its resident experts,
    and psum the combined output. Collective bytes per step ~ O(T_global * D),
    independent of expert count.
    """
    E, k = cfg.moe_num_experts, cfg.moe_top_k
    # EP domain: (data, model) — pods hold replicas of the expert shards and
    # serve their own batch halves (expert ranges are per (data, model) id)
    ep_axes = tuple(a for a in mesh.axis_names if a != "pod")
    ep = math.prod(mesh.shape[a] for a in ep_axes)
    e_shards, f_shards, n_local_e, slots = moe_layout(cfg, ep)
    batch_axes = () if batch_spec is None else (
        (batch_spec,) if isinstance(batch_spec, str) else tuple(batch_spec))

    x_spec = P(batch_spec, None, None)
    w_spec = P(ep_axes, None, None)
    wd_spec = P(ep_axes, None, None)

    def local_fn(x_local, router, wg, wu, wd_):
        act = cfg.activation_dtype
        if batch_axes:
            x_all = jax.lax.all_gather(x_local, batch_axes, axis=0, tiled=True)
        else:
            x_all = x_local
        B_g, S, D = x_all.shape
        T = B_g * S
        x_flat = x_all.reshape(T, D)

        logits = x_flat.astype(jnp.float32) @ router
        probs = jax.nn.softmax(logits, axis=-1)
        topw, topi = jax.lax.top_k(probs, k)
        topw = topw / jnp.sum(topw, axis=-1, keepdims=True)

        # flattened device id over the EP axes -> disjoint expert ranges
        dev = jnp.int32(0)
        for a in ep_axes:
            dev = dev * mesh.shape[a] + jax.lax.axis_index(a)
        e_start = (dev // f_shards) * n_local_e

        flat_e = topi.reshape(-1)
        flat_w = topw.reshape(-1)
        is_local = (flat_e >= e_start) & (flat_e < e_start + n_local_e)
        sort_key = jnp.where(is_local, flat_e, E)
        order = jnp.argsort(sort_key, stable=True)
        C = _capacity(T * k, e_shards, cfg.moe_capacity_factor)
        sel = order[:C]
        sel_key = sort_key[sel]
        valid = sel_key < E
        sel_local_e = jnp.where(valid, jnp.clip(sel_key - e_start, 0, n_local_e - 1),
                                n_local_e - 1)
        sel_tok = sel // k
        group_sizes = jnp.bincount(sel_local_e, length=n_local_e).astype(jnp.int32)

        xs = jnp.take(x_flat, sel_tok, axis=0)
        out_rows = _grouped_ffn(cfg, xs, wg.astype(act), wu.astype(act),
                                wd_.astype(act), group_sizes)
        w_row = (flat_w[sel] * valid).astype(out_rows.dtype)
        out = jnp.zeros((T, D), out_rows.dtype).at[sel_tok].add(out_rows * w_row[:, None])
        out = jax.lax.psum(out, ep_axes)
        out = out.reshape(B_g, S, D)
        if batch_axes:
            # back to the local batch shard
            n_b = math.prod(mesh.shape[a] for a in batch_axes)
            b_idx = jnp.int32(0)
            for a in batch_axes:
                b_idx = b_idx * mesh.shape[a] + jax.lax.axis_index(a)
            B_l = B_g // n_b
            out = jax.lax.dynamic_slice_in_dim(out, b_idx * B_l, B_l, axis=0)
        return out

    fn = _shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(x_spec, P(None, None), w_spec, w_spec, wd_spec),
        out_specs=x_spec,
        check_vma=False,
    )
    return fn(x, p["router"], p["wg"], p["wu"], p["wd_"])


def moe_aux_loss(cfg: ModelConfig, p: dict, x) -> jax.Array:
    """Switch-style load-balance loss over the global batch (fp32)."""
    E, k = cfg.moe_num_experts, cfg.moe_top_k
    x_flat = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
    logits = x_flat @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    _, topi = jax.lax.top_k(probs, k)
    onehot = jax.nn.one_hot(topi, E, dtype=jnp.float32).sum(axis=1)  # [T, E]
    frac_routed = onehot.mean(axis=0) / k
    mean_prob = probs.mean(axis=0)
    return E * jnp.sum(frac_routed * mean_prob)
