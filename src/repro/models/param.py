"""Abstract parameter specs with logical sharding axes.

Parameters are described abstractly (shape + logical axes + init scale) so that
the dry-run can build sharded ``jax.ShapeDtypeStruct`` trees without allocating,
while the real launcher materializes them with ``init_params``.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P


@dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]  # one logical axis name (or None) per dim
    init: str = "normal"  # normal | zeros | ones | ssm_a | ssm_dt
    scale: float = 1.0  # stddev multiplier for normal init
    dtype: Any = jnp.float32

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def tree_map_specs(fn, tree):
    return jax.tree.map(fn, tree, is_leaf=is_spec)


def init_param(spec: ParamSpec, key) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    if spec.init == "ssm_a":
        # A_log in [log(1), log(16)) per head (mamba2 init)
        u = jax.random.uniform(key, spec.shape, jnp.float32, 1.0, 16.0)
        return jnp.log(u).astype(spec.dtype)
    if spec.init == "ssm_dt":
        # dt bias ~ softplus^-1(U(1e-3, 1e-1))
        u = jax.random.uniform(key, spec.shape, jnp.float32, 1e-3, 1e-1)
        return jnp.log(jnp.expm1(u)).astype(spec.dtype)
    # truncated-normal fan-in init
    fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
    std = spec.scale / np.sqrt(max(1, fan_in))
    return (jax.random.truncated_normal(key, -2.0, 2.0, spec.shape, jnp.float32) * std).astype(
        spec.dtype
    )


def init_params(tree, key) -> Any:
    """Materialize a ParamSpec tree into arrays (deterministic per path)."""
    leaves, treedef = jax.tree.flatten(tree, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    vals = [init_param(s, k) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def abstract_params(tree) -> Any:
    return tree_map_specs(lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), tree)


# ---------------------------------------------------------------------------
# Logical -> physical sharding rules
# ---------------------------------------------------------------------------

Rules = Dict[str, Any]  # logical axis name -> mesh axis (str | tuple | None)


def train_rules(multi_pod: bool) -> Rules:
    fsdp = ("pod", "data") if multi_pod else ("data",)
    return {
        "embed": fsdp,  # FSDP: shard the d_model dim of weights
        "heads": "model",
        "kv_heads": "model",
        "head_dim": None,
        "mlp": "model",
        "vocab": "model",
        "expert_slot": "model",  # MoE expert(+ffn-chunk) slots
        "expert_embed": fsdp,  # ZeRO-sharded expert d_model dim (gathered in situ)
        "expert_mlp": None,
        "layers": None,
        "ssm_inner": "model",
        "ssm_heads": "model",
        "state": None,
        "conv": None,
        "batch": fsdp,
        "seq": None,
        "act_embed": None,
        "act_heads": "model",
        "kv_seq": None,
        "moe_mode": "gather",
    }


def fsdp_rules(multi_pod: bool) -> Rules:
    """Pure FSDP/ZeRO-3: batch over every axis; params stored sharded on their
    d_model dim over all axes and all-gathered per layer by GSPMD."""
    allax = ("pod", "data", "model") if multi_pod else ("data", "model")
    return {
        "embed": allax,
        "heads": None,
        "kv_heads": None,
        "head_dim": None,
        "mlp": None,
        "vocab": None,
        "expert_slot": "model",
        "expert_embed": ("pod", "data") if multi_pod else ("data",),
        "expert_mlp": None,
        "moe_mode": "gather",
        "layers": None,
        "ssm_inner": None,
        "ssm_heads": None,
        "state": None,
        "conv": None,
        "batch": allax,
        "seq": None,
        "act_embed": None,
        "act_heads": None,
        "kv_seq": None,
    }


def serve_rules(multi_pod: bool, decode_seq_shard: bool = False) -> Rules:
    """Inference: weights TP over model, replicated over data; batch over data.
    Expert weights are ZeRO-sharded over the data axes and gathered in situ
    (prefill amortizes the gather over thousands of tokens); decode switches
    to token-routed EP (make_rules flips moe_mode/expert_* below)."""
    dp = ("pod", "data") if multi_pod else ("data",)
    return {
        "embed": None,
        "heads": "model",
        "kv_heads": "model",
        "head_dim": None,
        "mlp": "model",
        "vocab": "model",
        "expert_slot": "model",
        "expert_embed": dp,
        "expert_mlp": None,
        "moe_mode": "gather",
        "layers": None,
        "ssm_inner": "model",
        "ssm_heads": "model",
        "state": None,
        "conv": None,
        "batch": dp,
        "seq": None,
        "act_embed": None,
        "act_heads": "model",
        # flash-decoding style: shard the KV cache sequence over the model axis
        "kv_seq": "model" if decode_seq_shard else None,
    }


def logical_to_spec(logical: Tuple[Optional[str], ...], rules: Rules) -> P:
    return P(*(rules.get(ax) if ax is not None else None for ax in logical))


def resolve_spec(shape: Tuple[int, ...], logical, rules: Rules, mesh) -> P:
    """Shape-aware spec: per dim, keep the longest prefix of the rule's mesh
    axes whose size product divides the dim (e.g. 8 KV heads on a 16-way model
    axis degrade to replication — the standard GQA fallback)."""
    entries = []
    for dim, ax in zip(shape, logical):
        axes = rules.get(ax) if ax is not None else None
        if axes is None:
            entries.append(None)
            continue
        axes = (axes,) if isinstance(axes, str) else tuple(axes)
        keep, prod = [], 1
        for a in axes:
            if dim % (prod * mesh.shape[a]) == 0:
                keep.append(a)
                prod *= mesh.shape[a]
            else:
                break
        entries.append(tuple(keep) if len(keep) > 1 else (keep[0] if keep else None))
    return P(*entries)


def param_pspecs(tree, rules: Rules, mesh=None):
    """PartitionSpec pytree for a ParamSpec tree."""
    if mesh is None:
        return tree_map_specs(lambda s: logical_to_spec(s.logical, rules), tree)
    return tree_map_specs(lambda s: resolve_spec(s.shape, s.logical, rules, mesh), tree)


def param_shardings(tree, mesh, rules: Rules):
    return tree_map_specs(
        lambda s: NamedSharding(mesh, resolve_spec(s.shape, s.logical, rules, mesh)), tree
    )
