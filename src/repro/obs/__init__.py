"""First-class observability for the POLCA power-plane stack (DESIGN.md §14).

The telemetry substrate the paper argues oversubscription control depends
on: ``metrics`` (counters/gauges/histograms with labels and snapshot/merge,
a ``span()`` wall-clock profiler, and a structured event log — all behind a
no-op :class:`NullRecorder` default so instrumentation never perturbs an
unobserved run), ``export`` (Prometheus text exposition, JSONL event
traces, per-run manifests under an ``--artifacts`` dir), and ``log`` (the
shared stderr stdlib-logging setup the launchers route prints through).

The hard guarantee, asserted in tier-1 tests and the observability
benchmark: recorder-on and recorder-off simulations are **bit-identical**
— observability observes, never perturbs.
"""

from repro.obs.export import (
    EVENTS_NAME,
    MANIFEST_NAME,
    METRICS_NAME,
    event_lines,
    prometheus_text,
    read_events,
    read_manifest,
    read_prometheus,
    run_manifest,
    write_artifacts,
)
from repro.obs.log import get_logger, setup_logging
from repro.obs.metrics import (
    DEFAULT_BUCKETS,
    NULL_RECORDER,
    Event,
    Histogram,
    MetricsRecorder,
    MetricsSnapshot,
    NullRecorder,
    SpanStats,
    get_recorder,
    recording,
    set_recorder,
)

__all__ = [
    "DEFAULT_BUCKETS",
    "EVENTS_NAME",
    "Event",
    "Histogram",
    "MANIFEST_NAME",
    "METRICS_NAME",
    "MetricsRecorder",
    "MetricsSnapshot",
    "NULL_RECORDER",
    "NullRecorder",
    "SpanStats",
    "event_lines",
    "get_logger",
    "get_recorder",
    "prometheus_text",
    "read_events",
    "read_manifest",
    "read_prometheus",
    "recording",
    "run_manifest",
    "set_recorder",
    "setup_logging",
    "write_artifacts",
]
