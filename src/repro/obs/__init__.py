"""First-class observability for the POLCA power-plane stack (DESIGN.md §14–15).

The telemetry substrate the paper argues oversubscription control depends
on: ``metrics`` (counters/gauges/histograms with labels and snapshot/merge,
a ``span()`` wall-clock profiler, and a structured event log — all behind a
no-op :class:`NullRecorder` default so instrumentation never perturbs an
unobserved run), ``export`` (Prometheus text exposition, JSONL event
traces, per-run manifests under an ``--artifacts`` dir), and ``log`` (the
shared stderr stdlib-logging setup the launchers route prints through).

On top of the passive recorder sits the *online* half: ``stream``
(O(1)-state windowed aggregation — P² quantile digests, EWMA slope over
the 40 s OOB horizon, tumbling/sliding windows — fed by the fleet telemetry
tick), ``alerts`` (the registered :class:`AlertSpec` rule family an
:class:`AlertEngine` evaluates per tick, with engage/release hysteresis),
and ``incidents`` (offline incident reconstruction from the exported event
trace: fault → detection → mitigation → clear timelines).

The hard guarantee, asserted in tier-1 tests and the observability
benchmark: recorder-on/off and alerts-on/off simulations are
**bit-identical** — observability observes, never perturbs.
"""

from repro.obs.alerts import (
    ALERT_BUILDERS,
    AlertEngine,
    AlertEvent,
    AlertSpec,
    coerce_alerts,
    default_alert_pack,
)
from repro.obs.export import (
    EVENTS_NAME,
    MANIFEST_NAME,
    METRICS_NAME,
    event_lines,
    prometheus_text,
    read_events,
    read_manifest,
    read_prometheus,
    run_manifest,
    write_artifacts,
)
from repro.obs.incidents import (
    INCIDENTS_NAME,
    AttributedAlert,
    Incident,
    IncidentReport,
    incidents_json,
    reconstruct_incidents,
    render_incidents_markdown,
)
from repro.obs.log import get_logger, setup_logging
from repro.obs.metrics import (
    DEFAULT_BUCKETS,
    NULL_RECORDER,
    Event,
    Histogram,
    MetricsRecorder,
    MetricsSnapshot,
    NullRecorder,
    SpanStats,
    get_recorder,
    recording,
    set_recorder,
)
from repro.obs.stream import (
    OOB_HORIZON_S,
    EwmaSlope,
    FleetStream,
    P2Quantile,
    SlidingCounter,
    TumblingWindow,
    WindowStats,
)

__all__ = [
    "ALERT_BUILDERS",
    "AlertEngine",
    "AlertEvent",
    "AlertSpec",
    "AttributedAlert",
    "DEFAULT_BUCKETS",
    "EVENTS_NAME",
    "Event",
    "EwmaSlope",
    "FleetStream",
    "Histogram",
    "INCIDENTS_NAME",
    "Incident",
    "IncidentReport",
    "MANIFEST_NAME",
    "METRICS_NAME",
    "MetricsRecorder",
    "MetricsSnapshot",
    "NULL_RECORDER",
    "NullRecorder",
    "OOB_HORIZON_S",
    "P2Quantile",
    "SlidingCounter",
    "SpanStats",
    "TumblingWindow",
    "WindowStats",
    "coerce_alerts",
    "default_alert_pack",
    "event_lines",
    "get_logger",
    "get_recorder",
    "incidents_json",
    "prometheus_text",
    "read_events",
    "read_manifest",
    "read_prometheus",
    "reconstruct_incidents",
    "recording",
    "render_incidents_markdown",
    "run_manifest",
    "set_recorder",
    "setup_logging",
    "write_artifacts",
]
