"""Shared stdlib-logging setup for the launchers (DESIGN.md §14).

One configuration point for everything under the ``repro`` logger namespace:
a stderr ``StreamHandler`` with a bare ``%(message)s`` formatter (so output
text at the default level is byte-identical to the ``print()`` calls it
replaced — only the stream moves, stdout stays clean for CSV/JSONL), and a
level taken from the ``REPRO_LOG_LEVEL`` environment variable (``DEBUG`` /
``INFO`` / ``WARNING`` / ``ERROR``; default ``INFO``).

Usage::

    from repro.obs.log import get_logger
    log = get_logger(__name__)
    log.info("arch=%s params=%.1fM", cfg.name, n_params / 1e6)
"""

from __future__ import annotations

import logging
import os
import sys

ENV_VAR = "REPRO_LOG_LEVEL"
_ROOT = "repro"
_configured = False


def setup_logging(level: str | int | None = None, *,
                  stream=None, force: bool = False) -> logging.Logger:
    """Configure the ``repro`` logger once (idempotent unless ``force``):
    stderr handler, message-only format, ``REPRO_LOG_LEVEL`` env level.
    Returns the root ``repro`` logger."""
    global _configured
    root = logging.getLogger(_ROOT)
    if _configured and not force:
        return root
    if level is None:
        level = os.environ.get(ENV_VAR, "INFO").upper()
    if isinstance(level, str):
        level = getattr(logging, level, logging.INFO)
    for h in list(root.handlers):
        root.removeHandler(h)
    handler = logging.StreamHandler(stream if stream is not None else sys.stderr)
    handler.setFormatter(logging.Formatter("%(message)s"))
    root.addHandler(handler)
    root.setLevel(level)
    root.propagate = False  # keep the global root logger out of the path
    _configured = True
    return root


def get_logger(name: str) -> logging.Logger:
    """A logger under the shared ``repro`` namespace, configuring the
    stderr handler on first use. ``name`` outside the namespace is nested
    under it (``repro.<name>``) so the one handler covers everything."""
    setup_logging()
    if name != _ROOT and not name.startswith(_ROOT + "."):
        name = f"{_ROOT}.{name}"
    return logging.getLogger(name)
