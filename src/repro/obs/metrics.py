"""Zero-perturbation metrics/span/event recording (DESIGN.md §14).

POLCA's deployment argument hinges on telemetry — the paper credits the
"stringent set of telemetry and controls" GPUs expose for making robust
oversubscription tractable — yet every signal in this reproduction used to
live in post-hoc result arrays. This module is the substrate that fixes
that: a lightweight in-process registry of **counters**, **gauges**, and
**histograms** (with labels and snapshot/merge semantics, so fork-pool
shards can record independently and reconcile), a **span** context manager
for wall-clock profiling of named stages, and a structured **event** log
(one ``(t, subsystem, kind, labels)`` record per state transition — brake
edges, rebalances, fault phases, planner probes).

The cardinal rule is that observability *observes, never perturbs*:

* instrumentation call sites are write-only — they never read recorder
  state back into control flow, never touch an RNG, and never reorder
  events — so recorder-on and recorder-off simulations are bit-identical
  (tier-1- and benchmark-asserted);
* the default recorder is a :class:`NullRecorder` whose methods are
  no-op ``pass`` bodies, so an uninstrumented run pays one dynamic global
  read plus an empty call per site (~100 ns) and nothing else;
* recorders are plain Python objects — no threads, no sockets, no global
  side effects beyond the module-level "current recorder" slot managed by
  :func:`set_recorder` / :func:`recording`.

Timestamps: simulation-domain events carry *simulation* time in ``t`` so
event traces are deterministic across runs and worker counts; wall-clock
lives only in spans (which are aggregated, and excluded from determinism
guarantees by nature).
"""

from __future__ import annotations

import time
from bisect import bisect_left
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

LabelKey = Tuple[Tuple[str, str], ...]
MetricKey = Tuple[str, LabelKey]

# Default histogram upper bounds (seconds-flavored but unit-agnostic):
# roughly geometric from 1 ms to 10 min, wide enough for queueing delays and
# span durations alike. The +inf overflow bucket is implicit.
DEFAULT_BUCKETS: Tuple[float, ...] = (
    0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5,
    1.0, 2.5, 5.0, 10.0, 30.0, 60.0, 120.0, 300.0, 600.0)


def label_key(labels: Dict[str, object]) -> LabelKey:
    """Canonical hashable form of a label set: sorted (key, str(value))
    pairs. Values are stringified once here so merge/export never depend on
    the original Python type."""
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


@dataclass
class Histogram:
    """Fixed-bucket cumulative histogram (Prometheus-style): ``counts[i]``
    tallies observations <= ``bounds[i]``, with one implicit +inf overflow
    bucket at the end. Mergeable iff the bucket bounds match."""

    bounds: Tuple[float, ...] = DEFAULT_BUCKETS
    counts: List[int] = field(default_factory=list)  # len(bounds) + 1
    sum: float = 0.0
    count: int = 0

    def __post_init__(self):
        if not self.counts:
            self.counts = [0] * (len(self.bounds) + 1)

    def observe(self, value: float) -> None:
        self.counts[bisect_left(self.bounds, value)] += 1
        self.sum += value
        self.count += 1

    def merge(self, other: "Histogram") -> None:
        if self.bounds != other.bounds:
            raise ValueError(
                f"cannot merge histograms with different bounds: "
                f"{self.bounds} vs {other.bounds}")
        for i, c in enumerate(other.counts):
            self.counts[i] += c
        self.sum += other.sum
        self.count += other.count

    def cumulative(self) -> List[int]:
        """Cumulative counts per bound (Prometheus ``_bucket`` semantics),
        overflow excluded — the +Inf bucket is ``count``."""
        out, acc = [], 0
        for c in self.counts[:-1]:
            acc += c
            out.append(acc)
        return out

    def quantile(self, q: float) -> float:
        """Approximate quantile from the buckets (upper bound of the bucket
        holding the q-th observation; +inf overflow reports the last finite
        bound). Good enough for report headlines, not for gating."""
        if self.count == 0:
            return 0.0
        rank = q * self.count
        acc = 0
        for i, c in enumerate(self.counts):
            acc += c
            if acc >= rank:
                return self.bounds[i] if i < len(self.bounds) else self.bounds[-1]
        return self.bounds[-1]


@dataclass
class SpanStats:
    """Aggregated wall-clock stats for one named stage."""

    count: int = 0
    total_s: float = 0.0
    min_s: float = 0.0
    max_s: float = 0.0

    def add(self, dt: float) -> None:
        self.min_s = dt if self.count == 0 else min(self.min_s, dt)
        self.max_s = max(self.max_s, dt)
        self.count += 1
        self.total_s += dt

    def merge(self, other: "SpanStats") -> None:
        if other.count == 0:
            return
        self.min_s = other.min_s if self.count == 0 else min(self.min_s, other.min_s)
        self.max_s = max(self.max_s, other.max_s)
        self.count += other.count
        self.total_s += other.total_s


@dataclass(frozen=True)
class Event:
    """One structured trace record: *simulation* (or logical) time ``t``,
    the emitting subsystem, an event kind, and a label dict. Events are
    kept in emission order; the JSONL exporter writes them verbatim."""

    t: float
    subsystem: str
    kind: str
    labels: LabelKey = ()

    def labels_dict(self) -> Dict[str, str]:
        return dict(self.labels)


@dataclass
class MetricsSnapshot:
    """A detached, mergeable copy of a recorder's state. ``merge`` is the
    fork-pool reconciliation primitive: counters and histograms add, gauges
    take the **max** per key (order-independent — the gauges the stack
    records are peaks/extents, so max is the only fold that makes merging
    per-member snapshots commutative; last-write-wins would depend on
    worker scheduling), spans fold their aggregates, events concatenate in
    order — so merging per-member snapshots in member order yields a
    worker-count-invariant result."""

    counters: Dict[MetricKey, float] = field(default_factory=dict)
    gauges: Dict[MetricKey, float] = field(default_factory=dict)
    hists: Dict[MetricKey, Histogram] = field(default_factory=dict)
    spans: Dict[MetricKey, SpanStats] = field(default_factory=dict)
    events: List[Event] = field(default_factory=list)

    def merge(self, other: "MetricsSnapshot") -> "MetricsSnapshot":
        for k, v in other.counters.items():
            self.counters[k] = self.counters.get(k, 0.0) + v
        for k, v in other.gauges.items():
            self.gauges[k] = v if k not in self.gauges \
                else max(self.gauges[k], v)
        for k, h in other.hists.items():
            if k in self.hists:
                self.hists[k].merge(h)
            else:
                self.hists[k] = Histogram(h.bounds, list(h.counts), h.sum, h.count)
        for k, s in other.spans.items():
            if k in self.spans:
                self.spans[k].merge(s)
            else:
                self.spans[k] = SpanStats(s.count, s.total_s, s.min_s, s.max_s)
        self.events.extend(other.events)
        return self

    @property
    def n_events(self) -> int:
        return len(self.events)

    def counter_total(self, name: str) -> float:
        """Sum of one counter across all label sets."""
        return sum(v for (n, _), v in self.counters.items() if n == name)

    def events_of(self, subsystem: Optional[str] = None,
                  kind: Optional[str] = None) -> List[Event]:
        return [e for e in self.events
                if (subsystem is None or e.subsystem == subsystem)
                and (kind is None or e.kind == kind)]


class _NullSpan:
    """Reusable no-op context manager (one shared instance, zero allocs)."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class NullRecorder:
    """The default recorder: every method is a no-op, so instrumentation
    costs one global read + one empty call per site when observability is
    off. ``enabled`` is the cheap gate for sites that would otherwise build
    labels eagerly."""

    enabled = False

    def counter(self, name: str, value: float = 1.0, **labels) -> None:
        pass

    def counter_k(self, name: str, value: float = 1.0,
                  labels: LabelKey = ()) -> None:
        pass

    def gauge(self, name: str, value: float, **labels) -> None:
        pass

    def observe(self, name: str, value: float, **labels) -> None:
        pass

    def observe_k(self, name: str, value: float,
                  labels: LabelKey = ()) -> None:
        pass

    def event(self, subsystem: str, kind: str, t: float = 0.0, **labels) -> None:
        pass

    def span(self, name: str, **labels):
        return _NULL_SPAN

    def snapshot(self) -> MetricsSnapshot:
        return MetricsSnapshot()

    def merge_snapshot(self, snap: MetricsSnapshot) -> None:
        pass


NULL_RECORDER = NullRecorder()


class _Span:
    """Wall-clock timing context for one named stage; folds into the
    recorder's per-(name, labels) :class:`SpanStats` on exit."""

    __slots__ = ("_rec", "_key", "_t0")

    def __init__(self, rec: "MetricsRecorder", key: MetricKey):
        self._rec = rec
        self._key = key

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        dt = time.perf_counter() - self._t0
        stats = self._rec.spans.get(self._key)
        if stats is None:
            stats = self._rec.spans[self._key] = SpanStats()
        stats.add(dt)
        return False


class MetricsRecorder(NullRecorder):
    """The real recorder: dict-backed registries keyed by
    ``(name, sorted-labels)``. Single-threaded by design (the whole stack
    is); fork-pool workers each get their own instance and snapshots are
    merged after the join."""

    enabled = True

    def __init__(self, hist_bounds: Sequence[float] = DEFAULT_BUCKETS):
        self.hist_bounds = tuple(hist_bounds)
        self.counters: Dict[MetricKey, float] = {}
        self.gauges: Dict[MetricKey, float] = {}
        self.hists: Dict[MetricKey, Histogram] = {}
        self.spans: Dict[MetricKey, SpanStats] = {}
        self.events: List[Event] = []

    # -- write paths ---------------------------------------------------------
    def counter(self, name: str, value: float = 1.0, **labels) -> None:
        key = (name, label_key(labels))
        self.counters[key] = self.counters.get(key, 0.0) + value

    def counter_k(self, name: str, value: float = 1.0,
                  labels: LabelKey = ()) -> None:
        """Counter with a pre-canonicalized label key (sorted
        ``(key, str-value)`` pairs) — the per-request hot-site fast path,
        skipping the kwargs build + sort + stringify of :meth:`counter`."""
        key = (name, labels)
        self.counters[key] = self.counters.get(key, 0.0) + value

    def gauge(self, name: str, value: float, **labels) -> None:
        self.gauges[(name, label_key(labels))] = float(value)

    def observe(self, name: str, value: float, **labels) -> None:
        key = (name, label_key(labels))
        h = self.hists.get(key)
        if h is None:
            h = self.hists[key] = Histogram(self.hist_bounds)
        h.observe(float(value))

    def observe_k(self, name: str, value: float,
                  labels: LabelKey = ()) -> None:
        """Histogram observation with a pre-canonicalized label key (see
        :meth:`counter_k`)."""
        key = (name, labels)
        h = self.hists.get(key)
        if h is None:
            h = self.hists[key] = Histogram(self.hist_bounds)
        h.observe(float(value))

    def event(self, subsystem: str, kind: str, t: float = 0.0, **labels) -> None:
        self.events.append(Event(float(t), subsystem, kind, label_key(labels)))

    def span(self, name: str, **labels) -> _Span:
        return _Span(self, (name, label_key(labels)))

    # -- snapshot / merge ----------------------------------------------------
    def snapshot(self) -> MetricsSnapshot:
        """A detached copy safe to pickle across a process boundary."""
        return MetricsSnapshot(
            counters=dict(self.counters),
            gauges=dict(self.gauges),
            hists={k: Histogram(h.bounds, list(h.counts), h.sum, h.count)
                   for k, h in self.hists.items()},
            spans={k: SpanStats(s.count, s.total_s, s.min_s, s.max_s)
                   for k, s in self.spans.items()},
            events=list(self.events),
        )

    def merge_snapshot(self, snap: MetricsSnapshot) -> None:
        """Fold a (worker) snapshot into this recorder, with snapshot-merge
        semantics (counters/hists add, gauges take the per-key max, events
        append in order)."""
        mine = MetricsSnapshot(self.counters, self.gauges, self.hists,
                               self.spans, self.events)
        mine.merge(snap)


# ---------------------------------------------------------------------------
# the current recorder (module-level, single slot)
# ---------------------------------------------------------------------------

_CURRENT: NullRecorder = NULL_RECORDER


def get_recorder() -> NullRecorder:
    """The currently installed recorder (the :data:`NULL_RECORDER` no-op by
    default). Instrumentation sites call this dynamically so Monte-Carlo
    shards can re-route recording per member."""
    return _CURRENT


def set_recorder(rec: Optional[NullRecorder]) -> NullRecorder:
    """Install ``rec`` (None restores the null recorder); returns the
    previously installed recorder so callers can restore it."""
    global _CURRENT
    prev = _CURRENT
    _CURRENT = rec if rec is not None else NULL_RECORDER
    return prev


@contextmanager
def recording(rec: Optional[NullRecorder]) -> Iterator[NullRecorder]:
    """Scope ``rec`` as the current recorder for the ``with`` body."""
    prev = set_recorder(rec)
    try:
        yield _CURRENT
    finally:
        set_recorder(prev)
