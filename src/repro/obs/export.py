"""Artifact export: Prometheus text exposition, JSONL event traces, and
per-run manifests (DESIGN.md §14).

Three interchange formats, written under one ``--artifacts DIR``:

* ``metrics.prom`` — Prometheus text exposition (v0.0.4) of every counter,
  gauge, histogram, and span aggregate in a :class:`MetricsSnapshot`.
  Spans export as ``<name>_seconds`` summaries (``_count``/``_sum``) plus
  ``_max``/``_min`` gauges; histograms as cumulative ``_bucket`` series.
* ``events.jsonl`` — the structured event trace, one JSON object per line
  (``ts``, ``subsystem``, ``kind``, ``labels``), in emission order with
  sorted keys — byte-deterministic for deterministic runs.
* ``manifest.json`` — what produced the artifacts: argv, seed, git sha,
  interpreter/numpy/jax versions, platform, and wall-clock. The paper-trail
  record that turns a results directory into a reproducible claim.

Parsers for all three live here too (``read_prometheus``, ``read_events``,
``read_manifest``) so ``tools/report.py`` and the tier-1 round-trip tests
share one implementation with the writers.
"""

from __future__ import annotations

import json
import os
import platform
import re
import subprocess
import sys
import time
from typing import Dict, List, Optional, TextIO, Tuple

from repro.obs.metrics import Event, LabelKey, MetricsSnapshot

MANIFEST_NAME = "manifest.json"
METRICS_NAME = "metrics.prom"
EVENTS_NAME = "events.jsonl"


# ---------------------------------------------------------------------------
# Prometheus text exposition
# ---------------------------------------------------------------------------

def _sanitize(name: str) -> str:
    """Prometheus metric-name charset: [a-zA-Z0-9_:]."""
    return "".join(c if (c.isalnum() or c in "_:") else "_" for c in name)


def _escape(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _fmt_labels(labels: LabelKey, extra: Tuple[Tuple[str, str], ...] = ()) -> str:
    pairs = tuple(labels) + tuple(extra)
    if not pairs:
        return ""
    body = ",".join(f'{_sanitize(k)}="{_escape(str(v))}"' for k, v in pairs)
    return "{" + body + "}"


def _fmt_value(v: float) -> str:
    f = float(v)
    return str(int(f)) if f == int(f) and abs(f) < 1e15 else repr(f)


def prometheus_text(snap: MetricsSnapshot) -> str:
    """The snapshot in Prometheus text exposition format, deterministically
    ordered (by metric name, then label set)."""
    lines: List[str] = []

    def emit_family(kind: str, entries: Dict, fmt) -> None:
        by_name: Dict[str, List] = {}
        for (name, labels), value in entries.items():
            by_name.setdefault(_sanitize(name), []).append((labels, value))
        for name in sorted(by_name):
            lines.append(f"# TYPE {name} {kind}")
            for labels, value in sorted(by_name[name]):
                fmt(name, labels, value)

    emit_family("counter", snap.counters,
                lambda n, l, v: lines.append(f"{n}{_fmt_labels(l)} {_fmt_value(v)}"))
    emit_family("gauge", snap.gauges,
                lambda n, l, v: lines.append(f"{n}{_fmt_labels(l)} {_fmt_value(v)}"))

    def fmt_hist(name, labels, h):
        cum = h.cumulative()
        for bound, c in zip(h.bounds, cum):
            lines.append(f"{name}_bucket{_fmt_labels(labels, (('le', repr(float(bound))),))} {c}")
        lines.append(f"{name}_bucket{_fmt_labels(labels, (('le', '+Inf'),))} {h.count}")
        lines.append(f"{name}_sum{_fmt_labels(labels)} {repr(h.sum)}")
        lines.append(f"{name}_count{_fmt_labels(labels)} {h.count}")

    emit_family("histogram", snap.hists, fmt_hist)

    def fmt_span(name, labels, s):
        lines.append(f"{name}_seconds_count{_fmt_labels(labels)} {s.count}")
        lines.append(f"{name}_seconds_sum{_fmt_labels(labels)} {repr(s.total_s)}")
        lines.append(f"{name}_seconds_min{_fmt_labels(labels)} {repr(s.min_s)}")
        lines.append(f"{name}_seconds_max{_fmt_labels(labels)} {repr(s.max_s)}")

    emit_family("summary", snap.spans, fmt_span)
    return "\n".join(lines) + ("\n" if lines else "")


def read_prometheus(path: str) -> Dict[str, Dict[str, List[Tuple[Dict[str, str], float]]]]:
    """Parse a ``metrics.prom`` file back into
    ``{type: {name: [(labels, value), ...]}}``. Minimal but sufficient for
    the files :func:`prometheus_text` writes (one metric per line, string
    label values, no exemplars)."""
    out: Dict[str, Dict[str, List[Tuple[Dict[str, str], float]]]] = {}
    types: Dict[str, str] = {}
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            if line.startswith("# TYPE "):
                _, _, name, kind = line.split(None, 3)
                types[name] = kind
                continue
            if line.startswith("#"):
                continue
            if "{" in line:
                name, rest = line.split("{", 1)
                body, value = rest.rsplit("} ", 1)
                labels = {
                    m.group(1): m.group(2).replace('\\"', '"')
                                 .replace("\\n", "\n").replace("\\\\", "\\")
                    for m in re.finditer(
                        r'([a-zA-Z0-9_:]+)="((?:[^"\\]|\\.)*)"', body)}
            else:
                name, value = line.rsplit(" ", 1)
                labels = {}
            # histogram/summary samples carry suffixed names (_bucket,
            # _sum, _seconds_count, ...) while TYPE declares the base —
            # resolve the kind via the longest declared prefix
            kind = types.get(name)
            if kind is None:
                for t_name in types:
                    if name.startswith(t_name + "_"):
                        if kind is None or len(t_name) > best:
                            kind, best = types[t_name], len(t_name)
            out.setdefault(kind or "untyped", {}).setdefault(name, []).append(
                (labels, float(value)))
    return out


# ---------------------------------------------------------------------------
# JSONL event trace
# ---------------------------------------------------------------------------

def event_lines(snap: MetricsSnapshot) -> List[str]:
    """One JSON line per event, emission order, sorted keys (deterministic
    byte-for-byte given a deterministic run)."""
    return [json.dumps({"ts": e.t, "subsystem": e.subsystem, "kind": e.kind,
                        "labels": e.labels_dict()}, sort_keys=True)
            for e in snap.events]


def write_events(snap: MetricsSnapshot, fp: TextIO) -> int:
    n = 0
    for line in event_lines(snap):
        fp.write(line + "\n")
        n += 1
    return n


def read_events(path: str) -> List[Event]:
    """Round-trip parser for ``events.jsonl``."""
    out: List[Event] = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            d = json.loads(line)
            out.append(Event(
                t=float(d["ts"]), subsystem=d["subsystem"], kind=d["kind"],
                labels=tuple(sorted((k, str(v))
                             for k, v in d.get("labels", {}).items()))))
    return out


# ---------------------------------------------------------------------------
# run manifest
# ---------------------------------------------------------------------------

def _git_sha() -> str:
    try:
        here = os.path.dirname(os.path.abspath(__file__))
        r = subprocess.run(["git", "rev-parse", "HEAD"], cwd=here,
                           capture_output=True, text=True, timeout=10)
        return r.stdout.strip() if r.returncode == 0 else "unknown"
    except (OSError, subprocess.SubprocessError):
        return "unknown"


def run_manifest(*, seed: Optional[int] = None, scenario=None,
                 argv: Optional[List[str]] = None,
                 extra: Optional[Dict] = None) -> Dict:
    """The per-run provenance record: pass ``scenario`` (a serializable
    :class:`~repro.experiments.scenario.Scenario`) to pin the exact
    experiment, ``seed`` for CLI-pinned seeds, ``extra`` for caller fields
    (wall-clock, row counts). jax is probed lazily — the power-plane stack
    runs without it."""
    import numpy as np
    try:
        import jax
        jax_version = jax.__version__
    except Exception:  # not installed / backend init failure: still record
        jax_version = None
    m: Dict = {
        "argv": list(sys.argv if argv is None else argv),
        "seed": seed,
        "git_sha": _git_sha(),
        "python": sys.version.split()[0],
        "platform": platform.platform(),
        "numpy": np.__version__,
        "jax": jax_version,
        "created_unix_s": time.time(),
    }
    if scenario is not None:
        m["scenario"] = (scenario.to_dict() if hasattr(scenario, "to_dict")
                         else str(scenario))
    if extra:
        m.update(extra)
    return m


def read_manifest(artifacts_dir: str) -> Dict:
    with open(os.path.join(artifacts_dir, MANIFEST_NAME)) as f:
        return json.load(f)


# ---------------------------------------------------------------------------
# the artifacts directory
# ---------------------------------------------------------------------------

def write_artifacts(artifacts_dir: str, snap: MetricsSnapshot,
                    manifest: Dict) -> Dict[str, str]:
    """Write ``manifest.json`` + ``metrics.prom`` + ``events.jsonl`` under
    ``artifacts_dir`` (created if needed). Returns {kind: path}."""
    os.makedirs(artifacts_dir, exist_ok=True)
    paths = {
        "manifest": os.path.join(artifacts_dir, MANIFEST_NAME),
        "metrics": os.path.join(artifacts_dir, METRICS_NAME),
        "events": os.path.join(artifacts_dir, EVENTS_NAME),
    }
    with open(paths["manifest"], "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
        f.write("\n")
    with open(paths["metrics"], "w") as f:
        f.write(prometheus_text(snap))
    with open(paths["events"], "w") as f:
        write_events(snap, f)
    return paths
