"""Online alerting over the fleet telemetry stream (DESIGN.md §15).

POLCA's deployment story is alert-driven mitigation: the control plane
watches cap proximity through a 40 s out-of-band telemetry path and reacts
before breakers do. This module is that alarm surface for the simulated
fleet: a registered, serializable rule family (:class:`AlertSpec`, carried
end-to-end on ``Scenario.alerts``) evaluated once per telemetry tick by an
:class:`AlertEngine` riding the fleet lockstep, against the streaming
window state of :class:`~repro.obs.stream.FleetStream`.

Five rule kinds are registered (``ALERT_BUILDERS`` backs
docs/registries.md exactly like the policy/router/fault registries):

* ``cap-proximity`` — a node's power fraction crosses distinct engage /
  release thresholds (hysteresis, so a fraction oscillating on one
  threshold cannot flap); optionally evaluated on the EWMA-slope value
  *projected one OOB horizon ahead*, the streaming twin of the
  controller's ``PowerForecaster``;
* ``brake-storm`` — brake edges per sliding window exceed a rate floor;
* ``slo-burn`` — shed arrivals as a fraction of offered over a sliding
  window (burn-rate alerting on the shed budget);
* ``conservation-violation`` — an interior node's budget drifts from the
  sum of its children's (watchdog; should never engage in a healthy run);
* ``fault-active`` — the chaos engine has a fault in force (ground truth,
  for measuring detection latency of the telemetry-driven rules).

Every engage/release transition appends an :class:`AlertEvent` to the
engine's log (surfaced as ``FleetResult.alert_events``) and mirrors into
the observability recorder as paired ``alert_engage`` / ``alert_release``
events — write-only, RNG-free: the engine reads fleet state and never
writes any back, so alerts-on and alerts-off runs are bit-identical
(tier-1-asserted), exactly like the recorder's own contract.
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.obs.metrics import get_recorder
from repro.obs.stream import OOB_HORIZON_S, FleetStream, SlidingCounter

#: target name for "the worst (maximum-fraction) node" in cap-proximity
ANY_NODE = "*"


@dataclass(frozen=True)
class AlertSpec:
    """One alert rule (JSON-serializable; ``Scenario.alerts`` carries a
    tuple of these). ``kind`` names an entry in ``ALERT_BUILDERS``;
    ``target`` scopes it — ``""`` is the root/site (or fleet-wide for rate
    rules), ``"*"`` the worst node, any other string a hierarchy node name
    (validated against the concrete run at bind time, like fault specs).

    Hysteresis: the rule engages after the signal holds at or above
    ``engage`` for ``for_ticks`` consecutive telemetry ticks, and releases
    after it holds *below* ``release`` for the same streak — ``engage >=
    release``, and the gap is the flap guard. ``window_s`` sizes the
    sliding window for rate rules (brake-storm, slo-burn). ``projected``
    (cap-proximity, root target only) evaluates the EWMA-slope projection
    one OOB actuation horizon (40 s) ahead instead of the instantaneous
    fraction."""

    kind: str
    target: str = ""
    engage: float = 1.0
    release: float = 0.9
    window_s: float = 60.0
    for_ticks: int = 1
    projected: bool = False
    name: str = ""

    def __post_init__(self):
        try:
            builder = ALERT_BUILDERS[self.kind]
        except KeyError:
            known = ", ".join(sorted(ALERT_BUILDERS))
            raise ValueError(
                f"invalid alert spec: unknown kind {self.kind!r} "
                f"(registered: {known})") from None
        if not self.name:
            auto = self.kind + (f":{self.target}" if self.target else "")
            object.__setattr__(self, "name", auto)
        _require(math.isfinite(self.engage) and math.isfinite(self.release),
                 self, "engage/release must be finite")
        _require(self.engage >= self.release, self,
                 "engage must be >= release (the hysteresis band)")
        _require(self.window_s > 0.0, self, "window_s must be positive")
        _require(int(self.for_ticks) >= 1, self, "for_ticks must be >= 1")
        builder.check(self)

    def describe(self) -> str:
        txt = (f"{self.kind}(target={self.target or '<root>'}, "
               f"engage={self.engage:g}, release={self.release:g}")
        if self.projected:
            txt += ", projected"
        return txt + ")"

    # -- serialization -------------------------------------------------------
    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d) -> "AlertSpec":
        return d if isinstance(d, AlertSpec) else cls(**d)


def _require(cond: bool, spec, why: str) -> None:
    if not cond:
        what = spec.describe() if hasattr(spec, "describe") else repr(spec)
        raise ValueError(f"invalid alert spec {what}: {why}")


# ---------------------------------------------------------------------------
# registry: one marker class per rule kind — docstrings feed the registry
# reference (docs/registries.md), ``check`` the structural validation,
# exactly like FAULT_EVENT_BUILDERS.
# ---------------------------------------------------------------------------

class CapProximity:
    """A node's power fraction crosses engage/release thresholds with hysteresis; target a node name, the root (``""``), or the worst node (``"*"``) — optionally on the 40 s OOB-horizon EWMA projection instead of the instantaneous value."""

    @staticmethod
    def check(spec: AlertSpec) -> None:
        _require(spec.engage > 0.0, spec,
                 "cap-proximity engage must be a positive power fraction")
        _require(not spec.projected or spec.target == "", spec,
                 "projected cap-proximity tracks the root slope only — "
                 "use target=\"\"")


class BrakeStorm:
    """Brake edges (engage or release, any row) per sliding window exceed a rate floor — the thrash detector for controllers fighting their own actuation delay."""

    @staticmethod
    def check(spec: AlertSpec) -> None:
        _require(spec.target == "", spec,
                 "brake-storm is fleet-wide; leave target empty")
        _require(spec.release >= 0.0, spec,
                 "brake-storm thresholds are edge counts, must be >= 0")


class SloBurn:
    """Shed arrivals as a fraction of offered arrivals over a sliding window — burn-rate alerting on the shed budget (engages only once real traffic was offered in the window)."""

    @staticmethod
    def check(spec: AlertSpec) -> None:
        _require(spec.target == "", spec,
                 "slo-burn is fleet-wide; leave target empty")
        _require(0.0 <= spec.release and spec.engage <= 1.0, spec,
                 "slo-burn thresholds are shed fractions in [0, 1]")


class ConservationViolation:
    """An interior node's budget drifts from the sum of its children's by more than ``engage`` watts — the invariant watchdog (a healthy run never engages it; chaos derates and rebalances both preserve conservation)."""

    @staticmethod
    def check(spec: AlertSpec) -> None:
        _require(spec.target != ANY_NODE, spec,
                 "conservation-violation targets a node name or \"\" "
                 "(= every interior node)")
        _require(spec.engage > 0.0, spec,
                 "engage is a watts tolerance, must be positive")


class FaultActive:
    """The chaos engine has a fault in force (a fenced row, or a derate applied and not yet restored) — ground truth, the yardstick detection latency of the telemetry-driven rules is measured against."""

    @staticmethod
    def check(spec: AlertSpec) -> None:
        _require(spec.target == "", spec,
                 "fault-active is fleet-wide; leave target empty")
        _require(spec.release >= 0.0, spec,
                 "fault-active thresholds are fault counts, must be >= 0")


ALERT_BUILDERS: Dict[str, type] = {
    "cap-proximity": CapProximity,
    "brake-storm": BrakeStorm,
    "slo-burn": SloBurn,
    "conservation-violation": ConservationViolation,
    "fault-active": FaultActive,
}


def coerce_alerts(alerts) -> Optional[Tuple[AlertSpec, ...]]:
    """Normalize ``Scenario.alerts`` input: None stays None; an iterable of
    AlertSpec / dicts becomes a tuple of AlertSpec."""
    if alerts is None:
        return None
    return tuple(AlertSpec.from_dict(a) for a in alerts)


def default_alert_pack() -> Tuple[AlertSpec, ...]:
    """The standing rule set the ``chaos-*`` scenarios carry: cap
    proximity on the fault-domain PDU, the worst node, and the projected
    site envelope; a brake-storm rate floor; slo-burn on shed traffic; the
    conservation watchdog; and the fault-active ground truth.

    Thresholds are tuned to the chaos family's operating point (105 kW
    rows, (2, 2, 3) site — the ``pdu0`` target binds only on hierarchies
    that have one): healthy steady state never crosses them (zero false
    alarms on ``chaos-noop``, benchmark-gated: its interior nodes stay
    under 0.87 of budget, its brake-edge rate under 8/120 s), while the
    30% PDU derate crosses cap-proximity within one telemetry tick of
    landing (the fraction jumps past 1.0 on a step; a ramp is caught just
    before its apply record as the fraction passes 0.96)."""
    return (
        AlertSpec("cap-proximity", target="pdu0", engage=0.96,
                  release=0.90),
        AlertSpec("cap-proximity", target=ANY_NODE, engage=1.10,
                  release=1.02),
        AlertSpec("cap-proximity", target="", engage=0.92, release=0.85,
                  projected=True, name="cap-proximity:site-projected"),
        AlertSpec("brake-storm", engage=10.0, release=2.0, window_s=120.0),
        AlertSpec("slo-burn", engage=0.05, release=0.005, window_s=300.0),
        AlertSpec("conservation-violation", engage=1.0, release=0.5),
        AlertSpec("fault-active", engage=0.5, release=0.5),
    )


@dataclass(frozen=True)
class AlertEvent:
    """One engage/release transition in the engine's audit log
    (``FleetResult.alert_events``): when, which rule, which phase, the
    signal value that crossed, and the threshold it crossed."""

    t: float
    name: str
    kind: str
    target: str
    phase: str  # "engage" | "release"
    value: float
    threshold: float


class _RuleState:
    """Mutable runtime state for one rule: hysteresis streaks, active
    flag, resolved node index, any sliding counters it owns, and the
    integer opcode ``bind`` resolves for per-tick signal dispatch."""

    __slots__ = ("spec", "node", "active", "streak", "t_engaged",
                 "edges", "shed", "offered", "op")

    def __init__(self, spec: AlertSpec):
        self.spec = spec
        self.node: Optional[int] = None
        self.active = False
        self.streak = 0
        self.t_engaged = 0.0
        self.edges: Optional[SlidingCounter] = None
        self.shed: Optional[SlidingCounter] = None
        self.offered: Optional[SlidingCounter] = None
        self.op = -1


# signal opcodes, resolved once at bind so the per-tick dispatch is an
# integer compare chain instead of repeated string equality
_OP_CAP_NODE = 0
_OP_CAP_ANY = 1
_OP_CAP_PROJ = 2
_OP_BRAKE = 3
_OP_SLO = 4
_OP_CONS_NODE = 5
_OP_CONS_ALL = 6
_OP_FAULT = 7


class AlertEngine:
    """Evaluates a rule set once per fleet telemetry tick.

    One engine drives one fleet: the fleet constructor calls :meth:`bind`
    (validating node targets against the concrete hierarchy, like
    ``ChaosInjector.bind``), then :meth:`on_tick` fires after the
    controller and chaos passes with the tick's already-sampled telemetry.
    The engine computes node fractions from the same sampled vectors
    ``FleetResult.node_power_frac`` folds — via one precomputed
    descendant-aggregation matmul, so per-node values agree with the
    offline result arrays to float round-off (the default pack's
    thresholds sit orders of magnitude above that).

    Strictly read-only against the simulation: signals come from sampled
    arrays and read-only scans; output goes to :attr:`events` and the
    current recorder. No RNG, no writes into rows/hierarchy/router state.
    """

    def __init__(self, specs: Sequence[AlertSpec], *, tick_s: float,
                 horizon_s: float = OOB_HORIZON_S):
        self.specs: Tuple[AlertSpec, ...] = tuple(specs)
        names = [s.name for s in self.specs]
        dup = {n for n in names if names.count(n) > 1}
        if dup:
            raise ValueError(f"duplicate alert names: {sorted(dup)} — "
                             f"set AlertSpec.name to disambiguate")
        # rates + root slope only: per-node tumbling windows are a stream
        # feature no rule consumes, and the engine must stay cheap per tick
        self.stream = FleetStream(tick_s, horizon_s=horizon_s,
                                  window_nodes=())
        self.events: List[AlertEvent] = []
        self._rules = [_RuleState(s) for s in self.specs]
        self._bound = False
        # per-tick work gates, resolved at bind from the rule set
        self._need_cons = any(s.kind == "conservation-violation"
                              for s in self.specs)
        self._need_faults = any(s.kind == "fault-active" for s in self.specs)
        self._track_queues = False  # no registered rule reads queue ages yet
        self._child_mat: Optional[np.ndarray] = None
        self._cons_buf: Optional[np.ndarray] = None
        self._empty_errs = np.zeros(0)
        # bind() fills these: per-tick scratch buffers + the (nodes x
        # leaves) aggregation matrix (the engine runs once per telemetry
        # tick on the hot path — no per-tick allocations beyond what
        # numpy reductions need)
        self._agg: Optional[np.ndarray] = None
        self._budget_buf: Optional[np.ndarray] = None
        self._node_w_buf: Optional[np.ndarray] = None
        self._frac_buf: Optional[np.ndarray] = None

    @property
    def n_active(self) -> int:
        return sum(1 for r in self._rules if r.active)

    def bind(self, fleet) -> None:
        """Resolve node targets against the fleet's hierarchy and size the
        per-rule sliding windows. Raises ``ValueError`` naming any rule
        whose target is not a node of this run."""
        h = fleet.hierarchy
        name_to_idx = {n: i for i, n in enumerate(h.names)}
        for r in self._rules:
            s = r.spec
            if s.kind == "cap-proximity":
                if s.target == "":
                    r.node = h.root
                elif s.target != ANY_NODE:
                    if s.target not in name_to_idx:
                        raise ValueError(
                            f"alert {s.describe()}: no hierarchy node named "
                            f"{s.target!r} (known: {sorted(h.names)})")
                    r.node = name_to_idx[s.target]
            elif s.kind == "conservation-violation" and s.target:
                idx = name_to_idx.get(s.target)
                if idx is None or idx < h.n_leaves:
                    raise ValueError(
                        f"alert {s.describe()}: target must name an "
                        f"interior node of this run "
                        f"(interior: {sorted(h.names[h.n_leaves:])})")
                r.node = idx
            elif s.kind == "brake-storm":
                r.edges = self.stream.sliding("brake_edges", s.window_s)
            elif s.kind == "slo-burn":
                r.shed = self.stream.sliding("shed", s.window_s)
                r.offered = self.stream.sliding("offered", s.window_s)
        if self._need_cons:
            # one (interior x nodes) child-sum matrix: the per-tick
            # conservation check becomes a single small matmul
            n_int = h.n_nodes - h.n_leaves
            mat = np.zeros((n_int, h.n_nodes))
            for i in range(h.n_leaves, h.n_nodes):
                mat[i - h.n_leaves, h.children[i]] = 1.0
            self._child_mat = mat
            self._cons_buf = np.empty(n_int)
        # aggregation matrix + scratch: node watts = agg @ row watts (leaf
        # rows are an identity block, interiors sum their leaf
        # descendants). One matmul per tick replaces a Python loop of
        # per-node reductions; values agree with Hierarchy.fold_w to
        # float round-off.
        agg = np.zeros((h.n_nodes, h.n_leaves))
        agg[:h.n_leaves, :h.n_leaves] = np.eye(h.n_leaves)
        for i in range(h.n_leaves, h.n_nodes):
            agg[i, h.leaf_desc[i]] = 1.0
        self._agg = agg
        self._budget_buf = np.empty(h.n_nodes)
        self._node_w_buf = np.empty(h.n_nodes)
        self._frac_buf = np.empty(h.n_nodes)
        # double-buffered brake flags: the stream keeps a reference to the
        # previous tick's vector for edge detection, so alternate buffers
        self._braked_bufs = (np.empty(h.n_leaves, dtype=bool),
                             np.empty(h.n_leaves, dtype=bool))
        self._braked_flip = 0
        # resolve signal opcodes now that node targets are resolved
        for r in self._rules:
            s = r.spec
            if s.kind == "cap-proximity":
                r.op = (_OP_CAP_PROJ if s.projected
                        else _OP_CAP_ANY if r.node is None and
                        s.target == ANY_NODE else _OP_CAP_NODE)
            elif s.kind == "brake-storm":
                r.op = _OP_BRAKE
            elif s.kind == "slo-burn":
                r.op = _OP_SLO
            elif s.kind == "conservation-violation":
                r.op = _OP_CONS_NODE if r.node is not None else _OP_CONS_ALL
            else:
                r.op = _OP_FAULT
        self._bound = True

    # -- tick hook -----------------------------------------------------------
    def on_tick(self, t: float, fleet, row_w: np.ndarray,
                leaf_budget_w: np.ndarray,
                interior_budget_w: np.ndarray) -> None:
        """Fold this tick into the stream and evaluate every rule.

        ``row_w`` / ``leaf_budget_w`` / ``interior_budget_w`` are the
        arrays the fleet driver just sampled (pre-controller budgets — the
        same vectors ``finalize()`` measures fractions against), so the
        engine adds no pass over history and no new reads of mutable
        control-plane state beyond the chaos/brake flags it scans."""
        assert self._bound, "AlertEngine.on_tick before bind"
        h = fleet.hierarchy
        nl = h.n_leaves
        budget = self._budget_buf
        budget[:nl] = leaf_budget_w
        budget[nl:] = interior_budget_w
        # the per-tick fold FleetResult.node_power_frac records, as one
        # matmul into reused scratch (round-off-equivalent to fold_w)
        node_w = self._node_w_buf
        np.matmul(self._agg, row_w, out=node_w)
        node_frac = np.divide(node_w, budget, out=self._frac_buf)
        braked = self._braked_bufs[self._braked_flip]
        self._braked_flip ^= 1
        for j, row in enumerate(fleet.rows):
            braked[j] = getattr(row.policy, "braked", False)
        queue_depth, max_age = (_queue_state(fleet.rows, t)
                                if self._track_queues else (0, None))
        self.stream.observe(
            t, node_frac, braked,
            shed_total=sum(fleet.n_shed.values()),
            offered_total=fleet.n_processed,
            queue_depth=queue_depth, max_queue_age_s=max_age)
        if self._need_cons:
            cons_err = self._cons_buf
            np.matmul(self._child_mat, budget, out=cons_err)
            np.subtract(budget[nl:], cons_err, out=cons_err)
            np.abs(cons_err, out=cons_err)
        else:
            cons_err = self._empty_errs
        faults = _faults_in_force(fleet) if self._need_faults else 0
        for r in self._rules:
            v = self._signal(r, node_frac, cons_err, faults)
            self._step(r, t, v)

    def _signal(self, r: _RuleState, node_frac: np.ndarray,
                cons_err: np.ndarray, faults: int) -> float:
        op = r.op
        if op == _OP_CAP_NODE:
            return float(node_frac[r.node])
        if op == _OP_CAP_ANY:
            return float(node_frac.max())
        if op == _OP_CAP_PROJ:
            v = self.stream.projected_root_frac()
            return v if not math.isnan(v) else float(node_frac[-1])
        if op == _OP_BRAKE:
            return r.edges.total
        if op == _OP_SLO:
            offered = r.offered.total
            return r.shed.total / offered if offered > 0.0 else 0.0
        if op == _OP_CONS_NODE:
            h0 = len(node_frac) - len(cons_err)
            return float(cons_err[r.node - h0])
        if op == _OP_CONS_ALL:
            return float(cons_err.max()) if len(cons_err) else 0.0
        if op == _OP_FAULT:
            return float(faults)
        raise AssertionError(f"unreachable: {r.spec.kind}")  # bind-resolved

    def _step(self, r: _RuleState, t: float, v: float) -> None:
        s = r.spec
        if not r.active:
            r.streak = r.streak + 1 if v >= s.engage else 0
            if r.streak >= s.for_ticks:
                r.active, r.streak, r.t_engaged = True, 0, t
                self._emit(r, t, "engage", v, s.engage)
        else:
            r.streak = r.streak + 1 if v < s.release else 0
            if r.streak >= s.for_ticks:
                r.active, r.streak = False, 0
                self._emit(r, t, "release", v, s.release)

    def _emit(self, r: _RuleState, t: float, phase: str, v: float,
              threshold: float) -> None:
        s = r.spec
        self.events.append(AlertEvent(
            t=t, name=s.name, kind=s.kind, target=s.target, phase=phase,
            value=float(v), threshold=float(threshold)))
        rec = get_recorder()
        if rec.enabled:
            labels = dict(alert=s.name, rule=s.kind, target=s.target or "-",
                          value=round(float(v), 6),
                          threshold=round(float(threshold), 6))
            if phase == "release":
                labels["engaged_s"] = round(t - r.t_engaged, 6)
            rec.event("alert", f"alert_{phase}", t=t, **labels)
            rec.counter("alert_transitions_total", kind=s.kind, phase=phase)


def _queue_state(rows, t: float) -> Tuple[int, float]:
    """Total queued requests and the oldest queued request's age — a pure
    read over server pools (ages are relative to the tick time, so this
    scan is deterministic and run-order-free)."""
    depth, oldest = 0, 0.0
    for row in rows:
        for srv in row.servers:
            q = srv.queue
            if q:
                depth += len(q)
                age = t - q[0].t_arrival
                if age > oldest:
                    oldest = age
    return depth, oldest


def _faults_in_force(fleet) -> int:
    """Ground-truth active fault count: fenced rows plus chaos derates
    that have started (ramping counts) and not yet restored."""
    alive = fleet.row_alive
    n = alive.size - int(np.count_nonzero(alive))
    chaos = fleet.chaos
    if chaos is not None:
        n += chaos.n_active_derates()
    return n
