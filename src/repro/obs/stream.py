"""Streaming window aggregation riding the telemetry tick (DESIGN.md §15).

POLCA's control plane is alert-driven: detect cap proximity under a 40 s
out-of-band actuation delay, then mitigate. The recorder (``obs.metrics``)
is the *passive* half — it remembers everything and reads nothing. This
module is the online half's substrate: windowed aggregates over the fleet's
telemetry tick stream that an alerting engine (``obs.alerts``) can evaluate
*during* the run, with strictly bounded state:

* :class:`P2Quantile` — the P² (Jain & Chlamtac) online quantile estimator:
  five markers, O(1) memory and O(1) per observation, no sample buffer;
* :class:`EwmaSlope` — Holt-style double exponential smoothing (EWMA level
  + EWMA trend) whose :meth:`~EwmaSlope.projected` value looks exactly one
  OOB actuation horizon ahead (40 s, the same horizon
  :class:`~repro.fleet.controller.PowerForecaster` forecasts over) — the
  streaming analogue of the controller's least-squares extrapolation;
* :class:`TumblingWindow` — fixed-width aligned windows with running
  count/mean/min/max and one P² digest per requested quantile; closing a
  window emits an immutable :class:`WindowStats`;
* :class:`SlidingCounter` — a ring buffer of per-tick increments giving an
  O(1)-per-tick rolling sum over the trailing window (rates: brake edges
  per minute, shed per offered);
* :class:`FleetStream` — the composite the fleet tick feeds once per
  telemetry sample: per-node power-fraction windows, the root-envelope
  EWMA slope, brake/shed/offered sliding channels, and a queue-age window.

Everything here is plain arithmetic over values the caller already holds:
no RNG, no recorder reads, no extra passes over history — feeding a stream
cannot perturb a simulation (the alerts-on/off bit-parity contract in
``tests/test_alerts.py`` rides on that).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

#: The paper's out-of-band telemetry/actuation latency (Table 1) — the
#: horizon `EwmaSlope.projected` looks ahead by default, matching
#: ``PowerForecaster(horizon_s=...)`` so streaming detection and controller
#: actuation reason about the same future instant.
OOB_HORIZON_S = 40.0


class P2Quantile:
    """The P² algorithm: estimate one quantile online with five markers.

    Exact for the first five observations, then maintains marker heights by
    piecewise-parabolic interpolation — O(1) state, O(1) per observation,
    no buffer. Deterministic: same observation sequence, same estimate.
    """

    __slots__ = ("q", "n", "_h", "_pos", "_des", "_inc")

    def __init__(self, q: float):
        if not (0.0 < q < 1.0):
            raise ValueError(f"quantile must be in (0, 1), got {q}")
        self.q = float(q)
        self.n = 0
        self._h: List[float] = []  # marker heights (first 5 obs, sorted)
        self._pos: List[float] = []
        self._des: List[float] = []
        self._inc: List[float] = []

    def observe(self, x: float) -> None:
        x = float(x)
        self.n += 1
        if self.n <= 5:
            # exact phase: keep the sorted sample
            lo, hi = 0, len(self._h)
            while lo < hi:
                mid = (lo + hi) // 2
                if self._h[mid] < x:
                    lo = mid + 1
                else:
                    hi = mid
            self._h.insert(lo, x)
            if self.n == 5:
                q = self.q
                self._pos = [1.0, 2.0, 3.0, 4.0, 5.0]
                self._des = [1.0, 1.0 + 2.0 * q, 1.0 + 4.0 * q,
                             3.0 + 2.0 * q, 5.0]
                self._inc = [0.0, q / 2.0, q, (1.0 + q) / 2.0, 1.0]
            return
        h, pos = self._h, self._pos
        # locate the cell (extending the extremes when x falls outside)
        if x < h[0]:
            h[0] = x
            k = 0
        elif x >= h[4]:
            h[4] = x
            k = 3
        else:
            k = 0
            while k < 3 and not (h[k] <= x < h[k + 1]):
                k += 1
        for i in range(k + 1, 5):
            pos[i] += 1.0
        for i in range(5):
            self._des[i] += self._inc[i]
        # adjust the three interior markers toward their desired positions
        for i in (1, 2, 3):
            d = self._des[i] - pos[i]
            if ((d >= 1.0 and pos[i + 1] - pos[i] > 1.0)
                    or (d <= -1.0 and pos[i - 1] - pos[i] < -1.0)):
                d = 1.0 if d > 0 else -1.0
                hp = self._parabolic(i, d)
                if not (h[i - 1] < hp < h[i + 1]):
                    hp = h[i] + d * ((h[i + int(d)] - h[i])
                                     / (pos[i + int(d)] - pos[i]))
                h[i] = hp
                pos[i] += d

    def _parabolic(self, i: int, d: float) -> float:
        h, pos = self._h, self._pos
        return h[i] + d / (pos[i + 1] - pos[i - 1]) * (
            (pos[i] - pos[i - 1] + d) * (h[i + 1] - h[i])
            / (pos[i + 1] - pos[i])
            + (pos[i + 1] - pos[i] - d) * (h[i] - h[i - 1])
            / (pos[i] - pos[i - 1]))

    def value(self) -> float:
        """The current estimate (NaN with no observations; exact while
        n <= 5)."""
        if self.n == 0:
            return math.nan
        if self.n <= 5:
            idx = min(len(self._h) - 1,
                      max(0, int(math.ceil(self.q * self.n)) - 1))
            return self._h[idx]
        return self._h[2]


class EwmaSlope:
    """Holt-style double exponential smoothing over an irregular tick
    stream: an EWMA level plus an EWMA trend (per-second slope), projected
    one OOB actuation horizon ahead. O(1) state; deterministic."""

    __slots__ = ("horizon_s", "alpha", "beta", "level", "slope", "_t_prev")

    def __init__(self, *, horizon_s: float = OOB_HORIZON_S,
                 alpha: float = 0.3, beta: float = 0.1):
        if not (0.0 < alpha <= 1.0 and 0.0 < beta <= 1.0):
            raise ValueError("alpha/beta must be in (0, 1]")
        self.horizon_s = float(horizon_s)
        self.alpha = float(alpha)
        self.beta = float(beta)
        self.level: Optional[float] = None
        self.slope = 0.0  # per second
        self._t_prev: Optional[float] = None

    def observe(self, t: float, x: float) -> None:
        t, x = float(t), float(x)
        if self.level is None:
            self.level, self._t_prev = x, t
            return
        dt = t - self._t_prev
        if dt <= 0.0:
            return  # duplicate tick: nothing to extrapolate over
        self._t_prev = t
        prev = self.level
        self.level = (self.alpha * x
                      + (1.0 - self.alpha) * (prev + self.slope * dt))
        inst = (self.level - prev) / dt
        self.slope = self.beta * inst + (1.0 - self.beta) * self.slope

    def projected(self, horizon_s: Optional[float] = None) -> float:
        """Level extrapolated ``horizon_s`` (default: the OOB horizon)
        seconds ahead — NaN until the first observation."""
        if self.level is None:
            return math.nan
        h = self.horizon_s if horizon_s is None else float(horizon_s)
        return self.level + self.slope * h


@dataclass(frozen=True)
class WindowStats:
    """One closed window's aggregates: span, count, running moments, and
    the P² quantile estimates that were live when the window rolled."""

    t_start: float
    t_end: float
    count: int
    mean: float
    minimum: float
    maximum: float
    quantiles: Tuple[Tuple[float, float], ...] = ()  # (q, estimate)

    def quantile(self, q: float) -> float:
        for qq, v in self.quantiles:
            if qq == q:
                return v
        raise KeyError(f"window has no q={q} digest "
                       f"(tracked: {[qq for qq, _ in self.quantiles]})")


class TumblingWindow:
    """Fixed-width windows aligned to multiples of ``width_s``: running
    count/sum/min/max plus one :class:`P2Quantile` per requested quantile.
    ``observe`` returns the just-closed :class:`WindowStats` when the
    observation lands in a new window, else ``None``; the most recent
    closed window stays readable at :attr:`last`."""

    __slots__ = ("width_s", "qs", "last", "_k", "_count", "_sum", "_min",
                 "_max", "_digests")

    def __init__(self, width_s: float, quantiles: Sequence[float] = (0.5, 0.99)):
        if width_s <= 0.0:
            raise ValueError(f"window width must be positive, got {width_s}")
        self.width_s = float(width_s)
        self.qs = tuple(float(q) for q in quantiles)
        self.last: Optional[WindowStats] = None
        self._k: Optional[int] = None
        self._reset()

    def _reset(self) -> None:
        self._count = 0
        self._sum = 0.0
        self._min = math.inf
        self._max = -math.inf
        self._digests = [P2Quantile(q) for q in self.qs]

    def _close(self) -> WindowStats:
        k = self._k
        return WindowStats(
            t_start=k * self.width_s,
            t_end=(k + 1) * self.width_s,
            count=self._count,
            mean=self._sum / self._count if self._count else math.nan,
            minimum=self._min if self._count else math.nan,
            maximum=self._max if self._count else math.nan,
            quantiles=tuple((q, d.value())
                            for q, d in zip(self.qs, self._digests)),
        )

    def observe(self, t: float, x: float) -> Optional[WindowStats]:
        k = int(math.floor(float(t) / self.width_s))
        closed = None
        if self._k is None:
            self._k = k
        elif k != self._k:
            closed = self.last = self._close()
            self._k = k
            self._reset()
        x = float(x)
        self._count += 1
        self._sum += x
        self._min = min(self._min, x)
        self._max = max(self._max, x)
        for d in self._digests:
            d.observe(x)
        return closed

    @property
    def live_count(self) -> int:
        """Observations in the currently-open window."""
        return self._count


class SlidingCounter:
    """Rolling sum of per-tick increments over the trailing ``width_s``
    seconds: a fixed ring of ``round(width_s / tick_s)`` slots, one
    :meth:`push` per telemetry tick, O(1) each. ``total`` is the windowed
    sum; ``filled`` says whether a full window has elapsed yet."""

    __slots__ = ("n_slots", "_ring", "_idx", "_pushed", "total")

    def __init__(self, width_s: float, tick_s: float):
        if width_s <= 0.0 or tick_s <= 0.0:
            raise ValueError("width_s and tick_s must be positive")
        self.n_slots = max(1, int(round(width_s / tick_s)))
        self._ring = [0.0] * self.n_slots
        self._idx = 0
        self._pushed = 0
        self.total = 0.0

    def push(self, x: float) -> None:
        x = float(x)
        self.total += x - self._ring[self._idx]
        self._ring[self._idx] = x
        self._idx = (self._idx + 1) % self.n_slots
        self._pushed += 1

    @property
    def filled(self) -> bool:
        return self._pushed >= self.n_slots


class FleetStream:
    """The fleet's per-tick streaming aggregate, fed once per telemetry
    sample by :meth:`observe` with values the fleet driver already computed
    (no extra passes over history, no recorder reads, no RNG):

    * latest per-node power fractions + one :class:`TumblingWindow` with a
      P² digest per tracked node (``window_nodes``; default every node — a
      caller that only consumes instantaneous fractions and rate channels,
      like the alert engine, passes ``()`` and pays nothing per tick);
    * :class:`EwmaSlope` on the root (site) fraction, projected over the
      OOB horizon;
    * per-tick deltas for brake edges / shed / offered, fanned into any
      registered :class:`SlidingCounter` channels (rules size their own
      windows via :meth:`sliding`);
    * a queue-age tumbling window over the oldest queued request's age.

    State is O(tracked nodes + registered windows), independent of run
    length.
    """

    CHANNELS = ("brake_edges", "shed", "offered")

    def __init__(self, tick_s: float, *, window_s: float = 60.0,
                 horizon_s: float = OOB_HORIZON_S,
                 quantiles: Sequence[float] = (0.5, 0.99),
                 window_nodes: Optional[Sequence[int]] = None):
        self.tick_s = float(tick_s)
        self.window_s = float(window_s)
        self.quantiles = tuple(quantiles)
        self.window_nodes = (None if window_nodes is None
                             else tuple(int(i) for i in window_nodes))
        self.t: Optional[float] = None
        self.n_ticks = 0
        self.node_frac: Optional[np.ndarray] = None  # latest [N]
        self.braked: Optional[np.ndarray] = None  # latest [R] bool
        self.queue_depth = 0
        self.root_slope = EwmaSlope(horizon_s=horizon_s)
        self.queue_age = TumblingWindow(self.window_s, self.quantiles)
        self.node_windows: Dict[int, TumblingWindow] = {}
        # per-tick deltas of the most recent observe() call
        self.brake_edges_tick = 0
        self.shed_tick = 0
        self.offered_tick = 0
        self._prev_braked: Optional[np.ndarray] = None
        self._prev_shed = 0
        self._prev_offered = 0
        self._sliding: Dict[str, List[SlidingCounter]] = {
            c: [] for c in self.CHANNELS}

    def sliding(self, channel: str, width_s: float) -> SlidingCounter:
        """Register (and return) a sliding window over one per-tick delta
        channel (``brake_edges`` / ``shed`` / ``offered``); the stream
        pushes into it on every subsequent tick."""
        if channel not in self._sliding:
            raise KeyError(f"unknown stream channel {channel!r} "
                           f"(known: {sorted(self._sliding)})")
        c = SlidingCounter(width_s, self.tick_s)
        self._sliding[channel].append(c)
        return c

    def observe(self, t: float, node_frac: np.ndarray, braked: np.ndarray,
                shed_total: int, offered_total: int, queue_depth: int = 0,
                max_queue_age_s: Optional[float] = None) -> None:
        """Fold one telemetry tick into every window. ``node_frac`` is the
        full leaves-first node power-fraction vector (root last) measured
        against the budgets in force this tick — exactly the per-tick rows
        of ``FleetResult.node_power_frac``. ``max_queue_age_s=None`` skips
        the queue-age window (callers that don't scan queues pay
        nothing)."""
        self.t = float(t)
        self.n_ticks += 1
        self.node_frac = node_frac
        self.braked = braked
        self.queue_depth = int(queue_depth)
        if self.window_nodes is None or self.window_nodes:
            idxs = (range(len(node_frac)) if self.window_nodes is None
                    else (i if i >= 0 else len(node_frac) + i
                          for i in self.window_nodes))
            for i in idxs:
                w = self.node_windows.get(i)
                if w is None:
                    w = self.node_windows[i] = TumblingWindow(
                        self.window_s, self.quantiles)
                w.observe(t, float(node_frac[i]))
        self.root_slope.observe(t, float(node_frac[-1]))
        if max_queue_age_s is not None:
            self.queue_age.observe(t, float(max_queue_age_s))
        if self._prev_braked is None:
            self.brake_edges_tick = int(np.count_nonzero(braked))
        else:
            self.brake_edges_tick = int(
                np.count_nonzero(braked != self._prev_braked))
        self._prev_braked = braked
        self.shed_tick = int(shed_total) - self._prev_shed
        self._prev_shed = int(shed_total)
        self.offered_tick = int(offered_total) - self._prev_offered
        self._prev_offered = int(offered_total)
        sliding = self._sliding
        for c in sliding["brake_edges"]:
            c.push(float(self.brake_edges_tick))
        for c in sliding["shed"]:
            c.push(float(self.shed_tick))
        for c in sliding["offered"]:
            c.push(float(self.offered_tick))

    def projected_root_frac(self) -> float:
        """The root power fraction one OOB horizon ahead (NaN before the
        first tick)."""
        return self.root_slope.projected()
