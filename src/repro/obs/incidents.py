"""Offline incident reconstruction from the exported event trace.

The artifacts pipeline leaves behind ``events.jsonl`` — chaos fault
transitions, row brake edges, controller rebalances, and alert
engage/release pairs, all on simulation time. This module folds that flat
trace back into *causal incident timelines*: one :class:`Incident` per
fault, carrying the alerts it triggered, detection latency against the
ground-truth schedule (the chaos events' ``t_sched`` label — a ramped
derate's apply record only lands when the ramp completes, but detection is
measured from when the fault *began*), time-to-mitigation (the first
rebalance after the fault began), time-to-clear (the last attached alert
release after restore), and the brake activity inside the window.

Reconstruction is a pure function of the trace: two passes, no simulator
state. Pass one pairs fault events into incidents (``row-crash`` closes on
the matching ``row-revive`` apply; budget derates close on their
``fault_restore``); pass two attributes every alert engage to *all*
incidents whose active window contains it (overlapping faults share their
alerts — attribution is causal-candidate, not exclusive), leaving the rest
as unattributed engages (the false-alarm count the ``chaos-noop`` gate
rides on). Events are stably sorted by ``(t, input order)`` first, so
out-of-order JSONL lines — merged traces, shard interleavings — cannot
change the result; an empty trace yields an empty report.

``tools/incidents.py`` is the CLI: it renders the markdown section and the
machine-readable ``incidents.json`` into an artifacts directory, and
``tools/report.py`` inlines the section when that file is present.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

from repro.obs.metrics import Event

#: artifacts-dir filename the CLI writes (next to events.jsonl etc.)
INCIDENTS_NAME = "incidents.json"

_ROW_OPEN = "row-crash"
_ROW_CLOSE = "row-revive"


@dataclass
class AttributedAlert:
    """One alert engage attributed to an incident, with its eventual
    release (``t_release`` stays None for an alert that never clears)."""

    name: str
    kind: str
    target: str
    t_engage: float
    value: float = math.nan
    t_release: Optional[float] = None

    def to_dict(self) -> dict:
        return {"name": self.name, "kind": self.kind, "target": self.target,
                "t_engage": self.t_engage, "value": self.value,
                "t_release": self.t_release}


@dataclass
class Incident:
    """One reconstructed fault timeline. Times are simulation seconds;
    ``t_sched`` is the ground-truth fault start (schedule), ``t_apply``
    when the transition record landed (ramp end for ramped derates),
    ``t_restore`` the restore/revive instant (None while unresolved)."""

    iid: int
    kind: str
    target: str
    t_sched: float
    t_apply: float
    t_restore: Optional[float] = None
    alerts: List[AttributedAlert] = field(default_factory=list)
    n_brake_edges: int = 0
    n_rebalances: int = 0
    t_first_rebalance: Optional[float] = None

    # -- derived timeline metrics -------------------------------------------
    def t_end(self) -> float:
        return self.t_restore if self.t_restore is not None else math.inf

    def contains(self, t: float) -> bool:
        return self.t_sched <= t < self.t_end()

    def first_detection(self) -> Optional[AttributedAlert]:
        """The first telemetry-driven alert engage (``fault-active`` is
        ground truth, not detection — it only counts when nothing else
        fired at all)."""
        telemetry = [a for a in self.alerts if a.kind != "fault-active"]
        pool = telemetry or self.alerts
        return min(pool, key=lambda a: a.t_engage) if pool else None

    def detection_latency_s(self) -> Optional[float]:
        """Seconds from the scheduled fault start to the first detection —
        includes ramp time and the OOB telemetry delay by construction."""
        det = self.first_detection()
        return None if det is None else det.t_engage - self.t_sched

    def detection_after_apply_s(self) -> Optional[float]:
        """Seconds from the apply record to the first detection — negative
        when a ramping fault was caught before it fully landed."""
        det = self.first_detection()
        return None if det is None else det.t_engage - self.t_apply

    def detection_latency_ticks(self, tick_s: float) -> Optional[float]:
        lat = self.detection_latency_s()
        return None if lat is None else lat / tick_s

    def time_to_mitigation_s(self) -> Optional[float]:
        """Fault start to the first controller rebalance after it (None
        under a static controller — nothing ever responds)."""
        if self.t_first_rebalance is None:
            return None
        return self.t_first_rebalance - self.t_sched

    def time_to_clear_s(self) -> Optional[float]:
        """Restore to the *last* attached alert release (0 floor: alerts
        that released during the fault don't make clearing negative); None
        while the fault is unresolved or an attached alert never
        released."""
        if self.t_restore is None or not self.alerts:
            return None
        if any(a.t_release is None for a in self.alerts):
            return None
        return max(0.0, max(a.t_release for a in self.alerts) - self.t_restore)

    @property
    def unresolved(self) -> bool:
        """Still open at end of trace: never restored, or an attached
        alert never released."""
        return (self.t_restore is None
                or any(a.t_release is None for a in self.alerts))

    def to_dict(self, tick_s: float) -> dict:
        return {
            "id": self.iid,
            "kind": self.kind,
            "target": self.target,
            "t_sched": self.t_sched,
            "t_apply": self.t_apply,
            "t_restore": self.t_restore,
            "unresolved": self.unresolved,
            "alerts": [a.to_dict() for a in self.alerts],
            "n_brake_edges": self.n_brake_edges,
            "n_rebalances": self.n_rebalances,
            "detection_latency_s": self.detection_latency_s(),
            "detection_latency_ticks": self.detection_latency_ticks(tick_s),
            "detection_after_apply_s": self.detection_after_apply_s(),
            "time_to_mitigation_s": self.time_to_mitigation_s(),
            "time_to_clear_s": self.time_to_clear_s(),
        }


@dataclass
class IncidentReport:
    """The full reconstruction: incidents in schedule order, plus every
    alert engage that matched no incident window (false alarms)."""

    incidents: List[Incident] = field(default_factory=list)
    unattributed_engages: List[Event] = field(default_factory=list)
    n_events: int = 0

    @property
    def n_incidents(self) -> int:
        return len(self.incidents)

    @property
    def n_false_alarms(self) -> int:
        return len(self.unattributed_engages)


def _f(labels: Dict[str, str], key: str, default: float) -> float:
    try:
        return float(labels[key])
    except (KeyError, ValueError):
        return default


def reconstruct_incidents(events: Sequence[Event]) -> IncidentReport:
    """Fold a flat event trace into :class:`IncidentReport` (see module
    docstring for the pairing and attribution rules)."""
    ordered = sorted(enumerate(events), key=lambda ie: (ie[1].t, ie[0]))
    trace = [e for _, e in ordered]

    # pass one: fault transitions -> incidents
    incidents: List[Incident] = []
    open_by_key: Dict[tuple, Incident] = {}  # (fault kind, target) -> open
    for e in trace:
        if e.subsystem != "chaos":
            continue
        lab = e.labels_dict()
        fault, target = lab.get("fault", "?"), lab.get("target", "?")
        t_sched = _f(lab, "t_sched", e.t)
        if e.kind == "fault_apply" and fault != _ROW_CLOSE:
            inc = Incident(iid=len(incidents), kind=fault, target=target,
                           t_sched=t_sched, t_apply=e.t)
            incidents.append(inc)
            open_by_key[(fault, target)] = inc
        elif e.kind == "fault_apply" and fault == _ROW_CLOSE:
            inc = open_by_key.pop((_ROW_OPEN, target), None)
            if inc is not None:
                inc.t_restore = t_sched
        elif e.kind == "fault_restore":
            inc = open_by_key.pop((fault, target), None)
            if inc is not None:
                inc.t_restore = t_sched

    # pass two: attribute alerts / brakes / rebalances to incident windows
    unattributed: List[Event] = []
    open_alerts: Dict[str, List[AttributedAlert]] = {}
    for e in trace:
        if e.subsystem == "alert" and e.kind == "alert_engage":
            lab = e.labels_dict()
            hits = [inc for inc in incidents if inc.contains(e.t)]
            if not hits:
                unattributed.append(e)
                continue
            refs = []
            for inc in hits:
                a = AttributedAlert(
                    name=lab.get("alert", "?"), kind=lab.get("rule", "?"),
                    target=lab.get("target", ""), t_engage=e.t,
                    value=_f(lab, "value", math.nan))
                inc.alerts.append(a)
                refs.append(a)
            open_alerts.setdefault(lab.get("alert", "?"), []).extend(refs)
        elif e.subsystem == "alert" and e.kind == "alert_release":
            name = e.labels_dict().get("alert", "?")
            for a in open_alerts.pop(name, ()):
                a.t_release = e.t
        elif e.subsystem == "row" and e.kind in ("brake_engage",
                                                 "brake_release"):
            for inc in incidents:
                if inc.contains(e.t):
                    inc.n_brake_edges += 1
        elif e.subsystem == "controller" and e.kind == "rebalance":
            for inc in incidents:
                if e.t >= inc.t_sched:
                    inc.n_rebalances += 1
                    if inc.t_first_rebalance is None:
                        inc.t_first_rebalance = e.t

    incidents.sort(key=lambda i: (i.t_sched, i.iid))
    return IncidentReport(incidents=incidents,
                          unattributed_engages=unattributed,
                          n_events=len(trace))


def incidents_json(report: IncidentReport, *, tick_s: float = 2.0) -> dict:
    """The machine-readable form ``incidents.json`` carries."""
    return {
        "tick_s": tick_s,
        "n_events": report.n_events,
        "n_incidents": report.n_incidents,
        "n_false_alarms": report.n_false_alarms,
        "false_alarms": [
            {"t": e.t, **e.labels_dict()} for e in report.unattributed_engages],
        "incidents": [inc.to_dict(tick_s) for inc in report.incidents],
    }


def _fmt(v: Optional[float], unit: str = "s") -> str:
    if v is None:
        return "—"
    return f"{v:g}{unit}"


def render_incidents_markdown(report: IncidentReport, *,
                              tick_s: float = 2.0) -> str:
    """The human-readable incident section (``tools/incidents.py`` prints
    it; ``tools/report.py`` inlines it into ``report.md``)."""
    out = ["## Incidents", ""]
    out.append(f"{report.n_incidents} incident(s), "
               f"{report.n_false_alarms} unattributed alert engage(s), "
               f"{report.n_events} trace events.")
    out.append("")
    if report.incidents:
        out.append("| # | fault | target | t_sched | detect (s / ticks) | "
                   "mitigate | clear | alerts | brakes | rebalances |")
        out.append("|---|---|---|---|---|---|---|---|---|---|")
        for inc in report.incidents:
            lat = inc.detection_latency_s()
            ticks = inc.detection_latency_ticks(tick_s)
            det = ("—" if lat is None
                   else f"{lat:g} / {ticks:g}")
            flag = " (open)" if inc.unresolved else ""
            out.append(
                f"| {inc.iid} | {inc.kind} | {inc.target} "
                f"| {inc.t_sched:g}s | {det} "
                f"| {_fmt(inc.time_to_mitigation_s())} "
                f"| {_fmt(inc.time_to_clear_s())}{flag} "
                f"| {len(inc.alerts)} | {inc.n_brake_edges} "
                f"| {inc.n_rebalances} |")
        out.append("")
        for inc in report.incidents:
            if not inc.alerts:
                continue
            out.append(f"**Incident {inc.iid}** ({inc.kind} on "
                       f"{inc.target}):")
            for a in sorted(inc.alerts, key=lambda a: (a.t_engage, a.name)):
                rel = (f"released {a.t_release:g}s" if a.t_release is not None
                       else "never released")
                out.append(f"- `{a.name}` engaged {a.t_engage:g}s "
                           f"(value {a.value:g}), {rel}")
            out.append("")
    if report.unattributed_engages:
        out.append("**Unattributed engages** (no fault window matched — "
                   "false alarms):")
        for e in report.unattributed_engages:
            lab = e.labels_dict()
            out.append(f"- `{lab.get('alert', '?')}` at {e.t:g}s "
                       f"(value {lab.get('value', '?')})")
        out.append("")
    return "\n".join(out).rstrip() + "\n"
