"""Fault-tolerant checkpointing: atomic, step-indexed, resumable.

Production shape: every ``interval`` steps the train state (params, optimizer
moments, step counter, data-pipeline cursor) is flattened and written to
``<dir>/step_<n>.npz`` via a temp-file rename (atomic on POSIX), then old
checkpoints beyond ``keep`` are garbage-collected. ``restore_latest``
tolerates torn/corrupt files (a killed writer) by falling back to the newest
readable checkpoint — the property the runtime's crash-restart tests exercise.

On a real multi-host pod each host writes its addressable shards (the layout
here is the single-host degenerate case of that; the pytree path scheme is
host-count independent).
"""

from __future__ import annotations

import os
import re
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

_STEP_RE = re.compile(r"step_(\d+)\.npz$")


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save(ckpt_dir: str, step: int, state: Any, *, keep: int = 3) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = _flatten(state)
    flat["__step__"] = np.asarray(step, np.int64)
    fd, tmp = tempfile.mkstemp(dir=ckpt_dir, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, **flat)
        final = os.path.join(ckpt_dir, f"step_{step}.npz")
        os.replace(tmp, final)  # atomic
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(list_steps(ckpt_dir))
    for s in steps[:-keep] if keep else []:
        try:
            os.unlink(os.path.join(ckpt_dir, f"step_{s}.npz"))
        except OSError:
            pass


def list_steps(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for fn in os.listdir(ckpt_dir):
        m = _STEP_RE.search(fn)
        if m:
            out.append(int(m.group(1)))
    return sorted(out)


def restore(ckpt_dir: str, step: int, state_template: Any) -> Any:
    """Restore into the template's structure (and shardings, via device_put)."""
    path = os.path.join(ckpt_dir, f"step_{step}.npz")
    with np.load(path) as data:
        flat = {k: data[k] for k in data.files}
    leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(state_template)
    new_leaves = []
    for p, leaf in leaves_with_path:
        key = "/".join(str(getattr(q, "key", getattr(q, "idx", q))) for q in p)
        arr = flat[key]
        if hasattr(leaf, "sharding"):
            arr = jax.device_put(arr.astype(leaf.dtype), leaf.sharding)
        new_leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, new_leaves)


def restore_latest(ckpt_dir: str, state_template: Any) -> Tuple[Optional[int], Any]:
    """Newest readable checkpoint (corrupt files skipped), or (None, template)."""
    for step in reversed(list_steps(ckpt_dir)):
        try:
            return step, restore(ckpt_dir, step, state_template)
        except Exception:
            continue  # torn write — fall back to the previous checkpoint
    return None, state_template
