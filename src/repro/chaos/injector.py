"""Runtime fault application: the ChaosInjector rides the fleet lockstep.

``FleetSimulator`` polls its injector once per telemetry tick, *after* the
controller's rebalance pass, so fault actuation has exactly the same
between-ticks semantics as a ``scope="tree"`` rebalance commit: budgets
change on the tick boundary, and the next row telemetry sample (and every
policy, router, admission controller, and forecaster downstream) observes
the fault-perturbed state with no special cases — the point of the chaos
engine is to ask whether the *unchanged* control plane recovers.

Three primitives implement the four registered event kinds:

* **fence/unfence** (``row-crash`` / ``row-revive``): flips the fleet's
  ``row_alive`` mask. The dispatcher routes new arrivals around dead rows
  (shedding when none are left); the crashed row's in-flight work drains
  naturally, and revival re-enters through ``RowSimulator.inject()`` —
  which already clears the drained-past-end state.
* **derate** (``node-derate`` / ``site-demand-response``): multiplies the
  target node's budget by ``g``, scaling its whole subtree uniformly
  (leaf budgets commit through ``RowSimulator.set_budget`` exactly like a
  rebalance) and subtracting the removed watts from every ancestor, so
  `conservation_errors` stays empty at every node. The node's physical
  capacity cap (``PowerHierarchy.node_cap_w``) drops with it, which is
  what stops a tree-scope controller from "healing" the fault by
  re-growing the derated subtree on its next pass. Ramps apply the same
  primitive incrementally on each tick until the target factor is reached.
* **restore**: returns the exact watts each event removed (tracked per
  event, summed over ramp steps) to the node's subtree and ancestors, so
  the root envelope round-trips even if a controller re-divided budgets
  while the fault was active.

Every *phase transition* (crash, revive, derate fully applied, restore)
appends a :class:`FaultRecord` with full before/after budget vectors to
``FleetResult.fault_events`` — the audit log the resilience benchmark and
tier-1 tests assert on. Per-tick ramp increments do not spam the log; the
apply record carries the pre-ramp snapshot.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.chaos.faults import FaultEvent, FaultSpec
from repro.obs.metrics import get_recorder

_CUM_ATOL = 1e-12


@dataclass(frozen=True, eq=False)
class FaultRecord:
    """One applied fault phase in the ``FleetResult.fault_events`` audit
    log: what happened, to which target, at which telemetry tick, and the
    full node-budget vector immediately before and after."""

    t: float
    kind: str
    target: str
    phase: str  # "apply" | "restore"
    factor: float
    node_budgets_before_w: np.ndarray = field(repr=False)
    node_budgets_after_w: np.ndarray = field(repr=False)
    detail: str = ""

    def __eq__(self, other) -> bool:
        # dataclass eq would ambiguously compare the budget arrays
        if not isinstance(other, FaultRecord):
            return NotImplemented
        return ((self.t, self.kind, self.target, self.phase, self.factor,
                 self.detail)
                == (other.t, other.kind, other.target, other.phase,
                    other.factor, other.detail)
                and np.array_equal(self.node_budgets_before_w,
                                   other.node_budgets_before_w)
                and np.array_equal(self.node_budgets_after_w,
                                   other.node_budgets_after_w))


class _DerateState:
    """Mutable runtime state for one budget event: cumulative applied
    factor (1.0 → event.factor during a ramp) and the net watts removed
    from the target node, which the restore hands back."""

    def __init__(self, event: FaultEvent, node: int):
        self.event = event
        self.node = node
        self.cum = 1.0
        self.applied_delta_w = 0.0
        self.before: Optional[np.ndarray] = None
        self.done = False
        self.restored = False


class ChaosInjector:
    """Applies a :class:`FaultSpec` to a running ``FleetSimulator``.

    One injector drives one fleet: ``bind()`` (called by the fleet's
    constructor) validates the timeline against the concrete run and
    resets all runtime state, then ``poll(t, fleet)`` fires on every
    telemetry tick. Build a fresh injector per fleet (``build_fleet``
    does) — Monte-Carlo members must not share actuation state.
    """

    def __init__(self, spec: FaultSpec):
        self.spec = spec
        self.records: List[FaultRecord] = []
        self._bound = False

    # -- lifecycle -----------------------------------------------------------
    def bind(self, fleet) -> None:
        """Validate the timeline against the fleet and compile the event
        schedule. Raises ``ValueError`` naming any event that falls beyond
        the trace, targets a missing row, or names an unknown node."""
        h = fleet.hierarchy
        self.spec.validate(duration_s=fleet.duration, n_rows=len(fleet.rows),
                           node_names=list(h.names))
        self.records = []
        self._base_budget_w = h.node_budget_w.copy()
        name_to_idx = {n: i for i, n in enumerate(h.names)}
        self._row_events = sorted(self.spec.row_events(), key=lambda e: e.t)
        self._row_next = 0
        self._derates: List[_DerateState] = []
        for e in self.spec.budget_events():
            node = h.root if e.node is None else name_to_idx[e.node]
            self._derates.append(_DerateState(e, node))
        self._ancestors: Dict[int, List[int]] = {}
        self._subtree: Dict[int, np.ndarray] = {}
        for d in self._derates:
            if d.node not in self._subtree:
                self._ancestors[d.node] = self._node_ancestors(h, d.node)
                self._subtree[d.node] = self._subtree_nodes(h, d.node)
        self._bound = True

    @staticmethod
    def _node_ancestors(h, node: int) -> List[int]:
        out, p = [], int(h.parent[node])
        while p >= 0:
            out.append(p)
            p = int(h.parent[p])
        return out

    @staticmethod
    def _subtree_nodes(h, node: int) -> np.ndarray:
        """All node indices under (and including) ``node`` — interior and
        leaf — found by a children-walk."""
        out, stack = [], [node]
        while stack:
            n = stack.pop()
            out.append(n)
            stack.extend(int(c) for c in h.children[n])
        return np.asarray(sorted(out), dtype=np.int64)

    # -- tick hook -----------------------------------------------------------
    def poll(self, t: float, fleet) -> None:
        """Apply every event scheduled at or before ``t``. Runs between
        telemetry ticks (after the controller's rebalance pass), so budget
        changes land with rebalance actuation semantics."""
        assert self._bound, "ChaosInjector.poll before bind"
        h = fleet.hierarchy
        while (self._row_next < len(self._row_events)
               and self._row_events[self._row_next].t <= t):
            e = self._row_events[self._row_next]
            self._row_next += 1
            before = h.node_budget_w.copy()
            fleet.set_row_alive(int(e.row), e.kind == "row-revive")
            self.records.append(FaultRecord(
                t=t, kind=e.kind, target=h.names[int(e.row)], phase="apply",
                factor=1.0, node_budgets_before_w=before,
                node_budgets_after_w=h.node_budget_w.copy(),
                detail=f"scheduled t={e.t:g}s"))
            self._record_transition(t, e.kind, h.names[int(e.row)], "apply",
                                    e.t)
        for d in self._derates:
            self._poll_derate(d, t, fleet)

    def _poll_derate(self, d: _DerateState, t: float, fleet) -> None:
        h = fleet.hierarchy
        e = d.event
        if not d.done and t >= e.t:
            if d.before is None:
                d.before = h.node_budget_w.copy()
            frac = 1.0 if e.ramp_s <= 0.0 else min(1.0, (t - e.t) / e.ramp_s)
            f_t = 1.0 + (e.factor - 1.0) * frac
            if f_t < d.cum - _CUM_ATOL:
                d.applied_delta_w += self._scale_subtree(
                    fleet, d.node, f_t / d.cum, t)
                d.cum = f_t
                self._update_cap(h, d.node)
            if d.cum <= e.factor + _CUM_ATOL:
                d.done = True
                self.records.append(FaultRecord(
                    t=t, kind=e.kind, target=h.names[d.node], phase="apply",
                    factor=e.factor, node_budgets_before_w=d.before,
                    node_budgets_after_w=h.node_budget_w.copy(),
                    detail=(f"-{d.applied_delta_w:.0f} W"
                            + (f" over {e.ramp_s:g}s ramp" if e.ramp_s else ""))))
                self._record_transition(t, e.kind, h.names[d.node], "apply",
                                        e.t)
        if d.done and not d.restored and e.until is not None and t >= e.until:
            before = h.node_budget_w.copy()
            self._restore(fleet, d, t)
            d.restored = True
            self._update_cap(h, d.node)
            self.records.append(FaultRecord(
                t=t, kind=e.kind, target=h.names[d.node], phase="restore",
                factor=e.factor, node_budgets_before_w=before,
                node_budgets_after_w=h.node_budget_w.copy(),
                detail=f"+{d.applied_delta_w:.0f} W returned"))
            self._record_transition(t, e.kind, h.names[d.node], "restore",
                                    e.until)

    def n_active_derates(self) -> int:
        """Budget derates currently in force: started (a ramp in progress
        counts) and not yet restored. Fenced rows are tracked by the
        fleet's ``row_alive`` mask, not here. Read-only — the fault-active
        alert rule polls this as its ground-truth signal."""
        return sum(1 for d in self._derates
                   if not d.restored
                   and (d.done or d.cum < 1.0 - _CUM_ATOL))

    @staticmethod
    def _record_transition(t: float, kind: str, target: str,
                           phase: str, t_sched: float) -> None:
        """Mirror a fault phase transition into the observability event
        trace — one event + counter per FaultRecord, write-only.
        ``t_sched`` is the timeline's scheduled time for this phase (the
        event's ``t``, or ``until`` for restores): incident reconstruction
        measures detection latency against it, since a ramped derate's
        apply record only lands when the ramp completes."""
        rec = get_recorder()
        if rec.enabled:
            rec.event("chaos",
                      "fault_apply" if phase == "apply" else "fault_restore",
                      t=t, fault=kind, target=target, phase=phase,
                      t_sched=round(t_sched, 6))
            rec.counter("chaos_fault_transitions_total",
                        kind=kind, phase=phase)

    # -- budget primitives ---------------------------------------------------
    def _scale_subtree(self, fleet, node: int, g: float, t: float) -> float:
        """Multiply ``node``'s budget (and its whole subtree, uniformly) by
        ``g``, committing leaf budgets through ``set_budget`` and removing
        the delta from every ancestor envelope. Returns the watts removed
        from ``node`` (negative g>1 deltas flow back on restore)."""
        h = fleet.hierarchy
        old = float(h.node_budget_w[node])
        h.node_budget_w[self._subtree[node]] *= g
        for li in h.subtree_leaves(node):
            fleet.rows[int(li)].set_budget(float(h.node_budget_w[int(li)]), t)
        delta = old - float(h.node_budget_w[node])
        for a in self._ancestors[node]:
            h.node_budget_w[a] -= delta
        return delta

    def _restore(self, fleet, d: _DerateState, t: float) -> None:
        """Give back exactly the watts this event removed: the subtree
        scales up so the target node regains ``applied_delta_w``, and every
        ancestor (root included) grows by the same amount — the site
        envelope round-trips even if a controller re-divided in between."""
        h = fleet.hierarchy
        cur = float(h.node_budget_w[d.node])
        g = (cur + d.applied_delta_w) / cur
        h.node_budget_w[self._subtree[d.node]] *= g
        for li in h.subtree_leaves(d.node):
            fleet.rows[int(li)].set_budget(float(h.node_budget_w[int(li)]), t)
        for a in self._ancestors[d.node]:
            h.node_budget_w[a] += d.applied_delta_w
        d.cum = 1.0

    def _update_cap(self, h, node: int) -> None:
        """Physical capacity cap = base budget x product of active derate
        factors on this node; lifted back to +inf once every event on the
        node has restored."""
        active = 1.0
        for d in self._derates:
            if d.node == node and not d.restored:
                active *= d.cum
        h.node_cap_w[node] = (self._base_budget_w[node] * active
                              if active < 1.0 - _CUM_ATOL else np.inf)
