"""Serializable fault timelines: the chaos engine's data model.

POLCA's safety argument is that the control plane *reacts* to rare power
emergencies, yet every healthy-fleet benchmark measures steady state. A
:class:`FaultSpec` makes the emergency itself a first-class, JSON-round-
trippable part of a :class:`~repro.experiments.scenario.Scenario`
(``Scenario.faults`` / ``with_faults``): an ordered timeline of
:class:`FaultEvent`\\ s the :class:`~repro.chaos.injector.ChaosInjector`
applies between fleet telemetry ticks. Four event kinds are registered (the
``FAULT_EVENT_BUILDERS`` registry backs docs/registries.md exactly like the
policy/router registries):

  * ``row-crash`` / ``row-revive`` — a row is fenced from the dispatcher
    (in-flight work drains; arrivals route around it, or are shed when no
    row is left) and later returns through the existing
    ``RowSimulator.inject()`` revival path;
  * ``node-derate`` — a step- or ramp-derate of any budget-tree node's
    deliverable capacity (a PDU losing a feed, a thermally throttled rack):
    the target's subtree budgets scale down and the lost watts leave every
    ancestor envelope, so the budget tree stays conservative; a hard
    capacity cap (``PowerHierarchy.node_cap_w``) stops rebalancing
    controllers from promising the node watts the hardware can no longer
    carry;
  * ``site-demand-response`` — a grid event shrinking the *root* (site)
    envelope on a schedule; exactly a ``node-derate`` targeting the root.

Budget events with ``until`` restore at that time: the removed watts are
returned to the node's subtree and every ancestor, so the root envelope
round-trips exactly even if a controller moved budgets in between.

Validation is two-stage: structural checks run at construction
(``__post_init__``), and :meth:`FaultSpec.validate` — called when the fleet
is *built*, before any event is simulated — checks the timeline against the
concrete run (events beyond the trace duration, rows that don't exist, node
names absent from the scenario's hierarchy) and raises ``ValueError``
naming the offending event instead of surfacing as a mid-run error.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Iterable, Optional, Sequence, Tuple

_ROW_KINDS = ("row-crash", "row-revive")
_BUDGET_KINDS = ("node-derate", "site-demand-response")


@dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault. ``kind`` names an entry in
    ``FAULT_EVENT_BUILDERS``; which other fields apply depends on it:

    * row events (``row-crash`` / ``row-revive``) target ``row`` (a leaf /
      row index) at time ``t``;
    * ``node-derate`` targets ``node`` (a hierarchy node *name*, e.g.
      ``"pdu0"`` or ``"rack0.1"``) and multiplies its deliverable capacity
      by ``factor`` (0 < factor <= 1), stepping instantly or ramping
      linearly over ``ramp_s`` (thermal derates ramp; breaker trips step);
    * ``site-demand-response`` is a ``node-derate`` whose target is
      implicitly the root — ``node`` must be left ``None``.

    Budget events with ``until`` restore the removed watts at that time;
    ``until=None`` is permanent for the rest of the trace.
    """

    kind: str
    t: float
    row: Optional[int] = None
    node: Optional[str] = None
    factor: float = 1.0
    until: Optional[float] = None
    ramp_s: float = 0.0

    def describe(self) -> str:
        """Compact human-readable form, used by validation errors and the
        audit log."""
        if self.kind in _ROW_KINDS:
            return f"{self.kind}(t={self.t:g}, row={self.row})"
        target = self.node if self.node is not None else "<root>"
        txt = f"{self.kind}(t={self.t:g}, node={target}, factor={self.factor:g}"
        if self.ramp_s:
            txt += f", ramp_s={self.ramp_s:g}"
        if self.until is not None:
            txt += f", until={self.until:g}"
        return txt + ")"


# ---------------------------------------------------------------------------
# registry: one marker class per event kind. The classes carry the docstring
# the registry reference (docs/registries.md) renders, and the per-kind
# structural validation — the same name-keyed pattern as the policy/router/
# rebalance registries, so FaultSpec stays JSON-serializable.
# ---------------------------------------------------------------------------

def _require(cond: bool, event: FaultEvent, why: str) -> None:
    if not cond:
        raise ValueError(f"invalid fault event {event.describe()}: {why}")


class RowCrash:
    """A row drops out of the serving pool: the dispatcher fences it (in-flight work drains, arrivals route around it or shed), budgets untouched."""

    @staticmethod
    def check(e: FaultEvent) -> None:
        _require(e.row is not None and int(e.row) >= 0, e,
                 "row events need a non-negative row index")
        _require(e.node is None, e, "row events target rows, not nodes")
        _require(e.until is None and e.ramp_s == 0.0, e,
                 "row events are instantaneous; schedule an explicit "
                 "row-revive instead of until/ramp_s")


class RowRevive:
    """A crashed row returns to the routing pool; a row drained past its duration re-enters through the RowSimulator.inject() revival path."""

    check = RowCrash.check


class NodeDerate:
    """Step- or ramp-derate of a budget-tree node's deliverable capacity (PDU feed loss, thermal throttle): subtree budgets scale down, the lost watts leave every ancestor envelope, and a capacity cap blocks controllers from re-growing the node until the optional restore."""

    @staticmethod
    def check(e: FaultEvent) -> None:
        _require(e.row is None, e, "budget events target nodes, not rows")
        _require(isinstance(e.node, str) and bool(e.node), e,
                 "node-derate needs a hierarchy node name")
        _check_budget_common(e)


class SiteDemandResponse:
    """Grid demand-response: the root (site) envelope shrinks by ``factor`` on a schedule and restores at ``until`` — a node-derate whose target is the root."""

    @staticmethod
    def check(e: FaultEvent) -> None:
        _require(e.row is None, e, "budget events target nodes, not rows")
        _require(e.node is None, e,
                 "site-demand-response targets the root implicitly; use "
                 "node-derate to name an interior node")
        _check_budget_common(e)


def _check_budget_common(e: FaultEvent) -> None:
    import math
    _require(math.isfinite(e.factor) and 0.0 < e.factor <= 1.0, e,
             "factor must be a capacity multiplier in (0, 1] — a 0 W budget "
             "divides telemetry by zero")
    _require(e.ramp_s >= 0.0, e, "ramp_s must be >= 0")
    _require(e.until is None or e.until > e.t + e.ramp_s, e,
             "until must come after the derate has fully applied "
             "(t + ramp_s)")


FAULT_EVENT_BUILDERS: Dict[str, type] = {
    "row-crash": RowCrash,
    "row-revive": RowRevive,
    "node-derate": NodeDerate,
    "site-demand-response": SiteDemandResponse,
}


@dataclass(frozen=True)
class FaultSpec:
    """An ordered, serializable fault timeline (``Scenario.faults``).

    Structural validity is checked at construction; run-shape validity
    (durations, row indices, node names) in :meth:`validate`, which the
    fleet builder calls before the simulation starts. An empty spec is a
    guaranteed no-op: the fleet driver skips the injector entirely, so a
    ``chaos-*`` scenario with ``FaultSpec()`` is bit-identical to its
    fault-free counterpart (tier-1-asserted)."""

    events: Tuple[FaultEvent, ...] = ()

    def __post_init__(self):
        events = tuple(e if isinstance(e, FaultEvent) else FaultEvent(**e)
                       for e in self.events)
        object.__setattr__(self, "events", events)
        import math
        for e in events:
            try:
                builder = FAULT_EVENT_BUILDERS[e.kind]
            except KeyError:
                known = ", ".join(sorted(FAULT_EVENT_BUILDERS))
                raise ValueError(
                    f"invalid fault event {e!r}: unknown kind {e.kind!r} "
                    f"(registered: {known})") from None
            _require(math.isfinite(e.t) and e.t >= 0.0, e,
                     "t must be a non-negative time")
            builder.check(e)

    # -- views ---------------------------------------------------------------
    @property
    def is_noop(self) -> bool:
        return not self.events

    def routing_only(self) -> "FaultSpec":
        """The row-crash/row-revive subset. Uncapped reference twins carry
        exactly this: a crash is an environmental capacity loss both runs
        must see (so SLO diffs isolate power management), while budget
        derates *are* power management and never touch a reference."""
        return FaultSpec(tuple(e for e in self.events if e.kind in _ROW_KINDS))

    def budget_events(self) -> Tuple[FaultEvent, ...]:
        return tuple(e for e in self.events if e.kind in _BUDGET_KINDS)

    def row_events(self) -> Tuple[FaultEvent, ...]:
        return tuple(e for e in self.events if e.kind in _ROW_KINDS)

    # -- run-shape validation ------------------------------------------------
    def validate(self, *, duration_s: float, n_rows: int,
                 node_names: Optional[Sequence[str]] = None) -> None:
        """Check the timeline against a concrete run, raising ``ValueError``
        naming the offending event. Called at fleet-build time — before any
        event is simulated — so a bad timeline never surfaces as a mid-run
        ``RuntimeError`` from ``inject()``."""
        names = set(node_names) if node_names is not None else None
        for e in self.events:
            _require(e.t <= duration_s, e,
                     f"event time is beyond the trace duration "
                     f"({duration_s:g} s)")
            _require(e.t + e.ramp_s <= duration_s, e,
                     f"ramp ends beyond the trace duration ({duration_s:g} s)")
            _require(e.until is None or e.until <= duration_s, e,
                     f"restore time is beyond the trace duration "
                     f"({duration_s:g} s)")
            if e.kind in _ROW_KINDS:
                _require(0 <= int(e.row) < n_rows, e,
                         f"row index out of range for a {n_rows}-row fleet")
            elif e.kind == "node-derate" and names is not None:
                _require(e.node in names, e,
                         f"no hierarchy node named {e.node!r} "
                         f"(known: {sorted(names)})")

    # -- serialization -------------------------------------------------------
    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d) -> "FaultSpec":
        if isinstance(d, FaultSpec):
            return d
        events: Iterable = d.get("events", ()) if isinstance(d, dict) else d
        return cls(tuple(FaultEvent(**e) if isinstance(e, dict) else e
                         for e in events))
