"""Chaos engine: injectable fault timelines for oversubscribed fleets.

The data model (:class:`FaultSpec` / :class:`FaultEvent`, pure data, JSON
round-trippable) lives in :mod:`repro.chaos.faults`; the runtime
(:class:`ChaosInjector`, polled by ``FleetSimulator`` between telemetry
ticks) in :mod:`repro.chaos.injector`. Scenarios opt in with
``Scenario.with_faults``; see DESIGN.md §13 and the ``chaos-*`` scenario
family.
"""

from repro.chaos.faults import (  # noqa: F401
    FAULT_EVENT_BUILDERS,
    FaultEvent,
    FaultSpec,
)
from repro.chaos.injector import ChaosInjector, FaultRecord  # noqa: F401

__all__ = [
    "FAULT_EVENT_BUILDERS",
    "FaultEvent",
    "FaultSpec",
    "ChaosInjector",
    "FaultRecord",
]
