"""Dynamic fleet power-rebalancing: move budget slack to where load lands.

Static per-row budgets strand headroom: in the derated-row ``fleet-*``
scenarios one row runs against a 30%-smaller envelope while its neighbors
hold slack they never use, so the derated row powerbrakes at load points the
rack as a whole could absorb. :class:`FleetController` closes that gap — it
runs on the same telemetry-grid lockstep as the rack managers and
periodically re-divides a *fixed* power envelope across the budget tree
(:class:`~repro.core.hierarchy.PowerHierarchy`): per rack, per cluster, or —
``scope="tree"`` — recursively at every interior node, so a site re-divides
across PDU sets, PDU sets across racks, and racks across rows, reaching
headroom stranded on a sibling *rack*, not just a sibling row. Conservation
is structural: every division re-normalizes the new budgets to its node's
envelope and asserts the sums match (tier-1-asserted every rebalance tick,
per node).

Rebalance policies are registered by name so
:class:`~repro.experiments.scenario.ControllerSpec` stays JSON-serializable:

  | policy       | target budgets                                          |
  | static       | never moves a watt (bit-identical to controller-less    |
  |              | fleets — asserted in tests and the benchmark)           |
  | proportional | envelope split proportional to measured row power       |
  | predictive   | envelope split proportional to *forecast* row power     |
  |              | over the 40 s OOB horizon (the same slope extrapolation |
  |              | ``PredictivePolcaPolicy`` caps on), so budget arrives   |
  |              | before the demand does                                  |

The forecast comes from a shared :class:`PowerForecaster` the fleet driver
feeds once per telemetry tick; the forecast-aware router
(:class:`~repro.fleet.router.ForecastAwareRouter`) consumes the same
per-row forecasts, closing the loop from the other side: the controller
moves budget toward predicted demand while the router steers marginal load
away from rows predicted to cross their (possibly just-rebalanced) budget.

Actuation semantics mirror the real control plane: new budgets take effect
at the *next* row telemetry sample (the rebalance lands between grid ticks),
and a row's POLCA policy sees the change only through its own
``power_frac`` — no policy state is touched, so hysteresis and escalation
counters survive rebalances unchanged.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from repro.obs.metrics import get_recorder
from repro.obs.stream import OOB_HORIZON_S

CONSERVATION_ATOL = 1e-6  # watts; rebalances re-normalize exactly


@dataclass(frozen=True)
class RebalanceEvent:
    """One applied rebalance: when, and the per-row budgets before/after.
    ``demand_w`` is the signal the policy split the envelope by (measured or
    forecast row power). Under ``scope="tree"`` the full per-node budget
    vectors (leaves first, root last — see
    :class:`~repro.core.hierarchy.PowerHierarchy`) are carried too, so
    interior budget motion (a site re-dividing across racks) is auditable
    next to the power series; they are ``None`` for the flat scopes.
    Carried in ``FleetResult.rebalances``."""

    t: float
    budgets_before_w: np.ndarray  # [R]
    budgets_after_w: np.ndarray  # [R]
    demand_w: np.ndarray  # [R]
    policy: str
    node_budgets_before_w: Optional[np.ndarray] = None  # [N] (tree scope)
    node_budgets_after_w: Optional[np.ndarray] = None  # [N] (tree scope)

    def moved_w(self) -> float:
        """Total watts that changed hands between rows (half the L1 delta)."""
        return float(np.abs(self.budgets_after_w - self.budgets_before_w).sum() / 2.0)


class PowerForecaster:
    """Per-row power forecast over the OOB horizon, shared by the predictive
    rebalance policy and the forecast-aware router.

    Maintains a sliding window of telemetry-grid samples per row and
    extrapolates each row's least-squares slope ``horizon_s`` ahead — the
    same estimator :class:`~repro.core.policy.PredictivePolcaPolicy` uses for
    predictive capping, vectorized over rows. Forecasts are clamped from
    below at the current measurement (a falling trend never *frees* budget
    early; rising trends claim it early), matching the policy's
    cap-early-never-uncap-early asymmetry. The default horizon is the
    shared :data:`~repro.obs.stream.OOB_HORIZON_S` constant, so the
    controller's forecast and the alerting stream's EWMA projection
    (:class:`~repro.obs.stream.EwmaSlope`) always reason about the same
    future instant.
    """

    def __init__(self, n_rows: int, *,
                 horizon_s: float = OOB_HORIZON_S, window: int = 8):
        self.horizon_s = float(horizon_s)
        self.window = int(window)
        self._t: List[float] = []
        self._w: List[np.ndarray] = []  # each [R]
        self._n_rows = n_rows

    def observe(self, t: float, row_w: np.ndarray) -> None:
        """Feed one telemetry-grid sample of per-row watts."""
        self._t.append(float(t))
        self._w.append(np.asarray(row_w, float).copy())
        if len(self._t) > self.window:
            del self._t[0]
            del self._w[0]

    def forecast_w(self) -> np.ndarray:
        """Predicted per-row watts ``horizon_s`` after the latest sample,
        ``max(current, extrapolated)`` per row. With < 3 samples the forecast
        is the latest measurement (no trend yet)."""
        if not self._w:
            return np.zeros(self._n_rows)
        cur = self._w[-1]
        if len(self._t) < 3:
            return cur.copy()
        t = np.asarray(self._t)
        w = np.stack(self._w)  # [S, R]
        dt = t - t.mean()
        den = float((dt * dt).sum())
        if den <= 0.0:
            return cur.copy()
        slope = (dt[:, None] * (w - w.mean(axis=0))).sum(axis=0) / den  # [R]
        return np.maximum(cur, cur + slope * self.horizon_s)


class RebalancePolicy:
    """Protocol: ``target_budgets(demand_w, budgets_w, envelope_w) ->
    targets | None`` for one scope group (a rack, or the whole cluster).
    ``None`` means "leave this group alone"; targets need not sum to the
    envelope — the controller floors, smooths, and re-normalizes them.
    ``needs_forecast`` declares whether ``demand_w`` should be the
    forecaster's prediction instead of the measured row power."""

    name: str = "rebalance"
    needs_forecast: bool = False

    def target_budgets(self, demand_w: np.ndarray, budgets_w: np.ndarray,
                       envelope_w: float) -> Optional[np.ndarray]:
        raise NotImplementedError


@dataclass
class StaticBudgetPolicy(RebalancePolicy):
    """Today's behavior: budgets stay exactly where provisioning put them.
    A static-controller fleet is bit-identical to a controller-less fleet
    (asserted in tier-1 and the benchmark parity row) — this is the seam
    that makes the controller a safe default-off feature."""

    name: str = "static"

    def target_budgets(self, demand_w, budgets_w, envelope_w):
        return None


@dataclass
class ProportionalDemandPolicy(RebalancePolicy):
    """Split the envelope proportional to measured row power. Reactive: it
    moves budget *after* demand has landed, so a fast-rising row can still
    spend the 40 s OOB window capped (or braked) before relief arrives —
    the gap the predictive policy closes."""

    name: str = "proportional"

    def target_budgets(self, demand_w, budgets_w, envelope_w):
        total = float(demand_w.sum())
        if total <= 0.0:
            return None
        return envelope_w * demand_w / total


@dataclass
class PredictiveRebalancePolicy(RebalancePolicy):
    """Split the envelope proportional to *forecast* row power over the OOB
    horizon (``PowerForecaster``): budget moves toward where demand is
    heading, so it lands before the row's POLCA policy would have had to
    cap — the fleet-level twin of ``PredictivePolcaPolicy``'s predictive
    capping."""

    name: str = "predictive"
    needs_forecast: bool = True

    def target_budgets(self, demand_w, budgets_w, envelope_w):
        total = float(demand_w.sum())
        if total <= 0.0:
            return None
        return envelope_w * demand_w / total


class FleetController:
    """Periodically re-divide a power envelope across the budget hierarchy.

    Bound to a :class:`~repro.core.hierarchy.PowerHierarchy` by the fleet
    driver; every ``interval_s`` it asks the policy for target budgets and
    applies the floored, low-passed, exactly-re-normalized result. Three
    scopes:

    * ``scope="rack"`` — each leaf-parent ("rack") node's rows share that
      node's frozen envelope (the classic per-rack rebalance);
    * ``scope="cluster"`` — all rows share the root envelope in one flat
      pool, ignoring interior budgets;
    * ``scope="tree"`` — the policy runs **recursively at every interior
      node**, top-down: the site re-divides its envelope across PDU sets,
      each PDU set across its racks, each rack across its rows — so
      headroom stranded on a sibling *rack* (not just a sibling row) flows
      to where demand is. Only the root envelope is frozen; interior node
      budgets move (committed back into ``hierarchy.node_budget_w``, so
      published group fractions track the budgets in force). A node's
      demand is the sum of its descendant rows' demand.

    Every division floors children at ``min_share`` of the node's equal
    split (a starved row still draws idle power — a zero budget would
    powerbrake it instantly), low-passes the step with ``alpha`` (full jumps
    oscillate against the 40 s actuation delay, the same failure mode strict
    cap-avoidance routing has), and re-normalizes exactly to the node's
    envelope. Conservation — children sums equal to each node's envelope —
    is asserted on every applied rebalance, per node.
    """

    def __init__(self, policy: RebalancePolicy, *, interval_s: float = 60.0,
                 scope: str = "rack", alpha: float = 0.5,
                 min_share: float = 0.5, deadband_w: float = 1.0):
        if scope not in ("rack", "cluster", "tree"):
            raise ValueError(
                f"scope must be 'rack', 'cluster', or 'tree', got {scope!r}")
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        if not 0.0 < min_share < 1.0:
            # a zero floor lets a zero-demand row's budget reach 0 W, which
            # divides its next telemetry sample by zero
            raise ValueError(f"min_share must be in (0, 1), got {min_share}")
        self.policy = policy
        self.interval_s = float(interval_s)
        self.scope = scope
        self.alpha = float(alpha)
        self.min_share = float(min_share)
        self.deadband_w = float(deadband_w)
        self.events: List[RebalanceEvent] = []
        self._hierarchy = None
        self._groups: List[np.ndarray] = []  # row-index arrays per scope group
        self._group_nodes: List[int] = []  # hierarchy node owning each group
        self._next_t: Optional[float] = None

    @property
    def needs_forecast(self) -> bool:
        return self.policy.needs_forecast

    def bind(self, hierarchy) -> None:
        """Attach the fleet's budget hierarchy (a
        :class:`~repro.core.hierarchy.PowerHierarchy`; called by
        FleetSimulator). Scope groups are fixed here, but each group's
        envelope is read *live* from its owning node's budget on every pass:
        rebalancing never changes those budgets under a flat scope (so this
        is bit-identical to the old frozen-at-bind envelopes on healthy
        fleets, tier-1-asserted), but a chaos-engine derate does — the
        controller must re-divide the watts actually deliverable *now*, not
        the watts provisioning promised. Under ``scope="tree"`` only the
        root envelope is read; interior envelopes are re-divided
        recursively. Binding resets the controller's schedule and event
        log, so one controller instance reused across fleets starts each
        run fresh."""
        self._next_t = None
        self.events = []
        self._hierarchy = hierarchy
        if self.scope == "rack":
            self._groups = [hierarchy.subtree_leaves(p)
                            for p in hierarchy.leaf_parents]
            self._group_nodes = [int(p) for p in hierarchy.leaf_parents]
        elif self.scope == "cluster":
            self._groups = [np.arange(hierarchy.n_leaves)]
            self._group_nodes = [hierarchy.root]
        else:  # tree: recursion walks the hierarchy itself
            self._groups = []
            self._group_nodes = []

    def _settle(self, target: np.ndarray, before_g: np.ndarray,
                envelope: float,
                caps: Optional[np.ndarray] = None) -> np.ndarray:
        """Floor, low-pass, and exactly re-normalize one division of
        ``envelope`` across a sibling group (rows of a rack, racks of a PDU
        set, ...). ``caps`` are the group's physical capacity ceilings
        (``PowerHierarchy.node_cap_w``, +inf when healthy): a chaos-derated
        member never receives more than its hardware can deliver, and the
        clipped watts go to siblings with headroom instead. Conservation
        against the envelope is asserted here, so every node division in
        every scope is checked; only a group capped *in its entirety* below
        the envelope may fall short (the shortfall is physically stranded —
        simultaneous sibling derates — and shows up in
        ``conservation_errors``)."""
        n = len(before_g)
        floor = self.min_share * envelope / n
        stepped = before_g + self.alpha * (np.maximum(target, floor)
                                           - before_g)
        stepped = np.maximum(stepped, floor)
        # exact conservation: scale the above-floor slack to the envelope
        slack = stepped - floor
        total_slack = float(slack.sum())
        budget_slack = envelope - floor * n
        if total_slack > 0.0:
            new = floor + slack * (budget_slack / total_slack)
        else:
            new = np.full(n, envelope / n)
        if caps is not None and bool(np.any(new > caps)):
            new = self._clamp_to_caps(new, np.asarray(caps, float),
                                      envelope, floor)
        total = float(new.sum())
        assert total <= envelope + CONSERVATION_ATOL, \
            (f"rebalance broke conservation: group sum {total:.6f} "
             f"> envelope {envelope:.6f}")
        assert (total >= envelope - CONSERVATION_ATOL
                or (caps is not None
                    and bool(np.all(new >= caps - CONSERVATION_ATOL)))), \
            (f"rebalance broke conservation: group sum {total:.6f} != "
             f"envelope {envelope:.6f} with capacity headroom left")
        get_recorder().counter("controller_conservation_checks_total")
        return new

    @staticmethod
    def _clamp_to_caps(new: np.ndarray, caps: np.ndarray, envelope: float,
                       floor: float) -> np.ndarray:
        """Clip each member to its capacity cap and hand the clipped watts
        to siblings with headroom (proportional to remaining headroom, or to
        current size among uncapped members), iterating to a fixed point —
        each round pins at least one more member at its cap, so the loop is
        bounded by the group size."""
        new = np.minimum(new, caps)
        for _ in range(len(new)):
            deficit = envelope - float(new.sum())
            if deficit <= CONSERVATION_ATOL:
                break
            head = caps - new
            open_ = head > CONSERVATION_ATOL
            if not bool(open_.any()):
                break  # every member pinned at a finite cap: watts stranded
            if bool(np.isinf(head[open_]).any()):
                weight = np.where(np.isinf(head), np.maximum(new, floor), 0.0)
            else:
                weight = np.where(open_, head, 0.0)
            new = np.minimum(new + deficit * weight / float(weight.sum()),
                             caps)
        return new

    def _tree_divide(self, demand_leaf: np.ndarray,
                     before_leaf: np.ndarray) -> Optional[np.ndarray]:
        """One recursive top-down pass over every interior node: each node
        re-divides its envelope across its children, the root's envelope
        frozen, every child's new budget becoming the envelope its own
        division runs under. Returns the full ``[N]`` post-pass node budget
        vector, or None when the policy declined to move anything."""
        h = self._hierarchy
        node_demand = h.node_w(demand_leaf)
        cur = h.node_budget_w.copy()
        cur[:h.n_leaves] = before_leaf
        node_after = cur.copy()
        any_target = False
        # parents always carry higher indices than their children, so a
        # descending walk over the interior nodes is exactly top-down
        for i in range(h.n_nodes - 1, h.n_leaves - 1, -1):
            kids = h.children[i]
            envelope = float(node_after[i])
            if len(kids) < 2:
                # an only child inherits it all, up to its capacity cap
                node_after[kids] = np.minimum(envelope, h.node_cap_w[kids])
                continue
            target = self.policy.target_budgets(node_demand[kids], cur[kids],
                                                envelope)
            if target is not None:
                any_target = True
            elif envelope == float(cur[kids].sum()):
                continue  # nothing moved here or above: keep shares exactly
            else:
                target = cur[kids]  # rescale shares to the moved envelope
            node_after[kids] = self._settle(np.asarray(target, float),
                                            cur[kids], envelope,
                                            caps=h.node_cap_w[kids])
        return node_after if any_target else None

    def maybe_rebalance(self, t: float, rows, row_w: np.ndarray,
                        forecast_w: Optional[np.ndarray]) -> Optional[RebalanceEvent]:
        """One controller tick. Returns the applied :class:`RebalanceEvent`,
        or None when the interval hasn't elapsed or no budget moved."""
        if self._hierarchy is None:
            raise RuntimeError("FleetController.maybe_rebalance before bind()")
        if self._next_t is None:
            self._next_t = t + self.interval_s  # first interval measures
            return None
        if t < self._next_t:
            return None
        self._next_t += self.interval_s
        demand = forecast_w if (self.policy.needs_forecast
                                and forecast_w is not None) else row_w
        h = self._hierarchy
        before = np.asarray([r.provisioned_w for r in rows], float)
        node_before = node_after = None
        if self.scope == "tree":
            node_after = self._tree_divide(demand, before)
            if node_after is None:
                return None
            node_before = h.node_budget_w.copy()
            node_before[:h.n_leaves] = before
            after = node_after[:h.n_leaves].copy()
        else:
            after = before.copy()
            for idx, node in zip(self._groups, self._group_nodes):
                if len(idx) < 2:
                    continue  # a one-row group has nothing to trade
                # live envelope: flat scopes never move interior budgets,
                # but a chaos-engine derate does — divide what the node can
                # actually deliver now
                envelope = float(h.node_budget_w[node])
                target = self.policy.target_budgets(demand[idx], before[idx],
                                                    envelope)
                if target is None:
                    continue
                after[idx] = self._settle(target, before[idx], envelope,
                                          caps=h.node_cap_w[idx])
        moved_w = float(np.abs(after - before).sum()) / 2.0
        if moved_w <= self.deadband_w:
            return None
        for r, b in zip(rows, after):
            if b != r.provisioned_w:
                r.set_budget(float(b), t)
        # commit the new budgets into the hierarchy so published group
        # fractions (and the next pass) see the budgets actually in force
        if node_after is not None:
            h.node_budget_w[:] = node_after
        else:
            h.node_budget_w[:h.n_leaves] = after
        ev = RebalanceEvent(t=t, budgets_before_w=before, budgets_after_w=after,
                            demand_w=np.asarray(demand, float).copy(),
                            policy=self.policy.name,
                            node_budgets_before_w=node_before,
                            node_budgets_after_w=node_after)
        self.events.append(ev)
        rec = get_recorder()
        if rec.enabled:
            rec.counter("controller_rebalance_total",
                        policy=self.policy.name, scope=self.scope)
            rec.observe("controller_moved_watts", moved_w)
            rec.event("controller", "rebalance", t=t,
                      policy=self.policy.name, scope=self.scope,
                      moved_w=round(moved_w, 6))
            # per-node budget deltas: the post-rebalance budget in force at
            # every named node (leaves carry row budgets; under tree scope
            # the interior nodes move too)
            names = h.names
            node_b = node_after if node_after is not None else None
            if node_b is None:
                node_b = h.node_budget_w
            for name, b in zip(names, node_b):
                rec.gauge("controller_node_budget_w", float(b), node=name)
        return ev


# ---------------------------------------------------------------------------
# registry (ControllerSpec round-trips through these by name)
# ---------------------------------------------------------------------------

REBALANCE_BUILDERS: Dict[str, Callable[..., RebalancePolicy]] = {
    "static": StaticBudgetPolicy,
    "proportional": ProportionalDemandPolicy,
    "predictive": PredictiveRebalancePolicy,
}


def build_rebalance_policy(kind: str, params: Dict[str, Any] = None) -> RebalancePolicy:
    """A fresh rebalance policy instance by registry name."""
    try:
        builder = REBALANCE_BUILDERS[kind]
    except KeyError:
        known = ", ".join(sorted(REBALANCE_BUILDERS))
        raise KeyError(
            f"unknown rebalance policy {kind!r}; registered: {known}") from None
    return builder(**(params or {}))


def build_controller(spec) -> FleetController:
    """A :class:`FleetController` from a serializable
    :class:`~repro.experiments.scenario.ControllerSpec`."""
    return FleetController(
        build_rebalance_policy(spec.kind, spec.params),
        interval_s=spec.interval_s, scope=spec.scope,
        alpha=spec.alpha, min_share=spec.min_share,
        deadband_w=spec.deadband_w)
