"""Routing-decision attribution: which dispatch choices cost latency.

Joins the fleet's decision log (:class:`~repro.fleet.fleet.RoutingDecision`)
with per-row completion records to attribute SLO impact and queueing delay
to each routing decision group — per target row, and per router reason tag
(e.g. ``cap-aware/uncapped`` vs ``cap-aware/t2``), per priority. Impact here
is relative to the unqueued, uncapped ideal latency of the request's
workload class (the row simulator's own ideal), so attribution works on a
single policy run; experiment-level SLO gates still use the paired
uncapped-reference comparison from ``run_experiment``.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List

import numpy as np

from repro.core.simulator import Request, WorkloadClass
from repro.core.slo import LatencyStats
from repro.fleet.fleet import FleetResult, RoutingDecision


@dataclass
class DecisionGroupStats:
    """Latency accounting for one group of routing decisions."""

    n_routed: int = 0
    n_completed: int = 0
    stats: LatencyStats = field(default_factory=LatencyStats)
    queue_delays_hp: List[float] = field(default_factory=list, repr=False)
    queue_delays_lp: List[float] = field(default_factory=list, repr=False)

    def queue_delay_mean(self, priority: str) -> float:
        xs = self.queue_delays_hp if priority == "high" else self.queue_delays_lp
        return float(np.mean(xs)) if xs else 0.0

    def queue_delay_p99(self, priority: str) -> float:
        xs = self.queue_delays_hp if priority == "high" else self.queue_delays_lp
        return float(np.percentile(np.asarray(xs), 99)) if xs else 0.0


@dataclass
class RoutingAttribution:
    """SLO impact and queueing delay per routing decision group."""

    per_row: Dict[int, DecisionGroupStats]
    per_reason: Dict[str, DecisionGroupStats]
    n_offered: int
    n_admitted: int
    n_shed: Dict[str, int]

    def summary(self) -> Dict[str, float]:
        out: Dict[str, float] = {
            "n_offered": float(self.n_offered),
            "n_admitted": float(self.n_admitted),
            "shed_hp": float(self.n_shed.get("high", 0)),
            "shed_lp": float(self.n_shed.get("low", 0)),
        }
        for row, g in sorted(self.per_row.items()):
            out[f"row{row}_hp_p99"] = g.stats.percentile("high", 99)
            out[f"row{row}_qdelay_hp_mean"] = g.queue_delay_mean("high")
        return out


def _ideal_latency(req: Request, workloads: List[WorkloadClass]) -> float:
    timing = workloads[req.wl].timing
    return timing.t_prefill + req.out_tokens * timing.t_token


def attribute_routing(fres: FleetResult, requests: List[Request],
                      workloads: List[WorkloadClass]) -> RoutingAttribution:
    """Per-row and per-reason latency attribution for one fleet run.

    Requests that were shed or still in flight at the end of the run appear
    in ``n_routed`` but not ``n_completed``; conservation over the decision
    log (offered == admitted + shed) is the fleet driver's invariant.
    """
    by_rid = {r.rid: r for r in requests}
    latencies = fres.merged_latencies()
    qdelays = fres.merged_queue_delays()
    per_row: Dict[int, DecisionGroupStats] = {}
    per_reason: Dict[str, DecisionGroupStats] = {}

    def groups_for(d: RoutingDecision):
        yield per_row.setdefault(d.row, DecisionGroupStats())
        yield per_reason.setdefault(d.reason, DecisionGroupStats())

    for d in fres.decisions:
        if d.row < 0:
            g = per_reason.setdefault(d.reason, DecisionGroupStats())
            g.n_routed += 1
            continue
        req = by_rid[d.rid]
        lat = latencies.get(d.rid)
        for g in groups_for(d):
            g.n_routed += 1
            if lat is None:
                continue
            g.n_completed += 1
            g.stats.add(d.priority, lat, _ideal_latency(req, workloads))
            qd = qdelays.get(d.rid)
            if qd is not None:
                (g.queue_delays_hp if d.priority == "high"
                 else g.queue_delays_lp).append(qd)
    return RoutingAttribution(
        per_row=per_row,
        per_reason=per_reason,
        n_offered=fres.n_offered,
        n_admitted=fres.n_admitted,
        n_shed=dict(fres.n_shed),
    )
