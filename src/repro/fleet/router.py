"""Pluggable fleet routing policies and priority-aware admission control.

A :class:`Router` picks the row each admitted request lands on, from a list
of :class:`RowView` snapshots (what a real cluster dispatcher observes: queue
depth of the request's server pool, row power against its budget, and the
controller's *commanded* cap state — the dispatcher and the rack manager
share a control plane, so cap commands are visible before they actuate
through the 40 s out-of-band path). An :class:`AdmissionController` decides
first whether the request runs at all: under a power emergency (cluster power
near the envelope, or any row powerbraked) low-priority work is shed instead
of queued, trading LP goodput for HP latency — the POLCA priority contract
applied at the fleet door rather than per-server. ``shed-lp`` sheds the
whole LP stream for the duration; ``shed-tokens`` meters the shedding to a
configured token relief rate (non-boolean shedding, same LP-first ordering).

Routers and admission controllers are registered by name so
:class:`~repro.experiments.scenario.RoutingSpec` stays JSON-serializable:

  | router           | decision                                             |
  | round-robin      | next row, state-blind                                |
  | jsq              | fewest pending requests in the request's server pool |
  | power-headroom   | most watts of headroom against the row budget        |
  | cap-aware        | least cap-severe tier for the request's priority,    |
  |                  | join-shortest-queue within the tier                  |
  | forecast-aware   | cap-aware cost plus a graded penalty on rows whose   |
  |                  | forecast power crosses the budget over the 40 s OOB  |
  |                  | horizon (consumes the shared PowerForecaster)        |
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core.simulator import Request


@dataclass(frozen=True)
class RowView:
    """One row's dispatcher-visible state at an arrival instant. Pool fields
    describe the request's candidate server pool (same candidate rule the row
    applies internally: workload class + priority, falling back to the whole
    class when the priority sub-pool is empty)."""

    index: int
    power_frac: float  # row power / row budget
    headroom_w: float  # row budget - row power (watts)
    braked: bool
    t1_capped: bool
    t2_capped: bool
    hp_capped: bool
    pool_size: int
    pool_idle: int  # idle servers in the pool
    pool_queued: int  # requests waiting in pool buffers
    # predicted row power / row budget over the 40 s OOB horizon, from the
    # fleet's shared PowerForecaster (one-tick-stale, like the group fracs);
    # None when no forecast consumer is configured
    forecast_frac: Optional[float] = None

    @property
    def pool_pending(self) -> int:
        """In-flight + buffered work the pool already owes."""
        return self.pool_queued + (self.pool_size - self.pool_idle)


@dataclass(frozen=True)
class FleetView:
    """Fleet-level state for admission decisions (cluster fraction is the
    one-tick-stale aggregate the rack managers publish)."""

    t: float = 0.0
    cluster_frac: float = 0.0
    n_braked: int = 0


class Router:
    """Protocol: ``route(req, views) -> (row_index, reason)``. ``reason`` is
    a short tag carried into the per-decision telemetry so SLO impact can be
    attributed to routing behavior (``fleet.metrics``). Routers that never
    read row state set ``needs_views = False`` and the fleet driver skips
    the per-arrival pool scans (it passes index-only placeholder views)."""

    name: str = "router"
    needs_views: bool = True
    # routers that read RowView.forecast_frac set this; the fleet driver
    # then maintains a shared PowerForecaster on the telemetry grid
    needs_forecast: bool = False

    def route(self, req: Request, views: List[RowView]) -> Tuple[int, str]:
        raise NotImplementedError


@dataclass
class RoundRobinRouter(Router):
    """State-blind baseline: rows in cyclic order."""

    name: str = "round-robin"
    needs_views: bool = False
    _next: int = 0

    def route(self, req: Request, views: List[RowView]) -> Tuple[int, str]:
        i = self._next % len(views)
        self._next += 1
        return views[i].index, "round-robin"


@dataclass
class JoinShortestQueueRouter(Router):
    """Fewest pending requests in the request's server pool; ties go to the
    lowest row index (deterministic)."""

    name: str = "jsq"

    def route(self, req: Request, views: List[RowView]) -> Tuple[int, str]:
        best = min(views, key=lambda v: (v.pool_pending, v.index))
        return best.index, "jsq"


@dataclass
class PowerHeadroomRouter(Router):
    """Most watts of slack against the row budget — spreads *power*, not
    queue depth, so hot rows shed load before they cross a cap threshold."""

    name: str = "power-headroom"

    def route(self, req: Request, views: List[RowView]) -> Tuple[int, str]:
        best = max(views, key=lambda v: (v.headroom_w, -v.index))
        return best.index, "power-headroom"


def _severity_tag(v: RowView, priority: str) -> str:
    if v.braked:
        return "braked"
    if v.hp_capped and priority == "high":
        return "hp-capped"
    if v.t2_capped:
        return "t2"
    if v.t1_capped:
        return "t1"
    return "uncapped"


@dataclass
class CapAwareRouter(Router):
    """Steer work away from frequency-capped and braked rows *proportionally
    to how much they would hurt*: each row is scored by its normalized pool
    load plus a cap-severity penalty for the request's priority, and the
    cheapest row wins. Braked rows carry a prohibitive penalty (288 MHz
    service is catastrophic — they are a last resort); T1/T2/HP caps carry
    graded penalties measured in pool-load units, so a capped row is still
    used once the uncapped rows queue deeper than the cap would cost. A
    strict avoid-capped-tiers preference instead collapses load onto the
    uncapped rows and oscillates their caps; the graded cost is what recovers
    the HP SLO under an oversubscribed, partially-capped cluster (the
    fleet_routing benchmark's headline)."""

    # penalties in units of pool load (pending work per pool server); the
    # defaults mirror how much each state actually slows service: a brake
    # (288 MHz) is ~5x slowdown — prohibitive — while T1/T2/HP frequency
    # caps cost <= ~10% and should only tip near-tie decisions (heavier
    # penalties over-divert, saturating the healthy rows' pools and costing
    # more in queueing than the caps cost in service speed)
    brake_penalty: float = 10.0
    hp_cap_penalty: float = 0.3  # HP work on an HP-capped row
    t2_penalty: float = 0.05
    t1_penalty: float = 0.02
    name: str = "cap-aware"

    def _cost(self, v: RowView, priority: str) -> float:
        load = v.pool_pending / max(1, v.pool_size)
        if v.braked:
            return load + self.brake_penalty
        pen = 0.0
        if v.hp_capped and priority == "high":
            pen = self.hp_cap_penalty
        elif v.t2_capped:
            pen = self.t2_penalty
        elif v.t1_capped:
            pen = self.t1_penalty
        return load + pen

    def route(self, req: Request, views: List[RowView]) -> Tuple[int, str]:
        best = min(views, key=lambda v: (self._cost(v, req.priority), v.index))
        return best.index, f"cap-aware/{_severity_tag(best, req.priority)}"


@dataclass
class ForecastAwareRouter(CapAwareRouter):
    """Cap-aware routing that also consumes the fleet's power *forecast*
    (ROADMAP item: routers that consume the predictive policy's power
    forecast). On top of the cap-severity cost, a row whose predicted power
    over the 40 s OOB horizon crosses ``forecast_threshold`` of its budget
    pays a penalty proportional to the predicted overshoot — load is steered
    away *before* the row's controller has to cap, which is 40 s earlier
    than the commanded-cap-state signal can react. The penalty is graded for
    the same reason the cap penalties are: a hard avoid-predicted-hot rule
    collapses load onto the cold rows and makes the forecast self-defeating.

    Pairs naturally with the predictive :class:`~repro.fleet.controller.
    FleetController` (both read the same shared forecaster): the controller
    moves budget toward predicted demand while this router moves marginal
    demand away from predicted congestion."""

    # predicted crossings of T2 start costing; a predicted brake (>= 1.0 of
    # budget) costs forecast_penalty * (1 - threshold) ~ 1.1 pool-load units
    forecast_threshold: float = 0.89
    forecast_penalty: float = 10.0
    name: str = "forecast-aware"
    needs_forecast: bool = True

    def _cost(self, v: RowView, priority: str) -> float:
        cost = super()._cost(v, priority)
        if v.forecast_frac is not None and v.forecast_frac > self.forecast_threshold:
            cost += self.forecast_penalty * (v.forecast_frac
                                             - self.forecast_threshold)
        return cost

    def route(self, req: Request, views: List[RowView]) -> Tuple[int, str]:
        best = min(views, key=lambda v: (self._cost(v, req.priority), v.index))
        tag = _severity_tag(best, req.priority)
        if (tag == "uncapped" and best.forecast_frac is not None
                and best.forecast_frac > self.forecast_threshold):
            tag = "forecast-hot"
        return best.index, f"forecast-aware/{tag}"


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------

class AdmissionController:
    """Protocol: ``admit(req, fleet_view) -> bool``. Shed requests never
    reach a row; the fleet driver counts them per priority (conservation:
    admitted + shed == offered, tier-1-asserted). ``needs_view = False``
    declares the controller admits unconditionally: the driver then skips
    both the per-arrival :class:`FleetView` scan and the ``admit`` call."""

    name: str = "admission"
    needs_view: bool = True

    def admit(self, req: Request, fleet: FleetView) -> bool:
        raise NotImplementedError


@dataclass
class AdmitAll(AdmissionController):
    name: str = "admit-all"
    needs_view: bool = False

    def admit(self, req: Request, fleet: FleetView) -> bool:
        return True


@dataclass
class ShedLowPriority(AdmissionController):
    """Priority-aware load shedding: during a power emergency — cluster power
    at/above ``shed_above`` of the envelope, or any row powerbraked — LP
    requests are dropped at the fleet door instead of adding load a capped
    cluster cannot serve. HP requests are always admitted."""

    shed_above: float = 0.97
    shed_when_braked: bool = True
    name: str = "shed-lp"

    def admit(self, req: Request, fleet: FleetView) -> bool:
        if req.priority == "high":
            return True
        emergency = (fleet.cluster_frac >= self.shed_above
                     or (self.shed_when_braked and fleet.n_braked > 0))
        return not emergency


@dataclass
class ShedTokenBudget(AdmissionController):
    """Token-budget shedding: meter *how much* work is shed instead of
    shedding everything low-priority (``shed-lp``'s boolean contract).

    While a power emergency holds — same trigger as ``shed-lp``: cluster
    power at/above ``shed_above`` of the envelope, or any row powerbraked —
    the controller accrues a token *debt* at ``relief_tokens_per_s`` (plus a
    ``burst_tokens`` down payment when the emergency window opens, so relief
    starts immediately) and sheds arriving requests while the debt is
    positive, debiting each shed request's ``out_tokens`` — overshoot banks
    as signed credit, so one large shed buys admission for the arrivals
    after it. Load beyond the configured relief rate is admitted even
    mid-emergency — the non-boolean upgrade: the shed stream tracks
    ``relief_tokens_per_s`` instead of swallowing the whole LP stream.
    Ordering is shared with ``shed-lp``: LP is shed
    first and HP is never shed (the POLCA priority contract); the debt is
    capped at ``max_debt_tokens`` so a long emergency cannot bank unbounded
    shedding against the recovery, and it resets the moment the emergency
    clears."""

    shed_above: float = 0.97
    shed_when_braked: bool = True
    relief_tokens_per_s: float = 1500.0  # shed rate the emergency demands
    burst_tokens: float = 4000.0  # immediate relief when the window opens
    max_debt_tokens: float = 20000.0
    name: str = "shed-tokens"
    _debt: float = field(default=0.0, repr=False)
    _last_t: Optional[float] = field(default=None, repr=False)

    def admit(self, req: Request, fleet: FleetView) -> bool:
        emergency = (fleet.cluster_frac >= self.shed_above
                     or (self.shed_when_braked and fleet.n_braked > 0))
        if emergency:
            if self._last_t is None:  # window opens: immediate down payment
                self._debt = min(self.max_debt_tokens, self._debt
                                 + self.burst_tokens)
            else:
                self._debt = min(self.max_debt_tokens, self._debt
                                 + (fleet.t - self._last_t)
                                 * self.relief_tokens_per_s)
            self._last_t = fleet.t
        else:
            self._debt = 0.0
            self._last_t = None
        if req.priority == "high":
            return True  # LP-first, and LP always covers: HP is never shed
        if emergency and self._debt > 0.0:
            self._debt -= float(req.out_tokens)  # overshoot banks as credit
            return False
        return True


# ---------------------------------------------------------------------------
# registries (RoutingSpec round-trips through these by name)
# ---------------------------------------------------------------------------

ROUTER_BUILDERS: Dict[str, Callable[..., Router]] = {
    "round-robin": RoundRobinRouter,
    "jsq": JoinShortestQueueRouter,
    "power-headroom": PowerHeadroomRouter,
    "cap-aware": CapAwareRouter,
    "forecast-aware": ForecastAwareRouter,
}

ADMISSION_BUILDERS: Dict[str, Callable[..., AdmissionController]] = {
    "admit-all": AdmitAll,
    "shed-lp": ShedLowPriority,
    "shed-tokens": ShedTokenBudget,
}


def build_router(kind: str, params: Dict[str, Any] = None) -> Router:
    try:
        builder = ROUTER_BUILDERS[kind]
    except KeyError:
        known = ", ".join(sorted(ROUTER_BUILDERS))
        raise KeyError(f"unknown router {kind!r}; registered: {known}") from None
    return builder(**(params or {}))


def build_admission(kind: str, params: Dict[str, Any] = None) -> AdmissionController:
    try:
        builder = ADMISSION_BUILDERS[kind]
    except KeyError:
        known = ", ".join(sorted(ADMISSION_BUILDERS))
        raise KeyError(
            f"unknown admission controller {kind!r}; registered: {known}") from None
    return builder(**(params or {}))
