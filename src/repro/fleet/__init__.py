"""Fleet serving layer: power-aware request routing, admission control, and
dynamic power rebalancing over oversubscribed clusters (DESIGN.md §10–§11).

``FleetSimulator`` drives M rows from one cluster-wide arrival process;
``router`` provides pluggable routing policies (round-robin, join-shortest-
queue, power-headroom, cap-state-aware, forecast-aware) plus priority-aware
admission control; ``controller`` re-balances per-row power budgets under
the fixed rack/cluster envelope (static / proportional / predictive);
``metrics`` attributes SLO impact and queueing delay per routing decision.
Scenarios opt in declaratively via
:class:`~repro.experiments.scenario.RoutingSpec` and
:class:`~repro.experiments.scenario.ControllerSpec`.
"""

from repro.fleet.controller import (
    REBALANCE_BUILDERS,
    FleetController,
    PowerForecaster,
    ProportionalDemandPolicy,
    PredictiveRebalancePolicy,
    RebalanceEvent,
    RebalancePolicy,
    StaticBudgetPolicy,
    build_controller,
    build_rebalance_policy,
)
from repro.fleet.fleet import (
    FleetResult,
    FleetSimulator,
    RoutingDecision,
    as_sim_result,
    build_fleet,
    fleet_trace,
    row_budgets,
)
from repro.fleet.metrics import (
    DecisionGroupStats,
    RoutingAttribution,
    attribute_routing,
)
from repro.fleet.router import (
    ADMISSION_BUILDERS,
    ROUTER_BUILDERS,
    AdmissionController,
    AdmitAll,
    CapAwareRouter,
    FleetView,
    ForecastAwareRouter,
    JoinShortestQueueRouter,
    PowerHeadroomRouter,
    RoundRobinRouter,
    Router,
    RowView,
    ShedLowPriority,
    ShedTokenBudget,
    build_admission,
    build_router,
)

__all__ = [
    "ADMISSION_BUILDERS",
    "REBALANCE_BUILDERS",
    "ROUTER_BUILDERS",
    "AdmissionController",
    "AdmitAll",
    "CapAwareRouter",
    "DecisionGroupStats",
    "FleetController",
    "FleetResult",
    "FleetSimulator",
    "FleetView",
    "ForecastAwareRouter",
    "JoinShortestQueueRouter",
    "PowerForecaster",
    "PowerHeadroomRouter",
    "PredictiveRebalancePolicy",
    "ProportionalDemandPolicy",
    "RebalanceEvent",
    "RebalancePolicy",
    "RoundRobinRouter",
    "Router",
    "RoutingAttribution",
    "RoutingDecision",
    "RowView",
    "ShedLowPriority",
    "ShedTokenBudget",
    "StaticBudgetPolicy",
    "as_sim_result",
    "attribute_routing",
    "build_admission",
    "build_controller",
    "build_fleet",
    "build_rebalance_policy",
    "build_router",
    "fleet_trace",
    "row_budgets",
]
