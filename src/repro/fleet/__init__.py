"""Fleet serving layer: power-aware request routing and admission control
over oversubscribed clusters (DESIGN.md §10).

``FleetSimulator`` drives M rows from one cluster-wide arrival process;
``router`` provides pluggable routing policies (round-robin, join-shortest-
queue, power-headroom, cap-state-aware) plus priority-aware admission
control; ``metrics`` attributes SLO impact and queueing delay per routing
decision. Scenarios opt in declaratively via
:class:`~repro.experiments.scenario.RoutingSpec`.
"""

from repro.fleet.fleet import (
    FleetResult,
    FleetSimulator,
    RoutingDecision,
    as_sim_result,
    build_fleet,
    fleet_trace,
    row_budgets,
)
from repro.fleet.metrics import (
    DecisionGroupStats,
    RoutingAttribution,
    attribute_routing,
)
from repro.fleet.router import (
    ADMISSION_BUILDERS,
    ROUTER_BUILDERS,
    AdmissionController,
    AdmitAll,
    CapAwareRouter,
    FleetView,
    JoinShortestQueueRouter,
    PowerHeadroomRouter,
    RoundRobinRouter,
    Router,
    RowView,
    ShedLowPriority,
    build_admission,
    build_router,
)

__all__ = [
    "ADMISSION_BUILDERS",
    "ROUTER_BUILDERS",
    "AdmissionController",
    "AdmitAll",
    "CapAwareRouter",
    "DecisionGroupStats",
    "FleetResult",
    "FleetSimulator",
    "FleetView",
    "JoinShortestQueueRouter",
    "PowerHeadroomRouter",
    "RoundRobinRouter",
    "Router",
    "RoutingAttribution",
    "RoutingDecision",
    "RowView",
    "ShedLowPriority",
    "as_sim_result",
    "attribute_routing",
    "build_admission",
    "build_fleet",
    "build_router",
    "fleet_trace",
    "row_budgets",
]
