"""Request-level fleet serving: one arrival process, M rows, a router.

The provisioning layer answers "how many servers fit the envelope"; this
layer answers "how does traffic *land* on those rows once some of them are
frequency-capped". :class:`FleetSimulator` drives M
:class:`~repro.core.simulator.RowSimulator`\\ s from a single cluster-wide
arrival stream (seeded through the same ``core.traces`` generator registry
the per-row path uses): each arrival is first passed through an admission
controller (LP shedding under power emergencies), then placed on a row by a
pluggable :class:`~repro.fleet.router.Router`, and injected into that row's
event queue via ``RowSimulator.inject``. Rows keep their own policies,
budgets, and event queues; the fleet driver interleaves arrival dispatch
with the telemetry-grid lockstep the ClusterSimulator established, publishing
one-tick-stale rack/cluster power fractions into every row before each tick.

Drive modes mirror ``RowSimulator``: ``run()`` is ``start`` +
``advance_to(duration)`` + ``finalize``, and ``advance_to`` is
stride-invariant, so the Monte-Carlo engine locksteps fleet members exactly
like row members. A single-row fleet under any router replays the standalone
``RowSimulator`` bit-for-bit on the same scenario (the cluster-wide trace
degenerates to the row trace, and injected arrivals reproduce the trace-fed
event order — tier-1-asserted).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.chaos.injector import ChaosInjector, FaultRecord
from repro.core.hierarchy import PowerHierarchy
from repro.core.simulator import Request, RowSimulator, SimConfig, SimResult
from repro.core.slo import LatencyStats
# row_budgets lives with the other budget-resolution rules in the
# experiments layer; re-exported here because fleet callers reach for it
# next to build_fleet
from repro.experiments.runner import row_budgets  # noqa: F401
from repro.fleet.controller import FleetController, PowerForecaster, RebalanceEvent
from repro.obs.alerts import AlertEngine, AlertEvent, AlertSpec, coerce_alerts
from repro.obs.metrics import get_recorder
from repro.fleet.router import (
    AdmissionController,
    AdmitAll,
    FleetView,
    Router,
    RowView,
)


@dataclass(frozen=True)
class RoutingDecision:
    """One dispatch: which row got the request (``row == -1`` means shed) and
    the router's reason tag. This is the join key for per-decision SLO and
    queueing-delay attribution (``fleet.metrics``)."""

    rid: int
    t: float
    wl: int
    priority: str
    row: int
    reason: str


@dataclass
class FleetResult:
    """Structured fleet telemetry: per-row results, the full decision log,
    shed accounting, and cluster-level power series on the telemetry grid."""

    row_results: List[SimResult]
    decisions: List[RoutingDecision] = field(repr=False)
    n_offered: int = 0
    n_admitted: int = 0
    n_shed: Dict[str, int] = field(default_factory=dict)  # per priority
    power_t: np.ndarray = field(default=None, repr=False)  # [T]
    row_power_frac: np.ndarray = field(default=None, repr=False)  # [T, R]
    rack_power_frac: np.ndarray = field(default=None, repr=False)  # [T, K]
    cluster_power_frac: np.ndarray = field(default=None, repr=False)  # [T]
    shed_cum: np.ndarray = field(default=None, repr=False)  # [T] total shed
    n_brakes: int = 0
    peak_cluster_frac: float = 0.0
    mean_cluster_frac: float = 0.0
    # dynamic rebalancing telemetry (empty without a FleetController): the
    # per-tick row budgets the row fractions were measured against, and the
    # applied rebalance events (fleet.controller.RebalanceEvent)
    row_budget_w: np.ndarray = field(default=None, repr=False)  # [T, R]
    rebalances: List[RebalanceEvent] = field(default_factory=list, repr=False)
    # full budget-tree telemetry (leaves first, root last; see
    # core.hierarchy.PowerHierarchy): per-node power fractions and the
    # per-tick node budgets they were measured against. rack_power_frac /
    # cluster_power_frac above are the leaf-parent / root slices of this.
    node_power_frac: np.ndarray = field(default=None, repr=False)  # [T, N]
    node_budget_w: np.ndarray = field(default=None, repr=False)  # [T, N]
    node_names: tuple = ()
    # chaos-engine audit (empty without an injector): every applied fault
    # phase with full before/after node budgets (chaos.injector.FaultRecord),
    # and the per-tick row-liveness mask crashes/revivals toggled
    fault_events: List[FaultRecord] = field(default_factory=list, repr=False)
    row_alive: np.ndarray = field(default=None, repr=False)  # [T, R] bool
    # online alerting audit (empty without Scenario.alerts): every
    # engage/release transition the AlertEngine fired on the tick lockstep
    # (obs.alerts.AlertEvent) — write-only, so carrying alerts never
    # changes any other field (tier-1-asserted)
    alert_events: List[AlertEvent] = field(default_factory=list, repr=False)

    @property
    def n_rebalances(self) -> int:
        return len(self.rebalances)

    @property
    def n_fault_events(self) -> int:
        return len(self.fault_events)

    @property
    def n_alert_events(self) -> int:
        return len(self.alert_events)

    def alerts_of(self, phase: Optional[str] = None,
                  kind: Optional[str] = None) -> List[AlertEvent]:
        return [a for a in self.alert_events
                if (phase is None or a.phase == phase)
                and (kind is None or a.kind == kind)]

    def budget_moved_w(self) -> float:
        """Total watts of budget the controller moved over the run."""
        return float(sum(ev.moved_w() for ev in self.rebalances))

    @property
    def n_rows(self) -> int:
        return len(self.row_results)

    @property
    def n_shed_total(self) -> int:
        return sum(self.n_shed.values())

    def merged_latencies(self) -> Dict[int, float]:
        """rid -> latency across all rows (rids are unique cluster-wide: the
        fleet serves one arrival stream)."""
        out: Dict[int, float] = {}
        for rr in self.row_results:
            out.update(rr.latencies)
        return out

    def merged_queue_delays(self) -> Dict[int, float]:
        out: Dict[int, float] = {}
        for rr in self.row_results:
            out.update(rr.queue_delays)
        return out


def as_sim_result(fres: FleetResult) -> SimResult:
    """Collapse a fleet run into the row-shaped ``SimResult`` the ensemble
    engine and SLO gates consume: pooled latencies, summed counters, and the
    cluster-level power series (fractions of the cluster budget)."""
    lat = LatencyStats(
        hp_impacts=[x for rr in fres.row_results for x in rr.latency.hp_impacts],
        lp_impacts=[x for rr in fres.row_results for x in rr.latency.lp_impacts])
    # fleet-level brake state: any row braked at that sample (rows share the
    # telemetry grid, but a revived row can have a ragged tail — skip then)
    braked = [rr.braked_series for rr in fres.row_results]
    if braked and all(b is not None and len(b) == len(braked[0])
                      for b in braked):
        braked_series = np.any(np.stack(braked), axis=0)
    else:
        braked_series = None
    return SimResult(
        latency=lat,
        n_brakes=fres.n_brakes,
        n_dropped=sum(rr.n_dropped for rr in fres.row_results) + fres.n_shed_total,
        n_completed=sum(rr.n_completed for rr in fres.row_results),
        served_tokens=sum(rr.served_tokens for rr in fres.row_results),
        peak_power_frac=fres.peak_cluster_frac,
        mean_power_frac=fres.mean_cluster_frac,
        power_t=fres.power_t,
        power_w=fres.cluster_power_frac,
        braked_series=braked_series,
        latencies=fres.merged_latencies(),
        cap_events=sum(rr.cap_events for rr in fres.row_results),
        queue_delays=fres.merged_queue_delays(),
    )


class FleetSimulator:
    """Dispatch one cluster-wide arrival stream over M rows.

    ``rows`` must be constructed with empty request lists (arrivals come from
    the dispatcher); ``requests`` must be sorted by arrival time (the trace
    generators emit them sorted). Budgets above the row default to the sum of
    their children's budgets, exactly like :class:`ClusterSimulator`; pass an
    explicit ``hierarchy`` (:class:`~repro.core.hierarchy.PowerHierarchy`)
    for arbitrary-depth site topologies — the default is the classic
    two-level row -> rack -> cluster split.
    """

    def __init__(self, rows: List[RowSimulator], requests: List[Request],
                 router: Router, admission: Optional[AdmissionController] = None,
                 *, rows_per_rack: int = 2,
                 rack_budget_w: Optional[List[float]] = None,
                 cluster_budget_w: Optional[float] = None,
                 telemetry_s: Optional[float] = None,
                 controller: Optional[FleetController] = None,
                 hierarchy: Optional[PowerHierarchy] = None,
                 chaos: Optional[ChaosInjector] = None,
                 alerts: Optional[List[AlertSpec]] = None):
        if not rows:
            raise ValueError("FleetSimulator needs at least one row")
        from repro.experiments.cluster import resolve_row_hierarchy
        self.rows = rows
        self.requests = requests
        self.router = router
        self.admission = admission if admission is not None else AdmitAll()
        self.hierarchy = resolve_row_hierarchy(
            rows, hierarchy, rows_per_rack=rows_per_rack,
            rack_budget_w=rack_budget_w, cluster_budget_w=cluster_budget_w)
        self.telemetry_s = float(telemetry_s or rows[0].cfg.telemetry_s)
        self.duration = max(r.duration for r in rows)
        self.controller = controller
        if controller is not None:
            controller.bind(self.hierarchy)
        # one shared forecaster feeds both the predictive controller and
        # forecast-consuming routers; None when nothing reads forecasts, so
        # controller-less fleets skip the per-tick estimator entirely
        need_fc = (getattr(router, "needs_forecast", False)
                   or (controller is not None and controller.needs_forecast))
        self._forecaster = (PowerForecaster(len(rows),
                                            horizon_s=rows[0].cfg.oob_latency_s)
                            if need_fc else None)
        self._forecast_frac: Optional[np.ndarray] = None  # [R], one-tick-stale

        # chaos engine: the injector rides the tick lockstep (polled after
        # the controller's pass) and toggles row_alive on crash/revive. The
        # mask gates *dispatch only*: dead rows drain their in-flight work
        # and keep reporting telemetry (a crashed row still draws power
        # until it winds down).
        self.row_alive = np.ones(len(rows), dtype=bool)
        self._any_dead = False
        self._alive_samples: List[np.ndarray] = []
        self.chaos = chaos
        if chaos is not None:
            chaos.bind(self)  # validates the timeline before anything runs

        # online alerting: the engine evaluates its rule set against each
        # tick's already-sampled telemetry, after the chaos poll. Strictly
        # write-only (events out, nothing read back into control flow), so
        # an alerted fleet replays an unalerted one bit for bit.
        specs = coerce_alerts(alerts)
        self.alert_engine = (
            AlertEngine(specs, tick_s=self.telemetry_s,
                        horizon_s=rows[0].cfg.oob_latency_s)
            if specs else None)
        if self.alert_engine is not None:
            self.alert_engine.bind(self)  # validates node targets up front

        self.decisions: List[RoutingDecision] = []
        self.n_shed: Dict[str, int] = {"high": 0, "low": 0}
        self._started = False
        self._i = 0  # next undispatched request
        self._next_tick = self.telemetry_s
        self._prev_row_w: Optional[np.ndarray] = None
        self._stale_cluster_frac = 0.0
        self._ticks: List[float] = []
        self._samples: List[np.ndarray] = []
        self._budget_samples: List[np.ndarray] = []
        self._interior_budget_samples: List[np.ndarray] = []
        self._shed_cum: List[int] = []
        # index-only placeholder views for routers with needs_views=False
        self._blind_views = [
            RowView(index=i, power_frac=0.0, headroom_w=0.0, braked=False,
                    t1_capped=False, t2_capped=False, hp_capped=False,
                    pool_size=1, pool_idle=1, pool_queued=0)
            for i in range(len(rows))]

    # ------------------------------------------------------------------
    def _advance_rows(self, t: float):
        # no alive-gating: a drained row returns immediately, and inject()
        # can revive one inside the final partial telemetry window
        for r in self.rows:
            r.advance_to(min(t, r.duration))

    def _publish_group_fracs(self, row_w: np.ndarray):
        frac = self.hierarchy.publish(self.rows, row_w)
        self._stale_cluster_frac = float(frac[self.hierarchy.root])

    def _view(self, i: int, req: Request) -> RowView:
        row = self.rows[i]
        cands = row.candidates(req.wl, req.priority)
        pol = row.policy
        return RowView(
            index=i,
            power_frac=row.row_power / row.provisioned_w,
            headroom_w=row.provisioned_w - row.row_power,
            braked=bool(getattr(pol, "braked", False)),
            t1_capped=bool(getattr(pol, "t1_capped", False)),
            t2_capped=bool(getattr(pol, "t2_capped", False)),
            hp_capped=bool(getattr(pol, "hp_capped", False)),
            pool_size=len(cands),
            pool_idle=sum(1 for s in cands if s.state == "idle"),
            pool_queued=sum(len(s.queue) for s in cands),
            forecast_frac=(float(self._forecast_frac[i])
                           if self._forecast_frac is not None else None),
        )

    def _fleet_view(self, t: float) -> FleetView:
        n_braked = sum(1 for r in self.rows
                       if getattr(r.policy, "braked", False))
        return FleetView(t=t, cluster_frac=self._stale_cluster_frac,
                         n_braked=n_braked)

    @property
    def n_processed(self) -> int:
        """Arrivals the dispatcher has consumed so far (dispatched or
        shed) — the alert engine's offered-traffic denominator."""
        return self._i

    def set_row_alive(self, i: int, alive: bool) -> None:
        """Fence (or unfence) row ``i`` from dispatch — the chaos engine's
        crash/revive primitive. Idempotent; budgets are untouched."""
        self.row_alive[int(i)] = bool(alive)
        self._any_dead = not bool(self.row_alive.all())

    def _dispatch(self, req: Request):
        # rows are current as of req.t_arrival (the driver advances them to
        # the arrival instant before routing)
        if self.admission.needs_view and not self.admission.admit(
                req, self._fleet_view(req.t_arrival)):
            self.n_shed[req.priority] = self.n_shed.get(req.priority, 0) + 1
            self.decisions.append(RoutingDecision(
                req.rid, req.t_arrival, req.wl, req.priority, -1,
                f"shed/{self.admission.name}"))
            get_recorder().counter("fleet_shed_total",
                                   reason=f"shed/{self.admission.name}",
                                   priority=req.priority)
            return
        if self._any_dead:
            # crashed rows are invisible to the router; with none left the
            # arrival is shed (counted, so admitted + shed == offered holds
            # through any outage)
            alive = [i for i in range(len(self.rows)) if self.row_alive[i]]
            if not alive:
                self.n_shed[req.priority] = self.n_shed.get(req.priority, 0) + 1
                self.decisions.append(RoutingDecision(
                    req.rid, req.t_arrival, req.wl, req.priority, -1,
                    "shed/row-crash"))
                get_recorder().counter("fleet_shed_total",
                                       reason="shed/row-crash",
                                       priority=req.priority)
                return
            views = ([self._view(i, req) for i in alive]
                     if self.router.needs_views
                     else [self._blind_views[i] for i in alive])
        else:
            # state-blind routers skip the per-pool snapshot scans entirely
            views = ([self._view(i, req) for i in range(len(self.rows))]
                     if self.router.needs_views else self._blind_views)
        row, reason = self.router.route(req, views)
        self.decisions.append(RoutingDecision(
            req.rid, req.t_arrival, req.wl, req.priority, row, reason))
        get_recorder().counter_k("fleet_dispatch_total", 1.0,
                                 (("reason", reason), ("row", str(row))))
        self.rows[row].inject(req)

    # ------------------------------------------------------------------
    def start(self):
        """Seed every row's event queue (idempotent). Part of the
        ``start`` / ``advance_to`` / ``finalize`` drive protocol the
        Monte-Carlo engine locksteps; ``run()`` composes all three."""
        if self._started:
            return
        self._started = True
        for r in self.rows:
            r.start()

    def advance_to(self, t_target: float) -> bool:
        """Process every arrival and telemetry tick with t <= t_target, in
        time order. Returns False once all arrivals are dispatched and the
        tick grid is past the fleet duration (no more driver work)."""
        t_target = min(t_target, self.duration)
        while True:
            t_arr = (self.requests[self._i].t_arrival
                     if self._i < len(self.requests) else math.inf)
            t_next = min(t_arr, self._next_tick)
            if t_next > t_target:
                break
            if t_arr <= self._next_tick:
                self._advance_rows(t_arr)
                self._dispatch(self.requests[self._i])
                self._i += 1
            else:
                # telemetry tick: publish the previous tick's aggregates
                # (one tick stale, matching ClusterSimulator), advance rows
                # through the tick, then sample
                if self._prev_row_w is not None:
                    self._publish_group_fracs(self._prev_row_w)
                self._advance_rows(self._next_tick)
                row_w = np.asarray([r.row_power for r in self.rows], float)
                budgets = np.asarray([r.provisioned_w for r in self.rows], float)
                self._ticks.append(self._next_tick)
                self._samples.append(row_w)
                self._budget_samples.append(budgets)
                # interior node budgets in force this tick (the tree-scope
                # controller re-divides these; static otherwise)
                self._interior_budget_samples.append(
                    self.hierarchy.node_budget_w[self.hierarchy.n_leaves:].copy())
                self._shed_cum.append(sum(self.n_shed.values()))
                rec = get_recorder()
                if rec.enabled:
                    rec.counter_k("fleet_ticks_total")
                    rec.gauge("fleet_cluster_power_frac",
                              self._stale_cluster_frac)
                fc_w = None
                if self._forecaster is not None:
                    self._forecaster.observe(self._next_tick, row_w)
                    fc_w = self._forecaster.forecast_w()
                    self._forecast_frac = fc_w / budgets
                if self.controller is not None:
                    # budget changes land between ticks: each row's policy
                    # sees them at its own next telemetry sample (one-tick
                    # actuation delay, like every other control-plane path)
                    self.controller.maybe_rebalance(self._next_tick, self.rows,
                                                    row_w, fc_w)
                if self.chaos is not None:
                    # faults land between ticks too, after the controller's
                    # pass: the control plane always acts on pre-fault state
                    # and discovers the fault at the next sample — the same
                    # actuation delay a real OOB plane has
                    self.chaos.poll(self._next_tick, self)
                    self._alive_samples.append(self.row_alive.copy())
                if self.alert_engine is not None:
                    # alert evaluation closes the tick: it sees the budgets
                    # this tick's fractions were measured against (pre-
                    # controller, matching FleetResult.node_power_frac) and
                    # the post-poll chaos state, and writes nothing back
                    self.alert_engine.on_tick(
                        self._next_tick, self, row_w, budgets,
                        self._interior_budget_samples[-1])
                self._prev_row_w = row_w
                self._next_tick += self.telemetry_s
        return not (self._i >= len(self.requests)
                    and self._next_tick > self.duration)

    def finalize(self) -> FleetResult:
        """Drain every row to its duration and assemble the structured
        :class:`FleetResult` (per-row results, decision log, shed accounting,
        folded power series, and — under a controller — the per-tick budget
        matrix plus applied rebalance events). Call exactly once, after the
        driver loop is done."""
        for r in self.rows:  # drain events between the last tick and duration
            r.advance_to(r.duration)
        row_results = [r.finalize() for r in self.rows]
        h = self.hierarchy
        power = (np.stack(self._samples) if self._samples
                 else np.zeros((0, len(self.rows))))  # [T, R] watts
        budgets = (np.stack(self._budget_samples) if self._budget_samples
                   else np.zeros((0, len(self.rows))))  # [T, R] watts
        interior = (np.stack(self._interior_budget_samples)
                    if self._interior_budget_samples
                    else np.zeros((0, h.n_nodes - h.n_leaves)))
        power_t = np.asarray(self._ticks)
        # every node fraction is measured against the budget actually in
        # force at that tick: per-row budgets move under any rebalancing
        # controller, interior budgets only under scope="tree" (identical to
        # the static fold when nothing ever moved)
        node_budget = np.concatenate([budgets, interior], axis=1)  # [T, N]
        node_frac = h.fold(power, node_budget_w=node_budget)
        rack_frac = node_frac[:, h.leaf_parents]
        cluster_frac = node_frac[:, h.root]
        row_frac = node_frac[:, :h.n_leaves]
        return FleetResult(
            row_results=row_results,
            decisions=self.decisions,
            n_offered=len(self.requests),
            n_admitted=len(self.requests) - sum(self.n_shed.values()),
            n_shed=dict(self.n_shed),
            power_t=power_t,
            row_power_frac=row_frac,
            rack_power_frac=rack_frac,
            cluster_power_frac=cluster_frac,
            shed_cum=np.asarray(self._shed_cum),
            n_brakes=sum(rr.n_brakes for rr in row_results),
            peak_cluster_frac=float(cluster_frac.max()) if len(cluster_frac) else 0.0,
            mean_cluster_frac=float(cluster_frac.mean()) if len(cluster_frac) else 0.0,
            row_budget_w=budgets,
            rebalances=(list(self.controller.events)
                        if self.controller is not None else []),
            node_power_frac=node_frac,
            node_budget_w=node_budget,
            node_names=h.names,
            fault_events=(list(self.chaos.records)
                          if self.chaos is not None else []),
            row_alive=(np.stack(self._alive_samples)
                       if self._alive_samples else None),
            alert_events=(list(self.alert_engine.events)
                          if self.alert_engine is not None else []),
        )

    def run(self) -> FleetResult:
        """Standalone drive: ``start`` + ``advance_to(duration)`` +
        ``finalize`` — bit-identical to any other stride over the same
        span (the drive protocol is stride-invariant)."""
        self.start()
        self.advance_to(self.duration)
        return self.finalize()


# ---------------------------------------------------------------------------
# scenario-driven construction (shared by run_experiment and the MC engine,
# so batched fleet members stay bit-identical with sequential runs)
# ---------------------------------------------------------------------------

def fleet_trace(scenario, workloads, shares) -> List[Request]:
    """The single cluster-wide arrival stream for a fleet scenario: the
    row-trace generator sized for the whole fleet (n_rows x n_servers busy
    servers drive the occupancy-matched Poisson rates). A one-row fleet
    therefore gets exactly the standalone row trace."""
    from repro.experiments.runner import row_trace
    n_total = scenario.fleet.n_rows * scenario.fleet.n_servers
    return row_trace(scenario, workloads, shares, n_total, seed=scenario.seed)


def build_fleet(scenario, workloads, shares, server,
                budget_w: Optional[float], policy_factory,
                requests: List[Request], *, reference: bool = False) -> FleetSimulator:
    """A FleetSimulator for ``scenario`` (which must carry a RoutingSpec).

    A scenario carrying a :class:`~repro.experiments.scenario.ControllerSpec`
    additionally gets a :class:`~repro.fleet.controller.FleetController`
    rebalancing row budgets on the telemetry grid; one carrying a
    :class:`~repro.experiments.scenario.HierarchySpec` runs under that
    arbitrary-depth budget tree (interior derates propagate down to the row
    budgets, keeping the tree conservative) instead of the default two-level
    rack split.

    ``reference=True`` builds the uncapped twin: NoCap policies on
    effectively-infinite row budgets, same router and admission spec (no
    emergency ever triggers, so nothing is shed) — the paper's
    capping-impact-only baseline, fleet-shaped. References never carry a
    controller or a shaped hierarchy: with nothing capped there is no
    headroom to move, and the baseline must isolate power-management impact.

    A scenario carrying a :class:`~repro.chaos.faults.FaultSpec`
    (``Scenario.faults``) gets a fresh
    :class:`~repro.chaos.injector.ChaosInjector` riding the tick lockstep
    (fresh per fleet — Monte-Carlo members must not share actuation state;
    the timeline is validated here, before anything runs). References carry
    only the row-crash/revive subset: a crash is an environmental capacity
    loss both twins must see, while budget derates are power-plane events
    the uncapped baseline by definition doesn't have.

    A scenario carrying ``Scenario.alerts`` gets an
    :class:`~repro.obs.alerts.AlertEngine` evaluating those rules on the
    tick lockstep (write-only: transitions land in
    ``FleetResult.alert_events`` and the recorder, never in control flow).
    References never carry alerts — the uncapped twin has no power plane
    to alarm on.
    """
    from repro.core.policy import NoCap
    from repro.experiments.runner import row_sim
    from repro.fleet.controller import build_controller
    from repro.fleet.router import build_admission, build_router

    spec = scenario.routing
    if spec is None:
        raise ValueError(f"scenario {scenario.name!r} has no RoutingSpec")
    fleet = scenario.fleet
    hspec = getattr(scenario, "hierarchy", None)
    n = fleet.n_servers
    rows = []
    hierarchy = None
    if reference:
        for i in range(fleet.n_rows):
            rows.append(RowSimulator(
                workloads, server, n, 10 * n, NoCap(), [], shares,
                SimConfig(power_scale=scenario.power_scale, record_power=False),
                duration=scenario.duration_s, row_index=i))
    else:
        budgets = row_budgets(scenario, budget_w, server)
        if hspec is not None:
            # shape the per-row base budgets through the tree: derated
            # interior nodes shrink their rows' budgets
            hierarchy = hspec.build(budgets)
            budgets = [float(b) for b in hierarchy.leaf_budget_w]
        for i in range(fleet.n_rows):
            rows.append(row_sim(scenario, workloads, shares, server,
                                budgets[i], policy_factory(), [], row_index=i))
    cspec = getattr(scenario, "controller", None)
    controller = (build_controller(cspec)
                  if cspec is not None and not reference else None)
    fspec = getattr(scenario, "faults", None)
    if fspec is not None and reference:
        fspec = fspec.routing_only()
    chaos = (ChaosInjector(fspec)
             if fspec is not None and not fspec.is_noop else None)
    aspecs = getattr(scenario, "alerts", None)
    return FleetSimulator(
        rows, requests,
        router=build_router(spec.router, spec.params),
        admission=build_admission(spec.admission, spec.admission_params),
        rows_per_rack=fleet.rows_per_rack,
        telemetry_s=scenario.telemetry.telemetry_s,
        controller=controller,
        hierarchy=hierarchy,
        chaos=chaos,
        alerts=None if reference else aspecs)
