"""Training launcher: end-to-end driver over the local device set.

Example (the (b) deliverable's end-to-end run — ~100M-class model, a few
hundred steps on CPU/small TPU):

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --smoke \\
      --steps 300 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

On a pod this same driver runs under the production mesh; here the mesh spans
whatever jax.devices() offers.
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs import get_config, smoke_config
from repro.data.pipeline import DataConfig, SyntheticTokenPipeline, device_put_batch
from repro.launch.inputs import make_rules
from repro.launch.mesh import make_local_mesh, set_mesh
from repro.launch.steps import build_train_step
from repro.models import model as model_mod
from repro.models.config import ShapeConfig
from repro.models.param import init_params
from repro.obs.log import get_logger
from repro.optim import make_optimizer
from repro.runtime.fault_tolerance import StragglerMonitor, TrainSupervisor

log = get_logger(__name__)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="reduced same-family config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--data-par", type=int, default=0, help="data axis size (0=n_devices)")
    ap.add_argument("--model-par", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-interval", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    n_dev = len(jax.devices())
    dp = args.data_par or max(1, n_dev // args.model_par)
    mesh = make_local_mesh(dp, args.model_par)
    shape = ShapeConfig("cli_train", args.seq, args.batch, "train")
    rules = make_rules(cfg, shape, mesh)

    pspecs = model_mod.model_specs(cfg, mesh.shape["model"])
    opt = make_optimizer(cfg.optimizer)
    with set_mesh(mesh):
        params = init_params(pspecs, jax.random.key(0))
        opt_state = init_params(opt.init_specs(pspecs), jax.random.key(1))
    state = {"params": params, "opt": opt_state}
    n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
    log.info(f"arch={cfg.name} params={n_params/1e6:.1f}M mesh={dict(mesh.shape)}")

    pipeline = SyntheticTokenPipeline(cfg, DataConfig(args.batch, args.seq))
    step_fn = jax.jit(build_train_step(cfg, mesh, rules, opt))

    def wrapped_step(state, batch):
        with set_mesh(mesh):
            new_state, metrics = step_fn(state, batch)
            metrics = {k: float(v) for k, v in metrics.items()}
        return new_state, metrics

    sup = TrainSupervisor(wrapped_step, pipeline, args.ckpt_dir,
                          ckpt_interval=args.ckpt_interval,
                          straggler=StragglerMonitor())
    t0 = time.time()
    state, last = sup.run(state, args.steps,
                          place_batch=lambda b: device_put_batch(b, mesh, rules))
    dt = time.time() - t0
    losses = [h["loss"] for h in sup.history]
    log.info(f"done: {last} steps in {dt:.1f}s "
             f"({dt/max(1,len(sup.history)):.3f}s/step) "
             f"loss {losses[0]:.3f} -> {losses[-1]:.3f} "
             f"restarts={sup.n_restarts} stragglers={len(sup.straggler.flagged_steps)}")
    assert losses[-1] < losses[0], "training should reduce loss"
    with open("/tmp/train_history.json", "w") as f:
        json.dump(sup.history, f)


if __name__ == "__main__":
    main()
