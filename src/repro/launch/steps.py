"""Jit-able train / prefill / decode steps with explicit shardings."""

from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import model as model_mod
from repro.models.config import ModelConfig, ShapeConfig
from repro.models.param import (
    Rules,
    abstract_params,
    logical_to_spec,
    param_shardings,
    resolve_spec,
)
from repro.launch import inputs as inputs_mod
from repro.optim import Optimizer


def model_param_specs(cfg: ModelConfig, mesh, rules: Rules = None) -> Any:
    moe_shards = 0
    if rules is not None and rules.get("moe_mode") == "token":
        moe_shards = mesh.shape["data"] * mesh.shape["model"]
    return model_mod.model_specs(cfg, mesh.shape["model"], moe_shards)


def abstract_state(cfg: ModelConfig, mesh, rules: Rules, opt: Optional[Optimizer]):
    """Abstract (ShapeDtypeStruct + sharding) train/serve state."""
    pspecs = model_param_specs(cfg, mesh, rules)
    trees = {"params": pspecs}
    if opt is not None:
        trees["opt"] = opt.init_specs(pspecs)

    def to_sds(s):
        return jax.ShapeDtypeStruct(
            s.shape, s.dtype,
            sharding=NamedSharding(mesh, resolve_spec(s.shape, s.logical, rules, mesh)),
        )

    return jax.tree.map(to_sds, trees, is_leaf=lambda x: hasattr(x, "logical"))


def build_train_step(cfg: ModelConfig, mesh, rules: Rules, opt: Optimizer):
    ctx = model_mod.MeshCtx(mesh, rules)

    def train_step(state, batch):
        params = state["params"]

        def lf(p):
            return model_mod.loss_fn(cfg, p, batch, ctx)

        loss, grads = jax.value_and_grad(lf)(params)
        new_params, new_opt, gnorm = opt.update(grads, state["opt"], params)
        metrics = {"loss": loss, "grad_norm": gnorm}
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def build_prefill_step(cfg: ModelConfig, shape: ShapeConfig, mesh, rules: Rules):
    ctx = model_mod.MeshCtx(mesh, rules)
    _, dec_S = inputs_mod.split_seq(cfg, shape.seq_len)
    max_len = model_mod.cache_len(dec_S)

    def prefill_step(params, batch):
        return model_mod.prefill_fn(cfg, params, batch, ctx, max_len=max_len)

    return prefill_step


def build_decode_step(cfg: ModelConfig, mesh, rules: Rules):
    ctx = model_mod.MeshCtx(mesh, rules)

    def decode_step(params, token, pos, cache):
        return model_mod.decode_fn(cfg, params, token, pos, cache, ctx)

    return decode_step


def build_serve_step(cfg: ModelConfig, shape: ShapeConfig, mesh, rules: Rules):
    """The step a shape cell lowers: train_step for train shapes, prefill for
    prefill shapes, one-token decode for decode shapes."""
    if shape.kind == "train":
        opt = Optimizer(cfg.optimizer)
        return build_train_step(cfg, mesh, rules, opt), opt
    if shape.kind == "prefill":
        return build_prefill_step(cfg, shape, mesh, rules), None
    return build_decode_step(cfg, mesh, rules), None
