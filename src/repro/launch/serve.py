"""Serving launcher: batched prefill+decode engine with POLCA in the loop.

The engine exposes exactly the two phases the paper characterizes (prompt =
compute-spike, token = flat memory-bound draw) and reports the per-phase
roofline/power operating points from the same analytic model POLCA's
simulator uses — so `--report-power` prints the Figure-4-style phase profile
of the model being served.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \\
      --requests 8 --prompt 64 --out-tokens 32 --report-power
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_config
from repro.core.power_model import A100, ServerPower
from repro.core.workload import request_timing
from repro.launch.inputs import make_rules
from repro.launch.mesh import make_local_mesh, set_mesh
from repro.launch.steps import build_decode_step, build_prefill_step
from repro.models import model as model_mod
from repro.models.config import ShapeConfig
from repro.models.param import init_params
from repro.obs.log import get_logger

log = get_logger(__name__)


class ServeEngine:
    def __init__(self, cfg, mesh, max_len: int, batch: int):
        self.cfg, self.mesh = cfg, mesh
        shape = ShapeConfig("serve", max_len, batch, "prefill")
        self.rules = make_rules(cfg, shape, mesh)
        with set_mesh(mesh):
            self.params = init_params(model_mod.model_specs(cfg, mesh.shape["model"]),
                                      jax.random.key(0))
        self.prefill = jax.jit(build_prefill_step(cfg, shape, mesh, self.rules))
        self.decode = jax.jit(build_decode_step(cfg, mesh, self.rules))

    def generate(self, tokens: np.ndarray, n_out: int, extra_inputs=None):
        """Greedy decode. tokens: [B, S]. Returns [B, n_out]."""
        batch = {"tokens": jnp.asarray(tokens)}
        if extra_inputs:
            batch.update(extra_inputs)
        outs = []
        with set_mesh(self.mesh):
            logits, cache = self.prefill(self.params, batch)
            pos = tokens.shape[1]
            tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
            for i in range(n_out):
                outs.append(np.asarray(tok)[:, 0])
                logits, cache = self.decode(self.params, tok,
                                            jnp.asarray(pos + i, jnp.int32), cache)
                tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
        return np.stack(outs, axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt", type=int, default=64)
    ap.add_argument("--out-tokens", type=int, default=32)
    ap.add_argument("--model-par", type=int, default=1)
    ap.add_argument("--report-power", action="store_true")
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = make_local_mesh(max(1, len(jax.devices()) // args.model_par), args.model_par)
    max_len = args.prompt + args.out_tokens
    eng = ServeEngine(cfg, mesh, max_len, args.requests)

    rng = np.random.default_rng(0)
    extra = {}
    if cfg.is_encoder_decoder:
        from repro.launch.inputs import split_seq
        enc_S, _ = split_seq(cfg, max_len)
        extra["enc_embeds"] = jnp.asarray(
            rng.standard_normal((args.requests, enc_S, cfg.d_model)), jnp.bfloat16)
    elif cfg.frontend == "vision_stub":
        extra["image_embeds"] = jnp.asarray(
            rng.standard_normal((args.requests, cfg.num_image_embeds, cfg.d_model)),
            jnp.bfloat16)
    tokens = rng.integers(0, cfg.vocab_size, (args.requests, args.prompt)).astype(np.int32)

    t0 = time.time()
    out = eng.generate(tokens, args.out_tokens, extra)
    dt = time.time() - t0
    log.info(f"served batch={args.requests} prompt={args.prompt} out={args.out_tokens} "
             f"in {dt:.2f}s ({dt/args.out_tokens*1e3:.1f} ms/token step)")
    log.info("sample output tokens: %s", out[0, :16])

    if args.report_power:
        # Figure-4-style phase profile from the shared workload/power model
        server = ServerPower(A100)
        full = get_config(args.arch)
        t = request_timing(full, args.prompt, args.requests, server)
        log.info(f"[power] {full.name}: prompt phase {t.t_prefill:.3f}s @ "
                 f"{t.prefill_point.power_at(server, 1.0):.0f}W (compute-bound "
                 f"u_c={t.prefill_point.u_compute:.2f}) | token phase "
                 f"{t.t_token*1e3:.1f}ms/tok @ {t.token_point.power_at(server, 1.0):.0f}W "
                 f"(memory-bound u_m={t.token_point.u_memory:.2f})")


if __name__ == "__main__":
    main()
