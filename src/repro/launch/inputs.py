"""Sharding-rule selection and abstract input specs for every step kind.

``input_specs`` returns weak-type-correct ``jax.ShapeDtypeStruct`` stand-ins
with attached shardings — shardable, no device allocation — exactly what
``jax.jit(...).lower(...)`` needs for the multi-pod dry-run.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import model as model_mod
from repro.models.config import ModelConfig, ShapeConfig
from repro.models.param import Rules, fsdp_rules, logical_to_spec, resolve_spec, serve_rules, train_rules


def _axes_size(mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return math.prod(mesh.shape[a] for a in axes)


def make_rules(cfg: ModelConfig, shape: ShapeConfig, mesh) -> Rules:
    multi = "pod" in mesh.axis_names
    if shape.kind == "train":
        rules = dict(fsdp_rules(multi) if cfg.train_strategy == "fsdp"
                     else train_rules(multi))
    else:
        rules = dict(serve_rules(multi, cfg.decode_seq_shard and shape.is_decode))
    # batch divisibility: progressively shrink the batch axes until they divide
    batch_axes = rules.get("batch")
    if batch_axes is not None:
        axes = (batch_axes,) if isinstance(batch_axes, str) else tuple(batch_axes)
        while axes and shape.global_batch % _axes_size(mesh, axes) != 0:
            axes = axes[1:]
        rules["batch"] = axes if axes else None
    # decode: experts resident over (data x model) with token routing — the
    # only layout where 400B-1T MoE weights fit a serving pod (see moe.py)
    if shape.is_decode and cfg.moe_num_experts:
        rules["moe_mode"] = "token"
        rules["expert_slot"] = ("data", "model")
        rules["expert_embed"] = None
    # tiny batches free the data axis: use it for KV sequence sharding too
    if shape.is_decode and cfg.decode_seq_shard and rules["batch"] is None:
        rules["kv_seq"] = ("data", "model") if "pod" not in mesh.axis_names else (
            "pod", "data", "model")
    return rules


def split_seq(cfg: ModelConfig, seq_len: int) -> Tuple[int, int]:
    """(encoder_len, decoder_len) for enc-dec models; (0, seq) otherwise."""
    if not cfg.is_encoder_decoder:
        return 0, seq_len
    enc = int(seq_len * cfg.encoder_seq_frac)
    if cfg.max_encoder_len:
        enc = min(enc, cfg.max_encoder_len)
    return enc, seq_len - enc


def _sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=NamedSharding(mesh, spec))


def train_input_specs(cfg: ModelConfig, shape: ShapeConfig, mesh, rules: Rules) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    bspec = rules.get("batch")
    enc_S, dec_S = split_seq(cfg, S)
    out: Dict[str, Any] = {}
    if cfg.is_encoder_decoder:
        out["enc_embeds"] = _sds((B, enc_S, cfg.d_model), jnp.bfloat16, mesh, P(bspec, None, None))
        out["tokens"] = _sds((B, dec_S), jnp.int32, mesh, P(bspec, None))
    elif cfg.frontend == "vision_stub":
        n_img = cfg.num_image_embeds
        out["image_embeds"] = _sds((B, n_img, cfg.d_model), jnp.bfloat16, mesh, P(bspec, None, None))
        out["tokens"] = _sds((B, S - n_img), jnp.int32, mesh, P(bspec, None))
    else:
        out["tokens"] = _sds((B, S), jnp.int32, mesh, P(bspec, None))
    if cfg.is_encoder_only:
        out["targets"] = _sds(out["tokens"].shape, jnp.int32, mesh, P(bspec, None))
    return out


def prefill_input_specs(cfg, shape, mesh, rules) -> Dict[str, Any]:
    return train_input_specs(cfg, shape, mesh, rules)


def cache_input_specs(cfg: ModelConfig, shape: ShapeConfig, mesh, rules: Rules):
    enc_S, dec_S = split_seq(cfg, shape.seq_len)
    spec_tree = model_mod.cache_specs(cfg, shape.global_batch, dec_S, enc_S)
    return jax.tree.map(
        lambda s: _sds(s.shape, s.dtype, mesh, resolve_spec(s.shape, s.logical, rules, mesh)),
        spec_tree,
        is_leaf=lambda x: hasattr(x, "logical"),
    )


def decode_input_specs(cfg: ModelConfig, shape: ShapeConfig, mesh, rules: Rules) -> Dict[str, Any]:
    B = shape.global_batch
    bspec = rules.get("batch")
    return {
        "token": _sds((B, 1), jnp.int32, mesh, P(bspec, None)),
        "pos": _sds((), jnp.int32, mesh, P()),
        "cache": cache_input_specs(cfg, shape, mesh, rules),
    }


def input_specs(cfg: ModelConfig, shape: ShapeConfig, mesh, rules: Rules) -> Dict[str, Any]:
    if shape.kind == "train":
        return train_input_specs(cfg, shape, mesh, rules)
    if shape.kind == "prefill":
        return prefill_input_specs(cfg, shape, mesh, rules)
    return decode_input_specs(cfg, shape, mesh, rules)
