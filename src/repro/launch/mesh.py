"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state.
"""

from __future__ import annotations

import jax


def set_mesh(mesh):
    """Version-compatible ``jax.set_mesh`` for the ``with set_mesh(mesh):``
    form ONLY.

    ``jax.set_mesh`` only exists on recent jax releases. Fall back to
    ``jax.sharding.use_mesh`` where available, and finally to the ``Mesh``
    object itself (a context manager on every jax version we support).
    Bare (non-``with``) calls are NOT emulated on old jax: the fallbacks
    return an unentered context manager instead of mutating global state.
    """
    native = getattr(jax, "_repro_native_set_mesh", None) or getattr(jax, "set_mesh", None)
    if native is not None and native is not set_mesh:
        return native(mesh)
    use_mesh = getattr(jax.sharding, "use_mesh", None)
    if use_mesh is not None:
        return use_mesh(mesh)
    return mesh


if hasattr(jax, "set_mesh"):
    jax._repro_native_set_mesh = jax.set_mesh
else:
    # Older jax: install the shim so existing `with jax.set_mesh(...)` call
    # sites keep working once this module is imported (with-form only; see
    # the docstring above).
    jax.set_mesh = set_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1, pod: int = 0):
    """Small mesh over however many devices are actually present (tests/smoke)."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


def mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def dp_axes(mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)
