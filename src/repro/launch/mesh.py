"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state.
"""

from __future__ import annotations

import jax


def set_mesh(mesh):
    """Version-compatible ``jax.set_mesh`` for the ``with set_mesh(mesh):``
    form ONLY.

    ``jax.set_mesh`` only exists on recent jax releases. Fall back to
    ``jax.sharding.use_mesh`` where available, and finally to the ``Mesh``
    object itself (a context manager on every jax version we support).
    Bare (non-``with``) calls are NOT emulated on old jax: the fallbacks
    return an unentered context manager instead of mutating global state.
    """
    native = getattr(jax, "_repro_native_set_mesh", None) or getattr(jax, "set_mesh", None)
    if native is not None and native is not set_mesh:
        return native(mesh)
    use_mesh = getattr(jax.sharding, "use_mesh", None)
    if use_mesh is not None:
        return use_mesh(mesh)
    return mesh


if hasattr(jax, "set_mesh"):
    jax._repro_native_set_mesh = jax.set_mesh
else:
    # Older jax: install the shim so existing `with jax.set_mesh(...)` call
    # sites keep working once this module is imported (with-form only; see
    # the docstring above).
    jax.set_mesh = set_mesh


def shard_map_compat(f, *, mesh, in_specs, out_specs, check_vma=False):
    """Version-compatible ``jax.shard_map``.

    Recent jax exposes ``jax.shard_map`` with the ``check_vma`` kwarg; older
    releases only have ``jax.experimental.shard_map.shard_map`` whose
    equivalent knob is ``check_rep``. Callers that disable varying-manual
    axis checking (the batched engine's replicated-consts layout trips it)
    work on both."""
    native = getattr(jax, "shard_map", None)
    if native is not None:
        return native(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)


def data_mesh(data: int = 0):
    """1-D ``("data",)`` mesh for member-axis sharding (batched engine).

    ``data=0`` spans every visible device. With
    ``--xla_force_host_platform_device_count=8`` (pinned in
    ``tests/conftest.py``) this exercises the real sharded path on CPU CI."""
    if data <= 0:
        data = len(jax.devices())
    return jax.make_mesh((data,), ("data",))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1, pod: int = 0):
    """Small mesh over however many devices are actually present (tests/smoke)."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


def mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def dp_axes(mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)
