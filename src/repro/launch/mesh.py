"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1, pod: int = 0):
    """Small mesh over however many devices are actually present (tests/smoke)."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


def mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def dp_axes(mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)
