import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay first (before any jax import): jax locks the
device count at first init, and the production meshes need 512 placeholder
host devices. Smoke tests and benchmarks never import this module.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --out results/dryrun.jsonl
  PYTHONPATH=src python -m repro.launch.dryrun ... --multi-pod   # 2x16x16 mesh

Each cell: jit(step).lower(**input_specs).compile() under the production mesh,
then memory_analysis() (proves it fits) and cost_analysis() + HLO collective
parse (feeds EXPERIMENTS.md §Roofline).
"""

import argparse
import json
import subprocess
import sys
import time
import traceback

import jax

from repro.configs import assigned_archs, get_config
from repro.launch.inputs import input_specs, make_rules, split_seq
from repro.launch.mesh import make_production_mesh, set_mesh
from repro.launch.steps import abstract_state, build_serve_step
from repro.models.config import SHAPES_BY_NAME, shape_applicable
from repro.obs.log import get_logger
from repro.optim import Optimizer
from repro.parallel.roofline import HBM_BYTES, build_roofline_extrapolated

log = get_logger(__name__)


def _lower_compile(cfg, shape, mesh, rules):
    step, opt = build_serve_step(cfg, shape, mesh, rules)
    specs = input_specs(cfg, shape, mesh, rules)
    t0 = time.time()
    with set_mesh(mesh):
        if shape.kind == "train":
            state = abstract_state(cfg, mesh, rules, opt)
            lowered = jax.jit(step).lower(state, specs)
        elif shape.kind == "prefill":
            state = abstract_state(cfg, mesh, rules, None)
            lowered = jax.jit(step).lower(state["params"], specs)
        else:
            state = abstract_state(cfg, mesh, rules, None)
            lowered = jax.jit(step).lower(state["params"], specs["token"],
                                          specs["pos"], specs["cache"])
        t_lower = time.time() - t0
        compiled = lowered.compile()
    return compiled, t_lower, time.time() - t0 - t_lower


def run_cell(arch: str, shape_name: str, multi_pod: bool, verbose: bool = True,
             overrides: dict | None = None) -> dict:
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.replace(**overrides)
    shape = SHAPES_BY_NAME[shape_name]
    ok, why = shape_applicable(cfg, shape)
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16"}
    if overrides:
        rec["overrides"] = overrides
    if not ok:
        rec.update(status="skipped", reason=why)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    rules = make_rules(cfg, shape, mesh)

    # Compile 1 (scan form): deployment artifact — memory_analysis ("fits")
    # reflects real loop-form buffer liveness.
    compiled, t_lower, t_compile = _lower_compile(cfg, shape, mesh, rules)
    mem = compiled.memory_analysis()

    # Compiles 2+3 (G=1 and G=2 fully unrolled): XLA cost analysis counts
    # while-loop bodies once, and fully unrolling 61-group models is
    # prohibitive — so we compile 1-group and 2-group variants (loops elide)
    # and extrapolate linearly: cost(G) = cost1 + (G-1) * (cost2 - cost1).
    # Exact because groups are computationally identical; cross-checked
    # against the full unroll on llama3.2-1b x train_4k (within 2%).
    def grouped(k):
        over = {"num_layers": k * len(cfg.pattern), "unroll_layers": True}
        if cfg.is_encoder_decoder:
            assert cfg.num_encoder_layers == cfg.num_groups, cfg.name
            over["num_encoder_layers"] = k
        return cfg.replace(**over)

    comp1, _, t_u1 = _lower_compile(grouped(1), shape, mesh, rules)
    comp2, _, t_u2 = _lower_compile(grouped(2), shape, mesh, rules)
    t_compile_u = t_u1 + t_u2

    enc_S, dec_S = split_seq(cfg, shape.seq_len)
    roof = build_roofline_extrapolated(comp1, comp2, cfg, shape, n_dev, enc_S, dec_S)
    bytes_per_dev = (mem.argument_size_in_bytes + mem.temp_size_in_bytes
                     + mem.output_size_in_bytes - mem.alias_size_in_bytes)
    rec.update(
        status="ok",
        lower_s=round(t_lower, 1),
        compile_s=round(t_compile, 1),
        compile_unrolled_s=round(t_compile_u, 1),
        arg_bytes=mem.argument_size_in_bytes,
        temp_bytes=mem.temp_size_in_bytes,
        out_bytes=mem.output_size_in_bytes,
        alias_bytes=mem.alias_size_in_bytes,
        bytes_per_device=bytes_per_dev,
        fits_hbm=bool(bytes_per_dev <= HBM_BYTES),
        roofline=roof.to_dict(),
    )
    if verbose:
        log.info(f"[{rec['mesh']}] {arch} x {shape_name}: "
                 f"lower {t_lower:.1f}s compile {t_compile:.1f}s | "
                 f"{bytes_per_dev/2**30:.2f} GiB/dev (fits={rec['fits_hbm']}) | "
                 f"bottleneck={roof.bottleneck} "
                 f"[C={roof.t_compute*1e3:.2f}ms M={roof.t_memory*1e3:.2f}ms "
                 f"X={roof.t_collective*1e3:.2f}ms] mfu_bound={roof.mfu_bound:.3f}")
        log.info("  memory_analysis: %s", mem)
        log.info("  analytic flops/device: %.3e bytes/device: %.3e | "
                 "hlo flops/device: %.3e bytes/device: %.3e",
                 roof.flops_per_device, roof.hbm_bytes_per_device,
                 roof.hlo_flops_per_device, roof.hlo_bytes_per_device)
        log.info("  collectives: %s %s", roof.collectives.ops,
                 {k: f"{v/2**20:.1f}MiB"
                  for k, v in roof.collectives.bytes_by_kind.items()})
    return rec


def run_all(out_path: str, multi_pod: bool, archs=None, shapes=None) -> int:
    """Run every cell in a subprocess (isolation: one bad cell can't sink the
    fleet run) appending JSONL records."""
    archs = archs or assigned_archs()
    shapes = shapes or list(SHAPES_BY_NAME)
    failures = 0
    for arch in archs:
        for shape_name in shapes:
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape_name, "--out", out_path]
            if multi_pod:
                cmd.append("--multi-pod")
            try:
                r = subprocess.run(cmd, env={**os.environ, "PYTHONPATH": "src"},
                                   timeout=1800)
                rc = r.returncode
            except subprocess.TimeoutExpired:
                rc = -1
            if rc != 0:
                failures += 1
                with open(out_path, "a") as f:
                    f.write(json.dumps({"arch": arch, "shape": shape_name,
                                        "mesh": "2x16x16" if multi_pod else "16x16",
                                        "status": "error"}) + "\n")
    return failures


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--set", action="append", default=[],
                    help="config override key=value (hillclimb experiments)")
    args = ap.parse_args()

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        for cast in (int, float):
            try:
                v = cast(v)
                break
            except ValueError:
                continue
        overrides[k] = v

    if args.arch == "all":
        assert args.out, "--all requires --out"
        n_fail = run_all(args.out, args.multi_pod,
                         shapes=None if args.shape == "all" else [args.shape])
        sys.exit(1 if n_fail else 0)

    shapes = list(SHAPES_BY_NAME) if args.shape == "all" else [args.shape]
    for shape_name in shapes:
        try:
            rec = run_cell(args.arch, shape_name, args.multi_pod,
                           overrides=overrides or None)
        except Exception:
            traceback.print_exc()
            sys.exit(1)
        if args.out:
            with open(args.out, "a") as f:
                f.write(json.dumps(rec) + "\n")


if __name__ == "__main__":
    main()
