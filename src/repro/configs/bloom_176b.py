"""bloom-176b (paper Fig. 3, decoder, inference-only; the paper's worst-case
evaluation workload) — 70L d_model=14336 112H d_ff=57344 vocab=250880.
ALiBi approximated by RoPE (backbone flops/bytes are what the power model
consumes). [arXiv:2211.05100]"""

from repro.models.config import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="bloom-176b",
    family="dense",
    num_layers=70,
    d_model=14336,
    num_heads=112,
    num_kv_heads=112,
    head_dim=128,
    d_ff=57344,
    vocab_size=250880,
    pattern=(ATTN,),
    mlp_type="gelu",
)

SMOKE = CONFIG.replace(
    name="bloom-176b-smoke",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=256,
)
