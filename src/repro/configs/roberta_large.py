"""roberta-large (paper Fig. 3, encoder) — 24L d_model=1024 16H d_ff=4096
vocab=50265. Bidirectional encoder; MLM-style loss; no decode step."""

from repro.models.config import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="roberta-large",
    family="encoder",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=50265,
    pattern=(ATTN,),
    mlp_type="gelu",
)

SMOKE = CONFIG.replace(
    name="roberta-large-smoke",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=256,
)
