"""mixtral-8x7b [moe] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000,
MoE 8 experts top-2, sliding-window attention. [arXiv:2401.04088; hf]"""

from repro.models.config import LOCAL, ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    pattern=(LOCAL,),  # SWA on every layer
    window_size=4096,
    moe_num_experts=8,
    moe_top_k=2,
    moe_d_ff=14336,
)

SMOKE = CONFIG.replace(
    name="mixtral-8x7b-smoke",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, moe_d_ff=128, vocab_size=256, window_size=16,
    moe_num_experts=4, moe_top_k=2,
)
