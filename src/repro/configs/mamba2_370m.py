"""mamba2-370m [ssm] — 48L d_model=1024 (attention-free) vocab=50280,
ssm_state=128. SSD (state-space duality). [arXiv:2405.21060; unverified]"""

from repro.models.config import MAMBA, ModelConfig

CONFIG = ModelConfig(
    train_strategy="fsdp",  # H1: small models are TP-collective-bound on 256 chips
    name="mamba2-370m",
    family="ssm",
    num_layers=48,
    d_model=1024,
    num_heads=16,  # unused (attention-free); placeholder for generic plumbing
    num_kv_heads=16,
    head_dim=64,
    d_ff=0,
    vocab_size=50280,
    pattern=(MAMBA,),
    ssm_d_state=128,
    ssm_headdim=64,
    ssm_expand=2,
    tie_embeddings=True,
)

SMOKE = CONFIG.replace(
    name="mamba2-370m-smoke",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    vocab_size=256, ssm_d_state=16, ssm_headdim=16, ssm_chunk=16,
)
