"""whisper-base [audio] — 6L d_model=512 8H (kv=8) d_ff=2048 vocab=51865.
Encoder-decoder; the conv audio frontend is a STUB: ``input_specs()`` provides
precomputed frame embeddings for the encoder. [arXiv:2212.04356; unverified]

Backbone approximations (noted per assignment: backbone only): GELU MLP as in
Whisper; RoPE in place of learned absolute positions; RMSNorm in place of
LayerNorm.
"""

from repro.models.config import ATTN, ModelConfig

CONFIG = ModelConfig(
    train_strategy="fsdp",  # H1: small models are TP-collective-bound on 256 chips
    name="whisper-base",
    family="audio",
    num_layers=6,
    num_encoder_layers=6,
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    vocab_size=51865,
    pattern=(ATTN,),
    mlp_type="gelu",
    frontend="audio_stub",
    encoder_seq_frac=0.5,
    max_encoder_len=1500,
)

SMOKE = CONFIG.replace(
    name="whisper-base-smoke",
    num_layers=2, num_encoder_layers=2, d_model=64, num_heads=4,
    num_kv_heads=4, head_dim=16, d_ff=128, vocab_size=256,
)
