"""Architecture registry: the 10 assigned archs + the paper's own workloads."""

from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ModelConfig

# assigned (arch-id -> module name)
ASSIGNED = {
    "whisper-base": "whisper_base",
    "mixtral-8x7b": "mixtral_8x7b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "internvl2-1b": "internvl2_1b",
    "llama3.2-1b": "llama3_2_1b",
    "gemma2-9b": "gemma2_9b",
    "yi-34b": "yi_34b",
    "qwen3-8b": "qwen3_8b",
    "mamba2-370m": "mamba2_370m",
    "jamba-1.5-large-398b": "jamba_1_5_large",
}

# the paper's own characterization workloads (Figure 3)
PAPER_OWN = {
    "roberta-large": "roberta_large",
    "gpt-neox-20b": "gpt_neox_20b",
    "opt-30b": "opt_30b",
    "bloom-176b": "bloom_176b",
    "flan-t5-xxl": "flan_t5_xxl",
}

ALL = {**ASSIGNED, **PAPER_OWN}


def get_config(name: str) -> ModelConfig:
    if name not in ALL:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ALL)}")
    mod = importlib.import_module(f"repro.configs.{ALL[name]}")
    return mod.CONFIG


def smoke_config(name: str) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    mod = importlib.import_module(f"repro.configs.{ALL[name]}")
    return mod.SMOKE


def assigned_archs() -> List[str]:
    return list(ASSIGNED)
