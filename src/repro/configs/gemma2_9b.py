"""gemma2-9b [dense] — 42L d_model=3584 16H (GQA kv=8) d_ff=14336 vocab=256000.
Local+global alternating attention, logit softcaps, post-norms, GeGLU.
[arXiv:2408.00118; hf]"""

from repro.models.config import ATTN, LOCAL, ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    family="dense",
    num_layers=42,
    d_model=3584,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,
    d_ff=14336,
    vocab_size=256000,
    pattern=(LOCAL, ATTN),  # alternating sliding-window / global
    window_size=4096,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    use_post_norm=True,
    mlp_type="geglu",
    tie_embeddings=True,
)

SMOKE = CONFIG.replace(
    name="gemma2-9b-smoke",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, window_size=16,
)
