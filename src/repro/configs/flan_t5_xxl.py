"""flan-t5-xxl (paper Fig. 3, encoder-decoder) — 24+24L d_model=4096 64H
head_dim=64 d_ff=10240 vocab=32128, gated-GELU. [arXiv:2210.11416]"""

from repro.models.config import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="flan-t5-xxl",
    family="encdec",
    num_layers=24,
    num_encoder_layers=24,
    d_model=4096,
    num_heads=64,
    num_kv_heads=64,
    head_dim=64,
    d_ff=10240,
    vocab_size=32128,
    pattern=(ATTN,),
    mlp_type="geglu",
    frontend="none",
    encoder_seq_frac=0.5,
)

SMOKE = CONFIG.replace(
    name="flan-t5-xxl-smoke",
    num_layers=2, num_encoder_layers=2, d_model=64, num_heads=4,
    num_kv_heads=4, head_dim=16, d_ff=128, vocab_size=256,
)
