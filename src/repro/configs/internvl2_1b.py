"""internvl2-1b [vlm] — 24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655.
InternViT + InternLM2/Qwen2 backbone; the ViT frontend is a STUB:
``input_specs()`` provides precomputed patch embeddings. [arXiv:2404.16821; hf]"""

from repro.models.config import ATTN, ModelConfig

CONFIG = ModelConfig(
    train_strategy="fsdp",  # H1: small models are TP-collective-bound on 256 chips
    name="internvl2-1b",
    family="vlm",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab_size=151655,
    pattern=(ATTN,),
    tie_embeddings=True,
    frontend="vision_stub",
    num_image_embeds=256,
    rope_theta=1_000_000.0,
)

SMOKE = CONFIG.replace(
    name="internvl2-1b-smoke",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, num_image_embeds=8,
)
