"""opt-30b (paper Fig. 3, decoder, inference-only) — 48L d_model=7168 56H
d_ff=28672 vocab=50272. [arXiv:2205.01068]"""

from repro.models.config import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="opt-30b",
    family="dense",
    num_layers=48,
    d_model=7168,
    num_heads=56,
    num_kv_heads=56,
    head_dim=128,
    d_ff=28672,
    vocab_size=50272,
    pattern=(ATTN,),
    mlp_type="gelu",
)

SMOKE = CONFIG.replace(
    name="opt-30b-smoke",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=256,
)
