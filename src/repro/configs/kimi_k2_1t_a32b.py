"""kimi-k2-1t-a32b [moe] — 61L d_model=7168 64H (GQA kv=8) d_ff=2048
vocab=163840, MoE 384 experts top-8 (+1 shared expert), ~1T total params.
Paper-table config. [arXiv:2501.kimi2; unverified]

Fitting notes (DESIGN.md §5): 1T params cannot carry fp32 AdamW state on a
256-chip v5e pod, so this config stores params in bf16 and uses factored
Adafactor — 4 bytes/param of state instead of 12.
"""

from repro.models.config import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=2048,
    vocab_size=163840,
    pattern=(ATTN,),
    moe_num_experts=384,
    moe_top_k=8,
    moe_d_ff=2048,
    moe_shared_expert_ff=2048,
    param_dtype="bfloat16",
    optimizer="adafactor",
)

SMOKE = CONFIG.replace(
    name="kimi-k2-smoke",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=32, moe_d_ff=32, moe_shared_expert_ff=32, vocab_size=256,
    moe_num_experts=8, moe_top_k=2,
)
