"""gpt-neox-20b (paper Fig. 3, decoder) — 44L d_model=6144 64H d_ff=24576
vocab=50432. [arXiv:2204.06745]"""

from repro.models.config import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="gpt-neox-20b",
    family="dense",
    num_layers=44,
    d_model=6144,
    num_heads=64,
    num_kv_heads=64,
    head_dim=96,
    d_ff=24576,
    vocab_size=50432,
    pattern=(ATTN,),
    mlp_type="gelu",
)

SMOKE = CONFIG.replace(
    name="gpt-neox-20b-smoke",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=256,
)
