"""jamba-1.5-large-398b [hybrid] — 72L d_model=8192 64H (GQA kv=8) d_ff=24576
vocab=65536, MoE 16 experts top-2, Mamba+attention 1:7 interleave.
[arXiv:2403.19887; hf]

Structure: 9 scanned groups of 8 blocks; attention at group position 4 (as in
the Jamba paper), every block followed by an FFN, MoE on every other block.
Jamba proper uses Mamba-1 mixers; we use the Mamba2/SSD mixer (the TPU-native
matmul form — see DESIGN.md hardware-adaptation notes). No RoPE (Jamba relies
on the Mamba layers for position).

Fitting: 398B params -> bf16 params + Adafactor (same reasoning as kimi-k2).
"""

from repro.models.config import ATTN, MAMBA, ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    pattern=(MAMBA, MAMBA, MAMBA, MAMBA, ATTN, MAMBA, MAMBA, MAMBA),
    ffn_every_block=True,
    use_rope=False,
    moe_num_experts=16,
    moe_top_k=2,
    moe_d_ff=24576,
    moe_layer_period=2,
    ssm_d_state=128,
    ssm_headdim=64,
    ssm_expand=2,
    param_dtype="bfloat16",
    optimizer="adafactor",
)

SMOKE = CONFIG.replace(
    name="jamba-1.5-smoke",
    num_layers=8, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, moe_d_ff=128, vocab_size=256,
    moe_num_experts=4, moe_top_k=2,
    ssm_d_state=16, ssm_headdim=16, ssm_chunk=16,
)
