"""yi-34b [dense] — 60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.
Llama-arch GQA. [arXiv:2403.04652; hf]"""

from repro.models.config import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="yi-34b",
    family="dense",
    num_layers=60,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    vocab_size=64000,
    pattern=(ATTN,),
    rope_theta=5_000_000.0,
    # 56 q heads cannot shard over the 16-way model axis; pad each GQA group
    # 7->8 query heads (zero wo rows -> exact outputs). See EXPERIMENTS §Perf H3.
    pad_heads_multiple=16,
)

SMOKE = CONFIG.replace(
    name="yi-34b-smoke",
    num_layers=2, d_model=64, num_heads=8, num_kv_heads=2, head_dim=8,
    d_ff=160, vocab_size=256,
)
