"""JAX-native batched ensemble engine with a numpy differential oracle.

The Monte-Carlo engine in ``provisioning.montecarlo`` parallelizes the
event-driven :class:`~repro.core.simulator.RowSimulator` across a fork pool —
throughput is capped by host cores (< 2 effective in CI), so risk tails stay
at tens of members. This module rebuilds the hot loop as a *tick-level fluid
model* that runs N ensemble members x T telemetry ticks as one batched device
program (DESIGN.md §15):

* **Lowering** — :func:`lower_ensemble` compiles a
  :class:`~repro.experiments.scenario.Scenario` + member seeds into a
  :class:`TickModel`: per-member occupancy on the 60 s trace grid, closed-form
  power coefficients from the Table-4 workload mix (idle + per-priority
  busy-power terms with the DVFS ``f^gamma`` law from
  ``core.power_model``), the POLCA thresholds/frequencies, fault timelines
  lowered to per-tick budget scales and row-alive masks, and the
  ``PowerHierarchy`` node matrix for segment-sum folds.

* **Two backends, one contract** — ``engine="jax"`` runs the tick advance as
  a ``lax.scan`` over time ``vmap``-ed over members, with the
  :class:`~repro.core.policy.PolcaPolicy` /
  :class:`~repro.core.policy.PredictivePolcaPolicy` observe step (windowed
  least-squares slope over the 40 s OOB horizon) carried in scan state as a
  vectorized boolean state machine. ``engine="numpy"`` is the differential
  **oracle**: the identical tick/ring contract driven by the *real* policy
  objects through :class:`~repro.core.telemetry.Telemetry`, one instance per
  (member, row) — so the vectorized state machine is checked against the
  genuine Algorithm-1 implementation, not a reimplementation of itself
  (``tests/test_batched_parity.py``).

* **Actuation ring** — out-of-band cap commands apply ``ceil(40/2)=20``
  ticks after issue and powerbrakes ``ceil(5/2)=3`` ticks after, modeled as
  a ``[rows, D, 2]`` ring buffer (NaN = no command); later-issued commands
  overwrite earlier ones per frequency field, which is exactly the DES event
  queue's same-due-time resolution.

The oracle contract deliberately accepts two float nonidentities, both
documented in DESIGN.md §15: XLA may fuse multiply-adds (power series agree
to ~1e-15, asserted <= 1e-6 relative), and ``jnp.sum`` may reorder the
predictive slope accumulation (~1e-16). Brake-tick *sets* are compared for
bit-equality on the harness scenarios; a flip would need a power sample
within ~1e-12 of a threshold.

``montecarlo.run_ensemble(engine=...)`` dispatches here, and
``planner.plan_capacity(engine="jax")`` uses the dense tails to activate the
CVaR gate in ``RiskConstraints``.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from functools import lru_cache
from typing import Dict, List, NamedTuple, Optional, Sequence, Tuple

import numpy as np

from repro.core.policy import PolcaPolicy, PredictivePolcaPolicy
from repro.core.simulator import SimResult
from repro.core.slo import LatencyStats
from repro.core.telemetry import Telemetry
from repro.core.traces import TABLE4, get_occupancy_generator
from repro.experiments.runner import build_workloads, row_budgets
from repro.experiments.scenario import Scenario
from repro.obs.metrics import get_recorder
from repro.provisioning.montecarlo import (
    EnsembleResult,
    EnsembleSpec,
    MemberStats,
    resolve_ensemble_budget,
)

# members x ticks above which run_batched_ensemble drops per-tick series by
# default (a [N, T] float64 matrix; 4e6 ~ 32 MB) — mirroring the
# record_power=False path of the DES engine
_SERIES_CELL_LIMIT = 4_000_000
# per-member SLO-impact samples are decimated onto at most this many slots
_IMPACT_SLOTS = 256
_JITTER_SALT = 9173  # member-occupancy jitter stream, disjoint from arrivals


@dataclass(frozen=True)
class TickModel:
    """A Scenario + member seeds lowered to the batched tick program.

    Everything both backends consume: static arrays on the tick/trace grids
    plus closed-form scalars. The model is engine-agnostic — running it with
    ``engine="numpy"`` and ``engine="jax"`` must agree per the oracle
    contract (DESIGN.md §15)."""

    base_name: str
    n_members: int
    n_rows: int
    n_ticks: int  # T
    dt: float  # telemetry_s
    occ60: np.ndarray = field(repr=False)  # [N, R, T60] occupancy, 60 s grid
    alive: np.ndarray = field(repr=False)  # [T, R] 0/1 row-crash mask
    budget_scale: np.ndarray = field(repr=False)  # [T, R] fault derates
    row_budget_w: np.ndarray = field(repr=False)  # [R] static budgets
    # power plane (closed form over the Table-4 mix; watts per server)
    p0_srv_w: float  # idle server watts
    k_lp_w: float  # LP busy-power coefficient (x f_lp^gamma)
    k_hp_w: float  # HP busy-power coefficient (x f_hp^gamma)
    lp_share: float  # LP fraction of the server pool
    gamma: float
    n_servers: int
    power_scale: float
    # policy constants (resolved from the PolicySpec)
    predictive: bool
    t1: float
    t2: float
    t1_buffer: float
    t2_buffer: float
    lp_freq_t1: float
    lp_freq_t2: float
    hp_freq_t2: float
    brake_freq: float
    escalation_ticks: int
    horizon_s: float
    window: int
    # actuation ring
    oob_ticks: int
    brake_ticks: int
    ring_depth: int  # D = max(oob, brake) + 1
    # SLO fluid proxy (per-priority clock-sensitive fraction + service time)
    a_hp: float
    a_lp: float
    svc_hp: float
    svc_lp: float
    has_hp: bool
    has_lp: bool
    # impact decimation
    stride: int
    n_slots: int  # S = ceil(T / stride)
    # hierarchy segment-sum fold (None = flat row accounting)
    node_matrix: Optional[np.ndarray] = field(default=None, repr=False)  # [n_nodes, R]
    node_names: Tuple[str, ...] = ()
    seeds: Tuple[int, ...] = ()

    @property
    def total_budget_w(self) -> float:
        return float(self.row_budget_w.sum())

    def tick_times(self) -> np.ndarray:
        """Telemetry timestamps: tick k samples t = (k+1) * dt."""
        return (np.arange(self.n_ticks, dtype=np.float64) + 1.0) * self.dt


@dataclass
class BatchedRun:
    """Raw output of one tick-program run (either backend).

    ``brake_fire[m, k, r]`` marks the policy firing a powerbrake on row r at
    tick k of member m — the brake-tick set the differential harness compares
    bit-for-bit. Series fields are ``None`` when the run dropped them
    (``keep_series=False``)."""

    engine: str
    model: TickModel
    brake_fire: np.ndarray = field(repr=False)  # [N, T, R] bool
    n_brakes: np.ndarray = field(repr=False)  # [N, R] int
    peak_frac: np.ndarray = field(repr=False)  # [N]
    mean_frac: np.ndarray = field(repr=False)  # [N]
    impacts_hp: np.ndarray = field(repr=False)  # [N, R, S]
    impacts_lp: np.ndarray = field(repr=False)  # [N, R, S]
    total_frac: Optional[np.ndarray] = field(default=None, repr=False)  # [N, T]
    row_w: Optional[np.ndarray] = field(default=None, repr=False)  # [N, T, R]
    node_w: Optional[np.ndarray] = field(default=None, repr=False)  # [N, T, nodes]

    def brake_ticks(self) -> np.ndarray:
        """Sorted (member, tick, row) index triples of every brake firing —
        the bit-compared set of the oracle contract."""
        return np.argwhere(self.brake_fire)

    def member_stats(self, m: int) -> LatencyStats:
        hp = self.impacts_hp[m].ravel() if self.model.has_hp else np.zeros(0)
        lp = self.impacts_lp[m].ravel() if self.model.has_lp else np.zeros(0)
        return LatencyStats(hp_impacts=[float(x) for x in hp],
                            lp_impacts=[float(x) for x in lp])


# ---------------------------------------------------------------------------
# lowering
# ---------------------------------------------------------------------------

def _policy_constants(sc: Scenario) -> Dict[str, object]:
    pol = sc.policy.build()
    if isinstance(pol, PredictivePolcaPolicy):
        predictive = True
    elif isinstance(pol, PolcaPolicy):
        predictive = False
    else:
        raise ValueError(
            f"batched engine supports polca/polca-predictive policies; "
            f"scenario {sc.name!r} uses {sc.policy.kind!r} (run it on the "
            f"event-driven engine instead)")
    return dict(
        predictive=predictive,
        t1=float(pol.t1), t2=float(pol.t2),
        t1_buffer=float(pol.t1_buffer), t2_buffer=float(pol.t2_buffer),
        lp_freq_t1=float(pol.lp_freq_t1), lp_freq_t2=float(pol.lp_freq_t2),
        hp_freq_t2=float(pol.hp_freq_t2), brake_freq=float(pol.brake_freq),
        escalation_ticks=int(pol.escalation_ticks),
        horizon_s=float(getattr(pol, "horizon_s", 40.0)),
        window=int(getattr(pol, "window", 8)),
    )


def _power_constants(sc: Scenario) -> Dict[str, float]:
    """Closed-form power/SLO coefficients over the Table-4 workload mix.

    A busy server running class w draws ``idle + k_w * f^gamma`` watts where
    ``k_w = n_dev * (p_peak - idle) * u_eff_w`` and ``u_eff_w`` is the
    prefill/decode-time-weighted roofline utilization — exactly
    ``DevicePower.power`` evaluated at the class's two
    :class:`~repro.core.workload.PhasePoint` operating points. Classes then
    collapse into one LP and one HP coefficient via share x priority mix."""
    wls, shares = build_workloads(sc)
    server = sc.fleet.server()
    dev = server.device
    k_lp = k_hp = lp_share = 0.0
    a_num = {"high": 0.0, "low": 0.0}
    svc_num = {"high": 0.0, "low": 0.0}
    wgt_tot = {"high": 0.0, "low": 0.0}
    for wl, share, spec in zip(wls, shares, TABLE4):
        mean_out = 0.5 * (spec.out_range[0] + spec.out_range[1])
        t_total = wl.timing.t_prefill + mean_out * wl.timing.t_token
        f_pre = wl.timing.t_prefill / t_total
        u_eff = 0.0
        cf_eff = 0.0
        for frac, pt in ((f_pre, wl.timing.prefill_point),
                         (1.0 - f_pre, wl.timing.token_point)):
            u = min(1.0, dev.w_compute * min(pt.u_compute, 1.0)
                    + dev.w_memory * min(pt.u_memory, 1.0))
            u_eff += frac * u
            cf_eff += frac * pt.compute_frac
        k_srv = server.n_devices * (dev.p_peak - dev.idle_w) * u_eff
        mix = wl.priority_mix
        k_hp += share * mix * k_srv
        k_lp += share * (1.0 - mix) * k_srv
        lp_share += share * (1.0 - mix)
        for prio, wgt in (("high", share * mix), ("low", share * (1.0 - mix))):
            wgt_tot[prio] += wgt
            a_num[prio] += wgt * cf_eff
            svc_num[prio] += wgt * t_total
    out = dict(p0_srv_w=float(server.idle_power), k_lp_w=float(k_lp),
               k_hp_w=float(k_hp), lp_share=float(lp_share),
               gamma=float(dev.gamma))
    for prio, key in (("high", "hp"), ("low", "lp")):
        has = wgt_tot[prio] > 0.0
        out[f"has_{key}"] = bool(has)
        out[f"a_{key}"] = float(a_num[prio] / wgt_tot[prio]) if has else 0.0
        out[f"svc_{key}"] = float(svc_num[prio] / wgt_tot[prio]) if has else 1.0
    return out


def _member_occupancy(sc: Scenario, seeds: Sequence[int], t60: np.ndarray,
                      n_rows: int, n_servers: int) -> np.ndarray:
    """[N, R, T60] occupancy: the scenario's registered generator per member
    seed + row, plus a member-seeded CLT busy-fraction jitter
    (sigma = sqrt(occ(1-occ)/n_servers)) standing in for the arrival-sampling
    noise of the DES — without it the diurnal family (which deliberately
    ignores the member seed) would collapse every member onto one curve."""
    gen = get_occupancy_generator(sc.traffic.generator)
    occ = np.empty((len(seeds), n_rows, len(t60)), dtype=np.float64)
    for mi, seed in enumerate(seeds):
        for r in range(n_rows):
            base = np.asarray(gen(t60, seed=int(seed), peak=sc.traffic.occ_peak,
                                  n_rows=n_rows, row=r,
                                  **sc.traffic.gen_params), dtype=np.float64)
            rng = np.random.default_rng([int(seed), r, _JITTER_SALT])
            sigma = np.sqrt(np.clip(base * (1.0 - base), 0.0, None) / n_servers)
            occ[mi, r] = np.clip(base + rng.standard_normal(len(t60)) * sigma,
                                 0.0, 1.0)
    return occ


def _lower_faults(sc: Scenario, n_ticks: int, dt: float, n_rows: int,
                  hierarchy) -> Tuple[np.ndarray, np.ndarray]:
    """Fault timeline -> ([T, R] alive mask, [T, R] budget scale).

    Row crashes zero a row's occupancy (it idles until revived); budget
    events scale the *derated subtree's* row budgets per tick, ramping
    linearly over ``ramp_s`` and restoring at ``until`` — the same
    conservative-tree semantics the ChaosInjector enforces on the DES path.
    Unlike ``run_experiment``, faults here do not require a RoutingSpec: the
    tick model has no dispatcher to fence, so the masks are the whole story."""
    alive = np.ones((n_ticks, n_rows), dtype=np.float64)
    bscale = np.ones((n_ticks, n_rows), dtype=np.float64)
    faults = sc.faults
    if faults is None or faults.is_noop:
        return alive, bscale
    names = list(hierarchy.names) if hierarchy is not None else None
    faults.validate(duration_s=sc.duration_s, n_rows=n_rows, node_names=names)
    t_ticks = (np.arange(n_ticks, dtype=np.float64) + 1.0) * dt
    for e in sorted(faults.row_events(), key=lambda e: e.t):
        alive[t_ticks >= e.t, int(e.row)] = (
            0.0 if e.kind == "row-crash" else 1.0)
    for e in faults.budget_events():
        if e.kind == "site-demand-response" or hierarchy is None:
            if e.kind == "node-derate" and hierarchy is None:
                raise ValueError(
                    f"fault event {e.describe()} targets a hierarchy node "
                    f"but scenario {sc.name!r} has no HierarchySpec")
            rows = np.arange(n_rows)
        else:
            rows = hierarchy.subtree_leaves(list(hierarchy.names).index(e.node))
        ramp = (np.clip((t_ticks - e.t) / e.ramp_s, 0.0, 1.0) if e.ramp_s > 0
                else (t_ticks >= e.t).astype(np.float64))
        scale = 1.0 - (1.0 - e.factor) * ramp
        if e.until is not None:
            scale = np.where(t_ticks >= e.until, 1.0, scale)
        bscale[:, rows] *= scale[:, None]
    return alive, bscale


def lower_ensemble(spec: EnsembleSpec, *, budget_w: Optional[float] = None
                   ) -> Tuple[TickModel, List[Scenario], float]:
    """Lower an EnsembleSpec to the batched tick program. Returns
    ``(model, member_scenarios, resolved_budget_w)`` — members carry the
    same pinned budget ``run_ensemble`` would pin, so planner decisions on
    either engine answer the same question."""
    sc = spec.base
    if sc.routing is not None:
        raise ValueError(
            f"batched engine runs unrouted row/cluster scenarios; "
            f"{sc.name!r} carries a RoutingSpec (use engine='numpy' — the "
            f"event-driven fleet path)")
    if sc.duration_s < 120.0:
        raise ValueError(
            f"batched engine needs duration_s >= 120 (two 60 s occupancy "
            f"samples to interpolate); {sc.name!r} has {sc.duration_s:g}")
    dt = float(sc.telemetry.telemetry_s)
    n_ticks = int(math.floor(sc.duration_s / dt))
    t60 = np.arange(0.0, sc.duration_s, 60.0)
    fleet = sc.fleet
    server = fleet.server()
    budget = (resolve_ensemble_budget(sc) if budget_w is None
              else float(budget_w))
    members = spec.member_scenarios(budget)

    hierarchy = None
    node_matrix = None
    node_names: Tuple[str, ...] = ()
    base_budgets = row_budgets(sc, budget, server)
    if sc.hierarchy is not None:
        if sc.hierarchy.n_rows != fleet.n_rows:
            raise ValueError(
                f"hierarchy shape {sc.hierarchy.shape} implies "
                f"{sc.hierarchy.n_rows} rows; fleet has {fleet.n_rows}")
        hierarchy = sc.hierarchy.build(base_budgets)
        row_budget = np.asarray(hierarchy.leaf_budget_w, dtype=np.float64)
        node_matrix = np.zeros((hierarchy.n_nodes, fleet.n_rows))
        for n in range(hierarchy.n_nodes):
            node_matrix[n, hierarchy.leaf_desc[n]] = 1.0
        node_names = tuple(hierarchy.names)
    else:
        row_budget = np.asarray(base_budgets, dtype=np.float64)

    alive, bscale = _lower_faults(sc, n_ticks, dt, fleet.n_rows, hierarchy)
    occ60 = _member_occupancy(sc, spec.seeds(), t60, fleet.n_rows,
                              fleet.n_servers)
    stride = max(1, math.ceil(n_ticks / _IMPACT_SLOTS))
    tc = sc.telemetry
    oob_ticks = max(1, math.ceil(tc.oob_latency_s / dt))
    brake_ticks = max(1, math.ceil(tc.brake_latency_s / dt))
    model = TickModel(
        base_name=sc.name, n_members=spec.n_seeds, n_rows=fleet.n_rows,
        n_ticks=n_ticks, dt=dt, occ60=occ60, alive=alive, budget_scale=bscale,
        row_budget_w=row_budget, n_servers=fleet.n_servers,
        power_scale=float(sc.power_scale),
        oob_ticks=oob_ticks, brake_ticks=brake_ticks,
        ring_depth=max(oob_ticks, brake_ticks) + 1,
        stride=stride, n_slots=math.ceil(n_ticks / stride),
        node_matrix=node_matrix, node_names=node_names,
        seeds=tuple(spec.seeds()),
        **_policy_constants(sc), **_power_constants(sc))
    return model, members, budget


# ---------------------------------------------------------------------------
# shared tick math (both backends call these with their own array module)
# ---------------------------------------------------------------------------

def _row_power_w(model: TickModel, occ, f_lp, f_hp, xp):
    """Per-row watts at occupancy + frequency state (the closed-form fluid
    power plane; identical expression on both backends)."""
    busy = (model.k_lp_w * f_lp ** model.gamma
            + model.k_hp_w * f_hp ** model.gamma)
    return (model.power_scale * model.n_servers
            * (model.p0_srv_w + occ * busy))


def _lp_power_w(model: TickModel, occ, f_lp, xp):
    return (model.power_scale * model.n_servers
            * (model.lp_share * model.p0_srv_w
               + occ * model.k_lp_w * f_lp ** model.gamma))


def _slo_step(model: TickModel, occ, f_lp, f_hp, backlog_hp, backlog_lp, xp):
    """One tick of the per-priority fluid SLO proxy: slowdown from the DVFS
    perf model (``a/f + (1-a)``) plus a queue-delay backlog integrator —
    occupancy x slowdown > 1 means the row can't keep up and delay accrues.
    Returns (backlog_hp', backlog_lp', impact_hp, impact_lp)."""
    sd_hp = model.a_hp / xp.maximum(f_hp, 1e-3) + (1.0 - model.a_hp)
    sd_lp = model.a_lp / xp.maximum(f_lp, 1e-3) + (1.0 - model.a_lp)
    backlog_hp = xp.maximum(0.0, backlog_hp + (occ * sd_hp - 1.0) * model.dt)
    backlog_lp = xp.maximum(0.0, backlog_lp + (occ * sd_lp - 1.0) * model.dt)
    imp_hp = (sd_hp - 1.0) + backlog_hp / model.svc_hp
    imp_lp = (sd_lp - 1.0) + backlog_lp / model.svc_lp
    return backlog_hp, backlog_lp, imp_hp, imp_lp


def _interp_weights(model: TickModel) -> Tuple[np.ndarray, np.ndarray]:
    """Per-tick (left index, right weight) into the 60 s occupancy grid —
    precomputed once so both backends interpolate identically."""
    t = model.tick_times()
    g = t / 60.0
    n60 = model.occ60.shape[2]
    i = np.clip(np.floor(g).astype(np.int64), 0, n60 - 2)
    w = np.clip(g - i, 0.0, 1.0)
    return i, w


# ---------------------------------------------------------------------------
# numpy oracle: the tick/ring contract driven by the real policy objects
# ---------------------------------------------------------------------------

def _run_oracle(model: TickModel, members: List[Scenario],
                keep_series: bool) -> BatchedRun:
    N, R, T, D = model.n_members, model.n_rows, model.n_ticks, model.ring_depth
    i_idx, i_w = _interp_weights(model)
    t_ticks = model.tick_times()
    brake_fire = np.zeros((N, T, R), dtype=bool)
    n_brakes = np.zeros((N, R), dtype=np.int64)
    peak = np.zeros(N)
    mean = np.zeros(N)
    imp_hp = np.zeros((N, R, model.n_slots))
    imp_lp = np.zeros((N, R, model.n_slots))
    total = np.zeros((N, T)) if keep_series else None
    row_w_out = np.zeros((N, T, R)) if keep_series else None
    total_budget = model.total_budget_w

    for m, member in enumerate(members):
        policies = [member.policy.build() for _ in range(R)]
        f_lp = np.ones(R)
        f_hp = np.ones(R)
        ring = np.full((R, D, 2), np.nan)
        backlog_hp = np.zeros(R)
        backlog_lp = np.zeros(R)
        occ60 = model.occ60[m]  # [R, T60]
        frac_sum = 0.0
        frac_peak = 0.0
        for k in range(T):
            slot = k % D
            pend = ring[:, slot, :]
            has = ~np.isnan(pend)
            f_lp = np.where(has[:, 0], pend[:, 0], f_lp)
            f_hp = np.where(has[:, 1], pend[:, 1], f_hp)
            ring[:, slot, :] = np.nan
            occ = (occ60[:, i_idx[k]] * (1.0 - i_w[k])
                   + occ60[:, i_idx[k] + 1] * i_w[k]) * model.alive[k]
            rw = _row_power_w(model, occ, f_lp, f_hp, np)
            frac = float(rw.sum()) / total_budget
            frac_peak = max(frac_peak, frac)
            frac_sum += frac
            if keep_series:
                total[m, k] = frac
                row_w_out[m, k] = rw
            tick_budget = model.row_budget_w * model.budget_scale[k]
            p = rw / tick_budget
            lp_frac = _lp_power_w(model, occ, f_lp, np) / tick_budget
            for r in range(R):
                pol = policies[r]
                before = pol.n_brakes
                cmds = pol.observe(Telemetry(
                    t=float(t_ticks[k]), power_frac=float(p[r]),
                    lp_power_frac=float(lp_frac[r]), row_index=r))
                if pol.n_brakes > before:
                    brake_fire[m, k, r] = True
                for cmd in cmds:
                    d = model.brake_ticks if cmd.brake else model.oob_ticks
                    s = (k + d) % D
                    if cmd.lp_freq is not None:
                        ring[r, s, 0] = cmd.lp_freq
                    if cmd.hp_freq is not None:
                        ring[r, s, 1] = cmd.hp_freq
            backlog_hp, backlog_lp, ih, il = _slo_step(
                model, occ, f_lp, f_hp, backlog_hp, backlog_lp, np)
            if k % model.stride == 0:
                imp_hp[m, :, k // model.stride] = ih
                imp_lp[m, :, k // model.stride] = il
        n_brakes[m] = [pol.n_brakes for pol in policies]
        peak[m] = frac_peak
        mean[m] = frac_sum / T

    node_w = None
    if keep_series and model.node_matrix is not None:
        node_w = np.einsum("ntr,mr->ntm", row_w_out, model.node_matrix)
    return BatchedRun(engine="numpy", model=model, brake_fire=brake_fire,
                      n_brakes=n_brakes, peak_frac=peak, mean_frac=mean,
                      impacts_hp=imp_hp, impacts_lp=imp_lp, total_frac=total,
                      row_w=row_w_out, node_w=node_w)


# ---------------------------------------------------------------------------
# jax engine: lax.scan over ticks, vmap over members
# ---------------------------------------------------------------------------

class _JaxCfg(NamedTuple):
    """Static (compile-time) shape/flag key for the jitted runner."""
    T: int
    R: int
    D: int
    W: int
    S: int
    stride: int
    oob_ticks: int
    brake_ticks: int
    esc: int
    predictive: bool
    keep_series: bool


@lru_cache(maxsize=32)
def _jax_runner(cfg: _JaxCfg):
    import jax
    import jax.numpy as jnp
    from jax import lax

    def polca_step(c, p_obs, p_raw, lp_frac, consts):
        """One vectorized tick of PolcaPolicy.observe over R rows. Mirrors
        core.policy line for line: the overload path sets every cap flag and
        skips releases; cap/escalation branches run only out of overload;
        releases read the *post-cap* flags, and the T1 release additionally
        requires T2 to have just released or been clear."""
        t1c, t2c, hpc, brk, t2s = c["t1c"], c["t2c"], c["hpc"], c["brk"], c["t2s"]
        over = p_obs > 1.0
        fire = over & ~brk
        rel_brake = ~over & brk
        if cfg.predictive:
            informed = (t2c & ~hpc & (p_raw > consts["t2"])
                        & (lp_frac < p_raw - consts["t2"]))
            t2s = jnp.where(informed, cfg.esc, t2s)
        hi2 = p_obs > consts["t2"]
        cap_t2 = ~over & hi2 & ~t2c
        esc_tick = ~over & hi2 & t2c & ~hpc
        t2s = jnp.where(cap_t2, 0, jnp.where(esc_tick, t2s + 1, t2s))
        cap_hp = esc_tick & (t2s >= cfg.esc)
        cap_t1 = ~over & ~hi2 & (p_obs > consts["t1"]) & ~t1c
        t2c_mid = t2c | over | cap_t2
        t1c_mid = t1c | over | cap_t2 | cap_t1
        hpc_mid = hpc | over | cap_hp
        rel_t2 = ~over & t2c_mid & (p_obs < consts["t2"] - consts["t2_buf"])
        t2c = t2c_mid & ~rel_t2
        hpc = hpc_mid & ~rel_t2
        rel_t1 = (~over & t1c_mid & ~t2c
                  & (p_obs < consts["t1"] - consts["t1_buf"]))
        t1c = t1c_mid & ~rel_t1
        new = dict(c, t1c=t1c, t2c=t2c, hpc=hpc, brk=over, t2s=t2s,
                   nbr=c["nbr"] + fire.astype(jnp.int32))
        # command emission per frequency field, in the policy's cmd-list
        # order (later overwrites earlier — the DES same-due-time rule)
        nanv = jnp.full(p_obs.shape, jnp.nan)
        lp_cmd = nanv
        hp_cmd = nanv
        lp_cmd = jnp.where(rel_brake, consts["lp_t2"], lp_cmd)
        hp_cmd = jnp.where(rel_brake, consts["hp_t2"], hp_cmd)
        lp_cmd = jnp.where(cap_t2, consts["lp_t2"], lp_cmd)
        hp_cmd = jnp.where(cap_hp, consts["hp_t2"], hp_cmd)
        lp_cmd = jnp.where(cap_t1, consts["lp_t1"], lp_cmd)
        lp_cmd = jnp.where(rel_t2, consts["lp_t1"], lp_cmd)
        hp_cmd = jnp.where(rel_t2, 1.0, hp_cmd)
        lp_cmd = jnp.where(rel_t1, 1.0, lp_cmd)
        return new, fire, lp_cmd, hp_cmd

    def predict(c, t, p, consts):
        """PredictivePolcaPolicy._predict: windowed least-squares slope
        extrapolated horizon_s ahead, clamped below 1.0 unless the measured
        power already breached (brakes are never predicted). Raw samples
        enter the history, exactly as in the reference policy."""
        ht, hp, k = c["hist_t"], c["hist_p"], c["k"]
        W = cfg.W
        idx = jnp.minimum(k, W - 1)
        ins_t = ht.at[:, idx].set(t)
        ins_p = hp.at[:, idx].set(p)
        roll_t = jnp.roll(ht, -1, axis=1).at[:, -1].set(t)
        roll_p = jnp.roll(hp, -1, axis=1).at[:, -1].set(p)
        grow = k < W
        ht = jnp.where(grow, ins_t, roll_t)
        hp = jnp.where(grow, ins_p, roll_p)
        nn = jnp.minimum(k + 1, W).astype(jnp.float64)
        valid = (jnp.arange(W) < jnp.minimum(k + 1, W))[None, :]
        tm = jnp.sum(jnp.where(valid, ht, 0.0), axis=1) / nn
        pm = jnp.sum(jnp.where(valid, hp, 0.0), axis=1) / nn
        dt_ = jnp.where(valid, ht - tm[:, None], 0.0)
        dp_ = jnp.where(valid, hp - pm[:, None], 0.0)
        num = jnp.sum(dt_ * dp_, axis=1)
        den = jnp.sum(dt_ * dt_, axis=1)
        slope = num / jnp.where(den > 0.0, den, 1.0)
        p_ext = jnp.where((nn >= 3) & (den > 0.0),
                          jnp.maximum(p, p + slope * consts["horizon"]), p)
        p_obs = jnp.where(p <= 1.0, jnp.minimum(p_ext, 1.0 - 1e-9), p_ext)
        return dict(c, hist_t=ht, hist_p=hp), p_obs

    def run(scalars, occ60_all, consts, xs):
        T, R, D, S = cfg.T, cfg.R, cfg.D, cfg.S

        def step_for(occ60):
            def step(c, x):
                k, t, ii, iw, alive, bscale = x
                slot = k % D
                pend = lax.dynamic_index_in_dim(c["ring"], slot, axis=1,
                                                keepdims=False)  # [R, 2]
                has = ~jnp.isnan(pend)
                f_lp = jnp.where(has[:, 0], pend[:, 0], c["f_lp"])
                f_hp = jnp.where(has[:, 1], pend[:, 1], c["f_hp"])
                ring = lax.dynamic_update_index_in_dim(
                    c["ring"], jnp.full((R, 2), jnp.nan), slot, axis=1)
                occ = ((occ60[:, ii] * (1.0 - iw) + occ60[:, ii + 1] * iw)
                       * alive)
                rw = _row_power_w(scalars, occ, f_lp, f_hp, jnp)
                frac = jnp.sum(rw) / consts["total_budget"]
                tick_budget = consts["row_budget"] * bscale
                p_raw = rw / tick_budget
                lp_frac = _lp_power_w(scalars, occ, f_lp, jnp) / tick_budget
                c = dict(c, f_lp=f_lp, f_hp=f_hp, ring=ring, k=k)
                if cfg.predictive:
                    c, p_obs = predict(c, t, p_raw, consts)
                else:
                    p_obs = p_raw
                c, fire, lp_cmd, hp_cmd = polca_step(c, p_obs, p_raw, lp_frac,
                                                     consts)
                ring = c["ring"]
                s_oob = (k + cfg.oob_ticks) % D
                s_brk = (k + cfg.brake_ticks) % D
                oob_slot = lax.dynamic_index_in_dim(ring, s_oob, axis=1,
                                                    keepdims=False)
                oob_slot = jnp.stack([
                    jnp.where(jnp.isnan(lp_cmd), oob_slot[:, 0], lp_cmd),
                    jnp.where(jnp.isnan(hp_cmd), oob_slot[:, 1], hp_cmd)],
                    axis=1)
                ring = lax.dynamic_update_index_in_dim(ring, oob_slot, s_oob,
                                                       axis=1)
                brk_slot = lax.dynamic_index_in_dim(ring, s_brk, axis=1,
                                                    keepdims=False)
                brk_val = jnp.where(fire[:, None],
                                    jnp.full((R, 2), consts["brake_freq"]),
                                    brk_slot)
                ring = lax.dynamic_update_index_in_dim(ring, brk_val, s_brk,
                                                       axis=1)
                bh, bl, ih, il = _slo_step(scalars, occ, f_lp, f_hp,
                                           c["backlog_hp"], c["backlog_lp"],
                                           jnp)
                imp = jnp.stack([ih, il], axis=1)  # [R, 2]
                zero = jnp.asarray(0, k.dtype)
                upd = lax.dynamic_update_slice(c["imp"], imp[None],
                                               (k // cfg.stride, zero, zero))
                imp_buf = jnp.where(k % cfg.stride == 0, upd, c["imp"])
                c = dict(c, ring=ring, backlog_hp=bh, backlog_lp=bl,
                         imp=imp_buf, peak=jnp.maximum(c["peak"], frac),
                         fsum=c["fsum"] + frac)
                ys = (fire, frac, rw) if cfg.keep_series else (fire,)
                return c, ys
            return step

        def run_member(occ60):
            carry = dict(
                f_lp=jnp.ones(R), f_hp=jnp.ones(R),
                ring=jnp.full((R, D, 2), jnp.nan),
                t1c=jnp.zeros(R, bool), t2c=jnp.zeros(R, bool),
                hpc=jnp.zeros(R, bool), brk=jnp.zeros(R, bool),
                t2s=jnp.zeros(R, jnp.int32), nbr=jnp.zeros(R, jnp.int32),
                backlog_hp=jnp.zeros(R), backlog_lp=jnp.zeros(R),
                imp=jnp.zeros((S, R, 2)), peak=jnp.asarray(0.0),
                fsum=jnp.asarray(0.0), k=jnp.asarray(0, jnp.int32),
            )
            if cfg.predictive:
                carry.update(hist_t=jnp.zeros((R, cfg.W)),
                             hist_p=jnp.zeros((R, cfg.W)))
            final, ys = lax.scan(step_for(occ60), carry, xs)
            out = dict(fire=ys[0], nbr=final["nbr"], peak=final["peak"],
                       mean=final["fsum"] / T, imp=final["imp"])
            if cfg.keep_series:
                out.update(frac=ys[1], row_w=ys[2])
            return out

        return jax.vmap(run_member)(occ60_all)

    return jax.jit(run, static_argnums=(0,))


def _run_jax(model: TickModel, keep_series: bool) -> BatchedRun:
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    cfg = _JaxCfg(T=model.n_ticks, R=model.n_rows, D=model.ring_depth,
                  W=max(1, model.window), S=model.n_slots,
                  stride=model.stride, oob_ticks=model.oob_ticks,
                  brake_ticks=model.brake_ticks, esc=model.escalation_ticks,
                  predictive=model.predictive, keep_series=keep_series)
    runner = _jax_runner(cfg)
    i_idx, i_w = _interp_weights(model)
    with enable_x64():
        consts = dict(
            t1=jnp.asarray(model.t1), t2=jnp.asarray(model.t2),
            t1_buf=jnp.asarray(model.t1_buffer),
            t2_buf=jnp.asarray(model.t2_buffer),
            lp_t1=jnp.asarray(model.lp_freq_t1),
            lp_t2=jnp.asarray(model.lp_freq_t2),
            hp_t2=jnp.asarray(model.hp_freq_t2),
            brake_freq=jnp.asarray(model.brake_freq),
            horizon=jnp.asarray(model.horizon_s),
            total_budget=jnp.asarray(model.total_budget_w),
            row_budget=jnp.asarray(model.row_budget_w),
        )
        xs = (jnp.arange(model.n_ticks, dtype=jnp.int32),
              jnp.asarray(model.tick_times()),
              jnp.asarray(i_idx, dtype=jnp.int32), jnp.asarray(i_w),
              jnp.asarray(model.alive), jnp.asarray(model.budget_scale))
        # the static arg: closed-form scalars only, hashable via the frozen
        # dataclass minus its array fields
        scalars = _ScalarModel.from_model(model)
        out = runner(scalars, jnp.asarray(model.occ60), consts, xs)
        fire = np.asarray(out["fire"])  # [N, T, R]
        imp = np.asarray(out["imp"])  # [N, S, R, 2]
        run = BatchedRun(
            engine="jax", model=model,
            brake_fire=np.asarray(fire, dtype=bool),
            n_brakes=np.asarray(out["nbr"], dtype=np.int64),
            peak_frac=np.asarray(out["peak"], dtype=np.float64),
            mean_frac=np.asarray(out["mean"], dtype=np.float64),
            impacts_hp=np.ascontiguousarray(imp[:, :, :, 0].transpose(0, 2, 1)),
            impacts_lp=np.ascontiguousarray(imp[:, :, :, 1].transpose(0, 2, 1)),
        )
        if keep_series:
            run.total_frac = np.asarray(out["frac"], dtype=np.float64)
            run.row_w = np.asarray(out["row_w"], dtype=np.float64)
            if model.node_matrix is not None:
                run.node_w = np.einsum("ntr,mr->ntm", run.row_w,
                                       model.node_matrix)
    return run


@dataclass(frozen=True)
class _ScalarModel:
    """The closed-form scalar slice of a TickModel — hashable, so it can be
    a static jit argument (the arrays travel as traced operands)."""
    dt: float
    p0_srv_w: float
    k_lp_w: float
    k_hp_w: float
    lp_share: float
    gamma: float
    n_servers: int
    power_scale: float
    a_hp: float
    a_lp: float
    svc_hp: float
    svc_lp: float

    @classmethod
    def from_model(cls, m: TickModel) -> "_ScalarModel":
        return cls(dt=m.dt, p0_srv_w=m.p0_srv_w, k_lp_w=m.k_lp_w,
                   k_hp_w=m.k_hp_w, lp_share=m.lp_share, gamma=m.gamma,
                   n_servers=m.n_servers, power_scale=m.power_scale,
                   a_hp=m.a_hp, a_lp=m.a_lp, svc_hp=m.svc_hp,
                   svc_lp=m.svc_lp)


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------

def run_tick_model(model: TickModel, members: List[Scenario], *,
                   engine: str = "jax",
                   keep_series: bool = True) -> BatchedRun:
    """Run a lowered tick program on one backend. ``engine="numpy"`` is the
    oracle (real policy objects through Telemetry); ``engine="jax"`` the
    vectorized device program. Differential tests run both and compare."""
    if engine == "numpy":
        return _run_oracle(model, members, keep_series)
    if engine == "jax":
        return _run_jax(model, keep_series)
    raise ValueError(f"unknown batched engine {engine!r} "
                     "(expected 'numpy' or 'jax')")


def _to_ensemble_result(model: TickModel, members: List[Scenario],
                        budget_w: float, run: BatchedRun) -> EnsembleResult:
    """Adapt a BatchedRun to the EnsembleResult shape the planner and the
    distributional statistics consume. ``power_frac`` rows are member
    total-budget fractions (the same quantity the DES engine stacks —
    ``SimResult.power_w`` records the telemetry fraction series)."""
    stats: List[MemberStats] = []
    t = model.tick_times()
    for m, sc in enumerate(members):
        series = (run.total_frac[m] if run.total_frac is not None else None)
        res = SimResult(
            latency=run.member_stats(m),
            n_brakes=int(run.n_brakes[m].sum()),
            n_dropped=0, n_completed=0, served_tokens=0.0,
            peak_power_frac=float(run.peak_frac[m]),
            mean_power_frac=float(run.mean_frac[m]),
            power_t=(t if series is not None else None),
            power_w=series)
        stats.append(MemberStats(sc, res, res.latency))
    if run.total_frac is not None:
        power = np.asarray(run.total_frac)
        power_t = t
    else:
        power = np.zeros((0, 0))
        power_t = np.zeros(0)
    return EnsembleResult(
        base_name=model.base_name, budget_w=budget_w, members=stats,
        power_t=power_t, power_frac=power,
        brake_counts=np.asarray(run.n_brakes.sum(axis=1)),
        peak_fracs=np.asarray(run.peak_frac),
        mean_fracs=np.asarray(run.mean_frac))


def run_batched_ensemble(spec: EnsembleSpec, *,
                         budget_w: Optional[float] = None,
                         engine: str = "jax",
                         keep_series: Optional[bool] = None) -> EnsembleResult:
    """Evaluate an ensemble on the batched tick engine.

    The drop-in dense-tail counterpart of ``montecarlo.run_ensemble`` —
    same EnsembleResult surface, 10^4+ members in one device program.
    ``keep_series=None`` keeps per-tick power series while ``members x
    ticks`` stays under 4e6 cells and drops them beyond (matching the DES
    engine's ``record_power=False`` empty-matrix shape)."""
    if engine == "batched-numpy":  # run_ensemble's name for the tick oracle
        engine = "numpy"
    with get_recorder().span("mc/run_batched", base=spec.base.name,
                             members=spec.n_seeds, engine=engine):
        model, members, budget = lower_ensemble(spec, budget_w=budget_w)
        if keep_series is None:
            keep_series = model.n_members * model.n_ticks <= _SERIES_CELL_LIMIT
        run = run_tick_model(model, members, engine=engine,
                             keep_series=keep_series)
        return _to_ensemble_result(model, members, budget, run)
