"""JAX-native batched ensemble engine with a numpy differential oracle.

The Monte-Carlo engine in ``provisioning.montecarlo`` parallelizes the
event-driven :class:`~repro.core.simulator.RowSimulator` across a fork pool —
throughput is capped by host cores (< 2 effective in CI), so risk tails stay
at tens of members. This module rebuilds the hot loop as a *tick-level fluid
model* that runs N ensemble members x T telemetry ticks as one batched device
program (DESIGN.md §15):

* **Lowering** — :func:`lower_ensemble` compiles a
  :class:`~repro.experiments.scenario.Scenario` + member seeds into a
  :class:`TickModel`: per-member occupancy on the 60 s trace grid, closed-form
  power coefficients from the Table-4 workload mix (idle + per-priority
  busy-power terms with the DVFS ``f^gamma`` law from
  ``core.power_model``), the POLCA thresholds/frequencies, fault timelines
  lowered to per-tick budget scales and row-alive masks, and the
  ``PowerHierarchy`` node matrix for segment-sum folds.

* **Three backends, one contract** — ``engine="jax"`` runs the tick advance
  as a ``lax.scan`` over time ``vmap``-ed over members, with the
  :class:`~repro.core.policy.PolcaPolicy` /
  :class:`~repro.core.policy.PredictivePolcaPolicy` observe step (windowed
  least-squares slope over the 40 s OOB horizon) carried in scan state as a
  vectorized boolean state machine; the latch math lives in
  :func:`repro.kernels.tick.polca_latch_step`, shared with the Pallas
  backend. ``engine="pallas"`` runs the non-predictive tick inner loop
  (power fold + latch/ring update) as the :func:`repro.kernels.ops.
  polca_tick` kernel, interpret-mode on CPU. ``engine="numpy"`` is the
  differential **oracle**: the identical tick/ring contract driven by the
  *real* policy objects through :class:`~repro.core.telemetry.Telemetry`,
  one instance per (member, row) — so the vectorized state machine is
  checked against the genuine Algorithm-1 implementation, not a
  reimplementation of itself (``tests/test_batched_parity.py``).

* **Grids, shards, chunks (DESIGN.md §16)** — per-scenario scalars are
  *traced* operands (:class:`_Consts`), not compile-time constants, so one
  compiled program serves every scenario sharing tick geometry:
  :func:`run_batched_grid` stacks M lowered models and ``vmap``s the
  scenario axis on top of the member axis (one jit call per geometry
  bucket), and a ``plan_capacity`` bisection stops recompiling per probe
  (``jax_trace_count`` is the regression hook). The member axis optionally
  shards over a ``("data",)`` mesh (``launch.mesh.data_mesh`` +
  ``shard_map``) and/or advances in ``member_chunk``-sized ``lax.scan``
  blocks, bounding live memory so 10^5-10^6-member tails fit on one host.

* **Actuation ring** — out-of-band cap commands apply ``ceil(40/2)=20``
  ticks after issue and powerbrakes ``ceil(5/2)=3`` ticks after, modeled as
  a ``[rows, D, 2]`` ring buffer (NaN = no command); later-issued commands
  overwrite earlier ones per frequency field, which is exactly the DES event
  queue's same-due-time resolution.

The oracle contract deliberately accepts two float nonidentities, both
documented in DESIGN.md §15: XLA may fuse multiply-adds (power series agree
to ~1e-15, asserted <= 1e-6 relative), and ``jnp.sum`` may reorder the
predictive slope accumulation (~1e-16). Brake-tick *sets* are compared for
bit-equality on the harness scenarios; a flip would need a power sample
within ~1e-12 of a threshold.

``montecarlo.run_ensemble(engine=...)`` dispatches here, and
``planner.plan_capacity(engine="jax")`` uses the dense tails to activate the
CVaR gate in ``RiskConstraints``.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from functools import lru_cache
from typing import Dict, List, NamedTuple, Optional, Sequence, Tuple

import numpy as np

from repro.core.policy import PolcaPolicy, PredictivePolcaPolicy
from repro.core.simulator import SimResult
from repro.core.slo import LatencyStats
from repro.core.telemetry import Telemetry
from repro.core.traces import TABLE4, get_occupancy_generator
from repro.experiments.runner import build_workloads, row_budgets
from repro.experiments.scenario import Scenario
from repro.obs.metrics import get_recorder
from repro.provisioning.montecarlo import (
    EnsembleResult,
    EnsembleSpec,
    MemberStats,
    resolve_ensemble_budget,
)

# members x ticks above which run_batched_ensemble drops per-tick series by
# default (a [N, T] float64 matrix; 4e6 ~ 32 MB) — mirroring the
# record_power=False path of the DES engine
_SERIES_CELL_LIMIT = 4_000_000
# per-member SLO-impact samples are decimated onto at most this many slots
_IMPACT_SLOTS = 256
# member_chunk=None (auto) scans blocks of about this many members (counted
# across the whole scenario axis): the ~2 KB/member scan carry then stays
# cache-resident, which beats a flat vmap well before memory binds
_AUTO_CHUNK_MEMBERS = 512
_JITTER_SALT = 9173  # member-occupancy jitter stream, disjoint from arrivals


@dataclass(frozen=True)
class TickModel:
    """A Scenario + member seeds lowered to the batched tick program.

    Everything both backends consume: static arrays on the tick/trace grids
    plus closed-form scalars. The model is engine-agnostic — running it with
    ``engine="numpy"`` and ``engine="jax"`` must agree per the oracle
    contract (DESIGN.md §15)."""

    base_name: str
    n_members: int
    n_rows: int
    n_ticks: int  # T
    dt: float  # telemetry_s
    occ60: np.ndarray = field(repr=False)  # [N, R, T60] occupancy, 60 s grid
    alive: np.ndarray = field(repr=False)  # [T, R] 0/1 row-crash mask
    budget_scale: np.ndarray = field(repr=False)  # [T, R] fault derates
    row_budget_w: np.ndarray = field(repr=False)  # [R] static budgets
    # power plane (closed form over the Table-4 mix; watts per server)
    p0_srv_w: float  # idle server watts
    k_lp_w: float  # LP busy-power coefficient (x f_lp^gamma)
    k_hp_w: float  # HP busy-power coefficient (x f_hp^gamma)
    lp_share: float  # LP fraction of the server pool
    gamma: float
    n_servers: int
    power_scale: float
    # policy constants (resolved from the PolicySpec)
    predictive: bool
    t1: float
    t2: float
    t1_buffer: float
    t2_buffer: float
    lp_freq_t1: float
    lp_freq_t2: float
    hp_freq_t2: float
    brake_freq: float
    escalation_ticks: int
    horizon_s: float
    window: int
    # actuation ring
    oob_ticks: int
    brake_ticks: int
    ring_depth: int  # D = max(oob, brake) + 1
    # SLO fluid proxy (per-priority clock-sensitive fraction + service time)
    a_hp: float
    a_lp: float
    svc_hp: float
    svc_lp: float
    has_hp: bool
    has_lp: bool
    # impact decimation
    stride: int
    n_slots: int  # S = ceil(T / stride)
    # hierarchy segment-sum fold (None = flat row accounting)
    node_matrix: Optional[np.ndarray] = field(default=None, repr=False)  # [n_nodes, R]
    node_names: Tuple[str, ...] = ()
    seeds: Tuple[int, ...] = ()

    @property
    def total_budget_w(self) -> float:
        return float(self.row_budget_w.sum())

    def tick_times(self) -> np.ndarray:
        """Telemetry timestamps: tick k samples t = (k+1) * dt."""
        return (np.arange(self.n_ticks, dtype=np.float64) + 1.0) * self.dt


@dataclass
class BatchedRun:
    """Raw output of one tick-program run (either backend).

    ``brake_fire[m, k, r]`` marks the policy firing a powerbrake on row r at
    tick k of member m — the brake-tick set the differential harness compares
    bit-for-bit. Series fields are ``None`` when the run dropped them
    (``keep_series=False``)."""

    engine: str
    model: TickModel
    # [N, T, R] bool; None when the run dropped the per-tick plane
    # (keep_brake_fire=False — dense tails keep only the n_brakes counts)
    brake_fire: Optional[np.ndarray] = field(repr=False)
    n_brakes: np.ndarray = field(repr=False)  # [N, R] int
    peak_frac: np.ndarray = field(repr=False)  # [N]
    mean_frac: np.ndarray = field(repr=False)  # [N]
    impacts_hp: np.ndarray = field(repr=False)  # [N, R, S]
    impacts_lp: np.ndarray = field(repr=False)  # [N, R, S]
    total_frac: Optional[np.ndarray] = field(default=None, repr=False)  # [N, T]
    row_w: Optional[np.ndarray] = field(default=None, repr=False)  # [N, T, R]
    node_w: Optional[np.ndarray] = field(default=None, repr=False)  # [N, T, nodes]

    def brake_ticks(self) -> np.ndarray:
        """Sorted (member, tick, row) index triples of every brake firing —
        the bit-compared set of the oracle contract."""
        if self.brake_fire is None:
            raise ValueError(
                "this run dropped the per-tick brake plane "
                "(keep_brake_fire=False); only n_brakes counts survive")
        return np.argwhere(self.brake_fire)

    def member_stats(self, m: int) -> LatencyStats:
        hp = self.impacts_hp[m].ravel() if self.model.has_hp else np.zeros(0)
        lp = self.impacts_lp[m].ravel() if self.model.has_lp else np.zeros(0)
        return LatencyStats(hp_impacts=[float(x) for x in hp],
                            lp_impacts=[float(x) for x in lp])


# ---------------------------------------------------------------------------
# lowering
# ---------------------------------------------------------------------------

def _policy_constants(sc: Scenario) -> Dict[str, object]:
    pol = sc.policy.build()
    if isinstance(pol, PredictivePolcaPolicy):
        predictive = True
    elif isinstance(pol, PolcaPolicy):
        predictive = False
    else:
        raise ValueError(
            f"batched engine supports polca/polca-predictive policies; "
            f"scenario {sc.name!r} uses {sc.policy.kind!r} (run it on the "
            f"event-driven engine instead)")
    return dict(
        predictive=predictive,
        t1=float(pol.t1), t2=float(pol.t2),
        t1_buffer=float(pol.t1_buffer), t2_buffer=float(pol.t2_buffer),
        lp_freq_t1=float(pol.lp_freq_t1), lp_freq_t2=float(pol.lp_freq_t2),
        hp_freq_t2=float(pol.hp_freq_t2), brake_freq=float(pol.brake_freq),
        escalation_ticks=int(pol.escalation_ticks),
        horizon_s=float(getattr(pol, "horizon_s", 40.0)),
        window=int(getattr(pol, "window", 8)),
    )


_POWER_CONSTS_CACHE: Dict[tuple, Dict[str, float]] = {}


def _power_constants(sc: Scenario) -> Dict[str, float]:
    """Closed-form power/SLO coefficients over the Table-4 workload mix.

    A busy server running class w draws ``idle + k_w * f^gamma`` watts where
    ``k_w = n_dev * (p_peak - idle) * u_eff_w`` and ``u_eff_w`` is the
    prefill/decode-time-weighted roofline utilization — exactly
    ``DevicePower.power`` evaluated at the class's two
    :class:`~repro.core.workload.PhasePoint` operating points. Classes then
    collapse into one LP and one HP coefficient via share x priority mix.

    Per-server coefficients are independent of fleet *size*, so the result
    is cached on the (model, device, devices/server, mix) key — a
    ``plan_capacity`` bisection re-lowers per probe (the occupancy jitter
    scales with ``n_servers``, so member traces legitimately change) but
    never recomputes this plane."""
    key = (sc.fleet.model, sc.fleet.device, sc.fleet.n_devices_per_server,
           sc.traffic.priority_mix_override)
    hit = _POWER_CONSTS_CACHE.get(key)
    if hit is not None:
        return hit
    wls, shares = build_workloads(sc)
    server = sc.fleet.server()
    dev = server.device
    k_lp = k_hp = lp_share = 0.0
    a_num = {"high": 0.0, "low": 0.0}
    svc_num = {"high": 0.0, "low": 0.0}
    wgt_tot = {"high": 0.0, "low": 0.0}
    for wl, share, spec in zip(wls, shares, TABLE4):
        mean_out = 0.5 * (spec.out_range[0] + spec.out_range[1])
        t_total = wl.timing.t_prefill + mean_out * wl.timing.t_token
        f_pre = wl.timing.t_prefill / t_total
        u_eff = 0.0
        cf_eff = 0.0
        for frac, pt in ((f_pre, wl.timing.prefill_point),
                         (1.0 - f_pre, wl.timing.token_point)):
            u = min(1.0, dev.w_compute * min(pt.u_compute, 1.0)
                    + dev.w_memory * min(pt.u_memory, 1.0))
            u_eff += frac * u
            cf_eff += frac * pt.compute_frac
        k_srv = server.n_devices * (dev.p_peak - dev.idle_w) * u_eff
        mix = wl.priority_mix
        k_hp += share * mix * k_srv
        k_lp += share * (1.0 - mix) * k_srv
        lp_share += share * (1.0 - mix)
        for prio, wgt in (("high", share * mix), ("low", share * (1.0 - mix))):
            wgt_tot[prio] += wgt
            a_num[prio] += wgt * cf_eff
            svc_num[prio] += wgt * t_total
    out = dict(p0_srv_w=float(server.idle_power), k_lp_w=float(k_lp),
               k_hp_w=float(k_hp), lp_share=float(lp_share),
               gamma=float(dev.gamma))
    for prio, pkey in (("high", "hp"), ("low", "lp")):
        has = wgt_tot[prio] > 0.0
        out[f"has_{pkey}"] = bool(has)
        out[f"a_{pkey}"] = float(a_num[prio] / wgt_tot[prio]) if has else 0.0
        out[f"svc_{pkey}"] = float(svc_num[prio] / wgt_tot[prio]) if has else 1.0
    _POWER_CONSTS_CACHE[key] = out
    return out


# base generator curves are independent of fleet size (only the CLT jitter
# scales with n_servers), so a plan_capacity bisection — which re-lowers per
# probe because fleets differ — reuses them across every probe
_BASE_OCC_CACHE: Dict[tuple, np.ndarray] = {}
# sized for a 4-generator x 10^3-seed x 2-row grid with headroom; entries
# are short 60 s-grid curves (a few KB each), so the cap is ~100 MB worst
# case and far smaller in practice
_BASE_OCC_CACHE_MAX = 16384


def _member_occupancy(sc: Scenario, seeds: Sequence[int], t60: np.ndarray,
                      n_rows: int, n_servers: int) -> np.ndarray:
    """[N, R, T60] occupancy: the scenario's registered generator per member
    seed + row, plus a member-seeded CLT busy-fraction jitter
    (sigma = sqrt(occ(1-occ)/n_servers)) standing in for the arrival-sampling
    noise of the DES — without it the diurnal family (which deliberately
    ignores the member seed) would collapse every member onto one curve."""
    gen = get_occupancy_generator(sc.traffic.generator)
    gkey = (sc.traffic.generator, len(t60),
            float(t60[-1]) if len(t60) else 0.0,
            float(sc.traffic.occ_peak), n_rows,
            tuple(sorted((k, repr(v))
                         for k, v in sc.traffic.gen_params.items())))
    occ = np.empty((len(seeds), n_rows, len(t60)), dtype=np.float64)
    for mi, seed in enumerate(seeds):
        for r in range(n_rows):
            ck = gkey + (int(seed), r)
            base = _BASE_OCC_CACHE.get(ck)
            if base is None:
                base = np.asarray(
                    gen(t60, seed=int(seed), peak=sc.traffic.occ_peak,
                        n_rows=n_rows, row=r, **sc.traffic.gen_params),
                    dtype=np.float64)
                if len(_BASE_OCC_CACHE) < _BASE_OCC_CACHE_MAX:
                    _BASE_OCC_CACHE[ck] = base
            rng = np.random.default_rng([int(seed), r, _JITTER_SALT])
            sigma = np.sqrt(np.clip(base * (1.0 - base), 0.0, None) / n_servers)
            occ[mi, r] = np.clip(base + rng.standard_normal(len(t60)) * sigma,
                                 0.0, 1.0)
    return occ


def _lower_faults(sc: Scenario, n_ticks: int, dt: float, n_rows: int,
                  hierarchy) -> Tuple[np.ndarray, np.ndarray]:
    """Fault timeline -> ([T, R] alive mask, [T, R] budget scale).

    Row crashes zero a row's occupancy (it idles until revived); budget
    events scale the *derated subtree's* row budgets per tick, ramping
    linearly over ``ramp_s`` and restoring at ``until`` — the same
    conservative-tree semantics the ChaosInjector enforces on the DES path.
    Unlike ``run_experiment``, faults here do not require a RoutingSpec: the
    tick model has no dispatcher to fence, so the masks are the whole story."""
    alive = np.ones((n_ticks, n_rows), dtype=np.float64)
    bscale = np.ones((n_ticks, n_rows), dtype=np.float64)
    faults = sc.faults
    if faults is None or faults.is_noop:
        return alive, bscale
    names = list(hierarchy.names) if hierarchy is not None else None
    faults.validate(duration_s=sc.duration_s, n_rows=n_rows, node_names=names)
    t_ticks = (np.arange(n_ticks, dtype=np.float64) + 1.0) * dt
    for e in sorted(faults.row_events(), key=lambda e: e.t):
        alive[t_ticks >= e.t, int(e.row)] = (
            0.0 if e.kind == "row-crash" else 1.0)
    for e in faults.budget_events():
        if e.kind == "site-demand-response" or hierarchy is None:
            if e.kind == "node-derate" and hierarchy is None:
                raise ValueError(
                    f"fault event {e.describe()} targets a hierarchy node "
                    f"but scenario {sc.name!r} has no HierarchySpec")
            rows = np.arange(n_rows)
        else:
            rows = hierarchy.subtree_leaves(list(hierarchy.names).index(e.node))
        ramp = (np.clip((t_ticks - e.t) / e.ramp_s, 0.0, 1.0) if e.ramp_s > 0
                else (t_ticks >= e.t).astype(np.float64))
        scale = 1.0 - (1.0 - e.factor) * ramp
        if e.until is not None:
            scale = np.where(t_ticks >= e.until, 1.0, scale)
        bscale[:, rows] *= scale[:, None]
    return alive, bscale


def lower_ensemble(spec: EnsembleSpec, *, budget_w: Optional[float] = None
                   ) -> Tuple[TickModel, List[Scenario], float]:
    """Lower an EnsembleSpec to the batched tick program. Returns
    ``(model, member_scenarios, resolved_budget_w)`` — members carry the
    same pinned budget ``run_ensemble`` would pin, so planner decisions on
    either engine answer the same question."""
    sc = spec.base
    if sc.routing is not None:
        raise ValueError(
            f"batched engine runs unrouted row/cluster scenarios; "
            f"{sc.name!r} carries a RoutingSpec (use engine='numpy' — the "
            f"event-driven fleet path)")
    if sc.duration_s < 120.0:
        raise ValueError(
            f"batched engine needs duration_s >= 120 (two 60 s occupancy "
            f"samples to interpolate); {sc.name!r} has {sc.duration_s:g}")
    dt = float(sc.telemetry.telemetry_s)
    n_ticks = int(math.floor(sc.duration_s / dt))
    t60 = np.arange(0.0, sc.duration_s, 60.0)
    fleet = sc.fleet
    server = fleet.server()
    budget = (resolve_ensemble_budget(sc) if budget_w is None
              else float(budget_w))
    members = spec.member_scenarios(budget)

    hierarchy = None
    node_matrix = None
    node_names: Tuple[str, ...] = ()
    base_budgets = row_budgets(sc, budget, server)
    if sc.hierarchy is not None:
        if sc.hierarchy.n_rows != fleet.n_rows:
            raise ValueError(
                f"hierarchy shape {sc.hierarchy.shape} implies "
                f"{sc.hierarchy.n_rows} rows; fleet has {fleet.n_rows}")
        hierarchy = sc.hierarchy.build(base_budgets)
        row_budget = np.asarray(hierarchy.leaf_budget_w, dtype=np.float64)
        node_matrix = np.zeros((hierarchy.n_nodes, fleet.n_rows))
        for n in range(hierarchy.n_nodes):
            node_matrix[n, hierarchy.leaf_desc[n]] = 1.0
        node_names = tuple(hierarchy.names)
    else:
        row_budget = np.asarray(base_budgets, dtype=np.float64)

    alive, bscale = _lower_faults(sc, n_ticks, dt, fleet.n_rows, hierarchy)
    occ60 = _member_occupancy(sc, spec.seeds(), t60, fleet.n_rows,
                              fleet.n_servers)
    stride = max(1, math.ceil(n_ticks / _IMPACT_SLOTS))
    tc = sc.telemetry
    oob_ticks = max(1, math.ceil(tc.oob_latency_s / dt))
    brake_ticks = max(1, math.ceil(tc.brake_latency_s / dt))
    model = TickModel(
        base_name=sc.name, n_members=spec.n_seeds, n_rows=fleet.n_rows,
        n_ticks=n_ticks, dt=dt, occ60=occ60, alive=alive, budget_scale=bscale,
        row_budget_w=row_budget, n_servers=fleet.n_servers,
        power_scale=float(sc.power_scale),
        oob_ticks=oob_ticks, brake_ticks=brake_ticks,
        ring_depth=max(oob_ticks, brake_ticks) + 1,
        stride=stride, n_slots=math.ceil(n_ticks / stride),
        node_matrix=node_matrix, node_names=node_names,
        seeds=tuple(spec.seeds()),
        **_policy_constants(sc), **_power_constants(sc))
    return model, members, budget


# ---------------------------------------------------------------------------
# shared tick math (both backends call these with their own array module)
# ---------------------------------------------------------------------------

def _row_power_w(model: TickModel, occ, f_lp, f_hp, xp):
    """Per-row watts at occupancy + frequency state (the closed-form fluid
    power plane; identical expression on both backends)."""
    busy = (model.k_lp_w * f_lp ** model.gamma
            + model.k_hp_w * f_hp ** model.gamma)
    return (model.power_scale * model.n_servers
            * (model.p0_srv_w + occ * busy))


def _lp_power_w(model: TickModel, occ, f_lp, xp):
    return (model.power_scale * model.n_servers
            * (model.lp_share * model.p0_srv_w
               + occ * model.k_lp_w * f_lp ** model.gamma))


def _slo_step(model: TickModel, occ, f_lp, f_hp, backlog_hp, backlog_lp, xp):
    """One tick of the per-priority fluid SLO proxy: slowdown from the DVFS
    perf model (``a/f + (1-a)``) plus a queue-delay backlog integrator —
    occupancy x slowdown > 1 means the row can't keep up and delay accrues.
    Returns (backlog_hp', backlog_lp', impact_hp, impact_lp)."""
    sd_hp = model.a_hp / xp.maximum(f_hp, 1e-3) + (1.0 - model.a_hp)
    sd_lp = model.a_lp / xp.maximum(f_lp, 1e-3) + (1.0 - model.a_lp)
    backlog_hp = xp.maximum(0.0, backlog_hp + (occ * sd_hp - 1.0) * model.dt)
    backlog_lp = xp.maximum(0.0, backlog_lp + (occ * sd_lp - 1.0) * model.dt)
    imp_hp = (sd_hp - 1.0) + backlog_hp / model.svc_hp
    imp_lp = (sd_lp - 1.0) + backlog_lp / model.svc_lp
    return backlog_hp, backlog_lp, imp_hp, imp_lp


def _interp_weights(model: TickModel) -> Tuple[np.ndarray, np.ndarray]:
    """Per-tick (left index, right weight) into the 60 s occupancy grid —
    precomputed once so both backends interpolate identically."""
    t = model.tick_times()
    g = t / 60.0
    n60 = model.occ60.shape[2]
    i = np.clip(np.floor(g).astype(np.int64), 0, n60 - 2)
    w = np.clip(g - i, 0.0, 1.0)
    return i, w


# ---------------------------------------------------------------------------
# numpy oracle: the tick/ring contract driven by the real policy objects
# ---------------------------------------------------------------------------

def _run_oracle(model: TickModel, members: List[Scenario],
                keep_series: bool) -> BatchedRun:
    N, R, T, D = model.n_members, model.n_rows, model.n_ticks, model.ring_depth
    i_idx, i_w = _interp_weights(model)
    t_ticks = model.tick_times()
    brake_fire = np.zeros((N, T, R), dtype=bool)
    n_brakes = np.zeros((N, R), dtype=np.int64)
    peak = np.zeros(N)
    mean = np.zeros(N)
    imp_hp = np.zeros((N, R, model.n_slots))
    imp_lp = np.zeros((N, R, model.n_slots))
    total = np.zeros((N, T)) if keep_series else None
    row_w_out = np.zeros((N, T, R)) if keep_series else None
    total_budget = model.total_budget_w

    for m, member in enumerate(members):
        policies = [member.policy.build() for _ in range(R)]
        f_lp = np.ones(R)
        f_hp = np.ones(R)
        ring = np.full((R, D, 2), np.nan)
        backlog_hp = np.zeros(R)
        backlog_lp = np.zeros(R)
        occ60 = model.occ60[m]  # [R, T60]
        frac_sum = 0.0
        frac_peak = 0.0
        for k in range(T):
            slot = k % D
            pend = ring[:, slot, :]
            has = ~np.isnan(pend)
            f_lp = np.where(has[:, 0], pend[:, 0], f_lp)
            f_hp = np.where(has[:, 1], pend[:, 1], f_hp)
            ring[:, slot, :] = np.nan
            occ = (occ60[:, i_idx[k]] * (1.0 - i_w[k])
                   + occ60[:, i_idx[k] + 1] * i_w[k]) * model.alive[k]
            rw = _row_power_w(model, occ, f_lp, f_hp, np)
            frac = float(rw.sum()) / total_budget
            frac_peak = max(frac_peak, frac)
            frac_sum += frac
            if keep_series:
                total[m, k] = frac
                row_w_out[m, k] = rw
            tick_budget = model.row_budget_w * model.budget_scale[k]
            p = rw / tick_budget
            lp_frac = _lp_power_w(model, occ, f_lp, np) / tick_budget
            for r in range(R):
                pol = policies[r]
                before = pol.n_brakes
                cmds = pol.observe(Telemetry(
                    t=float(t_ticks[k]), power_frac=float(p[r]),
                    lp_power_frac=float(lp_frac[r]), row_index=r))
                if pol.n_brakes > before:
                    brake_fire[m, k, r] = True
                for cmd in cmds:
                    d = model.brake_ticks if cmd.brake else model.oob_ticks
                    s = (k + d) % D
                    if cmd.lp_freq is not None:
                        ring[r, s, 0] = cmd.lp_freq
                    if cmd.hp_freq is not None:
                        ring[r, s, 1] = cmd.hp_freq
            backlog_hp, backlog_lp, ih, il = _slo_step(
                model, occ, f_lp, f_hp, backlog_hp, backlog_lp, np)
            if k % model.stride == 0:
                imp_hp[m, :, k // model.stride] = ih
                imp_lp[m, :, k // model.stride] = il
        n_brakes[m] = [pol.n_brakes for pol in policies]
        peak[m] = frac_peak
        mean[m] = frac_sum / T

    node_w = None
    if keep_series and model.node_matrix is not None:
        node_w = np.einsum("ntr,mr->ntm", row_w_out, model.node_matrix)
    return BatchedRun(engine="numpy", model=model, brake_fire=brake_fire,
                      n_brakes=n_brakes, peak_frac=peak, mean_frac=mean,
                      impacts_hp=imp_hp, impacts_lp=imp_lp, total_frac=total,
                      row_w=row_w_out, node_w=node_w)


# ---------------------------------------------------------------------------
# jax engine: scenario-axis vmap over (member vmap / chunked scan) over a
# lax.scan over ticks
# ---------------------------------------------------------------------------

class _JaxCfg(NamedTuple):
    """Static (compile-time) shape/flag key for the jitted runner.

    Deliberately *only* shapes and branch flags: every scalar constant
    (thresholds, power coefficients, ``n_servers`` — which changes per
    ``plan_capacity`` probe) travels as a traced operand in :class:`_Consts`,
    so one compiled program serves a whole probe bisection and every
    scenario of a grid bucket. ``jax_trace_count()`` is the regression
    hook asserting that."""

    T: int
    R: int
    D: int
    W: int
    S: int
    stride: int
    oob_ticks: int
    brake_ticks: int
    esc: int
    predictive: bool
    keep_series: bool
    keep_fire: bool
    chunk: int  # member-block size for the inner lax.scan; 0 = plain vmap


class _Consts(NamedTuple):
    """Traced per-scenario constants of the tick program. Scalar leaves are
    0-d (single scenario) or ``[M]`` (grid mode — the scenario-axis vmap
    maps over the leading axis of every leaf); ``row_budget`` is ``[R]`` /
    ``[M, R]``. Field names match :class:`repro.kernels.tick.TickConsts`
    so the shared step math reads either."""

    t1: object
    t2: object
    t1_buf: object
    t2_buf: object
    lp_t1: object
    lp_t2: object
    hp_t2: object
    brake_freq: object
    p0_srv_w: object
    k_lp_w: object
    k_hp_w: object
    lp_share: object
    gamma: object
    n_servers: object
    power_scale: object
    dt: object
    horizon: object
    a_hp: object
    a_lp: object
    svc_hp: object
    svc_lp: object
    total_budget: object
    row_budget: object


_CONST_SCALARS = (
    "t1", "t2", "t1_buf", "t2_buf", "lp_t1", "lp_t2", "hp_t2", "brake_freq",
    "p0_srv_w", "k_lp_w", "k_hp_w", "lp_share", "gamma", "n_servers",
    "power_scale", "dt", "horizon", "a_hp", "a_lp", "svc_hp", "svc_lp",
    "total_budget")

_MODEL_FIELD = dict(t1_buf="t1_buffer", t2_buf="t2_buffer",
                    lp_t1="lp_freq_t1", lp_t2="lp_freq_t2",
                    hp_t2="hp_freq_t2", horizon="horizon_s",
                    total_budget="total_budget_w")


def _model_const(model: TickModel, name: str) -> float:
    return float(getattr(model, _MODEL_FIELD.get(name, name)))


# every trace of the batched runner (== one XLA compile of one _JaxCfg +
# operand-shape combination), appended at trace time
_TRACE_EVENTS: List[_JaxCfg] = []


def jax_trace_count() -> int:
    """How many times this process has traced the batched jax runner.

    Each trace is one XLA compilation; constants are operands, so only a
    *new geometry* (fresh ``_JaxCfg`` or operand shapes) retraces. The
    planner regression gate asserts a multi-probe bisection traces once."""
    return len(_TRACE_EVENTS)


@lru_cache(maxsize=64)
def _jax_runner(cfg: _JaxCfg, mesh=None):
    import jax
    import jax.numpy as jnp
    from jax import lax

    from repro.kernels.tick import PolcaLatches, polca_latch_step

    def predict(c, t, p, consts):
        """PredictivePolcaPolicy._predict: windowed least-squares slope
        extrapolated horizon_s ahead, clamped below 1.0 unless the measured
        power already breached (brakes are never predicted). Raw samples
        enter the history, exactly as in the reference policy."""
        ht, hp, k = c["hist_t"], c["hist_p"], c["k"]
        W = cfg.W
        idx = jnp.minimum(k, W - 1)
        ins_t = ht.at[:, idx].set(t)
        ins_p = hp.at[:, idx].set(p)
        roll_t = jnp.roll(ht, -1, axis=1).at[:, -1].set(t)
        roll_p = jnp.roll(hp, -1, axis=1).at[:, -1].set(p)
        grow = k < W
        ht = jnp.where(grow, ins_t, roll_t)
        hp = jnp.where(grow, ins_p, roll_p)
        nn = jnp.minimum(k + 1, W).astype(jnp.float64)
        valid = (jnp.arange(W) < jnp.minimum(k + 1, W))[None, :]
        tm = jnp.sum(jnp.where(valid, ht, 0.0), axis=1) / nn
        pm = jnp.sum(jnp.where(valid, hp, 0.0), axis=1) / nn
        dt_ = jnp.where(valid, ht - tm[:, None], 0.0)
        dp_ = jnp.where(valid, hp - pm[:, None], 0.0)
        num = jnp.sum(dt_ * dp_, axis=1)
        den = jnp.sum(dt_ * dt_, axis=1)
        slope = num / jnp.where(den > 0.0, den, 1.0)
        p_ext = jnp.where((nn >= 3) & (den > 0.0),
                          jnp.maximum(p, p + slope * consts.horizon), p)
        p_obs = jnp.where(p <= 1.0, jnp.minimum(p_ext, 1.0 - 1e-9), p_ext)
        return dict(c, hist_t=ht, hist_p=hp), p_obs

    def run_scenario(occ60_all, consts, xs):
        T, R, D, S = cfg.T, cfg.R, cfg.D, cfg.S

        def step_for(occ60):
            def step(c, x):
                k, t, ii, iw, alive, bscale = x
                slot = k % D
                pend = lax.dynamic_index_in_dim(c["ring"], slot, axis=1,
                                                keepdims=False)  # [R, 2]
                has = ~jnp.isnan(pend)
                f_lp = jnp.where(has[:, 0], pend[:, 0], c["f_lp"])
                f_hp = jnp.where(has[:, 1], pend[:, 1], c["f_hp"])
                ring = lax.dynamic_update_index_in_dim(
                    c["ring"], jnp.full((R, 2), jnp.nan), slot, axis=1)
                occ = ((occ60[:, ii] * (1.0 - iw) + occ60[:, ii + 1] * iw)
                       * alive)
                rw = _row_power_w(consts, occ, f_lp, f_hp, jnp)
                frac = jnp.sum(rw) / consts.total_budget
                tick_budget = consts.row_budget * bscale
                p_raw = rw / tick_budget
                lp_frac = _lp_power_w(consts, occ, f_lp, jnp) / tick_budget
                c = dict(c, f_lp=f_lp, f_hp=f_hp, ring=ring, k=k)
                if cfg.predictive:
                    c, p_obs = predict(c, t, p_raw, consts)
                else:
                    p_obs = p_raw
                lat = PolcaLatches(t1c=c["t1c"], t2c=c["t2c"], hpc=c["hpc"],
                                   brk=c["brk"], t2s=c["t2s"])
                lat, fire, lp_cmd, hp_cmd = polca_latch_step(
                    lat, p_obs, p_raw, lp_frac, consts,
                    esc=cfg.esc, predictive=cfg.predictive)
                c = dict(c, t1c=lat.t1c, t2c=lat.t2c, hpc=lat.hpc,
                         brk=lat.brk, t2s=lat.t2s,
                         nbr=c["nbr"] + fire.astype(jnp.int32))
                ring = c["ring"]
                s_oob = (k + cfg.oob_ticks) % D
                s_brk = (k + cfg.brake_ticks) % D
                oob_slot = lax.dynamic_index_in_dim(ring, s_oob, axis=1,
                                                    keepdims=False)
                oob_slot = jnp.stack([
                    jnp.where(jnp.isnan(lp_cmd), oob_slot[:, 0], lp_cmd),
                    jnp.where(jnp.isnan(hp_cmd), oob_slot[:, 1], hp_cmd)],
                    axis=1)
                ring = lax.dynamic_update_index_in_dim(ring, oob_slot, s_oob,
                                                       axis=1)
                brk_slot = lax.dynamic_index_in_dim(ring, s_brk, axis=1,
                                                    keepdims=False)
                brk_val = jnp.where(fire[:, None],
                                    jnp.full((R, 2), consts.brake_freq),
                                    brk_slot)
                ring = lax.dynamic_update_index_in_dim(ring, brk_val, s_brk,
                                                       axis=1)
                bh, bl, ih, il = _slo_step(consts, occ, f_lp, f_hp,
                                           c["backlog_hp"], c["backlog_lp"],
                                           jnp)
                imp = jnp.stack([ih, il], axis=1)  # [R, 2]
                zero = jnp.asarray(0, k.dtype)
                upd = lax.dynamic_update_slice(c["imp"], imp[None],
                                               (k // cfg.stride, zero, zero))
                imp_buf = jnp.where(k % cfg.stride == 0, upd, c["imp"])
                c = dict(c, ring=ring, backlog_hp=bh, backlog_lp=bl,
                         imp=imp_buf, peak=jnp.maximum(c["peak"], frac),
                         fsum=c["fsum"] + frac)
                ys = ()
                if cfg.keep_fire:
                    ys += (fire,)
                if cfg.keep_series:
                    ys += (frac, rw)
                return c, ys
            return step

        def run_member(occ60):
            carry = dict(
                f_lp=jnp.ones(R), f_hp=jnp.ones(R),
                ring=jnp.full((R, D, 2), jnp.nan),
                t1c=jnp.zeros(R, bool), t2c=jnp.zeros(R, bool),
                hpc=jnp.zeros(R, bool), brk=jnp.zeros(R, bool),
                t2s=jnp.zeros(R, jnp.int32), nbr=jnp.zeros(R, jnp.int32),
                backlog_hp=jnp.zeros(R), backlog_lp=jnp.zeros(R),
                imp=jnp.zeros((S, R, 2)), peak=jnp.asarray(0.0),
                fsum=jnp.asarray(0.0), k=jnp.asarray(0, jnp.int32),
            )
            if cfg.predictive:
                carry.update(hist_t=jnp.zeros((R, cfg.W)),
                             hist_p=jnp.zeros((R, cfg.W)))
            final, ys = lax.scan(step_for(occ60), carry, xs)
            out = dict(nbr=final["nbr"], peak=final["peak"],
                       mean=final["fsum"] / T, imp=final["imp"])
            i = 0
            if cfg.keep_fire:
                out["fire"] = ys[i]
                i += 1
            if cfg.keep_series:
                out["frac"] = ys[i]
                out["row_w"] = ys[i + 1]
            return out

        if cfg.chunk <= 0:
            return jax.vmap(run_member)(occ60_all)
        # bounded-memory tails: scan over member blocks so the in-flight
        # working set is one block's state, not all N members' at once
        N = occ60_all.shape[0]
        blocked = occ60_all.reshape(
            (N // cfg.chunk, cfg.chunk) + occ60_all.shape[1:])
        _, outs = lax.scan(
            lambda _, blk: (None, jax.vmap(run_member)(blk)), None, blocked)
        return jax.tree_util.tree_map(
            lambda a: a.reshape((N,) + a.shape[2:]), outs)

    def run(occ60_g, consts_g, t_g, ii_g, iw_g, alive_g, bscale_g, ks):
        _TRACE_EVENTS.append(cfg)

        def scenario(occ60_all, consts, t, ii, iw, alive, bscale):
            return run_scenario(occ60_all, consts,
                                (ks, t, ii, iw, alive, bscale))

        # scenario axis on top of the member axis: one program, M scenarios.
        # t / ii / iw are geometry-determined (n_ticks, dt, n60 — all in
        # _geometry_key), hence identical across the bucket: in_axes=None
        # keeps the per-tick occ60 interpolation a dynamic-slice instead of
        # an M-batched gather (~1.5x per-member cost on CPU at M=4).
        return jax.vmap(scenario, in_axes=(0, 0, None, None, None, 0, 0))(
            occ60_g, consts_g, t_g, ii_g, iw_g, alive_g, bscale_g)

    fn = run
    if mesh is not None:
        # shard the member axis (dim 1 everywhere) over the mesh's "data"
        # axis; constants/timelines replicate. Each device runs the whole
        # scan on its member shard — no cross-device collectives in the hot
        # loop, so throughput scales with device count.
        from jax.sharding import PartitionSpec
        from repro.launch.mesh import shard_map_compat
        member = PartitionSpec(None, "data")
        rep = PartitionSpec()
        fn = shard_map_compat(
            run, mesh=mesh,
            in_specs=(member, rep, rep, rep, rep, rep, rep, rep),
            out_specs=member, check_vma=False)
    # donating the occupancy grid lets XLA reuse its buffer for outputs on
    # accelerators; the CPU backend has no donation and would only warn
    donate = (0,) if jax.default_backend() != "cpu" else ()
    return jax.jit(fn, donate_argnums=donate)


def _geometry_key(model: TickModel) -> tuple:
    """The bucket key for grid lowering: two TickModels sharing this key
    compile to the same XLA program (same ``_JaxCfg`` + operand shapes) and
    can run stacked under the scenario-axis vmap."""
    return (model.n_ticks, model.n_rows, model.ring_depth,
            max(1, model.window), model.n_slots, model.stride,
            model.oob_ticks, model.brake_ticks, model.escalation_ticks,
            model.predictive, model.n_members, model.occ60.shape[2],
            float(model.dt))


def _run_jax_models(models: Sequence[TickModel], *, keep_series: bool,
                    keep_fire: bool = True,
                    member_chunk: Optional[int] = None,
                    mesh=None) -> List[BatchedRun]:
    """Run one geometry bucket of TickModels as a single device program.

    Per-scenario constants stack on a leading ``[M]`` axis and the runner
    vmaps the scenario axis over the member program — so an M-scenario grid
    (or an M-probe planner sweep re-using one compiled program) costs one
    dispatch, not M. ``member_chunk`` bounds device memory by scanning
    member blocks; ``mesh`` shards the member axis over its "data" axis.
    Members are padded (cyclically) to the chunk x device multiple and
    sliced back — padding members are independent lanes, so results are
    invariant to both knobs (tier-1 asserted)."""
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    m0 = models[0]
    key0 = _geometry_key(m0)
    for m in models[1:]:
        if _geometry_key(m) != key0:
            raise ValueError(
                f"grid bucket mixes tick geometries: {_geometry_key(m)} vs "
                f"{key0} (bucket specs with run_batched_grid)")
    N = m0.n_members
    n_dev = 1
    if mesh is not None:
        n_dev = dict(zip(mesh.axis_names, mesh.devices.shape)).get("data", 1)
        if n_dev <= 1:
            mesh = None
    if member_chunk is None:
        # auto: cache-sized member blocks. The scan carry is ~2 KB/member,
        # so a flat vmap over 10^3+ members thrashes L2 and per-member
        # throughput drops ~40% (benchmarks/batched_engine.py measures the
        # cliff); scanning blocks of ~_AUTO_CHUNK_MEMBERS members (counted
        # across the whole scenario axis) keeps the live state
        # cache-resident long before memory becomes the binding constraint.
        # The block count is rounded so padding stays minimal.
        if N * len(models) <= _AUTO_CHUNK_MEMBERS:
            member_chunk = 0
        else:
            c0 = max(1, _AUTO_CHUNK_MEMBERS // len(models))
            n_blocks = math.ceil(N / (max(1, n_dev) * c0))
            member_chunk = math.ceil(N / (max(1, n_dev) * n_blocks))
    chunk = max(0, int(member_chunk or 0))
    mult = max(1, n_dev) * max(1, chunk)
    n_pad = (-N) % mult
    idx = np.resize(np.arange(N), N + n_pad)
    cfg = _JaxCfg(T=m0.n_ticks, R=m0.n_rows, D=m0.ring_depth,
                  W=max(1, m0.window), S=m0.n_slots, stride=m0.stride,
                  oob_ticks=m0.oob_ticks, brake_ticks=m0.brake_ticks,
                  esc=m0.escalation_ticks, predictive=m0.predictive,
                  keep_series=keep_series, keep_fire=keep_fire, chunk=chunk)
    runner = _jax_runner(cfg, mesh)
    with enable_x64():
        def _f(vals):
            return jnp.asarray(np.asarray(vals, dtype=np.float64))

        occ60_g = jnp.asarray(np.stack([m.occ60[idx] for m in models]))
        consts_g = _Consts(
            **{name: _f([_model_const(m, name) for m in models])
               for name in _CONST_SCALARS},
            row_budget=_f(np.stack([m.row_budget_w for m in models])))
        # shared across the bucket by construction (geometry-keyed): pass
        # unbatched so the runner's scenario vmap broadcasts them
        i_idx, i_w = _interp_weights(m0)
        t_g = _f(m0.tick_times())
        ii_g = jnp.asarray(i_idx, dtype=jnp.int32)
        iw_g = _f(i_w)
        alive_g = _f(np.stack([m.alive for m in models]))
        bscale_g = _f(np.stack([m.budget_scale for m in models]))
        ks = jnp.arange(cfg.T, dtype=jnp.int32)
        out = runner(occ60_g, consts_g, t_g, ii_g, iw_g, alive_g, bscale_g,
                     ks)
        out = {k: np.asarray(v) for k, v in out.items()}
    runs: List[BatchedRun] = []
    for i, m in enumerate(models):
        sub = {k: v[i][:N] for k, v in out.items()}
        imp = sub["imp"]  # [N, S, R, 2]
        run = BatchedRun(
            engine="jax", model=m,
            brake_fire=(np.asarray(sub["fire"], dtype=bool)
                        if keep_fire else None),
            n_brakes=np.asarray(sub["nbr"], dtype=np.int64),
            peak_frac=np.asarray(sub["peak"], dtype=np.float64),
            mean_frac=np.asarray(sub["mean"], dtype=np.float64),
            impacts_hp=np.ascontiguousarray(imp[:, :, :, 0].transpose(0, 2, 1)),
            impacts_lp=np.ascontiguousarray(imp[:, :, :, 1].transpose(0, 2, 1)),
        )
        if keep_series:
            run.total_frac = np.asarray(sub["frac"], dtype=np.float64)
            run.row_w = np.asarray(sub["row_w"], dtype=np.float64)
            if m.node_matrix is not None:
                run.node_w = np.einsum("ntr,mr->ntm", run.row_w,
                                       m.node_matrix)
        runs.append(run)
    return runs


def _run_jax(model: TickModel, keep_series: bool, *, keep_fire: bool = True,
             member_chunk: Optional[int] = None, mesh=None) -> BatchedRun:
    return _run_jax_models([model], keep_series=keep_series,
                           keep_fire=keep_fire, member_chunk=member_chunk,
                           mesh=mesh)[0]


# ---------------------------------------------------------------------------
# pallas engine: the tick inner loop as a kernel (repro.kernels.tick)
# ---------------------------------------------------------------------------

def _run_pallas(model: TickModel, keep_series: bool) -> BatchedRun:
    """Tick loop on the Pallas kernel backend (``repro.kernels.tick``).

    The kernel owns what dominates the scan body — the power fold, the
    latch update, and the actuation ring — per member block; occupancy
    interpolation and the SLO fluid proxy run as numpy pre/post-passes
    using the *same expressions* as the oracle (elementwise, so those
    planes are bit-identical by construction and the differential gate
    pins the kernel's brake sets / power series)."""
    if model.predictive:
        raise ValueError(
            "engine='pallas' runs the non-predictive PolcaPolicy tick loop; "
            f"{model.base_name!r} lowered a predictive policy (use "
            "engine='jax', which carries the slope window in scan state)")
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    from repro.kernels import ops as kops
    from repro.kernels.tick import TickConsts

    N, R, T = model.n_members, model.n_rows, model.n_ticks
    i_idx, i_w = _interp_weights(model)
    # effective per-tick occupancy — the oracle's expression, vectorized
    occ = ((model.occ60[:, :, i_idx] * (1.0 - i_w)
            + model.occ60[:, :, i_idx + 1] * i_w)
           * model.alive.T[None])  # [N, R, T]
    occ_ntr = np.ascontiguousarray(occ.transpose(0, 2, 1))
    consts = TickConsts(
        t1=model.t1, t2=model.t2, t1_buf=model.t1_buffer,
        t2_buf=model.t2_buffer, lp_t1=model.lp_freq_t1,
        lp_t2=model.lp_freq_t2, hp_t2=model.hp_freq_t2,
        brake_freq=model.brake_freq, p0_srv_w=model.p0_srv_w,
        k_lp_w=model.k_lp_w, k_hp_w=model.k_hp_w, lp_share=model.lp_share,
        gamma=model.gamma, n_servers=model.n_servers,
        power_scale=model.power_scale)
    with enable_x64():
        out = kops.polca_tick(
            jnp.asarray(occ_ntr), jnp.asarray(model.budget_scale),
            jnp.asarray(model.row_budget_w), consts=consts,
            oob_ticks=model.oob_ticks, brake_ticks=model.brake_ticks,
            ring_depth=model.ring_depth, esc=model.escalation_ticks)
        row_w = np.asarray(out["row_w"], dtype=np.float64)  # [N, T, R]
        fire = np.asarray(out["fire"], dtype=bool)
        f_lp = np.asarray(out["f_lp"], dtype=np.float64)
        f_hp = np.asarray(out["f_hp"], dtype=np.float64)
        nbr = np.asarray(out["n_brakes"], dtype=np.int64)
    frac = row_w.sum(axis=2) / model.total_budget_w  # [N, T]
    backlog_hp = np.zeros((N, R))
    backlog_lp = np.zeros((N, R))
    imp_hp = np.zeros((N, R, model.n_slots))
    imp_lp = np.zeros((N, R, model.n_slots))
    for k in range(T):
        backlog_hp, backlog_lp, ih, il = _slo_step(
            model, occ_ntr[:, k], f_lp[:, k], f_hp[:, k],
            backlog_hp, backlog_lp, np)
        if k % model.stride == 0:
            imp_hp[:, :, k // model.stride] = ih
            imp_lp[:, :, k // model.stride] = il
    run = BatchedRun(
        engine="pallas", model=model, brake_fire=fire, n_brakes=nbr,
        peak_frac=frac.max(axis=1), mean_frac=frac.mean(axis=1),
        impacts_hp=imp_hp, impacts_lp=imp_lp)
    if keep_series:
        run.total_frac = frac
        run.row_w = row_w
        if model.node_matrix is not None:
            run.node_w = np.einsum("ntr,mr->ntm", row_w, model.node_matrix)
    return run


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------

def run_tick_model(model: TickModel, members: List[Scenario], *,
                   engine: str = "jax", keep_series: bool = True,
                   keep_brake_fire: bool = True,
                   member_chunk: Optional[int] = None,
                   mesh=None) -> BatchedRun:
    """Run a lowered tick program on one backend. ``engine="numpy"`` is the
    oracle (real policy objects through Telemetry); ``engine="jax"`` the
    vectorized device program; ``engine="pallas"`` the kernel backend
    (non-predictive policies). Differential tests run oracle + device
    backends on the same model and compare."""
    if engine == "numpy":
        return _run_oracle(model, members, keep_series)
    if engine == "jax":
        return _run_jax(model, keep_series, keep_fire=keep_brake_fire,
                        member_chunk=member_chunk, mesh=mesh)
    if engine == "pallas":
        return _run_pallas(model, keep_series)
    raise ValueError(f"unknown batched engine {engine!r} "
                     "(expected 'numpy', 'jax', or 'pallas')")


def run_tick_models(models: Sequence[TickModel], *,
                    keep_series: bool = True, keep_brake_fire: bool = True,
                    member_chunk: Optional[int] = None,
                    mesh=None) -> List[BatchedRun]:
    """Run a same-geometry bucket of lowered tick programs as ONE
    scenario-vmapped jit call (DESIGN.md §16) and return one
    :class:`BatchedRun` per model, in order.

    This is the model-level grid entry — :func:`run_batched_grid` lowers
    specs, buckets them by :func:`_geometry_key`, and lands here. It is
    jax-engine only: the oracle and Pallas backends have no scenario axis
    and run per model via :func:`run_tick_model`."""
    return _run_jax_models(list(models), keep_series=keep_series,
                           keep_fire=keep_brake_fire,
                           member_chunk=member_chunk, mesh=mesh)


# dense-tail cutover: above this member count run_batched_ensemble stops
# materializing per-member python MemberStats/LatencyStats objects (O(N)
# python floats) and returns the vectorized EnsembleResult arrays instead
_MEMBER_STATS_LIMIT = 20_000


def _to_ensemble_result(model: TickModel, members: List[Scenario],
                        budget_w: float, run: BatchedRun,
                        member_stats: bool = True) -> EnsembleResult:
    """Adapt a BatchedRun to the EnsembleResult shape the planner and the
    distributional statistics consume. ``power_frac`` rows are member
    total-budget fractions (the same quantity the DES engine stacks —
    ``SimResult.power_w`` records the telemetry fraction series).

    ``member_stats=False`` is the dense-tail mode: the members list stays
    empty and per-member SLO impacts ride as ``[N, K]`` arrays — every
    distributional statistic on EnsembleResult falls back to the
    vectorized path (same numbers, no 10^5 python objects)."""
    t = model.tick_times()
    if run.total_frac is not None:
        power = np.asarray(run.total_frac)
        power_t = t
    else:
        power = np.zeros((0, 0))
        power_t = np.zeros(0)
    common = dict(
        base_name=model.base_name, budget_w=budget_w,
        power_t=power_t, power_frac=power,
        brake_counts=np.asarray(run.n_brakes.sum(axis=1)),
        peak_fracs=np.asarray(run.peak_frac),
        mean_fracs=np.asarray(run.mean_frac))
    if not member_stats:
        N = run.impacts_hp.shape[0]
        return EnsembleResult(
            members=[],
            member_impacts_hp=(run.impacts_hp.reshape(N, -1)
                               if model.has_hp else np.zeros((N, 0))),
            member_impacts_lp=(run.impacts_lp.reshape(N, -1)
                               if model.has_lp else np.zeros((N, 0))),
            **common)
    stats: List[MemberStats] = []
    for m, sc in enumerate(members):
        series = (run.total_frac[m] if run.total_frac is not None else None)
        res = SimResult(
            latency=run.member_stats(m),
            n_brakes=int(run.n_brakes[m].sum()),
            n_dropped=0, n_completed=0, served_tokens=0.0,
            peak_power_frac=float(run.peak_frac[m]),
            mean_power_frac=float(run.mean_frac[m]),
            power_t=(t if series is not None else None),
            power_w=series)
        stats.append(MemberStats(sc, res, res.latency))
    return EnsembleResult(members=stats, **common)


def _auto_flags(model: TickModel, keep_series: Optional[bool],
                keep_brake_fire: Optional[bool],
                member_stats: Optional[bool]) -> Tuple[bool, bool, bool]:
    """Resolve the None-means-auto memory knobs from the model's size."""
    cells = model.n_members * model.n_ticks
    if keep_series is None:
        keep_series = cells <= _SERIES_CELL_LIMIT
    if keep_brake_fire is None:
        # the bool [N, T, R] plane; 50x the f64 series budget in cells
        keep_brake_fire = cells * model.n_rows <= 50 * _SERIES_CELL_LIMIT
    if member_stats is None:
        member_stats = model.n_members <= _MEMBER_STATS_LIMIT
    return keep_series, keep_brake_fire, member_stats


def run_batched_ensemble(spec: EnsembleSpec, *,
                         budget_w: Optional[float] = None,
                         engine: str = "jax",
                         keep_series: Optional[bool] = None,
                         keep_brake_fire: Optional[bool] = None,
                         member_stats: Optional[bool] = None,
                         member_chunk: Optional[int] = None,
                         mesh=None) -> EnsembleResult:
    """Evaluate an ensemble on the batched tick engine.

    The drop-in dense-tail counterpart of ``montecarlo.run_ensemble`` —
    same EnsembleResult surface, 10^5+ members in one device program.
    The ``None``-default knobs auto-scale with ensemble size (DESIGN.md
    §16 memory budget): ``keep_series`` keeps per-tick power series under
    4e6 member-tick cells; ``keep_brake_fire`` drops the [N, T, R] brake
    plane (counts survive) past 2e8 cells; ``member_stats`` switches to
    dense [N, K] impact arrays past 2e4 members. ``member_chunk`` scans
    member blocks for bounded memory and cache residency (``None`` = auto:
    ~512-member blocks once the run is big enough; ``0`` = flat vmap);
    ``mesh`` shards the member axis over a "data" mesh axis
    (``launch.mesh.data_mesh``)."""
    if engine == "batched-numpy":  # run_ensemble's name for the tick oracle
        engine = "numpy"
    with get_recorder().span("mc/run_batched", base=spec.base.name,
                             members=spec.n_seeds, engine=engine):
        model, members, budget = lower_ensemble(spec, budget_w=budget_w)
        keep_series, keep_fire, member_stats = _auto_flags(
            model, keep_series, keep_brake_fire, member_stats)
        run = run_tick_model(model, members, engine=engine,
                             keep_series=keep_series,
                             keep_brake_fire=keep_fire,
                             member_chunk=member_chunk, mesh=mesh)
        return _to_ensemble_result(model, members, budget, run,
                                   member_stats=member_stats)


def run_batched_grid(specs: Sequence[EnsembleSpec], *,
                     budget_w: Optional[float] = None,
                     engine: str = "jax",
                     keep_series: Optional[bool] = None,
                     keep_brake_fire: Optional[bool] = None,
                     member_stats: Optional[bool] = None,
                     member_chunk: Optional[int] = None,
                     mesh=None) -> List[EnsembleResult]:
    """Evaluate M ensembles as (at most a few) single device programs.

    Specs are lowered individually (per-spec budget resolution unless
    ``budget_w`` pins one envelope), bucketed by tick geometry
    (:func:`_geometry_key`), and each bucket runs stacked under the
    scenario-axis vmap — the mc-* scenario family (shared fleet/duration/
    telemetry) is one bucket, so a 6-family CVaR frontier is one jit call.
    Results come back in spec order, one EnsembleResult per spec.

    ``engine="numpy"``/``"pallas"`` fall back to a per-scenario loop (the
    oracle is the reference semantics; the kernel recompiles per scenario
    by design) — the grid API stays engine-agnostic for differential
    tests."""
    if engine == "batched-numpy":
        engine = "numpy"
    lowered = [lower_ensemble(s, budget_w=budget_w) for s in specs]
    with get_recorder().span("mc/run_grid", scenarios=len(specs),
                             members=sum(m.n_members for m, _, _ in lowered),
                             engine=engine):
        runs: List[Optional[BatchedRun]] = [None] * len(lowered)
        flags = [_auto_flags(m, keep_series, keep_brake_fire, member_stats)
                 for m, _, _ in lowered]
        if engine == "jax":
            buckets: Dict[tuple, List[int]] = {}
            for i, (m, _, _) in enumerate(lowered):
                # keep_* flags join the key: they change the traced program
                key = _geometry_key(m) + flags[i][:2]
                buckets.setdefault(key, []).append(i)
            for idxs in buckets.values():
                ks, kf, _ = flags[idxs[0]]
                bruns = _run_jax_models(
                    [lowered[i][0] for i in idxs], keep_series=ks,
                    keep_fire=kf, member_chunk=member_chunk, mesh=mesh)
                for i, r in zip(idxs, bruns):
                    runs[i] = r
        else:
            for i, (m, mem, _) in enumerate(lowered):
                runs[i] = run_tick_model(m, mem, engine=engine,
                                         keep_series=flags[i][0],
                                         keep_brake_fire=flags[i][1])
        return [_to_ensemble_result(m, mem, budget, run,
                                    member_stats=flags[i][2])
                for i, ((m, mem, budget), run) in enumerate(zip(lowered,
                                                                runs))]
