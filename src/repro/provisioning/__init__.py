"""Provisioning planner: trace ensembles, Monte-Carlo capacity evaluation,
and risk-constrained oversubscription search (DESIGN.md §9).

Importing this package registers the scenario-family trace generators
(bursty, colocated, failover-surge, rack-incident, nighttime) and the named
``mc-*`` scenarios alongside the figure scenarios.
"""

from repro.provisioning.batched import (
    BatchedRun,
    TickModel,
    jax_trace_count,
    lower_ensemble,
    run_batched_ensemble,
    run_batched_grid,
    run_tick_model,
    run_tick_models,
)
from repro.provisioning.ensembles import (
    GENERATOR_FAMILY,
    MC_BASE_NAME,
    MC_SCENARIO_FAMILY,
    SiteTrace,
    compose_rows,
    compose_site,
)
from repro.provisioning.montecarlo import (
    EnsembleResult,
    EnsembleSpec,
    MemberStats,
    resolve_ensemble_budget,
    run_ensemble,
    run_ensemble_grid,
    run_ensemble_sequential,
)
from repro.provisioning.planner import (
    PlanPoint,
    PlanResult,
    RiskConstraints,
    plan_capacity,
    plan_controller_comparison,
    plan_scenarios,
)

__all__ = [
    "BatchedRun",
    "EnsembleResult",
    "EnsembleSpec",
    "GENERATOR_FAMILY",
    "MC_BASE_NAME",
    "MC_SCENARIO_FAMILY",
    "MemberStats",
    "PlanPoint",
    "PlanResult",
    "RiskConstraints",
    "SiteTrace",
    "TickModel",
    "compose_rows",
    "compose_site",
    "jax_trace_count",
    "lower_ensemble",
    "plan_capacity",
    "plan_controller_comparison",
    "plan_scenarios",
    "resolve_ensemble_budget",
    "run_batched_ensemble",
    "run_batched_grid",
    "run_ensemble",
    "run_ensemble_grid",
    "run_ensemble_sequential",
    "run_tick_model",
    "run_tick_models",
]
