"""Risk-constrained capacity planner (the paper's headline 30% claim).

POLCA §7: with the T1/T2 controller, the same row power envelope safely
hosts ~30% more inference servers. This module turns that one-off figure
into a *search*: :func:`plan_capacity` bisects over the number of added
servers, evaluating each candidate fleet with a Monte-Carlo ensemble of
seeded traffic realizations (``repro.provisioning.montecarlo``) and keeping
the largest fleet whose ensemble satisfies the risk constraints:

* ``max_brake_prob`` — bound on P[a traffic realization triggers >= 1
  hardware powerbrake] (the paper plans for zero);
* ``max_slo_violation_prob`` — bound on P[a realization misses the Table-5
  latency SLOs] (percentile gates from ``core.slo``);
* ``survive`` — a chaos fault timeline (``repro.chaos.FaultSpec``) the plan
  must *ride through*: every probe additionally runs the candidate fleet
  with the timeline injected and gates on ``max_fault_brake_prob`` /
  ``max_fault_brakes``. This prices k-failure survivability — "how much
  oversubscription can I keep if a PDU dies at peak" — instead of planning
  for the fault-free best case. Injecting a fault only removes capacity, so
  feasibility stays monotone in fleet size and bisection stays sound.

SLO impacts are measured the way the paper measures them: each member diffs
per-request latencies against an uncapped reference run on the same trace
(``EnsembleSpec(with_reference=True)``), so the gate isolates capping impact
from queueing noise — which is also what keeps feasibility monotone in fleet
size (more servers on the same budget -> strictly more capping pressure) and
bisection sound. The planner records every probe so the frontier is
auditable. The budget is resolved once from the provisioned baseline and held
fixed across candidates and members: the question is "how far can THIS
envelope stretch", not "what envelope would each fleet want".
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

from repro.chaos.faults import FaultSpec
from repro.core.slo import DEFAULT_SLO, SLO
from repro.experiments.scenario import Scenario
from repro.obs.metrics import get_recorder
from repro.provisioning.montecarlo import (
    EnsembleResult,
    EnsembleSpec,
    resolve_ensemble_budget,
    run_ensemble,
)

_EPS = 1e-12


@dataclass(frozen=True)
class RiskConstraints:
    """What the planner is allowed to risk across traffic realizations.

    ``max_brakes`` is a per-horizon brake-count budget: a realization is
    brake-feasible while its powerbrake count stays <= ``max_brakes`` (0
    keeps the paper's zero-tolerance), and ``max_brake_prob`` bounds the
    probability of exceeding that budget. Loosening either admits larger
    fleets (planner-monotonicity is tier-1-asserted).

    ``survive`` adds a survivability gate: when set, every probe also runs
    the candidate fleet with that fault timeline injected (same seeds, same
    pinned budget) and requires P[faulted member exceeds
    ``max_fault_brakes``] <= ``max_fault_brake_prob``. The defaults demand
    the paper's zero-tolerance *under the fault* — the difference between
    the fault-free and surviving ``safe_added_servers`` is the
    oversubscription cost of k-failure survivability. SLO gates stay on the
    fault-free ensemble: a derated fleet is expected to shed/slow, the
    survivability question is whether the hardware brake ever fires.

    ``slo_cvar_alpha`` activates the dense-tail CVaR gate: each probe
    additionally requires CVaR_alpha over the per-member P``slo_cvar_q``
    SLO impact of ``slo_cvar_priority`` requests to stay <=
    ``max_slo_cvar``. Unlike the probability gates above (which only see
    *whether* a member missed), CVaR prices *how bad* the worst ``(1 -
    alpha)`` tail is — but it needs enough members for that tail to hold at
    least one full sample, so ``plan_capacity`` validates ``n_seeds >=
    ceil(1 / (1 - alpha))`` and the intended pairing is ``engine="jax"``
    dense tails (DESIGN.md §15)."""

    max_brake_prob: float = 0.0  # P[member exceeds the brake budget]
    max_brakes: int = 0  # brakes tolerated per realization/horizon
    max_slo_violation_prob: float = 0.0  # P[member misses the SLO]
    slo: SLO = DEFAULT_SLO
    survive: Optional[FaultSpec] = None  # fault timeline the plan must ride through
    max_fault_brake_prob: float = 0.0  # P[faulted member exceeds fault budget]
    max_fault_brakes: int = 0  # brakes tolerated per faulted realization
    slo_cvar_alpha: Optional[float] = None  # None: CVaR gate off
    max_slo_cvar: float = 0.0  # bound on CVaR_alpha[per-member Pq impact]
    slo_cvar_priority: str = "high"  # which priority class the gate watches
    slo_cvar_q: float = 99.0  # per-member tail percentile fed into CVaR


@dataclass
class PlanPoint:
    """One bisection probe: a candidate fleet and its ensemble verdict."""

    added_servers: int
    added_frac: float
    feasible: bool
    brake_prob: float
    slo_violation_prob: float
    peak_frac_max: float
    fault_brake_prob: Optional[float] = None  # survivability gate (survive set)
    slo_cvar: Optional[float] = None  # CVaR gate value (slo_cvar_alpha set)
    ensemble: Optional[EnsembleResult] = field(default=None, repr=False)


@dataclass
class PlanResult:
    """Outcome of one capacity search."""

    scenario_name: str
    n_provisioned: int
    budget_w: float
    safe_added_servers: int
    probes: List[PlanPoint]
    capped: bool = False  # search hit max_added_frac while still feasible
    feasible_at_zero: bool = True

    @property
    def safe_added_frac(self) -> float:
        return self.safe_added_servers / self.n_provisioned

    @property
    def safe_n_servers(self) -> int:
        return self.n_provisioned + self.safe_added_servers

    def summary(self) -> Dict[str, float]:
        """The search verdict in one flat dict (benchmark rows)."""
        return {"safe_added_frac": self.safe_added_frac,
                "safe_n_servers": float(self.safe_n_servers),
                "budget_w": self.budget_w,
                "n_probes": float(len(self.probes))}


def _violation_prob(ens: EnsembleResult, slo: SLO) -> float:
    """P[member misses the SLO], powerbrakes excluded (they are constrained
    separately by ``max_brake_prob``). Delegates to the EnsembleResult so
    dense-tail results (``member_stats=False``, no per-member python
    objects) gate identically to member-object ones."""
    return ens.slo_violation_prob(slo)


def plan_capacity(base: Scenario, *,
                  constraints: RiskConstraints = RiskConstraints(),
                  n_seeds: int = 4, seed0: int = 1000,
                  max_added_frac: float = 0.60,
                  budget_w: Optional[float] = None,
                  n_workers: Optional[int] = None,
                  keep_ensembles: bool = False,
                  engine: str = "numpy", **engine_opts) -> PlanResult:
    """Maximum deployable fleet for ``base``'s traffic family under
    ``constraints``.

    Bisects over integer added-server counts in ``[0, n_provisioned *
    max_added_frac]``; each probe runs an ``n_seeds``-member Monte-Carlo
    ensemble at a pinned budget (resolved from ``base`` once unless
    ``budget_w`` pins it externally — e.g. to plan several traffic scenarios
    against the same baseline-calibrated envelope).

    ``engine`` selects the ensemble backend per :func:`run_ensemble` —
    ``"jax"`` is the dense-tail mode that makes 10^3+-seed probes (and so
    the CVaR gate) affordable. On that engine the probe loop compiles ONE
    device program for the whole bisection: per-scenario scalars
    (``n_servers``, thresholds, budgets) are traced operands, so probes
    differing only in fleet size / pinned budget hit the jit cache
    (regression-asserted via ``batched.jax_trace_count`` in
    ``tests/test_grid_engine.py``), and the base occupancy curves are
    cached across probes (only the fleet-scaled CLT jitter is recomputed).
    ``engine_opts`` forward to :func:`run_ensemble` (``member_chunk``,
    ``mesh``, ``member_stats``, ...). ``constraints.survive`` requires the
    event-driven ``"numpy"`` engine (the chaos injector rides the
    FleetSimulator, which the tick lowering rejects).
    """
    n_prov = base.fleet.n_provisioned
    survive = constraints.survive
    if survive is not None and survive.is_noop:
        survive = None
    if survive is not None and base.routing is None:
        raise ValueError(
            f"RiskConstraints.survive needs a routed-fleet scenario (the "
            f"chaos engine rides the FleetSimulator); {base.name!r} has no "
            f"RoutingSpec")
    if survive is not None and engine != "numpy":
        raise ValueError(
            "RiskConstraints.survive needs engine='numpy': the survivability "
            "gate runs the routed FleetSimulator, which the batched tick "
            f"engines do not model (got engine={engine!r})")
    cvar_alpha = constraints.slo_cvar_alpha
    if cvar_alpha is not None:
        min_seeds = int(math.ceil(1.0 / (1.0 - cvar_alpha)))
        if n_seeds < min_seeds:
            raise ValueError(
                f"slo_cvar_alpha={cvar_alpha} needs n_seeds >= {min_seeds} "
                f"for the (1 - alpha) tail to hold a full member (got "
                f"n_seeds={n_seeds}); dense tails are what engine='jax' is "
                f"for")
    budget = resolve_ensemble_budget(base) if budget_w is None else float(budget_w)
    probes: List[PlanPoint] = []

    def probe(k: int) -> PlanPoint:
        sc = base.with_fleet(added_frac=k / n_prov).with_(budget=budget)
        rec = get_recorder()
        with rec.span("planner/probe", scenario=base.name, added=k):
            ens = run_ensemble(EnsembleSpec(sc, n_seeds=n_seeds, seed0=seed0,
                                            n_workers=n_workers,
                                            with_reference=True),
                               budget_w=budget, engine=engine, **engine_opts)
            brake_p = ens.brake_prob(constraints.max_brakes)
            slo_p = _violation_prob(ens, constraints.slo)
            cvar: Optional[float] = None
            if cvar_alpha is not None:
                cvar = ens.slo_cvar(constraints.slo_cvar_priority,
                                    cvar_alpha, q=constraints.slo_cvar_q)
            fault_p: Optional[float] = None
            if survive is not None:
                # same seeds + pinned budget, fault timeline injected: the only
                # difference vs `ens` is the fault, so the gate isolates it. No
                # reference twins — the gate is brake-only.
                fens = run_ensemble(
                    EnsembleSpec(sc.with_(faults=survive), n_seeds=n_seeds,
                                 seed0=seed0, n_workers=n_workers),
                    budget_w=budget)
                fault_p = fens.brake_prob(constraints.max_fault_brakes)
        pt = PlanPoint(
            added_servers=k, added_frac=k / n_prov,
            feasible=(brake_p <= constraints.max_brake_prob + _EPS
                      and slo_p <= constraints.max_slo_violation_prob + _EPS
                      and (cvar is None
                           or cvar <= constraints.max_slo_cvar + _EPS)
                      and (fault_p is None
                           or fault_p <= constraints.max_fault_brake_prob + _EPS)),
            brake_prob=brake_p, slo_violation_prob=slo_p,
            peak_frac_max=float(ens.peak_fracs.max()) if len(ens.peak_fracs) else 0.0,
            fault_brake_prob=fault_p, slo_cvar=cvar,
            ensemble=ens if keep_ensembles else None)
        probes.append(pt)
        if rec.enabled:
            # probe outcome: logical time is the probe ordinal (the planner
            # has no simulation clock of its own)
            rec.event("planner", "probe", t=float(len(probes)),
                      scenario=base.name, added=k,
                      feasible=pt.feasible,
                      brake_prob=round(brake_p, 6),
                      slo_violation_prob=round(slo_p, 6))
            rec.counter("planner_probes_total",
                        outcome="feasible" if pt.feasible else "infeasible")
        return pt

    hi = max(1, int(math.floor(n_prov * max_added_frac)))
    top = probe(hi)
    if top.feasible:
        return PlanResult(base.name, n_prov, budget, hi, probes, capped=True)
    bottom = probe(0)
    if not bottom.feasible:
        return PlanResult(base.name, n_prov, budget, 0, probes,
                          feasible_at_zero=False)
    lo = 0
    while hi - lo > 1:
        mid = (lo + hi) // 2
        if probe(mid).feasible:
            lo = mid
        else:
            hi = mid
    return PlanResult(base.name, n_prov, budget, lo, probes)


def plan_controller_comparison(base: Scenario,
                               kinds: Sequence[str] = ("static", "predictive"),
                               *,
                               constraints: RiskConstraints = RiskConstraints(),
                               n_seeds: int = 4, seed0: int = 1000,
                               max_added_frac: float = 0.60,
                               budget_w: Optional[float] = None,
                               n_workers: Optional[int] = None) -> Dict[str, PlanResult]:
    """How much safe oversubscription dynamic rebalancing buys back.

    Plans the same routed-fleet scenario once per
    :class:`~repro.experiments.scenario.ControllerSpec` kind — every plan
    shares the same traffic family, router, and (pinned) power envelope, so
    the difference in ``safe_added_servers`` between ``static`` and a
    dynamic policy is attributable to budget rebalancing alone. ``base``
    must carry a RoutingSpec; its ControllerSpec (when present) supplies the
    interval/scope/step settings each kind inherits.
    """
    if base.routing is None:
        raise ValueError(
            f"plan_controller_comparison needs a routed-fleet scenario; "
            f"{base.name!r} has no RoutingSpec")
    budget = (resolve_ensemble_budget(base) if budget_w is None
              else float(budget_w))
    out: Dict[str, PlanResult] = {}
    for kind in kinds:
        sc = base.with_controller(kind).with_(name=f"{base.name}+{kind}")
        out[kind] = plan_capacity(sc, constraints=constraints, n_seeds=n_seeds,
                                  seed0=seed0, max_added_frac=max_added_frac,
                                  budget_w=budget, n_workers=n_workers)
    return out


def plan_scenarios(bases: List[Scenario], *,
                   constraints: RiskConstraints = RiskConstraints(),
                   n_seeds: int = 4, seed0: int = 1000,
                   max_added_frac: float = 0.60,
                   budget_w: Optional[float] = None,
                   n_workers: Optional[int] = None) -> Dict[str, PlanResult]:
    """Per-scenario safe oversubscription ratios for a generator family, all
    planned against the same power envelope (resolved from the first base
    unless pinned). This is the provisioning-planner headline table: how far
    the envelope stretches under nominal, bursty, colocated, failover,
    incident, and nighttime traffic."""
    if not bases:
        return {}
    budget = (resolve_ensemble_budget(bases[0]) if budget_w is None
              else float(budget_w))
    return {b.name: plan_capacity(b, constraints=constraints, n_seeds=n_seeds,
                                  seed0=seed0, max_added_frac=max_added_frac,
                                  budget_w=budget, n_workers=n_workers)
            for b in bases}
