"""Compositional trace ensembles (the provisioning-planner trace layer).

The paper's capacity-planning claim is evaluated against one hand-built
diurnal trace; real provisioning decisions are made against *families* of
traffic realizations ("From Servers to Sites" composes server traces into
rack/row/site traces for exactly this reason). This module provides:

* **Occupancy-curve generators** — seeded, parameterized scenario families
  well beyond the single diurnal baseline: ``bursty`` (flash crowds),
  ``colocated`` (training + inference on one row), ``failover-surge``
  (regional failover absorbs a neighbor's traffic), ``rack-incident``
  (capacity loss + redistribution), and ``nighttime`` (low-entropy trough
  traffic). Each registers in the ``core.traces`` generator registry, so any
  :class:`~repro.experiments.scenario.Scenario` selects one declaratively via
  ``TrafficSpec(generator=..., gen_params=...)``.

* **Correlated row composition** — :func:`compose_rows` mixes a shared
  fleet-wide component with per-row idiosyncratic noise under a correlation
  knob ``rho``, so multi-row scenarios span the correlation spectrum between
  "every row peaks together" (worst case for a shared budget) and
  "independent rows" (statistical multiplexing headroom).

* **Site-trace composition** — :func:`compose_site` folds per-row power
  series into rack and site series (the planning hierarchy), preserving the
  conservation invariant ``sum(rows) == rack`` / ``sum(racks) == site``.

Named Monte-Carlo scenarios (``mc-*``) register alongside the existing
Scenario registry on import.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import numpy as np

from repro.core.hierarchy import PowerHierarchy
from repro.core.traces import DAY, occupancy_curve, register_occupancy_generator
from repro.experiments.scenario import (
    FleetSpec,
    PolicySpec,
    Scenario,
    TrafficSpec,
    register_scenario,
)

OCC_LO, OCC_HI = 0.05, 0.98  # same clip band as the diurnal baseline


def _slow_noise(rng: np.ndarray, t: np.ndarray, sigma: float) -> np.ndarray:
    """Smooth low-frequency noise (coarse gaussian knots, interpolated)."""
    knots = t[:: max(1, len(t) // 200)]
    return np.interp(t, knots, rng.normal(0.0, sigma, size=len(knots)))


def compose_rows(base: np.ndarray, n_rows: int, *, rho: float, seed: int,
                 sigma: float = 0.04, t_grid: np.ndarray = None) -> np.ndarray:
    """[n_rows, T] row occupancy curves sharing ``base`` with correlation
    ``rho``: each row is ``base + rho*shared_noise + (1-rho)*own_noise``.
    ``rho=1`` makes every row identical (synchronized peaks), ``rho=0``
    decorrelates them fully."""
    t = np.arange(len(base), dtype=float) if t_grid is None else t_grid
    rho = float(np.clip(rho, 0.0, 1.0))
    shared = _slow_noise(np.random.default_rng(seed), t, sigma)
    rows = np.empty((n_rows, len(base)))
    for r in range(n_rows):
        own = _slow_noise(np.random.default_rng((seed + 1) * 7919 + r), t, sigma)
        rows[r] = base + rho * shared + (1.0 - rho) * own
    return np.clip(rows, OCC_LO, OCC_HI)


def _row_view(base: np.ndarray, t_grid: np.ndarray, *, seed: int, n_rows: int,
              row: int, rho: float, sigma: float = 0.04) -> np.ndarray:
    """One row's curve out of the correlated composition (single-row
    scenarios skip the composition entirely)."""
    if n_rows <= 1:
        return np.clip(base, OCC_LO, OCC_HI)
    return compose_rows(base, n_rows, rho=rho, seed=seed, sigma=sigma,
                        t_grid=t_grid)[row]


# ---------------------------------------------------------------------------
# scenario-family generators
# ---------------------------------------------------------------------------

def bursty(t_grid: np.ndarray, *, seed: int = 1, peak: float = 0.62,
           n_rows: int = 1, row: int = 0, rho: float = 0.8,
           bursts_per_day: float = 3.0, burst_amp_lo: float = 0.15,
           burst_amp_hi: float = 0.35, burst_rise_s: float = 120.0,
           burst_decay_s: float = 1500.0) -> np.ndarray:
    """Flash-crowd traffic: the diurnal baseline plus Poisson-arriving
    occupancy spikes with a fast rise and exponential decay. Bursts are
    fleet-wide events (a viral prompt hits every row), so they ride the
    shared component regardless of ``rho``."""
    rng = np.random.default_rng(seed)
    base = occupancy_curve(t_grid, peak=peak, seed=seed)
    duration = float(t_grid[-1]) if len(t_grid) else 0.0
    n_bursts = rng.poisson(bursts_per_day * duration / DAY)
    spikes = np.zeros_like(base)
    for _ in range(n_bursts):
        t0 = rng.uniform(0.0, duration)
        amp = rng.uniform(burst_amp_lo, burst_amp_hi)
        dt = t_grid - t0
        rise = np.clip(dt / burst_rise_s, 0.0, 1.0)
        spikes += np.where(dt >= 0.0, amp * rise * np.exp(-dt / burst_decay_s), 0.0)
    return _row_view(base + spikes, t_grid, seed=seed, n_rows=n_rows, row=row,
                     rho=rho)


def colocated(t_grid: np.ndarray, *, seed: int = 1, peak: float = 0.62,
              n_rows: int = 1, row: int = 0, rho: float = 0.5,
              train_share: float = 0.45, inference_share: float = 0.50,
              n_jobs: int = 8, job_util_lo: float = 0.55,
              job_util_hi: float = 0.95) -> np.ndarray:
    """Training + inference colocated on one row: a piecewise-constant
    training floor (back-to-back jobs at different utilizations, seeded) under
    a scaled diurnal inference layer. High mean, low diurnal swing — the
    profile POLCA §5.2 treats as the hard case for oversubscription."""
    rng = np.random.default_rng(seed)
    inference = occupancy_curve(t_grid, peak=peak, seed=seed) * inference_share
    duration = float(t_grid[-1]) if len(t_grid) else 0.0
    edges = np.sort(rng.uniform(0.0, duration, size=max(0, n_jobs - 1)))
    utils = rng.uniform(job_util_lo, job_util_hi, size=n_jobs)
    train = utils[np.searchsorted(edges, t_grid)] * train_share
    return _row_view(inference + train, t_grid, seed=seed, n_rows=n_rows,
                     row=row, rho=rho)


def failover_surge(t_grid: np.ndarray, *, seed: int = 1, peak: float = 0.62,
                   n_rows: int = 1, row: int = 0, rho: float = 0.9,
                   surge_frac: float = 0.45, surge_hours_lo: float = 1.0,
                   surge_hours_hi: float = 4.0,
                   ramp_s: float = 600.0) -> np.ndarray:
    """Regional-failover surge: baseline diurnal traffic, plus one window
    (seeded start, 1-4 h) where this site absorbs a failed region's load —
    occupancy steps up by ``surge_frac`` with a DNS-drain-speed ramp."""
    rng = np.random.default_rng(seed)
    base = occupancy_curve(t_grid, peak=peak, seed=seed)
    duration = float(t_grid[-1]) if len(t_grid) else 0.0
    span = rng.uniform(surge_hours_lo, surge_hours_hi) * 3600.0
    t0 = rng.uniform(0.0, max(1.0, duration - span))
    up = np.clip((t_grid - t0) / ramp_s, 0.0, 1.0)
    down = np.clip((t0 + span - t_grid) / ramp_s, 0.0, 1.0)
    window = np.minimum(up, down)
    return _row_view(base * (1.0 + surge_frac * window), t_grid, seed=seed,
                     n_rows=n_rows, row=row, rho=rho)


def rack_incident(t_grid: np.ndarray, *, seed: int = 1, peak: float = 0.62,
                  n_rows: int = 1, row: int = 0, rho: float = 0.8,
                  rows_per_rack: int = 2, repair_hours: float = 6.0) -> np.ndarray:
    """Capacity incident: at a seeded time one rack drops off (its rows go to
    the idle floor) and the surviving rows absorb its traffic until repair —
    load-conserving redistribution. With a single row, the row plays the
    survivor: it absorbs a failed neighbor rack's share."""
    rng = np.random.default_rng(seed)
    base = occupancy_curve(t_grid, peak=peak, seed=seed)
    duration = float(t_grid[-1]) if len(t_grid) else 0.0
    t0 = rng.uniform(0.0, max(1.0, duration * 0.8))
    window = (t_grid >= t0) & (t_grid < t0 + repair_hours * 3600.0)
    n_lost = max(1, min(rows_per_rack, max(1, n_rows - 1)))
    if n_rows > 1:
        lost_rack = int(rng.integers(0, max(1, -(-n_rows // rows_per_rack))))
        lost = range(lost_rack * rows_per_rack,
                     min(n_rows, lost_rack * rows_per_rack + rows_per_rack))
        n_lost = len(list(lost))
        curve = _row_view(base, t_grid, seed=seed, n_rows=n_rows, row=row,
                          rho=rho)
        if row in lost:
            return np.where(window, OCC_LO, curve)
        absorb = n_lost / max(1, n_rows - n_lost)
        return np.clip(np.where(window, curve * (1.0 + absorb), curve),
                       OCC_LO, OCC_HI)
    # single row: survivor absorbing one lost rack's worth of traffic
    absorb = n_lost / max(1, rows_per_rack)
    return np.clip(np.where(window, base * (1.0 + absorb), base),
                   OCC_LO, OCC_HI)


def nighttime(t_grid: np.ndarray, *, seed: int = 1, peak: float = 0.62,
              n_rows: int = 1, row: int = 0, rho: float = 0.3,
              level_frac: float = 0.45, noise: float = 0.01) -> np.ndarray:
    """Low-entropy nighttime traffic: a flat trough at ``level_frac * peak``
    with tiny noise — the regime where oversubscription headroom is largest
    and a planner should push far past the daytime-safe ratio."""
    rng = np.random.default_rng(seed)
    base = np.full_like(np.asarray(t_grid, float), level_frac * peak)
    base = base + _slow_noise(rng, np.asarray(t_grid, float), noise)
    return _row_view(base, t_grid, seed=seed, n_rows=n_rows, row=row, rho=rho,
                     sigma=noise)


GENERATOR_FAMILY = {
    "bursty": bursty,
    "colocated": colocated,
    "failover-surge": failover_surge,
    "rack-incident": rack_incident,
    "nighttime": nighttime,
}

for _name, _gen in GENERATOR_FAMILY.items():
    register_occupancy_generator(_name, _gen, overwrite=True)


# ---------------------------------------------------------------------------
# site-trace composition
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class SiteTrace:
    """Row -> rack -> ... -> site power composition (watts, [.., T] arrays).
    ``rack_w`` is the leaf-parent level; arbitrary-depth compositions carry
    the full per-node series in ``node_w`` (leaves first, root last, node
    order of the folding :class:`~repro.core.hierarchy.PowerHierarchy`)."""

    row_w: np.ndarray  # [R, T]
    rack_w: np.ndarray  # [K, T]
    site_w: np.ndarray  # [T]
    rack_of: np.ndarray  # [R] rack index per row
    node_w: Optional[np.ndarray] = field(default=None, repr=False)  # [N, T]
    node_names: Tuple[str, ...] = ()


def compose_site(row_w: np.ndarray, *, rows_per_rack: int = 2,
                 hierarchy: Optional[PowerHierarchy] = None) -> SiteTrace:
    """Fold per-row power series through the planning hierarchy — one
    :meth:`~repro.core.hierarchy.PowerHierarchy.fold_w` (the same fold the
    cluster and fleet simulators account with, so planner-shaped budgets and
    runtime telemetry can never disagree on composition). Conservation
    invariants hold exactly: every node's series is the sum of its rows.

    By default the tree is the two-level row -> rack -> site split, which
    requires ``n_rows`` divisible by ``rows_per_rack`` — a ragged tail rack
    used to be composed silently; now it raises. Pass an explicit
    ``hierarchy`` for arbitrary-depth (or ragged) site topologies.
    """
    row_w = np.atleast_2d(np.asarray(row_w, float))
    n_rows = row_w.shape[0]
    if hierarchy is None:
        if rows_per_rack < 1:
            raise ValueError(f"rows_per_rack must be >= 1, got {rows_per_rack}")
        if n_rows % rows_per_rack:
            raise ValueError(
                f"compose_site: {n_rows} rows do not divide into racks of "
                f"{rows_per_rack} — a ragged tail rack would be silently "
                f"mis-sized; pass a divisible n_rows or an explicit "
                f"PowerHierarchy for ragged topologies")
        # budgets are irrelevant for a watts fold; ones keep the tree valid
        hierarchy = PowerHierarchy.two_level(
            np.ones(n_rows), rows_per_rack=rows_per_rack)
    elif hierarchy.n_leaves != n_rows:
        raise ValueError(f"hierarchy has {hierarchy.n_leaves} leaves for "
                         f"{n_rows} rows")
    node_w = hierarchy.fold_w(row_w.T).T  # [N, T]
    ordinal = {int(p): k for k, p in enumerate(hierarchy.leaf_parents)}
    rack_of = np.asarray([ordinal[int(hierarchy.parent[i])]
                          for i in range(n_rows)])
    return SiteTrace(row_w=row_w, rack_w=node_w[hierarchy.leaf_parents],
                     site_w=node_w[hierarchy.root], rack_of=rack_of,
                     node_w=node_w, node_names=hierarchy.names)


# ---------------------------------------------------------------------------
# named Monte-Carlo scenarios (registered alongside the figure scenarios)
# ---------------------------------------------------------------------------

MC_BASE_NAME = "mc-diurnal"
MC_SCENARIO_FAMILY: List[str] = [
    MC_BASE_NAME,
    "mc-bursty",
    "mc-colocated",
    "mc-failover",
    "mc-rack-incident",
    "mc-nighttime",
]


def _mc_scenario(name: str, generator: str, **gen_params) -> Scenario:
    return register_scenario(Scenario(
        name=name,
        duration_s=DAY / 2,
        fleet=FleetSpec(n_provisioned=40, added_frac=0.0),
        policy=PolicySpec("polca"),
        traffic=TrafficSpec(occ_peak=0.62, generator=generator,
                            gen_params=gen_params),
        budget="calibrated",
        compare_to_reference=False,
    ), overwrite=True)


_mc_scenario(MC_BASE_NAME, "diurnal")
_mc_scenario("mc-bursty", "bursty")
_mc_scenario("mc-colocated", "colocated")
_mc_scenario("mc-failover", "failover-surge")
_mc_scenario("mc-rack-incident", "rack-incident")
_mc_scenario("mc-nighttime", "nighttime")
