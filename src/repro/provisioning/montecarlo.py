"""Vectorized Monte-Carlo ensemble engine over the POLCA cluster simulator.

``run_ensemble`` evaluates N seeded traffic realizations of a scenario (and
``run_ensemble_grid`` an N seeds x M scenarios grid) in one batched pass:

* the row power budget is resolved **once** from the base scenario and pinned
  across every member — Monte-Carlo asks "how does one fixed infrastructure
  design behave under traffic uncertainty", so per-member re-calibration
  (what a naive ``run_experiment`` loop does) would erase the very
  variability being measured;
* members run as a lockstep fleet of :class:`RowSimulator`\\ s (advanced on a
  shared stride grid, the same drive mode the ClusterSimulator uses), sharded
  across a small fork-based process pool;
* per-tick power series land in one ``[members, ticks]`` numpy matrix and
  every distributional statistic — powerbrake-count CDFs, SLO-impact
  percentiles, peak-power exceedance curves — is a vectorized reduction over
  it.

Member simulations are constructed through the exact same
:func:`repro.experiments.runner.row_trace` / ``row_sim`` path as
``run_experiment``, so batched results are **bit-identical** to a sequential
``run_experiment`` loop over :meth:`EnsembleSpec.member_scenarios` (asserted
in tests) while avoiding its per-member budget calibration — and, in the
default no-reference mode, its per-member uncapped reference runs too (SLO
impacts are then relative to the unqueued uncapped ideal). Set
``EnsembleSpec(with_reference=True)`` for the paper's paired-reference SLO
comparison (the capacity planner does): references run in the same batched
pass.

Members are not restricted to single rows: a scenario carrying a
``RoutingSpec`` runs as a whole routed fleet
(:class:`~repro.fleet.fleet.FleetSimulator`, DESIGN.md §10) through the same
lockstep protocol, with its cluster-level power series and pooled latencies
feeding the distributional statistics — so capacity planning runs over
multi-row fleets exactly as over rows. Fleet members carrying a
``ControllerSpec`` additionally run under the dynamic power-rebalancing
controller (DESIGN.md §11); their uncapped reference twins never do, so the
SLO gate still isolates power-management impact. That is what lets
``plan_capacity`` (and ``plan_controller_comparison``) quantify how much
safe oversubscription rebalancing buys back.

Fault timelines ride along for free: a base scenario carrying
``Scenario.faults`` propagates it to every member through
:meth:`EnsembleSpec.member_scenarios` (``with_`` copies the field), and
``build_fleet`` constructs a **fresh** ``ChaosInjector`` per member fleet —
no actuation state is shared across members or workers, so faulted
ensembles remain worker-count-invariant and bit-reproducible (asserted in
``tests/test_chaos.py``). That per-member injection is what
``RiskConstraints.survive`` builds the planner's survivability gate on.
"""

from __future__ import annotations

import math
import multiprocessing
import os
import warnings
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.policy import NoCap
from repro.core.simulator import RowSimulator, SimConfig, SimResult
from repro.core.slo import (
    DEFAULT_SLO,
    SLO,
    LatencyStats,
    impact_vs_reference,
    meets_slo,
)
from repro.experiments.runner import (
    ExperimentResult,
    build_workloads,
    resolve_budget,
    row_sim,
    row_trace,
    run_experiment,
)
from repro.experiments.scenario import Scenario
from repro.obs.metrics import (
    MetricsRecorder,
    MetricsSnapshot,
    NULL_RECORDER,
    get_recorder,
    recording,
)

import repro.provisioning.ensembles  # noqa: F401  (registers trace generators)


@dataclass(frozen=True)
class EnsembleSpec:
    """N seeded members of one base scenario.

    ``seed0 + k`` seeds member ``k``'s traffic realization. ``n_workers``
    defaults to the available CPUs (capped by the member count); pass 1 to
    force a single-process run. ``lockstep_stride_s`` only controls how often
    the lockstep driver yields between members — results are stride-invariant
    (the row event queues are exact regardless of drive granularity).

    ``with_reference=True`` pairs every member with an uncapped reference run
    on the same trace, so SLO stats are the paper's capping-impact-only
    comparison (what the planner gates on) instead of ideal-relative impacts
    that fold queueing noise in. It doubles the per-member cost.
    """

    base: Scenario
    n_seeds: int = 8
    seed0: int = 1000
    n_workers: Optional[int] = None
    lockstep_stride_s: float = 120.0
    with_reference: bool = False

    def seeds(self) -> List[int]:
        """The member seeds, in member order: ``seed0 + k`` for member k."""
        return [self.seed0 + k for k in range(self.n_seeds)]

    def member_scenarios(self, budget_w: Optional[float] = None) -> List[Scenario]:
        """The concrete per-member scenarios the engine simulates: pinned
        explicit budget, one seed each."""
        budget = self.base.budget if budget_w is None else float(budget_w)
        return [self.base.with_(name=f"{self.base.name}@s{s}", seed=s,
                                budget=budget,
                                compare_to_reference=self.with_reference)
                for s in self.seeds()]


@dataclass
class MemberStats:
    """One ensemble member: its scenario, the policy-run SimResult, and the
    SLO-impact stats (reference-relative when the member ran with a paired
    uncapped reference, ideal-relative otherwise)."""

    scenario: Scenario
    result: SimResult
    stats: LatencyStats

    @property
    def meets(self) -> bool:
        """Whether this member meets its scenario's SLO (brakes included)."""
        return meets_slo(self.stats, self.result.n_brakes, self.scenario.slo)


@dataclass
class EnsembleResult:
    """Distributional telemetry over one ensemble (vectorized accounting)."""

    base_name: str
    budget_w: float
    members: List[MemberStats]
    power_t: np.ndarray = field(repr=False)  # [T] telemetry grid
    power_frac: np.ndarray = field(repr=False)  # [N, T] of row budget
    brake_counts: np.ndarray = field(repr=False)  # [N]
    peak_fracs: np.ndarray = field(repr=False)  # [N]
    mean_fracs: np.ndarray = field(repr=False)  # [N]
    # dense-tail mode (batched engine, ``member_stats=False``): ``members``
    # stays empty and per-member SLO impact samples ride as [N, K] arrays —
    # the statistics below fall back to vectorized paths over these, so a
    # 10^5-member result carries no per-member python objects
    member_impacts_hp: Optional[np.ndarray] = field(default=None, repr=False)
    member_impacts_lp: Optional[np.ndarray] = field(default=None, repr=False)

    @property
    def n_members(self) -> int:
        if self.members:
            return len(self.members)
        return int(len(self.brake_counts))

    def _dense_impacts(self, priority: str) -> Optional[np.ndarray]:
        """[N, K] impact samples in dense-tail mode, else None."""
        if self.members:
            return None
        return (self.member_impacts_hp if priority == "high"
                else self.member_impacts_lp)

    def _member_percentiles(self, priority: str, q: float) -> np.ndarray:
        """Per-member q-th percentile impact, [N] — member-object path and
        dense path produce bit-identical values (same np.percentile on the
        same samples; empty members are 0.0 like LatencyStats)."""
        dense = self._dense_impacts(priority)
        if dense is not None:
            if dense.shape[1] == 0:
                return np.zeros(dense.shape[0])
            return np.percentile(dense, q, axis=1)
        key = "hp_impacts" if priority == "high" else "lp_impacts"
        return np.asarray([
            float(np.percentile(np.asarray(getattr(m.stats, key)), q))
            if len(getattr(m.stats, key)) else 0.0
            for m in self.members])

    # -- powerbrake distribution -------------------------------------------
    def brake_prob(self, max_brakes: int = 0) -> float:
        """P[a member experiences more than ``max_brakes`` powerbrakes].
        The default (0) is the zero-tolerance P[>= 1 brake]; the planner
        passes its ``RiskConstraints.max_brakes`` budget here."""
        return float(np.mean(self.brake_counts > max_brakes))

    def brake_cdf(self) -> Tuple[np.ndarray, np.ndarray]:
        """(counts, P[brakes <= count]) — the powerbrake-count CDF."""
        counts = np.sort(self.brake_counts)
        return counts, np.arange(1, len(counts) + 1) / len(counts)

    def brake_cvar(self, alpha: float) -> float:
        """CVaR_alpha of the per-member powerbrake count: the expected count
        over the worst ``(1 - alpha)`` fraction of members.  Fractional tail
        mass is interpolated so the estimator is continuous in alpha."""
        return _cvar(np.asarray(self.brake_counts, float), alpha)

    def slo_cvar(self, priority: str, alpha: float, q: float = 99.0) -> float:
        """CVaR_alpha over the per-member P``q`` SLO impact of ``priority``.
        Each member contributes one tail statistic (its own q-th percentile
        impact); CVaR then averages the worst ``(1 - alpha)`` of those —
        the dense-tail gate behind ``RiskConstraints.slo_cvar_alpha``."""
        return _cvar(np.asarray(self._member_percentiles(priority, q),
                                float), alpha)

    # -- power distribution -------------------------------------------------
    def peak_exceedance(self, levels: Sequence[float]) -> np.ndarray:
        """P[member peak power > level] per level (fractions of budget)."""
        lv = np.asarray(levels, float)
        return (self.peak_fracs[None, :] > lv[:, None]).mean(axis=1)

    def power_exceedance(self, levels: Sequence[float]) -> np.ndarray:
        """Time-pooled P[instantaneous row power > level] over all members."""
        lv = np.asarray(levels, float)
        if self.power_frac.size == 0:
            return np.zeros_like(lv)
        # sort once + searchsorted per level: O(NT log NT), no [L, NT] matrix
        flat = np.sort(self.power_frac, axis=None)
        return 1.0 - np.searchsorted(flat, lv, side="right") / flat.size

    # -- SLO distribution ---------------------------------------------------
    def slo_impacts(self, priority: str) -> np.ndarray:
        """All per-request latency impacts of ``priority``, pooled."""
        dense = self._dense_impacts(priority)
        if dense is not None:
            return dense.ravel() if dense.size else np.zeros(0)
        key = "hp_impacts" if priority == "high" else "lp_impacts"
        xs = [getattr(m.stats, key) for m in self.members]
        return np.concatenate([np.asarray(x) for x in xs]) if any(
            len(x) for x in xs) else np.zeros(0)

    def slo_percentile(self, priority: str, q: float) -> float:
        xs = self.slo_impacts(priority)
        return float(np.percentile(xs, q)) if len(xs) else 0.0

    def _meets_mask(self, slo: SLO, include_brakes: bool) -> np.ndarray:
        """[N] bool per-member SLO gate, vectorized over both storage modes
        (same strict-< percentile comparisons as :func:`core.slo.meets_slo`)."""
        ok = ((self._member_percentiles("high", 50) < slo.hp_p50)
              & (self._member_percentiles("high", 99) < slo.hp_p99)
              & (self._member_percentiles("low", 50) < slo.lp_p50)
              & (self._member_percentiles("low", 99) < slo.lp_p99))
        if include_brakes:
            ok = ok & (np.asarray(self.brake_counts) <= slo.max_powerbrakes)
        return ok

    def meets_fraction(self, slo: Optional[SLO] = None) -> float:
        """Fraction of members meeting the SLO (per-member gate). ``slo=None``
        uses each member's own scenario SLO (dense-tail results, which carry
        no scenarios, fall back to :data:`~repro.core.slo.DEFAULT_SLO`)."""
        if self.members:
            if slo is None:
                return float(np.mean([m.meets for m in self.members]))
            return float(np.mean([
                meets_slo(m.stats, m.result.n_brakes, slo)
                for m in self.members]))
        if self.n_members == 0:
            return float("nan")
        return float(np.mean(self._meets_mask(slo or DEFAULT_SLO, True)))

    def slo_violation_prob(self, slo: Optional[SLO] = None) -> float:
        """P[member misses the SLO], powerbrakes *excluded* (the planner
        constrains those separately via ``max_brake_prob``). Works in both
        member-object and dense-tail modes — the vectorized percentile gate
        is bit-identical to looping ``meets_slo(m.stats, 0, slo)``."""
        if self.n_members == 0:
            return 0.0
        return float(1.0 - np.mean(self._meets_mask(slo or DEFAULT_SLO,
                                                    False)))

    def summary(self) -> Dict[str, float]:
        """Headline distributional stats in one flat dict (benchmark rows)."""
        return {
            "n_members": float(self.n_members),
            "brake_prob": self.brake_prob(),
            "meets_frac": self.meets_fraction(),
            "peak_p50": float(np.median(self.peak_fracs)),
            "peak_max": float(self.peak_fracs.max()) if len(self.peak_fracs) else 0.0,
            "hp_p99": self.slo_percentile("high", 99),
            "lp_p99": self.slo_percentile("low", 99),
        }


def _cvar(xs: np.ndarray, alpha: float) -> float:
    """Interpolated upper-tail CVaR: mean of the worst ``(1 - alpha)``
    probability mass of ``xs``.  ``alpha=0`` degenerates to the plain mean,
    ``alpha -> 1`` to the sample maximum."""
    if not 0.0 <= alpha < 1.0:
        raise ValueError(f"alpha must be in [0, 1), got {alpha}")
    n = xs.size
    if n == 0:
        return 0.0
    ordered = np.sort(xs)[::-1]  # descending: worst first
    mass = (1.0 - alpha) * n  # tail size in member units, may be fractional
    if mass <= 1.0:
        return float(ordered[0])
    whole = int(math.floor(mass))
    total = float(ordered[:whole].sum())
    if whole < n and mass > whole:
        total += (mass - whole) * float(ordered[whole])
    return total / mass


# ---------------------------------------------------------------------------
# the batched engine
# ---------------------------------------------------------------------------

_WLS_CACHE: Dict[tuple, tuple] = {}


def _cached_workloads(scenario: Scenario):
    key = (scenario.fleet.model, scenario.fleet.device,
           scenario.fleet.n_devices_per_server,
           scenario.traffic.priority_mix_override)
    if key not in _WLS_CACHE:
        _WLS_CACHE[key] = build_workloads(scenario)
    return _WLS_CACHE[key]


def _member_budget_w(sc: Scenario) -> Optional[float]:
    if sc.budget == "nominal":
        return None  # RowSimulator default: n_provisioned x rating
    if isinstance(sc.budget, (int, float)):
        return float(sc.budget)
    raise ValueError(
        f"member {sc.name!r} reached the batch runner with budget="
        f"{sc.budget!r}; resolve it to watts first (run_ensemble "
        "pins the base scenario's resolved budget across members)")


def _finalize_member(sim) -> SimResult:
    """Row members finalize to a SimResult directly; fleet members collapse
    their FleetResult into the cluster-shaped equivalent."""
    res = sim.finalize()
    if isinstance(res, SimResult):
        return res
    from repro.fleet.fleet import as_sim_result
    return as_sim_result(res)


def _run_shard(payload: Tuple[List[Scenario], float, int]
               ) -> Tuple[List[Tuple[SimResult, LatencyStats]],
                          Optional[MetricsSnapshot]]:
    """Worker: run one shard of members as a lockstep pool (the cluster
    drive mode: start all, advance all on a stride grid, finalize all).
    Members whose scenario requests a reference comparison get a paired
    uncapped reference simulation in the same lockstep pass. Members whose
    scenario carries a RoutingSpec run as whole routed fleets
    (:class:`~repro.fleet.fleet.FleetSimulator`) — multi-row ensemble members
    lockstep next to single-row ones through the same drive protocol, with
    any declared ControllerSpec rebalancing their row budgets in-run.

    Observability: with a recorder installed (inherited across the fork),
    each member records into its **own** fresh recorder — member snapshots
    merge back in member order regardless of sharding, so event traces are
    worker-count-invariant (tier-1-asserted). Reference twins record under
    the null recorder (they are a measurement baseline, not part of the
    observed run). The shard itself is timed by one ``mc/shard`` span, the
    fork-pool skew signal (wall-clock; excluded from determinism by
    nature). Returns ``(results, snapshot-or-None)``."""
    scenarios, stride, shard_idx = payload
    member_recs: Optional[List[MetricsRecorder]] = (
        [MetricsRecorder() for _ in scenarios]
        if get_recorder().enabled else None)
    shard_rec = MetricsRecorder() if member_recs is not None else NULL_RECORDER
    with shard_rec.span("mc/shard", shard=shard_idx,
                        members=len(scenarios)):
        out = _run_shard_pool(scenarios, stride, member_recs)
    if member_recs is None:
        return out, None
    snap = shard_rec.snapshot()
    for r in member_recs:
        snap.merge(r.snapshot())
    return out, snap


def _run_shard_pool(scenarios: List[Scenario], stride: float,
                    member_recs: Optional[List[MetricsRecorder]]
                    ) -> List[Tuple[SimResult, LatencyStats]]:
    sims: List[object] = []
    refs: List[Optional[object]] = []
    traces = []
    for sc in scenarios:
        wls, shares = _cached_workloads(sc)
        server = sc.fleet.server()
        n = sc.fleet.n_servers
        budget = _member_budget_w(sc)
        if sc.routing is not None:
            from repro.fleet.fleet import build_fleet, fleet_trace
            reqs = fleet_trace(sc, wls, shares)
            traces.append(reqs)
            sims.append(build_fleet(sc, wls, shares, server, budget,
                                    sc.policy.build, reqs))
            refs.append(build_fleet(sc, wls, shares, server, budget,
                                    sc.policy.build, reqs, reference=True)
                        if sc.compare_to_reference else None)
            continue
        reqs = row_trace(sc, wls, shares, n, seed=sc.seed)
        traces.append(reqs)
        sims.append(row_sim(sc, wls, shares, server, budget,
                            sc.policy.build(), reqs))
        if sc.compare_to_reference:
            # uncapped twin, constructed exactly as run_experiment's _run_row
            refs.append(RowSimulator(wls, server, n, 10 * n, NoCap(), reqs,
                                     shares,
                                     SimConfig(power_scale=sc.power_scale,
                                               record_power=False),
                                     duration=sc.duration_s))
        else:
            refs.append(None)
    pool = sims + [r for r in refs if r is not None]
    # per-pool-slot recorder: member i records into its own recorder,
    # reference twins into the no-op null recorder
    pool_recs = ((list(member_recs)
                  + [NULL_RECORDER] * (len(pool) - len(sims)))
                 if member_recs is not None else [NULL_RECORDER] * len(pool))
    for s in pool:
        s.start()
    duration = max((s.duration for s in pool), default=0.0)
    alive = [True] * len(pool)
    t = stride
    while t <= duration and any(alive):
        for i, s in enumerate(pool):
            if alive[i]:
                with recording(pool_recs[i]):
                    alive[i] = s.advance_to(min(t, s.duration))
        t += stride
    for i, s in enumerate(pool):
        with recording(pool_recs[i]):
            s.advance_to(s.duration)
    out = []
    for k, (sim, ref, reqs) in enumerate(zip(sims, refs, traces)):
        with recording(pool_recs[k]):
            res = _finalize_member(sim)
        if ref is None:
            stats = res.latency
        else:
            with recording(NULL_RECORDER):
                ref_latencies = _finalize_member(ref).latencies
            stats = impact_vs_reference(res.latencies, ref_latencies,
                                        {r.rid: r.priority for r in reqs})
        out.append((res, stats))
    return out


def _map_shards(shards: List[Tuple[List[Scenario], float, int]],
                n_workers: int
                ) -> List[Tuple[List[Tuple[SimResult, LatencyStats]],
                                Optional[MetricsSnapshot]]]:
    if n_workers <= 1 or len(shards) <= 1:
        return [_run_shard(sh) for sh in shards]
    try:
        ctx = multiprocessing.get_context("fork")
        with ctx.Pool(processes=n_workers) as pool:
            return pool.map(_run_shard, shards)
    except (OSError, ValueError) as e:  # restricted sandboxes: no fork/sem
        warnings.warn(f"process pool unavailable ({e}); running inline")
        return [_run_shard(sh) for sh in shards]


def _default_workers(n_members: int, n_workers: Optional[int]) -> int:
    if n_workers is not None:
        return max(1, n_workers)
    try:
        cpus = len(os.sched_getaffinity(0))
    except AttributeError:  # non-linux
        cpus = os.cpu_count() or 1
    return max(1, min(cpus, n_members))


def _run_members(members: List[Scenario], stride: float,
                 n_workers: int) -> List[Tuple[SimResult, LatencyStats]]:
    """One batched pass over concrete member scenarios, order-preserving.
    Worker metric snapshots fold back into the ambient recorder in shard
    (i.e. member) order, so the merged trace is identical for any worker
    count."""
    w = _default_workers(len(members), n_workers)
    bounds = np.linspace(0, len(members), w + 1).astype(int)
    spans = [(a, b) for a, b in zip(bounds, bounds[1:]) if b > a]
    shards = [(members[a:b], stride, si) for si, (a, b) in enumerate(spans)]
    rec = get_recorder()
    out: List[Tuple[SimResult, LatencyStats]] = []
    for results, snap in _map_shards(shards, len(shards)):
        out.extend(results)
        if snap is not None and rec.enabled:
            rec.merge_snapshot(snap)
    return out


def _ensemble_result(base: Scenario, budget_w: float, members: List[Scenario],
                     pairs: List[Tuple[SimResult, LatencyStats]]) -> EnsembleResult:
    stats = [MemberStats(sc, res, st) for sc, (res, st) in zip(members, pairs)]
    results = [res for res, _ in pairs]
    series = [res.power_w for res in results if res.power_w is not None]
    if series and all(len(s) == len(series[0]) for s in series):
        power = np.stack(series)
        power_t = results[0].power_t
    else:  # record_power off, or ragged (heterogeneous durations)
        power = np.zeros((0, 0))
        power_t = np.zeros(0)
    return EnsembleResult(
        base_name=base.name,
        budget_w=budget_w,
        members=stats,
        power_t=power_t,
        power_frac=power,
        brake_counts=np.asarray([r.n_brakes for r in results]),
        peak_fracs=np.asarray([r.peak_power_frac for r in results]),
        mean_fracs=np.asarray([r.mean_power_frac for r in results]),
    )


def resolve_ensemble_budget(base: Scenario) -> float:
    """The pinned row budget (watts) shared by every ensemble member."""
    wls, shares = _cached_workloads(base)
    server = base.fleet.server()
    budget = resolve_budget(base, wls, shares, server)
    if budget is None:  # "nominal": pin the explicit equivalent
        budget = base.fleet.n_provisioned * server.provisioned_w
    return float(budget)


def run_ensemble(spec: EnsembleSpec, *, budget_w: Optional[float] = None,
                 engine: str = "numpy", **engine_opts) -> EnsembleResult:
    """Evaluate all members of ``spec`` in one batched pass.

    ``engine`` selects the execution backend:

    * ``"numpy"`` (default) — the event-driven fork-pool oracle above, the
      reference semantics every other backend is differentially tested
      against;
    * ``"jax"`` — the jit/vmap/``lax.scan`` device program in
      :mod:`repro.provisioning.batched` (DESIGN.md §15-16), a fluid
      tick-level lowering that runs 10^5+ members in one call;
    * ``"batched-numpy"`` — the numpy tick-level oracle of that same
      lowering (drives the real policy objects), used by the parity
      harness;
    * ``"pallas"`` — the Pallas tick kernel backend
      (:mod:`repro.kernels.tick`, non-predictive policies).

    ``engine_opts`` forward to ``run_batched_ensemble`` (``member_chunk``,
    ``mesh``, ``member_stats``, ``keep_series``, ``keep_brake_fire``);
    they are meaningless for the event-driven engine and rejected there.
    """
    if engine in ("jax", "batched-numpy", "pallas"):
        from repro.provisioning.batched import run_batched_ensemble
        return run_batched_ensemble(spec, budget_w=budget_w, engine=engine,
                                    **engine_opts)
    if engine != "numpy":
        raise ValueError(
            f"unknown ensemble engine {engine!r}; "
            "expected 'numpy', 'jax', 'batched-numpy', or 'pallas'")
    if engine_opts:
        raise ValueError(
            f"engine options {sorted(engine_opts)} only apply to the "
            "batched engines, not engine='numpy'")
    with get_recorder().span("mc/run_ensemble", base=spec.base.name,
                             members=spec.n_seeds):
        budget = (resolve_ensemble_budget(spec.base) if budget_w is None
                  else float(budget_w))
        members = spec.member_scenarios(budget)
        results = _run_members(members, spec.lockstep_stride_s,
                               _default_workers(len(members), spec.n_workers))
        return _ensemble_result(spec.base, budget, members, results)


def run_ensemble_grid(bases: Sequence[Scenario], *, n_seeds: int = 8,
                      seed0: int = 1000, n_workers: Optional[int] = None,
                      budget_w: Optional[float] = None,
                      lockstep_stride_s: float = 120.0,
                      engine: str = "numpy",
                      **engine_opts) -> Dict[str, EnsembleResult]:
    """N seeds x M scenarios in one batched pass.

    ``engine="numpy"`` (default) flattens all M*N members into a single
    work list, shards it across the fork pool together, and re-groups into
    one :class:`EnsembleResult` per base scenario. The batched engines
    (``"jax"``/``"batched-numpy"``/``"pallas"``) dispatch to
    :func:`repro.provisioning.batched.run_batched_grid`, which buckets
    scenarios by tick geometry and runs each bucket as ONE scenario-axis
    vmapped device program — an M-family CVaR frontier is a single jit
    call (DESIGN.md §16). ``engine_opts`` forward there (``member_chunk``,
    ``mesh``, ``member_stats``, ...)."""
    specs = [EnsembleSpec(b, n_seeds=n_seeds, seed0=seed0,
                          n_workers=n_workers,
                          lockstep_stride_s=lockstep_stride_s) for b in bases]
    if engine in ("jax", "batched-numpy", "pallas"):
        from repro.provisioning.batched import run_batched_grid
        results = run_batched_grid(specs, budget_w=budget_w, engine=engine,
                                   **engine_opts)
        return {s.base.name: r for s, r in zip(specs, results)}
    if engine != "numpy":
        raise ValueError(
            f"unknown ensemble engine {engine!r}; "
            "expected 'numpy', 'jax', 'batched-numpy', or 'pallas'")
    if engine_opts:
        raise ValueError(
            f"engine options {sorted(engine_opts)} only apply to the "
            "batched engines, not engine='numpy'")
    budgets = [resolve_ensemble_budget(s.base) if budget_w is None
               else float(budget_w) for s in specs]
    member_lists = [s.member_scenarios(bw) for s, bw in zip(specs, budgets)]
    flat = [m for ml in member_lists for m in ml]
    results = _run_members(flat, lockstep_stride_s,
                           _default_workers(len(flat), n_workers))
    out: Dict[str, EnsembleResult] = {}
    i = 0
    for spec, bw, ml in zip(specs, budgets, member_lists):
        out[spec.base.name] = _ensemble_result(spec.base, bw, ml,
                                               results[i:i + len(ml)])
        i += len(ml)
    return out


def run_ensemble_sequential(spec: EnsembleSpec, *,
                            n_members: Optional[int] = None) -> List[ExperimentResult]:
    """The naive alternative the engine replaces: a Python loop calling
    ``run_experiment`` per seed with the base scenario's declared semantics
    (so per-member budget calibration and reference runs are repeated N
    times). Kept as the speed-comparison baseline for the capacity-planning
    benchmark; ``n_members`` limits how many seeds are actually run."""
    seeds = spec.seeds()[:n_members if n_members is not None else spec.n_seeds]
    return [run_experiment(spec.base.with_(seed=s)) for s in seeds]
