"""Gradient compression for the data-parallel reduction: int8 + error feedback.

At multi-pod scale the DP gradient reduce-scatter crosses the (slow) inter-pod
links; quantizing to int8 with per-tensor scales cuts those bytes 4x vs fp32.
Error feedback (Karimireddy et al.) accumulates the quantization residual
locally so the scheme stays convergent.

Usage: wrap grads between value_and_grad and the optimizer update. The
quantize-dequantize pair brackets the point where GSPMD inserts the cross-pod
collective (the psum happens on the int8-scaled values' dequantized form; XLA
fuses the scaling). For exactness-sensitive runs leave it off (default).
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def init_error_feedback(param_specs_or_params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                        param_specs_or_params)


def compress_decompress(g, ef):
    """int8 quantize->dequantize with error feedback. Returns (g_hat, ef')."""
    g = g.astype(jnp.float32) + ef
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    g_hat = q.astype(jnp.float32) * scale
    return g_hat, g - g_hat


def compress_grads(grads, ef_state) -> Tuple[Any, Any]:
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(ef_state)
    out = [compress_decompress(g, e) for g, e in zip(flat_g, flat_e)]
    g_hat = treedef.unflatten([o[0] for o in out])
    new_ef = treedef.unflatten([o[1] for o in out])
    return g_hat, new_ef
