from repro.optim.optimizers import (  # noqa: F401
    Optimizer,
    OptState,
    global_norm,
    make_optimizer,
    opt_init_specs,
)
