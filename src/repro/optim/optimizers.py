"""Optimizers: AdamW and factored Adafactor, as pure functions over pytrees.

Optimizer state mirrors parameter sharding (FSDP: ZeRO-sharded moments). The
state tree is built from ``ParamSpec``s so the dry-run can get abstract state
with correct shardings without allocating (``opt_init_specs``).

Optional gradient compression (int8 + error feedback) for the data-parallel
all-reduce lives in ``repro.optim.compression`` and wraps the grads before
the optimizer update.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.param import ParamSpec, is_spec, tree_map_specs

OptState = Dict[str, Any]


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def _adamw_init_specs(param_specs) -> OptState:
    def mom(s: ParamSpec) -> ParamSpec:
        return ParamSpec(s.shape, s.logical, init="zeros", dtype=jnp.float32)

    return {
        "mu": tree_map_specs(mom, param_specs),
        "nu": tree_map_specs(mom, param_specs),
    }


def _adamw_update(grads, state, params, *, lr, b1, b2, eps, wd):
    c = state["count"] + 1
    cf = c.astype(jnp.float32)

    def upd(g, mu, nu, p):
        g = g.astype(jnp.float32)
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * jnp.square(g)
        mu_hat = mu / (1 - b1 ** cf)
        nu_hat = nu / (1 - b2 ** cf)
        step = mu_hat / (jnp.sqrt(nu_hat) + eps) + wd * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), mu, nu

    flat_g, treedef = jax.tree.flatten(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    flat_nu = treedef.flatten_up_to(state["nu"])
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, n, p) for g, m, n, p in zip(flat_g, flat_mu, flat_nu, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_state = {
        "mu": treedef.unflatten([o[1] for o in out]),
        "nu": treedef.unflatten([o[2] for o in out]),
        "count": c,
    }
    return new_p, new_state


# ---------------------------------------------------------------------------
# Adafactor (factored second moment; no first moment) — for the 1T-param archs
# ---------------------------------------------------------------------------

def _adafactor_init_specs(param_specs) -> OptState:
    def row(s: ParamSpec):
        if len(s.shape) < 2:
            return ParamSpec(s.shape, s.logical, init="zeros", dtype=jnp.float32)
        return ParamSpec(s.shape[:-1], s.logical[:-1], init="zeros", dtype=jnp.float32)

    def col(s: ParamSpec):
        if len(s.shape) < 2:
            return ParamSpec((1,), (None,), init="zeros", dtype=jnp.float32)
        return ParamSpec(s.shape[:-2] + s.shape[-1:], s.logical[:-2] + s.logical[-1:],
                         init="zeros", dtype=jnp.float32)

    return {
        "vr": tree_map_specs(row, param_specs),
        "vc": tree_map_specs(col, param_specs),
    }


def _adafactor_update(grads, state, params, *, lr, b2, eps, wd):
    c = state["count"] + 1
    cf = c.astype(jnp.float32)
    decay = 1.0 - cf ** -0.8  # t^-0.8 schedule from the Adafactor paper

    def upd(g, vr, vc, p):
        g = g.astype(jnp.float32)
        g2 = jnp.square(g) + eps
        if g.ndim < 2:
            vr_n = decay * vr + (1 - decay) * g2
            update = g * jax.lax.rsqrt(vr_n)
            vc_n = vc
        else:
            vr_n = decay * vr + (1 - decay) * jnp.mean(g2, axis=-1)
            vc_n = decay * vc + (1 - decay) * jnp.mean(g2, axis=-2)
            r = vr_n / jnp.mean(vr_n, axis=-1, keepdims=True)
            update = g / (jnp.sqrt(r)[..., None] * jnp.sqrt(vc_n)[..., None, :])
        # clip update rms to 1.0 (Adafactor d=1)
        rms = jnp.sqrt(jnp.mean(jnp.square(update)) + 1e-30)
        update = update / jnp.maximum(1.0, rms)
        newp = p.astype(jnp.float32) * (1 - lr * wd) - lr * update
        return newp.astype(p.dtype), vr_n, vc_n

    flat_g, treedef = jax.tree.flatten(grads)
    flat_vr = treedef.flatten_up_to(state["vr"])
    flat_vc = treedef.flatten_up_to(state["vc"])
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, r_, c_, p) for g, r_, c_, p in zip(flat_g, flat_vr, flat_vc, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_state = {
        "vr": treedef.unflatten([o[1] for o in out]),
        "vc": treedef.unflatten([o[2] for o in out]),
        "count": c,
    }
    return new_p, new_state


# ---------------------------------------------------------------------------
# Public factory
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Optimizer:
    name: str
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0

    def init_specs(self, param_specs) -> OptState:
        if self.name == "adafactor":
            st = _adafactor_init_specs(param_specs)
        else:
            st = _adamw_init_specs(param_specs)
        st["count"] = ParamSpec((), (), init="zeros", dtype=jnp.int32)
        return st

    def update(self, grads, state, params) -> Tuple[Any, OptState, jax.Array]:
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, self.grad_clip / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)
        if self.name == "adafactor":
            p, s = _adafactor_update(grads, state, params, lr=self.lr, b2=self.b2,
                                     eps=self.eps, wd=self.weight_decay)
        else:
            p, s = _adamw_update(grads, state, params, lr=self.lr, b1=self.b1,
                                 b2=self.b2, eps=self.eps, wd=self.weight_decay)
        return p, s, gnorm


def make_optimizer(name: str, **kw) -> Optimizer:
    return Optimizer(name=name, **kw)


def opt_init_specs(opt: Optimizer, param_specs) -> OptState:
    return opt.init_specs(param_specs)
