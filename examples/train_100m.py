"""End-to-end training driver: a ~100M-parameter llama-family model for a few
hundred steps with checkpoint/restart and straggler monitoring.

  PYTHONPATH=src python examples/train_100m.py                # ~100M, 300 steps
  PYTHONPATH=src python examples/train_100m.py --small        # ~20M, 200 steps (fast CPU)

Resume after interruption is automatic: rerun the same command and the
supervisor restores the newest checkpoint.
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.checkpoint import checkpointer
from repro.configs import get_config
from repro.data.pipeline import DataConfig, SyntheticTokenPipeline, device_put_batch
from repro.launch.inputs import make_rules
from repro.launch.mesh import make_local_mesh, set_mesh
from repro.launch.steps import build_train_step
from repro.models import model as model_mod
from repro.models.config import ShapeConfig
from repro.models.param import init_params
from repro.optim import make_optimizer
from repro.runtime.fault_tolerance import TrainSupervisor


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--small", action="store_true")
    ap.add_argument("--steps", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m_ckpt")
    args = ap.parse_args()

    base = get_config("llama3.2-1b")
    if args.small:
        cfg = base.replace(name="llama-20m", num_layers=4, d_model=256, num_heads=8,
                           num_kv_heads=4, head_dim=32, d_ff=1024, vocab_size=32000)
        batch, seq, steps = 4, 128, args.steps or 200
    else:
        # ~100M-class: 8L x d=512 + 50k vocab (tied) ~ 51M blocks + 26M embed
        cfg = base.replace(name="llama-100m", num_layers=8, d_model=768, num_heads=12,
                           num_kv_heads=4, head_dim=64, d_ff=3072, vocab_size=50304)
        batch, seq, steps = 8, 256, args.steps or 300

    mesh = make_local_mesh(len(jax.devices()), 1)
    shape = ShapeConfig("e2e", seq, batch, "train")
    rules = make_rules(cfg, shape, mesh)
    opt = make_optimizer(cfg.optimizer, lr=1e-3)
    pspecs = model_mod.model_specs(cfg, mesh.shape["model"])
    with set_mesh(mesh):
        state = {"params": init_params(pspecs, jax.random.key(0)),
                 "opt": init_params(opt.init_specs(pspecs), jax.random.key(1))}
    n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(state["params"]))
    print(f"model={cfg.name} params={n_params/1e6:.1f}M steps={steps} "
          f"batch={batch} seq={seq}")

    start, state = checkpointer.restore_latest(args.ckpt_dir, state)
    start = start or 0
    if start:
        print(f"resuming from checkpoint at step {start}")

    pipe = SyntheticTokenPipeline(cfg, DataConfig(batch, seq))
    jit_step = jax.jit(build_train_step(cfg, mesh, rules, opt))

    def step_fn(st, b):
        with set_mesh(mesh):
            st, m = jit_step(st, b)
        return st, {k: float(v) for k, v in m.items()}

    sup = TrainSupervisor(step_fn, pipe, args.ckpt_dir, ckpt_interval=50)
    state, last = sup.run(state, steps, start_step=start,
                          place_batch=lambda b: device_put_batch(b, mesh, rules))
    losses = [h["loss"] for h in sup.history]
    print(f"finished at step {last}: loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"(stragglers flagged: {len(sup.straggler.flagged_steps)})")
    assert losses[-1] < losses[0], "loss must decrease"


if __name__ == "__main__":
    main()
