"""Quickstart: the three layers of the framework in one minute on CPU.

  1. train a reduced llama config for a few steps (data -> step -> checkpoint);
  2. serve it (prefill + decode engine);
  3. run the POLCA power plane: characterize the model's phases, then
     oversubscribe a simulated row by +30% under Algorithm 1.

  PYTHONPATH=src python examples/quickstart.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_config
from repro.core.power_model import A100, ServerPower
from repro.core.workload import request_timing
from repro.data.pipeline import DataConfig, SyntheticTokenPipeline, device_put_batch
from repro.experiments import get_scenario, run_experiment
from repro.launch.inputs import make_rules
from repro.launch.mesh import make_local_mesh, set_mesh
from repro.launch.serve import ServeEngine
from repro.launch.steps import build_train_step
from repro.models import model as model_mod
from repro.models.config import ShapeConfig
from repro.models.param import init_params
from repro.optim import make_optimizer

# ---------------------------------------------------------------- 1. train
cfg = smoke_config("llama3.2-1b")
mesh = make_local_mesh(1, 1)
shape = ShapeConfig("quickstart", 64, 4, "train")
rules = make_rules(cfg, shape, mesh)
opt = make_optimizer(cfg.optimizer)
pspecs = model_mod.model_specs(cfg, 1)
with set_mesh(mesh):
    state = {"params": init_params(pspecs, jax.random.key(0)),
             "opt": init_params(opt.init_specs(pspecs), jax.random.key(1))}
pipe = SyntheticTokenPipeline(cfg, DataConfig(4, 64))
step = jax.jit(build_train_step(cfg, mesh, rules, opt))
losses = []
with set_mesh(mesh):
    for i in range(10):
        state, metrics = step(state, device_put_batch(pipe.batch_at(i), mesh, rules))
        losses.append(float(metrics["loss"]))
print(f"[train] loss {losses[0]:.3f} -> {losses[-1]:.3f} over {len(losses)} steps")
assert losses[-1] < losses[0]

# ---------------------------------------------------------------- 2. serve
eng = ServeEngine(cfg, mesh, max_len=48, batch=2)
prompts = np.arange(2 * 32, dtype=np.int32).reshape(2, 32) % cfg.vocab_size
out = eng.generate(prompts, 8)
print(f"[serve] generated tokens: {out[0].tolist()}")

# ---------------------------------------------------------------- 3. POLCA
server = ServerPower(A100)
t = request_timing(get_config("llama3.2-1b"), 2048, 8, server)
print(f"[power] llama3.2-1b x8batch: prompt {t.prefill_point.power_at(server,1.0):.0f}W "
      f"(compute-bound) | token {t.token_point.power_at(server,1.0):.0f}W (memory-bound)")

o = run_experiment(get_scenario("quickstart-plus30"))
s = o.stats.summary()
print(f"[polca] +30% servers: meets_SLO={o.meets} powerbrakes={o.result.n_brakes} "
      f"HP_p99={s['hp_p99']:.2%} LP_p99={s['lp_p99']:.2%} "
      f"peak_power={o.result.peak_power_frac:.1%} of provisioned")
print("OK")
