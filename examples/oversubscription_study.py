"""POLCA capacity study: how many servers can a fixed power budget host?

Sweeps added-server fractions under Algorithm 1 on a production-style trace
via the declarative Scenario API, prints the Fig-13-style frontier, a
multi-row cluster composition, and the phase-aware (beyond-paper) extension.

  PYTHONPATH=src python examples/oversubscription_study.py [--hours 6]
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import get_config
from repro.core.phase_aware import sweep
from repro.core.power_model import A100, ServerPower
from repro.core.workload import request_timing
from repro.experiments import FleetSpec, PolicySpec, Scenario, run_experiment


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--hours", type=float, default=6.0)
    ap.add_argument("--provisioned", type=int, default=40)
    ap.add_argument("--cluster-rows", type=int, default=0,
                    help="also run an N-row cluster at +30% (0 = skip)")
    args = ap.parse_args()
    dur = args.hours * 3600.0
    server = ServerPower(A100)

    print(f"row: {args.provisioned} provisioned DGX-class servers, "
          f"{args.provisioned * server.provisioned_w / 1e3:.0f} kW budget")
    print(f"{'added':>7} {'policy':>8} {'peak':>6} {'brakes':>6} {'HP p99':>8} "
          f"{'LP p99':>8} {'SLO':>5}")
    for add in [0.0, 0.20, 0.30, 0.40]:
        for kind in ["no-cap", "polca"]:
            sc = Scenario(
                name=f"study-{kind}-{add:.0%}",
                duration_s=dur,
                fleet=FleetSpec(n_provisioned=args.provisioned, added_frac=add),
                policy=PolicySpec(kind),
            )
            o = run_experiment(sc)
            s = o.stats.summary()
            print(f"{add:>6.0%} {kind:>8} {o.result.peak_power_frac:>6.2f} "
                  f"{o.result.n_brakes:>6} {s['hp_p99']:>8.2%} {s['lp_p99']:>8.2%} "
                  f"{'yes' if o.meets else 'NO':>5}")

    if args.cluster_rows:
        sc = Scenario(
            name="study-cluster",
            duration_s=dur,
            fleet=FleetSpec(n_provisioned=args.provisioned, added_frac=0.30,
                            n_rows=args.cluster_rows, rows_per_rack=2),
            policy=PolicySpec("polca"),
            compare_to_reference=False,
        )
        o = run_experiment(sc)
        c = o.cluster
        print(f"\ncluster: {c.n_rows} rows x {sc.fleet.n_servers} servers "
              f"(+30% each) -> peak {c.peak_cluster_frac:.1%} of cluster budget, "
              f"{c.n_brakes} brakes, 40s spike {c.spike(40.0):.3f}")

    print("\nbeyond-paper: phase-aware token-phase down-clock (zero TTFT impact)")
    timing = request_timing(get_config("bloom-176b"), 2048, 1, server)
    for o in sweep(timing, server, 1000, [1350 / 1410, 1275 / 1410, 1110 / 1410]):
        print(f"  f_token={o.f_token:.3f}: peak_power -{o.peak_power_saving:.1%}, "
              f"token latency +{o.token_latency_impact:.1%}")


if __name__ == "__main__":
    main()
