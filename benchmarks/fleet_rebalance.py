"""Dynamic fleet power-rebalancing: controller-policy sweep on the
derated-row cluster (DESIGN.md §11).

Validates the fleet controller's three claims:
  * at the stressed load point where static per-row budgets powerbrake the
    derated row and blow the Table-5 HP SLO even under cap-aware routing,
    predictive rebalancing (budget follows the 40 s OOB-horizon forecast)
    meets the HP SLOs with zero powerbrakes — the headline: the
    oversubscription headroom was there all along, stranded on the derated
    row's rack partner;
  * a static-ControllerSpec fleet is bit-identical to a controller-less
    (PR 3) fleet — the controller is a safe default-off feature;
  * rebalancing removes brake risk across seeded traffic realizations
    (Monte-Carlo ensemble), and — in full mode — ``plan_capacity`` over
    controller-bearing fleets quantifies the safe oversubscription bought
    back versus static budgets.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import Bench, module_main, seeded
from repro.experiments import get_scenario, run_experiment
from repro.experiments.runner import build_workloads, resolve_budget
from repro.provisioning import (
    RiskConstraints,
    plan_controller_comparison,
    run_ensemble_grid,
)

HP_P50_SLO = 0.01  # Table 5
HP_P99_SLO = 0.05


def run(quick: bool = False) -> Bench:
    b = Bench()
    dur = 3 * 3600.0 if quick else None  # registered: 6 h
    base = seeded(get_scenario("fleet-rebalance-static"))
    if dur is not None:
        base = base.with_(duration_s=dur)
    wls, shares = build_workloads(base)
    budget = resolve_budget(base, wls, shares, base.fleet.server())
    base = base.with_(budget=budget)  # calibrate once, share across variants

    variants = ["static", "proportional", "predictive", "forecast-router"]
    summaries = {}
    for kind in variants:
        sc = seeded(get_scenario(f"fleet-rebalance-{kind}")).with_(
            duration_s=base.duration_s, budget=budget)
        t0 = time.perf_counter()
        o = run_experiment(sc)
        us = (time.perf_counter() - t0) * 1e6
        s = o.stats.summary()
        summaries[kind] = (s, o)
        f = o.fleet
        b.add(f"rebalance/{kind}",
              f"hp_p99={s['hp_p99']:.1%} lp_p99={s['lp_p99']:.1%} "
              f"brakes={o.result.n_brakes} rebalances={f.n_rebalances} "
              f"moved={f.budget_moved_w() / 1e3:.0f}kW", us, None)

    # ---- headline: predictive rebalancing recovers the HP SLO gap ----------
    st, st_o = summaries["static"]
    pr, pr_o = summaries["predictive"]
    static_violates = (st["hp_p99"] >= HP_P99_SLO or st_o.result.n_brakes > 0)
    predictive_meets = (pr["hp_p50"] < HP_P50_SLO and pr["hp_p99"] < HP_P99_SLO
                        and pr_o.result.n_brakes == 0)
    b.add("rebalance/predictive_recovers_hp_slo",
          f"static hp_p99={st['hp_p99']:.1%} brakes={st_o.result.n_brakes} "
          f"({'violated' if static_violates else 'met'}); predictive "
          f"hp_p99={pr['hp_p99']:.2%} brakes={pr_o.result.n_brakes} "
          f"({'met' if predictive_meets else 'violated'})",
          0.0, static_violates and predictive_meets)

    # the derated row's budget actually grew (slack moved toward demand)
    fb = pr_o.fleet.row_budget_w
    derated = int(np.argmin(fb[0]))
    uplift = float(fb[:, derated].max() / fb[0, derated] - 1.0)
    b.add("rebalance/derated_row_uplift",
          f"row {derated} budget peak uplift {uplift:.1%} "
          f"(from {fb[0, derated] / 1e3:.1f}kW)", 0.0, uplift > 0.0)

    # ---- static ControllerSpec == controller-less fleet, bit for bit -------
    par_sc = base.with_(duration_s=min(base.duration_s, 1800.0),
                        compare_to_reference=False)
    with_ctl = run_experiment(par_sc)
    without = run_experiment(par_sc.with_(controller=None))
    fa, fo = with_ctl.fleet, without.fleet
    bit = (with_ctl.result.latencies == without.result.latencies
           and np.array_equal(fa.cluster_power_frac, fo.cluster_power_frac)
           and np.array_equal(fa.row_power_frac, fo.row_power_frac)
           and fa.decisions == fo.decisions
           and fa.n_rebalances == 0)
    b.add("rebalance/static_bit_parity",
          f"static-controller fleet == PR3 controller-less fleet: {bit}",
          0.0, bit)

    # ---- ensemble: rebalancing removes brake risk across realizations ------
    n_seeds = 2 if quick else 4
    ens_dur = 1800.0 if quick else 3600.0
    bases = [base.with_(duration_s=ens_dur, compare_to_reference=False),
             seeded(get_scenario("fleet-rebalance-predictive")).with_(
                 duration_s=ens_dur, budget=budget,
                 compare_to_reference=False)]
    t0 = time.perf_counter()
    grid = run_ensemble_grid(bases, n_seeds=n_seeds, seed0=1000,
                             budget_w=budget)
    us = (time.perf_counter() - t0) * 1e6
    bp_static = grid[bases[0].name].brake_prob()
    bp_pred = grid[bases[1].name].brake_prob()
    b.add("rebalance/ensemble_brake_risk",
          f"P[>=1 brake] over {n_seeds} seeds: static={bp_static:.2f} "
          f"predictive={bp_pred:.2f}", us, bp_pred < bp_static)

    # ---- full mode: how much oversubscription rebalancing buys back --------
    if not quick:
        plan_base = base.with_(duration_s=3600.0)
        t0 = time.perf_counter()
        plans = plan_controller_comparison(
            plan_base, ("static", "predictive"),
            constraints=RiskConstraints(),
            n_seeds=2, seed0=1000, max_added_frac=0.30, budget_w=budget)
        us = (time.perf_counter() - t0) * 1e6
        st_plan, pr_plan = plans["static"], plans["predictive"]
        b.add("rebalance/planner_buyback",
              f"safe added servers under the same envelope: "
              f"static={st_plan.safe_added_servers} "
              f"({st_plan.safe_added_frac:.1%}) "
              f"predictive={pr_plan.safe_added_servers} "
              f"({pr_plan.safe_added_frac:.1%})", us,
              pr_plan.safe_added_servers >= st_plan.safe_added_servers)
    return b


if __name__ == "__main__":
    module_main(run)
