"""Site-scale hierarchical power rebalancing (DESIGN.md §12).

Validates the :class:`~repro.core.hierarchy.PowerHierarchy` layer's three
claims on the registered ``site-*`` scenarios — a 12-row site (2 PDU sets x
2 racks x 3 rows) whose second rack sits on a 30%-derated, planner-shaped
PDU budget:

  * **hierarchical rebalancing buys back site-level headroom that flat
    budgets strand** — static budgets powerbrake the derated rack and blow
    the Table-5 HP SLO; *rack-scope* rebalancing cannot help (all three
    siblings inside the derated rack are equally starved — the slack lives
    on the *sibling rack and the other PDU set*, unreachable from a flat
    per-rack scope); tree-scope predictive rebalancing, re-dividing the
    site envelope recursively across PDU sets -> racks -> rows, meets the
    HP SLOs with zero powerbrakes on the same trace and envelope;
  * **conservation is per-node**: on every applied rebalance and every
    telemetry tick, each interior node's budget equals the sum of its
    children's, and the site (root) envelope never moves;
  * **the refactor is invisible to two-level scenarios**: an existing
    ``fleet-*`` scenario run through an explicit two-level
    :class:`~repro.experiments.scenario.HierarchySpec` is bit-identical
    (latencies, decisions, power fractions) to the default rack-split path
    — the same parity the tier-1 suite asserts for the pre-refactor code.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import Bench, module_main, seeded
from repro.experiments import (
    SITE_SCENARIO_FAMILY,
    HierarchySpec,
    get_scenario,
    run_experiment,
)
from repro.experiments.runner import build_workloads, resolve_budget

HP_P50_SLO = 0.01  # Table 5
HP_P99_SLO = 0.05


def _node_conservation_ok(hierarchy, node_budget_w: np.ndarray,
                          atol: float = 1e-3) -> bool:
    """Every interior node's per-tick budget equals its children's sum."""
    for i in range(hierarchy.n_leaves, hierarchy.n_nodes):
        kids = hierarchy.children[i]
        if not np.allclose(node_budget_w[:, kids].sum(axis=1),
                           node_budget_w[:, i], atol=atol):
            return False
    return True


def run(quick: bool = False) -> Bench:
    b = Bench()
    dur = 2 * 3600.0 if quick else None  # registered: 6 h
    base = seeded(get_scenario("site-static"))
    if dur is not None:
        base = base.with_(duration_s=dur)
    wls, shares = build_workloads(base)
    budget = resolve_budget(base, wls, shares, base.fleet.server())
    hierarchy = base.hierarchy.build(np.ones(base.fleet.n_rows))

    summaries = {}
    for name in SITE_SCENARIO_FAMILY:
        sc = seeded(get_scenario(name)).with_(duration_s=base.duration_s,
                                              budget=budget)
        t0 = time.perf_counter()
        o = run_experiment(sc)
        us = (time.perf_counter() - t0) * 1e6
        kind = name.removeprefix("site-")
        summaries[kind] = o
        s = o.stats.summary()
        f = o.fleet
        b.add(f"site/{kind}",
              f"hp_p99={s['hp_p99']:.1%} lp_p99={s['lp_p99']:.1%} "
              f"brakes={o.result.n_brakes} rebalances={f.n_rebalances} "
              f"moved={f.budget_moved_w() / 1e3:.0f}kW", us, None)

    # ---- headline: only the recursive (tree) scope recovers the site ------
    st = summaries["static"]
    rk = summaries["rack-predictive"]
    tr = summaries["tree-predictive"]
    st_s, rk_s, tr_s = (o.stats.summary() for o in (st, rk, tr))
    static_violates = (st_s["hp_p99"] >= HP_P99_SLO or st.result.n_brakes > 0)
    rack_violates = (rk_s["hp_p99"] >= HP_P99_SLO or rk.result.n_brakes > 0)
    tree_meets = (tr_s["hp_p50"] < HP_P50_SLO and tr_s["hp_p99"] < HP_P99_SLO
                  and tr.result.n_brakes == 0)
    b.add("site/tree_recovers_site_slo",
          f"static hp_p99={st_s['hp_p99']:.1%}/{st.result.n_brakes} brakes, "
          f"tree hp_p99={tr_s['hp_p99']:.2%}/{tr.result.n_brakes} brakes "
          f"on the same trace + site envelope",
          0.0, static_violates and tree_meets)
    b.add("site/rack_scope_strands_headroom",
          f"rack-scope rebalancing hp_p99={rk_s['hp_p99']:.1%} "
          f"brakes={rk.result.n_brakes} (cannot reach the sibling rack's "
          f"slack); tree-scope brakes={tr.result.n_brakes}",
          0.0, rack_violates and tree_meets)

    # the derated rack's *interior node* budget actually grew: budget moved
    # across racks, not just across rows inside one
    names = list(tr.fleet.node_names)
    derated = names.index("rack0.1")
    col = tr.fleet.node_budget_w[:, derated]
    uplift = float(col.max() / col[0] - 1.0)
    b.add("site/derated_rack_uplift",
          f"rack0.1 budget peak uplift {uplift:.1%} "
          f"(from {col[0] / 1e3:.0f}kW; an interior-node rebalance)",
          0.0, uplift > 0.0)

    # ---- per-node conservation, every rebalance + every tick --------------
    ok = tr.fleet.n_rebalances > 0
    for ev in tr.fleet.rebalances:
        na = ev.node_budgets_after_w
        ok = ok and na is not None
        if na is None:
            continue
        for i in range(hierarchy.n_leaves, hierarchy.n_nodes):
            kids = hierarchy.children[i]
            ok = ok and abs(float(na[kids].sum()) - float(na[i])) <= 1e-3
        ok = ok and float(na[hierarchy.root]) == float(
            ev.node_budgets_before_w[hierarchy.root])
    ok = ok and _node_conservation_ok(hierarchy, tr.fleet.node_budget_w)
    root_col = tr.fleet.node_budget_w[:, hierarchy.root]
    ok = ok and np.allclose(root_col, root_col[0], atol=1e-6)
    b.add("site/per_node_conservation",
          f"{tr.fleet.n_rebalances} rebalances x "
          f"{hierarchy.n_nodes - hierarchy.n_leaves} interior nodes: "
          f"children sums == node budgets; root envelope frozen at "
          f"{root_col[0] / 1e3:.0f}kW", 0.0, ok)

    # ---- two-level scenarios are bit-identical through the new path -------
    par = seeded(get_scenario("fleet-cap-aware")).with_(
        duration_s=min(base.duration_s, 1800.0), compare_to_reference=False)
    a = run_experiment(par)
    spec = HierarchySpec(shape=(3, 2), level_names=("cluster", "rack"))
    c = run_experiment(par.with_(hierarchy=spec))
    bit = (a.result.latencies == c.result.latencies
           and a.fleet.decisions == c.fleet.decisions
           and np.array_equal(a.fleet.cluster_power_frac,
                              c.fleet.cluster_power_frac)
           and np.array_equal(a.fleet.row_power_frac, c.fleet.row_power_frac)
           and np.array_equal(a.fleet.rack_power_frac,
                              c.fleet.rack_power_frac))
    b.add("site/two_level_bit_parity",
          f"fleet-cap-aware via explicit two-level HierarchySpec == default "
          f"rack split: {bit}", 0.0, bit)
    return b


if __name__ == "__main__":
    module_main(run)
