"""Table 2 (inference column): production-like cluster power statistics from
a 1-week simulated baseline row — peak utilization, short-window spikes,
diurnal pattern."""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import Bench, WEEK, module_main, seeded
from repro.experiments import get_scenario, run_experiment


def run(quick: bool = False) -> Bench:
    b = Bench()
    sc = seeded(get_scenario("table2-baseline")).with_(
        duration_s=WEEK / 7 if quick else WEEK)
    t0 = time.perf_counter()
    res = run_experiment(sc).result
    us = (time.perf_counter() - t0) * 1e6

    s2, s5, s40 = res.spike(2.0), res.spike(5.0), res.spike(40.0)
    # diurnal: correlation of the power series with a 24h sinusoid
    t = res.power_t
    w = res.power_w
    ref = np.sin(2 * np.pi * (t / 86400.0 - 0.375))
    diurnal_corr = float(np.corrcoef(w - w.mean(), ref)[0, 1])

    ok_peak = 0.65 <= res.peak_power_frac <= 0.88  # paper: 79% (see EXPERIMENTS §calibration)
    ok_spikes = s2 <= 0.12 and s40 <= 0.16  # paper: 9% / 11.8%
    b.add("table2/inference/peak_util",
          f"{res.peak_power_frac:.3f} (paper 0.79)", us, ok_peak)
    b.add("table2/inference/spikes",
          f"2s={s2:.3f} 5s={s5:.3f} 40s={s40:.3f} (paper .09/.091/.118)",
          0.0, ok_spikes)
    b.add("table2/inference/diurnal",
          f"corr_with_24h_sine={diurnal_corr:.2f} mean_util={res.mean_power_frac:.3f}",
          0.0, diurnal_corr > 0.5)
    b.add("table2/inference/headroom",
          f"headroom={1-res.peak_power_frac:.3f} -> oversubscription candidate",
          0.0, res.peak_power_frac < 0.9)
    return b


if __name__ == "__main__":
    module_main(run)
