"""Fig. 13: T1/T2 threshold-space search on a 1-week trace — added servers vs
SLO compliance and powerbrake avoidance. Selects T1=80/T2=89 and +30%."""

from __future__ import annotations

import time

from benchmarks.common import Bench, WEEK, module_main, seeded
from repro.experiments import get_scenario, threshold_search

COMBOS = [(0.75, 0.85), (0.80, 0.89), (0.85, 0.95)]


def run(quick: bool = False) -> Bench:
    b = Bench()
    # policy exploration on a shorter slice
    base = seeded(get_scenario("fig13-search-base")).with_(
        duration_s=WEEK / 14 if quick else WEEK / 2)
    grid = [0.20, 0.30] if quick else [0.20, 0.25, 0.30, 0.325, 0.35, 0.40]
    t0 = time.perf_counter()
    out = threshold_search(base, COMBOS, grid)
    us = (time.perf_counter() - t0) * 1e6
    for (t1, t2), r in out.items():
        b.add(f"fig13/T{t1*100:.0f}-{t2*100:.0f}",
              f"max_added_no_brake={r['max_added_no_brake']:.1%} "
              f"max_added_slo={r['max_added_slo']:.1%}",
              us if (t1, t2) == COMBOS[0] else 0.0, None)

    sel = out[(0.80, 0.89)]
    ok = sel["max_added_slo"] >= 0.30 and sel["max_added_no_brake"] >= 0.30
    b.add("fig13/selected/T80-89@+30%",
          f"meets_SLO_and_no_brake_at_+30%: {ok} (paper: yes)", 0.0, ok)
    # 85-95 should be weaker on brake-avoidance or not better than 80-89
    weaker = out[(0.85, 0.95)]["max_added_no_brake"] <= sel["max_added_no_brake"] + 0.051
    b.add("fig13/T85-95_riskier", f"{weaker} (paper: only 32.5%)", 0.0, weaker)
    return b


if __name__ == "__main__":
    module_main(run)
