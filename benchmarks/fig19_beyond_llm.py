"""Fig. 19 (§7): power-oversubscription insights beyond text LLMs — vision and
audio/multimodal models (our assigned internvl2-1b VLM + whisper-base) show
flatter phase contrast but the same superlinear frequency-scaling response."""

from __future__ import annotations

import time

from benchmarks.common import Bench, SERVER
from repro.configs import get_config
from repro.core.workload import request_timing

TDP = SERVER.device.tdp_w


def run(quick: bool = False) -> Bench:
    b = Bench()
    llm = request_timing(get_config("bloom-176b"), 2048, 1, SERVER)
    llm_contrast = (llm.prefill_point.power_at(SERVER, 1.0)
                    / llm.token_point.power_at(SERVER, 1.0))
    for name, prompt, batch in [("internvl2-1b", 1024, 8), ("whisper-base", 3000, 8)]:
        cfg = get_config(name)
        t0 = time.perf_counter()
        t = request_timing(cfg, prompt, batch, SERVER)
        us = (time.perf_counter() - t0) * 1e6
        contrast = (t.prefill_point.power_at(SERVER, 1.0)
                    / t.token_point.power_at(SERVER, 1.0))
        f = 1275 / 1410
        p_red = 1 - t.prefill_point.power_at(SERVER, f) / t.prefill_point.power_at(SERVER, 1.0)
        perf = t.latency(64, SERVER.device, f, f) / t.latency(64, SERVER.device) - 1
        ok = p_red > perf  # superlinear response transfers (contrast informational)
        b.add(f"fig19/{name}",
              f"phase_contrast={contrast:.2f} (LLM {llm_contrast:.2f}) "
              f"freq_cap: dP={p_red:.1%} dT={perf:.1%} superlinear={p_red > perf}",
              us, ok)
    return b


if __name__ == "__main__":
    for r in run().rows:
        print(r.csv())
