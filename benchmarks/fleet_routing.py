"""Fleet routing: load x routing-policy sweep under an oversubscribed
cluster with one derated row (DESIGN.md §10).

Validates the fleet layer's three claims:
  * at the stressed load/envelope point, cap-state-aware routing meets the
    Table-5 HP SLOs (p50 < 1%, p99 < 5% latency impact) where round-robin
    violates them — and with far fewer powerbrakes than any power-blind
    router (zero at the registered operating point);
  * a single-row fleet reproduces the standalone ``RowSimulator`` result
    bit-for-bit (the request-injection hook preserves event order);
  * priority-aware admission control conserves requests exactly
    (admitted + shed == offered) and sheds LP only.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import Bench, module_main, seeded
from repro.experiments import get_scenario, run_experiment
from repro.experiments.runner import build_workloads, resolve_budget
from repro.experiments.scenario import RoutingSpec, TrafficSpec

HP_P50_SLO = 0.01  # Table 5
HP_P99_SLO = 0.05


def _loads(base, quick: bool):
    """(label, scenario) load points sharing the base-calibrated budget."""
    wls, shares = build_workloads(base)
    budget = resolve_budget(base, wls, shares, base.fleet.server())
    stressed = base.with_(budget=budget)
    points = [("design", stressed)]
    if not quick:
        light = stressed.with_(traffic=TrafficSpec(
            occ_peak=0.50, gen_params={"trough": 0.43}))
        points.insert(0, ("light", light))
    return points


def run(quick: bool = False) -> Bench:
    b = Bench()
    dur = 3 * 3600.0 if quick else None  # registered: 6 h
    base = seeded(get_scenario("fleet-round-robin"))
    if dur is not None:
        base = base.with_(duration_s=dur)
    routers = (["round-robin", "jsq", "cap-aware"] if quick else
               ["round-robin", "jsq", "power-headroom", "cap-aware"])

    summaries = {}
    for load, sc_load in _loads(base, quick):
        for router in routers:
            sc = sc_load.with_routing(router)
            t0 = time.perf_counter()
            o = run_experiment(sc)
            us = (time.perf_counter() - t0) * 1e6
            s = o.stats.summary()
            summaries[(load, router)] = (s, o)
            b.add(f"fleet/{load}/{router}",
                  f"hp_p99={s['hp_p99']:.1%} lp_p99={s['lp_p99']:.1%} "
                  f"brakes={o.result.n_brakes} "
                  f"shed={o.fleet.n_shed_total}", us, None)

    # ---- headline: cap-aware recovers the HP SLO where RR violates it ------
    rr, _ = summaries[("design", "round-robin")]
    cap, cap_o = summaries[("design", "cap-aware")]
    rr_violates = rr["hp_p99"] >= HP_P99_SLO
    cap_meets = cap["hp_p50"] < HP_P50_SLO and cap["hp_p99"] < HP_P99_SLO
    b.add("fleet/cap_aware_recovers_hp_slo",
          f"round-robin hp_p99={rr['hp_p99']:.1%} (SLO 5%: "
          f"{'violated' if rr_violates else 'met'}); cap-aware "
          f"hp_p50={cap['hp_p50']:.2%} hp_p99={cap['hp_p99']:.1%} "
          f"({'met' if cap_meets else 'violated'})",
          0.0, rr_violates and cap_meets)
    rr_brakes = summaries[("design", "round-robin")][1].result.n_brakes
    b.add("fleet/cap_aware_brake_reduction",
          f"powerbrakes at design load: round-robin={rr_brakes} "
          f"cap-aware={cap_o.result.n_brakes}",
          0.0, cap_o.result.n_brakes < rr_brakes)

    # ---- single-row fleet == standalone RowSimulator, bit for bit ----------
    solo_sc = seeded(get_scenario("fig14-plus30")).with_(duration_s=3600.0)
    solo = run_experiment(solo_sc)
    one = run_experiment(solo_sc.with_(routing=RoutingSpec("round-robin")))
    fr, sr = one.fleet.row_results[0], solo.result
    bit = (fr.latencies == sr.latencies
           and np.array_equal(fr.power_w, sr.power_w)
           and (fr.n_brakes, fr.cap_events) == (sr.n_brakes, sr.cap_events)
           and one.stats.summary() == solo.stats.summary())
    b.add("fleet/single_row_bit_parity",
          f"1-row fleet == standalone RowSimulator: {bit}", 0.0, bit)

    # ---- admission control: conservation + LP-only shedding ----------------
    shed_sc = seeded(get_scenario("fleet-rr-shed"))
    if dur is not None:
        shed_sc = shed_sc.with_(duration_s=dur)
    o = run_experiment(shed_sc.with_(compare_to_reference=False))
    f = o.fleet
    conserved = (f.n_admitted + f.n_shed_total == f.n_offered
                 and f.n_shed.get("high", 0) == 0
                 and f.n_shed.get("low", 0) > 0)
    b.add("fleet/admission_conservation",
          f"offered={f.n_offered} admitted={f.n_admitted} "
          f"shed_lp={f.n_shed.get('low', 0)} shed_hp={f.n_shed.get('high', 0)} "
          f"(admitted + shed == offered; LP only)",
          0.0, conserved)
    return b


if __name__ == "__main__":
    module_main(run)
