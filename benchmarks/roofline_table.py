"""§Roofline table generator: reads the dry-run JSONL (launch/dryrun.py --out)
and emits one row per (arch x shape x mesh) with the three terms, bottleneck,
and mfu bound. Skips gracefully when the dry-run hasn't been executed."""

from __future__ import annotations

import json
import os

from benchmarks.common import Bench

RESULTS = os.environ.get("DRYRUN_RESULTS",
                         os.path.join(os.path.dirname(__file__), "..", "results",
                                      "dryrun.jsonl"))


def load_records(path=RESULTS):
    if not os.path.exists(path):
        return []
    recs = {}
    with open(path) as f:
        for line in f:
            try:
                r = json.loads(line)
            except json.JSONDecodeError:
                continue
            recs[(r["arch"], r["shape"], r["mesh"])] = r  # last write wins
    return list(recs.values())


def run(quick: bool = False) -> Bench:
    b = Bench()
    recs = load_records()
    if not recs:
        b.add("roofline/NO_DRYRUN_RESULTS",
              f"run `python -m repro.launch.dryrun --arch all --out {RESULTS}`", 0.0)
        return b
    n_ok = n_skip = n_err = 0
    for r in sorted(recs, key=lambda x: (x["mesh"], x["arch"], x["shape"])):
        key = f"roofline/{r['mesh']}/{r['arch']}/{r['shape']}"
        if r["status"] == "skipped":
            n_skip += 1
            b.add(key, f"SKIPPED: {r['reason'][:80]}", 0.0)
            continue
        if r["status"] != "ok":
            n_err += 1
            b.add(key, "ERROR", 0.0, False)
            continue
        n_ok += 1
        ro = r["roofline"]
        terms = {"c": ro["t_compute_s"], "m": ro["t_memory_s"], "x": ro["t_collective_s"]}
        bound = max(terms.values()) or 1.0
        ideal = terms["m"] if r["shape"].startswith(("decode", "long")) else terms["c"]
        ro["roofline_fraction"] = ideal / bound  # recompute (older records lack it)
        b.add(key,
              f"C={ro['t_compute_s']*1e3:.2f}ms M={ro['t_memory_s']*1e3:.2f}ms "
              f"X={ro['t_collective_s']*1e3:.2f}ms bound={ro['bottleneck']} "
              f"roofline_frac={ro.get('roofline_fraction', 0):.3f} "
              f"mfu={ro['mfu_bound']:.3f} fits={r['fits_hbm']} "
              f"{r['bytes_per_device']/2**30:.1f}GiB/dev",
              (r.get("compile_s", 0) + r.get("compile_unrolled_s", 0)) * 1e6,
              None)  # informational: baseline fits issues are §Perf material
    b.add("roofline/summary", f"ok={n_ok} skipped={n_skip} errors={n_err}", 0.0,
          n_err == 0)
    return b


if __name__ == "__main__":
    for r in run().rows:
        print(r.csv())
