"""Online alerting guarantees: detection latency, zero false alarms,
incident reconciliation, zero perturbation (DESIGN.md §15).

Validates the hard claims the ``repro.obs`` online half (streaming windows
+ :class:`~repro.obs.alerts.AlertEngine`) ships under, on the registered
``chaos-*`` family and its default alert pack:

  * **a PDU loss is detected within one telemetry tick of the derate
    landing** — a step derate on ``pdu0`` crosses the cap-proximity rule on
    the very next tick; a ramped derate is caught no later than its apply
    record (the fraction crosses the engage threshold as the ramp tops
    out);
  * **zero false alarms on a healthy site** — ``chaos-noop`` (same traffic,
    no faults) produces no alert engages over the full run;
  * **incident reconstruction reconciles 1:1 with the fault audit log** —
    folding the event trace back into incidents recovers exactly the
    ``FleetResult.fault_events`` windows, with the engage times the engine
    actually emitted and no unattributed engages;
  * **alerting observes, never perturbs** — alerts-on and alerts-off runs
    are bit-identical (latencies, power series, routing decisions, budget
    trajectories), and the instrumented run costs <= 5% wall clock.
"""

from __future__ import annotations

import gc
import time

import numpy as np

from benchmarks.common import Bench, module_main, seeded
from repro.chaos import FaultEvent, FaultSpec
from repro.experiments import get_scenario, run_experiment
from repro.obs import alerts as obs_alerts
from repro.obs.incidents import reconstruct_incidents
from repro.obs.metrics import MetricsRecorder, recording


def _first_detection(alert_events):
    """Earliest telemetry-driven engage (fault-active is ground truth the
    injector hands the engine, not detection)."""
    return min((a.t for a in alert_events
                if a.phase == "engage" and a.kind != "fault-active"),
               default=None)


def run(quick: bool = False) -> Bench:
    b = Bench()
    # the fault window (2400 s -> 4800 s) and the default pack's thresholds
    # are the registered chaos operating point; quick trims the tail, not
    # the window
    dur = 7200.0
    base = seeded(get_scenario("chaos-pdu-loss-tree")).with_(
        duration_s=dur, compare_to_reference=False)
    tick = base.telemetry.telemetry_s

    # ---- step derate: detected on the next telemetry tick ------------------
    step = base.with_faults(FaultSpec((
        FaultEvent("node-derate", t=2400.0, node="pdu0", factor=0.7,
                   until=4800.0, ramp_s=0.0),)))
    t0 = time.perf_counter()
    so = run_experiment(step)
    us = (time.perf_counter() - t0) * 1e6
    sf = so.fleet
    t_apply = min(r.t for r in sf.fault_events if r.phase == "apply")
    det = _first_detection(sf.alert_events)
    lat = None if det is None else det - t_apply
    ok = lat is not None and 0.0 <= lat <= tick + 1e-9
    b.add("alerting/step_detection",
          f"30% pdu0 step at t={t_apply:g}s detected at t="
          f"{det if det is None else f'{det:g}'}s — "
          f"{'-' if lat is None else f'{lat:g}'}s "
          f"<= 1 telemetry tick ({tick:g}s); "
          f"{sf.n_alert_events} alert transitions", us, ok)

    # ---- ramped derate: caught no later than the apply record --------------
    # (recorded run — the same trace feeds the incident-reconcile row)
    rec = MetricsRecorder()
    t0 = time.perf_counter()
    with recording(rec):
        ro = run_experiment(base)
    us = (time.perf_counter() - t0) * 1e6
    rf = ro.fleet
    r_apply = min(r.t for r in rf.fault_events if r.phase == "apply")
    r_sched = r_apply - base.faults.events[0].ramp_s
    r_det = _first_detection(rf.alert_events)
    r_ok = r_det is not None and r_sched <= r_det <= r_apply + tick + 1e-9
    b.add("alerting/ramp_detection",
          f"ramped derate (sched t={r_sched:g}s, lands t={r_apply:g}s) "
          f"detected at t={r_det if r_det is None else f'{r_det:g}'}s — "
          f"within one tick of landing", us, r_ok)

    # ---- incident reconstruction reconciles with the fault audit log -------
    snap = rec.snapshot()
    rep = reconstruct_incidents(snap.events)
    want = sorted((r.t, r.target) for r in rf.fault_events
                  if r.phase == "apply" and r.kind != "row-revive")
    got = sorted((i.t_apply, i.target) for i in rep.incidents)
    restores = sorted(r.t for r in rf.fault_events if r.phase == "restore")
    got_restores = sorted(i.t_restore for i in rep.incidents
                          if i.t_restore is not None)
    det_match = (rep.incidents
                 and rep.incidents[0].first_detection() is not None
                 and rep.incidents[0].first_detection().t_engage == r_det)
    reconciled = (want == got and restores == got_restores
                  and rep.n_false_alarms == 0 and bool(det_match))
    b.add("alerting/incident_reconcile",
          f"{rep.n_incidents} incident(s) == {len(want)} fault window(s), "
          f"restores match, first detection t="
          f"{r_det if r_det is None else f'{r_det:g}'}s, "
          f"{rep.n_false_alarms} unattributed engages "
          f"({rep.n_events} trace events)", 0.0, reconciled)

    # ---- healthy site: zero false alarms -----------------------------------
    noop = seeded(get_scenario("chaos-noop")).with_(
        compare_to_reference=False)
    if quick:
        noop = noop.with_(duration_s=dur)
    t0 = time.perf_counter()
    no = run_experiment(noop)
    us = (time.perf_counter() - t0) * 1e6
    n_eng = sum(1 for a in no.fleet.alert_events if a.phase == "engage")
    b.add("alerting/noop_false_alarms",
          f"chaos-noop over {noop.duration_s / 3600:g}h under the default "
          f"pack: {n_eng} alert engages (want 0)", us, n_eng == 0)

    # ---- alerts-on == alerts-off, <= 5% overhead ---------------------------
    # the overhead is attributed directly: every AlertEngine.on_tick call
    # inside one alerted run is timed in place, and the gate compares that
    # accumulated engine time against the rest of the run. A/B wall-clock
    # ratios of whole runs measure the machine more than the engine — on a
    # shared host, run-to-run scheduling noise alone swings several times
    # the 5% being gated. Timed with the process recorder detached (under
    # ``--artifacts`` the harness recorder's bookkeeping would inflate
    # both sides with unrelated cost) and a GC pass before each run.
    off_sc = base.with_(alerts=None)
    acc = [0.0, 0]
    orig_on_tick = obs_alerts.AlertEngine.on_tick

    def _timed_on_tick(self, *a, **k):
        t0 = time.perf_counter()
        out = orig_on_tick(self, *a, **k)
        acc[0] += time.perf_counter() - t0
        acc[1] += 1
        return out

    with recording(None):
        gc.collect()
        off = run_experiment(off_sc)
        obs_alerts.AlertEngine.on_tick = _timed_on_tick
        try:
            gc.collect()
            t0 = time.perf_counter()
            on = run_experiment(base)
            wall = time.perf_counter() - t0
        finally:
            obs_alerts.AlertEngine.on_tick = orig_on_tick
    fo, fn = off.fleet, on.fleet
    bit = (off.result.latencies == on.result.latencies
           and np.array_equal(fo.cluster_power_frac, fn.cluster_power_frac)
           and np.array_equal(fo.row_power_frac, fn.row_power_frac)
           and np.array_equal(fo.node_budget_w, fn.node_budget_w)
           and fo.decisions == fn.decisions
           and fo.n_shed == fn.n_shed
           and fo.fault_events == fn.fault_events
           and not fo.alert_events and fn.n_alert_events > 0)
    b.add("alerting/bit_parity",
          f"alerts-on == alerts-off bit-for-bit over the fault run: {bit} "
          f"({fn.n_alert_events} transitions recorded on the on-side)",
          0.0, bit)
    # engine seconds on top of everything else the run did: equivalent to
    # the alerted/bare wall-clock ratio, without differencing two noisy
    # whole-run timings
    ratio = wall / (wall - acc[0])
    b.add("alerting/overhead",
          f"engine {acc[0] * 1e3:.0f}ms over {acc[1]} ticks "
          f"({acc[0] * 1e6 / max(acc[1], 1):.1f}us/tick) of a {wall:.2f}s "
          f"run (x{ratio:.3f})", acc[0] * 1e6, ratio <= 1.05)
    return b


if __name__ == "__main__":
    module_main(run)
