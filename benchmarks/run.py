"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived[,PASS|FAIL]`` CSV rows; rows carrying a
validation flag assert the corresponding paper claim (DESIGN.md §7).

  PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig13,table2]
      [--artifacts out/]

``--only`` takes a comma-separated list of module basenames; a token
selects the modules it names exactly or prefixes at an underscore boundary
(``fig13`` selects ``fig13_threshold_search``; ``fig1`` matches nothing
and errors instead of silently selecting fig13-fig19). ``--artifacts DIR``
records the whole run through the observability stack (``repro.obs``) and
writes a run manifest, Prometheus metrics, the JSONL event trace, and one
``BENCH_<module>.json`` per module — the perf-trajectory artifact pipeline
``tools/report.py`` renders and diffs. The CSV on stdout is byte-identical
either way: recording is write-only.
"""

from __future__ import annotations

import argparse
import importlib
import sys
import time
import traceback
from typing import List, Optional

MODULES = [
    "benchmarks.fig04_phase_timeseries",
    "benchmarks.fig05_config_sweeps",
    "benchmarks.fig06_07_capping",
    "benchmarks.fig08_09_training",
    "benchmarks.table2_cluster_stats",
    "benchmarks.fig13_threshold_search",
    "benchmarks.fig14_15_throughput_sweeps",
    "benchmarks.fig16_six_week",
    "benchmarks.fig17_18_policy_comparison",
    "benchmarks.fig19_beyond_llm",
    "benchmarks.capacity_planning",
    "benchmarks.fleet_routing",
    "benchmarks.fleet_rebalance",
    "benchmarks.site_hierarchy",
    "benchmarks.chaos_resilience",
    "benchmarks.phase_aware_savings",
    "benchmarks.kernel_micro",
    "benchmarks.roofline_table",
    "benchmarks.observability",
    "benchmarks.alerting",
    "benchmarks.batched_engine",
]


def select_modules(only: Optional[str]) -> List[str]:
    """Resolve ``--only`` to a subset of MODULES, original order, deduped.

    Each comma-separated token must match at least one module basename —
    exactly, or as a prefix ending at an underscore boundary — otherwise
    the run aborts naming the known basenames (a typo must not silently
    run the wrong figures)."""
    if not only:
        return list(MODULES)
    basenames = {m.rsplit(".", 1)[-1]: m for m in MODULES}
    chosen = set()
    for token in (t.strip() for t in only.split(",")):
        if not token:
            continue
        matches = [b for b in basenames
                   if b == token or b.startswith(token + "_")]
        if not matches:
            known = ", ".join(sorted(basenames))
            raise SystemExit(
                f"--only: {token!r} matches no benchmark module "
                f"(known: {known})")
        chosen.update(matches)
    return [m for b, m in basenames.items() if b in chosen]


def main() -> None:
    from benchmarks import common

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated module basenames (exact or "
                         "underscore-boundary prefix match)")
    ap.add_argument("--seed", type=int, default=None,
                    help="override every scenario's seed (reproducible runs)")
    ap.add_argument("--artifacts", default=None, metavar="DIR",
                    help="record the run and write manifest + metrics + "
                         "events + BENCH_<module>.json under DIR")
    args = ap.parse_args()
    common.set_seed(args.seed)
    modules = select_modules(args.only)

    rec = None
    if args.artifacts:
        from repro.obs.metrics import MetricsRecorder, set_recorder
        rec = MetricsRecorder()
        set_recorder(rec)

    # progress to stderr via the shared repro logger (REPRO_LOG_LEVEL
    # gates it); stdout stays pure CSV
    from repro.obs.log import get_logger
    log = get_logger("benchmarks.run")

    t0 = time.perf_counter()
    print("name,us_per_call,derived[,validation]")
    n_fail = 0
    for i, modname in enumerate(modules, 1):
        basename = modname.rsplit(".", 1)[-1]
        log.info("[%d/%d] %s ...", i, len(modules), basename)
        t_mod = time.perf_counter()
        try:
            mod = importlib.import_module(modname)
            if rec is not None:
                with rec.span("bench/module", module=basename):
                    bench = mod.run(quick=args.quick)
            else:
                bench = mod.run(quick=args.quick)
            for row in bench.rows:
                print(row.csv())
                if row.ok is False:
                    n_fail += 1
            if args.artifacts:
                common.write_bench_json(args.artifacts, basename, bench.rows)
            mod_fail = sum(1 for r in bench.rows if r.ok is False)
            log.info("[%d/%d] %s: %d rows, %d failing, %.1fs",
                     i, len(modules), basename, len(bench.rows), mod_fail,
                     time.perf_counter() - t_mod)
        except Exception:
            print(f"{modname},0.0,EXCEPTION,FAIL")
            traceback.print_exc()
            n_fail += 1
            log.error("[%d/%d] %s: raised after %.1fs", i, len(modules),
                      basename, time.perf_counter() - t_mod)
            if args.artifacts:
                common.write_bench_json(args.artifacts, basename, None)
        sys.stdout.flush()
    print(f"# validation_failures={n_fail}")
    if rec is not None:
        from repro.obs.export import run_manifest, write_artifacts
        from repro.obs.metrics import set_recorder
        set_recorder(None)
        manifest = run_manifest(seed=common.BENCH_SEED, extra={
            "kind": "benchmarks.run",
            "quick": bool(args.quick),
            "modules": [m.rsplit(".", 1)[-1] for m in modules],
            "validation_failures": n_fail,
            "wall_clock_s": round(time.perf_counter() - t0, 3),
        })
        write_artifacts(args.artifacts, rec.snapshot(), manifest)
    if n_fail:
        sys.exit(1)


if __name__ == "__main__":
    main()
