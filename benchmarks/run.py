"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived[,PASS|FAIL]`` CSV rows; rows carrying a
validation flag assert the corresponding paper claim (DESIGN.md §7).

  PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig13]
"""

from __future__ import annotations

import argparse
import importlib
import sys
import traceback

MODULES = [
    "benchmarks.fig04_phase_timeseries",
    "benchmarks.fig05_config_sweeps",
    "benchmarks.fig06_07_capping",
    "benchmarks.fig08_09_training",
    "benchmarks.table2_cluster_stats",
    "benchmarks.fig13_threshold_search",
    "benchmarks.fig14_15_throughput_sweeps",
    "benchmarks.fig16_six_week",
    "benchmarks.fig17_18_policy_comparison",
    "benchmarks.fig19_beyond_llm",
    "benchmarks.capacity_planning",
    "benchmarks.fleet_routing",
    "benchmarks.fleet_rebalance",
    "benchmarks.site_hierarchy",
    "benchmarks.chaos_resilience",
    "benchmarks.phase_aware_savings",
    "benchmarks.kernel_micro",
    "benchmarks.roofline_table",
]


def main() -> None:
    from benchmarks import common

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument("--seed", type=int, default=None,
                    help="override every scenario's seed (reproducible runs)")
    args = ap.parse_args()
    common.set_seed(args.seed)

    print("name,us_per_call,derived[,validation]")
    n_fail = 0
    for modname in MODULES:
        if args.only and args.only not in modname:
            continue
        try:
            mod = importlib.import_module(modname)
            bench = mod.run(quick=args.quick)
            for row in bench.rows:
                print(row.csv())
                if row.ok is False:
                    n_fail += 1
        except Exception:
            print(f"{modname},0.0,EXCEPTION,FAIL")
            traceback.print_exc()
            n_fail += 1
        sys.stdout.flush()
    print(f"# validation_failures={n_fail}")
    if n_fail:
        sys.exit(1)


if __name__ == "__main__":
    main()
