"""Kernel microbenchmarks: XLA attention path step time on this host (CPU) and
interpret-mode kernel validation timing. Wall numbers are host-dependent; the
derived column carries the correctness deltas vs ref (the portable result)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Bench
from repro.kernels import ops, ref


def _bench(fn, *args, n=3, **kw):
    fn(*args, **kw).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args, **kw)
    out.block_until_ready()
    return out, (time.perf_counter() - t0) / n * 1e6


def run(quick: bool = False) -> Bench:
    b = Bench()
    key = jax.random.key(0)
    B, S, H, KV, hd = 1, 512, 8, 2, 64
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.bfloat16)
    k = jax.random.normal(ks[1], (B, S, KV, hd), jnp.bfloat16)
    v = jax.random.normal(ks[2], (B, S, KV, hd), jnp.bfloat16)

    out, us = _bench(ops.flash_attention, q, k, v, causal=True, interpret=True)
    want = ref.mha_reference(q, k, v, causal=True)
    err = float(np.max(np.abs(np.float32(out) - np.float32(want))))
    b.add("kernel/flash_attention_interpret", f"max_err_vs_ref={err:.1e}", us, err < 3e-2)

    qd = jax.random.normal(ks[0], (4, H, hd), jnp.bfloat16)
    kd = jax.random.normal(ks[1], (4, 2048, KV, hd), jnp.bfloat16)
    vd = jax.random.normal(ks[2], (4, 2048, KV, hd), jnp.bfloat16)
    out, us = _bench(ops.decode_attention, qd, kd, vd, 1500, interpret=True)
    want = ref.decode_attention_reference(qd, kd, vd, 1500)
    err = float(np.max(np.abs(np.float32(out) - np.float32(want))))
    b.add("kernel/decode_attention_interpret", f"max_err_vs_ref={err:.1e}", us, err < 3e-2)
    return b


if __name__ == "__main__":
    for r in run().rows:
        print(r.csv())
