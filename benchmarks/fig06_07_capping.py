"""Fig. 6: power capping (reactive — prompt spikes leak past the cap) vs
frequency capping (proactive — bounds power everywhere, costs perf everywhere).
Fig. 7: peak-power reduction vs performance reduction under frequency scaling
— the superlinearity POLCA exploits."""

from __future__ import annotations

import time

from benchmarks.common import Bench, SERVER
from repro.configs import get_config
from repro.core.workload import request_timing

TDP = SERVER.device.tdp_w
DEV = SERVER.device


def run(quick: bool = False) -> Bench:
    b = Bench()

    # ---- Fig 6: BLOOM (input 8192, output 128, batch 1) --------------------
    cfg = get_config("bloom-176b")
    t = request_timing(cfg, 8192, 1, SERVER)
    p_prompt = t.prefill_point.power_at(SERVER, 1.0)
    p_token = t.token_point.power_at(SERVER, 1.0)
    cap_w = p_token + 0.3 * (p_prompt - p_token)  # a power cap below prompt needs
    # reactive power capping: enforcement lag ~ O(100ms); the <1 s prompt spike
    # largely completes before the cap engages -> spike leaks through
    leak = p_prompt - cap_w
    # frequency capping at f matching the same steady-state power
    f = 0.88
    p_prompt_f = t.prefill_point.power_at(SERVER, f)
    ok6 = leak > 0 and p_prompt_f < p_prompt
    b.add("fig06/bloom/power_cap_reactive",
          f"spike_leak={leak:.0f}W_above_cap (prompt {p_prompt:.0f}W vs cap {cap_w:.0f}W)",
          0.0, leak > 0)
    b.add("fig06/bloom/freq_cap_proactive",
          f"prompt_bounded={p_prompt_f:.0f}W<{p_prompt:.0f}W at f={f:.2f}", 0.0, ok6)

    # ---- Fig 7a: per-model freq sweep ---------------------------------------
    models = ["bloom-176b"] if quick else ["gpt-neox-20b", "opt-30b", "bloom-176b", "flan-t5-xxl"]
    freqs = [1.0, 1305 / 1410, 1275 / 1410, 1110 / 1410]
    superlinear_all = True
    for name in models:
        cfg = get_config(name)
        t0 = time.perf_counter()
        tm = request_timing(cfg, 2048, 1, SERVER)
        pts = []
        for f in freqs[1:]:
            p0 = tm.prefill_point.power_at(SERVER, 1.0)
            pf = tm.prefill_point.power_at(SERVER, f)
            power_red = 1 - pf / p0
            lat0 = tm.latency(512, DEV, 1.0, 1.0)
            latf = tm.latency(512, DEV, f, f)
            perf_red = latf / lat0 - 1
            pts.append((f, power_red, perf_red))
            superlinear_all &= power_red > perf_red
        best = max((p for p in pts if p[2] <= 0.085), key=lambda p: p[1], default=None)
        derived = " ".join(f"f={f:.2f}:dP={pr:.1%}/dT={tr:.1%}" for f, pr, tr in pts)
        ok = best is not None and best[1] >= 0.15 and superlinear_all
        b.add(f"fig07a/{name}", derived + (f" | best@<=7%: dP={best[1]:.1%}" if best else ""),
              (time.perf_counter() - t0) * 1e6, ok)

    # ---- Fig 7b: BLOOM sensitivity vs prompt computation --------------------
    rows = []
    for inp, bs in [(512, 1), (2048, 1), (8192, 1), (2048, 8)]:
        tm = request_timing(cfg_b := get_config("bloom-176b"), inp, bs, SERVER)
        f = 1275 / 1410
        lat0 = tm.latency(512, DEV)
        latf = tm.latency(512, DEV, f, f)
        rows.append((inp * bs, latf / lat0 - 1))
    ok_b = rows[0][1] <= rows[-1][1] + 1e-9  # more prompt compute => more impact
    b.add("fig07b/bloom/prompt_size_sensitivity",
          " ".join(f"tok{n}:dT={d:.1%}" for n, d in rows), 0.0, ok_b)
    b.add("fig07/superlinearity", f"power_drop>perf_drop for all pts: {superlinear_all}",
          0.0, superlinear_all)
    return b


if __name__ == "__main__":
    for r in run().rows:
        print(r.csv())
