"""Fig. 8/9 + Table 2 (training side): training power reaches TDP with
coordinated per-iteration swings; frequency capping reclaims peak power at
modest throughput cost but only helps the swing when troughs are near idle."""

from __future__ import annotations

import time

from benchmarks.common import Bench, SERVER
from repro.configs import get_config
from repro.core.workload import train_profile

TDP = SERVER.device.tdp_w

# (model, trough_frac, trough_util) — Fig 8: RoBERTa stays ~75% at the
# boundary, GPT-NeoX ~50%, Flan-T5 drops to idle
TRAIN = [
    ("roberta-large", 0.10, 0.75),
    ("gpt-neox-20b", 0.15, 0.50),
    ("flan-t5-xxl", 0.20, 0.05),
]


def run(quick: bool = False) -> Bench:
    b = Bench()
    for name, tf, tu in TRAIN:
        cfg = get_config(name)
        t0 = time.perf_counter()
        prof = train_profile(cfg, batch=32, seq=2048, server=SERVER,
                             trough_frac=tf, trough_util=tu)
        us = (time.perf_counter() - t0) * 1e6
        p_peak = (prof.compute_point.power_at(SERVER, 1.0) - SERVER.other_w) / SERVER.n_devices
        p_trough = SERVER.device.power(tu, tu * 0.5, 1.0)
        swing = (p_peak - p_trough) / TDP
        ok_peak = p_peak / TDP > 0.9  # training reaches ~TDP (Fig 8)
        b.add(f"fig08/{name}",
              f"peak={p_peak/TDP:.2f}xTDP trough={p_trough/TDP:.2f}xTDP "
              f"swing={swing:.2f}xTDP iter={prof.t_iter:.2f}s", us, ok_peak)

        # Fig 9: frequency capping at 1275 MHz
        f = 1275.0 / 1410.0
        p_peak_f = (prof.compute_point.power_at(SERVER, f) - SERVER.other_w) / SERVER.n_devices
        thr_loss = SERVER.device.perf_scale(prof.compute_point.compute_frac, f) - 1
        peak_red = 1 - p_peak_f / p_peak
        p_trough_f = SERVER.device.power(tu, tu * 0.5, f)
        trough_red = 1 - p_trough_f / p_trough
        # capping helps the *swing* only if troughs don't fall as much as peaks
        helps_swing = tu < 0.2
        ok9 = peak_red >= 0.15 and thr_loss <= 0.12
        b.add(f"fig09/{name}",
              f"freq_cap: peak_red={peak_red:.1%} thr_loss={thr_loss:.1%} "
              f"trough_red={trough_red:.1%} helps_swing={helps_swing}", 0.0, ok9)

    # cluster-level training characteristics (Table 2, training column):
    # thousands of GPUs swing together
    prof = train_profile(get_config("gpt-neox-20b"), 32, 2048, SERVER,
                         trough_frac=0.15, trough_util=0.2)
    p_hi = prof.compute_point.power_at(SERVER, 1.0)
    p_lo = SERVER.power(0.2, 0.1, 1.0)
    swing_frac = (p_hi - p_lo) / SERVER.provisioned_w
    peak_util = p_hi / SERVER.provisioned_w
    ok = 0.90 < peak_util <= 1.05 and swing_frac > 0.25
    b.add("table2/training_cluster",
          f"peak_util={peak_util:.2f} coordinated_swing={swing_frac:.2f} "
          f"(paper: 0.97, 0.375)", 0.0, ok)
    return b


if __name__ == "__main__":
    for r in run().rows:
        print(r.csv())
