"""Fig. 4: per-inference power phases — spiky compute-bound prompt, long flat
memory-bound token phase — for the paper's four inference models."""

from __future__ import annotations

import time

from benchmarks.common import Bench, SERVER
from repro.configs import get_config
from repro.core.workload import request_timing

MODELS = ["gpt-neox-20b", "opt-30b", "bloom-176b", "flan-t5-xxl"]
TDP = SERVER.device.tdp_w


def run(quick: bool = False) -> Bench:
    b = Bench()
    for name in MODELS:
        cfg = get_config(name)
        t0 = time.perf_counter()
        t = request_timing(cfg, prompt=2048, batch=1, server=SERVER)
        us = (time.perf_counter() - t0) * 1e6
        p_prompt = (t.prefill_point.power_at(SERVER, 1.0) - SERVER.other_w) / SERVER.n_devices
        p_token = (t.token_point.power_at(SERVER, 1.0) - SERVER.other_w) / SERVER.n_devices
        # paper: prompt spikes at/above TDP (large models), token ~0.4-0.6 TDP,
        # prompt lasts <~1s, token phase much longer
        big = cfg.total_params() > 1e10
        ok = (p_token / TDP < 0.72
              and (p_prompt / TDP > 0.85 if big else p_prompt / TDP > 0.4)
              and (t.t_prefill < 3.0)
              and 256 * t.t_token > t.t_prefill)
        b.add(f"fig04/{name}",
              f"prompt={p_prompt/TDP:.2f}xTDP/{t.t_prefill*1e3:.0f}ms "
              f"token={p_token/TDP:.2f}xTDP/{t.t_token*1e3:.1f}ms_per_tok",
              us, ok)
    return b


if __name__ == "__main__":
    for r in run().rows:
        print(r.csv())
