"""Fig. 16 + §6.1 trace replication: six-week power trace, MAPE < 3% between
the simulated row power and the analytic production-style target; POLCA
at +30% preserves the daily pattern at a higher offset with larger spikes."""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import (Bench, N_PROVISIONED, SERVER, WEEK,
                               bloom_workloads, module_main, seeded)
from repro.core.traces import replication_report, rolling_mean
from repro.experiments import get_scenario, run_experiment


def _smooth(x, k):
    return rolling_mean(x, k)


def run(quick: bool = False) -> Bench:
    b = Bench()
    wls, shares = bloom_workloads()
    dur = WEEK if quick else 6 * WEEK
    base = seeded(get_scenario("fig16-six-week")).with_(duration_s=dur)

    t0 = time.perf_counter()
    res = run_experiment(base).result
    us = (time.perf_counter() - t0) * 1e6

    # 5-minute averages (the paper's Fig 16 granularity); quick mode asserts
    # the same <3% MAPE gate on its one-week slice
    rep = replication_report(res.power_t, res.power_w, wls, shares, SERVER,
                             N_PROVISIONED, N_PROVISIONED,
                             occ_peak=base.traffic.occ_peak, duration_s=dur)
    k = int(round(rep.smooth_window_s / 2.0))
    m = rep.mape
    b.add("fig16/trace_replication_mape", f"MAPE={m:.3%} (paper: <3%)", us, m < 0.03)

    # +30% servers with POLCA: same shape, higher offset, larger spikes
    dur2 = dur if quick else WEEK  # shape comparison needs one week
    res30 = run_experiment(base.with_(duration_s=dur2)
                               .with_fleet(added_frac=0.30)
                               .with_policy("polca")).result
    base_w = res.power_w[: len(res30.power_w)]
    n = min(len(base_w), len(res30.power_w))
    sm0, sm30 = _smooth(base_w[:n], k), _smooth(res30.power_w[:n], k)
    nn = min(len(sm0), len(sm30))
    corr = float(np.corrcoef(sm0[:nn], sm30[:nn])[0, 1])
    offset = float(np.mean(sm30[:nn] - sm0[:nn]))
    spike_ratio = res30.spike(2.0) / max(1e-9, res.spike(2.0))
    b.add("fig16/+30%_same_pattern", f"corr={corr:.2f} offset=+{offset:.3f}",
          0.0, corr > 0.8 and offset > 0.05)
    b.add("fig16/+30%_larger_spikes",
          f"2s_spike_ratio={spike_ratio:.2f} 40s_ratio="
          f"{res30.spike(40.0)/max(1e-9, res.spike(40.0)):.2f} "
          f"(informational: absolute spike W scale with +30% offset)", 0.0, None)
    return b


if __name__ == "__main__":
    module_main(run)
