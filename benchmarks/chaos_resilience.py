"""Chaos-engine resilience: fault timelines vs the power control plane
(DESIGN.md §13).

Validates the :mod:`repro.chaos` subsystem's three claims on the registered
``chaos-*`` scenarios — a healthy 12-row site (2 PDU sets x 2 racks x 3
rows) hit by injected faults mid-trace:

  * **PDU loss separates static budgets from tree-scope rebalancing** — a
    30% derate on ``pdu0`` powerbrakes the static fleet (half the site
    suddenly over-subscribes a shrunken feed), while tree-scope predictive
    rebalancing + shed-lp admission rides the same fault through with zero
    brakes and bounded HP p99: the controller re-divides the surviving
    envelope under the new physical cap (``node_cap_w``) instead of
    "healing" the fault;
  * **crash -> revive conserves work and watts** — every offered request is
    admitted or shed (``admitted + shed == offered`` across the outage), no
    request is dispatched to the dead row, the row re-enters service after
    revival, and a demand-response event returns the root envelope to its
    pre-fault value *exactly* (the injector restores the tracked delta, not
    an inverse factor);
  * **the planner prices survivability** — ``RiskConstraints.survive``
    re-runs every capacity probe under a k-row-crash timeline, and the safe
    oversubscription that survives the crash is strictly below the
    fault-free figure but strictly above zero: k-failure tolerance costs
    headroom, it does not erase it.

A no-op ``FaultSpec`` is also asserted invisible here (``chaos-noop`` is
bit-identical to ``site-static``), the same parity tier-1 asserts.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import Bench, module_main, seeded
from repro.chaos import FaultEvent, FaultSpec
from repro.experiments import get_scenario, run_experiment
from repro.experiments.scenario import (
    ControllerSpec,
    FleetSpec,
    HierarchySpec,
    PolicySpec,
    RoutingSpec,
    Scenario,
    TrafficSpec,
)
from repro.provisioning.planner import RiskConstraints, plan_capacity

HP_P99_SLO = 0.05  # Table 5

CHAOS_RUN_ORDER = ("chaos-pdu-loss-static", "chaos-pdu-loss-tree",
                   "chaos-row-crash", "chaos-demand-response")


def run(quick: bool = False) -> Bench:
    b = Bench()
    dur = 2 * 3600.0 if quick else None  # registered: 6 h
    base = seeded(get_scenario("chaos-pdu-loss-static"))
    if dur is not None:
        base = base.with_(duration_s=dur)
    # one explicit thin-headroom budget for the whole family (the registered
    # 105 kW/row): every fault hits the same healthy-site envelope, so
    # differences are attributable to the fault + the control plane
    budget = base.budget

    outs = {}
    for name in CHAOS_RUN_ORDER:
        sc = seeded(get_scenario(name)).with_(duration_s=base.duration_s,
                                              budget=budget)
        t0 = time.perf_counter()
        o = run_experiment(sc)
        us = (time.perf_counter() - t0) * 1e6
        kind = name.removeprefix("chaos-")
        outs[kind] = o
        s = o.stats.summary()
        f = o.fleet
        b.add(f"chaos/{kind}",
              f"hp_p99={s['hp_p99']:.1%} brakes={o.result.n_brakes} "
              f"faults={f.n_fault_events} shed={f.n_shed_total} "
              f"rebalances={f.n_rebalances}", us, None)

    # ---- headline: PDU loss — static collapses, tree+shed-lp rides through
    st = outs["pdu-loss-static"]
    tr = outs["pdu-loss-tree"]
    tr_s = tr.stats.summary()
    recovered = (st.result.n_brakes > 0 and tr.result.n_brakes == 0
                 and tr_s["hp_p99"] < HP_P99_SLO)
    b.add("chaos/pdu_loss_recovery",
          f"static brakes={st.result.n_brakes} under 30% pdu0 derate; "
          f"tree-scope predictive + shed-lp brakes={tr.result.n_brakes} "
          f"hp_p99={tr_s['hp_p99']:.2%} on the same fault + envelope",
          0.0, recovered)

    # ---- headline: crash -> revive conserves offered work ------------------
    cr = outs["row-crash"].fleet
    crash_ev = [e for e in seeded(get_scenario("chaos-row-crash")).faults.events
                if e.kind == "row-crash"][0]
    revive_t = [e for e in seeded(get_scenario("chaos-row-crash")).faults.events
                if e.kind == "row-revive"][0].t
    dead_row = crash_ev.row
    conserved = cr.n_offered == cr.n_admitted + cr.n_shed_total
    to_dead = [d for d in cr.decisions
               if d.row == dead_row and crash_ev.t < d.t <= revive_t]
    after = [d for d in cr.decisions if d.row == dead_row and d.t > revive_t]
    dead_ticks = (int((~cr.row_alive[:, dead_row]).sum())
                  if cr.row_alive is not None else 0)
    b.add("chaos/crash_conservation",
          f"offered={cr.n_offered} == admitted+shed="
          f"{cr.n_admitted + cr.n_shed_total}; {len(to_dead)} dispatches to "
          f"row {dead_row} during the {dead_ticks}-tick outage, "
          f"{len(after)} after revival",
          0.0, conserved and not to_dead and len(after) > 0 and dead_ticks > 0)

    # ---- demand-response: the ONLY thing that moves the root, and it moves
    # back exactly (restore returns the tracked delta, not an inverse factor)
    dr = outs["demand-response"].fleet
    root = list(dr.node_names).index("site")
    col = dr.node_budget_w[:, root]
    dipped = float(col.min()) < float(col[0]) - 1.0
    returned = abs(float(col[-1]) - float(col[0])) < 1e-6
    b.add("chaos/demand_response_round_trip",
          f"root envelope {col[0] / 1e3:.0f}kW -> min {col.min() / 1e3:.0f}kW "
          f"-> final {col[-1] / 1e3:.0f}kW (exact return); "
          f"{dr.n_fault_events} fault records", 0.0,
          dipped and returned and dr.n_fault_events >= 2)

    # ---- no-op FaultSpec is bit-invisible ----------------------------------
    par_dur = min(base.duration_s, 1800.0)
    noop = run_experiment(seeded(get_scenario("chaos-noop")).with_(
        duration_s=par_dur, compare_to_reference=False))
    site = run_experiment(seeded(get_scenario("site-static")).with_(
        duration_s=par_dur, compare_to_reference=False))
    bit = (noop.result.latencies == site.result.latencies
           and noop.fleet.decisions == site.fleet.decisions
           and np.array_equal(noop.fleet.cluster_power_frac,
                              site.fleet.cluster_power_frac))
    b.add("chaos/noop_bit_parity",
          f"chaos-noop (empty FaultSpec) == site-static bit-for-bit: {bit}",
          0.0, bit)

    # ---- headline: the oversubscription cost of k-failure survivability ----
    plan_base = seeded(Scenario(
        name="chaos-plan", duration_s=1800.0,
        fleet=FleetSpec(n_provisioned=8, added_frac=0.0, n_rows=4),
        policy=PolicySpec("polca"),
        traffic=TrafficSpec(occ_peak=0.62),
        routing=RoutingSpec("cap-aware", admission="shed-lp",
                            admission_params={"shed_above": 0.97}),
        controller=ControllerSpec("predictive", scope="tree"),
        hierarchy=HierarchySpec(shape=(2, 2)), budget="calibrated"))
    crash2 = FaultSpec((FaultEvent("row-crash", t=600.0, row=0),
                        FaultEvent("row-crash", t=700.0, row=1),
                        FaultEvent("row-revive", t=1500.0, row=0),
                        FaultEvent("row-revive", t=1500.0, row=1)))
    n_seeds = 2 if quick else 3
    t0 = time.perf_counter()
    free = plan_capacity(plan_base, n_seeds=n_seeds, max_added_frac=0.5)
    surv = plan_capacity(plan_base,
                         constraints=RiskConstraints(survive=crash2),
                         n_seeds=n_seeds, max_added_frac=0.5)
    us = (time.perf_counter() - t0) * 1e6
    priced = 0 < surv.safe_added_servers < free.safe_added_servers
    b.add("chaos/planner_survivability",
          f"fault-free safe_added={free.safe_added_servers} "
          f"(+{free.safe_added_frac:.0%}); surviving a 2-row crash "
          f"safe_added={surv.safe_added_servers} (+{surv.safe_added_frac:.0%}) "
          f"over {len(surv.probes)} probes", us, priced)
    return b


if __name__ == "__main__":
    module_main(run)
