"""Beyond-paper (§7 'phase-aware power management'): the serving engine knows
prefill vs decode, so the controller can down-clock only the token phase —
zero TTFT impact, peak-power reduction proportional to the token-phase share
of row power, convertible into extra oversubscribed servers."""

from __future__ import annotations

import time

from benchmarks.common import Bench, N_PROVISIONED, SERVER, bloom_workloads
from repro.configs import get_config
from repro.core.phase_aware import sweep
from repro.core.workload import request_timing


def run(quick: bool = False) -> Bench:
    b = Bench()
    t0 = time.perf_counter()
    timing = request_timing(get_config("bloom-176b"), 2048, 1, SERVER)
    outs = sweep(timing, SERVER, mean_out_tokens=1000,
                 freqs=[1350 / 1410, 1275 / 1410, 1110 / 1410])
    us = (time.perf_counter() - t0) * 1e6
    for o in outs:
        # extra headroom: peak is token-dominated, so peak saving ~ extra servers
        extra = o.peak_power_saving / (1 + o.peak_power_saving) + o.peak_power_saving
        b.add(f"phase_aware/f={o.f_token:.3f}",
              f"avg_power_saving={o.avg_power_saving:.1%} "
              f"peak_saving={o.peak_power_saving:.1%} "
              f"token_lat=+{o.token_latency_impact:.1%} TTFT=+0% "
              f"extra_headroom~{o.peak_power_saving:.1%}",
              us if o is outs[0] else 0.0,
              o.avg_power_saving > 0 and o.ttft_impact == 0.0)
    # headline: at the LP-T1 clock the token phase frees >=8% power for <=5% token latency
    mid = outs[1]
    b.add("phase_aware/headline",
          f"@1275MHz: {mid.peak_power_saving:.1%} peak power for "
          f"{mid.token_latency_impact:.1%} token latency, 0% TTFT "
          f"(stacks on POLCA's +30%)",
          0.0, mid.peak_power_saving >= 0.05 and mid.token_latency_impact <= 0.08)
    return b


if __name__ == "__main__":
    for r in run().rows:
        print(r.csv())
