"""Fig. 17/18: POLCA vs 1-Thresh-Low-Pri / 1-Thresh-All / No-cap at +30%
oversubscription — latency impact, SLO compliance, powerbrake counts; plus the
+5% workload-power robustness run."""

from __future__ import annotations

import time

from benchmarks.common import Bench, WEEK, module_main, seeded
from repro.experiments import PolicySpec, get_scenario, run_experiment

POLICIES = [
    ("polca", PolicySpec("polca")),
    ("1-thresh-low-pri", PolicySpec("one-threshold", {"cap_hp": False})),
    ("1-thresh-all", PolicySpec("one-threshold", {"cap_hp": True})),
    ("no-cap", PolicySpec("no-cap")),
]


def run(quick: bool = False) -> Bench:
    b = Bench()
    base = seeded(get_scenario("fig17-comparison")).with_(
        duration_s=WEEK / 14 if quick else WEEK / 2)

    outcomes = {}
    for scale, tag in ([(1.0, "")] if quick else [(1.0, ""), (1.05, "+5%power")]):
        for name, spec in POLICIES:
            t0 = time.perf_counter()
            o = run_experiment(base.with_(policy=spec, power_scale=scale))
            us = (time.perf_counter() - t0) * 1e6
            s = o.stats.summary()
            outcomes[(name, tag)] = o
            b.add(f"fig17/{name}{('/' + tag) if tag else ''}",
                  f"HP_p99={s['hp_p99']:.3%} LP_p99={s['lp_p99']:.3%} "
                  f"meets_SLO={o.meets} brakes={o.result.n_brakes}", us, None)

    # paper claims: POLCA meets SLOs with zero brakes; 1-thresh-all caps HP
    # aggressively (worse HP impact than POLCA); robustness under +5%
    polca = outcomes[("polca", "")]
    all_ = outcomes[("1-thresh-all", "")]
    b.add("fig17/polca_meets_slo", f"{polca.meets} brakes={polca.result.n_brakes}",
          0.0, polca.meets and polca.result.n_brakes == 0)
    b.add("fig17/1-thresh-all_hurts_hp",
          f"HP_p99 {all_.stats.summary()['hp_p99']:.3%} >= polca "
          f"{polca.stats.summary()['hp_p99']:.3%}",
          0.0, all_.stats.summary()["hp_p99"] >= polca.stats.summary()["hp_p99"] - 1e-9)
    if ("polca", "+5%power") in outcomes:
        rob = outcomes[("polca", "+5%power")]
        nocap5 = outcomes[("no-cap", "+5%power")]
        # the paper's wording: POLCA is "the most robust" under the +5% drift —
        # zero powerbrakes and the best HP tail of every policy
        others_hp = [outcomes[(n, "+5%power")].stats.summary()["hp_p99"]
                     for (n, _) in POLICIES if n != "polca"]
        others_brakes = [outcomes[(n, "+5%power")].result.n_brakes
                         for (n, _) in POLICIES if n != "polca"]
        most_robust = (rob.result.n_brakes == 0
                       and rob.stats.summary()["hp_p99"] <= min(others_hp) + 1e-9)
        b.add("fig17/polca_robust_to_+5%",
              f"brakes=0 vs baselines {others_brakes}; HP_p99 "
              f"{rob.stats.summary()['hp_p99']:.1%} vs best-baseline "
              f"{min(others_hp):.1%} -> most robust={most_robust}",
              0.0, most_robust)
        b.add("fig18/powerbrakes",
              " ".join(f"{n}{t and '/' + t}:{outcomes[(n, t)].result.n_brakes}"
                       for (n, t) in outcomes),
              0.0, rob.result.n_brakes == 0 and nocap5.result.n_brakes >= rob.result.n_brakes)
    return b


if __name__ == "__main__":
    module_main(run)
