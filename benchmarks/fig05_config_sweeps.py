"""Fig. 5: power (mean, peak) and latency sensitivity to input size, batch
size and output size for inference (BLOOM-176B, GPT-NeoX-20B)."""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import Bench, SERVER
from repro.configs import get_config
from repro.core.workload import request_timing

TDP = SERVER.device.tdp_w


def _gpu(p):
    return (p - SERVER.other_w) / SERVER.n_devices / TDP


def run(quick: bool = False) -> Bench:
    b = Bench()
    models = ["bloom-176b"] if quick else ["bloom-176b", "gpt-neox-20b"]
    for name in models:
        cfg = get_config(name)
        t0 = time.perf_counter()

        # (a,b) input sweep at batch 1, output 128
        inputs = [256, 1024, 4096, 8192]
        peaks, lats = [], []
        for inp in inputs:
            t = request_timing(cfg, inp, 1, SERVER)
            peaks.append(_gpu(t.prefill_point.power_at(SERVER, 1.0)))
            lats.append(t.latency(128, SERVER.device))
        # peak rises with input, or the model is already saturated at/above
        # TDP for every input size (BLOOM's regime in the paper's Fig 5a)
        ok_a = (all(x <= y + 1e-9 for x, y in zip(peaks, peaks[1:]))
                or min(peaks) >= 0.95)
        ok_b = (lats[2] - lats[0]) / lats[0] < 0.35  # ~flat latency till 4k
        b.add(f"fig05a/{name}/input_sweep",
              "peak_xTDP=" + "/".join(f"{p:.2f}" for p in peaks), 0.0, ok_a)
        b.add(f"fig05b/{name}/latency_vs_input",
              "lat_s=" + "/".join(f"{l:.2f}" for l in lats), 0.0, ok_b)

        # (c,d) batch sweep at input 256 (unsaturated prompt: peak still rising)
        batches = [1, 4, 16]
        bpk, bmean, blat = [], [], []
        for bs in batches:
            t = request_timing(cfg, 256, bs, SERVER)
            bpk.append(_gpu(t.prefill_point.power_at(SERVER, 1.0)))
            bmean.append(_gpu(t.token_point.power_at(SERVER, 1.0)))
            blat.append(t.latency(128, SERVER.device))
        ok_c = ((bpk[-1] >= bpk[0] - 0.02 or min(bpk) >= 0.95)
                and bmean[-1] >= bmean[0] - 1e-9)
        b.add(f"fig05c/{name}/batch_sweep",
              "peak=" + "/".join(f"{p:.2f}" for p in bpk)
              + " mean=" + "/".join(f"{p:.2f}" for p in bmean), 0.0, ok_c)
        b.add(f"fig05d/{name}/latency_vs_batch",
              "lat_s=" + "/".join(f"{l:.2f}" for l in blat), 0.0,
              blat[-1] >= blat[0] - 1e-9)

        # (e,f) output sweep: power flat, latency linear
        outs = [128, 512, 2048]
        t = request_timing(cfg, 2048, 1, SERVER)
        olat = [t.latency(o, SERVER.device) for o in outs]
        lin = np.polyfit(outs, olat, 1)
        resid = np.max(np.abs(np.polyval(lin, outs) - olat) / np.asarray(olat))
        b.add(f"fig05e/{name}/power_vs_output",
              f"peak_const={_gpu(t.prefill_point.power_at(SERVER,1.0)):.2f}xTDP", 0.0, True)
        b.add(f"fig05f/{name}/latency_vs_output",
              "lat_s=" + "/".join(f"{l:.1f}" for l in olat)
              + f" linear_resid={resid:.1e}", (time.perf_counter() - t0) * 1e6,
              resid < 1e-6)
    return b


if __name__ == "__main__":
    for r in run().rows:
        print(r.csv())
