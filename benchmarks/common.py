"""Shared benchmark plumbing: Row records, CSV output, validation asserts,
and the ``--seed`` CLI plumbing that makes every benchmark run reproducible
from the command line (flag -> Scenario.seed -> trace generators)."""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.power_model import A100, ServerPower  # noqa: E402
from repro.core.traces import build_workload_classes  # noqa: E402

# CLI-pinned seed (None = each scenario keeps its registered seed). Set via
# ``--seed`` on benchmarks.run or any module's __main__; modules route every
# scenario they construct through ``seeded()`` so the override reaches the
# trace generators end to end.
BENCH_SEED: Optional[int] = None


def set_seed(seed: Optional[int]) -> None:
    global BENCH_SEED
    BENCH_SEED = seed


def seeded(scenario):
    """The scenario with the CLI seed applied (identity when none given)."""
    return scenario if BENCH_SEED is None else scenario.with_(seed=BENCH_SEED)


def write_bench_json(artifacts_dir: str, basename: str,
                     rows: Optional[List["Row"]]) -> str:
    """One ``BENCH_<module>.json`` per module under the artifacts dir:
    row name -> {us_per_call, derived, ok}. ``rows=None`` records a module
    that raised before producing rows (rendered FAIL by tools/report.py)."""
    os.makedirs(artifacts_dir, exist_ok=True)
    path = os.path.join(artifacts_dir, f"BENCH_{basename}.json")
    payload = (None if rows is None else
               {r.name: {"us_per_call": r.us_per_call, "derived": r.derived,
                         "ok": r.ok} for r in rows})
    with open(path, "w") as f:
        json.dump({"module": basename, "rows": payload}, f, indent=2,
                  sort_keys=True)
        f.write("\n")
    return path


def module_main(run_fn: Callable) -> None:
    """Shared __main__ entry for benchmark modules: --quick, --seed, and
    --artifacts (manifest + metrics + events + BENCH_<module>.json, the
    same pipeline ``benchmarks.run --artifacts`` drives for the full
    suite)."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--seed", type=int, default=None,
                    help="override every scenario's seed (reproducibility)")
    ap.add_argument("--artifacts", default=None, metavar="DIR",
                    help="record the run and write manifest + metrics + "
                         "events + BENCH_<module>.json under DIR")
    args = ap.parse_args()
    set_seed(args.seed)
    # progress to stderr through the shared repro logger (REPRO_LOG_LEVEL
    # gates it); the CSV contract on stdout is untouched
    from repro.obs.log import get_logger
    log = get_logger("benchmarks")
    basename = os.path.splitext(os.path.basename(sys.argv[0]))[0]
    log.info("%s ...%s", basename, " (quick)" if args.quick else "")
    t0 = time.perf_counter()
    if args.artifacts:
        from repro.obs.export import run_manifest, write_artifacts
        from repro.obs.metrics import MetricsRecorder, recording
        rec = MetricsRecorder()
        with recording(rec), rec.span("bench/module", module=basename):
            bench = run_fn(quick=args.quick)
        write_bench_json(args.artifacts, basename, bench.rows)
        write_artifacts(args.artifacts, rec.snapshot(), run_manifest(
            seed=BENCH_SEED,
            extra={"kind": f"benchmarks.{basename}",
                   "quick": bool(args.quick),
                   "wall_clock_s": round(time.perf_counter() - t0, 3)}))
    else:
        bench = run_fn(quick=args.quick)
    log.info("%s: %d rows, %d failing, %.1fs", basename, len(bench.rows),
             sum(1 for r in bench.rows if r.ok is False),
             time.perf_counter() - t0)
    for row in bench.rows:
        print(row.csv())


@dataclass
class Row:
    name: str
    us_per_call: float  # wall time of the measured unit (us)
    derived: str  # the figure's headline quantity
    ok: Optional[bool] = None  # paper-claim validation (None = informational)

    def csv(self) -> str:
        flag = "" if self.ok is None else (",PASS" if self.ok else ",FAIL")
        return f"{self.name},{self.us_per_call:.1f},{self.derived}{flag}"


class Bench:
    """Context helper: times the block, collects rows."""

    def __init__(self):
        self.rows: List[Row] = []

    def add(self, name: str, derived: str, t_us: float = 0.0, ok=None):
        self.rows.append(Row(name, t_us, derived, ok))

    def timed(self, name: str, fn: Callable, derived_fn: Callable = None, ok_fn=None):
        t0 = time.perf_counter()
        out = fn()
        us = (time.perf_counter() - t0) * 1e6
        derived = derived_fn(out) if derived_fn else str(out)
        ok = ok_fn(out) if ok_fn else None
        self.rows.append(Row(name, us, derived, ok))
        return out


SERVER = ServerPower(A100)
_WLS = None


def bloom_workloads():
    global _WLS
    if _WLS is None:
        _WLS = build_workload_classes("bloom-176b", SERVER)
    return _WLS


# standard row-scale parameters (paper Table 1)
N_PROVISIONED = 40
WEEK = 7 * 86400.0
