"""Shared benchmark plumbing: Row records, CSV output, validation asserts,
and the ``--seed`` CLI plumbing that makes every benchmark run reproducible
from the command line (flag -> Scenario.seed -> trace generators)."""

from __future__ import annotations

import argparse
import os
import sys
import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.power_model import A100, ServerPower  # noqa: E402
from repro.core.traces import build_workload_classes  # noqa: E402

# CLI-pinned seed (None = each scenario keeps its registered seed). Set via
# ``--seed`` on benchmarks.run or any module's __main__; modules route every
# scenario they construct through ``seeded()`` so the override reaches the
# trace generators end to end.
BENCH_SEED: Optional[int] = None


def set_seed(seed: Optional[int]) -> None:
    global BENCH_SEED
    BENCH_SEED = seed


def seeded(scenario):
    """The scenario with the CLI seed applied (identity when none given)."""
    return scenario if BENCH_SEED is None else scenario.with_(seed=BENCH_SEED)


def module_main(run_fn: Callable) -> None:
    """Shared __main__ entry for benchmark modules: --quick and --seed."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--seed", type=int, default=None,
                    help="override every scenario's seed (reproducibility)")
    args = ap.parse_args()
    set_seed(args.seed)
    for row in run_fn(quick=args.quick).rows:
        print(row.csv())


@dataclass
class Row:
    name: str
    us_per_call: float  # wall time of the measured unit (us)
    derived: str  # the figure's headline quantity
    ok: Optional[bool] = None  # paper-claim validation (None = informational)

    def csv(self) -> str:
        flag = "" if self.ok is None else (",PASS" if self.ok else ",FAIL")
        return f"{self.name},{self.us_per_call:.1f},{self.derived}{flag}"


class Bench:
    """Context helper: times the block, collects rows."""

    def __init__(self):
        self.rows: List[Row] = []

    def add(self, name: str, derived: str, t_us: float = 0.0, ok=None):
        self.rows.append(Row(name, t_us, derived, ok))

    def timed(self, name: str, fn: Callable, derived_fn: Callable = None, ok_fn=None):
        t0 = time.perf_counter()
        out = fn()
        us = (time.perf_counter() - t0) * 1e6
        derived = derived_fn(out) if derived_fn else str(out)
        ok = ok_fn(out) if ok_fn else None
        self.rows.append(Row(name, us, derived, ok))
        return out


SERVER = ServerPower(A100)
_WLS = None


def bloom_workloads():
    global _WLS
    if _WLS is None:
        _WLS = build_workload_classes("bloom-176b", SERVER)
    return _WLS


# standard row-scale parameters (paper Table 1)
N_PROVISIONED = 40
WEEK = 7 * 86400.0
