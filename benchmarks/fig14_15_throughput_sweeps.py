"""Fig. 14: HP/LP throughput at +30% servers under POLCA.
Fig. 15a: capping-frequency sweep for LP at T1.  Fig. 15b: LP-fraction sweep."""

from __future__ import annotations

import dataclasses
import time

from benchmarks.common import Bench, WEEK, module_main, seeded
from repro.experiments import get_scenario, run_experiment


def run(quick: bool = False) -> Bench:
    b = Bench()
    dur = WEEK / 14 if quick else WEEK / 2
    base = seeded(get_scenario("fig14-plus30")).with_(duration_s=dur)

    # ---- Fig 14 -------------------------------------------------------------
    t0 = time.perf_counter()
    o = run_experiment(base)
    us = (time.perf_counter() - t0) * 1e6
    ok14 = o.throughput_ratio_hp > 0.995 and o.throughput_ratio_lp > 0.98
    b.add("fig14/throughput@+30%",
          f"HP={o.throughput_ratio_hp:.4f} LP={o.throughput_ratio_lp:.4f} "
          f"(paper: HP unaffected, LP<2% decline)", us, ok14)
    s = o.stats.summary()
    b.add("fig14/latency@+30%",
          f"HP p50={s['hp_p50']:.3%} p99={s['hp_p99']:.3%} "
          f"LP p50={s['lp_p50']:.3%} p99={s['lp_p99']:.3%} brakes={o.result.n_brakes}",
          0.0, o.meets)

    # ---- Fig 15a: LP capping frequency at T1 --------------------------------
    freqs = [1350, 1275, 1110, 1000]
    for mhz in (freqs[:2] if quick else freqs):
        f = mhz / 1410.0
        oo = run_experiment(base.with_(duration_s=dur / 2)
                                .with_policy("polca", lp_freq_t1=f))
        ss = oo.stats.summary()
        b.add(f"fig15a/lp_cap_{mhz}MHz",
              f"LP p99={ss['lp_p99']:.3%} meets={oo.meets}", 0.0, None)

    # ---- Fig 15b: LP fraction sweep ------------------------------------------
    for lp_frac in ([0.3, 0.7] if quick else [0.2, 0.4, 0.6, 0.8]):
        sc = base.with_(
            duration_s=dur / 2,
            traffic=dataclasses.replace(base.traffic,
                                        priority_mix_override=1 - lp_frac))
        oo = run_experiment(sc)
        ss = oo.stats.summary()
        b.add(f"fig15b/lp_frac_{lp_frac:.1f}",
              f"HP p99={ss['hp_p99']:.3%} LP p99={ss['lp_p99']:.3%} "
              f"brakes={oo.result.n_brakes}", 0.0, None)
    return b


if __name__ == "__main__":
    module_main(run)
