"""Observability guarantees: zero perturbation, exact reconciliation,
bounded overhead (DESIGN.md §14).

Validates the hard claims the ``repro.obs`` subsystem ships under:
  * recorder-on and recorder-off fleet simulations are **bit-identical**
    (latencies, power series, routing decisions, shed accounting) — the
    instrumentation observes, never perturbs;
  * benchmark CSV rows (name, derived, validation — everything but the
    wall-clock column) are identical with and without a recorder;
  * brake engage/release *edge* events in the trace reconcile exactly with
    ``braked_series`` transitions, per row;
  * a Monte-Carlo ensemble records the same counters/histograms/events for
    any worker count (snapshots merge in member order);
  * the exported artifacts (Prometheus text, JSONL events, manifest) parse
    back to the recorded state;
  * full instrumentation + export costs <= 5% wall-clock on a
    fleet-rebalance run.
"""

from __future__ import annotations

import os
import tempfile
import time

import numpy as np

from benchmarks.common import Bench, module_main, seeded
from repro.experiments import get_scenario, run_experiment
from repro.experiments.runner import build_workloads, resolve_budget
from repro.obs.export import (
    read_events,
    read_manifest,
    read_prometheus,
    run_manifest,
    write_artifacts,
)
from repro.obs.metrics import MetricsRecorder, recording
from repro.provisioning.montecarlo import EnsembleSpec, run_ensemble


def _series_edges(series) -> tuple:
    """(engage, release) transition counts of a braked series, initial
    state unbraked — the exact semantics the row emits edge events under."""
    s = np.asarray(series, bool)
    if s.size == 0:
        return 0, 0
    prev = np.concatenate([[False], s[:-1]])
    return int(np.sum(~prev & s)), int(np.sum(prev & ~s))


def _event_edges(snap, row: int) -> tuple:
    eng = sum(1 for e in snap.events_of("row", "brake_engage")
              if e.labels_dict().get("row") == str(row))
    rel = sum(1 for e in snap.events_of("row", "brake_release")
              if e.labels_dict().get("row") == str(row))
    return eng, rel


def run(quick: bool = False) -> Bench:
    b = Bench()
    dur = 3 * 3600.0 if quick else 6 * 3600.0
    base = seeded(get_scenario("fleet-rebalance-static")).with_(
        duration_s=dur, compare_to_reference=False)
    wls, shares = build_workloads(base)
    budget = resolve_budget(base, wls, shares, base.fleet.server())
    base = base.with_(budget=budget)

    # ---- recorder-off vs recorder-on: bit-identical fleet results ----------
    # best-of-N interleaved timing: single-shot wall clocks on a shared box
    # swing far more than the ~2% true recorder cost
    reps = 3
    t_off = t_on = float("inf")
    off = on = rec = None
    for _ in range(reps):
        t0 = time.perf_counter()
        off = run_experiment(base)
        t_off = min(t_off, time.perf_counter() - t0)
        r = MetricsRecorder()
        t0 = time.perf_counter()
        with recording(r):
            on = run_experiment(base)
        if time.perf_counter() - t0 < t_on:
            t_on = time.perf_counter() - t0
            rec = r
    with tempfile.TemporaryDirectory() as tmp:
        t0 = time.perf_counter()
        write_artifacts(tmp, rec.snapshot(), run_manifest(seed=base.seed))
        t_on += time.perf_counter() - t0
        snap = rec.snapshot()
        fo, fn = off.fleet, on.fleet
        bit = (off.result.latencies == on.result.latencies
               and np.array_equal(fo.cluster_power_frac, fn.cluster_power_frac)
               and np.array_equal(fo.row_power_frac, fn.row_power_frac)
               and fo.decisions == fn.decisions
               and fo.n_shed == fn.n_shed
               and off.result.n_brakes == on.result.n_brakes)
        b.add("obs/fleet_bit_parity",
              f"recorder-on == recorder-off over {dur / 3600:.0f}h fleet run: "
              f"{bit} ({snap.n_events} events, "
              f"{len(snap.counters)} counter series recorded)", 0.0, bit)

        # ---- overhead: full instrumentation + export within 5% -------------
        ratio = t_on / t_off
        b.add("obs/overhead",
              f"instrumented+exported {t_on:.2f}s vs bare {t_off:.2f}s "
              f"best-of-{reps} (x{ratio:.3f})", (t_on - t_off) * 1e6,
              ratio <= 1.05)

        # ---- brake edges reconcile exactly with braked_series --------------
        edges_match, n_edges = True, 0
        for i, rr in enumerate(fn.row_results):
            want = _series_edges(rr.braked_series)
            got = _event_edges(snap, i)
            n_edges += got[0] + got[1]
            edges_match = edges_match and want == got
        b.add("obs/brake_edge_reconcile",
              f"engage/release events == braked_series transitions on all "
              f"{fn.n_rows} rows: {edges_match} ({n_edges} edges)",
              0.0, edges_match)

        # ---- export round-trip ---------------------------------------------
        prom = read_prometheus(os.path.join(tmp, "metrics.prom"))
        events = read_events(os.path.join(tmp, "events.jsonl"))
        manifest = read_manifest(tmp)
        n_dispatch = sum(v for _, v in prom.get("counter", {}).get(
            "fleet_dispatch_total", []))
        roundtrip = (len(events) == snap.n_events
                     and n_dispatch == snap.counter_total("fleet_dispatch_total")
                     and manifest.get("seed") == base.seed
                     and manifest.get("numpy"))
        b.add("obs/export_roundtrip",
              f"prom/jsonl/manifest parse back: {bool(roundtrip)} "
              f"({len(events)} events, dispatch={n_dispatch:.0f})",
              0.0, bool(roundtrip))

    # ---- CSV rows identical with a recorder installed ----------------------
    from benchmarks import table2_cluster_stats
    rows_off = [(r.name, r.derived, r.ok)
                for r in table2_cluster_stats.run(quick=True).rows]
    with recording(MetricsRecorder()):
        rows_on = [(r.name, r.derived, r.ok)
                   for r in table2_cluster_stats.run(quick=True).rows]
    same = rows_off == rows_on
    b.add("obs/csv_row_identity",
          f"table2 quick rows (name,derived,validation) identical under a "
          f"recorder: {same} ({len(rows_off)} rows)", 0.0, same)

    # ---- ensemble traces invariant to worker count -------------------------
    ens_base = base.with_(duration_s=1800.0)
    snaps = []
    for w in (1, 2):
        r = MetricsRecorder()
        with recording(r):
            run_ensemble(EnsembleSpec(ens_base, n_seeds=2, seed0=1000,
                                      n_workers=w), budget_w=budget)
        snaps.append(r.snapshot())
    s1, s2 = snaps
    inv = (s1.counters == s2.counters and s1.gauges == s2.gauges
           and s1.hists == s2.hists and s1.events == s2.events)
    b.add("obs/mc_worker_invariance",
          f"2-member ensemble counters/gauges/hists/events identical for "
          f"n_workers=1 vs 2: {inv} ({s1.n_events} events)", 0.0, inv)
    return b


if __name__ == "__main__":
    module_main(run)
