"""Provisioning planner: per-scenario safe oversubscription ratios via the
Monte-Carlo capacity search (DESIGN.md §9).

Validates the subsystem's three claims:
  * the planner reproduces the paper's headline on the baseline diurnal
    scenario — >= ~30% more deployable servers inside the same power envelope
    under the SLO + zero-powerbrake risk constraints;
  * it reports safe ratios for the whole scenario-generator family (>= 5
    distinct generators), all planned against the same envelope;
  * the batched engine is bit-identical to a sequential ``run_experiment``
    loop and amortizes its per-member budget-calibration + reference work
    (wall speedup printed; the structural ratio is ~3x single-core and scales
    with effective cores — >= 5x on >= 2-core hosts).
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import Bench, WEEK, module_main, seeded
from repro.experiments import FLEET_SCENARIO_FAMILY, get_scenario, run_experiment
from repro.provisioning import (
    MC_BASE_NAME,
    MC_SCENARIO_FAMILY,
    EnsembleSpec,
    plan_scenarios,
    resolve_ensemble_budget,
    run_ensemble,
    run_ensemble_grid,
    run_ensemble_sequential,
)


def run(quick: bool = False) -> Bench:
    b = Bench()
    dur = WEEK / 14 if quick else WEEK / 7  # 12 h quick, 24 h full
    n_seeds = 2 if quick else 6
    bases = [seeded(get_scenario(name)).with_(duration_s=dur)
             for name in MC_SCENARIO_FAMILY]

    # one envelope for the whole family: calibrated from the diurnal baseline
    budget = resolve_ensemble_budget(bases[0])

    t0 = time.perf_counter()
    plans = plan_scenarios(bases, n_seeds=n_seeds, seed0=1000, budget_w=budget)
    us = (time.perf_counter() - t0) * 1e6

    for name in MC_SCENARIO_FAMILY:
        p = plans[name]
        note = (" (capped)" if p.capped else
                "" if p.feasible_at_zero else
                " (infeasible even at the provisioned fleet: derate needed)")
        b.add(f"capacity/safe_ratio/{name}",
              f"+{p.safe_added_frac:.1%} ({p.safe_n_servers} servers on "
              f"{p.n_provisioned}-server budget, {len(p.probes)} probes){note}",
              us if name == MC_BASE_NAME else 0.0, None)

    baseline = plans[MC_BASE_NAME]
    b.add("capacity/baseline_reproduces_+30%",
          f"safe_added={baseline.safe_added_frac:.1%} "
          f"(paper: ~30% more servers, zero brakes, SLOs met)",
          0.0, baseline.safe_added_frac >= 0.30 - 1e-9)
    n_reported = sum(1 for p in plans.values() if p.probes)
    b.add("capacity/scenario_family_coverage",
          f"{n_reported} scenario generators planned (need >= 5); "
          "ratios span "
          f"{min(p.safe_added_frac for p in plans.values()):.1%}.."
          f"{max(p.safe_added_frac for p in plans.values()):.1%}",
          0.0, n_reported >= 5)

    # ---- CVaR-vs-alpha frontier per generator family (grid engine) ---------
    # ONE run_ensemble_grid(engine="jax") call evaluates the whole mc-*
    # family as a single scenario-vmapped device program (DESIGN.md §16);
    # the envelope is tightened 5% below calibration so the LP-capping tail
    # the frontier prices is actually active. CVaR is monotone in alpha by
    # construction (a larger alpha averages a worse subset) — the PASS row
    # asserts that on every family, which guards the dense-tail statistics
    # plumbing end to end (impacts arrays, per-member percentiles, _cvar).
    cv_seeds = 256 if quick else 1024
    cv_dur = 6 * 3600.0 if quick else 12 * 3600.0
    cv_alphas = (0.0, 0.5, 0.9, 0.99)
    cv_bases = [b_.with_(duration_s=cv_dur) for b_ in bases]
    t0 = time.perf_counter()
    cv_grid = run_ensemble_grid(cv_bases, n_seeds=cv_seeds, seed0=500,
                                budget_w=0.95 * budget, engine="jax")
    cv_us = (time.perf_counter() - t0) * 1e6
    frontier_ok = True
    for name in MC_SCENARIO_FAMILY:
        ens = cv_grid[name]
        curve = [ens.slo_cvar("low", a) for a in cv_alphas]
        mono = all(y >= x - 1e-12 for x, y in zip(curve, curve[1:]))
        frontier_ok = frontier_ok and mono and all(np.isfinite(curve))
        b.add(f"capacity/cvar_frontier/{name}",
              "slo_cvar(lp,p99)@alpha={"
              + ",".join(f"{a:g}:{v:.4f}" for a, v in zip(cv_alphas, curve))
              + f"}} n={ens.n_members}", 0.0, None)
    b.add("capacity/cvar_frontier_monotone",
          f"{len(MC_SCENARIO_FAMILY)} families x {cv_seeds} members x "
          f"{len(cv_alphas)} alphas from ONE grid call at 95% envelope; "
          f"every frontier monotone in alpha: {frontier_ok}",
          cv_us, frontier_ok)

    # ---- fleet-* family: plan the routed-fleet scenarios (ROADMAP item) ----
    # the planner sweeps the whole dispatch-policy family against ONE pinned
    # envelope: how far the same power stretches under each router. Smoke
    # mode keeps one seed and a short horizon; full mode plans properly.
    fl_dur = 1800.0 if quick else 2 * 3600.0
    fl_seeds = 1 if quick else 2
    fl_max = 0.10 if quick else 0.30
    fleet_bases = [seeded(get_scenario(name)).with_(duration_s=fl_dur)
                   for name in FLEET_SCENARIO_FAMILY]
    fl_budget = resolve_ensemble_budget(fleet_bases[0])
    t0 = time.perf_counter()
    fl_plans = plan_scenarios(fleet_bases, n_seeds=fl_seeds, seed0=1000,
                              budget_w=fl_budget, max_added_frac=fl_max)
    us = (time.perf_counter() - t0) * 1e6
    for name in FLEET_SCENARIO_FAMILY:
        p = fl_plans[name]
        note = (" (capped)" if p.capped else
                "" if p.feasible_at_zero else
                " (infeasible even at the provisioned fleet)")
        b.add(f"capacity/fleet_safe_ratio/{name}",
              f"+{p.safe_added_frac:.1%} ({p.safe_n_servers} servers/row on "
              f"{p.n_provisioned}-server row budgets, "
              f"{len(p.probes)} probes){note}",
              us if name == FLEET_SCENARIO_FAMILY[0] else 0.0, None)
    b.add("capacity/fleet_family_planned",
          f"{len(fl_plans)} routed-fleet scenarios planned against one "
          f"envelope (need {len(FLEET_SCENARIO_FAMILY)}); ratios span "
          f"{min(p.safe_added_frac for p in fl_plans.values()):.1%}.."
          f"{max(p.safe_added_frac for p in fl_plans.values()):.1%}",
          0.0, len(fl_plans) == len(FLEET_SCENARIO_FAMILY))

    # ---- batched engine vs the naive sequential run_experiment loop --------
    spd_base = (seeded(get_scenario(MC_BASE_NAME))
                .with_(duration_s=(3 * 3600.0 if quick else dur),
                       compare_to_reference=True)
                .with_fleet(added_frac=0.30))
    spec = EnsembleSpec(spd_base, n_seeds=32, seed0=300)
    t0 = time.perf_counter()
    ens = run_ensemble(spec)
    t_batched = time.perf_counter() - t0
    n_naive = 4 if quick else 8  # measured subset, extrapolated linearly
    t0 = time.perf_counter()
    run_ensemble_sequential(spec, n_members=n_naive)
    t_naive = (time.perf_counter() - t0) / n_naive * spec.n_seeds
    ratio = t_naive / max(1e-9, t_batched)
    b.add("capacity/batched_vs_sequential_32members",
          f"batched={t_batched:.1f}s naive_loop={t_naive:.1f}s(est from "
          f"{n_naive}) speedup={ratio:.1f}x (floor 2x; >=5x on >=2 effective "
          "cores: naive repeats calibration+reference per member)",
          0.0, ratio >= 2.0)

    # ---- bit-parity spot check (full check lives in tier-1 tests) ----------
    par_spec = EnsembleSpec(spd_base.with_(duration_s=3600.0,
                                           compare_to_reference=False),
                            n_seeds=4, seed0=300)
    par = run_ensemble(par_spec)
    ok = True
    for m, sc in zip(par.members, par_spec.member_scenarios(par.budget_w)):
        o = run_experiment(sc)
        ok = ok and (m.result.latencies == o.result.latencies
                     and np.array_equal(m.result.power_w, o.result.power_w)
                     and m.result.n_brakes == o.result.n_brakes)
    b.add("capacity/batched_bit_parity_4members",
          f"batched == sequential run_experiment: {ok}", 0.0, ok)
    return b


if __name__ == "__main__":
    module_main(run)
