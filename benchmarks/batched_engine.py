"""Batched ensemble engine: oracle parity, dense-tail throughput, CVaR tail
(DESIGN.md §15).

Validates the subsystem's three claims:
  * the jax jit/vmap/`lax.scan` device program reproduces the numpy tick
    oracle exactly — brake-tick sets bit-identical, power series within 1e-6
    relative (the differential contract tier-1 drills property-style in
    tests/test_batched_parity.py);
  * a 10^4-member ensemble completes in one device program with a measured
    members/sec speedup over the DES fork pool on the same scenario — the
    dense tails the fork pool (capped by host cores) could never reach;
  * those tails make CVaR meaningful: the `RiskConstraints.slo_cvar_alpha`
    statistic is finite, monotone in alpha, and degenerates to the worst
    member as alpha -> 1 on a 10^4-member tail.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import Bench, module_main, seeded
from repro.experiments.scenario import FleetSpec, Scenario, TrafficSpec
from repro.provisioning import (
    EnsembleSpec,
    lower_ensemble,
    run_batched_ensemble,
    run_ensemble,
    run_tick_model,
)


def _scenario(occ_peak: float = 0.97, power_scale: float = 1.15) -> Scenario:
    return seeded(Scenario(
        name="batched-bench", duration_s=1800.0,
        fleet=FleetSpec(n_provisioned=20, added_frac=0.30, n_rows=2,
                        rows_per_rack=2),
        traffic=TrafficSpec(occ_peak=occ_peak),
        budget="nominal", power_scale=power_scale,
        compare_to_reference=False))


def run(quick: bool = False) -> Bench:
    b = Bench()
    sc = _scenario()

    # ---- differential parity: numpy tick oracle vs jax device program -----
    # hot enough (scale 1.30) that the brake path is demonstrably covered
    hot = _scenario(occ_peak=0.99, power_scale=1.30)
    model, members, _ = lower_ensemble(EnsembleSpec(hot, n_seeds=4, seed0=77))
    t0 = time.perf_counter()
    oracle = run_tick_model(model, members, engine="numpy")
    jaxed = run_tick_model(model, members, engine="jax")
    us = (time.perf_counter() - t0) * 1e6
    brake_ok = bool(np.array_equal(oracle.brake_fire, jaxed.brake_fire))
    rel = float(np.max(np.abs(jaxed.total_frac - oracle.total_frac)
                       / np.maximum(np.abs(oracle.total_frac), 1e-12)))
    n_brakes = int(oracle.n_brakes.sum())
    b.add("batched/oracle_parity_4members",
          f"brake_sets_identical={brake_ok} ({n_brakes} brakes exercised) "
          f"power_rel_err={rel:.1e} (bound 1e-6)",
          us, brake_ok and rel <= 1e-6 and n_brakes > 0)

    # ---- dense-tail throughput: 10^4 members vs the DES fork pool ---------
    n_tail = 10_000
    t0 = time.perf_counter()
    tail = run_batched_ensemble(EnsembleSpec(sc, n_seeds=n_tail, seed0=1),
                                engine="jax", keep_series=False)
    t_jax = time.perf_counter() - t0
    mps_jax = n_tail / t_jax
    n_ref = 2 if quick else 4  # DES members measured, extrapolated linearly
    t0 = time.perf_counter()
    run_ensemble(EnsembleSpec(sc, n_seeds=n_ref, seed0=1), engine="numpy")
    mps_pool = n_ref / (time.perf_counter() - t0)
    speedup = mps_jax / max(1e-9, mps_pool)
    b.add(f"batched/throughput_{n_tail}_members",
          f"jax={mps_jax:.0f} members/s vs fork_pool={mps_pool:.1f} "
          f"members/s (est from {n_ref}) speedup={speedup:.0f}x on the same "
          f"scenario ({model.n_ticks} ticks x {model.n_rows} rows)",
          t_jax * 1e6, tail.n_members == n_tail and speedup > 1.0)

    # ---- CVaR on the dense tail -------------------------------------------
    alphas = (0.0, 0.9, 0.99, 0.999)
    t0 = time.perf_counter()
    slo = [tail.slo_cvar("low", a) for a in alphas]
    brk = [tail.brake_cvar(a) for a in alphas]
    us = (time.perf_counter() - t0) * 1e6
    monotone = (all(y >= x - 1e-12 for x, y in zip(slo, slo[1:]))
                and all(y >= x - 1e-12 for x, y in zip(brk, brk[1:])))
    worst = float(tail.brake_counts.max())
    degenerate = abs(brk[-1] - worst) <= max(1e-9, 0.05 * max(worst, 1.0))
    b.add("batched/cvar_tail_10k",
          f"slo_cvar(lp,p99)@a={{{','.join(f'{a:g}:{v:.3f}' for a, v in zip(alphas, slo))}}} "
          f"brake_cvar@0.999={brk[-1]:.1f} (max={worst:.0f}) "
          f"monotone={monotone}",
          us, monotone and degenerate and all(np.isfinite(slo)))
    return b


if __name__ == "__main__":
    module_main(run)
