"""Batched ensemble engine: oracle/kernel parity, grid lowering, dense-tail
throughput (DESIGN.md §15-16).

Validates the subsystem's claims:
  * the jax jit/vmap/`lax.scan` device program AND the Pallas tick kernel
    backend reproduce the numpy tick oracle exactly — brake-tick sets
    bit-identical, power series within 1e-6 relative (the differential
    contract tier-1 drills property-style in tests/test_batched_parity.py
    and tests/test_grid_engine.py);
  * a grid of >= 4 scenarios x 10^3 members runs as ONE scenario-vmapped,
    once-traced device program, bit-identical to the per-scenario loop,
    with per-member throughput >= the flat-vmap engine it grew out of
    (auto member chunking keeps the ~2 KB/member scan carry
    cache-resident); at planner-probe shapes one grid dispatch is strictly
    faster than M sequential jit calls;
  * a 10^5-member tail completes under bounded memory via chunked member
    scans (bit-identical statistics to the unchunked program at 10^4), and
    the member axis shards across host devices without changing a bit;
  * those tails make CVaR meaningful: the `RiskConstraints.slo_cvar_alpha`
    statistic is finite, monotone in alpha, and degenerates to the worst
    member as alpha -> 1 on a 10^4-member tail.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import Bench, module_main, seeded
from repro.experiments.scenario import FleetSpec, Scenario, TrafficSpec
from repro.launch.mesh import data_mesh
from repro.provisioning import (
    EnsembleSpec,
    jax_trace_count,
    lower_ensemble,
    run_batched_ensemble,
    run_ensemble,
    run_tick_model,
    run_tick_models,
)

GRID_GENERATORS = ("diurnal", "bursty", "colocated", "nighttime")


def _scenario(occ_peak: float = 0.97, power_scale: float = 1.15,
              generator: str = "diurnal") -> Scenario:
    import repro.provisioning  # noqa: F401  (registers generator families)
    return seeded(Scenario(
        name=f"batched-bench-{generator}", duration_s=1800.0,
        fleet=FleetSpec(n_provisioned=20, added_frac=0.30, n_rows=2,
                        rows_per_rack=2),
        traffic=TrafficSpec(occ_peak=occ_peak, generator=generator),
        budget="nominal", power_scale=power_scale,
        compare_to_reference=False))


def _same_stats(a, b) -> bool:
    return (np.array_equal(a.brake_counts, b.brake_counts)
            and np.array_equal(a.peak_fracs, b.peak_fracs)
            and np.array_equal(a.mean_fracs, b.mean_fracs))


def _timed(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def run(quick: bool = False) -> Bench:
    b = Bench()
    sc = _scenario()

    # ---- differential parity: numpy tick oracle vs jax device program -----
    # hot enough (scale 1.30) that the brake path is demonstrably covered
    hot = _scenario(occ_peak=0.99, power_scale=1.30)
    model, members, _ = lower_ensemble(EnsembleSpec(hot, n_seeds=4, seed0=77))
    t0 = time.perf_counter()
    oracle = run_tick_model(model, members, engine="numpy")
    jaxed = run_tick_model(model, members, engine="jax")
    us = (time.perf_counter() - t0) * 1e6
    brake_ok = bool(np.array_equal(oracle.brake_fire, jaxed.brake_fire))
    rel = float(np.max(np.abs(jaxed.total_frac - oracle.total_frac)
                       / np.maximum(np.abs(oracle.total_frac), 1e-12)))
    n_brakes = int(oracle.n_brakes.sum())
    b.add("batched/oracle_parity_4members",
          f"brake_sets_identical={brake_ok} ({n_brakes} brakes exercised) "
          f"power_rel_err={rel:.1e} (bound 1e-6)",
          us, brake_ok and rel <= 1e-6 and n_brakes > 0)

    # ---- Pallas tick kernel vs the same oracle ----------------------------
    t0 = time.perf_counter()
    pal = run_tick_model(model, members, engine="pallas")
    us = (time.perf_counter() - t0) * 1e6
    pal_brake_ok = bool(np.array_equal(oracle.brake_fire, pal.brake_fire))
    pal_rel = float(np.max(np.abs(pal.total_frac - oracle.total_frac)
                           / np.maximum(np.abs(oracle.total_frac), 1e-12)))
    b.add("batched/pallas_kernel_parity",
          f"brake_sets_identical={pal_brake_ok} power_rel_err={pal_rel:.1e} "
          f"(bound 1e-6; same {n_brakes}-brake workload as the scan engine)",
          us, pal_brake_ok and pal_rel <= 1e-6)

    # ---- grid: M scenarios as ONE scenario-vmapped program ----------------
    # Engine-layer measurements on pre-lowered models, so the measured
    # object is exactly the jit call. Two regimes, two gates:
    #  * 4 x 10^3 members — bit-identity vs the per-scenario loop, ONE
    #    trace, and per-member throughput >= the flat (unchunked) vmap the
    #    auto member_chunk replaces, on the same 4000-member workload: the
    #    scan carry is ~2 KB/member, so the flat program falls off the L2
    #    cliff that cache-sized member blocks avoid;
    #  * 4 x 50 members (a plan_capacity probe shape) — one dispatch
    #    strictly faster than M sequential jit calls. On a single-core
    #    host the big-N regime ties (identical member-tick work, nothing
    #    to parallelize), so dispatch amortization carries this gate.
    n_grid = 1000
    lowered = [lower_ensemble(EnsembleSpec(_scenario(generator=g),
                                           n_seeds=n_grid, seed0=11))
               for g in GRID_GENERATORS]
    g_models = [m for m, _, _ in lowered]
    M = len(g_models)
    tr0 = jax_trace_count()
    grid_runs = run_tick_models(g_models, keep_series=False)
    one_trace = jax_trace_count() - tr0 == 1
    loop_runs = [run_tick_model(m, mem, engine="jax", keep_series=False)
                 for m, mem, _ in lowered]
    grid_identical = all(
        np.array_equal(g.n_brakes, l.n_brakes)
        and np.array_equal(g.peak_frac, l.peak_frac)
        and np.array_equal(g.mean_frac, l.mean_frac)
        for g, l in zip(grid_runs, loop_runs))
    t_grid = min(_timed(lambda: run_tick_models(g_models, keep_series=False))
                 for _ in range(3))
    t_loop = min(_timed(lambda: [
        run_tick_model(m, mem, engine="jax", keep_series=False)
        for m, mem, _ in lowered]) for _ in range(3))
    mps_grid = M * n_grid / t_grid
    flat_model, flat_mem, _ = lower_ensemble(
        EnsembleSpec(_scenario(), n_seeds=M * n_grid, seed0=11))
    kw_flat = dict(engine="jax", keep_series=False, member_chunk=0)
    run_tick_model(flat_model, flat_mem, **kw_flat)
    t_flat = min(_timed(lambda: run_tick_model(flat_model, flat_mem,
                                               **kw_flat))
                 for _ in range(2))
    mps_flat = M * n_grid / t_flat
    b.add(f"batched/grid_{M}x{n_grid}_members",
          f"bit_identical_to_loop={grid_identical} one_trace={one_trace} "
          f"grid={t_grid * 1e3:.0f}ms ({mps_grid:.0f} members/s, "
          f"auto-chunked) vs flat-vmap engine {mps_flat:.0f} members/s on "
          f"the same {M * n_grid} members; {M} sequential jit calls: "
          f"{t_loop * 1e3:.0f}ms (ties within noise on 1 core)",
          t_grid * 1e6,
          grid_identical and one_trace and mps_grid >= mps_flat)

    n_small = 50
    sm_lowered = [lower_ensemble(EnsembleSpec(_scenario(generator=g),
                                              n_seeds=n_small, seed0=17))
                  for g in GRID_GENERATORS]
    sm_models = [m for m, _, _ in sm_lowered]
    run_tick_models(sm_models, keep_series=False)
    [run_tick_model(m, mem, engine="jax", keep_series=False)
     for m, mem, _ in sm_lowered]
    t_sm_grid = min(_timed(lambda: run_tick_models(sm_models,
                                                   keep_series=False))
                    for _ in range(5))
    t_sm_loop = min(_timed(lambda: [
        run_tick_model(m, mem, engine="jax", keep_series=False)
        for m, mem, _ in sm_lowered]) for _ in range(5))
    b.add(f"batched/grid_one_dispatch_vs_{M}_calls",
          f"{M} scenarios x {n_small} members (planner probe shape): "
          f"grid={t_sm_grid * 1e3:.0f}ms vs {M} sequential jit "
          f"calls={t_sm_loop * 1e3:.0f}ms "
          f"({t_sm_loop / t_sm_grid:.2f}x)",
          t_sm_grid * 1e6, t_sm_grid < t_sm_loop)

    # ---- dense-tail throughput: 10^4 members vs the DES fork pool ---------
    n_tail = 10_000
    t0 = time.perf_counter()
    tail = run_batched_ensemble(EnsembleSpec(sc, n_seeds=n_tail, seed0=1),
                                engine="jax", keep_series=False)
    t_jax = time.perf_counter() - t0
    mps_jax = n_tail / t_jax
    n_ref = 2 if quick else 4  # DES members measured, extrapolated linearly
    t0 = time.perf_counter()
    run_ensemble(EnsembleSpec(sc, n_seeds=n_ref, seed0=1), engine="numpy")
    mps_pool = n_ref / (time.perf_counter() - t0)
    speedup = mps_jax / max(1e-9, mps_pool)
    b.add(f"batched/throughput_{n_tail}_members",
          f"jax={mps_jax:.0f} members/s vs fork_pool={mps_pool:.1f} "
          f"members/s (est from {n_ref}) speedup={speedup:.0f}x on the same "
          f"scenario ({model.n_ticks} ticks x {model.n_rows} rows)",
          t_jax * 1e6, tail.n_members == n_tail and speedup > 1.0)

    # ---- chunked member scan: identical bits, bounded memory --------------
    # the 10^4 tail re-run in member_chunk blocks must be bit-identical to
    # the flat vmap above, then the big tail (10^5 full / 2x10^4 quick)
    # rides the same chunked program — live state per block stays
    # chunk-sized regardless of N
    chunk = 2048
    chunked = run_batched_ensemble(EnsembleSpec(sc, n_seeds=n_tail, seed0=1),
                                   engine="jax", keep_series=False,
                                   member_chunk=chunk)
    chunk_identical = _same_stats(tail, chunked)
    n_big = 20_000 if quick else 100_000
    t0 = time.perf_counter()
    big = run_batched_ensemble(EnsembleSpec(sc, n_seeds=n_big, seed0=1),
                               engine="jax", keep_series=False,
                               keep_brake_fire=False, member_chunk=4096)
    t_big = time.perf_counter() - t0
    b.add(f"batched/chunked_tail_{n_big}_members",
          f"chunk={chunk}_bit_identical_at_{n_tail}={chunk_identical}; "
          f"{n_big}-member tail in {t_big:.1f}s "
          f"({n_big / t_big:.0f} members/s, chunk=4096, series+brake-plane "
          f"dropped, dense member stats) brake_prob={big.brake_prob():.4f}",
          t_big * 1e6,
          chunk_identical and big.n_members == n_big
          and bool(np.isfinite(big.peak_fracs).all()))

    # ---- sharded member axis (host devices) -------------------------------
    import jax as _jax
    n_dev = len(_jax.devices())
    sharded = run_batched_ensemble(EnsembleSpec(sc, n_seeds=n_tail, seed0=1),
                                   engine="jax", keep_series=False,
                                   mesh=data_mesh())
    b.add(f"batched/sharded_{n_tail}_members",
          f"data_mesh over {n_dev} device(s) bit-identical to single-device "
          f"program: {_same_stats(tail, sharded)} (tests force 8 host CPU "
          "devices; smoke sets XLA_FLAGS for this module)",
          0.0, _same_stats(tail, sharded))

    # ---- CVaR on the dense tail -------------------------------------------
    alphas = (0.0, 0.9, 0.99, 0.999)
    t0 = time.perf_counter()
    slo = [tail.slo_cvar("low", a) for a in alphas]
    brk = [tail.brake_cvar(a) for a in alphas]
    us = (time.perf_counter() - t0) * 1e6
    monotone = (all(y >= x - 1e-12 for x, y in zip(slo, slo[1:]))
                and all(y >= x - 1e-12 for x, y in zip(brk, brk[1:])))
    worst = float(tail.brake_counts.max())
    degenerate = abs(brk[-1] - worst) <= max(1e-9, 0.05 * max(worst, 1.0))
    b.add("batched/cvar_tail_10k",
          f"slo_cvar(lp,p99)@a={{{','.join(f'{a:g}:{v:.3f}' for a, v in zip(alphas, slo))}}} "
          f"brake_cvar@0.999={brk[-1]:.1f} (max={worst:.0f}) "
          f"monotone={monotone}",
          us, monotone and degenerate and all(np.isfinite(slo)))
    return b


if __name__ == "__main__":
    module_main(run)
