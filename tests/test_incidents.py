"""Incident reconstruction from synthetic event traces
(repro.obs.incidents + the tools/incidents.py CLI)."""

import json
import os
import sys

import pytest

from repro.obs.incidents import (
    INCIDENTS_NAME,
    incidents_json,
    reconstruct_incidents,
    render_incidents_markdown,
)
from repro.obs.metrics import Event, label_key

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "tools"))


def _ev(t, subsystem, kind, **labels):
    return Event(float(t), subsystem, kind, label_key(labels))


def _fault(t_apply, fault, target, *, t_sched=None, phase="fault_apply"):
    return _ev(t_apply, "chaos", phase, fault=fault, target=target,
               t_sched=t_sched if t_sched is not None else t_apply)


def _engage(t, name, rule, target="", value=1.0):
    return _ev(t, "alert", "alert_engage", alert=name, rule=rule,
               target=target, value=value)


def _release(t, name):
    return _ev(t, "alert", "alert_release", alert=name)


# ------------------------------------------------------------ basic shapes

def test_empty_trace_yields_empty_report():
    rep = reconstruct_incidents([])
    assert rep.n_incidents == 0 and rep.n_false_alarms == 0
    assert rep.n_events == 0
    doc = incidents_json(rep)
    assert doc["incidents"] == [] and doc["false_alarms"] == []
    md = render_incidents_markdown(rep)
    assert "0 incident(s)" in md


def test_single_fault_full_timeline():
    trace = [
        _fault(120.0, "node-derate", "row3", t_sched=100.0),  # ramped
        _engage(110.0, "cap-proximity:pdu0", "cap-proximity", "pdu0", 0.97),
        _ev(130.0, "row", "brake_engage", row="row3"),
        _ev(140.0, "controller", "rebalance", n_moves=2),
        _ev(150.0, "row", "brake_release", row="row3"),
        _fault(400.0, "node-derate", "row3", phase="fault_restore"),
        _release(410.0, "cap-proximity:pdu0"),
    ]
    rep = reconstruct_incidents(trace)
    assert rep.n_incidents == 1 and rep.n_false_alarms == 0
    inc = rep.incidents[0]
    assert (inc.kind, inc.target) == ("node-derate", "row3")
    assert (inc.t_sched, inc.t_apply, inc.t_restore) == (100.0, 120.0, 400.0)
    # detection measured against the schedule: the ramp was caught before
    # its apply record landed
    assert inc.detection_latency_s() == 10.0
    assert inc.detection_after_apply_s() == -10.0
    assert inc.detection_latency_ticks(2.0) == 5.0
    assert inc.time_to_mitigation_s() == 40.0
    assert inc.time_to_clear_s() == 10.0
    assert inc.n_brake_edges == 2 and inc.n_rebalances == 1
    assert not inc.unresolved
    a = inc.alerts[0]
    assert (a.name, a.t_engage, a.t_release) == ("cap-proximity:pdu0",
                                                 110.0, 410.0)
    assert a.value == pytest.approx(0.97)


def test_overlapping_faults_share_alerts():
    trace = [
        _fault(100.0, "node-derate", "row0"),
        _fault(150.0, "site-demand-response", "site"),
        _engage(160.0, "cap-proximity:pdu0", "cap-proximity", "pdu0"),
        _fault(200.0, "node-derate", "row0", phase="fault_restore"),
        _engage(250.0, "slo-burn", "slo-burn"),  # only the DR still open
        _fault(300.0, "site-demand-response", "site",
               phase="fault_restore"),
        _release(310.0, "cap-proximity:pdu0"),
        _release(320.0, "slo-burn"),
    ]
    rep = reconstruct_incidents(trace)
    assert rep.n_incidents == 2 and rep.n_false_alarms == 0
    derate, dr = rep.incidents
    # the 160 s engage falls inside both windows: attributed to both
    assert [a.name for a in derate.alerts] == ["cap-proximity:pdu0"]
    assert [a.name for a in dr.alerts] == ["cap-proximity:pdu0", "slo-burn"]
    # one release resolves every attributed copy of the alert
    assert all(a.t_release == 310.0 for a in derate.alerts)
    assert dr.alerts[1].t_release == 320.0
    assert not derate.unresolved and not dr.unresolved


def test_never_releasing_alert_keeps_incident_open():
    trace = [
        _fault(100.0, "node-derate", "row1"),
        _engage(110.0, "brake-storm", "brake-storm"),
        _fault(200.0, "node-derate", "row1", phase="fault_restore"),
        # no release before the trace ends
    ]
    rep = reconstruct_incidents(trace)
    inc = rep.incidents[0]
    assert inc.t_restore == 200.0
    assert inc.alerts[0].t_release is None
    assert inc.unresolved
    assert inc.time_to_clear_s() is None
    assert "(open)" in render_incidents_markdown(rep)


def test_unrestored_fault_is_unresolved_and_absorbs_late_engages():
    trace = [
        _fault(100.0, "row-crash", "row2"),
        _engage(99999.0, "fault-active", "fault-active"),  # open-ended window
    ]
    rep = reconstruct_incidents(trace)
    inc = rep.incidents[0]
    assert inc.t_restore is None and inc.unresolved
    assert inc.time_to_clear_s() is None
    assert [a.name for a in inc.alerts] == ["fault-active"]
    assert rep.n_false_alarms == 0


def test_row_crash_closed_by_row_revive():
    trace = [
        _fault(100.0, "row-crash", "row2"),
        _fault(500.0, "row-revive", "row2"),  # revive *apply* closes it
    ]
    rep = reconstruct_incidents(trace)
    assert rep.n_incidents == 1  # the revive is a closer, not an incident
    inc = rep.incidents[0]
    assert inc.kind == "row-crash" and inc.t_restore == 500.0
    assert not inc.unresolved


def test_out_of_order_jsonl_is_stably_resorted():
    trace = [
        _fault(120.0, "node-derate", "row3", t_sched=100.0),
        _engage(110.0, "cap-proximity:pdu0", "cap-proximity", "pdu0", 0.97),
        _ev(140.0, "controller", "rebalance"),
        _fault(400.0, "node-derate", "row3", phase="fault_restore"),
        _release(410.0, "cap-proximity:pdu0"),
    ]
    shuffled = [trace[i] for i in (4, 1, 3, 0, 2)]
    a = incidents_json(reconstruct_incidents(trace))
    b = incidents_json(reconstruct_incidents(shuffled))
    assert a == b
    assert a["incidents"][0]["detection_latency_s"] == 10.0


def test_engage_outside_any_window_is_a_false_alarm():
    trace = [
        _fault(100.0, "node-derate", "row0"),
        _fault(200.0, "node-derate", "row0", phase="fault_restore"),
        _engage(250.0, "cap-proximity:pdu0", "cap-proximity", "pdu0", 1.01),
    ]
    rep = reconstruct_incidents(trace)
    assert rep.n_false_alarms == 1
    assert rep.incidents[0].alerts == []
    doc = incidents_json(rep)
    assert doc["false_alarms"][0]["t"] == 250.0
    assert doc["false_alarms"][0]["alert"] == "cap-proximity:pdu0"
    assert "false alarms" in render_incidents_markdown(rep)


def test_fault_active_is_ground_truth_not_detection():
    trace = [
        _fault(100.0, "node-derate", "row0"),
        _engage(102.0, "fault-active", "fault-active"),
        _engage(130.0, "cap-proximity:pdu0", "cap-proximity", "pdu0"),
        _fault(300.0, "node-derate", "row0", phase="fault_restore"),
    ]
    inc = reconstruct_incidents(trace).incidents[0]
    det = inc.first_detection()
    assert det.name == "cap-proximity:pdu0"  # telemetry rule wins
    assert inc.detection_latency_s() == 30.0
    # with only the ground-truth alert, it is the fallback detection
    inc2 = reconstruct_incidents(trace[:2] + trace[3:]).incidents[0]
    assert inc2.first_detection().name == "fault-active"


def test_time_to_clear_floors_at_zero():
    trace = [
        _fault(100.0, "node-derate", "row0"),
        _engage(110.0, "slo-burn", "slo-burn"),
        _release(150.0, "slo-burn"),  # cleared *during* the fault
        _fault(300.0, "node-derate", "row0", phase="fault_restore"),
    ]
    inc = reconstruct_incidents(trace).incidents[0]
    assert inc.time_to_clear_s() == 0.0


# ----------------------------------------------------------------- the CLI

def test_incidents_cli_round_trip(tmp_path):
    import incidents as cli
    lines = [
        {"ts": 120.0, "subsystem": "chaos", "kind": "fault_apply",
         "labels": {"fault": "node-derate", "target": "row3",
                    "t_sched": "100.0"}},
        {"ts": 130.0, "subsystem": "alert", "kind": "alert_engage",
         "labels": {"alert": "cap-proximity:pdu0", "rule": "cap-proximity",
                    "target": "pdu0", "value": "0.97"}},
        {"ts": 400.0, "subsystem": "chaos", "kind": "fault_restore",
         "labels": {"fault": "node-derate", "target": "row3",
                    "t_sched": "400.0"}},
        {"ts": 410.0, "subsystem": "alert", "kind": "alert_release",
         "labels": {"alert": "cap-proximity:pdu0"}},
    ]
    with open(tmp_path / "events.jsonl", "w") as f:
        for rec in lines:
            f.write(json.dumps(rec) + "\n")
    (tmp_path / "manifest.json").write_text(json.dumps(
        {"scenario": {"telemetry": {"telemetry_s": 2.0}}}))
    doc, rep, tick_s = cli.build_incidents(str(tmp_path))
    assert tick_s == 2.0
    assert doc["n_incidents"] == 1 and doc["n_false_alarms"] == 0
    assert doc["incidents"][0]["detection_latency_s"] == 30.0
    assert doc["incidents"][0]["detection_latency_ticks"] == 15.0
    on_disk = json.loads((tmp_path / INCIDENTS_NAME).read_text())
    assert on_disk == doc


def test_incidents_cli_missing_trace(tmp_path):
    import incidents as cli
    assert cli.main([str(tmp_path)]) == 1
