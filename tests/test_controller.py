"""Fleet power-rebalancing controller: conservation on every rebalance tick,
static-policy bit-parity with controller-less (PR 3) fleets, determinism
across Monte-Carlo worker counts, forecaster/router units, and ControllerSpec
serialization."""

import numpy as np
import pytest

from repro.experiments import (
    ControllerSpec,
    FleetSpec,
    PolicySpec,
    RoutingSpec,
    Scenario,
    TrafficSpec,
    get_scenario,
    run_experiment,
)
from repro.fleet import (
    FleetController,
    ForecastAwareRouter,
    PowerForecaster,
    PredictiveRebalancePolicy,
    ProportionalDemandPolicy,
    StaticBudgetPolicy,
    build_controller,
    build_rebalance_policy,
)
from repro.fleet.router import RowView
from repro.provisioning import EnsembleSpec, run_ensemble


def _fleet_scenario(**kw) -> Scenario:
    base = dict(
        name="controller-test",
        duration_s=1800.0,
        fleet=FleetSpec(n_provisioned=16, added_frac=0.25, n_rows=4,
                        rows_per_rack=2,
                        row_budget_fracs=(1.0, 1.0, 1.0, 0.7)),
        policy=PolicySpec("polca"),
        traffic=TrafficSpec(occ_peak=0.9),
        routing=RoutingSpec("cap-aware"),
        controller=ControllerSpec("predictive", interval_s=30.0),
        budget="nominal",
        compare_to_reference=False,
    )
    base.update(kw)
    return Scenario(**base)


# ------------------------------------------------------------- forecaster
def test_forecaster_flat_series_predicts_current():
    fc = PowerForecaster(2, horizon_s=40.0)
    for t in (2.0, 4.0, 6.0, 8.0):
        fc.observe(t, np.array([100.0, 50.0]))
    assert np.allclose(fc.forecast_w(), [100.0, 50.0])


def test_forecaster_extrapolates_rising_clamps_falling():
    fc = PowerForecaster(2, horizon_s=10.0)
    for i, t in enumerate((2.0, 4.0, 6.0, 8.0)):
        fc.observe(t, np.array([100.0 + 10.0 * i, 100.0 - 10.0 * i]))
    pred = fc.forecast_w()
    # rising at 5 W/s -> +50 W over the horizon; falling clamps at current
    assert pred[0] == pytest.approx(130.0 + 50.0)
    assert pred[1] == pytest.approx(70.0), "falling trend never frees budget"


def test_forecaster_few_samples_returns_current():
    fc = PowerForecaster(1)
    assert np.allclose(fc.forecast_w(), [0.0])
    fc.observe(2.0, np.array([42.0]))
    assert np.allclose(fc.forecast_w(), [42.0])


# ------------------------------------------------------- forecast router
def _view(i, **kw):
    base = dict(index=i, power_frac=0.5, headroom_w=100.0, braked=False,
                t1_capped=False, t2_capped=False, hp_capped=False,
                pool_size=4, pool_idle=2, pool_queued=0)
    base.update(kw)
    return RowView(**base)


def _req(priority="high"):
    from repro.core.simulator import Request
    return Request(t_arrival=0.0, wl=0, prompt=128, out_tokens=128,
                   priority=priority, rid=0)


def test_forecast_router_penalizes_predicted_overshoot():
    r = ForecastAwareRouter()
    views = [_view(0, forecast_frac=1.05), _view(1, forecast_frac=0.7)]
    row, reason = r.route(_req(), views)
    assert row == 1
    # without forecasts it degrades to plain cap-aware (tie -> lowest index)
    views = [_view(0), _view(1)]
    assert r.route(_req(), views)[0] == 0
    # predicted-hot picks get a dedicated reason tag
    views = [_view(0, forecast_frac=1.2), _view(1, forecast_frac=1.3)]
    _, reason = r.route(_req(), views)
    assert reason == "forecast-aware/forecast-hot"


def test_forecast_router_registered():
    from repro.fleet import build_router
    r = build_router("forecast-aware", {"forecast_penalty": 3.0})
    assert isinstance(r, ForecastAwareRouter)
    assert r.needs_forecast and r.needs_views
    assert r.forecast_penalty == 3.0


# ------------------------------------------------------------ controller
def test_rebalance_registry_round_trip():
    for kind, cls in (("static", StaticBudgetPolicy),
                      ("proportional", ProportionalDemandPolicy),
                      ("predictive", PredictiveRebalancePolicy)):
        assert isinstance(build_rebalance_policy(kind), cls)
    with pytest.raises(KeyError):
        build_rebalance_policy("nope")
    with pytest.raises(ValueError):
        FleetController(StaticBudgetPolicy(), scope="row")
    with pytest.raises(ValueError):
        FleetController(StaticBudgetPolicy(), alpha=0.0)
    with pytest.raises(ValueError):
        # a zero floor could zero a row's budget (division by zero at its
        # next telemetry sample)
        FleetController(StaticBudgetPolicy(), min_share=0.0)


def test_controller_spec_serializable():
    sc = _fleet_scenario()
    assert Scenario.from_json(sc.to_json()) == sc
    spec = ControllerSpec("proportional", params={"x": 1}, interval_s=10.0,
                          scope="cluster", alpha=0.3, min_share=0.2)
    assert ControllerSpec(**{k: v for k, v in spec.__dict__.items()}) == spec


def test_with_controller_splits_spec_and_policy_params():
    sc = _fleet_scenario().with_controller("proportional", interval_s=15.0,
                                           scope="cluster")
    assert sc.controller.kind == "proportional"
    assert sc.controller.interval_s == 15.0
    assert sc.controller.scope == "cluster"
    assert sc.controller.params == {}


def test_rebalance_scenarios_registered_and_serializable():
    for name in ("fleet-rebalance-static", "fleet-rebalance-proportional",
                 "fleet-rebalance-predictive",
                 "fleet-rebalance-forecast-router"):
        sc = get_scenario(name)
        assert sc.routing is not None and sc.controller is not None
        assert Scenario.from_json(sc.to_json()) == sc


def test_conservation_every_rebalance_tick():
    """Acceptance: the sum of row budgets equals the fixed rack envelope at
    every applied rebalance, and the recorded per-tick budget matrix
    conserves the cluster envelope on every telemetry tick."""
    sc = _fleet_scenario()
    o = run_experiment(sc)
    f = o.fleet
    assert f.n_rebalances > 0, "the derated cluster must trigger rebalances"
    hier_rack = [(0, 1), (2, 3)]
    for ev in f.rebalances:
        for rack in hier_rack:
            before = sum(ev.budgets_before_w[list(rack)])
            after = sum(ev.budgets_after_w[list(rack)])
            assert after == pytest.approx(before, abs=1e-6)
        assert ev.moved_w() > 0.0
    # per-tick budget matrix: cluster total never moves
    totals = f.row_budget_w.sum(axis=1)
    assert np.allclose(totals, totals[0], atol=1e-6)


def test_cluster_scope_conserves_cluster_envelope():
    sc = _fleet_scenario(controller=ControllerSpec(
        "proportional", interval_s=30.0, scope="cluster"))
    o = run_experiment(sc)
    f = o.fleet
    assert f.n_rebalances > 0
    for ev in f.rebalances:
        assert ev.budgets_after_w.sum() == pytest.approx(
            ev.budgets_before_w.sum(), abs=1e-6)
    totals = f.row_budget_w.sum(axis=1)
    assert np.allclose(totals, totals[0], atol=1e-6)


def test_min_share_floor_holds():
    sc = _fleet_scenario(controller=ControllerSpec(
        "proportional", interval_s=30.0, min_share=0.5))
    o = run_experiment(sc)
    f = o.fleet
    # rack envelope = row budgets of its two rows; floor = 0.5 * env / 2
    env = f.row_budget_w[0, 2] + f.row_budget_w[0, 3]
    floor = 0.5 * env / 2
    assert float(f.row_budget_w[:, 2:].min()) >= floor - 1e-6


def test_budget_moves_toward_derated_row_demand():
    """The derated row (same traffic pressure, 30% less budget) must gain
    budget from its rack partner once rebalancing runs."""
    sc = _fleet_scenario()
    o = run_experiment(sc)
    fb = o.fleet.row_budget_w
    assert float(fb[:, 3].max()) > float(fb[0, 3]), "derated row gains budget"
    assert float(fb[:, 2].min()) < float(fb[0, 2]), "its rack partner cedes"


def test_static_controller_bit_parity_with_pr3_fleet():
    """Acceptance: ControllerSpec('static') fleets are bit-identical to
    controller-less fleets — latencies, decisions, power series, fractions."""
    sc = _fleet_scenario(controller=ControllerSpec("static"))
    a = run_experiment(sc)
    b = run_experiment(sc.with_(controller=None))
    assert a.result.latencies == b.result.latencies
    assert a.fleet.decisions == b.fleet.decisions
    assert np.array_equal(a.fleet.cluster_power_frac,
                          b.fleet.cluster_power_frac)
    assert np.array_equal(a.fleet.row_power_frac, b.fleet.row_power_frac)
    assert a.fleet.n_rebalances == 0
    assert a.result.n_brakes == b.result.n_brakes
    # budgets were recorded but never moved
    assert np.all(a.fleet.row_budget_w == a.fleet.row_budget_w[0])


def test_controller_determinism_and_seed_sensitivity():
    sc = _fleet_scenario()
    a = run_experiment(sc)
    b = run_experiment(sc)
    c = run_experiment(sc.with_(seed=sc.seed + 1))
    assert a.result.latencies == b.result.latencies
    assert len(a.fleet.rebalances) == len(b.fleet.rebalances)
    for ea, eb in zip(a.fleet.rebalances, b.fleet.rebalances):
        assert ea.t == eb.t
        assert np.array_equal(ea.budgets_after_w, eb.budgets_after_w)
    assert a.result.latencies != c.result.latencies, "seed must matter"


def test_controller_ensemble_worker_invariance():
    """Acceptance: controller-bearing fleet members produce bit-identical
    ensembles regardless of worker count (determinism across workers)."""
    base = _fleet_scenario(duration_s=1200.0)
    e1 = run_ensemble(EnsembleSpec(base, n_seeds=3, seed0=700, n_workers=1))
    e2 = run_ensemble(EnsembleSpec(base, n_seeds=3, seed0=700, n_workers=3))
    assert np.array_equal(e1.brake_counts, e2.brake_counts)
    for m1, m2 in zip(e1.members, e2.members):
        assert m1.result.latencies == m2.result.latencies
        assert np.array_equal(m1.result.power_w, m2.result.power_w)


def test_controller_ensemble_matches_sequential_run_experiment():
    base = _fleet_scenario(duration_s=1200.0)
    spec = EnsembleSpec(base, n_seeds=2, seed0=700, n_workers=2)
    ens = run_ensemble(spec)
    for m, sc in zip(ens.members, spec.member_scenarios(ens.budget_w)):
        o = run_experiment(sc)
        assert m.result.latencies == o.result.latencies
        assert m.result.n_brakes == o.result.n_brakes


def test_row_fracs_measured_against_in_force_budgets():
    """Per-row peak/mean power fractions under a controller are measured
    against the budget in force when the power was drawn (budget eras), not
    the final rebalanced budget — the derated row's enlarged final budget
    must not deflate its early near-brake peak."""
    sc = _fleet_scenario()
    o = run_experiment(sc)
    f = o.fleet
    derated = 3
    assert float(f.row_budget_w[-1, derated]) > float(f.row_budget_w[0, derated])
    rr = f.row_results[derated]
    # tick-grid fraction peak (already era-correct) lower-bounds the
    # event-level era-accounted peak; final-budget division would undershoot
    assert rr.peak_power_frac >= float(f.row_power_frac[:, derated].max()) - 1e-9
    # and the ceding partner's fractions never exceed a budget it honored
    partner = 2
    assert f.row_results[partner].peak_power_frac <= \
        float(f.row_power_frac[:, partner].max()) + 1e-9 or \
        f.row_results[partner].peak_power_frac <= 1.0 + 1e-9


def test_controller_rebinds_fresh_across_fleets():
    """One FleetController instance reused across two FleetSimulators must
    rebalance both runs and not leak the first run's events into the second
    (bind() resets schedule + event log)."""
    from repro.experiments.runner import build_workloads, resolve_budget
    from repro.fleet.fleet import build_fleet, fleet_trace
    from repro.fleet import FleetSimulator, build_router
    sc = _fleet_scenario(duration_s=900.0)
    wls, shares = build_workloads(sc)
    server = sc.fleet.server()
    budget = resolve_budget(sc, wls, shares, server)
    reqs = fleet_trace(sc, wls, shares)
    first = build_fleet(sc, wls, shares, server, budget, sc.policy.build, reqs)
    ctl = first.controller
    r1 = first.run()
    assert r1.n_rebalances > 0
    from repro.experiments.runner import row_sim
    from repro.fleet.fleet import row_budgets
    rows = [row_sim(sc, wls, shares, server, b, sc.policy.build(), [],
                    row_index=i)
            for i, b in enumerate(row_budgets(sc, budget, server))]
    second = FleetSimulator(rows, reqs, router=build_router("cap-aware"),
                            rows_per_rack=sc.fleet.rows_per_rack,
                            telemetry_s=sc.telemetry.telemetry_s,
                            controller=ctl)
    r2 = second.run()
    assert r2.n_rebalances > 0, "reused controller must rebalance run 2"
    assert r2.rebalances[0].t < sc.duration_s
    assert len(r2.rebalances) == len(r1.rebalances)


def test_controller_spec_carries_deadband():
    sc = _fleet_scenario().with_controller("proportional", deadband_w=50.0)
    assert sc.controller.deadband_w == 50.0
    assert sc.controller.params == {}, "deadband_w is a spec field, not a policy param"
    from repro.fleet import build_controller
    assert build_controller(sc.controller).deadband_w == 50.0


def test_reference_twin_never_carries_controller():
    from repro.experiments.runner import build_workloads, resolve_budget
    from repro.fleet.fleet import build_fleet, fleet_trace
    sc = _fleet_scenario()
    wls, shares = build_workloads(sc)
    server = sc.fleet.server()
    budget = resolve_budget(sc, wls, shares, server)
    reqs = fleet_trace(sc, wls, shares)
    ref = build_fleet(sc, wls, shares, server, budget, sc.policy.build, reqs,
                      reference=True)
    assert ref.controller is None
    live = build_fleet(sc, wls, shares, server, budget, sc.policy.build, reqs)
    assert live.controller is not None
