"""resolve_spec / rules properties (hypothesis) + cache padding."""

import jax
import pytest
from _hypothesis_compat import given, settings, st  # real hypothesis in CI
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import make_local_mesh
from repro.models.param import resolve_spec, serve_rules, train_rules


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape


@given(st.integers(1, 4096), st.integers(1, 16), st.integers(1, 16))
@settings(max_examples=200, deadline=None)
def test_resolve_spec_always_divides(dim, a, b):
    mesh = FakeMesh({"data": a, "model": b})
    rules = {"x": ("data", "model")}
    spec = resolve_spec((dim,), ("x",), rules, mesh)
    axes = spec[0]
    if axes is None:
        return
    axes = (axes,) if isinstance(axes, str) else axes
    prod = 1
    for ax in axes:
        prod *= mesh.shape[ax]
    assert dim % prod == 0


@given(st.integers(1, 64))
@settings(max_examples=50, deadline=None)
def test_resolve_spec_keeps_full_rule_when_divisible(k):
    mesh = FakeMesh({"data": 4, "model": 8})
    spec = resolve_spec((32 * k,), ("x",), {"x": ("data", "model")}, mesh)
    assert spec[0] == ("data", "model")


def test_cache_len_padding():
    from repro.models.model import CACHE_PAD, cache_len

    assert cache_len(512) == 512
    assert cache_len(31268) % CACHE_PAD == 0
    assert cache_len(31268) >= 31268
    assert cache_len(1) == CACHE_PAD


def test_rules_have_all_logical_axes():
    for rules in (train_rules(False), train_rules(True),
                  serve_rules(False), serve_rules(True, True)):
        for k in ("embed", "heads", "mlp", "vocab", "batch", "kv_seq",
                  "expert_slot", "expert_embed", "ssm_inner"):
            assert k in rules
