"""Grid-engine semantics (DESIGN.md §16): scenario-axis vmap, member
chunking, device sharding, dense-tail statistics, and compile-count reuse.

The contract under test is *bit*-identity, not closeness: the grid program,
the chunked program, and the sharded program are the same computation graph
over the same float64 operands, so XLA must produce identical bits — any
drift means the lowering changed the math, exactly what these properties
exist to catch.
"""

import numpy as np
import pytest

from conftest import (
    PARITY_GENERATORS,
    assert_engine_parity,
    parity_scenario,
)
from repro.launch.mesh import data_mesh
from repro.provisioning.batched import (
    jax_trace_count,
    lower_ensemble,
    run_batched_ensemble,
    run_batched_grid,
    run_tick_model,
)
from repro.provisioning.montecarlo import (
    EnsembleSpec,
    run_ensemble,
    run_ensemble_grid,
)
from repro.provisioning.planner import plan_capacity

GRID_GENERATORS = ("diurnal", "bursty", "colocated", "nighttime")


def _grid_specs(n_seeds=4):
    return [EnsembleSpec(parity_scenario(generator=g), n_seeds=n_seeds)
            for g in GRID_GENERATORS]


def _assert_results_identical(a, b):
    assert a.base_name == b.base_name
    np.testing.assert_array_equal(a.brake_counts, b.brake_counts)
    np.testing.assert_array_equal(a.peak_fracs, b.peak_fracs)
    np.testing.assert_array_equal(a.mean_fracs, b.mean_fracs)
    np.testing.assert_array_equal(a.power_frac, b.power_frac)


def test_grid_bit_identical_to_per_scenario_loop_and_one_trace():
    """M scenarios sharing tick geometry: one vmapped program, results
    bit-identical to M independent run_ensemble calls."""
    specs = _grid_specs()
    t0 = jax_trace_count()
    grid = run_batched_grid(specs, engine="jax")
    assert jax_trace_count() - t0 == 1, (
        "a same-geometry grid must lower to ONE traced program")
    loop = [run_ensemble(s, engine="jax") for s in specs]
    for g, l in zip(grid, loop):
        _assert_results_identical(g, l)


def test_run_ensemble_grid_jax_dispatch():
    """montecarlo.run_ensemble_grid(engine='jax') routes to the batched grid
    and keys results by base name, same numbers as run_ensemble."""
    bases = [parity_scenario(generator=g) for g in GRID_GENERATORS[:2]]
    out = run_ensemble_grid(bases, n_seeds=3, engine="jax")
    assert set(out) == {b.name for b in bases}
    for b in bases:
        single = run_ensemble(EnsembleSpec(b, n_seeds=3), engine="jax")
        _assert_results_identical(out[b.name], single)


@pytest.mark.parametrize("chunk", [3, 5, 12])
def test_member_chunk_invariance(chunk):
    """Chunked lax.scan over member blocks (including a non-dividing chunk,
    which pads with cyclic members and slices back) is bit-identical to the
    flat vmap."""
    spec = EnsembleSpec(parity_scenario(generator="bursty"), n_seeds=12)
    flat = run_ensemble(spec, engine="jax")
    chunked = run_ensemble(spec, engine="jax", member_chunk=chunk)
    _assert_results_identical(flat, chunked)


@pytest.mark.parametrize("n_dev", [1, 2, 8])
def test_device_count_invariance(n_dev):
    """shard_map over the forced host-CPU 'data' axis: 1 vs N devices give
    identical bits (the member axis is embarrassingly parallel)."""
    spec = EnsembleSpec(parity_scenario(generator="diurnal"), n_seeds=8)
    base = run_ensemble(spec, engine="jax")
    sharded = run_ensemble(spec, engine="jax", mesh=data_mesh(n_dev))
    _assert_results_identical(base, sharded)


def test_sharded_and_chunked_compose():
    spec = EnsembleSpec(parity_scenario(generator="colocated"), n_seeds=10)
    base = run_ensemble(spec, engine="jax")
    both = run_ensemble(spec, engine="jax", mesh=data_mesh(4), member_chunk=2)
    _assert_results_identical(base, both)


def test_plan_capacity_probe_count_does_not_multiply_compiles():
    """Satellite-6 regression: per-scenario scalars are traced operands, so
    a whole bisection (fleet size varies, budget pinned) compiles once."""
    sc = parity_scenario(generator="diurnal")
    t0 = jax_trace_count()
    plan = plan_capacity(sc, n_seeds=4, engine="jax")
    assert len(plan.probes) >= 3, "bisection too shallow to regression-test"
    assert jax_trace_count() - t0 <= 1, (
        f"{len(plan.probes)} probes retraced the engine "
        f"{jax_trace_count() - t0} times; scalar consts leaked back into "
        "the jit cache key")


@pytest.mark.parametrize("generator", PARITY_GENERATORS)
def test_pallas_engine_parity(generator):
    """The Pallas tick kernel backend satisfies the same oracle contract as
    the scan engine: brake sets bit-identical, power within 1e-6 relative."""
    model, members, _ = lower_ensemble(
        EnsembleSpec(parity_scenario(generator=generator), n_seeds=3))
    oracle = run_tick_model(model, members, engine="numpy")
    pallas = run_tick_model(model, members, engine="pallas")
    assert pallas.engine == "pallas"
    assert_engine_parity(oracle, pallas)


def test_pallas_rejects_predictive():
    model, members, _ = lower_ensemble(EnsembleSpec(
        parity_scenario(policy="polca-predictive"), n_seeds=2))
    with pytest.raises(ValueError, match="predictive"):
        run_tick_model(model, members, engine="pallas")


def test_dense_member_stats_equivalent():
    """member_stats=False drops the per-member python objects but every
    distributional statistic must return the same numbers."""
    spec = EnsembleSpec(parity_scenario(generator="bursty"), n_seeds=12)
    rich = run_batched_ensemble(spec, engine="jax", member_stats=True)
    dense = run_batched_ensemble(spec, engine="jax", member_stats=False)
    assert rich.n_members == dense.n_members == 12
    assert len(dense.members) == 0 and dense.member_impacts_hp is not None
    for prio in ("high", "low"):
        np.testing.assert_array_equal(rich.slo_impacts(prio),
                                      dense.slo_impacts(prio))
        for q in (50.0, 99.0):
            assert rich.slo_percentile(prio, q) == dense.slo_percentile(prio, q)
        for alpha in (0.0, 0.5, 0.9):
            assert rich.slo_cvar(prio, alpha) == dense.slo_cvar(prio, alpha)
    assert rich.meets_fraction() == dense.meets_fraction()
    assert rich.slo_violation_prob() == dense.slo_violation_prob()
    assert rich.summary() == dense.summary()


def test_keep_brake_fire_false_drops_plane_keeps_counts():
    spec = EnsembleSpec(parity_scenario(generator="diurnal"), n_seeds=3)
    model, members, _ = lower_ensemble(spec)
    full = run_tick_model(model, members, engine="jax")
    lean = run_tick_model(model, members, engine="jax", keep_brake_fire=False)
    assert lean.brake_fire is None
    np.testing.assert_array_equal(full.n_brakes, lean.n_brakes)
    with pytest.raises(ValueError, match="keep_brake_fire"):
        lean.brake_ticks()


def test_engine_opts_rejected_on_event_driven_engine():
    spec = EnsembleSpec(parity_scenario(generator="diurnal"), n_seeds=2)
    with pytest.raises(ValueError, match="engine options"):
        run_ensemble(spec, engine="numpy", member_chunk=4)
