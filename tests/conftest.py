"""Shared test fixtures + the batched-engine differential harness helpers.

XLA_FLAGS: the test session pins ``--xla_force_host_platform_device_count=8``
(below, before the first ``import jax``) so the sharded member-axis path of
the batched engine (``launch.mesh.data_mesh`` + ``shard_map``) and the
multi-device expert-parallel MoE tests run for real on CPU CI. This only
affects pytest: ``tools/smoke.sh`` benchmark invocations don't load this
conftest and keep seeing the machine's real device inventory (the grid
benchmark opts in with the same flag itself); ``launch/dryrun.py`` still
forces its own 512 placeholder devices.

Skip audit (every remaining tier-1 skip, with its justification):

* the former ``test_moe.py`` device-count skips (3x "needs 2 devices" at
  test_moe_matches_dense_reference, 2x "needs more devices" at
  test_token_routed_matches_dense_reference) now RUN here on the forced
  8-device host platform; they still self-skip on hosts with fewer devices
  when the flag is overridden.
* ``slow``-marked tests (10^4-member tail smokes) are deselected unless
  ``--runslow`` is passed — the same tail is PASS-gated on every merge via
  ``benchmarks/batched_engine.py`` in tools/smoke.sh.

The four former ``pytest.importorskip("hypothesis")`` module skips
(test_policy/test_simulator/test_roofline/test_sharding) are gone: they now
import ``tests/_hypothesis_compat.py``, which falls back to a seeded-RNG
property replayer when hypothesis isn't installed.
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

_FLAG = "--xla_force_host_platform_device_count=8"
if _FLAG.split("=")[0] not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") + " " + _FLAG).strip()

import jax
import numpy as np
import pytest

from repro.launch.mesh import make_local_mesh


def pytest_addoption(parser):
    parser.addoption("--runslow", action="store_true", default=False,
                     help="run slow-marked tests (10^4-member tail smokes)")


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running dense-tail test; needs --runslow")


def pytest_collection_modifyitems(config, items):
    if config.getoption("--runslow"):
        return
    skip_slow = pytest.mark.skip(
        reason="slow dense-tail test: pass --runslow (benchmarks/"
               "batched_engine.py gates the same tail every merge)")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip_slow)


@pytest.fixture(scope="session")
def mesh1():
    return make_local_mesh(1, 1)


def make_batch(cfg, B, S, seed=0):
    import numpy as np
    import jax.numpy as jnp
    from repro.launch.inputs import split_seq

    rng = np.random.default_rng(seed)
    enc_S, dec_S = split_seq(cfg, S)
    batch = {}
    if cfg.is_encoder_decoder:
        batch["enc_embeds"] = jnp.asarray(
            rng.standard_normal((B, enc_S, cfg.d_model)), jnp.bfloat16)
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, dec_S)), jnp.int32)
    elif cfg.frontend == "vision_stub":
        batch["image_embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg.num_image_embeds, cfg.d_model)), jnp.bfloat16)
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, S - cfg.num_image_embeds)), jnp.int32)
    else:
        batch["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    if cfg.is_encoder_only:
        batch["targets"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, batch["tokens"].shape), jnp.int32)
    return batch


# ---------------------------------------------------------------------------
# batched-engine differential harness (DESIGN.md §15)
#
# Shared by tests/test_batched_parity.py (and importable from any module as
# ``from conftest import ...``): build a randomized scenario, lower it once,
# run the numpy tick oracle and the jax device program on the *same*
# TickModel, and assert the oracle contract — brake-tick sets bit-identical,
# power series within 1e-6 relative, statistics matching.
# ---------------------------------------------------------------------------

PARITY_GENERATORS = ("diurnal", "bursty", "colocated", "failover-surge",
                     "rack-incident", "nighttime")
PARITY_POWER_RTOL = 1e-6  # ISSUE-9 oracle contract bound (measured ~1e-15)


def parity_scenario(*, generator="diurnal", n_rows=2, occ_peak=0.9,
                    duration_s=2 * 3600.0, policy=None, hierarchy=None,
                    faults=None, power_scale=1.08, n_provisioned=20,
                    added_frac=0.30):
    """One randomized-family scenario for the differential harness. Small
    fleets + short horizons keep a property example < 100 ms while still
    exercising T1/T2 caps (and brakes at high ``occ_peak``/``power_scale``)."""
    from repro.experiments.scenario import FleetSpec, Scenario, TrafficSpec
    import repro.provisioning  # noqa: F401  (registers generator families)

    sc = Scenario(
        name=f"parity-{generator}", duration_s=float(duration_s),
        fleet=FleetSpec(n_provisioned=n_provisioned, added_frac=added_frac,
                        n_rows=n_rows, rows_per_rack=max(1, n_rows // 2)),
        traffic=TrafficSpec(occ_peak=float(occ_peak), generator=generator),
        budget="nominal", power_scale=float(power_scale),
        hierarchy=hierarchy, compare_to_reference=False)
    if policy is not None:
        sc = sc.with_policy(policy)
    if faults is not None:
        sc = sc.with_faults(faults)
    return sc


def run_both_engines(scenario, *, n_seeds=3, seed0=1000, keep_series=True):
    """Lower once, run the numpy tick oracle + the jax engine on the same
    TickModel. Returns (model, oracle_run, jax_run)."""
    from repro.provisioning.batched import lower_ensemble, run_tick_model
    from repro.provisioning.montecarlo import EnsembleSpec

    model, members, _ = lower_ensemble(
        EnsembleSpec(scenario, n_seeds=n_seeds, seed0=seed0))
    oracle = run_tick_model(model, members, engine="numpy",
                            keep_series=keep_series)
    jaxed = run_tick_model(model, members, engine="jax",
                           keep_series=keep_series)
    return model, oracle, jaxed


def assert_engine_parity(oracle, jaxed, *, rtol=PARITY_POWER_RTOL):
    """The ISSUE-9 oracle contract, asserted in one place."""
    # brake-tick sets are BIT-identical: same (member, tick, row) triples
    assert np.array_equal(oracle.brake_fire, jaxed.brake_fire), (
        "brake-tick sets differ between engines")
    np.testing.assert_array_equal(oracle.n_brakes, jaxed.n_brakes)
    # power series within rtol relative error
    for name in ("total_frac", "row_w", "node_w"):
        a, b = getattr(oracle, name), getattr(jaxed, name)
        assert (a is None) == (b is None), f"{name} presence differs"
        if a is not None:
            np.testing.assert_allclose(b, a, rtol=rtol, atol=0.0,
                                       err_msg=f"{name} outside {rtol} rel")
    np.testing.assert_allclose(jaxed.peak_frac, oracle.peak_frac, rtol=rtol)
    np.testing.assert_allclose(jaxed.mean_frac, oracle.mean_frac, rtol=rtol)
    # SLO-impact decimation buffers: absolute tolerance (impacts cross zero)
    np.testing.assert_allclose(jaxed.impacts_hp, oracle.impacts_hp,
                               rtol=rtol, atol=1e-9)
    np.testing.assert_allclose(jaxed.impacts_lp, oracle.impacts_lp,
                               rtol=rtol, atol=1e-9)
