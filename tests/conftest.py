"""Shared test fixtures.

NOTE: no XLA_FLAGS here — smoke tests and benches must see the real (single)
CPU device; only launch/dryrun.py forces 512 placeholder devices.
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import pytest

from repro.launch.mesh import make_local_mesh


@pytest.fixture(scope="session")
def mesh1():
    return make_local_mesh(1, 1)


def make_batch(cfg, B, S, seed=0):
    import numpy as np
    import jax.numpy as jnp
    from repro.launch.inputs import split_seq

    rng = np.random.default_rng(seed)
    enc_S, dec_S = split_seq(cfg, S)
    batch = {}
    if cfg.is_encoder_decoder:
        batch["enc_embeds"] = jnp.asarray(
            rng.standard_normal((B, enc_S, cfg.d_model)), jnp.bfloat16)
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, dec_S)), jnp.int32)
    elif cfg.frontend == "vision_stub":
        batch["image_embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg.num_image_embeds, cfg.d_model)), jnp.bfloat16)
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, S - cfg.num_image_embeds)), jnp.int32)
    else:
        batch["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    if cfg.is_encoder_only:
        batch["targets"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, batch["tokens"].shape), jnp.int32)
    return batch
