"""Cluster-simulator behaviour + conservation properties."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # real hypothesis in CI

from repro.core.policy import NoCap, PolcaPolicy
from repro.core.power_model import A100, ServerPower
from repro.core.simulator import Request, RowSimulator, SimConfig
from repro.core.traces import (
    TABLE4,
    build_workload_classes,
    generate_requests,
    mape,
    occupancy_curve,
)

SERVER = ServerPower(A100)
WLS, SHARES = build_workload_classes("bloom-176b", SERVER)


def _run(n_servers, n_prov, policy, dur=1800.0, seed=0, power_scale=1.0, occ=0.97):
    reqs = generate_requests(dur, n_servers, WLS, SHARES, seed=seed,
                             occ_kwargs={"peak": occ})
    sim = RowSimulator(WLS, SERVER, n_servers, n_prov, policy, reqs, SHARES,
                       SimConfig(power_scale=power_scale), duration=dur)
    return sim.run(), reqs


def test_request_conservation():
    res, reqs = _run(20, 20, NoCap(), dur=1200.0)
    in_flight_max = 2 * 20  # one serving + one buffered per server
    assert res.n_completed + res.n_dropped <= len(reqs)
    assert res.n_completed + res.n_dropped >= len(reqs) - in_flight_max


def test_power_within_physical_bounds():
    res, _ = _run(20, 20, NoCap(), dur=1200.0)
    max_possible = 20 * (SERVER.n_devices * SERVER.device.p_peak + SERVER.other_w)
    assert 0 < res.peak_power_frac <= max_possible / (20 * SERVER.provisioned_w)
    assert res.mean_power_frac <= res.peak_power_frac
    idle_frac = SERVER.idle_power / SERVER.provisioned_w
    assert res.mean_power_frac >= idle_frac * 0.99


def test_uncapped_lowload_run_has_near_zero_latency_impact():
    # low occupancy: queues stay empty, so actual ~= unqueued ideal
    res, _ = _run(20, 40, NoCap(), dur=1200.0, occ=0.35)
    s = res.latency.summary()
    assert s["hp_p99"] < 0.02 or s["n_hp"] == 0
    assert res.n_brakes == 0


def test_impact_vs_reference_run_is_zero_for_identical_policies():
    from repro.core.slo import impact_vs_reference

    r1, reqs = _run(24, 20, NoCap(), dur=1200.0, seed=2)
    r2, _ = _run(24, 20, NoCap(), dur=1200.0, seed=2)
    prios = {r.rid: r.priority for r in reqs}
    st = impact_vs_reference(r2.latencies, r1.latencies, prios)
    s = st.summary()
    assert s["hp_p99"] == 0.0 and s["lp_p99"] == 0.0


def test_oversubscription_triggers_capping_and_stays_safe():
    res, _ = _run(30, 20, PolcaPolicy(), dur=2400.0)  # 50% oversubscribed
    assert res.cap_events > 0
    # powerbrake may fire under this extreme ratio, but power always recovers:
    # the final power integral stays below provisioned on average
    assert res.mean_power_frac < 1.0


def test_capping_slows_lp_more_than_hp():
    """Against the uncapped reference run on the same trace, LP (capped first
    and hardest) sees at least the median impact HP sees."""
    from repro.core.slo import impact_vs_reference

    dur = 4800.0
    reqs = generate_requests(dur, 26, WLS, SHARES, seed=5, occ_kwargs={"peak": 0.85})
    prios = {r.rid: r.priority for r in reqs}
    ref = RowSimulator(WLS, SERVER, 26, 200, NoCap(), reqs, SHARES,
                       SimConfig(), duration=dur).run()
    res = RowSimulator(WLS, SERVER, 26, 20, PolcaPolicy(), reqs, SHARES,
                       SimConfig(), duration=dur).run()
    assert res.cap_events > 0
    s = impact_vs_reference(res.latencies, ref.latencies, prios).summary()
    assert s["lp_p50"] >= s["hp_p50"] - 1e-9
    assert s["lp_p99"] >= s["hp_p99"] - 0.05


def test_power_scale_monotone():
    r1, _ = _run(24, 20, NoCap(), dur=1200.0)
    r2, _ = _run(24, 20, NoCap(), dur=1200.0, power_scale=1.05)
    assert r2.peak_power_frac > r1.peak_power_frac
    assert r2.mean_power_frac > r1.mean_power_frac


def test_brakes_fire_on_overload():
    """Deliberate overload (many servers, +15% power) must brake, not melt."""
    res, _ = _run(34, 20, NoCap(), dur=2400.0, power_scale=1.15)
    assert res.n_brakes >= 1


@given(st.integers(min_value=1, max_value=6))
@settings(max_examples=6, deadline=None)
def test_determinism(seed):
    r1, _ = _run(16, 16, PolcaPolicy(), dur=600.0, seed=seed)
    r2, _ = _run(16, 16, PolcaPolicy(), dur=600.0, seed=seed)
    assert r1.n_completed == r2.n_completed
    assert r1.latencies == r2.latencies
    assert np.allclose(r1.power_w, r2.power_w)


def test_mape_helper():
    a = np.array([1.0, 2.0, 3.0])
    assert mape(a, a) == 0.0
    assert abs(mape(a * 1.02, a) - 0.02) < 1e-9


def test_occupancy_curve_bounds():
    t = np.arange(0, 7 * 86400.0, 300.0)
    occ = occupancy_curve(t)
    assert (occ >= 0.05).all() and (occ <= 0.98).all()
    daily = occ[: len(occ) // 7].reshape(-1)
    assert daily.max() - daily.min() > 0.2  # visible diurnal swing
