"""Chaos engine: injectable fault timelines against the routed fleet
(DESIGN.md §13).

Covers the subsystem contract end to end: spec-time and bind-time
validation, no-op bit-parity with the pre-chaos fleets, per-tick budget
conservation through node derates, exact root-envelope round-trips,
crash -> revive accounting, fixed-seed determinism, Monte-Carlo
worker-invariance with fault timelines, and the planner's survivability
gate."""

import numpy as np
import pytest

from repro.chaos import ChaosInjector, FaultEvent, FaultSpec
from repro.experiments import (
    CHAOS_SCENARIO_FAMILY,
    ControllerSpec,
    FleetSpec,
    HierarchySpec,
    PolicySpec,
    RoutingSpec,
    Scenario,
    TrafficSpec,
    get_scenario,
    run_experiment,
)
from repro.provisioning import EnsembleSpec, run_ensemble
from repro.provisioning.planner import RiskConstraints, plan_capacity


def _chaos_scenario(faults=None, **kw) -> Scenario:
    base = dict(
        name="chaos-test",
        duration_s=1500.0,
        fleet=FleetSpec(n_provisioned=16, added_frac=0.25, n_rows=8),
        policy=PolicySpec("polca"),
        traffic=TrafficSpec(occ_peak=0.9),
        routing=RoutingSpec("cap-aware"),
        controller=ControllerSpec("predictive", interval_s=30.0, scope="tree"),
        hierarchy=HierarchySpec(shape=(2, 2, 2)),
        budget="nominal",
        compare_to_reference=False,
        faults=faults,
    )
    base.update(kw)
    return Scenario(**base)


# ------------------------------------------------------------- validation
def test_fault_spec_structural_validation():
    with pytest.raises(ValueError, match="unknown kind"):
        FaultSpec((FaultEvent("meteor-strike", t=10.0),))
    with pytest.raises(ValueError, match="factor"):
        FaultSpec((FaultEvent("node-derate", t=10.0, node="pdu0",
                              factor=1.5),))
    with pytest.raises(ValueError, match="until"):
        FaultSpec((FaultEvent("node-derate", t=100.0, node="pdu0",
                              factor=0.8, until=50.0),))
    with pytest.raises(ValueError, match="node"):
        FaultSpec((FaultEvent("node-derate", t=10.0, factor=0.8),))
    with pytest.raises(ValueError, match="row"):
        FaultSpec((FaultEvent("row-crash", t=10.0),))
    with pytest.raises(ValueError, match="node"):
        FaultSpec((FaultEvent("row-crash", t=10.0, row=0, node="pdu0"),))


def test_bind_time_validation_names_the_offending_event():
    """Events beyond the trace or naming nonexistent hierarchy nodes fail
    at fleet construction, before any simulation runs."""
    with pytest.raises(ValueError, match="duration"):
        run_experiment(_chaos_scenario(
            FaultSpec((FaultEvent("row-crash", t=99999.0, row=0),))))
    with pytest.raises(ValueError, match="no-such-node"):
        run_experiment(_chaos_scenario(
            FaultSpec((FaultEvent("node-derate", t=100.0,
                                  node="no-such-node", factor=0.8),))))
    with pytest.raises(ValueError, match="row"):
        run_experiment(_chaos_scenario(
            FaultSpec((FaultEvent("row-crash", t=100.0, row=99),))))


def test_faults_require_routing():
    sc = _chaos_scenario(
        FaultSpec((FaultEvent("row-crash", t=100.0, row=0),)),
        routing=None, controller=None, hierarchy=None)
    with pytest.raises(ValueError, match="RoutingSpec"):
        run_experiment(sc)


def test_routing_only_keeps_row_events():
    fs = FaultSpec((FaultEvent("row-crash", t=100.0, row=0),
                    FaultEvent("node-derate", t=200.0, node="pdu0",
                               factor=0.8),
                    FaultEvent("row-revive", t=300.0, row=0)))
    ro = fs.routing_only()
    assert [e.kind for e in ro.events] == ["row-crash", "row-revive"]
    assert FaultSpec().routing_only().is_noop


def test_chaos_family_registered_and_serializable():
    for name in CHAOS_SCENARIO_FAMILY:
        sc = get_scenario(name)
        assert sc.routing is not None and sc.faults is not None
        assert Scenario.from_json(sc.to_json()) == sc
    assert get_scenario("chaos-noop").faults.is_noop
    assert not get_scenario("chaos-row-crash").faults.is_noop


# ------------------------------------------------------------- bit parity
def test_noop_fault_spec_bit_parity_with_pr5_fleet():
    """Acceptance: a registered chaos-* scenario with an empty FaultSpec is
    bit-identical to its pre-chaos counterpart."""
    noop = run_experiment(get_scenario("chaos-noop").with_(
        duration_s=1800.0, compare_to_reference=False))
    site = run_experiment(get_scenario("site-static").with_(
        duration_s=1800.0, compare_to_reference=False))
    assert noop.result.latencies == site.result.latencies
    assert noop.fleet.decisions == site.fleet.decisions
    assert np.array_equal(noop.fleet.cluster_power_frac,
                          site.fleet.cluster_power_frac)
    assert np.array_equal(noop.fleet.node_budget_w, site.fleet.node_budget_w)
    assert noop.fleet.fault_events == []


# -------------------------------------------------- derates: conservation
_DERATE = FaultSpec((FaultEvent("node-derate", t=300.0, node="pdu0",
                                factor=0.7, until=1200.0),))


def test_derate_conserves_every_node_and_restores_root_exactly():
    o = run_experiment(_chaos_scenario(_DERATE))
    f = o.fleet
    h = _chaos_scenario().hierarchy.build(np.ones(8))
    # per-tick conservation at every interior node, through apply+restore
    for i in range(h.n_leaves, h.n_nodes):
        kids = h.children[i]
        assert np.allclose(f.node_budget_w[:, kids].sum(axis=1),
                           f.node_budget_w[:, i], atol=1e-3)
    root = f.node_budget_w[:, h.root]
    assert float(root.min()) < float(root[0]) - 1.0, \
        "the derate must shrink the root (the watts are physically lost)"
    assert abs(float(root[-1]) - float(root[0])) < 1e-6, \
        "restore must return the tracked delta exactly"
    phases = [(r.kind, r.phase) for r in f.fault_events]
    assert ("node-derate", "apply") in phases
    assert ("node-derate", "restore") in phases
    for r in f.fault_events:
        assert r.node_budgets_before_w is not None
        assert r.node_budgets_after_w is not None


def test_ramp_derate_is_monotone_then_restores():
    fs = FaultSpec((FaultEvent("node-derate", t=300.0, node="pdu0",
                               factor=0.7, until=1200.0, ramp_s=300.0),))
    o = run_experiment(_chaos_scenario(
        fs, controller=ControllerSpec("static")))
    f = o.fleet
    names = list(f.node_names)
    col = f.node_budget_w[:, names.index("pdu0")]
    t = f.power_t
    ramp = col[(t >= 300.0) & (t <= 600.0)]
    assert np.all(np.diff(ramp) <= 1e-9), "ramp must be non-increasing"
    hold = col[(t > 650.0) & (t < 1200.0)]
    assert np.allclose(hold, col[0] * 0.7, rtol=1e-6)
    assert abs(float(col[-1]) - float(col[0])) < 1e-6


def test_derated_node_cap_respected_under_tree_rebalancing():
    """The controller must not 'heal' the fault: while the derate holds, the
    derated node's budget stays at/below its physical cap even as tree-scope
    passes re-divide the site."""
    o = run_experiment(_chaos_scenario(_DERATE))
    f = o.fleet
    assert f.n_rebalances > 0
    names = list(f.node_names)
    col = f.node_budget_w[:, names.index("pdu0")]
    t = f.power_t
    cap = float(col[0]) * 0.7
    inside = col[(t > 310.0) & (t <= 1200.0)]
    assert np.all(inside <= cap + 1e-6)


# ----------------------------------------------------- crash -> revive
_CRASH = FaultSpec((FaultEvent("row-crash", t=400.0, row=3),
                    FaultEvent("row-revive", t=1100.0, row=3)))


def test_crash_revive_round_trip_and_accounting():
    o = run_experiment(_chaos_scenario(_CRASH))
    f = o.fleet
    assert f.n_offered == f.n_admitted + f.n_shed_total
    during = [d for d in f.decisions if d.row == 3 and 400.0 < d.t <= 1100.0]
    after = [d for d in f.decisions if d.row == 3 and d.t > 1100.0]
    assert during == [], "no dispatch may reach a dead row"
    assert len(after) > 0, "the revived row must re-enter service"
    assert f.row_alive is not None
    dead = ~f.row_alive[:, 3]
    assert dead.any() and not dead.all()
    others = np.delete(f.row_alive, 3, axis=1)
    assert bool(others.all()), "only the crashed row may go dead"
    kinds = [(r.kind, r.phase) for r in f.fault_events]
    assert ("row-crash", "apply") in kinds
    assert ("row-revive", "apply") in kinds


def test_all_rows_dead_sheds_with_reason():
    fs = FaultSpec(tuple(
        [FaultEvent("row-crash", t=400.0, row=i) for i in range(8)]
        + [FaultEvent("row-revive", t=800.0, row=i) for i in range(8)]))
    o = run_experiment(_chaos_scenario(fs))
    f = o.fleet
    assert f.n_offered == f.n_admitted + f.n_shed_total
    reasons = {d.reason for d in f.decisions if d.reason.startswith("shed")}
    assert "shed/row-crash" in reasons


# ------------------------------------------------------------ determinism
def test_chaos_determinism_under_fixed_seed():
    a = run_experiment(_chaos_scenario(_DERATE))
    b = run_experiment(_chaos_scenario(_DERATE))
    assert a.result.latencies == b.result.latencies
    assert a.fleet.fault_events == b.fleet.fault_events
    assert np.array_equal(a.fleet.node_budget_w, b.fleet.node_budget_w)
    c = run_experiment(_chaos_scenario(_DERATE, seed=8))
    assert a.result.latencies != c.result.latencies, "seed must matter"


def test_faulted_ensemble_worker_invariance():
    """Fault timelines ride per member with a fresh injector each: results
    are bit-identical across Monte-Carlo worker counts."""
    base = _chaos_scenario(_CRASH, duration_s=1200.0)
    e1 = run_ensemble(EnsembleSpec(base, n_seeds=2, seed0=900, n_workers=1))
    e2 = run_ensemble(EnsembleSpec(base, n_seeds=2, seed0=900, n_workers=2))
    assert np.array_equal(e1.brake_counts, e2.brake_counts)
    for m1, m2 in zip(e1.members, e2.members):
        assert m1.result.latencies == m2.result.latencies
        assert np.array_equal(m1.result.power_w, m2.result.power_w)
        assert m1.scenario.faults == base.faults


# ------------------------------------------------------- injector re-use
def test_injector_rebinds_fresh_state():
    """bind() resets actuation state: one spec can drive many fleets (what
    per-member Monte-Carlo construction relies on)."""
    inj = ChaosInjector(_DERATE)
    a = run_experiment(_chaos_scenario(_DERATE))
    b = run_experiment(_chaos_scenario(_DERATE))
    assert a.fleet.fault_events == b.fleet.fault_events
    assert inj.records == []


# --------------------------------------------------- planner survivability
def test_planner_survivability_gate():
    base = _chaos_scenario(None, duration_s=900.0,
                           fleet=FleetSpec(n_provisioned=8, added_frac=0.0,
                                           n_rows=4),
                           hierarchy=HierarchySpec(shape=(2, 2)),
                           traffic=TrafficSpec(occ_peak=0.62))
    crash = FaultSpec((FaultEvent("row-crash", t=300.0, row=0),
                       FaultEvent("row-crash", t=350.0, row=1),
                       FaultEvent("row-revive", t=800.0, row=0),
                       FaultEvent("row-revive", t=800.0, row=1)))
    surv = plan_capacity(base, constraints=RiskConstraints(survive=crash),
                         n_seeds=1, max_added_frac=0.5, n_workers=1)
    assert all(p.fault_brake_prob is not None for p in surv.probes)
    free = plan_capacity(base, n_seeds=1, max_added_frac=0.5, n_workers=1)
    assert all(p.fault_brake_prob is None for p in free.probes)
    assert surv.safe_added_servers <= free.safe_added_servers, \
        "surviving a crash can never admit a larger fleet"
    # a no-op timeline is the same as no gate at all
    noop = plan_capacity(base,
                         constraints=RiskConstraints(survive=FaultSpec()),
                         n_seeds=1, max_added_frac=0.5, n_workers=1)
    assert noop.safe_added_servers == free.safe_added_servers
    with pytest.raises(ValueError, match="routed"):
        plan_capacity(base.with_(routing=None, controller=None,
                                 hierarchy=None),
                      constraints=RiskConstraints(survive=crash), n_seeds=1)
