"""End-to-end behaviour tests: every assigned arch (reduced config) runs a
train step and, where applicable, a prefill->decode cycle with exact
consistency between the two paths."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_batch
from repro.configs import ALL, ASSIGNED, smoke_config
from repro.launch.inputs import make_rules, split_seq
from repro.launch.mesh import set_mesh
from repro.launch.steps import build_decode_step, build_prefill_step, build_train_step
from repro.models import model as model_mod
from repro.models.config import ShapeConfig
from repro.models.param import init_params
from repro.optim import make_optimizer

B, S = 2, 32


def _setup(name, mesh, kind="train"):
    cfg = smoke_config(name)
    shape = ShapeConfig("t", S, B, kind)
    rules = make_rules(cfg, shape, mesh)
    params = init_params(model_mod.model_specs(cfg, mesh.shape["model"]),
                         jax.random.key(0))
    return cfg, shape, rules, params


@pytest.mark.parametrize("name", sorted(ALL))
def test_train_step_all_archs(name, mesh1):
    cfg, shape, rules, params = _setup(name, mesh1)
    opt = make_optimizer(cfg.optimizer)
    opt_state = init_params(opt.init_specs(model_mod.model_specs(cfg, 1)),
                            jax.random.key(1))
    state = {"params": params, "opt": opt_state}
    batch = make_batch(cfg, B, S)
    step = jax.jit(build_train_step(cfg, mesh1, rules, opt))
    with set_mesh(mesh1):
        state2, metrics = step(state, batch)
        state3, metrics3 = step(state2, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually moved and second step stays finite
    moved = any(
        not np.allclose(np.asarray(a, np.float32), np.asarray(b, np.float32))
        for a, b in zip(jax.tree.leaves(state["params"]),
                        jax.tree.leaves(state2["params"])))
    assert moved
    assert np.isfinite(float(metrics3["loss"]))


@pytest.mark.parametrize("name", sorted(ASSIGNED))
def test_prefill_decode_consistency(name, mesh1):
    cfg = smoke_config(name)
    if cfg.is_encoder_only:
        pytest.skip("encoder-only: no decode step")
    shape = ShapeConfig("t", S, B, "prefill")
    rules = make_rules(cfg, shape, mesh1)
    params = init_params(model_mod.model_specs(cfg, 1), jax.random.key(0))
    batch = make_batch(cfg, B, S, seed=3)
    _, dec_S = split_seq(cfg, S)
    n_txt = batch["tokens"].shape[1]

    pf = jax.jit(build_prefill_step(cfg, shape, mesh1, rules))
    dc = jax.jit(build_decode_step(cfg, mesh1, rules))
    b_part = dict(batch)
    b_part["tokens"] = batch["tokens"][:, :-1]
    img = cfg.num_image_embeds if cfg.frontend == "vision_stub" else 0
    pos = jnp.asarray(n_txt - 1 + img, jnp.int32)
    with set_mesh(mesh1):
        logits_full, _ = pf(params, batch)
        _, cache = pf(params, b_part)
        logits_dec, new_cache = dc(params, batch["tokens"][:, -1:], pos, cache)
    a = np.asarray(logits_full[:, -1, :], np.float32)
    b = np.asarray(logits_dec[:, -1, :], np.float32)
    rel = np.max(np.abs(a - b)) / (np.max(np.abs(a)) + 1e-6)
    assert rel < 0.06, f"{name}: decode/prefill mismatch rel={rel}"
    # cache structure preserved
    assert jax.tree.structure(cache) == jax.tree.structure(new_cache)


@pytest.mark.parametrize("name", sorted(ASSIGNED))
def test_output_shapes_and_no_nans(name, mesh1):
    cfg, shape, rules, params = _setup(name, mesh1, "prefill")
    if cfg.is_encoder_only:
        pytest.skip("encoder-only")
    batch = make_batch(cfg, B, S)
    pf = jax.jit(build_prefill_step(cfg, shape, mesh1, rules))
    with set_mesh(mesh1):
        logits, cache = pf(params, batch)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    for leaf in jax.tree.leaves(cache):
        assert np.isfinite(np.asarray(leaf, np.float32)).all()


def test_greedy_generation_deterministic(mesh1):
    """Serving engine produces identical greedy tokens across runs."""
    from repro.launch.serve import ServeEngine

    cfg = smoke_config("llama3.2-1b")
    eng = ServeEngine(cfg, mesh1, max_len=24, batch=2)
    toks = np.arange(2 * 16, dtype=np.int32).reshape(2, 16) % cfg.vocab_size
    out1 = eng.generate(toks, 8)
    out2 = eng.generate(toks, 8)
    assert (out1 == out2).all()
    assert out1.shape == (2, 8)
