"""Fleet serving layer: routing determinism, single-row bit-parity with the
standalone RowSimulator, admission-control conservation, router behavior,
and Monte-Carlo fleet-member parity."""

import numpy as np
import pytest

from repro.experiments import (
    FleetSpec,
    PolicySpec,
    RoutingSpec,
    Scenario,
    TrafficSpec,
    get_scenario,
    run_experiment,
)
from repro.fleet import (
    CapAwareRouter,
    FleetView,
    JoinShortestQueueRouter,
    RoundRobinRouter,
    RowView,
    ShedLowPriority,
    attribute_routing,
    build_admission,
    build_router,
)
from repro.fleet.fleet import fleet_trace
from repro.provisioning import (
    EnsembleSpec,
    RiskConstraints,
    plan_capacity,
    run_ensemble,
)


def _fleet_scenario(**kw) -> Scenario:
    base = dict(
        name="fleet-test",
        duration_s=1800.0,
        fleet=FleetSpec(n_provisioned=16, added_frac=0.25, n_rows=3,
                        rows_per_rack=2,
                        row_budget_fracs=(1.0, 1.0, 0.7)),
        policy=PolicySpec("polca"),
        traffic=TrafficSpec(occ_peak=0.9),
        routing=RoutingSpec("cap-aware"),
        budget="nominal",
        compare_to_reference=False,
    )
    base.update(kw)
    return Scenario(**base)


# ---------------------------------------------------------------- routers
def _view(i, **kw):
    base = dict(index=i, power_frac=0.5, headroom_w=100.0, braked=False,
                t1_capped=False, t2_capped=False, hp_capped=False,
                pool_size=4, pool_idle=2, pool_queued=0)
    base.update(kw)
    return RowView(**base)


def _req(priority="high"):
    from repro.core.simulator import Request
    return Request(t_arrival=0.0, wl=0, prompt=128, out_tokens=128,
                   priority=priority, rid=0)


def test_round_robin_cycles():
    r = RoundRobinRouter()
    views = [_view(i) for i in range(3)]
    picks = [r.route(_req(), views)[0] for _ in range(6)]
    assert picks == [0, 1, 2, 0, 1, 2]


def test_jsq_picks_least_pending():
    r = JoinShortestQueueRouter()
    views = [_view(0, pool_idle=0, pool_queued=3),
             _view(1, pool_idle=1, pool_queued=0),
             _view(2, pool_idle=0, pool_queued=1)]
    assert r.route(_req(), views)[0] == 1


def test_cap_aware_avoids_braked_rows():
    r = CapAwareRouter()
    views = [_view(0, braked=True, pool_idle=4),
             _view(1, pool_idle=0, pool_queued=2)]
    row, reason = r.route(_req("high"), views)
    assert row == 1, "a queued healthy row beats an idle braked row"
    assert reason == "cap-aware/uncapped"
    # ...unless every row is braked: then least-loaded braked row wins
    views = [_view(0, braked=True, pool_idle=4),
             _view(1, braked=True, pool_idle=0, pool_queued=2)]
    row, reason = r.route(_req("high"), views)
    assert row == 0 and reason == "cap-aware/braked"


def test_cap_aware_steers_hp_from_capped_rows_on_ties():
    r = CapAwareRouter()
    views = [_view(0, t2_capped=True, hp_capped=True),
             _view(1)]
    assert r.route(_req("high"), views)[0] == 1
    # LP is not slowed by the HP cap tier; mild T2 penalty still tips ties
    views = [_view(0, t1_capped=True), _view(1)]
    assert r.route(_req("low"), views)[0] == 1


def test_admission_sheds_lp_only_during_emergency():
    adm = ShedLowPriority(shed_above=0.97)
    calm = FleetView(t=0.0, cluster_frac=0.5, n_braked=0)
    hot = FleetView(t=0.0, cluster_frac=0.99, n_braked=0)
    braked = FleetView(t=0.0, cluster_frac=0.5, n_braked=1)
    assert adm.admit(_req("low"), calm)
    assert not adm.admit(_req("low"), hot)
    assert not adm.admit(_req("low"), braked)
    for fv in (calm, hot, braked):
        assert adm.admit(_req("high"), fv), "HP is never shed"


def test_router_registry_round_trip():
    for kind in ("round-robin", "jsq", "power-headroom", "cap-aware"):
        assert build_router(kind) is not build_router(kind)
    with pytest.raises(KeyError):
        build_router("nope")
    with pytest.raises(KeyError):
        build_admission("nope")


# ------------------------------------------------------------- scenarios
def test_fleet_scenarios_registered_and_serializable():
    for name in ("fleet-round-robin", "fleet-jsq", "fleet-power-headroom",
                 "fleet-cap-aware", "fleet-rr-shed"):
        sc = get_scenario(name)
        assert sc.routing is not None
        assert Scenario.from_json(sc.to_json()) == sc


# ------------------------------------------------------------ simulation
def test_fleet_seeded_determinism():
    sc = _fleet_scenario()
    a = run_experiment(sc)
    b = run_experiment(sc)
    c = run_experiment(sc.with_(seed=sc.seed + 1))
    assert a.result.latencies == b.result.latencies
    assert np.array_equal(a.fleet.cluster_power_frac, b.fleet.cluster_power_frac)
    assert [d for d in a.fleet.decisions] == [d for d in b.fleet.decisions]
    assert a.result.latencies != c.result.latencies, "seed must matter"


def test_fleet_duration_not_multiple_of_telemetry():
    """A duration off the telemetry grid must run clean end to end (the
    final partial window used to crash inject() on drained rows)."""
    sc = _fleet_scenario(duration_s=1801.7)
    o = run_experiment(sc)
    f = o.fleet
    assert f.n_admitted + f.n_shed_total == f.n_offered
    assert all(d.t <= 1801.7 for d in f.decisions)


def test_inject_revives_drained_row():
    """inject() into a row whose event queue overshot its duration (possible
    in the final partial telemetry window) revives it instead of raising or
    silently dropping the arrival."""
    from repro.core.policy import NoCap
    from repro.core.simulator import Request, RowSimulator, SimConfig
    from repro.experiments.runner import build_workloads
    sc = _fleet_scenario()
    wls, shares = build_workloads(sc)
    row = RowSimulator(wls, sc.fleet.server(), 4, 4, NoCap(), [], shares,
                       SimConfig(record_power=False), duration=3.0)
    row.start()
    assert row.advance_to(3.0) is False  # telemetry@4s overshot: drained
    row.inject(Request(2.5, 0, 1024, 8, "high", 0))
    row.advance_to(3.0)
    assert any(s.state != "idle" for s in row.servers), \
        "the late arrival must enter service"
    row.finalize()
    with pytest.raises(ValueError):
        row.inject(Request(3.5, 0, 1024, 8, "high", 1))  # beyond duration


def test_single_row_fleet_bit_identical_to_standalone():
    """Acceptance: a one-row round-robin fleet reproduces the standalone
    RowSimulator path bit-for-bit (trace, events, telemetry, stats)."""
    base = get_scenario("fig14-plus30").with_(duration_s=3600.0)
    solo = run_experiment(base)
    fleet = run_experiment(base.with_(routing=RoutingSpec("round-robin")))
    fr, sr = fleet.fleet.row_results[0], solo.result
    assert fr.latencies == sr.latencies
    assert fr.queue_delays == sr.queue_delays
    assert np.array_equal(fr.power_w, sr.power_w)
    assert (fr.n_brakes, fr.cap_events, fr.n_completed, fr.n_dropped) \
        == (sr.n_brakes, sr.cap_events, sr.n_completed, sr.n_dropped)
    assert fr.peak_power_frac == sr.peak_power_frac
    assert fr.mean_power_frac == sr.mean_power_frac
    # reference-relative stats (both paths pair an uncapped twin) match too
    assert fleet.stats.summary() == solo.stats.summary()
    assert fleet.meets == solo.meets


def test_admission_conservation():
    """Acceptance: admitted + shed == offered, and shedding is LP-only."""
    sc = _fleet_scenario(routing=RoutingSpec(
        "cap-aware", admission="shed-lp",
        admission_params={"shed_above": 0.5}))  # shed aggressively
    o = run_experiment(sc)
    fres = o.fleet
    from repro.experiments.runner import build_workloads
    wls, shares = build_workloads(sc)
    assert fres.n_offered == len(fleet_trace(sc, wls, shares))
    assert fres.n_admitted + fres.n_shed_total == fres.n_offered
    assert fres.n_shed.get("high", 0) == 0
    assert fres.n_shed.get("low", 0) > 0, "aggressive threshold must shed"
    shed_decisions = [d for d in fres.decisions if d.row < 0]
    assert len(shed_decisions) == fres.n_shed_total
    assert all(d.priority == "low" for d in shed_decisions)
    # shed requests never reach a row
    served = set(fres.merged_latencies())
    assert served.isdisjoint({d.rid for d in shed_decisions})
    # decision log covers every offered request exactly once
    assert len(fres.decisions) == fres.n_offered
    assert len({d.rid for d in fres.decisions}) == fres.n_offered


def test_routing_attribution_joins_decisions_with_outcomes():
    sc = _fleet_scenario()
    o = run_experiment(sc)
    from repro.experiments.runner import build_workloads
    wls, shares = build_workloads(sc)
    reqs = fleet_trace(sc, wls, shares)
    att = attribute_routing(o.fleet, reqs, wls)
    assert att.n_offered == len(reqs)
    assert set(att.per_row) <= set(range(sc.fleet.n_rows))
    n_routed = sum(g.n_routed for g in att.per_row.values())
    assert n_routed == att.n_admitted
    n_completed = sum(g.n_completed for g in att.per_row.values())
    assert n_completed == sum(rr.n_completed for rr in o.fleet.row_results)
    assert att.summary()["n_offered"] == float(len(reqs))


def test_heterogeneous_budgets_applied_per_row():
    sc = _fleet_scenario()
    o = run_experiment(sc)
    # the derated row's budget is 70% of the others': identical traffic
    # pressure must push it to a higher fraction of its own budget
    fracs = o.fleet.row_power_frac
    assert fracs.shape[1] == 3
    assert float(fracs[:, 2].mean()) > float(fracs[:, 0].mean())


# ------------------------------------------------------- ensembles/planner
def test_fleet_ensemble_bit_parity_with_sequential_run_experiment():
    """Acceptance (ROADMAP open item): multi-row fleet members run in the
    batched Monte-Carlo engine bit-identically to a sequential loop."""
    base = _fleet_scenario(duration_s=1200.0)
    spec = EnsembleSpec(base, n_seeds=3, seed0=500, n_workers=2)
    ens = run_ensemble(spec)
    assert ens.power_frac.shape[0] == 3
    for m, sc in zip(ens.members, spec.member_scenarios(ens.budget_w)):
        o = run_experiment(sc)
        assert m.result.latencies == o.result.latencies
        assert np.array_equal(m.result.power_w, o.result.power_w)
        assert m.result.n_brakes == o.result.n_brakes


def test_fleet_ensemble_reference_mode_matches_run_experiment():
    base = _fleet_scenario(duration_s=1200.0)
    spec = EnsembleSpec(base, n_seeds=2, seed0=500, n_workers=1,
                        with_reference=True)
    ens = run_ensemble(spec)
    for m, sc in zip(ens.members, spec.member_scenarios(ens.budget_w)):
        o = run_experiment(sc)
        assert m.stats.summary() == o.stats.summary()
        assert m.meets == o.meets


def test_planner_over_fleet_members():
    """plan_capacity accepts a routed-fleet base scenario (multi-row
    ensemble members, the ROADMAP open item)."""
    base = _fleet_scenario(duration_s=900.0).with_fleet(added_frac=0.0)
    plan = plan_capacity(
        base, constraints=RiskConstraints(max_brake_prob=1.0,
                                          max_slo_violation_prob=1.0),
        n_seeds=2, seed0=650, max_added_frac=0.25, n_workers=2)
    assert plan.capped and plan.safe_added_servers == 4
    assert all(p.ensemble is None for p in plan.probes)
