"""Checkpoint/restore + crash-restart + elastic resharding."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpointer
from repro.configs import smoke_config
from repro.data.pipeline import DataConfig, SyntheticTokenPipeline
from repro.launch.inputs import make_rules
from repro.launch.mesh import make_local_mesh, set_mesh
from repro.launch.steps import build_train_step
from repro.models import model as model_mod
from repro.models.config import ShapeConfig
from repro.models.param import init_params
from repro.optim import make_optimizer
from repro.runtime.fault_tolerance import FaultInjector, StragglerMonitor, TrainSupervisor


def _tiny_state():
    return {"params": {"w": jnp.arange(6.0).reshape(2, 3), "b": jnp.ones((3,))},
            "opt": {"mu": {"w": jnp.zeros((2, 3)), "b": jnp.zeros((3,))},
                    "count": jnp.asarray(4, jnp.int32)}}


def test_roundtrip(tmp_path):
    st = _tiny_state()
    checkpointer.save(str(tmp_path), 7, st)
    step, st2 = checkpointer.restore_latest(str(tmp_path), st)
    assert step == 7
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(st2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_gc_keeps_latest(tmp_path):
    st = _tiny_state()
    for s in range(6):
        checkpointer.save(str(tmp_path), s, st, keep=3)
    assert checkpointer.list_steps(str(tmp_path)) == [3, 4, 5]


def test_torn_write_fallback(tmp_path):
    st = _tiny_state()
    checkpointer.save(str(tmp_path), 1, st)
    checkpointer.save(str(tmp_path), 2, st)
    # corrupt the newest checkpoint (simulated kill mid-write + bad rename)
    with open(os.path.join(tmp_path, "step_3.npz"), "wb") as f:
        f.write(b"not a zip")
    step, _ = checkpointer.restore_latest(str(tmp_path), st)
    assert step == 2


def test_supervisor_crash_restart_replays_exactly(tmp_path, mesh1):
    """Injected faults mid-run: the supervisor restores and the final state
    equals the fault-free run (step-addressable pipeline => exact replay)."""
    cfg = smoke_config("llama3.2-1b")
    shape = ShapeConfig("t", 32, 2, "train")
    rules = make_rules(cfg, shape, mesh1)
    opt = make_optimizer(cfg.optimizer)
    pspecs = model_mod.model_specs(cfg, 1)
    with set_mesh(mesh1):
        params = init_params(pspecs, jax.random.key(0))
        opt_state = init_params(opt.init_specs(pspecs), jax.random.key(1))
    state0 = {"params": params, "opt": opt_state}
    pipeline = SyntheticTokenPipeline(cfg, DataConfig(2, 32))
    base_step = jax.jit(build_train_step(cfg, mesh1, rules, opt))

    def clean_step(state, batch):
        with set_mesh(mesh1):
            s, m = base_step(state, {k: jnp.asarray(v) for k, v in batch.items()})
        return s, m

    sup_clean = TrainSupervisor(clean_step, pipeline, str(tmp_path / "clean"),
                                ckpt_interval=4)
    final_clean, _ = sup_clean.run(jax.tree.map(lambda x: x, state0), 12)

    inj = FaultInjector(fail_at=[6, 9])
    calls = {"n": 0}

    def faulty_step(state, batch):
        step_idx = len(sup_faulty.history)
        inj.maybe_fail(step_idx)
        return clean_step(state, batch)

    sup_faulty = TrainSupervisor(faulty_step, pipeline, str(tmp_path / "faulty"),
                                 ckpt_interval=4)
    final_faulty, _ = sup_faulty.run(jax.tree.map(lambda x: x, state0), 12)
    assert sup_faulty.n_restarts == 2
    for a, b in zip(jax.tree.leaves(final_clean["params"]),
                    jax.tree.leaves(final_faulty["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-6)


def test_straggler_monitor_flags_outliers():
    mon = StragglerMonitor(threshold=2.0)
    for i in range(10):
        mon.observe(i, 0.1)
    assert mon.observe(10, 0.5)
    assert mon.flagged_steps == [10]
    assert not mon.observe(11, 0.12)


def test_elastic_reshard_roundtrip(mesh1):
    """Host state re-placed onto a new mesh keeps values and new shardings."""
    from repro.runtime.fault_tolerance import elastic_reshard
    from jax.sharding import NamedSharding, PartitionSpec as P

    host_state = {"w": np.arange(8.0, dtype=np.float32).reshape(2, 4)}

    def template_fn(mesh):
        return {"w": jax.ShapeDtypeStruct((2, 4), jnp.float32,
                                          sharding=NamedSharding(mesh, P(None, None)))}

    out = elastic_reshard(template_fn, host_state, mesh1)
    np.testing.assert_array_equal(np.asarray(out["w"]), host_state["w"])
    assert out["w"].sharding.mesh.shape == mesh1.shape
