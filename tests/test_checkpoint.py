"""Checkpoint/restore + crash-restart + elastic resharding."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpointer
from repro.configs import smoke_config
from repro.data.pipeline import DataConfig, SyntheticTokenPipeline
from repro.launch.inputs import make_rules
from repro.launch.mesh import make_local_mesh, set_mesh
from repro.launch.steps import build_train_step
from repro.models import model as model_mod
from repro.models.config import ShapeConfig
from repro.models.param import init_params
from repro.optim import make_optimizer
from repro.runtime.fault_tolerance import (
    BrakeSentinel,
    FaultInjector,
    StragglerMonitor,
    TrainSupervisor,
)


def _tiny_state():
    return {"params": {"w": jnp.arange(6.0).reshape(2, 3), "b": jnp.ones((3,))},
            "opt": {"mu": {"w": jnp.zeros((2, 3)), "b": jnp.zeros((3,))},
                    "count": jnp.asarray(4, jnp.int32)}}


def test_roundtrip(tmp_path):
    st = _tiny_state()
    checkpointer.save(str(tmp_path), 7, st)
    step, st2 = checkpointer.restore_latest(str(tmp_path), st)
    assert step == 7
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(st2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_gc_keeps_latest(tmp_path):
    st = _tiny_state()
    for s in range(6):
        checkpointer.save(str(tmp_path), s, st, keep=3)
    assert checkpointer.list_steps(str(tmp_path)) == [3, 4, 5]


def test_torn_write_fallback(tmp_path):
    st = _tiny_state()
    checkpointer.save(str(tmp_path), 1, st)
    checkpointer.save(str(tmp_path), 2, st)
    # corrupt the newest checkpoint (simulated kill mid-write + bad rename)
    with open(os.path.join(tmp_path, "step_3.npz"), "wb") as f:
        f.write(b"not a zip")
    step, _ = checkpointer.restore_latest(str(tmp_path), st)
    assert step == 2


def test_supervisor_crash_restart_replays_exactly(tmp_path, mesh1):
    """Injected faults mid-run: the supervisor restores and the final state
    equals the fault-free run (step-addressable pipeline => exact replay)."""
    cfg = smoke_config("llama3.2-1b")
    shape = ShapeConfig("t", 32, 2, "train")
    rules = make_rules(cfg, shape, mesh1)
    opt = make_optimizer(cfg.optimizer)
    pspecs = model_mod.model_specs(cfg, 1)
    with set_mesh(mesh1):
        params = init_params(pspecs, jax.random.key(0))
        opt_state = init_params(opt.init_specs(pspecs), jax.random.key(1))
    state0 = {"params": params, "opt": opt_state}
    pipeline = SyntheticTokenPipeline(cfg, DataConfig(2, 32))
    base_step = jax.jit(build_train_step(cfg, mesh1, rules, opt))

    def clean_step(state, batch):
        with set_mesh(mesh1):
            s, m = base_step(state, {k: jnp.asarray(v) for k, v in batch.items()})
        return s, m

    sup_clean = TrainSupervisor(clean_step, pipeline, str(tmp_path / "clean"),
                                ckpt_interval=4)
    final_clean, _ = sup_clean.run(jax.tree.map(lambda x: x, state0), 12)

    inj = FaultInjector(fail_at=[6, 9])
    calls = {"n": 0}

    def faulty_step(state, batch):
        step_idx = len(sup_faulty.history)
        inj.maybe_fail(step_idx)
        return clean_step(state, batch)

    sup_faulty = TrainSupervisor(faulty_step, pipeline, str(tmp_path / "faulty"),
                                 ckpt_interval=4)
    final_faulty, _ = sup_faulty.run(jax.tree.map(lambda x: x, state0), 12)
    assert sup_faulty.n_restarts == 2
    for a, b in zip(jax.tree.leaves(final_clean["params"]),
                    jax.tree.leaves(final_faulty["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-6)


def test_fault_injector_reset_reinjects():
    inj = FaultInjector(fail_at=[2])
    with pytest.raises(RuntimeError):
        inj.maybe_fail(2)
    inj.maybe_fail(2)  # already seen: silent
    inj.reset()
    with pytest.raises(RuntimeError):
        inj.maybe_fail(2)  # same timeline fires again after reset


class _CountingPipeline:
    """Step-addressable stub: the supervisor only calls batch_at(step)."""

    def batch_at(self, step):
        return {"step": step}


def test_supervisor_power_event_checkpoints_and_drains(tmp_path):
    """A sustained-brake power event checkpoints the run and drains at the
    next step boundary — the straggler mitigation, triggered by the power
    plane. Other events are recorded + forwarded but do not drain."""
    seen = []

    def step_fn(state, batch):
        n = int(state["x"])
        if n == 3:
            sup.power_event("sustained-brake")
        return {"x": state["x"] + 1.0}, {"loss": 0.0}

    sup = TrainSupervisor(step_fn, _CountingPipeline(), str(tmp_path),
                          ckpt_interval=100, on_power_event=seen.append)
    sup.power_event("brake-cleared")  # informational: no drain
    state, step = sup.run({"x": np.asarray(0.0)}, 10)
    assert step == 4, "drain must happen at the boundary after the event"
    assert float(state["x"]) == 4.0
    assert sup.power_events == ["brake-cleared", "sustained-brake"]
    assert seen == sup.power_events, "on_power_event hook sees every event"
    assert checkpointer.list_steps(str(tmp_path))[-1] == 4
    # the drain is one-shot: resuming completes the run
    state, step = sup.run(state, 10, start_step=step)
    assert step == 10 and float(state["x"]) == 10.0


def test_brake_sentinel_fires_on_sustained_runs_only():
    s = BrakeSentinel(sustain_ticks=3)
    pattern = [False, True, True, False, True, True, True, True]
    fired = [s.observe(float(i), b) for i, b in enumerate(pattern)]
    # one event, exactly at the 3rd consecutive braked tick; a longer run
    # does not re-fire
    assert fired == [None, None, None, None, None, None,
                     "sustained-brake", None]
    assert s.events == [6.0]


def test_brake_sentinel_scan_real_telemetry_drains_supervisor(tmp_path):
    """End to end: a row simulation braked by an undersized budget produces
    a braked_series whose sustained run the sentinel converts into the
    supervisor power event that checkpoints + drains the training loop."""
    from repro.experiments import get_scenario, run_experiment

    o = run_experiment(get_scenario("fig14-plus30").with_(
        duration_s=900.0, budget=14_000.0, compare_to_reference=False))
    assert o.result.braked_series is not None

    def step_fn(state, batch):
        return {"x": state["x"] + 1.0}, {"loss": 0.0}

    sup = TrainSupervisor(step_fn, _CountingPipeline(), str(tmp_path))
    fired = BrakeSentinel(sustain_ticks=3).scan(o.result, supervisor=sup)
    assert fired, "an undersized budget must yield a sustained brake"
    assert "sustained-brake" in sup.power_events
    state, step = sup.run({"x": np.asarray(0.0)}, 5)
    assert step == 0, "pending drain fires before the first step"
    assert checkpointer.list_steps(str(tmp_path)) == [0]


def test_straggler_monitor_flags_outliers():
    mon = StragglerMonitor(threshold=2.0)
    for i in range(10):
        mon.observe(i, 0.1)
    assert mon.observe(10, 0.5)
    assert mon.flagged_steps == [10]
    assert not mon.observe(11, 0.12)


def test_elastic_reshard_roundtrip(mesh1):
    """Host state re-placed onto a new mesh keeps values and new shardings."""
    from repro.runtime.fault_tolerance import elastic_reshard
    from jax.sharding import NamedSharding, PartitionSpec as P

    host_state = {"w": np.arange(8.0, dtype=np.float32).reshape(2, 4)}

    def template_fn(mesh):
        return {"w": jax.ShapeDtypeStruct((2, 4), jnp.float32,
                                          sharding=NamedSharding(mesh, P(None, None)))}

    out = elastic_reshard(template_fn, host_state, mesh1)
    np.testing.assert_array_equal(np.asarray(out["w"]), host_state["w"])
    assert out["w"].sharding.mesh.shape == mesh1.shape
