"""Roofline machinery: HLO collective parsing + analytic cost sanity."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # real hypothesis in CI

from repro.configs import get_config
from repro.launch.inputs import split_seq
from repro.models.config import DECODE_32K, LONG_500K, PREFILL_32K, TRAIN_4K, shape_applicable
from repro.parallel import analytic
from repro.parallel.roofline import Roofline, model_flops, parse_collectives

HLO = """
ENTRY %main {
  %ag = bf16[64,1024]{1,0} all-gather(%x), channel_id=1, replica_groups=[16,16]<=[256], dimensions={0}
  %ar = f32[16,4096,2048]{2,1,0} all-reduce(%y), channel_id=2, replica_groups=[16,16]<=[256], to_apply=%add
  %rs = bf16[4,128]{1,0} reduce-scatter(%z), channel_id=3, replica_groups=[4,4]<=[16], dimensions={0}
  %cp = u32[8]{0} collective-permute(%w), channel_id=4, source_target_pairs={{0,1}}
  %a2a = bf16[2,64]{1,0} all-to-all(%v), channel_id=5, replica_groups={{0,1,2,3}}, dimensions={0}
  %tup = (f32[16,8]{1,0}, f32[16,8]{1,0}) all-reduce(%p, %q), channel_id=6, replica_groups=[2,8]<=[16]
  %agstart = bf16[64]{0} all-gather-start(%m), channel_id=7, replica_groups=[2,2]<=[4]
  %not_a_collective = f32[3]{0} add(%a, %b)
}
"""


def test_parse_collectives_kinds_and_counts():
    st_ = parse_collectives(HLO)
    assert st_.ops["all-gather"] == 2  # incl. -start
    assert st_.ops["all-reduce"] == 2
    assert st_.ops["reduce-scatter"] == 1
    assert st_.ops["collective-permute"] == 1
    assert st_.ops["all-to-all"] == 1


def test_parse_collectives_bytes():
    st_ = parse_collectives(HLO)
    ag = 64 * 1024 * 2 * (15 / 16)
    ar = 2 * (16 * 4096 * 2048 * 4) * (15 / 16)
    rs = 4 * 128 * 2 * 3
    cp = 8 * 4
    a2a = 2 * 64 * 2 * (3 / 4)
    tup = 2 * (2 * 16 * 8 * 4) * (7 / 8)
    agstart = 64 * 2 * (1 / 2)
    want = ag + ar + rs + cp + a2a + tup + agstart
    assert abs(st_.total_bytes - want) / want < 1e-9


def test_model_flops_against_param_count():
    """Analytic einsum count brackets the 6*N*D rule: equal up to the remat
    factor and the attention-core FLOPs that 6ND ignores."""
    for name in ("llama3.2-1b", "yi-34b", "qwen3-8b"):
        cfg = get_config(name)
        enc_S, dec_S = split_seq(cfg, TRAIN_4K.seq_len)
        exact = analytic.step_cost(cfg, TRAIN_4K, enc_S, dec_S).flops
        # 6ND scaled by the fwd-recompute factor (remat 'full': 8ND)
        simple = model_flops(cfg, TRAIN_4K) * analytic.REMAT_FACTOR[cfg.remat_policy] / 3.0
        assert 0.9 < exact / simple < 1.6, name


def test_moe_active_flops_much_smaller_than_total():
    kimi = get_config("kimi-k2-1t-a32b")
    assert kimi.total_params() > 0.9e12  # ~1T
    assert kimi.active_params() < 0.05 * kimi.total_params()


def test_decode_cost_is_memory_bound():
    cfg = get_config("yi-34b")
    c = analytic.step_cost(cfg, DECODE_32K, 0, DECODE_32K.seq_len)
    # arithmetic intensity (flops/byte) far below v5e machine balance (~240)
    assert c.flops / c.hbm_bytes < 60


def test_prefill_cost_is_compute_bound():
    cfg = get_config("yi-34b")
    c = analytic.step_cost(cfg, PREFILL_32K, 0, PREFILL_32K.seq_len)
    assert c.flops / c.hbm_bytes > 240  # above machine balance


def test_swa_bounds_long_context_flops():
    """mixtral decode at 500k must cost ~ the 4096-window, not ~ 500k."""
    cfg = get_config("mixtral-8x7b")
    c_long = analytic.step_cost(cfg, LONG_500K, 0, LONG_500K.seq_len)
    big = cfg.replace(window_size=LONG_500K.seq_len)
    c_full = analytic.step_cost(big, LONG_500K, 0, LONG_500K.seq_len)
    assert c_long.flops < 0.15 * c_full.flops


def test_shape_applicability_rules():
    assert shape_applicable(get_config("mamba2-370m"), LONG_500K)[0]
    assert shape_applicable(get_config("jamba-1.5-large-398b"), LONG_500K)[0]
    assert shape_applicable(get_config("mixtral-8x7b"), LONG_500K)[0]
    for full in ("llama3.2-1b", "gemma2-9b", "yi-34b", "qwen3-8b",
                 "internvl2-1b", "kimi-k2-1t-a32b", "whisper-base"):
        ok, why = shape_applicable(get_config(full), LONG_500K)
        assert not ok and "full-attention" in why


@given(st.floats(1e9, 1e15), st.floats(1e6, 1e13), st.floats(0, 1e12))
@settings(max_examples=50, deadline=None)
def test_roofline_bottleneck_consistency(fl, by, co):
    r = Roofline(fl, by, co, model_flops_global=fl * 256, n_devices=256)
    t = {"compute": r.t_compute, "memory": r.t_memory, "collective": r.t_collective}
    assert r.t_bound == max(t.values())
    assert t[r.bottleneck] == r.t_bound
    assert 0 <= r.mfu_bound
