"""Property-test layer: real hypothesis when installed, a seeded shim when not.

CI installs ``hypothesis`` via requirements-dev.txt and gets the real
shrinking/coverage engine. Containers without it (the tier-1 image bakes only
the runtime stack) used to skip every property module outright via
``pytest.importorskip``; this shim keeps those invariants exercised
everywhere by replaying each property over a deterministic seeded-RNG sample
instead. The shim draws are reproducible (seeded from the test's qualified
name + example index, not the process hash seed) and deliberately
boundary-biased, but it does not shrink failures — when a property fails
under the shim, re-run under real hypothesis for a minimal counterexample.

Only the API surface the test-suite uses is shimmed: ``given``, ``settings``
(unknown kwargs ignored), and ``st.floats / integers / lists / booleans /
sampled_from / tuples / just``.
"""

import zlib

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only sans hypothesis
    HAVE_HYPOTHESIS = False

    import functools
    import inspect

    import numpy as np

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng):
            return self._draw(rng)

    class _St:
        """The ``strategies`` module surface the suite uses."""

        @staticmethod
        def floats(min_value=None, max_value=None, *, allow_nan=False,
                   allow_infinity=False, width=64):
            lo = 0.0 if min_value is None else float(min_value)
            hi = 1.0 if max_value is None else float(max_value)

            def draw(rng):
                if rng.random() < 0.15:  # boundary bias: edges + midpoint
                    return float(rng.choice([lo, hi, 0.5 * (lo + hi)]))
                return float(rng.uniform(lo, hi))

            return _Strategy(draw)

        @staticmethod
        def integers(min_value=None, max_value=None):
            lo = 0 if min_value is None else int(min_value)
            hi = 2 ** 16 if max_value is None else int(max_value)

            def draw(rng):
                if rng.random() < 0.15:
                    return int(rng.choice([lo, hi]))
                return int(rng.integers(lo, hi + 1))

            return _Strategy(draw)

        @staticmethod
        def lists(elements, *, min_size=0, max_size=10):
            def draw(rng):
                n = int(rng.integers(min_size, max_size + 1))
                return [elements.example(rng) for _ in range(n)]

            return _Strategy(draw)

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(0, 2)))

        @staticmethod
        def sampled_from(seq):
            items = list(seq)
            return _Strategy(lambda rng: items[int(rng.integers(len(items)))])

        @staticmethod
        def tuples(*strats):
            return _Strategy(lambda rng: tuple(s.example(rng) for s in strats))

        @staticmethod
        def just(value):
            return _Strategy(lambda rng: value)

    st = _St()

    def settings(*args, max_examples=100, **_ignored):
        """Records max_examples on the test fn; everything else (deadline,
        derandomize, suppress_health_check, ...) has no shim equivalent."""
        if args and callable(args[0]):  # bare @settings
            return args[0]

        def apply(fn):
            fn._shim_max_examples = max_examples
            return fn

        return apply

    def given(*strategies):
        def decorate(fn):
            @functools.wraps(fn)
            def wrapper(*fargs, **fkwargs):
                n = getattr(wrapper, "_shim_max_examples",
                            getattr(fn, "_shim_max_examples", 100))
                base = zlib.crc32(
                    f"{fn.__module__}.{fn.__qualname__}".encode())
                for i in range(n):
                    rng = np.random.default_rng((base, i))
                    drawn = [s.example(rng) for s in strategies]
                    try:
                        fn(*fargs, *drawn, **fkwargs)
                    except Exception as exc:
                        note = (f"shim falsifying example "
                                f"#{i}/{n}: {drawn!r}")
                        if hasattr(exc, "add_note"):
                            exc.add_note(note)
                        raise

            # pytest must not mistake the property's drawn parameters for
            # fixtures: hide the wrapped signature (hypothesis does the same)
            del wrapper.__wrapped__
            wrapper.__signature__ = inspect.Signature()
            return wrapper

        return decorate
