"""Scenario/Experiment API: serialization, registry, telemetry-protocol
parity with the legacy step(p) protocol, and ClusterSimulator properties."""

import numpy as np
import pytest

from repro.core.policy import NoCap, OneThreshold, PolcaPolicy, PredictivePolcaPolicy
from repro.core.power_model import A100, ServerPower
from repro.core.simulator import RowSimulator, SimConfig
from repro.core.telemetry import Telemetry, dispatch
from repro.core.traces import build_workload_classes, generate_requests
from repro.experiments import (
    ClusterSimulator,
    FleetSpec,
    PolicySpec,
    Scenario,
    get_scenario,
    list_scenarios,
    run_experiment,
)

SERVER = ServerPower(A100)
WLS, SHARES = build_workload_classes("bloom-176b", SERVER)


# ---------------------------------------------------------------- Scenario
def test_scenario_json_round_trip():
    sc = Scenario(
        name="rt",
        duration_s=3600.0,
        fleet=FleetSpec(n_provisioned=20, added_frac=0.3, n_rows=2),
        policy=PolicySpec("polca", {"t1": 0.78, "t2": 0.9}),
        budget=123456.0,
    )
    assert Scenario.from_json(sc.to_json()) == sc
    # registry entries round-trip too (they are what benchmarks run)
    for name in list_scenarios():
        s = get_scenario(name)
        assert Scenario.from_dict(s.to_dict()) == s


def test_registry_lookup():
    sc = get_scenario("fig14-plus30")
    assert sc.fleet.n_servers == 52 and sc.fleet.n_provisioned == 40
    with pytest.raises(KeyError):
        get_scenario("no-such-scenario")


def test_policy_spec_builds_fresh_instances():
    spec = PolicySpec("polca", {"t1": 0.7})
    a, b = spec.build(), spec.build()
    assert a is not b and a.t1 == 0.7
    assert PolicySpec("one-threshold", {"cap_hp": True}).build().cap_hp
    assert PolicySpec("no-cap").build().name == "no-cap"
    assert PolicySpec("polca-predictive").build().name == "polca-predictive"


# ---------------------------------------------------------------- Telemetry
def _power_walk():
    rng = np.random.default_rng(3)
    p = 0.6
    out = []
    for _ in range(400):
        p = float(np.clip(p + rng.normal(0, 0.04), 0.0, 1.2))
        out.append(p)
    return out


def test_step_and_observe_are_identical_on_bare_fractions():
    """The legacy step(p) shim and observe(Telemetry) must replay the same
    command stream — step IS observe on a wrapped sample."""
    for mk in (PolcaPolicy, lambda: OneThreshold(cap_hp=True), NoCap):
        via_step, via_observe = mk(), mk()
        for p in _power_walk():
            assert via_step.step(p) == via_observe.observe(Telemetry.from_power_frac(p))
        assert via_step.n_brakes == via_observe.n_brakes


class _LegacyOnlyPolicy:
    """Old-protocol policy (no observe): the simulator must still drive it."""

    def __init__(self):
        self.inner = PolcaPolicy()
        self.n_brakes = 0

    def step(self, p):
        cmds = self.inner.step(p)
        self.n_brakes = self.inner.n_brakes
        return cmds


def test_simulator_parity_old_vs_new_protocol():
    """On identical traces, a telemetry-protocol PolcaPolicy and a legacy
    step(p)-only wrapper produce identical simulation results."""
    dur = 1800.0
    reqs = generate_requests(dur, 26, WLS, SHARES, seed=9, occ_kwargs={"peak": 0.9})
    r_new = RowSimulator(WLS, SERVER, 26, 20, PolcaPolicy(), reqs, SHARES,
                         SimConfig(), duration=dur).run()
    r_old = RowSimulator(WLS, SERVER, 26, 20, _LegacyOnlyPolicy(), reqs, SHARES,
                         SimConfig(), duration=dur).run()
    assert r_new.latencies == r_old.latencies
    assert np.array_equal(r_new.power_w, r_old.power_w)
    assert r_new.cap_events == r_old.cap_events
    assert r_new.n_brakes == r_old.n_brakes


def test_dispatch_prefers_observe():
    seen = {}

    class Rich:
        def observe(self, tel):
            seen["tel"] = tel
            return []

        def step(self, p):  # pragma: no cover - must not be called
            raise AssertionError("dispatch must prefer observe")

    tel = Telemetry(t=4.0, power_frac=0.5, lp_power_frac=0.2)
    dispatch(Rich(), tel)
    assert seen["tel"].lp_power_frac == 0.2


def test_simulator_telemetry_sample_is_consistent():
    dur = 900.0
    reqs = generate_requests(dur, 16, WLS, SHARES, seed=4, occ_kwargs={"peak": 0.9})
    sim = RowSimulator(WLS, SERVER, 16, 16, NoCap(), reqs, SHARES,
                       SimConfig(), duration=dur)
    sim.start()
    sim.advance_to(dur / 2)
    tel = sim.sample_telemetry(dur / 2)
    # priority split sums to the row total; phase split is a sub-fraction
    assert tel.hp_power_frac + tel.lp_power_frac == pytest.approx(tel.power_frac)
    assert 0.0 <= tel.prefill_power_frac <= tel.power_frac + 1e-9
    assert tel.rack_power_frac is None and tel.cluster_power_frac is None


def test_predictive_policy_caps_earlier_on_a_ramp():
    """On a steady upward ramp the predictive variant must issue its first
    cap no later than (and with headroom, earlier than) reactive POLCA."""
    ramp = [0.5 + 0.004 * i for i in range(120)]  # crosses T1=0.80 at i=75

    def first_cap_tick(pol):
        for i, p in enumerate(ramp):
            if pol.observe(Telemetry(t=2.0 * i, power_frac=p)):
                return i
        return len(ramp)

    reactive = first_cap_tick(PolcaPolicy())
    predictive = first_cap_tick(PredictivePolcaPolicy())
    assert predictive < reactive
    # prediction must never fabricate a powerbrake
    pol = PredictivePolcaPolicy()
    for i, p in enumerate(ramp):
        pol.observe(Telemetry(t=2.0 * i, power_frac=p))
    assert pol.n_brakes == 0


def test_predictive_policy_escalates_when_lp_share_is_too_small():
    pol = PredictivePolcaPolicy(escalation_ticks=50)
    # drive into T2-capped state
    pol.observe(Telemetry(t=0.0, power_frac=0.95, lp_power_frac=0.5))
    assert pol.t2_capped and not pol.hp_capped
    # LP share (1%) cannot shed the 6% excess over T2 -> immediate HP cap
    cmds = pol.observe(Telemetry(t=2.0, power_frac=0.95, lp_power_frac=0.01))
    assert any(c.hp_freq is not None for c in cmds)
    assert pol.hp_capped


# ---------------------------------------------------------------- Cluster
def _make_rows(n_rows, dur=1200.0, n=24, prov=20, mk=PolcaPolicy):
    rows = []
    for i in range(n_rows):
        reqs = generate_requests(dur, n, WLS, SHARES, seed=100 + i,
                                 occ_kwargs={"peak": 0.9})
        rows.append(RowSimulator(WLS, SERVER, n, prov, mk(), reqs, SHARES,
                                 SimConfig(), duration=dur, row_index=i))
    return rows


def test_cluster_reproduces_single_row_bit_for_bit():
    """Acceptance: per-row budget == single-row budget -> identical results."""
    cres = ClusterSimulator(_make_rows(3), rows_per_rack=2).run()
    solo = [r.run() for r in _make_rows(3)]
    for a, b in zip(cres.row_results, solo):
        assert a.latencies == b.latencies
        assert np.array_equal(a.power_w, b.power_w)
        assert (a.n_brakes, a.cap_events, a.n_completed) == \
               (b.n_brakes, b.cap_events, b.n_completed)


def test_cluster_determinism():
    a = ClusterSimulator(_make_rows(2, dur=900.0), rows_per_rack=2).run()
    b = ClusterSimulator(_make_rows(2, dur=900.0), rows_per_rack=2).run()
    assert np.array_equal(a.cluster_power_frac, b.cluster_power_frac)
    for ra, rb in zip(a.row_results, b.row_results):
        assert ra.latencies == rb.latencies


def test_cluster_hierarchy_accounting():
    cres = ClusterSimulator(_make_rows(4, dur=600.0), rows_per_rack=2).run()
    assert cres.row_power_frac.shape[1] == 4
    assert cres.rack_power_frac.shape[1] == 2
    # budgets default to sums of children: cluster frac == mean of rack fracs
    # weighted equally here (all rows identical)
    np.testing.assert_allclose(cres.cluster_power_frac,
                               cres.rack_power_frac.mean(axis=1), rtol=1e-12)
    np.testing.assert_allclose(cres.cluster_power_frac,
                               cres.row_power_frac.mean(axis=1), rtol=1e-12)
    assert 0.0 < cres.peak_cluster_frac <= 1.3


def test_cluster_rows_see_group_telemetry():
    rows = _make_rows(2, dur=300.0)
    ClusterSimulator(rows, rows_per_rack=2).run()
    # after the first tick, the lockstep driver publishes stale group fracs
    for r in rows:
        rack, cluster = r.group_fracs
        assert rack is not None and cluster is not None
        assert 0.0 < rack < 1.5 and 0.0 < cluster < 1.5


# ---------------------------------------------------------------- runner
def test_run_experiment_row_path_matches_legacy_evaluate():
    from repro.core.oversubscription import evaluate

    sc = Scenario(name="parity", duration_s=2400.0,
                  fleet=FleetSpec(n_provisioned=20, added_frac=0.3))
    o_new = run_experiment(sc)
    o_old = evaluate(PolcaPolicy, WLS, SHARES, SERVER, 20, 26, 2400.0)
    assert o_new.result.latencies == o_old.result.latencies
    assert o_new.stats.summary() == o_old.stats.summary()
    assert o_new.meets == o_old.meets
    assert o_new.throughput_ratio_hp == o_old.throughput_ratio_hp


def test_run_experiment_cluster_path():
    sc = get_scenario("cluster-2rack").with_(duration_s=900.0)
    o = run_experiment(sc)
    assert o.cluster is not None and o.cluster.n_rows == 4
    assert o.n_servers == 4 * sc.fleet.n_servers
    assert o.ref_result is None
    s = o.stats.summary()
    assert s["n_hp"] + s["n_lp"] > 0
