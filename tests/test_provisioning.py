"""Provisioning subsystem: ensemble determinism, composition invariants,
batched-vs-sequential Monte-Carlo bit-parity, planner monotonicity."""

import numpy as np
import pytest

from repro.core.slo import SLO
from repro.core.traces import (
    get_occupancy_generator,
    list_occupancy_generators,
    replication_report,
)
from repro.experiments import (
    FleetSpec,
    PolicySpec,
    Scenario,
    TrafficSpec,
    get_scenario,
    run_experiment,
)
from repro.provisioning import (
    MC_SCENARIO_FAMILY,
    EnsembleSpec,
    RiskConstraints,
    compose_rows,
    compose_site,
    plan_capacity,
    resolve_ensemble_budget,
    run_ensemble,
    run_ensemble_grid,
)

T_GRID = np.arange(0.0, 6 * 3600.0, 60.0)

SMALL = Scenario(
    name="prov-small",
    duration_s=1800.0,
    fleet=FleetSpec(n_provisioned=20, added_frac=0.30),
    policy=PolicySpec("polca"),
    traffic=TrafficSpec(occ_peak=0.9),
    budget="nominal",
    compare_to_reference=False,
)


# ------------------------------------------------------------- generators
def test_generator_family_registered():
    names = list_occupancy_generators()
    for expected in ("diurnal", "bursty", "colocated", "failover-surge",
                     "rack-incident", "nighttime"):
        assert expected in names


@pytest.mark.parametrize("name", ["bursty", "colocated", "failover-surge",
                                  "rack-incident", "nighttime"])
def test_generator_determinism_and_range(name):
    gen = get_occupancy_generator(name)
    a = gen(T_GRID, seed=11, peak=0.62)
    b = gen(T_GRID, seed=11, peak=0.62)
    c = gen(T_GRID, seed=12, peak=0.62)
    assert np.array_equal(a, b), "same seed must replay bit-identically"
    assert not np.array_equal(a, c), "different seeds must differ"
    assert a.shape == T_GRID.shape
    assert a.min() >= 0.05 - 1e-12 and a.max() <= 0.98 + 1e-12


def test_generator_rows_are_deterministic_per_row():
    gen = get_occupancy_generator("bursty")
    r0 = gen(T_GRID, seed=3, peak=0.62, n_rows=4, row=0, rho=0.5)
    r0b = gen(T_GRID, seed=3, peak=0.62, n_rows=4, row=0, rho=0.5)
    r1 = gen(T_GRID, seed=3, peak=0.62, n_rows=4, row=1, rho=0.5)
    assert np.array_equal(r0, r0b)
    assert not np.array_equal(r0, r1), "rows must decorrelate at rho<1"


def test_rack_incident_zeroes_lost_rack_rows():
    gen = get_occupancy_generator("rack-incident")
    rows = [gen(T_GRID, seed=5, peak=0.62, n_rows=4, row=r, rows_per_rack=2)
            for r in range(4)]
    floors = [np.isclose(r, 0.05).mean() for r in rows]
    # exactly one rack (2 rows) sits at the idle floor during the incident
    assert sum(f > 0.2 for f in floors) == 2, floors


# ------------------------------------------------------------ composition
def test_compose_rows_correlation_extremes():
    base = get_occupancy_generator("diurnal")(T_GRID, seed=1, peak=0.62)
    sync = compose_rows(base, 3, rho=1.0, seed=9, t_grid=T_GRID)
    indep = compose_rows(base, 3, rho=0.0, seed=9, t_grid=T_GRID)
    assert np.array_equal(sync[0], sync[1]), "rho=1: rows identical"
    assert not np.array_equal(indep[0], indep[1]), "rho=0: rows differ"
    assert sync.shape == (3, len(T_GRID))


def test_compose_site_conservation_invariants():
    rng = np.random.default_rng(0)
    row_w = rng.uniform(10.0, 100.0, size=(6, 40))
    site = compose_site(row_w, rows_per_rack=2)
    assert site.rack_w.shape == (3, 40)
    for k in range(3):
        np.testing.assert_allclose(site.rack_w[k],
                                   row_w[site.rack_of == k].sum(axis=0),
                                   rtol=1e-12)
    np.testing.assert_allclose(site.site_w, row_w.sum(axis=0), rtol=1e-12)
    np.testing.assert_allclose(site.site_w, site.rack_w.sum(axis=0), rtol=1e-12)
    # the full per-node series is carried too (leaves, racks, root)
    assert site.node_w.shape == (6 + 3 + 1, 40)
    assert site.node_names[-1] == "cluster"


def test_compose_site_rejects_ragged_racks():
    """Regression: n_rows not divisible by rows_per_rack used to compose a
    silently mis-sized tail rack; it must raise a clear ValueError now."""
    row_w = np.ones((5, 16))
    with pytest.raises(ValueError, match="do not divide into racks"):
        compose_site(row_w, rows_per_rack=2)
    with pytest.raises(ValueError, match="rows_per_rack"):
        compose_site(np.ones((4, 8)), rows_per_rack=0)
    # an explicit hierarchy is the sanctioned escape hatch for ragged trees
    from repro.core.hierarchy import PowerHierarchy
    ragged = PowerHierarchy.two_level(np.ones(5), rows_per_rack=2)
    site = compose_site(row_w, hierarchy=ragged)
    assert site.rack_w.shape == (3, 16)
    np.testing.assert_allclose(site.site_w, row_w.sum(axis=0), rtol=1e-12)


# ---------------------------------------------------------------- registry
def test_mc_scenarios_registered_and_serializable():
    for name in MC_SCENARIO_FAMILY:
        sc = get_scenario(name)
        assert Scenario.from_json(sc.to_json()) == sc


# --------------------------------------------------------------- ensembles
def test_ensemble_determinism_and_worker_invariance():
    spec1 = EnsembleSpec(SMALL, n_seeds=3, seed0=700, n_workers=1)
    spec2 = EnsembleSpec(SMALL, n_seeds=3, seed0=700, n_workers=2)
    a, b, c = run_ensemble(spec1), run_ensemble(spec1), run_ensemble(spec2)
    for other in (b, c):
        assert np.array_equal(a.power_frac, other.power_frac)
        assert np.array_equal(a.brake_counts, other.brake_counts)
        for ma, mo in zip(a.members, other.members):
            assert ma.result.latencies == mo.result.latencies


def test_batched_bit_parity_with_sequential_run_experiment():
    """Acceptance: the batched engine reproduces a sequential
    ``run_experiment`` loop bit-for-bit on a 4-member ensemble."""
    spec = EnsembleSpec(SMALL, n_seeds=4, seed0=900, n_workers=2)
    ens = run_ensemble(spec)
    for m, sc in zip(ens.members, spec.member_scenarios(ens.budget_w)):
        o = run_experiment(sc)
        assert m.result.latencies == o.result.latencies
        assert np.array_equal(m.result.power_w, o.result.power_w)
        assert (m.result.n_brakes, m.result.cap_events, m.result.n_completed) \
            == (o.result.n_brakes, o.result.cap_events, o.result.n_completed)
        assert m.result.peak_power_frac == o.result.peak_power_frac


def test_batched_reference_mode_matches_run_experiment_stats():
    spec = EnsembleSpec(SMALL, n_seeds=2, seed0=900, n_workers=1,
                        with_reference=True)
    ens = run_ensemble(spec)
    for m, sc in zip(ens.members, spec.member_scenarios(ens.budget_w)):
        o = run_experiment(sc)
        assert m.result.latencies == o.result.latencies
        assert m.stats.summary() == o.stats.summary()
        assert m.meets == o.meets


def test_ensemble_distributional_telemetry():
    ens = run_ensemble(EnsembleSpec(SMALL, n_seeds=3, seed0=700, n_workers=1))
    counts, cdf = ens.brake_cdf()
    assert len(counts) == 3 and cdf[-1] == 1.0
    assert np.all(np.diff(cdf) >= 0)
    levels = [0.2, 0.6, 1.0]
    pe = ens.peak_exceedance(levels)
    pw = ens.power_exceedance(levels)
    for curve in (pe, pw):
        assert np.all(curve >= 0.0) and np.all(curve <= 1.0)
        assert np.all(np.diff(curve) <= 1e-12), "exceedance must be decreasing"
    assert 0.0 <= ens.brake_prob() <= 1.0
    assert ens.power_frac.shape[0] == 3


def test_ensemble_grid_groups_by_scenario():
    other = SMALL.with_(name="prov-small-nocap", policy=PolicySpec("no-cap"))
    out = run_ensemble_grid([SMALL, other], n_seeds=2, seed0=700, n_workers=2)
    assert set(out) == {"prov-small", "prov-small-nocap"}
    solo = run_ensemble(EnsembleSpec(SMALL, n_seeds=2, seed0=700, n_workers=1))
    assert np.array_equal(out["prov-small"].brake_counts, solo.brake_counts)
    assert np.array_equal(out["prov-small"].power_frac, solo.power_frac)


# ------------------------------------------------------------------ planner
def test_planner_monotonic_in_risk_constraints():
    """Acceptance: tighter risk bound -> fewer deployable servers."""
    base = SMALL.with_fleet(added_frac=0.0)
    kw = dict(n_seeds=2, seed0=810, max_added_frac=0.5, n_workers=2)
    loose = plan_capacity(base, constraints=RiskConstraints(
        max_brake_prob=1.0, max_slo_violation_prob=1.0), **kw)
    mid = plan_capacity(base, constraints=RiskConstraints(
        max_brake_prob=1.0, max_slo_violation_prob=1.0,
        slo=SLO(hp_p50=10.0, hp_p99=10.0, lp_p50=10.0, lp_p99=10.0)), **kw)
    tight = plan_capacity(base, constraints=RiskConstraints(
        max_brake_prob=0.0, max_slo_violation_prob=0.0), **kw)
    assert loose.capped and loose.safe_added_servers == 10
    assert tight.safe_added_servers <= mid.safe_added_servers
    assert mid.safe_added_servers <= loose.safe_added_servers
    assert tight.safe_added_servers < loose.safe_added_servers
    assert tight.probes, "planner must record its probes"
    assert tight.budget_w == pytest.approx(loose.budget_w)


def test_planner_monotonic_in_brake_budget():
    """Loosening the per-horizon brake-count budget (max_brakes) admits
    fleets at least as large, and a brake budget sits between zero-tolerance
    and unconstrained (ROADMAP open item: brake budgets, not just zero)."""
    # a budget tight enough that brake counts grow with the fleet (nominal
    # would never brake inside the search range)
    budget = 0.88 * 20 * SMALL.fleet.server().provisioned_w
    base = SMALL.with_fleet(added_frac=0.0).with_(budget=budget)
    slo_off = SLO(hp_p50=10.0, hp_p99=10.0, lp_p50=10.0, lp_p99=10.0,
                  max_powerbrakes=10**9)
    kw = dict(n_seeds=2, seed0=810, max_added_frac=0.5, n_workers=2,
              budget_w=budget)
    plans = [plan_capacity(base, constraints=RiskConstraints(
                 max_brakes=mb, slo=slo_off,
                 max_slo_violation_prob=1.0), **kw)
             for mb in (0, 20, 10**6)]
    sizes = [p.safe_added_servers for p in plans]
    assert sizes == sorted(sizes), f"brake budget must be monotone: {sizes}"
    assert plans[-1].capped and sizes[-1] == 10
    assert sizes[0] < sizes[-1], "zero-tolerance must bind on this envelope"
    assert all(p.probes for p in plans), "planner must record its probes"
    assert plans[1].budget_w == pytest.approx(plans[0].budget_w)
    # the underlying exceedance is monotone in the brake budget too
    ens = run_ensemble(EnsembleSpec(SMALL, n_seeds=3, seed0=810, n_workers=1))
    probs = [ens.brake_prob(k) for k in (0, 1, 5, 10**6)]
    assert probs == sorted(probs, reverse=True)
    assert probs[-1] == 0.0


def test_planner_reports_infeasible_at_zero():
    # a budget so tight even the provisioned fleet brakes
    base = SMALL.with_fleet(added_frac=0.0).with_(budget=1000.0)
    plan = plan_capacity(base, n_seeds=2, seed0=810, n_workers=1,
                         budget_w=1000.0)
    assert plan.safe_added_servers == 0 and not plan.feasible_at_zero


# ------------------------------------------------------------- cvar gate
HOT = SMALL.with_(power_scale=1.15, traffic=TrafficSpec(occ_peak=0.95))


def _dense_tail(n_seeds=64):
    return run_ensemble(EnsembleSpec(HOT, n_seeds=n_seeds, seed0=5),
                        engine="jax")


def test_cvar_monotone_in_alpha():
    """CVaR averages a shrinking worst-case tail, so it is nondecreasing in
    alpha — on brake counts and on the SLO-impact tail alike."""
    ens = _dense_tail()
    alphas = [0.0, 0.25, 0.5, 0.75, 0.9, 0.95]
    brake = [ens.brake_cvar(a) for a in alphas]
    slo = [ens.slo_cvar("low", a) for a in alphas]
    assert all(b2 >= b1 - 1e-12 for b1, b2 in zip(brake, brake[1:]))
    assert all(s2 >= s1 - 1e-12 for s1, s2 in zip(slo, slo[1:]))
    # alpha=0 degenerates to the plain mean
    np.testing.assert_allclose(ens.brake_cvar(0.0),
                               ens.brake_counts.mean(), rtol=1e-12)


def test_cvar_degenerates_to_max_as_alpha_approaches_one():
    """Once the (1 - alpha) tail holds <= 1 member, CVaR is the sample
    max — the max-brake / worst-member statistic."""
    ens = _dense_tail()
    n = ens.n_members
    alpha = 1.0 - 0.5 / n  # tail mass 0.5 member
    np.testing.assert_allclose(ens.brake_cvar(alpha),
                               float(ens.brake_counts.max()), rtol=0.0)
    per_member = [float(np.percentile(m.stats.lp_impacts, 99.0))
                  if len(m.stats.lp_impacts) else 0.0 for m in ens.members]
    np.testing.assert_allclose(ens.slo_cvar("low", alpha), max(per_member),
                               rtol=1e-12)
    with pytest.raises(ValueError):
        ens.brake_cvar(1.0)  # alpha must stay < 1


def test_planner_cvar_gate_infeasible_at_zero_on_dense_tail():
    """With a zero CVaR budget on a tail that has real LP capping impact,
    the dense-jax plan is infeasible even at zero added servers — the gate
    actually bites (other gates are opened wide so only CVaR can fail)."""
    ens = _dense_tail(n_seeds=16)
    assert ens.slo_cvar("low", 0.9) > 0.0  # the tail is genuinely loaded
    base = HOT.with_fleet(added_frac=0.0)
    # an envelope 20% under nominal: even the provisioned fleet caps LP
    tight = 0.8 * resolve_ensemble_budget(base)
    cons = RiskConstraints(max_brakes=10 ** 9, max_slo_violation_prob=1.0,
                           slo_cvar_alpha=0.9, max_slo_cvar=0.0,
                           slo_cvar_priority="low")
    plan = plan_capacity(base, n_seeds=16, seed0=5, engine="jax",
                         budget_w=tight, constraints=cons)
    assert plan.safe_added_servers == 0 and not plan.feasible_at_zero
    assert all(p.slo_cvar is not None and p.slo_cvar > 0.0
               for p in plan.probes)
    # loosening the CVaR budget past the observed tail re-admits the fleet
    loose = RiskConstraints(max_brakes=10 ** 9, max_slo_violation_prob=1.0,
                            slo_cvar_alpha=0.9, max_slo_cvar=1e9,
                            slo_cvar_priority="low")
    plan2 = plan_capacity(base, n_seeds=16, seed0=5, engine="jax",
                          budget_w=tight, constraints=loose)
    assert plan2.feasible_at_zero
    assert plan2.safe_added_servers >= plan.safe_added_servers


def test_planner_cvar_requires_enough_seeds():
    """alpha's tail must hold >= 1 full member: n_seeds >= 1 / (1 - alpha)."""
    with pytest.raises(ValueError, match="n_seeds >= 20"):
        plan_capacity(HOT, n_seeds=8, engine="jax",
                      constraints=RiskConstraints(slo_cvar_alpha=0.95))


def test_planner_survive_requires_numpy_engine():
    """The survivability gate rides the routed FleetSimulator, which the
    batched tick engines reject."""
    from repro.chaos.faults import FaultEvent, FaultSpec
    from repro.experiments.scenario import RoutingSpec

    routed = HOT.with_(routing=RoutingSpec(router="round-robin"))
    survive = FaultSpec(
        (FaultEvent("site-demand-response", t=600.0, factor=0.9,
                    until=1200.0),))
    with pytest.raises(ValueError, match="engine='numpy'"):
        plan_capacity(routed, n_seeds=4, engine="jax",
                      constraints=RiskConstraints(survive=survive))


# ---------------------------------------------------------------- traces
def test_replication_report_public_api():
    sc = get_scenario("table2-baseline").with_(duration_s=6 * 3600.0)
    res = run_experiment(sc).result
    from benchmarks.common import SERVER, bloom_workloads
    wls, shares = bloom_workloads()
    rep = replication_report(res.power_t, res.power_w, wls, shares, SERVER,
                             40, 40, occ_peak=sc.traffic.occ_peak,
                             duration_s=sc.duration_s)
    assert np.isfinite(rep.mape) and rep.mape >= 0.0
    assert len(rep.sim_smooth) == len(rep.target_smooth) > 0
